"""Paper Table 1: model-size feasibility and time-to-converge.

Four parts:
  (a) feasibility arithmetic at the paper's true scales (Pubmed/Wiki
      unigram/bigram × K) — per-worker model bytes under MP (V·K/(S·M))
      vs DP (V·K), against the paper's 8 GB low-end node (and the v5e
      16 GB HBM of the target deployment), swept over the
      ``blocks_per_worker`` pipeline depth S;
  (b) measured time-to-target-likelihood on a scaled-down grid of model
      sizes, MP vs DP, on this container;
  (c) measured ``blocks_per_worker`` sweep: peak resident block bytes vs
      total model bytes (asserting the ceil(V/(S·M))×K law) and the
      per-iteration cost of deeper pipelining;
  (d) measured hybrid (D, M, S) sweep over the 2D (data, model) grid
      (DESIGN.md §8) at a fixed total worker budget: resident bytes stay
      a function of S·M only, distributed bytes grow with D, and the
      per-round-synced staleness error stays orders below the AD-LDA
      corner (D = R, M = 1);
  (e) the K ≥ 64k big-model point (DESIGN.md §13): a subprocess streams
      a sharded zipf corpus through `StreamingLDA` at V×K = 8192×65536
      (2 GiB of dense counts), trains, and exports a sharded serving
      snapshot — while ``ru_maxrss`` stays well under the full model
      size, the measured proof that neither the corpus nor the model is
      ever resident.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks.common import emit_csv_row, save_result
from repro.configs.lda_paper import LDA_CONFIGS
from repro.core.counts import model_bytes
from repro.core.data_parallel import DataParallelLDA
from repro.core.model_parallel import ModelParallelLDA
from repro.data.synthetic import synthetic_corpus

NODE_RAM = 8 * 2 ** 30          # paper's low-end cluster node
V5E_HBM = 16 * 2 ** 30          # target chip
WORKERS = 64                    # paper's Table-1 cluster size
BLOCK_SWEEP = (1, 2, 4)         # blocks_per_worker (S) pipeline depths


def feasibility():
    """Dense counts = the TPU adaptation (HBM-resident int32 blocks);
    sparse bound = the paper's CPU hash-map storage, where nonzeros are
    bounded by the corpus token count (≈12 B per nonzero entry)."""
    rows = []
    for name, cfg in LDA_CONFIGS.items():
        per_mp, total = model_bytes(cfg.vocab_size, cfg.num_topics, WORKERS)
        per_dp, _ = model_bytes(cfg.vocab_size, cfg.num_topics, 1)
        nnz = min(cfg.num_tokens, cfg.model_variables)
        sparse_total = nnz * 12
        rows.append({
            "config": name,
            "model_variables": cfg.model_variables,
            "dense_total_gib": round(total / 2 ** 30, 2),
            "dense_dp_per_worker_gib": round(per_dp / 2 ** 30, 2),
            "dense_mp_per_worker_gib": round(per_mp / 2 ** 30, 2),
            # resident block under an S-deep pipeline: V·K/(S·M) — the
            # model-capacity lever independent of worker count
            "dense_mp_resident_gib_by_s": {
                s: round(model_bytes(cfg.vocab_size, cfg.num_topics,
                                     WORKERS,
                                     blocks_per_worker=s)[0] / 2 ** 30, 3)
                for s in BLOCK_SWEEP},
            "sparse_dp_per_worker_gib": round(sparse_total / 2 ** 30, 2),
            "sparse_mp_per_worker_gib": round(
                sparse_total / WORKERS / 2 ** 30, 3),
            "dp_fits_8gb_node_sparse": sparse_total < NODE_RAM,
            "mp_fits_8gb_node_sparse": sparse_total / WORKERS < NODE_RAM,
            "mp_fits_v5e_dense": per_mp * 64 / 256 < V5E_HBM,
        })
    return rows


def pipeline_sweep(seed=0, workers=8):
    """Measured S sweep: peak resident block bytes vs total model bytes.

    Asserts the resident-memory law the refactor exists for — the block a
    worker actively holds is exactly ``ceil(V/(S·M)) × K`` int32 rows —
    and reports the wall-clock cost of the deeper rotation."""
    vocab, topics = 1600, 32
    corpus, _, _ = synthetic_corpus(250, vocab, topics, 50, seed=seed)
    total_bytes = vocab * topics * 4
    rows = []
    for s in BLOCK_SWEEP:
        lda = ModelParallelLDA(corpus, topics, workers, seed=seed,
                               blocks_per_worker=s)
        rep = lda.memory_report()
        vb = -(-vocab // (s * workers))
        assert lda.resident_block_rows == vb, (s, lda.resident_block_rows)
        assert rep["resident_block_bytes"] == vb * topics * 4, rep
        t0 = time.time()
        lda.run(3)
        rows.append({
            "blocks_per_worker": s,
            "num_blocks": rep["num_blocks"],
            "resident_block_shape": list(rep["resident_block_shape"]),
            "peak_resident_block_bytes": rep["resident_block_bytes"],
            "total_model_bytes": total_bytes,
            "resident_fraction": round(
                rep["resident_block_bytes"] / total_bytes, 4),
            "seconds_3_iters": round(time.time() - t0, 2),
            "log_likelihood": lda.log_likelihood(),
        })
    return rows


def hybrid_sweep(seed=0):
    """Measured (D, M, S) sweep on the hybrid 2D grid: every row uses the
    same corpus and (mostly) the same total worker count R = D·M, so the
    numbers isolate how the grid SHAPE trades memory against staleness.

    The AD-LDA corner (M=1) carries the full table per replica and syncs
    once per S rounds; the pure-MP corner (D=1) has zero cross-replica
    staleness; hybrids sit in between — the paper's Fig 2–4 story as one
    table.
    """
    vocab, topics = 1600, 32
    corpus, _, _ = synthetic_corpus(250, vocab, topics, 50, seed=seed)
    rows = []
    for d, m, s in [(1, 8, 1), (2, 4, 1), (4, 2, 1), (8, 1, 1),
                    (2, 4, 2), (4, 2, 2), (2, 2, 4)]:
        lda = ModelParallelLDA(corpus, topics, m, seed=seed,
                               data_parallel=d, blocks_per_worker=s)
        rep = lda.memory_report()
        vb = -(-vocab // (s * m))
        assert rep["resident_block_bytes"] == vb * topics * 4, rep
        assert rep["distributed_model_bytes"] == \
            d * rep["replica_model_bytes"], rep
        t0 = time.time()
        lda.run(3)
        rows.append({
            "data_parallel": d,
            "num_workers": m,
            "blocks_per_worker": s,
            "grid_rows": rep["num_shards"],
            "num_blocks": rep["num_blocks"],
            "resident_block_bytes": rep["resident_block_bytes"],
            "replica_model_bytes": rep["replica_model_bytes"],
            "distributed_model_bytes": rep["distributed_model_bytes"],
            "seconds_3_iters": round(time.time() - t0, 2),
            "delta_error": lda.delta_error(),
            "log_likelihood": lda.log_likelihood(),
        })
    return rows


def measured(seed=0):
    """Scaled-down Table 1: grow V×K, measure time to reach a target LL."""
    rows = []
    for vocab, topics in [(800, 16), (1600, 32), (3200, 64)]:
        corpus, _, _ = synthetic_corpus(250, vocab, topics, 50, seed=seed)
        results = {}
        for name, engine in [
                ("mp", ModelParallelLDA(corpus, topics, 8, seed=seed)),
                ("dp", DataParallelLDA(corpus, topics, 8, seed=seed))]:
            # target: 97% of the gap from initial LL to a converged LL
            ll0 = engine.log_likelihood()
            probe = ModelParallelLDA(corpus, topics, 8, seed=seed + 1)
            probe.run(20)
            target = ll0 + 0.97 * (probe.log_likelihood() - ll0)
            t0 = time.time()
            iters = 0
            while engine.log_likelihood() < target and iters < 40:
                engine.step()
                iters += 1
            results[name] = {"iters": iters,
                             "seconds": round(time.time() - t0, 2),
                             "reached": engine.log_likelihood() >= target}
        rows.append({"vocab": vocab, "topics": topics,
                     "model_vars": vocab * topics, **results})
    return rows


_BIG_STREAM_SCRIPT = r"""
import json, os, resource, sys, tempfile, time
workdir, store = sys.argv[1], sys.argv[2]
vocab, topics, m, s, docs, doc_len = (int(x) for x in sys.argv[3:9])
from repro.data.stream import ShardedCorpus, write_zipf_stream
from repro.core.engine.streaming import StreamingLDA
write_zipf_stream(os.path.join(workdir, "corpus"), num_docs=docs,
                  vocab_size=vocab, doc_len=doc_len, zipf_a=1.1, seed=0,
                  docs_per_shard=64)
sc = ShardedCorpus(os.path.join(workdir, "corpus"))
lda = StreamingLDA(sc, os.path.join(workdir, "run"), topics, m,
                   blocks_per_worker=s, sampler_mode="sparse", seed=0,
                   store=store)
iters = []
for _ in range(2):
    t0 = time.perf_counter()
    lda.step()
    iters.append(round(time.perf_counter() - t0, 2))
lda.save_checkpoint()
lda.save_snapshot_sharded(os.path.join(workdir, "snap"))
rep = lda.memory_report()
peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
print("BIGSTREAM " + json.dumps({
    "vocab": vocab, "topics": topics, "num_workers": m,
    "blocks_per_worker": s, "num_blocks": rep["num_blocks"],
    "num_tokens": sc.num_tokens, "sampler": "sparse", "store": store,
    "resident_block_bytes": rep["resident_block_bytes"],
    "total_model_bytes": rep["total_model_bytes"],
    "resident_store_bytes": rep["resident_store_bytes"],
    "total_store_bytes": rep["total_store_bytes"],
    "store_occupancy": rep["store_occupancy"],
    "peak_rss_bytes": peak, "iter_seconds": iters,
    "log_likelihood": None}))
"""


def _run_stream(store, vocab, topics, m, s, docs=256, doc_len=32,
                timeout=3600):
    """One out-of-core streaming run in a subprocess (so ``ru_maxrss``
    reflects that workload alone) -> its measured row, or an error."""
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "src"))
        out = subprocess.run(
            [sys.executable, "-c", _BIG_STREAM_SCRIPT, td, store,
             str(vocab), str(topics), str(m), str(s), str(docs),
             str(doc_len)],
            env=env, capture_output=True, text=True, timeout=timeout)
        if out.returncode != 0:
            return {"error": out.stderr[-2000:]}
        line = [ln for ln in out.stdout.splitlines()
                if ln.startswith("BIGSTREAM ")][0]
        row = json.loads(line[len("BIGSTREAM "):])
    row["peak_rss_gib"] = round(row["peak_rss_bytes"] / 2 ** 30, 3)
    row["total_model_gib"] = round(row["total_model_bytes"] / 2 ** 30, 3)
    row["resident_block_mib"] = round(
        row["resident_block_bytes"] / 2 ** 20, 1)
    row["resident_store_mib"] = round(
        row["resident_store_bytes"] / 2 ** 20, 3)
    row["rss_fraction_of_model"] = round(
        row["peak_rss_bytes"] / row["total_model_bytes"], 3)
    # the whole point: the full dense model never became resident
    row["out_of_core"] = row["peak_rss_bytes"] < row["total_model_bytes"]
    return row


def big_model_stream():
    """(e) The K = 65536 point: train + checkpoint + sharded-snapshot
    export entirely out of core, with the OS-measured peak RSS as the
    resident ceiling (geometry unchanged since the point was first
    recorded — the trajectory stays comparable)."""
    return _run_stream("dense", 8192, 65536, 2, 8)


def tail_store_stream():
    """(f) The CountStore memory claim, measured (DESIGN.md §16).

    Pair point: the K = 65536 streaming run again at S = 2 — resident
    dense blocks of ``[4096, 65536]`` (1 GiB) plus the sparse prologue's
    dense-shaped f32 buffers — under ``store="dense"`` vs
    ``store="tail"``, same seed, same Zipf corpus, bitwise the same
    chain; the ratio of measured ``ru_maxrss`` ceilings is the headline
    (target >= 4x).  Beyond-dense point: V x K = 16384 x 262144 — a
    16 GiB dense model whose S = 8 dense streaming run would hold
    1 GiB resident blocks and several dense-shaped f32 prologue buffers,
    past the paper's 8 GiB node budget — runs under the tail store with
    a flat ceiling."""
    pair = {}
    for store in ("dense", "tail"):
        pair[store] = _run_stream(store, 8192, 65536, 2, 2)
    out = {"pair_k64k_s2": pair, "ratio_target": 4.0}
    if all("error" not in r for r in pair.values()):
        out["rss_ratio_dense_over_tail"] = round(
            pair["dense"]["peak_rss_bytes"]
            / pair["tail"]["peak_rss_bytes"], 2)
        out["ratio_met"] = out["rss_ratio_dense_over_tail"] >= 4.0
    vocab, topics = 16384, 262144
    dense_total = vocab * topics * 4
    beyond = _run_stream("tail", vocab, topics, 2, 8)
    out["beyond_dense_k256k"] = beyond
    out["beyond_dense_total_model_gib"] = round(dense_total / 2 ** 30, 2)
    out["node_ram_gib"] = round(NODE_RAM / 2 ** 30, 1)
    # why this point was previously out of reach: the DENSE total model
    # alone is 2x the paper's low-end node, before any f32 working set
    out["dense_model_exceeds_node_ram"] = dense_total > NODE_RAM
    if "error" not in beyond:
        out["tail_fits_node_ram"] = beyond["peak_rss_bytes"] < NODE_RAM
    return out


def run():
    out = {"feasibility_paper_scale": feasibility(),
           "measured_scaled_down": measured(),
           "blocks_per_worker_sweep": pipeline_sweep(),
           "hybrid_dms_sweep": hybrid_sweep(),
           "big_model_stream_64k": big_model_stream(),
           "tail_store_stream": tail_store_stream()}
    save_result("table1_model_size", out)
    big = out["feasibility_paper_scale"][-1]
    m = out["measured_scaled_down"][-1]
    deep = out["blocks_per_worker_sweep"][-1]
    hyb = out["hybrid_dms_sweep"][1]          # (D=2, M=4, S=1) hybrid row
    stream = out["big_model_stream_64k"]
    stream_note = (
        f"k64k_peak_rss_gib={stream['peak_rss_gib']};"
        f"k64k_model_gib={stream['total_model_gib']};"
        f"k64k_out_of_core={stream['out_of_core']}"
        if "error" not in stream else "k64k=ERROR")
    ts = out["tail_store_stream"]
    if "rss_ratio_dense_over_tail" in ts:
        stream_note += (
            f";tail_rss_ratio={ts['rss_ratio_dense_over_tail']}"
            f";tail_ratio_met={ts['ratio_met']}")
    else:
        stream_note += ";tail_rss_ratio=ERROR"
    beyond = ts.get("beyond_dense_k256k", {})
    stream_note += (
        f";k256k_tail_peak_rss_gib={beyond['peak_rss_gib']}"
        f";k256k_dense_model_gib={ts['beyond_dense_total_model_gib']}"
        if "error" not in beyond else ";k256k=ERROR")
    emit_csv_row("table1_model_size", m["mp"]["seconds"] * 1e6,
                 f"bigram10k_dp_dense_gib={big['dense_dp_per_worker_gib']};"
                 f"mp_dense_gib={big['dense_mp_per_worker_gib']};"
                 f"mp_sparse_fits_8gb={big['mp_fits_8gb_node_sparse']};"
                 f"mp_iters={m['mp']['iters']};dp_iters={m['dp']['iters']};"
                 f"s{deep['blocks_per_worker']}_resident_frac="
                 f"{deep['resident_fraction']};"
                 f"hybrid_d{hyb['data_parallel']}m{hyb['num_workers']}"
                 f"_delta={hyb['delta_error']:.5f};{stream_note}")
    return out


if __name__ == "__main__":
    run()
