"""O(1) alias-table Metropolis–Hastings sampler backend (LightLDA-style).

The exact samplers (``scan``/``batched``/``pallas``) pay O(K) per token:
an inverse-CDF draw must touch every topic lane.  LightLDA (Yuan et al.
2014) replaces the exact draw with a cycle of two Metropolis–Hastings
proposals that factor the eq.-(1) conditional through the word-major
buckets of SparseLDA (`core/sparse.py`):

  * **word proposal**  ``q_w(k) ∝ Ĉ_k^t + β``  — drawn from a Vose alias
    table built per *word row* of the resident block at round start;
  * **doc proposal**   ``q_d(k) ∝ Ĉ_d^k + α_k`` — drawn from an alias
    table built per *local document row* at round start.

Each proposal is corrected by the exact eq.-(1) acceptance ratio

    A(s -> t) = min(1, [π(t) q(s)] / [π(s) q(t)])

so the chain targets the same collapsed posterior as the exact samplers
even though the proposal tables are stale (built from round-start counts
Ĉ) and the proposal priors are quantized to the integer grid of
`core/alias.py` (the acceptance evaluates q from that same grid, so the
quantization shifts only the proposal, never the target).  Per-token
cost is O(1) amortized: the draw is two table lookups, the acceptance a
handful of scalar count gathers; the O((Vb + D_loc)·K) table build is
shared by every token that samples against it, and HOW LONG a table is
shared is the ``table_lifetime`` schedule (see below) — once per block
per round originally, once per iteration under traveling tables.

Determinism: every decision (cell pick, alias resolve, accept) compares
values produced by single IEEE ops on integer-derived operands — the
acceptance test is the division-free cross-multiplied form

    u·π(s)·q(t) < π(t)·q(s)   ⇔   u < A(s -> t)

(π = N/D expanded so only multiplications remain) — because f32
reductions and divisions do NOT lower bit-identically across the vmap /
shard_map / host-oracle compilations of this sampler, and draw-for-draw
replay (`kvstore`) plus cross-backend bit-identity demand that the SAME
uniforms always produce the SAME draws.

Staleness model (DESIGN.md §9): like ``batched``, this sampler freezes
the block-local counts at round start and applies the ¬dn self-exclusion
as a rank-1 correction at the token's round-start assignment; count
deltas fold in exactly at round end.  Draws are therefore
*distribution-equal* but not trajectory-equal to the exact chain —
validated statistically (`tests/test_mh_stats.py`) instead of bitwise.

Table lifetime (DESIGN.md §10): the acceptance ratio evaluates the
*target* from the live (round-start frozen) counts and the *proposal*
density from the table's own ``W`` grid, so ANY table with full support
keeps the chain exact — tables may be arbitrarily stale.  Two build
schedules exploit this:

* ``round`` — :func:`sweep_block_mh` builds word + doc tables from the
  round-start counts on every call (the original schedule, O((Vb +
  D_loc)·K) per block per round);
* ``iteration`` — the engine builds each block's word table once per
  iteration (at the block's first residency) and the doc tables once per
  iteration (from iteration-start ``cdk``), then feeds them to
  :func:`sweep_block_mh_tables` for every subsequent round.  Word tables
  travel the ring with their block in the packed ``core/alias.py``
  layout; the per-iteration build cost drops from ``B = S·M`` builds to
  ``S`` word builds + 1 doc build per worker.

Randomness: the engine supplies ONE external uniform per token per round.
:func:`uniform_streams` expands it into the ``4·num_cycles`` sub-draws a
token's MH cycle consumes via a splitmix32 hash of the uniform's IEEE
bits — pure integer arithmetic, mirrored bit-for-bit by
:func:`uniform_streams_np`, so a device MH run is replayable draw-for-draw
against the `kvstore` host oracle fed the same uniforms.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alias import (alias_resolve, build_alias_tables,
                              pack_tables, split_cell_uniform,
                              unpack_tables)

# MH proposal cycles per token per round (each cycle = one word proposal +
# one doc proposal, LightLDA's default depth).
DEFAULT_MH_CYCLES = 2

_GOLDEN = 0x9E3779B9          # stream-id spacing (Weyl constant)
_M1, _M2 = 0x21F0AAAD, 0x735A2D97  # splitmix32 finalizer multipliers


def _splitmix32(x):
    """splitmix32 finalizer on uint32 (jnp); wraps mod 2**32."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(_M2)
    x = x ^ (x >> 15)
    return x


def uniform_streams(u: jax.Array, n: int) -> jax.Array:
    """Expand uniforms ``u`` [T] into ``n`` streams -> [n, T] f32.

    Stream ``i`` at token slot ``t`` hashes the IEEE-754 bits of ``u[t]``
    xored with ``(i+1)·GOLDEN`` and a token-lane salt ``t·M1``; uniforms
    are the top 24 bits scaled to [0, 1).  The lane salt matters: the
    engine's externally drawn f32 uniforms carry only 24 payload bits, so
    within a big block two tokens WILL collide — without the salt they
    would then share every proposal/accept sub-draw of the round.  The
    slot index is part of the shared (engine, host-oracle) token layout,
    so replayability is unaffected.
    """
    bits = jax.lax.bitcast_convert_type(u.astype(jnp.float32), jnp.uint32)
    lane = jnp.arange(u.shape[0], dtype=jnp.uint32) * jnp.uint32(_M1)
    ids = (jnp.arange(1, n + 1, dtype=jnp.uint32)
           * jnp.uint32(_GOLDEN))[:, None]
    h = _splitmix32((bits ^ lane)[None, :] ^ ids)
    return (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0 ** -24)


def uniform_streams_np(u: np.ndarray, n: int) -> np.ndarray:
    """Bit-exact numpy mirror of :func:`uniform_streams` (for tests)."""
    bits = np.asarray(u, np.float32).view(np.uint32)
    lane = (np.arange(bits.shape[0], dtype=np.uint32) * np.uint32(_M1))
    ids = (np.arange(1, n + 1, dtype=np.uint32)
           * np.uint32(_GOLDEN))[:, None]
    x = (bits ^ lane)[None, :] ^ ids
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(_M1)
    x = x ^ (x >> np.uint32(15))
    x = x * np.uint32(_M2)
    x = x ^ (x >> np.uint32(15))
    return (x >> np.uint32(8)).astype(np.float32) * np.float32(2.0 ** -24)


# ---------------------------------------------------------------------------
# Acceptance ratio (pure, for unit tests / closed-form checks)
# ---------------------------------------------------------------------------

def accept_ratio(pi_new, pi_old, q_new, q_old):
    """MH acceptance ratio for proposal ``old -> new``:
    ``[π(new) q(old)] / [π(old) q(new)]``.  With ``q ∝ π`` this is
    identically 1 (the proposal IS the target).  The samplers decide
    ``u < ratio`` in the algebraically equivalent cross-multiplied form
    (see module docstring); this quotient form is the specification the
    unit tests pin down.
    """
    return (pi_new * q_old) / (pi_old * q_new)


def _target_terms(kk, d, t, z0, cdk_f, ckt_f, ck_f, alpha, beta, vbeta):
    """Numerator/denominator of the eq.-(1) mass at topic ``kk`` from
    frozen counts, with the ¬dn self-exclusion as a rank-1 correction at
    ``z0`` (the token's round-start assignment — its contribution sits in
    the frozen counts).  All args vectorized over tokens."""
    excl = (kk == z0).astype(jnp.float32)
    num = ((cdk_f[d, kk] - excl + alpha[kk])
           * (ckt_f[t, kk] - excl + beta))
    den = ck_f[kk] - excl + vbeta
    return num, den


def block_proposal_tables(cdk: jax.Array, ckt_block: jax.Array,
                          alpha: jax.Array, beta) -> Tuple[tuple, tuple]:
    """Round-start proposal state for one block: ONE concatenated table
    build over the word rows (prior β) and doc rows (prior α), so the
    K-step pairing loop runs once over ``Vb + D_loc`` rows.  Returns
    ``(word_table, doc_table)``, each ``(cut, alias, U, W)``.

    Shared by ``sweep_block_mh`` and the Pallas wrapper
    (`ops.sweep_block_mh_pallas`) — their bit-identity depends on this
    prologue staying common.
    """
    k = alpha.shape[0]
    vb = ckt_block.shape[0]
    prior = jnp.concatenate([
        jnp.broadcast_to(jnp.asarray(beta, jnp.float32), (vb, k)),
        jnp.broadcast_to(alpha, (cdk.shape[0], k))])
    cut, alias_t, u_cap, w = build_alias_tables(
        jnp.concatenate([ckt_block, cdk]), prior)
    word_table = (cut[:vb], alias_t[:vb], u_cap[:vb], w[:vb])
    doc_table = (cut[vb:], alias_t[vb:], u_cap[vb:], w[vb:])
    return word_table, doc_table


@jax.jit
def build_word_tables(ckt_block: jax.Array, beta) -> jax.Array:
    """One block's word-proposal tables (``q_w ∝ Ĉ_k^t + β``) in the
    packed rotatable layout: [Vb, K] counts -> [3, Vb, K] int32.

    Per-row bits are identical to the rows :func:`block_proposal_tables`
    builds — the Vose pairing is row-independent, so splitting the word
    rows out of the concatenated build changes nothing — which is what
    lets the per-iteration schedule coexist with the per-round one."""
    vb, k = ckt_block.shape
    prior = jnp.broadcast_to(jnp.asarray(beta, jnp.float32), (vb, k))
    cut, alias_t, _, w = build_alias_tables(ckt_block, prior)
    return pack_tables(cut, alias_t, w)


@jax.jit
def build_doc_tables(cdk: jax.Array, alpha: jax.Array) -> jax.Array:
    """One worker's doc-proposal tables (``q_d ∝ Ĉ_d^k + α_k``), packed:
    [D_loc, K] counts -> [3, D_loc, K] int32."""
    cut, alias_t, _, w = build_alias_tables(
        cdk, jnp.broadcast_to(alpha, cdk.shape))
    return pack_tables(cut, alias_t, w)


def _mh_step(z_cur, z0, d, t, mask, u_draw, u_acc, row, table,
             cdk_f, ckt_f, ck_f, alpha, beta, vbeta):
    """One MH proposal step, vectorized over the token axis.

    ``row`` selects the token's row of the proposal family's tables
    (``t`` for the word proposal, ``d`` for the doc proposal) and
    ``table = (cut, alias, U, W)`` is that family's alias table.  The
    target is always the eq.-(1) conditional; only the proposal differs.
    """
    cut, alias, u_cap, w = table
    k = ck_f.shape[0]
    j, frac = split_cell_uniform(u_draw, k)
    prop = alias_resolve(cut[row, j], alias[row, j], u_cap[row], j, frac)
    n_new, d_new = _target_terms(prop, d, t, z0, cdk_f, ckt_f, ck_f,
                                 alpha, beta, vbeta)
    n_old, d_old = _target_terms(z_cur, d, t, z0, cdk_f, ckt_f, ck_f,
                                 alpha, beta, vbeta)
    q_new = w[row, prop].astype(jnp.float32)
    q_old = w[row, z_cur].astype(jnp.float32)
    # u < [π_new q_old] / [π_old q_new], cross-multiplied (all factors > 0
    # for valid tokens); association order fixed left-to-right — the
    # Pallas kernel (`kernels/mh_alias.py`) mirrors this exact expression
    accept = u_acc * n_old * d_new * q_new < n_new * d_old * q_old
    return jnp.where(accept & mask, prop, z_cur)


# ---------------------------------------------------------------------------
# Numpy mirror of the MH cycle (host-oracle replay of frozen-count sweeps)
# ---------------------------------------------------------------------------

def _mh_step_np(z_cur, z0, d, t, mask, u_draw, u_acc, row, table,
                cdk_f, ckt_f, ck_f, alpha, beta, vbeta):
    """Numpy mirror of :func:`_mh_step`, op-for-op: same single-IEEE-op
    decision chains (cell pick, alias resolve, cross-multiplied accept),
    so given the same inputs it produces the same draws bit-for-bit —
    the fold-in host oracle (`kvstore.fold_in_oracle`) is built on it."""
    cut, alias_t, u_cap, w = table
    k = ck_f.shape[0]
    x = np.asarray(u_draw, np.float32) * np.float32(k)
    j = np.minimum(x.astype(np.int32), k - 1)
    frac = x - j.astype(np.float32)
    prop = np.where(frac * u_cap[row] < cut[row, j], j,
                    alias_t[row, j]).astype(np.int32)

    def target(kk):
        excl = (kk == z0).astype(np.float32)
        num = ((cdk_f[d, kk] - excl + alpha[kk])
               * (ckt_f[t, kk] - excl + beta))
        den = ck_f[kk] - excl + vbeta
        return num, den

    n_new, d_new = target(prop)
    n_old, d_old = target(z_cur)
    q_new = w[row, prop].astype(np.float32)
    q_old = w[row, z_cur].astype(np.float32)
    accept = u_acc * n_old * d_new * q_new < n_new * d_old * q_old
    return np.where(accept & mask, prop, z_cur).astype(np.int32)


def mh_cycle_np(z, doc, word_off, mask, u, cdk_f, ckt_f, ck_f, alpha,
                beta, vbeta, word_table, doc_table,
                num_cycles: int = DEFAULT_MH_CYCLES) -> np.ndarray:
    """Numpy mirror of the ``_mh_sweep_core`` z-update: run the full MH
    cycle against FROZEN f32 count views and the given alias tables
    (each ``(cut, alias, U, W)`` numpy tuples, e.g. from
    ``alias.unpack_tables_np``).  Returns the new assignments; the caller
    owns the count-delta fold, which is what lets the fold-in oracle
    reuse this with the model counts simply never folded."""
    streams = uniform_streams_np(np.asarray(u, np.float32), 4 * num_cycles)
    z0 = np.asarray(z, np.int32)
    z_cur = z0.copy()
    mask = np.asarray(mask, bool)
    beta = np.float32(beta)
    vbeta = np.float32(vbeta)
    alpha = np.asarray(alpha, np.float32)
    for c in range(num_cycles):
        z_cur = _mh_step_np(z_cur, z0, doc, word_off, mask,
                            streams[4 * c], streams[4 * c + 1], word_off,
                            word_table, cdk_f, ckt_f, ck_f, alpha, beta,
                            vbeta)
        z_cur = _mh_step_np(z_cur, z0, doc, word_off, mask,
                            streams[4 * c + 2], streams[4 * c + 3], doc,
                            doc_table, cdk_f, ckt_f, ck_f, alpha, beta,
                            vbeta)
    return np.where(mask, z_cur, z0).astype(np.int32)


# ---------------------------------------------------------------------------
# Engine-facing block samplers
# ---------------------------------------------------------------------------

def _mh_sweep_core(cdk, ckt_block, ck, doc, word_off, z, mask, u,
                   alpha, beta, vbeta, word_table, doc_table, num_cycles):
    """Shared sweep body: run the MH cycles against the given proposal
    tables (fresh or stale — the acceptance corrects either) and fold the
    count deltas exactly.  The target terms always come from the live
    round-start counts passed in, never from the tables."""
    ckt_f = ckt_block.astype(jnp.float32)
    cdk_f = cdk.astype(jnp.float32)
    ck_f = ck.astype(jnp.float32)
    streams = uniform_streams(u, 4 * num_cycles)

    z_cur = z
    for c in range(num_cycles):
        z_cur = _mh_step(
            z_cur, z, doc, word_off, mask, streams[4 * c],
            streams[4 * c + 1], word_off, word_table,
            cdk_f, ckt_f, ck_f, alpha, beta, vbeta)
        z_cur = _mh_step(
            z_cur, z, doc, word_off, mask, streams[4 * c + 2],
            streams[4 * c + 3], doc, doc_table,
            cdk_f, ckt_f, ck_f, alpha, beta, vbeta)

    z_new = jnp.where(mask, z_cur, z)
    delta = mask.astype(jnp.int32)
    cdk = cdk.at[doc, z].add(-delta).at[doc, z_new].add(delta)
    ckt_block = ckt_block.at[word_off, z].add(-delta) \
                         .at[word_off, z_new].add(delta)
    ck = ck.at[z].add(-delta).at[z_new].add(delta)
    return cdk, ckt_block, ck, z_new


@partial(jax.jit, static_argnames=("num_cycles",))
def sweep_block_mh(cdk: jax.Array, ckt_block: jax.Array, ck: jax.Array,
                   doc: jax.Array, word_off: jax.Array, z: jax.Array,
                   mask: jax.Array, u: jax.Array,
                   alpha: jax.Array, beta: jax.Array, vbeta: jax.Array,
                   num_cycles: int = DEFAULT_MH_CYCLES
                   ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Alias-table MH sweep over one block; registry signature/semantics
    of ``sweep_block_batched`` (frozen per round, deltas folded exactly).
    Round table lifetime: builds fresh word + doc tables on every call.

    Per round: O((Vb + D_loc)·K) to build the word/doc alias tables, then
    O(num_cycles) per token — table lookups and scalar count gathers only,
    never a [T, K] mass materialization.
    """
    word_table, doc_table = block_proposal_tables(cdk, ckt_block, alpha,
                                                  beta)
    return _mh_sweep_core(cdk, ckt_block, ck, doc, word_off, z, mask, u,
                          alpha, beta, vbeta, word_table, doc_table,
                          num_cycles)


@partial(jax.jit, static_argnames=("num_cycles",))
def sweep_block_mh_tables(cdk: jax.Array, ckt_block: jax.Array,
                          ck: jax.Array, doc: jax.Array,
                          word_off: jax.Array, z: jax.Array,
                          mask: jax.Array, u: jax.Array,
                          alpha: jax.Array, beta: jax.Array,
                          vbeta: jax.Array, word_packed: jax.Array,
                          doc_packed: jax.Array,
                          num_cycles: int = DEFAULT_MH_CYCLES
                          ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                     jax.Array]:
    """Iteration table lifetime: MH sweep against CALLER-OWNED packed
    proposal tables (``word_packed`` [3, Vb, K] built at the block's first
    residency and rotated with it, ``doc_packed`` [3, D_loc, K] built from
    iteration-start ``cdk``) — zero table-build cost on this path.

    The tables may be up to ``B - 1`` rounds stale; the eq.-(1) acceptance
    evaluates q from the tables' own ``W`` grid and the target from the
    live round-start counts, so the chain's invariant distribution is the
    same as :func:`sweep_block_mh`'s (DESIGN.md §10).
    """
    return _mh_sweep_core(cdk, ckt_block, ck, doc, word_off, z, mask, u,
                          alpha, beta, vbeta, unpack_tables(word_packed),
                          unpack_tables(doc_packed), num_cycles)
