"""Shared benchmark utilities: timing, CSV output, result storage."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def platform_metadata() -> Dict:
    """Where a benchmark ran: JAX backend, device count, and whether
    Pallas kernels execute in interpret mode (the off-TPU validation
    path — orders of magnitude slower, so trajectory points are only
    comparable within the same platform tuple).  Injected into every
    saved result and the root BENCH_e2e.json digest."""
    import jax
    backend = jax.default_backend()
    return {"jax_backend": backend,
            "device_count": jax.device_count(),
            "pallas_interpret": backend != "tpu"}


def save_result(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    if isinstance(payload, dict) and "platform" not in payload:
        payload = {**payload, "platform": platform_metadata()}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def time_call(fn: Callable, repeats: int = 3) -> float:
    """Median wall time in microseconds."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit_csv_row(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
