"""Per-architecture smoke tests (assignment requirement).

Each assigned architecture is instantiated in its REDUCED variant (≤2
layers, d_model ≤ 128, ≤4 experts) and runs one forward/loss, one gradient
step, and one cache decode step on CPU, asserting output shapes and
finiteness.  The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.train.optimizer import AdamW
from repro.train.train_step import make_train_step

B, T = 2, 32


def _batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T))),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(rng.normal(
            size=(B, cfg.num_patch_embeds, cfg.d_model)).astype(np.float32))
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(rng.normal(
            size=(B, cfg.encoder_seq, cfg.d_model)).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_limits(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss_and_shapes(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)
    logits, aux = model.forward(params, batch["tokens"],
                                batch.get("patch_embeds"),
                                batch.get("frames"))
    total = T + (cfg.num_patch_embeds if cfg.family == "vlm" else 0)
    assert logits.shape == (B, total, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) < np.log(cfg.vocab_size) + 2.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_no_nans(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(0)
    opt = AdamW(learning_rate=1e-3)
    opt_state = opt.init(params)
    step = make_train_step(model, opt)
    rng = np.random.default_rng(1)
    batch = _batch(cfg, rng)
    params, opt_state, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree_util.tree_leaves(params):
        assert np.isfinite(np.asarray(leaf)).all(), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(2)
    caches = model.init_cache(B, 64)
    kwargs = {}
    if cfg.family == "audio":
        frames = jnp.asarray(rng.normal(
            size=(B, cfg.encoder_seq, cfg.d_model)).astype(np.float32))
        kwargs["enc_out"] = model._encode(params, frames)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)))
    logits, new_caches = model.decode_step(
        params, caches, tok, jnp.zeros((B,), jnp.int32), **kwargs)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert (jax.tree_util.tree_structure(new_caches)
            == jax.tree_util.tree_structure(caches))


@pytest.mark.parametrize("arch", ["gemma3-1b", "olmo-1b", "xlstm-350m",
                                  "hymba-1.5b"])
def test_decode_matches_prefill(arch):
    """Greedy decode over a short prompt must equal teacher-forced forward
    (cache correctness: same logits at every position)."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(3)
    t = 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, t)))
    full_logits, _ = model.forward(params, toks)
    caches = model.init_cache(B, 64)
    outs = []
    for i in range(t):
        step_logits, caches = model.decode_step(
            params, caches, toks[:, i:i + 1],
            jnp.full((B,), i, jnp.int32))
        outs.append(step_logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=0.15, atol=0.15)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_assigned_dimensions(arch):
    """The full configs carry the exact assigned sizes."""
    cfg = get_config(arch)
    expected = {
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_moe_expert_counts():
    q2 = get_config("qwen2-moe-a2.7b")
    assert (q2.num_experts, q2.num_experts_per_tok,
            q2.num_shared_experts) == (60, 4, 4)
    q3 = get_config("qwen3-moe-235b-a22b")
    assert (q3.num_experts, q3.num_experts_per_tok) == (128, 8)


def test_qwen3_total_params_about_235b():
    import numpy as np
    from repro.models import build_model
    cfg = get_config("qwen3-moe-235b-a22b")
    params = build_model(cfg).abstract_params()
    n = sum(int(np.prod(x.shape))
            for x in jax.tree_util.tree_leaves(params))
    assert 2.2e11 < n < 2.5e11, n
