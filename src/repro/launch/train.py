"""End-to-end LM training driver (runs for real on this CPU container with
reduced configs; the same code path drives the production mesh).

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.data_iter import modality_wrapper, synthetic_lm_stream
from repro.train.optimizer import AdamW
from repro.train.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="olmo-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced variant (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(args.seed)
    opt = AdamW(learning_rate=args.lr, warmup_steps=20,
                total_steps=args.steps)
    opt_state = opt.init(params)
    if args.resume and args.ckpt and os.path.exists(args.ckpt + ".npz"):
        params = load_checkpoint(args.ckpt, params)
        print(f"resumed from {args.ckpt}")
    step_fn = jax.jit(make_train_step(model, opt, accum_steps=args.accum))

    stream = modality_wrapper(
        synthetic_lm_stream(cfg.vocab_size, args.batch, args.seq,
                            seed=args.seed), cfg, seed=args.seed)
    history = []
    t0 = time.time()
    tokens_done = 0
    for step, batch in zip(range(1, args.steps + 1), stream):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        tokens_done += args.batch * args.seq
        if step % args.log_every == 0 or step == 1:
            loss = float(metrics["loss"])
            tps = tokens_done / (time.time() - t0)
            print(f"step {step:5d}  loss {loss:7.4f}  "
                  f"gnorm {float(metrics['grad_norm']):7.3f}  "
                  f"lr {float(metrics['lr']):.2e}  tok/s {tps:,.0f}",
                  flush=True)
            history.append({"step": step, "loss": loss, "tokens_per_s": tps})
    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.steps)
        print(f"saved checkpoint to {args.ckpt}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(history, f, indent=1)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
