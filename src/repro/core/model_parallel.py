"""Back-compat facade for the model-parallel engine (DESIGN.md §2–§3).

The engine now lives in the :mod:`repro.core.engine` package —

  * ``engine/state.py``    — :class:`MPState`, layout/init/gather;
  * ``engine/rounds.py``   — per-round worker step + sampler registry;
  * ``engine/backends.py`` — vmap / shard_map execution backends;
  * ``engine/api.py``      — :class:`ModelParallelLDA`;

— generalized from the original one-block-per-worker rotation to an
``S·M``-block pipeline (``blocks_per_worker=S``).  This module re-exports
the public names (and the underscore-prefixed internals some launch tools
import) so every pre-refactor import keeps working::

    from repro.core.model_parallel import ModelParallelLDA, MPState
"""
from repro.core.engine.api import ModelParallelLDA
from repro.core.engine.backends import (iteration_vmap,
                                        make_shard_map_iteration)
from repro.core.engine.rounds import resolve_sampler, worker_round
from repro.core.engine.state import MPState

# Pre-package spellings, kept for external callers (e.g. launch/lda_dryrun).
# Behavioral note: since the table-lifetime PR the iteration functions
# DONATE their state buffers (in-place count updates) — callers must not
# read the argument state after the call; rebind it like the facade does.
_iteration_vmap = iteration_vmap
_iteration_shard_map = make_shard_map_iteration
_make_sampler = resolve_sampler
_worker_round = worker_round

__all__ = [
    "ModelParallelLDA", "MPState", "iteration_vmap",
    "make_shard_map_iteration",
]
