"""Pallas TPU kernel for the hybrid sparse Gibbs lane block.

The sparse sampler's per-token work is confined to the nonzero topic
LANES of the word and doc count rows (``core/sparse_device.py``,
DESIGN.md §12) — at most ``wcap + dcap`` lanes per token instead of K.
This kernel runs exactly that lane block: the ``[word | doc]`` segment
masses with the rank-1 z0 exclusion, their sequential prefix sums, the
counted-clamped segment draws, and the three-way segment select — for a
tile of tokens per grid step, with ONLY the padded lane operands resident
in VMEM.  Unlike `gibbs_conditional.py`/`mh_alias.py`, whose VMEM
working set grows with K, this kernel's footprint is fixed by the lane
capacities: it *shrinks* with sparsity, which is the point of the sparse
family.

The dense-segment machinery stays outside: the frozen per-word cumsum
``Dcs`` is built once per round in the shared prologue, and the O(log K)
shifted-suffix bisection runs in the shared jnp epilogue.  The kernel
returns the triple ``(z_lane, is_dense, y_dense)`` — the drawn lane
topic, whether the draw fell through to the dense segment, and the dense
residual — which is precisely what ``sparse_device._lane_draw_jnp``
computes, op for op and in the same association order:

* lane masses, ``where``-masking and clamping mirror
  ``lane_masses_jnp`` exactly;
* prefix sums use the same sequential-association chain
  (``_lane_cumsum``), so the 128-lane padding appends exact ``+0.0``
  terms and every real-lane prefix is preserved bitwise;
* the counted draws consume padded lanes only in branches that are never
  selected (padded cumsum entries equal the segment total, which the
  ``<``/``≤`` counts exclude whenever the draw is consumed);
* scalar lane picks are one-hot reductions (`mh_alias.py` idiom) — exact
  selects, associativity-free.

Hence ``sparse_pallas == sparse`` bit for bit, asserted by
tests/test_sparse_device.py.  Tokens ride the grid rows ([Tp, 1] scalar
columns, [Tp, capP] lane blocks, capP padded to the 128-lane boundary);
invalid padding rows carry ``mask = 0`` and are dropped by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sparse_device import _lane_cumsum

TILE_T = 128


def _sel(vals, idx, zero):
    """vals [G, L] gathered at idx [G, 1] -> [G, 1] one-hot reduction."""
    iota = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
    return jnp.sum(jnp.where(iota == idx, vals, zero), axis=-1,
                   keepdims=True)


def _segment_draw(cs, total, x, lanes_k):
    """Counted-clamped inverse-CDF draw over one padded lane segment —
    the kernel form of ``sparse_device._segment_draw`` ([G, 1] scalars,
    one-hot lane pick)."""
    idx = jnp.sum((cs <= x).astype(jnp.int32), axis=-1, keepdims=True)
    last = jnp.sum((cs < total).astype(jnp.int32), axis=-1, keepdims=True)
    pick = jnp.minimum(jnp.minimum(idx, last), cs.shape[-1] - 1)
    return _sel(lanes_k, pick, 0)


def _sparse_lane_kernel(wkk_ref, wvalid_ref, wckt_ref, wcdk_ref, wck_ref,
                        wal_ref, dkk_ref, dvalid_ref, dckt_ref, dcdk_ref,
                        dck_ref, h_ref, z0_ref, mask_ref, u_ref,
                        sdense_ref, const_ref,
                        zlane_ref, isdense_ref, ydense_ref):
    beta = const_ref[0, 0]
    vbeta = const_ref[0, 1]
    z0 = z0_ref[...]                       # [G, 1]
    mask = mask_ref[...] != 0              # [G, 1]
    h = h_ref[...] != 0                    # [G, 1] head-word flag
    u = u_ref[...]                         # [G, 1]
    sdense = sdense_ref[...]               # [G, 1] perturbed dense total

    # word-sparse segment (tail words only — wvalid is 0 on head rows)
    wkk = wkk_ref[...]                     # [G, WP] lane topic ids
    ew = ((wkk == z0) & mask).astype(jnp.float32)
    wraw = ((wal_ref[...] + (wcdk_ref[...] - ew)) * (wckt_ref[...] - ew)
            / (wck_ref[...] - ew + vbeta))
    wval = jnp.maximum(jnp.where(wvalid_ref[...] != 0, wraw, 0.0), 0.0)
    wcs = _lane_cumsum(wval)
    sw = wcs[..., -1:]

    # doc-sparse segment (B_k on tail rows, Y_k on head rows)
    dkk = dkk_ref[...]                     # [G, DP]
    ed = ((dkk == z0) & mask).astype(jnp.float32)
    cross = jnp.where(h, dckt_ref[...] - ed, 0.0)
    draw_ = ((dcdk_ref[...] - ed) * (beta + cross)
             / (dck_ref[...] - ed + vbeta))
    dval = jnp.maximum(jnp.where(dvalid_ref[...] != 0, draw_, 0.0), 0.0)
    dcs = _lane_cumsum(dval)
    sd = dcs[..., -1:]

    # segment-ordered CDF [word | doc | dense], one uniform rescaled
    total = sw + sd + sdense
    x = u * total
    yd = x - sw
    ydense = yd - sd
    in_w = x < sw
    in_d = ~in_w & (yd < sd)
    kw = _segment_draw(wcs, sw, x, wkk)
    kd = _segment_draw(dcs, sd, yd, dkk)

    zlane_ref[...] = jnp.where(in_w, kw, kd)
    isdense_ref[...] = (~(in_w | in_d)).astype(jnp.int32)
    ydense_ref[...] = ydense


def _pad_lanes(x, value):
    """Pad [T, cap] lane arrays to the 128-lane boundary, [T] scalars to
    [T, 1] columns, and the token axis to the tile boundary."""
    if x.ndim == 1:
        x = x[:, None]
    t, c = x.shape
    cp = -(-c // 128) * 128 if c > 1 else 1
    tp = -(-t // TILE_T) * TILE_T
    return jnp.pad(x, ((0, tp - t), (0, cp - c)), constant_values=value)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparse_lane_call(wops: dict, dops: dict, h_t: jax.Array,
                     z0: jax.Array, mask: jax.Array, u: jax.Array,
                     sdense: jax.Array, beta, vbeta,
                     interpret: bool = True):
    """Pad, tile and launch the lane kernel; returns the unpadded
    ``(z_lane, is_dense, y_dense)`` triple of ``_lane_draw_jnp``.

    ``wops``/``dops`` are the gathered per-token lane operand dicts of
    ``sparse_device.sparse_prologue`` — the wrapper adds no arithmetic of
    its own, so the kernel consumes bit-identical inputs to the jnp lane
    block.  Padding rows carry ``mask = 0`` and zero operands; padded
    lanes are invalid (zero mass), which the kernel's counted draws never
    select in a consumed branch."""
    t = z0.shape[0]
    args = [_pad_lanes(wops["kk"], 0),
            _pad_lanes(wops["valid"].astype(jnp.int32), 0),
            _pad_lanes(wops["ckt"], 0.0), _pad_lanes(wops["cdk"], 0.0),
            _pad_lanes(wops["ck"], 0.0), _pad_lanes(wops["alpha"], 0.0),
            _pad_lanes(dops["kk"], 0),
            _pad_lanes(dops["valid"].astype(jnp.int32), 0),
            _pad_lanes(dops["ckt"], 0.0), _pad_lanes(dops["cdk"], 0.0),
            _pad_lanes(dops["ck"], 0.0),
            _pad_lanes(h_t.astype(jnp.int32), 0),
            _pad_lanes(z0.astype(jnp.int32), 0),
            _pad_lanes(mask.astype(jnp.int32), 0),
            _pad_lanes(u.astype(jnp.float32), 0.0),
            _pad_lanes(sdense.astype(jnp.float32), 0.0),
            jnp.array([[beta, vbeta, 0.0, 0.0]], jnp.float32)]
    tp = args[0].shape[0]
    wp, dp = args[0].shape[1], args[6].shape[1]
    grid = (tp // TILE_T,)
    row = lambda i: (i, 0)
    rep = lambda i: (0, 0)
    lane_spec = lambda c: pl.BlockSpec((TILE_T, c), row)
    col = pl.BlockSpec((TILE_T, 1), row)
    z_lane, is_dense, ydense = pl.pallas_call(
        _sparse_lane_kernel,
        grid=grid,
        in_specs=[lane_spec(wp)] * 6 + [lane_spec(dp)] * 5
        + [col] * 5 + [pl.BlockSpec((1, 4), rep)],
        out_specs=[col, col, col],
        out_shape=[jax.ShapeDtypeStruct((tp, 1), jnp.int32),
                   jax.ShapeDtypeStruct((tp, 1), jnp.int32),
                   jax.ShapeDtypeStruct((tp, 1), jnp.float32)],
        interpret=interpret,
    )(*args)
    return (z_lane[:t, 0], is_dense[:t, 0] != 0, ydense[:t, 0])
