"""Paper Figure 3: the Δ_{r,i} parallelization error per round.

MP drifts only in the non-separable C_k (synced per round) — Δ stays near
zero.  The DP baseline's word-topic staleness error is orders of magnitude
larger, which is the mechanism behind Figure 2's convergence gap.
"""
from __future__ import annotations

from benchmarks.common import emit_csv_row, save_result
from repro.core.data_parallel import DataParallelLDA
from repro.core.model_parallel import ModelParallelLDA
from repro.data.synthetic import synthetic_corpus


def run(num_docs=300, vocab=1200, topics=32, doc_len=60, workers=8,
        iters=10, seed=0):
    corpus, _, _ = synthetic_corpus(num_docs, vocab, topics, doc_len,
                                    seed=seed)
    mp = ModelParallelLDA(corpus, topics, workers, seed=seed)
    dp = DataParallelLDA(corpus, topics, workers, seed=seed)
    mp_err, dp_err = [], []
    for _ in range(iters):
        mp.step()
        dp.step()
        mp_err.append([float(e) for e in mp.round_errors])
        dp_err.append(dp.model_error())
    flat = [e for r in mp_err for e in r]
    out = {"mp_delta_per_round": mp_err,
           "dp_staleness_per_iter": dp_err,
           "mp_delta_mean": sum(flat) / len(flat),
           "mp_delta_max": max(flat),
           "dp_staleness_mean": sum(dp_err) / len(dp_err)}
    out["ratio_dp_over_mp"] = out["dp_staleness_mean"] / max(
        out["mp_delta_mean"], 1e-12)
    save_result("fig3_error", out)
    emit_csv_row("fig3_delta_error", 0.0,
                 f"mp_mean={out['mp_delta_mean']:.6f};"
                 f"dp_mean={out['dp_staleness_mean']:.6f};"
                 f"dp/mp={out['ratio_dp_over_mp']:.1f}x")
    return out


if __name__ == "__main__":
    run()
