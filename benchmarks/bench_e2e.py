"""End-to-end engine throughput: WHOLE iterations, not single-block sweeps.

    PYTHONPATH=src python -m benchmarks.bench_e2e [--smoke]

`bench_samplers.py` times one block sweep in isolation; this benchmark
times ``ModelParallelLDA.step()`` — S·M rounds with rotation, ``C_k``
sync, and (for the MH family) the table build schedule — which is the
quantity the table-lifetime amortization (ISSUE 4, DESIGN.md §10)
actually improves: per-round builds are engine overhead invisible to a
single-sweep benchmark.

Two report sections:

* **headline** — the MH pair at K = 4096 on one geometry, each at BOTH
  table lifetimes on the identical workload.  The acceptance bar is
  ``iteration`` tokens/s > ``round`` tokens/s for ``mh`` AND
  ``mh_pallas``: the per-iteration schedule pays ``S + 1`` alias builds
  per worker where the per-round schedule pays ``S·M``.
* **geometry sweep** — samplers × (D, M, S) at a smaller K, tracking how
  throughput composes with the pipeline depth and the data axis.

Engines run with ``track_error=False`` (the Fig-3 drift statistic is
pure overhead here) and state donation on — the benchmark ASSERTS both:
donation at the lowering level (``tf.aliasing_output`` on the state
args) and live (the pre-step buffer is actually consumed).

Results land in ``benchmarks/results/bench_e2e.json`` and — full mode
only — the repo-root ``BENCH_e2e.json`` (smoke mode never clobbers the
recorded perf trajectory; it exists so `scripts/ci.sh` exercises this
path on every run).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (RESULTS_DIR, emit_csv_row,
                               platform_metadata, save_result)
from repro.core.engine.api import ModelParallelLDA
from repro.core.engine.backends import iteration_vmap
from repro.data.synthetic import synthetic_corpus

ROOT_JSON = os.path.join(os.path.dirname(__file__), os.pardir,
                         "BENCH_e2e.json")

# full-mode workload: ~6k tokens, V = 256 — small enough that a K = 4096
# iteration is dominated by exactly what the lifetime schedule changes
# (table builds), matching the big-K regime the MH backend targets
FULL = dict(docs=128, vocab=256, doc_len=48, k_headline=4096,
            k_sweep=256, repeats=2,
            headline_geom=(1, 4, 2),        # (D, M, S): 8-round pipeline
            sweep_geoms=((1, 2, 1), (1, 4, 2), (2, 2, 1)))
SMOKE = dict(docs=24, vocab=64, doc_len=16, k_headline=64,
             k_sweep=64, repeats=1,
             headline_geom=(1, 2, 2),
             sweep_geoms=((1, 2, 1),))


def _make_engine(corpus, k, geom, sampler, lifetime=None, seed=0):
    d, m, s = geom
    return ModelParallelLDA(corpus, k, num_workers=m, seed=seed,
                            sampler_mode=sampler, blocks_per_worker=s,
                            data_parallel=d, table_lifetime=lifetime,
                            track_error=False)


def _verify_donation(lda) -> dict:
    """Satellite check: the iteration donates the MPState buffers.

    (i) lowering level — every state tensor arg carries an
    ``tf.aliasing_output`` annotation in the lowered module;
    (ii) live — after one step the pre-step buffer is deleted (the
    runtime really did reuse it instead of copying).
    """
    u = jnp.zeros((lda.num_rounds, lda.num_shards, lda.capacity),
                  jnp.float32)
    lowered = iteration_vmap.lower(
        lda.state, u, lda.doc, lda.woff, lda.mask, lda.alpha,
        jnp.float32(lda.beta), jnp.float32(lda.vbeta),
        sampler_mode=lda.sampler_mode, sync_ck=lda.sync_ck,
        data_parallel=lda.data_parallel,
        table_lifetime=lda.table_lifetime, track_error=lda.track_error)
    text = lowered.as_text()
    n_alias = text.count("tf.aliasing_output")
    assert n_alias >= 6, (
        f"expected all 6 MPState buffers donated, lowering marks {n_alias}")
    pre = lda.state.cdk
    lda.step()
    assert pre.is_deleted(), \
        "MPState.cdk survived a step — donation did not take effect"
    return {"lowered_aliased_args": n_alias, "live_buffer_donated": True}


def _time_engine(lda, repeats: int) -> dict:
    """Median seconds per iteration (post-warmup), tokens/s derived."""
    lda.step()                                    # compile + warm
    jax.block_until_ready(lda.state.cdk)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        lda.step()
        jax.block_until_ready(lda.state.cdk)
        times.append(time.perf_counter() - t0)
    times.sort()
    sec = times[len(times) // 2]
    tokens = lda.corpus.num_tokens
    return {"sec_per_iteration": sec, "tokens_per_s": tokens / sec}


def run(smoke: bool = False, seed: int = 0) -> dict:
    cfg = SMOKE if smoke else FULL
    corpus, _, _ = synthetic_corpus(cfg["docs"], cfg["vocab"], 16,
                                    cfg["doc_len"], seed=seed)
    out = {
        "mode": "smoke" if smoke else "full",
        "workload": {"docs": cfg["docs"], "vocab": cfg["vocab"],
                     "doc_len": cfg["doc_len"],
                     "tokens": corpus.num_tokens},
    }

    # donation satellite: checked once on a representative MH engine
    out["donation"] = _verify_donation(
        _make_engine(corpus, cfg["k_sweep"], cfg["sweep_geoms"][0], "mh"))

    # -- headline: table-lifetime A/B for the MH family at big K ---------
    k = cfg["k_headline"]
    d, m, s = cfg["headline_geom"]
    headline = {"k": k, "geometry": {"data_parallel": d, "workers": m,
                                     "blocks_per_worker": s,
                                     "rounds": m * s}}
    for sampler in ("mh", "mh_pallas"):
        rec = {}
        for lifetime in ("round", "iteration"):
            lda = _make_engine(corpus, k, cfg["headline_geom"], sampler,
                               lifetime)
            rec[lifetime] = _time_engine(lda, cfg["repeats"])
            emit_csv_row(f"e2e_{sampler}_{lifetime}_k{k}",
                         rec[lifetime]["sec_per_iteration"] * 1e6,
                         f"tokens_per_s="
                         f"{rec[lifetime]['tokens_per_s']:.0f}")
        rec["iteration_speedup"] = (rec["iteration"]["tokens_per_s"]
                                    / rec["round"]["tokens_per_s"])
        headline[sampler] = rec
    headline["improved"] = all(
        headline[sm]["iteration_speedup"] > 1.0
        for sm in ("mh", "mh_pallas"))
    out[f"k{k}"] = headline
    out["e2e_improved_at_headline_k"] = headline["improved"]

    # -- geometry sweep: samplers × (D, M, S) at sweep K ------------------
    ks = cfg["k_sweep"]
    sweep = {}
    for geom in cfg["sweep_geoms"]:
        gname = "d{}m{}s{}".format(*geom)
        rec = {}
        for sampler, lifetime in (("batched", None), ("mh", "round"),
                                  ("mh", "iteration")):
            if smoke and sampler == "batched":
                continue
            label = sampler if lifetime is None else \
                f"{sampler}_{lifetime}"
            lda = _make_engine(corpus, ks, geom, sampler, lifetime)
            rec[label] = _time_engine(lda, cfg["repeats"])
            emit_csv_row(f"e2e_{label}_k{ks}_{gname}",
                         rec[label]["sec_per_iteration"] * 1e6,
                         f"tokens_per_s={rec[label]['tokens_per_s']:.0f}")
        sweep[gname] = rec
    out[f"k{ks}_geometry_sweep"] = sweep

    save_result("bench_e2e_smoke" if smoke else "bench_e2e", out)
    if not smoke:
        aggregate_root(out)
    return out


def aggregate_root(e2e_payload: dict | None = None) -> str:
    """Write the repo-root ``BENCH_e2e.json``: the e2e trajectory at top
    level plus a digest of every per-benchmark JSON under
    ``benchmarks/results/`` — one file that answers "how fast is the
    system end to end, and what feeds that number"."""
    out_path = os.path.abspath(ROOT_JSON)
    if e2e_payload is None:
        path = os.path.join(RESULTS_DIR, "bench_e2e.json")
        if os.path.exists(path):
            with open(path) as f:
                e2e_payload = json.load(f)
        elif os.path.exists(out_path):
            # no fresh e2e run this invocation: keep the recorded
            # trajectory rather than clobbering it with null
            with open(out_path) as f:
                e2e_payload = json.load(f).get("e2e")
    # comparability stamp (satellite): trajectory points only mean
    # something relative to the platform that produced them
    root = {"platform": platform_metadata(), "e2e": e2e_payload,
            "benchmarks": {}}
    if os.path.isdir(RESULTS_DIR):
        for name in sorted(os.listdir(RESULTS_DIR)):
            # smoke-mode outputs are CI artifacts, never trajectory data
            if (not name.endswith(".json") or name.startswith("bench_e2e")
                    or name.endswith("_smoke.json")):
                continue
            try:
                with open(os.path.join(RESULTS_DIR, name)) as f:
                    root["benchmarks"][name[:-5]] = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
    with open(out_path, "w") as f:
        json.dump(root, f, indent=1)
    return out_path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI workload; skips the root BENCH_e2e.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    res = run(smoke=args.smoke)
    hk = [k for k in res if k.startswith("k") and "sweep" not in k][0]
    h = res[hk]
    for sm in ("mh", "mh_pallas"):
        print(f"# {sm} {hk}: round={h[sm]['round']['tokens_per_s']:.0f} "
              f"iteration={h[sm]['iteration']['tokens_per_s']:.0f} tok/s "
              f"(speedup {h[sm]['iteration_speedup']:.2f}x)")


if __name__ == "__main__":
    main()
