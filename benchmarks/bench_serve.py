"""Open-loop serving benchmark: traffic replay through the scheduler
(DESIGN.md §14).

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]

Where `bench_infer.py` measures the fold-in engine closed-loop (batch
after batch, back to back), this benchmark measures the SCHEDULER the
way production traffic hits it: a seeded Poisson arrival process with
heavy-tailed doc lengths and a hot-query fraction, replayed open-loop
under wall time.  Two phases per sampler:

* **saturation** — every request arrives at t=0 (offered load ≫
  capacity, queue sized to hold the burst): served queries/s is the
  scheduler's ceiling, the number capacity planning divides traffic by.
* **latency** — the same trace shape offered at ~60% of the measured
  saturation rate, with one snapshot HOT-SWAP at the midpoint: p50/p99
  response latency (queueing included — the open-loop property), cache
  hit rate, and the zero-dropped / finite-p99 assertions the CI smoke
  also enforces.
* **degraded** — the latency trace replayed with replica 0 scripted to
  fail EVERY dispatch (DESIGN.md §15): the surviving replicas absorb the
  load through the retry + circuit-breaker path, every admitted query is
  still answered (dropped == 0 — retries are bitwise-invisible, so the
  answers are the healthy ones), and the fault counters (retries,
  breaker opens, failures) land in the results.

Results land in ``benchmarks/results/bench_serve.json`` and — full mode
only — fold into the repo-root ``BENCH_e2e.json`` trajectory.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.bench_e2e import aggregate_root
from benchmarks.common import emit_csv_row, save_result
from repro.core.engine.api import ModelParallelLDA
from repro.core.faults import FaultPlan
from repro.data.synthetic import synthetic_corpus
from repro.serve.scheduler import ServingScheduler, WallClock
from repro.serve.traffic import poisson_trace, replay_open_loop

FULL = dict(docs=128, vocab=256, topics=16, k=256, doc_len=48,
            train_iters=3, sweeps=5, samplers=("scan", "mh"),
            requests=256, max_len=64, hot_fraction=0.25, hot_pool=8,
            replicas=2, max_batch=16, max_queue=4096)
SMOKE = dict(docs=24, vocab=64, topics=8, k=16, doc_len=16,
             train_iters=1, sweeps=2, samplers=("scan",),
             requests=32, max_len=16, hot_fraction=0.25, hot_pool=4,
             replicas=2, max_batch=8, max_queue=1024)


def _train_snapshots(cfg, seed: int):
    """Two snapshots of the same run at different iterations — the
    'training advanced, serve the new model' pair the hot-swap replays."""
    corpus, _, _ = synthetic_corpus(cfg["docs"], cfg["vocab"],
                                    cfg["topics"], cfg["doc_len"],
                                    seed=seed)
    lda = ModelParallelLDA(corpus, cfg["k"], num_workers=2, seed=seed,
                           sampler_mode="batched", track_error=False)
    lda.run(max(cfg["train_iters"] - 1, 1))
    snap_a = lda.snapshot()
    lda.run(1)
    return snap_a, lda.snapshot()


def _scheduler(cfg, snap, sampler, seed, **kw):
    return ServingScheduler(snap, sampler=sampler, num_sweeps=cfg["sweeps"],
                            seed=seed, num_replicas=cfg["replicas"],
                            max_batch=cfg["max_batch"],
                            max_queue=cfg["max_queue"],
                            cache_capacity=256, clock=WallClock(), **kw)


def run(smoke: bool = False, seed: int = 0) -> dict:
    cfg = SMOKE if smoke else FULL
    snap_a, snap_b = _train_snapshots(cfg, seed)
    out = {
        "mode": "smoke" if smoke else "full",
        "workload": {"vocab": cfg["vocab"], "k": cfg["k"],
                     "requests": cfg["requests"],
                     "fold_in_sweeps": cfg["sweeps"],
                     "max_doc_len": cfg["max_len"],
                     "hot_fraction": cfg["hot_fraction"],
                     "replicas": cfg["replicas"],
                     "max_batch": cfg["max_batch"]},
        "samplers": {},
    }
    for sampler in cfg["samplers"]:
        # saturation: the whole trace arrives at once (rate -> inf);
        # served/s against a never-empty queue is the throughput ceiling
        sat_trace = poisson_trace(cfg["requests"], 1e9, cfg["vocab"],
                                  seed=seed + 1, max_len=cfg["max_len"],
                                  hot_fraction=cfg["hot_fraction"],
                                  hot_pool=cfg["hot_pool"])
        sched = _scheduler(cfg, snap_a, sampler, seed)
        # compile every reachable bucket OUTSIDE the timed loops — the
        # jit cache is shape-keyed, so this also covers the post-swap
        # snapshot; without it p99 measures XLA compiles, not serving
        buckets = sched.warm(cfg["max_len"])
        sat = replay_open_loop(sched, sat_trace)
        assert sat["dropped"] == 0
        sat_qps = sat["served_qps"]

        # latency: same trace shape at ~60% of saturation, one mid-replay
        # hot-swap; p50/p99 include queueing (open loop)
        rate = max(0.6 * sat_qps, 1.0)
        lat_trace = poisson_trace(cfg["requests"], rate, cfg["vocab"],
                                  seed=seed + 2, max_len=cfg["max_len"],
                                  hot_fraction=cfg["hot_fraction"],
                                  hot_pool=cfg["hot_pool"])
        sched = _scheduler(cfg, snap_a, sampler, seed)
        lat = replay_open_loop(sched, lat_trace,
                               swap_after=cfg["requests"] // 2,
                               swap_snapshot=snap_b)
        assert lat["dropped"] == 0, lat
        assert np.isfinite(lat["p99_ms"]), lat
        assert len(lat["epochs"]) == 2      # both snapshots really served
        # degraded: same offered rate with replica 0 failing every
        # dispatch — measures the price of riding through an outage
        deg_trace = poisson_trace(cfg["requests"], rate, cfg["vocab"],
                                  seed=seed + 2, max_len=cfg["max_len"],
                                  hot_fraction=cfg["hot_fraction"],
                                  hot_pool=cfg["hot_pool"])
        sched = _scheduler(cfg, snap_a, sampler, seed,
                           breaker_cooldown=0.05,
                           fault_plan=FaultPlan.replica_fail(0, nth=0))
        sched.warm(cfg["max_len"])
        deg = replay_open_loop(sched, deg_trace)
        assert deg["dropped"] == 0, deg
        assert deg["faults"]["replica_failures"] > 0, deg

        rec = {"warmed_buckets": buckets,
               "saturation_qps": sat_qps,
               "saturation": {k: sat[k] for k in
                              ("served_qps", "elapsed_s", "batches")},
               "latency": {k: lat[k] for k in
                           ("offered_qps", "served_qps", "p50_ms",
                            "p99_ms", "dropped", "swap_epoch", "epochs",
                            "cache", "batches")},
               "degraded": {k: deg[k] for k in
                            ("served_qps", "p50_ms", "p99_ms", "dropped",
                             "faults")}}
        out["samplers"][sampler] = rec
        emit_csv_row(f"serve_{sampler}_k{cfg['k']}", lat["p50_ms"] * 1e3,
                     f"sat_qps={sat_qps:.1f},p99_ms={lat['p99_ms']:.2f},"
                     f"cache_hits={lat['cache']['hits']}")
    save_result("bench_serve_smoke" if smoke else "bench_serve", out)
    if not smoke:
        aggregate_root()      # fold into the repo-root BENCH trajectory
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI workload; not recorded in the root "
                         "BENCH trajectory")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    res = run(smoke=args.smoke)
    for sampler, rec in res["samplers"].items():
        lat = rec["latency"]
        print(f"# {sampler}: saturation {rec['saturation_qps']:,.1f} q/s; "
              f"at {lat['offered_qps']:,.1f} q/s offered: "
              f"p50 {lat['p50_ms']:.2f} ms  p99 {lat['p99_ms']:.2f} ms  "
              f"cache {lat['cache']['hits']}/{lat['cache']['hits'] + lat['cache']['misses']} hit  "
              f"epochs {lat['epochs']}")
        deg = rec["degraded"]
        print(f"# {sampler} degraded (replica 0 down): "
              f"p50 {deg['p50_ms']:.2f} ms  p99 {deg['p99_ms']:.2f} ms  "
              f"dropped {deg['dropped']}  faults {deg['faults']}")


if __name__ == "__main__":
    main()
