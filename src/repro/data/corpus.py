"""Corpus container and I/O.

A corpus is a flat token stream: parallel int32 arrays ``doc``/``word``.
This is the persistent, conditionally-independent "data" half of the
data/model dichotomy the paper draws; samplers carry the transient ``z``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Sequence

import numpy as np


@dataclasses.dataclass
class Corpus:
    doc: np.ndarray          # [N] int32 document id per token
    word: np.ndarray         # [N] int32 word id per token
    num_docs: int
    vocab_size: int
    vocab: List[str] | None = None   # optional id -> string

    @property
    def num_tokens(self) -> int:
        return int(self.doc.shape[0])

    def doc_lengths(self) -> np.ndarray:
        return np.bincount(self.doc, minlength=self.num_docs)

    def word_freqs(self) -> np.ndarray:
        return np.bincount(self.word, minlength=self.vocab_size)

    def validate(self) -> None:
        assert self.doc.shape == self.word.shape
        assert self.doc.min(initial=0) >= 0 and self.word.min(initial=0) >= 0
        assert self.doc.max(initial=-1) < self.num_docs
        assert self.word.max(initial=-1) < self.vocab_size


def from_documents(docs_as_word_lists: Sequence[Sequence[int]],
                   vocab_size: int, vocab: List[str] | None = None) -> Corpus:
    doc_ids, word_ids = [], []
    for d, ws in enumerate(docs_as_word_lists):
        doc_ids.extend([d] * len(ws))
        word_ids.extend(ws)
    return Corpus(np.asarray(doc_ids, np.int32), np.asarray(word_ids, np.int32),
                  len(docs_as_word_lists), vocab_size, vocab)


def from_texts(texts: Sequence[str], min_count: int = 1) -> Corpus:
    """Whitespace tokenizer + vocabulary build — enough for the examples."""
    counts: Dict[str, int] = {}
    tokenized = []
    for t in texts:
        toks = t.lower().split()
        tokenized.append(toks)
        for w in toks:
            counts[w] = counts.get(w, 0) + 1
    vocab = sorted(w for w, c in counts.items() if c >= min_count)
    index = {w: i for i, w in enumerate(vocab)}
    docs = [[index[w] for w in toks if w in index] for toks in tokenized]
    return from_documents(docs, len(vocab), vocab)


def bigram_corpus(corpus: Corpus) -> Corpus:
    """Augment with bigrams the way the paper builds Wiki-bigram (§5):
    consecutive token pairs become phrase ids in an enlarged vocabulary."""
    doc, word = corpus.doc, corpus.word
    same_doc = doc[1:] == doc[:-1]
    pairs = word[:-1][same_doc].astype(np.int64) * corpus.vocab_size \
        + word[1:][same_doc].astype(np.int64)
    uniq, inv = np.unique(pairs, return_inverse=True)
    return Corpus(doc[:-1][same_doc].astype(np.int32), inv.astype(np.int32),
                  corpus.num_docs, int(uniq.shape[0]))


def save_corpus(corpus: Corpus, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez_compressed(path, doc=corpus.doc, word=corpus.word,
                        num_docs=corpus.num_docs, vocab_size=corpus.vocab_size)
    if corpus.vocab is not None:
        with open(path + ".vocab.json", "w") as f:
            json.dump(corpus.vocab, f)


def load_corpus(path: str) -> Corpus:
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    vocab = None
    vpath = path + ".vocab.json"
    if os.path.exists(vpath):
        with open(vpath) as f:
            vocab = json.load(f)
    return Corpus(data["doc"], data["word"], int(data["num_docs"]),
                  int(data["vocab_size"]), vocab)
