"""Device-side sparse Gibbs sampler: hybrid dense-head/sparse-tail layout.

The host bucket sampler (`core/sparse.py`, Yao et al. 2009) shows WHY
long-tail corpora admit O(nnz) per-token sampling; this module is the
device port that makes the engine's per-token cost track the nonzeros
instead of K (DESIGN.md §12).  It is the first registry sampler whose
working set *shrinks* with sparsity — the prerequisite for the K ≥ 64k
regime of ROADMAP item 3.

Semantics: a FROZEN-count batched sweep, exactly the relaxation class of
``core.sampler.sweep_block_batched`` — counts frozen at round start, the
¬dn self-exclusion applied as a rank-1 correction at the round-start
assignment ``z0``, deltas folded exactly afterwards.  Token draws are
therefore independent given the frozen counts: the chain is
distribution-equal (not trajectory-equal) to the exact ``scan`` chain,
validated statistically like ``mh`` (tests/test_sparse_stats.py), while
everything around the draw stays bitwise testable — the host oracle
resolves this very sampler from the registry, so engine runs replay
draw-for-draw at any (D, M, S) geometry.

Per-token mass decomposition (paper eq. 2 rearranged around a hybrid
vocabulary split; ``'`` marks the rank-1 z0 exclusion, ``denom`` is
``C_k + Vβ``):

* **tail word** (``nnz(C^t) ≤ wcap``):
  ``p_k = A_k + B_k + C_k`` with the dense smoothing bucket
  ``A_k = α_k β/denom'_k``, the document-sparse bucket
  ``B_k = β C_d'^k/denom'_k`` on the ≤ ``dcap`` nonzero lanes of the
  doc row, and the word-sparse bucket
  ``C_k = (α_k + C_d'^k) C'^t_k/denom'_k`` on the ≤ ``wcap`` nonzero
  lanes of the word row.
* **head word** (``nnz(C^t) > wcap`` — the hot-vocabulary prefix):
  the word row is dense anyway, so the word term folds into the dense
  segment: ``p_k = X_k + Y_k`` with ``X_k = α_k(β + C'^t_k)/denom'_k``
  dense and ``Y_k = C_d'^k(β + C'^t_k)/denom'_k`` on the doc lanes —
  eq. (3)'s split, evaluated on the head row.

The dense segment is shared machinery for both cases: per word row
``D_v,k = α_k(β + head_v·C^t_v,k)/denom_k`` (frozen counts) is cumsummed
ONCE per round into ``Dcs [Vb, K+?]``; a token's exclusion perturbs
exactly one lane (``z0``), handled as a shift ``δ = D'(z0) − D(z0)`` on
the cumsum suffix, so the dense draw is two O(log K) binary searches —
never an O(K) row materialization.  The head/tail split is decided per
round from the frozen counts, so ``wcap`` is a pure performance knob
(overflowing rows fall back to the dense-head path, never drop mass);
``dcap`` by contrast must bound ``nnz(C_d^k)`` — ``min(K, max doc
length)``, which :func:`default_sparse_args` derives and the facade and
host oracle share so replays stay bitwise.

The CDF a token draws from is segment-ordered
``[word lanes | doc lanes | dense]``, one uniform per token rescaled by
the total mass, with the counted-clamped inverse-CDF idiom of
``sample_from_mass`` inside each segment (exact at ``u → 1.0`` and on
zero-mass segments).

``sweep_block_sparse`` is the jnp form; ``kernels/ops.py`` wraps the
Pallas kernel (`kernels/sparse_gibbs.py`) around the same prologue and
epilogue so ``sparse_pallas`` is bit-identical to ``sparse``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_WCAP = 32


def default_sparse_args(num_topics: int, max_doc_len: int,
                        wcap: int = DEFAULT_WCAP) -> tuple:
    """Static sampler config for the sparse family, as a hashable tuple of
    pairs (it rides jit cache keys).  ``dcap`` is the CORRECTNESS bound —
    every ``C_d^k`` row has at most ``min(K, N_d)`` nonzeros; ``wcap`` is
    the head/tail threshold (pure perf knob).  The engine facade and the
    host oracle both derive their config through this one function, so an
    oracle replay runs the identical jitted sampler."""
    k = int(num_topics)
    return (("dcap", max(1, min(k, int(max_doc_len)))),
            ("wcap", max(1, min(k, int(wcap)))))


def _extract_lanes(counts: jax.Array, cap: int) -> jax.Array:
    """Nonzero topic lanes of each count row, CSR-style padded: [N, cap]
    int32 of ascending topic ids, sentinel K past the row's nnz.  The
    cumsum-position scatter is the `core/alias.py` compaction idiom; rows
    with nnz > cap overflow silently (callers either bound cap — doc
    rows — or route overflowing rows to the dense head — word rows)."""
    n, k = counts.shape
    nzm = counts > 0
    pos = jnp.cumsum(nzm.astype(jnp.int32), axis=1) - 1
    tgt = jnp.where(nzm, pos, cap)             # cap/overflow -> dropped
    kio = jax.lax.broadcasted_iota(jnp.int32, (n, k), 1)
    lanes = jnp.full((n, cap), k, jnp.int32)
    return lanes.at[jnp.arange(n)[:, None], tgt].set(kio, mode="drop")


def _row_count(csrows: jax.Array, rows: jax.Array, y: jax.Array,
               strict: bool = False) -> jax.Array:
    """``#{j : csrows[rows, j] ≤ y}`` (``< y`` when strict) per token by
    bisection — O(log K) scalar gathers per token instead of an O(K) row
    load; ``csrows`` rows are nondecreasing (cumsums of non-negatives,
    monotone under f32 rounding)."""
    kp = csrows.shape[1]
    t = rows.shape[0]
    steps = int(np.ceil(np.log2(kp + 1))) + 1

    def body(_, lo_hi):
        lo, hi = lo_hi
        act = lo < hi
        mid = (lo + hi) // 2
        v = csrows[rows, jnp.minimum(mid, kp - 1)]
        go = act & ((v < y) if strict else (v <= y))
        return (jnp.where(go, mid + 1, lo),
                jnp.where(act & ~go, mid, hi))

    lo = jnp.zeros(t, jnp.int32)
    hi = jnp.full(t, kp, jnp.int32)
    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def _lane_cumsum(x: jax.Array) -> jax.Array:
    """Sequential-association prefix sum over the last (lane) axis.

    NOT ``jnp.cumsum``: XLA may associate a parallel prefix sum
    differently at different widths, and the Pallas kernel runs this scan
    over lanes PADDED to the 128 boundary — appending exact ``+0.0``
    terms to a left-to-right chain preserves every prefix bitwise, which
    is what keeps ``sparse_pallas == sparse`` exact.  Lane counts are
    ≤ 256, so the unrolled chain is cheap."""
    cols = [x[..., 0:1]]
    for j in range(1, x.shape[-1]):
        cols.append(cols[-1] + x[..., j:j + 1])
    return jnp.concatenate(cols, axis=-1)


def _segment_draw(cs: jax.Array, total: jax.Array, x: jax.Array,
                  lanes_k: jax.Array) -> jax.Array:
    """Counted-clamped inverse-CDF draw within one padded lane segment
    (the ``sample_from_mass`` idiom, rowwise): returns the drawn lane's
    topic id.  Only consumed when ``x < total`` for the segment, where
    the clamp guarantees a positive-mass (hence valid) lane."""
    idx = jnp.sum((cs <= x[:, None]).astype(jnp.int32), axis=1)
    last = jnp.sum((cs < total[:, None]).astype(jnp.int32), axis=1)
    pick = jnp.minimum(jnp.minimum(idx, last), cs.shape[1] - 1)
    return jnp.take_along_axis(lanes_k, pick[:, None], axis=1)[:, 0]


def sparse_prologue(cdk, ckt_block, ck, doc, word_off, z, mask, alpha,
                    beta, vbeta, dcap: int, wcap: int) -> dict:
    """Round-frozen layout build + per-token operand gathers — everything
    upstream of the lane-mass arithmetic, shared verbatim by the jnp
    sampler and the Pallas wrapper (bit-identity by construction).

    Cost per round: O((Vb + D_loc)·K) for the lane extraction and the
    dense cumsum — the same amortization class as the MH table builds —
    then O(wcap + dcap + log K) per token."""
    k = ck.shape[0]
    ckt_f = ckt_block.astype(jnp.float32)
    cdk_f = cdk.astype(jnp.float32)
    ck_f = ck.astype(jnp.float32)
    denom = ck_f + vbeta

    nnz_w = jnp.sum((ckt_block > 0).astype(jnp.int32), axis=1)
    head = nnz_w > wcap                                    # [Vb]
    wl = _extract_lanes(ckt_block, wcap)                   # [Vb, wcap]
    dl = _extract_lanes(cdk, dcap)                         # [Dloc, dcap]

    # dense segment, frozen: D_v = α(β + head_v·C^t_v)/denom, cumsummed
    hterm = jnp.where(head[:, None], ckt_f, 0.0)
    dmass = alpha[None, :] * (beta + hterm) / denom[None, :]
    dcs = jnp.cumsum(dmass, axis=1)                        # [Vb, K]
    sdense_row = dcs[:, -1]

    h_t = head[word_off]                                   # [T]

    def gather(lanes_rows, rows):
        lanes = lanes_rows[rows]                           # [T, cap]
        valid = lanes < k
        kk = jnp.minimum(lanes, k - 1)
        return {"kk": kk, "valid": valid,
                "ckt": ckt_f[word_off[:, None], kk],
                "cdk": cdk_f[doc[:, None], kk],
                "ck": ck_f[kk], "alpha": alpha[kk]}

    wops = gather(wl, word_off)
    wops["valid"] = wops["valid"] & ~h_t[:, None]          # head: lane off
    dops = gather(dl, doc)

    # per-token rank-1 dense perturbation at z0: δ = D'(z0) − D(z0).
    # D(z0) recomputed per token is bitwise the cumsum addend (same ops
    # on the same gathered inputs), so sD + δ ≥ 0 and the shifted-suffix
    # search below is exact.
    a0 = alpha[z]
    c0 = ckt_f[word_off, z]
    k0 = ck_f[z]
    dz0 = a0 * (beta + jnp.where(h_t, c0, 0.0)) / (k0 + vbeta)
    dz0x = a0 * (beta + jnp.where(h_t, c0 - 1.0, 0.0)) / (k0 - 1.0 + vbeta)
    delta = jnp.where(mask, dz0x - dz0, 0.0)
    sdense = sdense_row[word_off] + delta

    return {"wops": wops, "dops": dops, "h_t": h_t, "dcs": dcs,
            "dcs_rows": word_off, "sdense": sdense, "delta": delta}


def tail_prologue(cdk, tail_topics, tail_counts, over_pad, row_map, ck,
                  doc, word_off, z, mask, alpha, beta, vbeta,
                  dcap: int) -> dict:
    """Store-native twin of :func:`sparse_prologue`: consumes a
    ``TailStore``'s device operands (`engine/countstore.py`) instead of a
    dense ``[Vb, K]`` block, producing a bitwise-identical ops dict.

    The memory win hinges on one observation: every TAIL row's dense
    segment is the SAME vector — ``head_v = 0`` makes
    ``D_v = α(β + 0)/denom`` word-independent — so instead of a
    ``[Vb, K]`` cumsum the dense segment is a ``[1 + Hcap, K]`` stack
    (row 0 the shared tail base, rows 1.. the overflow heads) reached
    through ``row_map``'s indirection.  Nothing in this function
    materializes a ``[Vb, K]`` buffer.

    Bitwise equivalence with the dense prologue is by construction:
    the stack rows run the exact op chain of the dense ``dmass``/cumsum
    (a zero ``hterm`` row for tails, the gathered head row otherwise),
    per-token gathers read the same count values (store lanes ==
    ``_extract_lanes`` of the frozen row, by the store's invariant), and
    every substitute gather on the indirection (sentinel lanes, clamped
    overflow indices for tail tokens) feeds positions the downstream
    ``where(valid, ·)`` / ``where(h_t, ·)`` masks discard — values may
    differ only where they are never consumed (no NaN risk: all
    denominators are positive)."""
    k = ck.shape[0]
    over_f = over_pad.astype(jnp.float32)
    cdk_f = cdk.astype(jnp.float32)
    ck_f = ck.astype(jnp.float32)
    denom = ck_f + vbeta

    dl = _extract_lanes(cdk, dcap)                         # [Dloc, dcap]

    # dense-segment stack: row 0 = shared tail base (hterm ≡ 0), rows
    # 1.. = overflow heads — same addend arithmetic as the dense path
    hstack = jnp.concatenate(
        [jnp.zeros((1, k), jnp.float32), over_f], axis=0)  # [1+Hcap, K]
    dmass = alpha[None, :] * (beta + hstack) / denom[None, :]
    dcs = jnp.cumsum(dmass, axis=1)
    sdense_row = dcs[:, -1]

    rows_t = row_map[word_off]                             # [T]; 0 = tail
    h_t = rows_t > 0
    orow = jnp.maximum(rows_t - 1, 0)                      # clamped: masked

    wlanes = tail_topics[word_off]                         # [T, wcap]
    wkk = jnp.minimum(wlanes, k - 1)
    wops = {"kk": wkk,
            "valid": (wlanes < k) & ~h_t[:, None],
            "ckt": tail_counts[word_off].astype(jnp.float32),
            "cdk": cdk_f[doc[:, None], wkk],
            "ck": ck_f[wkk], "alpha": alpha[wkk]}

    dlanes = dl[doc]                                       # [T, dcap]
    dkk = jnp.minimum(dlanes, k - 1)
    dops = {"kk": dkk, "valid": dlanes < k,
            "ckt": over_f[orow[:, None], dkk],
            "cdk": cdk_f[doc[:, None], dkk],
            "ck": ck_f[dkk], "alpha": alpha[dkk]}

    a0 = alpha[z]
    c0 = over_f[orow, z]
    k0 = ck_f[z]
    dz0 = a0 * (beta + jnp.where(h_t, c0, 0.0)) / (k0 + vbeta)
    dz0x = a0 * (beta + jnp.where(h_t, c0 - 1.0, 0.0)) / (k0 - 1.0 + vbeta)
    delta = jnp.where(mask, dz0x - dz0, 0.0)
    sdense = sdense_row[rows_t] + delta

    return {"wops": wops, "dops": dops, "h_t": h_t, "dcs": dcs,
            "dcs_rows": rows_t, "sdense": sdense, "delta": delta}


def lane_masses_jnp(wops, dops, h_t, z0, mask, beta, vbeta):
    """The lane-segment arithmetic the Pallas kernel mirrors op-for-op:
    masses, cumsums and segment totals for the word-sparse and
    document-sparse lanes of every token."""
    ew = ((wops["kk"] == z0[:, None]) & mask[:, None]).astype(jnp.float32)
    wraw = ((wops["alpha"] + (wops["cdk"] - ew)) * (wops["ckt"] - ew)
            / (wops["ck"] - ew + vbeta))
    wval = jnp.maximum(jnp.where(wops["valid"], wraw, 0.0), 0.0)
    wcs = _lane_cumsum(wval)
    sw = wcs[:, -1]

    ed = ((dops["kk"] == z0[:, None]) & mask[:, None]).astype(jnp.float32)
    cross = jnp.where(h_t[:, None], dops["ckt"] - ed, 0.0)
    draw_ = ((dops["cdk"] - ed) * (beta + cross)
             / (dops["ck"] - ed + vbeta))
    dval = jnp.maximum(jnp.where(dops["valid"], draw_, 0.0), 0.0)
    dcs = _lane_cumsum(dval)
    sd = dcs[:, -1]
    return wcs, sw, dcs, sd


def _lane_draw_jnp(ops, z0, mask, u, beta, vbeta):
    """jnp lane block: segment the CDF as [word | doc | dense], draw the
    lane segments, hand the dense residual to the epilogue.  Returns
    ``(z_lane, is_dense, y_dense)`` — the Pallas kernel computes exactly
    this triple."""
    wops, dops, h_t = ops["wops"], ops["dops"], ops["h_t"]
    wcs, sw, dcs, sd = lane_masses_jnp(wops, dops, h_t, z0, mask, beta,
                                       vbeta)
    total = sw + sd + ops["sdense"]
    x = u * total
    yd = x - sw
    ydense = yd - sd
    in_w = x < sw
    in_d = ~in_w & (yd < sd)
    kw = _segment_draw(wcs, sw, x, wops["kk"])
    kd = _segment_draw(dcs, sd, yd, dops["kk"])
    z_lane = jnp.where(in_w, kw, kd)
    return z_lane, ~(in_w | in_d), ydense


def _dense_segment_pick(ops, ydense, z, k):
    """Dense-segment draw: shifted-suffix bisection on the frozen cumsum
    rows, indexed through ``ops["dcs_rows"]`` (the word row itself in the
    dense layout, the shared-base/overflow indirection in the tail
    layout — same gathered values either way).

    Counted draw on the z0-perturbed cumsum Dcs'_k = Dcs_k + δ·[k ≥ z0]:
    split the count at z0 — prefix counts against y, suffix against
    y − δ — so the rank-1 exclusion never materializes a dense row."""
    dcs, delta, rows = ops["dcs"], ops["delta"], ops["dcs_rows"]
    c1 = _row_count(dcs, rows, ydense)
    c2 = _row_count(dcs, rows, ydense - delta)
    idx = jnp.minimum(c1, z) + jnp.maximum(c2 - z, 0)
    l1 = _row_count(dcs, rows, ops["sdense"], strict=True)
    l2 = _row_count(dcs, rows, ops["sdense"] - delta, strict=True)
    last = jnp.minimum(l1, z) + jnp.maximum(l2 - z, 0)
    return jnp.minimum(jnp.minimum(idx, last), k - 1).astype(jnp.int32)


def sparse_epilogue(ops, z_lane, is_dense, ydense, cdk, ckt_block, ck,
                    doc, word_off, z, mask):
    """Dense-segment draw + final select + exact delta fold — downstream
    of the lane block, shared by the jnp and Pallas paths."""
    k = ck.shape[0]
    k_dense = _dense_segment_pick(ops, ydense, z, k)

    z_new = jnp.where(is_dense, k_dense, z_lane)
    z_new = jnp.where(mask, z_new, z)
    d = mask.astype(jnp.int32)
    cdk = cdk.at[doc, z].add(-d).at[doc, z_new].add(d)
    ckt_block = ckt_block.at[word_off, z].add(-d).at[word_off, z_new].add(d)
    ck = ck.at[z].add(-d).at[z_new].add(d)
    return cdk, ckt_block, ck, z_new


@partial(jax.jit, static_argnames=("dcap", "wcap"))
def sweep_block_sparse(cdk, ckt_block, ck, doc, word_off, z, mask, u,
                       alpha, beta, vbeta, dcap: int = 64,
                       wcap: int = DEFAULT_WCAP):
    """Engine-facing hybrid sparse sampler (module docstring).  Same
    signature and frozen-count semantics as ``sweep_block_batched``; the
    registry closes ``dcap``/``wcap`` over it (static — they shape every
    lane buffer)."""
    ops = sparse_prologue(cdk, ckt_block, ck, doc, word_off, z, mask,
                          alpha, beta, vbeta, dcap, wcap)
    z_lane, is_dense, ydense = _lane_draw_jnp(ops, z, mask, u, beta, vbeta)
    return sparse_epilogue(ops, z_lane, is_dense, ydense, cdk, ckt_block,
                           ck, doc, word_off, z, mask)


@partial(jax.jit, static_argnames=("dcap",))
def sweep_block_sparse_tail(cdk, tail_topics, tail_counts, over_pad,
                            row_map, ck, doc, word_off, z, mask, u,
                            alpha, beta, vbeta, dcap: int = 64):
    """Store-native form of :func:`sweep_block_sparse`: the word-count
    block arrives as a ``TailStore``'s device operands (lane pair +
    overflow stack + row map) and is never densified — the ZERO-
    CONVERSION path of DESIGN.md §16.  ``wcap`` is implied by the lane
    shape; ``dcap`` stays static (it shapes the doc-lane buffers).

    Returns ``(cdk, ck, z_new)`` — the word-block fold happens host-side
    via ``TailStore.apply_token_delta`` (exact, order-free integer
    adds), which is bitwise equal to the dense path's
    ``frozen + Σ(out − frozen)`` commit.  Draw-for-draw equality with
    :func:`sweep_block_sparse` on the densified block is pinned by
    tests/test_countstore.py."""
    ops = tail_prologue(cdk, tail_topics, tail_counts, over_pad, row_map,
                        ck, doc, word_off, z, mask, alpha, beta, vbeta,
                        dcap)
    z_lane, is_dense, ydense = _lane_draw_jnp(ops, z, mask, u, beta, vbeta)
    k = ck.shape[0]
    k_dense = _dense_segment_pick(ops, ydense, z, k)
    z_new = jnp.where(is_dense, k_dense, z_lane)
    z_new = jnp.where(mask, z_new, z)
    d = mask.astype(jnp.int32)
    cdk = cdk.at[doc, z].add(-d).at[doc, z_new].add(d)
    ck = ck.at[z].add(-d).at[z_new].add(d)
    return cdk, ck, z_new
