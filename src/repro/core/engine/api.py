"""Public engine facade: :class:`ModelParallelLDA` (the paper's full
system, generalized to ``S`` blocks per worker and ``D`` data replicas —
DESIGN.md §2–§3, §8).

Example::

    lda = ModelParallelLDA(corpus, num_topics=64, num_workers=8,
                           blocks_per_worker=4)   # 32-block pipeline
    history = lda.run(num_iterations=50)
    state = lda.gather_counts()

    hybrid = ModelParallelLDA(corpus, num_topics=64, num_workers=8,
                              data_parallel=4)    # 4 × 8 (data, model) grid
    hybrid.run(num_iterations=50)

``blocks_per_worker`` (``S``) is the model-capacity lever: the resident
word-topic block per worker is ``ceil(V / (S·M)) × K`` rows, so growing
``S`` shrinks the per-worker resident model without adding workers —
the paper's "model size exceeds any single node's RAM" claim as a tunable.

``sampler_mode`` selects the per-block sampler from the `rounds.py`
registry: the exact ``scan``, the word-frozen ``batched``/``pallas``
pair, or the O(1) alias-table MH pair ``mh``/``mh_pallas`` (DESIGN.md
§9).  The MH modes target the same collapsed posterior but are only
distribution-equal to the exact chain, so their validation is the
statistical suite `tests/test_mh_stats.py` plus a draw-for-draw host
oracle replay (`kvstore.HostModelParallelLDA(sampler="mh")`).

``table_lifetime`` governs how long MH proposal tables live (DESIGN.md
§10): ``"iteration"`` (the default for the MH family) builds each
block's word table once per iteration at its first residency and rotates
the packed table with the block, with doc tables built once from
iteration-start counts — amortizing the O((Vb + D_loc)·K) build by a
factor of ``S·M``; ``"round"`` is the original rebuild-every-round
schedule (the A/B baseline).  The chain stays exact either way — the
eq.-(1) acceptance corrects arbitrarily stale proposals — and the host
oracle mirrors whichever schedule is selected, so replay stays bitwise.

``track_error=False`` skips the per-round Fig-3 drift statistic (the
``delta_error()`` history) — benchmarks use it to keep the hot path free
of an unconsumed [R, K]-wide reduction per round.

``data_parallel`` (``D``) is the throughput lever: documents shard
``D·M`` ways over a 2D ``(data, model)`` grid while each replica keeps a
copy of the block pipeline, reconciled by a per-round delta psum along
``data`` (the AD-LDA all-reduce confined to the resident slice).  The
parallelization error stays confined to ``{C_k}`` within a round —
doc-topic counts are exact by construction, word-topic counts exact at
every round boundary — which is the quantity the paper measures in
Figs 2–4.  ``D = 1`` is bit-identical to the original 1D engine
(``engine/reference.py``); ``M = 1`` degenerates to AD-LDA
(``core/data_parallel.py``'s staleness model with ``S`` vocabulary-sliced
sync points per iteration).
"""
from __future__ import annotations

import json
import os
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.counts import CountState
from repro.core.engine import state as engine_state
from repro.core.engine.backends import (iteration_vmap,
                                        make_shard_map_iteration)
from repro.core.engine.rounds import resolve_sampler, table_capable
from repro.core.likelihood import doc_log_likelihood, word_log_likelihood
from repro.data.corpus import Corpus


class ModelParallelLDA:
    """Model-parallel LDA trainer over an ``S·M``-block pipeline."""

    def __init__(self, corpus: Corpus, num_topics: int, num_workers: int,
                 alpha: float | np.ndarray = 0.1, beta: float = 0.01,
                 seed: int = 0, sampler_mode: str = "scan",
                 sync_ck: bool = True, backend: str = "vmap",
                 mesh: Optional[Mesh] = None, axis: str = "w",
                 blocks_per_worker: int = 1, data_parallel: int = 1,
                 data_axis: str = "data",
                 table_lifetime: Optional[str] = None,
                 track_error: bool = True,
                 sampler_args: Optional[tuple] = None,
                 store: str = "dense"):
        corpus.validate()
        if blocks_per_worker < 1:
            raise ValueError(
                f"blocks_per_worker must be >= 1, got {blocks_per_worker}")
        if data_parallel < 1:
            raise ValueError(
                f"data_parallel must be >= 1, got {data_parallel}")
        if data_parallel > 1 and not sync_ck:
            raise ValueError(
                "data_parallel > 1 requires sync_ck=True: replica copies "
                "of a block are only well-defined between round "
                "boundaries (same restriction as the host oracle)")
        self.corpus = corpus
        self.num_topics = int(num_topics)
        self.num_workers = int(num_workers)
        self.blocks_per_worker = int(blocks_per_worker)
        self.data_parallel = int(data_parallel)
        self.alpha = jnp.full((num_topics,), alpha, jnp.float32) \
            if np.isscalar(alpha) else jnp.asarray(alpha, jnp.float32)
        self.beta = float(beta)
        self.vbeta = float(beta * corpus.vocab_size)
        if sampler_args is None:
            if sampler_mode in ("sparse", "sparse_pallas"):
                # the sparse family needs its static lane capacities: dcap
                # must bound nnz(cdk row) ≤ min(K, longest doc); the host
                # oracle derives the SAME config from the same corpus so
                # replays run the identical jitted sampler.
                from repro.core.sparse_device import default_sparse_args
                sampler_args = default_sparse_args(
                    num_topics, int(corpus.doc_lengths().max()))
            else:
                sampler_args = ()
        self.sampler_args = tuple(sampler_args)
        resolve_sampler(sampler_mode, self.sampler_args)  # fail fast
        self.sampler_mode = sampler_mode
        from repro.core.engine import countstore
        countstore.resolve_store(store)                   # fail fast
        self.store_kind = store
        self._store_wcap = int(dict(self.sampler_args).get(
            "wcap", countstore.DEFAULT_TAIL_WCAP))
        if table_lifetime is None:
            # the amortized schedule is the default wherever it applies
            table_lifetime = ("iteration" if table_capable(sampler_mode)
                              else "round")
        if table_lifetime not in ("round", "iteration"):
            raise ValueError(
                f"unknown table_lifetime {table_lifetime!r}; "
                "expected 'round' or 'iteration'")
        if table_lifetime == "iteration" and not table_capable(sampler_mode):
            raise ValueError(
                f"table_lifetime='iteration' needs a table-capable "
                f"sampler (the MH family), got {sampler_mode!r}")
        self.table_lifetime = table_lifetime
        self.track_error = bool(track_error)
        self.sync_ck = bool(sync_ck)
        self.backend = backend
        self.axis = axis
        self.data_axis = data_axis
        self._rng = np.random.default_rng(seed)
        self._build()
        if backend == "shard_map":
            # 2D (data, model) layout when D > 1 or the caller hands us a
            # mesh that already carries the data axis (lets tests exercise
            # the 2D code path at D = 1).
            use_2d = (self.data_parallel > 1
                      or (mesh is not None and data_axis in mesh.axis_names))
            need = self.num_shards
            if mesh is None:
                if len(jax.devices()) < need:
                    raise ValueError(
                        f"shard_map backend needs {need} devices, "
                        f"have {len(jax.devices())}")
                if use_2d:
                    mesh = Mesh(
                        np.array(jax.devices()[:need]).reshape(
                            self.data_parallel, self.num_workers),
                        (data_axis, axis))
                else:
                    mesh = Mesh(np.array(jax.devices()[:need]), (axis,))
            else:
                # a mismatched mesh would silently drop grid rows (each
                # device keeps only its first local row) — reject early
                want = {axis: self.num_workers}
                if use_2d:
                    want[data_axis] = self.data_parallel
                got = dict(mesh.shape)
                if got != want:
                    raise ValueError(
                        f"mesh axes {got} do not match the "
                        f"(data_parallel={self.data_parallel}, "
                        f"num_workers={self.num_workers}) grid; expected "
                        f"exactly {want}")
            self.mesh = mesh
            self._iter_fn = make_shard_map_iteration(
                mesh, axis, sampler_mode, sync_ck,
                data_axis=data_axis if use_2d else None,
                table_lifetime=self.table_lifetime,
                track_error=self.track_error,
                sampler_args=self.sampler_args)
        else:
            self.mesh = None
            self._iter_fn = None

    # -- construction ------------------------------------------------------
    def _build(self) -> None:
        self.layout = engine_state.build_layout(
            self.corpus, self.num_workers, self.blocks_per_worker,
            self.data_parallel)
        z0 = self._rng.integers(
            0, self.num_topics, size=self.corpus.num_tokens).astype(np.int32)
        self.z_init = z0
        self.state = engine_state.init_state(self.layout, self.num_topics,
                                             z0)
        self.iteration_count = 0

    # -- layout views (kept as attributes of the facade) -------------------
    @property
    def partition(self):
        return self.layout.partition

    @property
    def shards(self):
        return self.layout.shards

    @property
    def indexes(self):
        return self.layout.indexes

    @property
    def capacity(self) -> int:
        return self.layout.capacity

    @property
    def doc(self):
        return self.layout.doc

    @property
    def woff(self):
        return self.layout.woff

    @property
    def mask(self):
        return self.layout.mask

    @property
    def num_shards(self) -> int:
        """Worker-grid rows ``R = D·M`` (== ``M`` at ``data_parallel=1``)."""
        return self.layout.num_shards

    @property
    def num_blocks(self) -> int:
        return self.layout.num_blocks

    @property
    def num_rounds(self) -> int:
        return self.layout.num_rounds

    @property
    def resident_block_rows(self) -> int:
        """``ceil(V / (S·M))`` — rows of the block a worker actively holds."""
        return self.layout.resident_block_rows

    def memory_report(self) -> dict:
        """Resident-vs-total model bytes (the paper's capacity claim),
        extended with the hybrid grid: the model is replicated ``D`` times
        (one copy per data replica, sharded over its ``M`` workers), so
        distributed bytes grow with ``D`` while the per-worker resident
        block stays ``ceil(V/(S·M)) × K`` — the two levers are orthogonal.
        """
        k = self.num_topics
        vb = self.resident_block_rows
        rep = {
            "num_workers": self.num_workers,
            "blocks_per_worker": self.blocks_per_worker,
            "data_parallel": self.data_parallel,
            "num_shards": self.num_shards,
            "num_blocks": self.num_blocks,
            "resident_block_shape": (vb, k),
            "resident_block_bytes": vb * k * 4,
            "parked_bytes_per_worker": (self.blocks_per_worker - 1)
            * vb * k * 4,
            "total_model_bytes": self.corpus.vocab_size * k * 4,
            "replica_model_bytes": self.num_blocks * vb * k * 4,
            "distributed_model_bytes": self.data_parallel
            * self.num_blocks * vb * k * 4,
            "store": self.store_kind,
        }
        if self.store_kind != "dense":
            # at-rest occupancy of the current chain under the selected
            # store (what a checkpoint of this state occupies)
            stores = engine_state.ckt_to_stores(
                np.asarray(self.state.ckt), self.store_kind,
                self._store_wcap)
            agg = {"head_rows": 0, "tail_rows": 0, "overflow_rows": 0,
                   "tail_nnz": 0}
            total = 0
            for st in stores:
                occ = st.occupancy()
                for key in agg:
                    agg[key] += occ[key]
                total += occ["nbytes_resident"]
            rep["store_occupancy"] = agg
            rep["total_store_bytes"] = total
        return rep

    def store_note(self) -> Optional[str]:
        """Densification note for the CLI config echo (DESIGN.md §16), or
        ``None`` for the dense default.  The in-memory engine's DEVICE
        chain is always dense — jit/donation/ppermute need static shapes
        — so a compressed store here governs the AT-REST artifacts
        (checkpoints) and is decoded to the dense device state on resume;
        the resident-memory win lives in the streaming engine."""
        if self.store_kind == "dense":
            return None
        vb, k = self.resident_block_rows, self.num_topics
        mib = self.num_blocks * vb * k * 4 / 2**20
        return (f"store={self.store_kind!r}: in-memory engine computes "
                f"on the dense device chain ({mib:.1f} MiB resident); "
                f"{self.store_kind!r} encoding applies to checkpoints "
                "at rest (use the streaming engine + sparse family for "
                "a compressed resident block)")

    # -- stepping ----------------------------------------------------------
    def _uniforms(self) -> jax.Array:
        b, r, cap = self.num_rounds, self.num_shards, self.capacity
        u = self._rng.random((b, r, cap), np.float32)  # [rounds, rows, T]
        return jnp.asarray(u)

    def step(self) -> None:
        """Run one iteration (= S·M rounds, every token sampled once)."""
        from repro.core import faults
        faults.fire("step", f"iter:{self.iteration_count},engine:mp")
        u = self._uniforms()
        if self.backend == "vmap":
            self.state, errs = iteration_vmap(
                self.state, u, self.doc, self.woff, self.mask,
                self.alpha, jnp.float32(self.beta), jnp.float32(self.vbeta),
                sampler_mode=self.sampler_mode, sync_ck=self.sync_ck,
                data_parallel=self.data_parallel,
                table_lifetime=self.table_lifetime,
                track_error=self.track_error,
                sampler_args=self.sampler_args)
        else:
            s = self.state
            out = self._iter_fn(
                s.cdk, s.ckt, s.block_id, s.ck_synced, s.ck_local, s.z,
                jnp.swapaxes(u, 0, 1), self.doc, self.woff, self.mask,
                self.alpha, jnp.float32(self.beta), jnp.float32(self.vbeta))
            self.state = engine_state.MPState(*out[:6])
            errs = out[6]
        self.round_errors = (np.asarray(errs).reshape(-1)
                             if self.track_error else np.zeros(0))
        self.iteration_count += 1

    def run(self, num_iterations: int,
            callback: Optional[Callable[[int, "ModelParallelLDA"],
                                        None]] = None,
            eval_every: int = 1) -> List[dict]:
        history = []
        for i in range(num_iterations):
            self.step()
            if (i + 1) % eval_every == 0:
                history.append({"iteration": self.iteration_count,
                                "log_likelihood": self.log_likelihood()})
            if callback is not None:
                callback(i, self)
        return history

    # -- checkpoint / resume -----------------------------------------------
    CKPT_FORMAT = "mp-lda-ckpt-v1"
    CKPT_FORMAT_V2 = "mp-lda-ckpt-v2"

    def save_checkpoint(self, path: str) -> str:
        """Serialize the full chain state to one ``.npz``: the six
        ``MPState`` arrays (the slot queues ``ckt``/``block_id`` included),
        the host rng's bit-generator state, the iteration count, and a
        config echo.  Taken at an iteration boundary — the only place
        ``step()`` returns control — where the traveling-table queue is
        empty (tables are iteration-local derived state, DESIGN.md §10)
        and ``ck_synced`` is reconciled, so nothing sampler- or
        backend-specific needs saving: a checkpoint written by the vmap
        backend resumes bit-exactly on shard_map and vice versa.

        The write is atomic (temp file + ``os.replace``), so a kill during
        checkpointing leaves either the old file or the new one, never a
        torn state.

        Format versioning (DESIGN.md §16): a dense-store engine writes
        the bitwise-frozen v1 record (``ckt`` as one dense array); a
        compressed store writes v2, where the slot queue is encoded as
        per-slot ``store-v2`` CountStore records.  :meth:`resume` reads
        both, and either decodes to the identical dense device state —
        cross-store resume is bitwise."""
        from repro.data.corpus import npz_stem
        s = self.state
        cfg = {
            "format": (self.CKPT_FORMAT if self.store_kind == "dense"
                       else self.CKPT_FORMAT_V2),
            "store": self.store_kind,
            "store_wcap": self._store_wcap,
            "num_topics": self.num_topics,
            "num_workers": self.num_workers,
            "blocks_per_worker": self.blocks_per_worker,
            "data_parallel": self.data_parallel,
            "sampler_mode": self.sampler_mode,
            "sampler_args": [list(p) for p in self.sampler_args],
            "table_lifetime": self.table_lifetime,
            "sync_ck": self.sync_ck,
            "alpha": np.asarray(self.alpha, np.float32).tolist(),
            "beta": self.beta,
            "iteration_count": self.iteration_count,
            # corpus fingerprint: resume re-derives the static layout from
            # the corpus, so the wrong corpus must be rejected loudly
            "num_tokens": self.corpus.num_tokens,
            "vocab_size": self.corpus.vocab_size,
            "num_docs": self.corpus.num_docs,
        }
        from repro.core import faults
        from repro.data import integrity
        rng_state = self._rng.bit_generator.state
        stem = npz_stem(path)
        os.makedirs(os.path.dirname(stem) or ".", exist_ok=True)
        final = stem + ".npz"
        faults.fire("mp_ckpt.begin", final)
        # atomic + crc32-sidecar publish (DESIGN.md §15): integrity.save_npz
        # writes a temp file, fsyncs, os.replace-s, then stamps <path>.sum
        # — its npz.tmp_written fire point plus mp_ckpt.begin/promoted here
        # bracket every instant the kill-during-checkpoint tests target
        arrays = dict(
            cdk=np.asarray(s.cdk),
            block_id=np.asarray(s.block_id),
            ck_synced=np.asarray(s.ck_synced),
            ck_local=np.asarray(s.ck_local), z=np.asarray(s.z),
            config=np.frombuffer(
                json.dumps(cfg).encode(), np.uint8),
            rng_state=np.frombuffer(
                json.dumps(rng_state).encode(), np.uint8))
        if self.store_kind == "dense":
            arrays["ckt"] = np.asarray(s.ckt)
        else:
            # v2: the slot queue as per-slot CountStore records
            stores = engine_state.ckt_to_stores(
                np.asarray(s.ckt), self.store_kind, self._store_wcap)
            aux_list = []
            for i, st in enumerate(stores):
                aux, arrs = st.pack()
                aux_list.append(aux)
                for name, arr in arrs.items():
                    arrays[f"store{i}_{name}"] = arr
            arrays["store_aux"] = np.frombuffer(
                json.dumps(aux_list).encode(), np.uint8)
        integrity.save_npz(final, **arrays)
        faults.fire("mp_ckpt.promoted", final)
        return final

    @classmethod
    def resume(cls, corpus: Corpus, path: str, backend: str = "vmap",
               mesh: Optional[Mesh] = None, axis: str = "w",
               data_axis: str = "data",
               track_error: bool = True,
               store: Optional[str] = None) -> "ModelParallelLDA":
        """Rebuild a trainer from :meth:`save_checkpoint` output.  The
        geometry, sampler, and hyperparameters come from the checkpoint's
        config echo; the backend is the caller's choice (checkpoints are
        backend-agnostic).  The restored run is draw-for-draw identical
        to one that never stopped: the static layout is a pure function
        of ``(corpus, M, S, D)``, the chain state is restored bitwise,
        and the rng continues from the saved bit-generator state.

        Both checkpoint formats load: v1 stores ``ckt`` dense, v2 as
        per-slot CountStore records — either decodes to the identical
        device state (integer round-trip), so resuming a v2 checkpoint
        continues the v1 chain bitwise and vice versa.  ``store``
        overrides the checkpoint's store kind for the resumed trainer
        (``None`` keeps it); the override only changes how FUTURE
        checkpoints are encoded, never the chain."""
        from repro.data import integrity
        from repro.data.corpus import npz_stem
        from repro.core.engine import countstore
        stem = npz_stem(path)
        # validated load: a bit-flipped or torn checkpoint raises the
        # integrity taxonomy here instead of np.load's zip errors (or
        # silently-decoded garbage) poisoning the resumed chain
        data = integrity.load_npz(stem + ".npz")
        try:
            cfg = json.loads(bytes(data["config"]).decode())
            rng_state = json.loads(bytes(data["rng_state"]).decode())
            arrays = {k: np.asarray(data[k]) for k in
                      ("cdk", "block_id", "ck_synced",
                       "ck_local", "z")}
        except KeyError as e:
            raise ValueError(
                f"{stem}.npz is not an engine checkpoint: "
                f"missing {e}") from e
        fmt = cfg.get("format")
        if fmt not in (cls.CKPT_FORMAT, cls.CKPT_FORMAT_V2):
            raise ValueError(
                f"unknown checkpoint format {fmt!r} in {stem}.npz; "
                f"expected {cls.CKPT_FORMAT!r} or {cls.CKPT_FORMAT_V2!r}")
        if fmt == cls.CKPT_FORMAT:
            arrays["ckt"] = np.asarray(data["ckt"])
        else:
            aux_list = json.loads(bytes(data["store_aux"]).decode())
            keys = list(data.keys())
            stores = []
            for i, aux in enumerate(aux_list):
                pre = f"store{i}_"
                arrs = {k[len(pre):]: np.asarray(data[k])
                        for k in keys if k.startswith(pre)}
                stores.append(countstore.unpack_record(aux, arrs))
            r = int(cfg["data_parallel"]) * int(cfg["num_workers"])
            arrays["ckt"] = engine_state.ckt_from_stores(
                stores, r, int(cfg["blocks_per_worker"]))
        for key in ("num_tokens", "vocab_size", "num_docs"):
            if int(cfg[key]) != int(getattr(corpus, key)):
                raise ValueError(
                    f"corpus does not match checkpoint: {key} is "
                    f"{getattr(corpus, key)}, checkpoint has {cfg[key]}")
        lda = cls(corpus, num_topics=cfg["num_topics"],
                  num_workers=cfg["num_workers"],
                  alpha=np.asarray(cfg["alpha"], np.float32),
                  beta=cfg["beta"],
                  sampler_mode=cfg["sampler_mode"],
                  sync_ck=cfg["sync_ck"], backend=backend, mesh=mesh,
                  axis=axis, blocks_per_worker=cfg["blocks_per_worker"],
                  data_parallel=cfg["data_parallel"],
                  data_axis=data_axis,
                  table_lifetime=cfg["table_lifetime"],
                  track_error=track_error,
                  sampler_args=tuple(
                      tuple(p) for p in cfg["sampler_args"]),
                  store=(store if store is not None
                         else cfg.get("store", "dense")))
        lda.state = engine_state.MPState(
            cdk=jnp.asarray(arrays["cdk"]),
            ckt=jnp.asarray(arrays["ckt"]),
            block_id=jnp.asarray(arrays["block_id"]),
            ck_synced=jnp.asarray(arrays["ck_synced"]),
            ck_local=jnp.asarray(arrays["ck_local"]),
            z=jnp.asarray(arrays["z"]))
        lda._rng.bit_generator.state = rng_state
        lda.iteration_count = int(cfg["iteration_count"])
        return lda

    # -- observation -------------------------------------------------------
    def gather_counts(self) -> CountState:
        """Reassemble the global model (the KV-store "dump")."""
        return engine_state.gather_counts(self.layout, self.state,
                                          self.num_topics)

    def snapshot(self, build_tables: bool = False):
        """Export the frozen serving snapshot (DESIGN.md §11): the
        reassembled ``C_k^t``/``C_k`` blocks plus — built once per
        snapshot, lazily unless ``build_tables`` — the packed per-word
        alias tables (`alias.pack_tables` layout) that make frozen-model
        MH fold-in O(1) per query token.  The export is taken at an
        iteration boundary, where every replica's block copies agree, so
        snapshots are backend- and geometry-independent for the same
        chain (the fold-in oracle tests pin this at several (D, M, S)).
        """
        from repro.core.infer import ModelSnapshot
        state = self.gather_counts()
        return ModelSnapshot.from_counts(
            np.asarray(state.ckt), np.asarray(state.ck),
            np.asarray(self.alpha), self.beta, build_tables=build_tables)

    def assignments(self) -> np.ndarray:
        """Current z in original token order."""
        return engine_state.gather_assignments(self.layout, self.state)

    def log_likelihood(self) -> float:
        state = self.gather_counts()
        lw = word_log_likelihood(state.ckt, state.ck, self.beta)
        ld = doc_log_likelihood(state.cdk, self.alpha)
        return float(lw + ld)

    def delta_error(self) -> float:
        """Mean pre-sync Δ_{r,i} over the rounds of the last iteration
        (paper Fig 3).  Falls back to the current post-sync drift if no
        iteration has run yet."""
        errs = getattr(self, "round_errors", None)
        if errs is not None and errs.size:
            return float(errs.mean())
        from repro.core.metrics import delta_error
        return delta_error(self.state.true_ck(),
                           self.state.local_ck_views())
