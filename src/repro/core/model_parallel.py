"""Model-parallel collapsed Gibbs sampling for LDA (paper §3–§4).

The engine implements Algorithm 1 (scheduler) + Algorithm 2 (worker) as a
single SPMD program:

  * documents are sharded over ``M`` workers (data-parallelism);
  * the word-topic table is partitioned into ``M`` disjoint word blocks
    (model-parallelism); worker ``m`` holds block ``(m + r) mod M`` in
    round ``r``;
  * rotation = one ``jax.lax.ppermute`` of the resident block per round —
    the "scheduler" is a compile-time permutation, the "key-value store"
    is the sharded array itself (DESIGN.md §2);
  * the non-separable topic totals ``{C_k}`` are synchronized once per
    round via ``psum`` of per-worker deltas and drift in between (§3.3).

Two execution backends with bit-identical semantics:

  * ``backend="vmap"`` — the worker axis is a batch axis on one device;
    ``ppermute`` becomes ``jnp.roll``, ``psum`` a sum.  Runs anywhere,
    used by tests/benchmarks on the single-CPU container.
  * ``backend="shard_map"`` — the worker axis is a mesh axis; collectives
    are real.  This is the production path; on the dry-run mesh the round
    rotation lowers to HLO ``collective-permute``.

Both backends share ``_worker_round`` so agreement tests are meaningful.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import schedule as sched
from repro.core.counts import CountState
from repro.core.invindex import build_inverted_index, scatter_assignments
from repro.core.likelihood import doc_log_likelihood, word_log_likelihood
from repro.core.sampler import sweep_block_batched, sweep_block_scan
from repro.data.corpus import Corpus
from repro.data.sharding import worker_shard


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MPState:
    """Stacked per-worker state (leading axis = workers)."""

    cdk: jax.Array        # [M, Dloc, K]
    ckt: jax.Array        # [M, Vb, K] resident block per worker
    block_id: jax.Array   # [M] which block each worker currently holds
    ck_synced: jax.Array  # [K] totals agreed at last round boundary
    ck_local: jax.Array   # [M, K] per-worker drifting view (§3.3)
    z: jax.Array          # [M, B, T] assignments in inverted-index layout

    def tree_flatten(self):
        return ((self.cdk, self.ckt, self.block_id, self.ck_synced,
                 self.ck_local, self.z), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def local_ck_views(self) -> np.ndarray:
        return np.asarray(self.ck_local)

    def true_ck(self) -> np.ndarray:
        return np.asarray(self.ck_synced) + (
            np.asarray(self.ck_local)
            - np.asarray(self.ck_synced)[None, :]).sum(axis=0)


def _worker_round(cdk, ckt_blk, block_id, ck_loc, z_all, u_r,
                  doc, woff, mask, alpha, beta, vbeta, *, sampler):
    """One worker, one round: sample the token group of the resident block.

    This is Algorithm 2 lines 2–5 — the "request model block" /
    "commit model block" steps are the surrounding rotation collective.
    """
    d = doc[block_id]
    t = woff[block_id]
    zz = z_all[block_id]
    mk = mask[block_id]
    cdk, ckt_blk, ck_loc, z_new = sampler(
        cdk, ckt_blk, ck_loc, d, t, zz, mk, u_r, alpha, beta, vbeta)
    z_all = z_all.at[block_id].set(z_new)
    return cdk, ckt_blk, ck_loc, z_all


def _make_sampler(mode: str):
    if mode == "scan":
        return partial(sweep_block_scan, use_eq3=True)
    if mode == "scan_eq1":
        return partial(sweep_block_scan, use_eq3=False)
    if mode == "batched":
        def f(cdk, ckt, ck, d, t, z, mk, u, alpha, beta, vbeta):
            return sweep_block_batched(cdk, ckt, ck, d, t, z, mk, u,
                                       alpha, beta, vbeta, None)
        return f
    if mode == "pallas":
        from repro.kernels.ops import sweep_block_pallas
        return sweep_block_pallas
    raise ValueError(f"unknown sampler mode {mode!r}")


# ---------------------------------------------------------------------------
# vmap backend (single device, worker axis = batch axis)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("sampler_mode", "sync_ck"))
def _iteration_vmap(state: MPState, u, doc, woff, mask, alpha, beta, vbeta,
                    sampler_mode: str = "scan", sync_ck: bool = True):
    """One full iteration = M rounds with rotation, stacked on one device."""
    sampler = _make_sampler(sampler_mode)
    num_workers = doc.shape[0]

    round_fn = partial(_worker_round, sampler=sampler)

    def round_step(carry, u_r):
        cdk, ckt, blk, ck_syn, ck_loc, z = carry
        cdk, ckt, ck_loc, z = jax.vmap(
            round_fn, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0,
                               None, None, None))(
            cdk, ckt, blk, ck_loc, z, u_r, doc, woff, mask,
            alpha, beta, vbeta)
        # rotation m -> m-1: the new resident block of worker m is the one
        # worker m+1 held, i.e. roll the stacked block axis by -1.
        ckt = jnp.roll(ckt, -1, axis=0)
        blk = jnp.roll(blk, -1, axis=0)
        # paper Fig-3 error: pre-sync ℓ1 drift of local {C_k} vs true totals
        ck_true = ck_syn + (ck_loc - ck_syn[None, :]).sum(axis=0)
        n_tok = jnp.maximum(ck_true.sum(), 1).astype(jnp.float32)
        err = (jnp.abs(ck_loc - ck_true[None, :]).sum().astype(jnp.float32)
               / (ck_loc.shape[0] * n_tok))
        if sync_ck:
            ck_loc = jnp.broadcast_to(ck_true, ck_loc.shape)
            ck_syn = ck_true
        return (cdk, ckt, blk, ck_syn, ck_loc, z), err

    carry = (state.cdk, state.ckt, state.block_id, state.ck_synced,
             state.ck_local, state.z)
    carry, errs = jax.lax.scan(round_step, carry, u)
    del num_workers
    return MPState(*carry), errs


# ---------------------------------------------------------------------------
# shard_map backend (one worker per device)
# ---------------------------------------------------------------------------

def _iteration_shard_map(mesh: Mesh, axis: str, sampler_mode: str,
                         sync_ck: bool):
    """Build the jitted per-device iteration function for ``mesh``."""
    perm = sched.rotation_permutation(mesh.shape[axis])
    sampler = _make_sampler(sampler_mode)

    def per_device(cdk, ckt, blk, ck_syn, ck_loc, z, u, doc, woff, mask,
                   alpha, beta, vbeta):
        # local shards arrive with a leading worker axis of size 1
        cdk, ckt, blk, ck_loc, z = (x[0] for x in (cdk, ckt, blk, ck_loc, z))
        doc, woff, mask, u = (x[0] for x in (doc, woff, mask, u))

        def round_step(carry, u_r):
            cdk, ckt, blk, ck_syn, ck_loc, z = carry
            cdk, ckt, ck_loc, z = _worker_round(
                cdk, ckt, blk, ck_loc, z, u_r, doc, woff, mask,
                alpha, beta, vbeta, sampler=sampler)
            # Algorithm 2 commit+request: move the block to the next owner.
            ckt = jax.lax.ppermute(ckt, axis, perm)
            blk = jax.lax.ppermute(blk, axis, perm)
            ck_true = ck_syn + jax.lax.psum(ck_loc - ck_syn, axis)
            n_tok = jnp.maximum(ck_true.sum(), 1).astype(jnp.float32)
            err = jax.lax.pmean(
                jnp.abs(ck_loc - ck_true).sum().astype(jnp.float32),
                axis) / n_tok
            if sync_ck:
                ck_loc = ck_true
                ck_syn = ck_true
            return (cdk, ckt, blk, ck_syn, ck_loc, z), err

        carry, errs = jax.lax.scan(
            round_step, (cdk, ckt, blk, ck_syn, ck_loc, z), u)
        cdk, ckt, blk, ck_syn, ck_loc, z = carry
        return (cdk[None], ckt[None], blk[None], ck_syn, ck_loc[None],
                z[None], errs)

    w = P(axis)
    return jax.jit(jax.shard_map(
        per_device, mesh=mesh,
        in_specs=(w, w, w, P(), w, w, w, w, w, w, P(), P(), P()),
        out_specs=(w, w, w, P(), w, w, P()),
        check_vma=False))


# ---------------------------------------------------------------------------
# Public engine
# ---------------------------------------------------------------------------

class ModelParallelLDA:
    """Model-parallel LDA trainer (the paper's full system).

    Example::

        lda = ModelParallelLDA(corpus, num_topics=64, num_workers=8)
        history = lda.run(num_iterations=50)
        state = lda.gather_counts()
    """

    def __init__(self, corpus: Corpus, num_topics: int, num_workers: int,
                 alpha: float | np.ndarray = 0.1, beta: float = 0.01,
                 seed: int = 0, sampler_mode: str = "scan",
                 sync_ck: bool = True, backend: str = "vmap",
                 mesh: Optional[Mesh] = None, axis: str = "w"):
        corpus.validate()
        self.corpus = corpus
        self.num_topics = int(num_topics)
        self.num_workers = int(num_workers)
        self.alpha = jnp.full((num_topics,), alpha, jnp.float32) \
            if np.isscalar(alpha) else jnp.asarray(alpha, jnp.float32)
        self.beta = float(beta)
        self.vbeta = float(beta * corpus.vocab_size)
        self.sampler_mode = sampler_mode
        self.sync_ck = bool(sync_ck)
        self.backend = backend
        self.axis = axis
        self.partition = sched.partition_vocab(corpus.vocab_size, num_workers)
        sched.validate_schedule(num_workers)
        self._rng = np.random.default_rng(seed)
        self._build(seed)
        if backend == "shard_map":
            if mesh is None:
                devs = np.array(jax.devices()[:num_workers])
                if devs.size < num_workers:
                    raise ValueError(
                        f"shard_map backend needs {num_workers} devices, "
                        f"have {len(jax.devices())}")
                mesh = Mesh(devs, (axis,))
            self.mesh = mesh
            self._iter_fn = _iteration_shard_map(
                mesh, axis, sampler_mode, sync_ck)
        else:
            self.mesh = None
            self._iter_fn = None

    # -- construction ------------------------------------------------------
    def _build(self, seed: int) -> None:
        c, m, k = self.corpus, self.num_workers, self.num_topics
        shards = [worker_shard(c, w, m) for w in range(m)]
        # common inverted-index capacity across workers (static shapes)
        caps = []
        for s in shards:
            blk = self.partition.block_of_word(s.word)
            caps.append(int(np.bincount(blk, minlength=m).max(initial=0)))
        cap = max(max(caps), 1)
        self.capacity = cap
        self.shards = shards
        self.indexes = [build_inverted_index(s.doc_local, s.word,
                                             self.partition, cap)
                        for s in shards]
        z0 = self._rng.integers(0, k, size=c.num_tokens).astype(np.int32)
        self.z_init = z0
        dloc = shards[0].num_local_docs
        vb = self.partition.block_size
        cdk = np.zeros((m, dloc, k), np.int32)
        ckt = np.zeros((m, vb, k), np.int32)
        for w, (s, idx) in enumerate(zip(shards, self.indexes)):
            zz = z0[s.token_id]
            np.add.at(cdk[w], (s.doc_local, zz), 1)
            blk = self.partition.block_of_word(s.word)
            off = self.partition.word_offset_in_block(s.word)
            # accumulate into the block rows this worker's tokens touch;
            # blocks then reduce across workers into their initial owner.
            np.add.at(ckt, (blk, off, zz), 1)
        ck = ckt.sum(axis=(0, 1)).astype(np.int32)
        doc = np.stack([i.doc for i in self.indexes])
        woff = np.stack([i.word_off for i in self.indexes])
        mask = np.stack([i.mask for i in self.indexes])
        zarr = np.zeros((m, m, cap), np.int32)
        for w, (s, idx) in enumerate(zip(shards, self.indexes)):
            real = idx.mask
            zarr[w][real] = z0[s.token_id][idx.token_id[real]]
        self.doc = jnp.asarray(doc)
        self.woff = jnp.asarray(woff)
        self.mask = jnp.asarray(mask)
        self.state = MPState(
            cdk=jnp.asarray(cdk),
            ckt=jnp.asarray(ckt),
            block_id=jnp.arange(m, dtype=jnp.int32),
            ck_synced=jnp.asarray(ck),
            ck_local=jnp.broadcast_to(jnp.asarray(ck), (m, k)),
            z=jnp.asarray(zarr),
        )
        self.iteration_count = 0

    # -- stepping ----------------------------------------------------------
    def _uniforms(self) -> jax.Array:
        m, cap = self.num_workers, self.capacity
        u = self._rng.random((m, m, cap), np.float32)  # [rounds, workers, T]
        return jnp.asarray(u)

    def step(self) -> None:
        """Run one iteration (= M rounds, every token sampled once)."""
        u = self._uniforms()
        if self.backend == "vmap":
            self.state, errs = _iteration_vmap(
                self.state, u, self.doc, self.woff, self.mask,
                self.alpha, jnp.float32(self.beta), jnp.float32(self.vbeta),
                sampler_mode=self.sampler_mode, sync_ck=self.sync_ck)
        else:
            s = self.state
            out = self._iter_fn(
                s.cdk, s.ckt, s.block_id, s.ck_synced, s.ck_local, s.z,
                jnp.swapaxes(u, 0, 1), self.doc, self.woff, self.mask,
                self.alpha, jnp.float32(self.beta), jnp.float32(self.vbeta))
            self.state = MPState(*out[:6])
            errs = out[6]
        self.round_errors = np.asarray(errs).reshape(-1)
        self.iteration_count += 1

    def run(self, num_iterations: int,
            callback: Optional[Callable[[int, "ModelParallelLDA"], None]] = None,
            eval_every: int = 1) -> List[dict]:
        history = []
        for i in range(num_iterations):
            self.step()
            if (i + 1) % eval_every == 0:
                history.append({"iteration": self.iteration_count,
                                "log_likelihood": self.log_likelihood()})
            if callback is not None:
                callback(i, self)
        return history

    # -- observation ---------------------------------------------------------
    def gather_counts(self) -> CountState:
        """Reassemble the global model (the KV-store "dump")."""
        m = self.num_workers
        vb = self.partition.block_size
        v, k = self.corpus.vocab_size, self.num_topics
        ckt_full = np.zeros((m * vb, k), np.int32)
        blocks = np.asarray(self.state.block_id)
        ckt = np.asarray(self.state.ckt)
        for w in range(m):
            b = int(blocks[w])
            ckt_full[b * vb:(b + 1) * vb] = ckt[w]
        ckt_full = ckt_full[:v]
        cdk_full = np.zeros((self.corpus.num_docs, k), np.int32)
        cdk = np.asarray(self.state.cdk)
        for w, s in enumerate(self.shards):
            real = s.doc_global >= 0
            cdk_full[s.doc_global[real]] = cdk[w][:real.sum()]
        ck = ckt_full.sum(axis=0).astype(np.int32)
        return CountState(jnp.asarray(cdk_full), jnp.asarray(ckt_full),
                          jnp.asarray(ck))

    def assignments(self) -> np.ndarray:
        """Current z in original token order."""
        z = np.zeros(self.corpus.num_tokens, np.int32)
        zs = np.asarray(self.state.z)
        for w, (s, idx) in enumerate(zip(self.shards, self.indexes)):
            z_local = scatter_assignments(idx, zs[w], s.token_id.shape[0])
            z[s.token_id] = z_local
        return z

    def log_likelihood(self) -> float:
        state = self.gather_counts()
        lw = word_log_likelihood(state.ckt, state.ck, self.beta)
        ld = doc_log_likelihood(state.cdk, self.alpha)
        return float(lw + ld)

    def delta_error(self) -> float:
        """Mean pre-sync Δ_{r,i} over the rounds of the last iteration
        (paper Fig 3).  Falls back to the current post-sync drift if no
        iteration has run yet."""
        errs = getattr(self, "round_errors", None)
        if errs is not None and errs.size:
            return float(errs.mean())
        from repro.core.metrics import delta_error
        return delta_error(self.state.true_ck(),
                           self.state.local_ck_views())
