"""Dry-run integration on a tiny 8-device mesh (subprocess, one arch per
family) — keeps CI honest without the 512-device full sweep."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.compat import AxisType, cost_analysis_dict, make_mesh, set_mesh
from repro.configs import get_config, INPUT_SHAPES, shape_applicable
from repro.models import build_model
from repro.launch.sharding_rules import (param_shardings, batch_shardings,
                                         cache_shardings, replicated)
from repro.launch.input_specs import input_specs
from repro.models.common import set_activation_sharding
from repro.train.optimizer import AdamW, AdamWState
from repro.train.train_step import make_train_step
import dataclasses
import numpy as np

mesh = make_mesh((2, 4), ("data", "model"),
                 axis_types=(AxisType.Auto,) * 2)
set_activation_sharding(("data",))

SMALL_SHAPE = dataclasses.replace(INPUT_SHAPES["train_4k"],
                                  seq_len=256, global_batch=8)
DEC_SHAPE = dataclasses.replace(INPUT_SHAPES["decode_32k"],
                                seq_len=512, global_batch=8)

for arch in ["olmo-1b", "qwen2-moe-a2.7b", "hymba-1.5b", "xlstm-350m",
             "whisper-medium", "llava-next-mistral-7b"]:
    cfg = dataclasses.replace(
        get_config(arch).reduced(), num_patch_embeds=min(
            get_config(arch).num_patch_embeds, 64))
    model = build_model(cfg)
    params = model.abstract_params()
    pshard = param_shardings(cfg, mesh, params)
    # train
    bundle = input_specs(cfg, SMALL_SHAPE, model)
    batch = bundle.args[0]
    bshard = batch_shardings(cfg, mesh, batch)
    opt = AdamW()
    opt_state = jax.eval_shape(opt.init, params)
    oshard = AdamWState(replicated(mesh, opt_state.step), pshard, pshard)
    step = make_train_step(model, opt)
    with set_mesh(mesh):
        c = jax.jit(step, in_shardings=(pshard, oshard, bshard)).lower(
            params, opt_state, batch).compile()
        assert cost_analysis_dict(c).get("flops", 0) > 0
    # decode
    bundle = input_specs(cfg, DEC_SHAPE, model)
    caches, tokens, pos = bundle.args[:3]
    enc = bundle.args[3] if len(bundle.args) > 3 else None
    cshard = cache_shardings(cfg, mesh, caches)
    tsh = batch_shardings(cfg, mesh, {"t": tokens, "p": pos})
    in_sh = [pshard, cshard, tsh["t"], tsh["p"]]
    args = [params, caches, tokens, pos]
    if enc is not None:
        in_sh.append(batch_shardings(cfg, mesh, {"e": enc})["e"])
        args.append(enc)
    def decode(params, caches, tokens, pos, *rest, _m=model):
        return _m.decode_step(params, caches, tokens, pos, *rest)
    with set_mesh(mesh):
        jax.jit(decode, in_shardings=tuple(in_sh)).lower(*args).compile()
    print(arch, "OK", flush=True)
print("SMALL_DRYRUN_OK")
"""


@pytest.mark.slow
def test_small_mesh_dryrun_all_families():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "SMALL_DRYRUN_OK" in out.stdout
