"""Document sharding across workers (the data-parallel half).

Documents are assigned round-robin by id (load-balanced in expectation);
each worker re-indexes its documents locally so ``C_d^k`` shards have the
same row count everywhere (required for SPMD static shapes).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.data.corpus import Corpus


@dataclasses.dataclass
class WorkerShard:
    worker: int
    doc_local: np.ndarray    # [n_local] int32 local doc index per token
    word: np.ndarray         # [n_local] int32 global word id per token
    token_id: np.ndarray     # [n_local] int32 position in the global stream
    doc_global: np.ndarray   # [D_local_padded] int32 global doc id per row (-1 pad)
    num_local_docs: int      # padded row count (same on all workers)


def shard_documents(num_docs: int, num_workers: int) -> List[np.ndarray]:
    """Round-robin document assignment: worker m gets docs {m, m+M, ...}."""
    return [np.arange(m, num_docs, num_workers, dtype=np.int32)
            for m in range(num_workers)]


def grid_index(data: int, model: int, num_workers: int) -> int:
    """Flatten a (data, model) grid position to a shard row ``g = d·M + m``.

    The engine stores all per-worker arrays with one leading axis of
    length ``R = D·M`` in this data-major order, which is exactly how a
    ``PartitionSpec(("data", "model"))`` splits a leading axis across the
    2D mesh — so the same row layout serves the vmap and shard_map
    backends (DESIGN.md §8).
    """
    return data * num_workers + model


def grid_shard(corpus: Corpus, data: int, model: int, data_parallel: int,
               num_workers: int) -> WorkerShard:
    """Document shard of the worker at (data replica, model position).

    Documents are sharded ``R = D·M`` ways: the data axis and the model
    axis both carry documents (each grid cell owns a disjoint doc set),
    while the vocabulary blocks are partitioned along model and
    REPLICATED along data.
    """
    return worker_shard(corpus, grid_index(data, model, num_workers),
                        data_parallel * num_workers)


def worker_shard(corpus: Corpus, worker: int, num_workers: int) -> WorkerShard:
    assignment = shard_documents(corpus.num_docs, num_workers)
    mine = assignment[worker]
    rows = -(-corpus.num_docs // num_workers)        # padded D_local
    local_of_global = np.full(corpus.num_docs, -1, np.int32)
    local_of_global[mine] = np.arange(mine.shape[0], dtype=np.int32)
    sel = np.nonzero(local_of_global[corpus.doc] >= 0)[0].astype(np.int32)
    doc_global = np.full(rows, -1, np.int32)
    doc_global[:mine.shape[0]] = mine
    return WorkerShard(
        worker=worker,
        doc_local=local_of_global[corpus.doc[sel]],
        word=corpus.word[sel],
        token_id=sel,
        doc_global=doc_global,
        num_local_docs=rows,
    )
