"""Pure-jnp oracle for the Gibbs-conditional kernel.

Identical semantics to ``gibbs_conditional.py`` with no tiling: used by the
kernel sweep tests (``assert_allclose`` on the mass, exact equality on the
drawn topics) and as the fallback path on backends without Pallas.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def gibbs_conditional_ref(ckt_group, cdk_rows, z_old, u, mask, ck, alpha,
                          beta, vbeta):
    """See ``gibbs_conditional_call`` — same inputs, same [G, Tg] output."""
    g, tg, k = cdk_rows.shape
    ckt = ckt_group.astype(jnp.float32)
    cdk = cdk_rows.astype(jnp.float32)
    ck = ck.astype(jnp.float32)
    alpha = alpha.astype(jnp.float32)
    coeff = (ckt + beta) / (ck + vbeta)[None, :]
    base = coeff[:, None, :] * (alpha[None, None, :] + cdk)
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (g, tg, k), 2)
    is_old = k_iota == z_old[:, :, None]
    corrected = ((ckt[:, None, :] - 1.0 + beta)
                 * (alpha[None, None, :] + cdk - 1.0)
                 / (ck[None, None, :] - 1.0 + vbeta))
    p = jnp.maximum(jnp.where(is_old, corrected, base), 0.0)
    # counted inverse-CDF draw (see core.sampler.sample_from_mass): exact
    # at u == 1.0 and on all-zero mass rows
    cum = jnp.cumsum(p, axis=-1)
    total = cum[:, :, -1:]
    idx = jnp.sum((cum <= u[:, :, None] * total).astype(jnp.int32), axis=-1)
    last = jnp.sum((cum < total).astype(jnp.int32), axis=-1)
    z_new = jnp.minimum(idx, last).astype(jnp.int32)
    return jnp.where(mask != 0, z_new, z_old.astype(jnp.int32))


@jax.jit
def conditional_mass_ref(ckt_group, cdk_rows, z_old, ck, alpha, beta, vbeta):
    """The unnormalized mass [G, Tg, K] — for allclose checks of the math."""
    g, tg, k = cdk_rows.shape
    ckt = ckt_group.astype(jnp.float32)
    cdk = cdk_rows.astype(jnp.float32)
    ck = ck.astype(jnp.float32)
    coeff = (ckt + beta) / (ck + vbeta)[None, :]
    base = coeff[:, None, :] * (alpha[None, None, :] + cdk)
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (g, tg, k), 2)
    is_old = k_iota == z_old[:, :, None]
    corrected = ((ckt[:, None, :] - 1.0 + beta)
                 * (alpha[None, None, :] + cdk - 1.0)
                 / (ck[None, None, :] - 1.0 + vbeta))
    return jnp.maximum(jnp.where(is_old, corrected, base), 0.0)
