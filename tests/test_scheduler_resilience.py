"""Scheduler resilience (DESIGN.md §15): per-replica circuit breakers,
bitwise-invisible retry-on-alternate-replica, deadline expiry, load
shedding, fingerprint-gated hot-swap, and the tolerant snapshot watcher.

The load-bearing property: replica failures are a ROUTING concern only.
Draws are keyed on (seed, fingerprint, multiset digest) — never on
which replica ran — so a response that survived two failed dispatch
attempts is bitwise the response a healthy system would have produced,
pinned here against ``reference_theta`` and against a fault-free
scheduler run.  And every admitted request gets a definite outcome:
``dropped() == 0`` even when every replica is down.
"""
import argparse
import os
import shutil

import numpy as np
import pytest

from repro.core import faults
from repro.core.faults import FaultPlan, FaultSpec
from repro.core.infer import ModelSnapshot, load_snapshot
from repro.data import integrity
from repro.serve.scheduler import (BREAKER_CLOSED, BREAKER_HALF_OPEN,
                                   BREAKER_OPEN, REJECT_DEADLINE,
                                   REJECT_REPLICA, REJECT_SHED,
                                   CorruptArtifactError, ReplicaHealth,
                                   ServingScheduler, VirtualClock,
                                   reference_theta)
from repro.serve.traffic import poisson_trace, replay_open_loop

V, K = 64, 8
SWEEPS = 3
SEED = 1


def _snapshot(seed: int) -> ModelSnapshot:
    rng = np.random.default_rng(seed)
    return ModelSnapshot.from_counts(
        rng.integers(0, 30, size=(V, K)).astype(np.int32))


@pytest.fixture(scope="module")
def snap_a():
    return _snapshot(10)


@pytest.fixture(scope="module")
def snap_b():
    return _snapshot(20)


def _sched(snap, **kw) -> ServingScheduler:
    kw.setdefault("clock", VirtualClock())
    kw.setdefault("sampler", "scan")
    kw.setdefault("num_sweeps", SWEEPS)
    kw.setdefault("seed", SEED)
    return ServingScheduler(snap, **kw)


def _docs(n, seed=0, lo=3, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, V, size=int(rng.integers(lo, hi)))
            .astype(np.int32) for _ in range(n)]


def _ref(snap, tokens):
    return reference_theta(snap, tokens, sampler="scan",
                           num_sweeps=SWEEPS, seed=SEED)


def _fail_replicas(*rids):
    """A plan under which every dispatch to the given replicas fails."""
    return FaultPlan([FaultSpec("replica_fail", "replica",
                                f"replica:{r},", nth=0) for r in rids])


# ---------------------------------------------------------------------------
# ReplicaHealth state machine
# ---------------------------------------------------------------------------

class TestReplicaHealth:
    def test_threshold_consecutive_failures_open(self):
        h = ReplicaHealth()
        h.record_failure(0.0, threshold=3)
        h.record_failure(0.0, threshold=3)
        assert h.state == BREAKER_CLOSED
        h.record_failure(1.0, threshold=3)
        assert h.state == BREAKER_OPEN and h.opens == 1
        assert h.opened_at == 1.0

    def test_success_resets_consecutive(self):
        h = ReplicaHealth()
        h.record_failure(0.0, 3)
        h.record_failure(0.0, 3)
        h.record_success()
        h.record_failure(0.0, 3)
        h.record_failure(0.0, 3)
        assert h.state == BREAKER_CLOSED        # streak was broken
        assert h.failures == 4 and h.successes == 1

    def test_cooldown_half_open_then_close(self):
        h = ReplicaHealth()
        for _ in range(3):
            h.record_failure(0.0, 3)
        assert not h.available(0.5, cooldown=1.0)
        assert h.available(1.0, cooldown=1.0)    # lazy open -> half_open
        assert h.state == BREAKER_HALF_OPEN
        h.record_success()
        assert h.state == BREAKER_CLOSED

    def test_half_open_failure_reopens_immediately(self):
        h = ReplicaHealth()
        for _ in range(3):
            h.record_failure(0.0, 3)
        h.available(2.0, cooldown=1.0)
        assert h.state == BREAKER_HALF_OPEN
        h.record_failure(2.0, 3)                 # probe failed
        assert h.state == BREAKER_OPEN and h.opens == 2
        assert h.opened_at == 2.0


# ---------------------------------------------------------------------------
# Retry on alternate replica: bitwise-invisible
# ---------------------------------------------------------------------------

class TestRetryBitwise:
    def test_failing_replica_answers_match_reference(self, snap_a):
        sched = _sched(snap_a, num_replicas=2,
                       fault_plan=_fail_replicas(0))
        docs = _docs(6, seed=3)
        rids = [sched.submit(d) for d in docs]
        while sched.pending:
            sched.tick()
            sched.clock.sleep(0.01)
        for rid, d in zip(rids, docs):
            r = sched.results[rid]
            assert r.status == "ok" and r.replica == 1
            np.testing.assert_array_equal(r.theta, _ref(snap_a, d))
        assert sched.dropped() == 0
        st = sched.stats()["faults"]
        assert st["replica_failures"] > 0

    def test_faulty_run_equals_clean_run_bitwise(self, snap_a):
        docs = _docs(8, seed=4)
        clean = _sched(snap_a, num_replicas=2)
        faulty = _sched(snap_a, num_replicas=2,
                        fault_plan=_fail_replicas(0))
        for s in (clean, faulty):
            for d in docs:
                s.submit(d)
            while s.pending:
                s.tick()
                s.clock.sleep(0.01)
        for rid in range(len(docs)):
            np.testing.assert_array_equal(
                clean.results[rid].theta, faulty.results[rid].theta,
                err_msg=f"request {rid}: retry changed the answer")
        assert faulty.retries >= 1               # the retries DID happen

    def test_within_tick_retry_serves_same_tick(self, snap_a):
        """A batch whose first candidate replica fails is answered by
        the next one in the SAME tick — no requeue round-trip."""
        sched = _sched(snap_a, num_replicas=2,
                       fault_plan=_fail_replicas(0))
        sched.submit(_docs(1, seed=5)[0])
        out = sched.tick()
        assert len(out) == 1 and out[0].status == "ok"
        assert out[0].replica == 1
        assert sched.retries == 1 and sched.replica_failures == 1


# ---------------------------------------------------------------------------
# Breaker routing in the scheduler
# ---------------------------------------------------------------------------

class TestBreakerRouting:
    def test_breaker_opens_and_stops_charging_failures(self, snap_a):
        sched = _sched(snap_a, num_replicas=2, breaker_threshold=3,
                       breaker_cooldown=100.0, max_batch=1,
                       fault_plan=_fail_replicas(0))
        for d in _docs(8, seed=6):
            sched.submit(d)
        while sched.pending:
            sched.tick()
            sched.clock.sleep(0.01)
        assert sched.health[0].state == BREAKER_OPEN
        # once open, replica 0 left the candidate list: exactly
        # `threshold` dispatches were wasted on it, not one per batch
        assert sched.health[0].failures == 3
        assert sched.health[0].opens == 1
        assert all(r.replica == 1 for r in sched.ok_responses()
                   if not r.cached)

    def test_single_replica_recovers_via_half_open_probe(self, snap_a):
        plan = FaultPlan([FaultSpec("replica_fail", "replica",
                                    "replica:0,", nth=1)])  # first only
        sched = _sched(snap_a, num_replicas=1, breaker_threshold=1,
                       breaker_cooldown=1.0, fault_plan=plan)
        doc = _docs(1, seed=7)[0]
        rid = sched.submit(doc)
        assert sched.tick() == []                # fails, breaker opens
        assert sched.health[0].state == BREAKER_OPEN
        assert sched.tick() == []                # still cooling down
        sched.clock.sleep(1.5)
        out = sched.tick()                       # half-open probe passes
        assert len(out) == 1 and out[0].req_id == rid
        np.testing.assert_array_equal(out[0].theta, _ref(snap_a, doc))
        assert sched.health[0].state == BREAKER_CLOSED
        assert sched.health[0].opens == 1

    def test_failed_probe_reopens_then_recovers(self, snap_a):
        # two specs, each nth=1: a raising spec aborts the matching scan,
        # so the second spec's counter only advances on the NEXT fire —
        # together they script exactly two consecutive failures
        plan = FaultPlan([FaultSpec("replica_fail", "replica",
                                    "replica:0,", nth=1) for _ in range(2)])
        sched = _sched(snap_a, num_replicas=1, breaker_threshold=1,
                       breaker_cooldown=1.0, max_retries=2,
                       fault_plan=plan)
        doc = _docs(1, seed=8)[0]
        sched.submit(doc)
        sched.tick()                             # fail #1 -> open
        sched.clock.sleep(1.5)
        sched.tick()                             # probe fails -> re-open
        assert sched.health[0].opens == 2
        sched.clock.sleep(1.5)
        out = sched.tick()                       # third attempt succeeds
        assert len(out) == 1 and out[0].status == "ok"
        np.testing.assert_array_equal(out[0].theta, _ref(snap_a, doc))

    def test_all_open_sheds_deadline_expires_dropped_zero(self, snap_a):
        sched = _sched(snap_a, num_replicas=2, breaker_threshold=2,
                       breaker_cooldown=1000.0, max_retries=5,
                       request_deadline=10.0,
                       fault_plan=_fail_replicas(0, 1))
        doc = _docs(1, seed=9)[0]
        rid = sched.submit(doc)
        sched.tick()                             # both fail once
        sched.tick()                             # both fail again -> open
        assert all(h.state == BREAKER_OPEN for h in sched.health)
        # admission now sheds instead of queueing into a dead system
        rid2 = sched.submit(doc[:3])
        assert sched.results[rid2].reason == REJECT_SHED
        assert sched.stats()["faults"]["shed"] == 1
        # the queued request ages out at its deadline with a structured
        # rejection — admitted but never silently dropped
        sched.clock.sleep(11.0)
        sched.tick()
        assert sched.results[rid].status == "rejected"
        assert sched.results[rid].reason == REJECT_DEADLINE
        assert sched.dropped() == 0

    def test_retry_budget_exhaustion_rejects(self, snap_a):
        sched = _sched(snap_a, num_replicas=1, breaker_threshold=10,
                       max_retries=1, fault_plan=_fail_replicas(0))
        rid = sched.submit(_docs(1, seed=10)[0])
        sched.tick()                             # retries = 1 (<= budget)
        sched.tick()                             # retries = 2 -> reject
        r = sched.results[rid]
        assert r.status == "rejected" and r.reason == REJECT_REPLICA
        assert sched.dropped() == 0
        assert sched.stats()["faults"]["failed_admitted"] == 1

    def test_replica_slow_charges_latency_not_errors(self, snap_a):
        sched = _sched(snap_a, num_replicas=1,
                       fault_plan=FaultPlan.replica_slow(0, 0.5, nth=0))
        doc = _docs(1, seed=11)[0]
        rid = sched.submit(doc)
        out = sched.tick()
        assert len(out) == 1 and out[0].status == "ok"
        assert sched.results[rid].latency >= 0.5  # virtual-clock charged
        assert sched.replica_failures == 0
        np.testing.assert_array_equal(out[0].theta, _ref(snap_a, doc))


# ---------------------------------------------------------------------------
# Open-loop replay under failures
# ---------------------------------------------------------------------------

class TestReplayUnderFailures:
    def test_one_dead_replica_every_admission_answered(self, snap_a):
        """The acceptance scenario: a replay with one always-failing
        replica of two answers every admitted query, bitwise equal to
        the reference fold-in, and the faults block lands in the
        summary."""
        sched = _sched(snap_a, num_replicas=2, breaker_cooldown=0.05,
                       fault_plan=_fail_replicas(0))
        trace = poisson_trace(40, 200.0, V, seed=2, hot_fraction=0.25)
        summary = replay_open_loop(sched, trace)
        assert summary["dropped"] == 0
        assert summary["served"] == sched.admitted
        assert summary["faults"]["replica_failures"] > 0
        for r in sched.ok_responses():
            canon = None
            # recover the submitted tokens from the trace by req_id
            canon = trace[r.req_id].tokens
            np.testing.assert_array_equal(
                r.theta, _ref(snap_a, canon),
                err_msg=f"request {r.req_id} diverged from reference")

    def test_all_replicas_dead_replay_terminates(self, snap_a):
        """Total outage: the replay must still terminate (idle steps
        advance the clock, cooldowns expire, retry budgets drain the
        queue) with a structured outcome for every admission."""
        sched = _sched(snap_a, num_replicas=2, breaker_threshold=2,
                       breaker_cooldown=0.02, max_retries=1,
                       request_deadline=0.5,
                       fault_plan=_fail_replicas(0, 1))
        trace = poisson_trace(10, 500.0, V, seed=3)
        summary = replay_open_loop(sched, trace)
        assert summary["dropped"] == 0
        assert len(sched.ok_responses()) == 0
        reasons = set(sched.stats()["rejections"])
        assert reasons <= {REJECT_SHED, REJECT_DEADLINE, REJECT_REPLICA}
        assert sum(sched.stats()["rejections"].values()) == len(trace)


# ---------------------------------------------------------------------------
# Fingerprint-gated hot-swap + stats plumbing
# ---------------------------------------------------------------------------

class TestValidatedSwap:
    def test_fingerprint_mismatch_refused_old_keeps_serving(self, snap_a,
                                                            snap_b):
        sched = _sched(snap_a)
        fp0 = sched.fingerprint
        with pytest.raises(CorruptArtifactError):
            sched.swap_snapshot(snap_b, expect_fingerprint="0" * 32)
        assert sched.epoch == 0 and sched.fingerprint == fp0
        doc = _docs(1, seed=12)[0]
        sched.submit(doc)
        out = sched.tick()
        assert out[0].fingerprint == fp0         # old epoch still serves
        np.testing.assert_array_equal(out[0].theta, _ref(snap_a, doc))

    def test_matching_fingerprint_swaps(self, snap_a, snap_b):
        sched = _sched(snap_a)
        epoch = sched.swap_snapshot(
            snap_b, expect_fingerprint=snap_b.fingerprint())
        assert epoch == 1 and sched.fingerprint == snap_b.fingerprint()

    def test_stats_exposes_fault_and_replica_blocks(self, snap_a):
        st = _sched(snap_a, num_replicas=2).stats()
        assert set(st["faults"]) == {"retries", "replica_failures",
                                     "breaker_opens", "shed",
                                     "deadline_expired", "failed_admitted"}
        assert len(st["replicas"]) == 2
        assert st["replicas"][0]["state"] == BREAKER_CLOSED


# ---------------------------------------------------------------------------
# Tolerant snapshot watcher (lda_serve --watch, §15)
# ---------------------------------------------------------------------------

def _watch_args(**kw):
    kw.setdefault("snapshot", "")
    kw.setdefault("watch", "")
    kw.setdefault("watch_interval", 0.0)
    return argparse.Namespace(**kw)


class TestTolerantWatcher:
    def test_corrupt_npz_skipped_then_swapped_after_repair(
            self, tmp_path, snap_a, snap_b):
        from repro.launch.lda_serve import _make_watcher
        base = str(tmp_path / "base.npz")
        snap_a.save(base)
        watch = tmp_path / "live"
        watch.mkdir()
        cand = str(watch / "snap_0001.npz")
        snap_b.save(cand)
        integrity.flip_byte(cand, seed=3)        # torn/corrupt export
        os.utime(base, (1.0, 1.0))
        os.utime(cand, (2.0, 2.0))

        sched = _sched(load_snapshot(base))
        on_tick = _make_watcher(_watch_args(snapshot=base,
                                            watch=str(watch)), sched)
        on_tick(sched, 0.0)
        assert sched.epoch == 0                  # skipped, old serving
        integrity.flip_byte(cand, seed=3)        # XOR twice = repaired
        on_tick(sched, 1.0)                      # watermark untouched:
        assert sched.epoch == 1                  # same candidate retried
        assert sched.fingerprint == snap_b.fingerprint()

    def test_half_copied_npz_skipped(self, tmp_path, snap_a, snap_b):
        from repro.launch.lda_serve import _make_watcher
        base = str(tmp_path / "base.npz")
        snap_a.save(base)
        watch = tmp_path / "live"
        watch.mkdir()
        full = str(tmp_path / "full.npz")
        snap_b.save(full)
        cand = str(watch / "snap_0001.npz")
        with open(full, "rb") as f, open(cand, "wb") as g:
            g.write(f.read()[:os.path.getsize(full) // 2])  # cp mid-flight
        os.utime(base, (1.0, 1.0))

        sched = _sched(load_snapshot(base))
        on_tick = _make_watcher(_watch_args(snapshot=base,
                                            watch=str(watch)), sched)
        on_tick(sched, 0.0)
        assert sched.epoch == 0
        shutil.copy(full, cand)                  # the cp finishes
        shutil.copy(integrity.sidecar_path(full),
                    integrity.sidecar_path(cand))
        on_tick(sched, 1.0)
        assert sched.epoch == 1

    def test_sharded_dir_without_meta_is_not_a_candidate(self, tmp_path,
                                                         snap_a):
        from repro.launch.lda_serve import _make_watcher
        watch = tmp_path / "live"
        partial = watch / "snap_0001"
        partial.mkdir(parents=True)
        integrity.save_npy(str(partial / "block_00000.npy"),
                           np.zeros((4, K), np.int32))
        # meta.json is written LAST by save_snapshot_sharded — absent
        # means mid-export, so the dir must not even be considered
        sched = _sched(snap_a)
        on_tick = _make_watcher(
            _watch_args(snapshot_dir=str(tmp_path / "unused"),
                        watch=str(watch)), sched)
        on_tick(sched, 0.0)
        assert sched.epoch == 0 and sched.swaps == 0
