"""Core of the reproduction: model-parallel collapsed Gibbs LDA."""
from repro.core.counts import CountState, build_counts, check_invariants
from repro.core.data_parallel import DataParallelLDA
from repro.core.engine import EngineLayout
from repro.core.likelihood import log_likelihood
from repro.core.metrics import delta_error, topic_recovery_score
from repro.core.model_parallel import ModelParallelLDA, MPState
from repro.core.schedule import partition_vocab, rotation_permutation

__all__ = [
    "CountState", "build_counts", "check_invariants", "DataParallelLDA",
    "EngineLayout", "log_likelihood", "delta_error", "topic_recovery_score",
    "ModelParallelLDA", "MPState", "partition_vocab", "rotation_permutation",
]
