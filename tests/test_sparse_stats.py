"""Statistical equivalence of the sparse sampler family (DESIGN.md §12).

Two distributional claims, each calibrated the `test_mh_stats.py` way —
a twin chain with a different seed measures a sampler's own seed-to-seed
spread, and the chain under test must land within a small multiple of it
(plus an absolute floor so a degenerate twin distance cannot make the
test vacuous):

1. **Host bucket sweep vs direct inverse-CDF** — `sparse_gibbs_sweep_np`
   is an EXACT serial collapsed Gibbs sampler (the A/B/C bucket walk is
   inverse-CDF over the same eq.-(1) mass, just bucket-major), so its
   chain must sit inside the exact chain's own twin-calibrated bounds
   SHARPLY: same full conditional, no relaxation offset to allow for.
2. **Engine ``sparse`` vs exact ``scan``** — the device sampler is a
   frozen-count batched relaxation (counts frozen per round, rank-1 ¬dn
   exclusion, exact delta fold): distribution-equal but not
   trajectory-equal to scan, exactly the relaxation class of ``batched``
   — so topic occupancy must match the exact chain within twin bounds,
   and doc-topic moments within the same modest drift guard the frozen
   family carries (much smaller than the MH local-proposal offset, but
   not zero on a short run).

The bitwise layer under these claims lives in `test_sparse_device.py`;
seeds are pinned so the bounds are exercised deterministically under
`scripts/ci.sh`.
"""
import numpy as np
import pytest

from repro.core.counts import build_counts
from repro.core.engine.api import ModelParallelLDA
from repro.core.sampler import gibbs_sweep_np
from repro.core.sparse import sparse_gibbs_sweep_np
from repro.data.synthetic import synthetic_corpus

K = 8
BURN, SAMPLES = 100, 50
CHI2_999_DF7 = 24.32          # chi-square 0.999 quantile at K-1 = 7 dof
# frozen-count drift guard (engine claim 2): the batched relaxation sits
# closer to the exact chain than MH's 0.15 local-proposal allowance
FROZEN_DOC_MOMENT_DRIFT = 0.10


@pytest.fixture(scope="module")
def diffuse_corpus():
    corpus, _, _ = synthetic_corpus(
        num_docs=40, vocab_size=120, num_topics=K, doc_len=30,
        alpha=0.5, seed=0, peaked=False)
    return corpus


def _flat_arrays(corpus):
    words = corpus.doc_words()
    doc = np.concatenate([np.full(len(w), i, np.int32)
                          for i, w in enumerate(words)])
    word = np.concatenate(words).astype(np.int32)
    return doc, word


def _summaries(cdk, ck, alpha):
    ck = np.asarray(ck, np.float64)
    cdk = np.asarray(cdk, np.float64)
    theta = (cdk + alpha) / (cdk.sum(1, keepdims=True) + alpha.sum())
    return (np.sort(ck)[::-1] / ck.sum(),
            float((theta ** 2).sum(1).mean()),
            float(-(theta * np.log(theta)).sum(1).mean()))


def _host_chain_stats(corpus, sweep_fn, seed):
    """Burn-in + sampling with a serial numpy sweep; label-invariant
    posterior summaries averaged over the sampled iterations."""
    doc, word = _flat_arrays(corpus)
    n = doc.shape[0]
    rng = np.random.default_rng(seed)
    z = rng.integers(0, K, n).astype(np.int32)
    state = build_counts(doc, word, z, corpus.num_docs,
                         corpus.vocab_size, K)
    cdk, ckt, ck = (np.array(state.cdk), np.array(state.ckt),
                    np.array(state.ck))
    alpha = np.full(K, 0.5, np.float64)
    occ, m2, ent = [], [], []
    for it in range(BURN + SAMPLES):
        z = sweep_fn(cdk, ckt, ck, doc, word, z, rng.random(n),
                     alpha, 0.01)
        if it < BURN:
            continue
        o, m, e = _summaries(cdk, ck, alpha)
        occ.append(o)
        m2.append(m)
        ent.append(e)
    return {"occupancy": np.mean(occ, axis=0), "theta_m2": np.mean(m2),
            "theta_entropy": np.mean(ent), "tokens": float(ck.sum())}


def _engine_chain_stats(corpus, sampler_mode, seed):
    lda = ModelParallelLDA(corpus, K, num_workers=2, seed=seed,
                           sampler_mode=sampler_mode)
    alpha = np.asarray(lda.alpha)
    occ, m2, ent = [], [], []
    for it in range(BURN + SAMPLES):
        lda.step()
        if it < BURN:
            continue
        state = lda.gather_counts()
        o, m, e = _summaries(np.asarray(state.cdk), np.asarray(state.ck),
                             alpha)
        occ.append(o)
        m2.append(m)
        ent.append(e)
    return {"occupancy": np.mean(occ, axis=0), "theta_m2": np.mean(m2),
            "theta_entropy": np.mean(ent),
            "tokens": float(np.asarray(state.ck).sum())}


def _chi2(obs, exp, tokens):
    o = obs * tokens
    e = np.maximum(exp * tokens, 1e-9)
    return float(((o - e) ** 2 / e).sum())


def _assert_within_twin_bounds(test, ref, twins, moment_floor):
    """Twin-calibrated bounds, TWO twins per reference: the L∞ of a
    sorted occupancy profile is heavy-tailed seed to seed (measured
    0.005–0.015 across exact-chain seeds on this corpus), so a single
    lucky twin would under-calibrate; the max over two twins is the
    spread estimate, with the same absolute floors as test_mh_stats."""
    twin_linf = max(np.abs(tw["occupancy"] - ref["occupancy"]).max()
                    for tw in twins)
    linf = np.abs(test["occupancy"] - ref["occupancy"]).max()
    assert linf <= max(3.0 * twin_linf, 0.02), \
        (linf, twin_linf, test["occupancy"], ref["occupancy"])

    twin_chi2 = max(_chi2(tw["occupancy"], ref["occupancy"], ref["tokens"])
                    for tw in twins)
    chi2 = _chi2(test["occupancy"], ref["occupancy"], ref["tokens"])
    assert chi2 <= max(3.0 * twin_chi2, CHI2_999_DF7), (chi2, twin_chi2)

    for key in ("theta_m2", "theta_entropy"):
        d = abs(test[key] - ref[key])
        bound = max(3.0 * max(abs(tw[key] - ref[key]) for tw in twins),
                    moment_floor * abs(ref[key]))
        assert d <= bound, (key, d, bound, test[key], ref[key])


@pytest.mark.slow
def test_sparse_np_matches_exact_np_chain_statistics(diffuse_corpus):
    """Claim 1 (module docstring): the serial bucket-walk chain inside the
    exact chain's twin-calibrated bounds, with the sharp 5% moment floor
    of the stale-table claim in test_mh_stats — both samplers draw the
    identical full conditional, so no relaxation allowance applies."""
    ref = _host_chain_stats(diffuse_corpus, gibbs_sweep_np, seed=0)
    twins = [_host_chain_stats(diffuse_corpus, gibbs_sweep_np, seed=s)
             for s in (1, 2)]
    sp = _host_chain_stats(diffuse_corpus, sparse_gibbs_sweep_np, seed=0)
    _assert_within_twin_bounds(sp, ref, twins, moment_floor=0.05)


@pytest.mark.slow
def test_sparse_engine_matches_exact_chain_statistics(diffuse_corpus):
    """Claim 2: the device hybrid sampler's chain vs the exact engine
    chain — occupancy within twin bounds, doc moments within the frozen-
    family drift guard (the batched relaxation class, DESIGN.md §12)."""
    ref = _engine_chain_stats(diffuse_corpus, "scan", seed=0)
    twins = [_engine_chain_stats(diffuse_corpus, "scan", seed=s)
             for s in (1, 2)]
    sp = _engine_chain_stats(diffuse_corpus, "sparse", seed=0)
    _assert_within_twin_bounds(sp, ref, twins,
                               moment_floor=FROZEN_DOC_MOMENT_DRIFT)
