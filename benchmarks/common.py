"""Shared benchmark utilities: timing, CSV output, result storage."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def save_result(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def time_call(fn: Callable, repeats: int = 3) -> float:
    """Median wall time in microseconds."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit_csv_row(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
