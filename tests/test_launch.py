"""Launch-layer units that run on one device: sharding-rule sanitization,
input specs, roofline parsing, accumulation heuristics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, \
    shape_applicable
from repro.launch.input_specs import batch_specs, input_specs
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.roofline import analysis as roofline
from repro.train.train_step import pick_accum_steps


def test_sanitize_drops_indivisible_axes():
    from repro.launch.sharding_rules import sanitize
    mesh = make_local_mesh(1, 1)
    # fake a mesh with axis sizes via a real 1x1 mesh: sanitize must keep
    # axes that divide (size 1 divides everything)
    spec = sanitize(mesh, P("data", "model"), (25, 60))
    assert tuple(spec) == ("data", "model")


def test_input_specs_shapes():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        for name, shape in INPUT_SHAPES.items():
            if shape_applicable(cfg, shape):
                continue
            bundle = input_specs(cfg, shape, model)
            if bundle.kind in ("train", "prefill"):
                batch = bundle.args[0]
                assert batch["tokens"].shape[0] == shape.global_batch
                total = batch["tokens"].shape[1] + (
                    cfg.num_patch_embeds if cfg.family == "vlm" else 0)
                assert total == shape.seq_len
            else:
                caches = bundle.args[0]
                assert len(caches) > 0


def test_vlm_batch_reserves_patch_positions():
    cfg = get_config("llava-next-mistral-7b")
    batch = batch_specs(cfg, INPUT_SHAPES["train_4k"])
    assert batch["patch_embeds"].shape[1] == 2880
    assert batch["tokens"].shape[1] == 4096 - 2880


def test_long500k_skips_match_design():
    should_run = {"gemma3-1b", "hymba-1.5b", "xlstm-350m"}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        skip = shape_applicable(cfg, INPUT_SHAPES["long_500k"])
        if arch in should_run:
            assert skip is None, arch
        else:
            assert skip is not None, arch


def test_collective_bytes_parser():
    hlo = """
  %ag = f32[16,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = bf16[4,4]{1,0} all-reduce(%y), to_apply=%add
  %cp = (f32[8]{0}, f32[8]{0}) collective-permute(%z)
  %nothing = f32[999]{0} add(%a, %b)
"""
    out = roofline.collective_bytes(hlo)
    assert out["bytes"]["all-gather"] == 16 * 128 * 4
    assert out["bytes"]["all-reduce"] == 16 * 2
    assert out["bytes"]["collective-permute"] == 2 * 8 * 4
    assert out["counts"]["all-gather"] == 1
    assert out["total_bytes"] == 16 * 128 * 4 + 32 + 64


def test_extrapolation_linear():
    c1 = roofline.RawCosts(10.0, 100.0, 5.0, {"bytes": {"all-reduce": 5.0},
                                              "counts": {}})
    c2 = roofline.RawCosts(16.0, 130.0, 8.0, {"bytes": {"all-reduce": 8.0},
                                              "counts": {}})
    full = roofline.extrapolate(c1, c2, 10)
    assert full.flops == 10 + 9 * 6
    assert full.bytes_accessed == 100 + 9 * 30
    assert full.coll_bytes == 5 + 9 * 3


def test_model_flops_moe_counts_active_only():
    q3 = get_config("qwen3-moe-235b-a22b")
    n_active = roofline.active_params(q3)
    # ~22B active (the A22B in the name), embeddings excluded
    assert 1.2e10 < n_active < 3.2e10, n_active


def test_pick_accum_steps():
    cfg = get_config("qwen3-moe-235b-a22b")
    shape = INPUT_SHAPES["train_4k"]
    a = pick_accum_steps(cfg, shape, data_shards=16)
    assert a >= 4 and shape.global_batch % a == 0
    small = get_config("olmo-1b")
    assert pick_accum_steps(small, INPUT_SHAPES["train_4k"], 16) <= 8


def test_roofline_terms_dominance():
    costs = roofline.RawCosts(197e12, 10.0, 10.0, {"bytes": {}, "counts": {}})
    terms = roofline.roofline_terms(costs)
    assert terms["dominant"] == "compute_s"
    assert abs(terms["compute_s"] - 1.0) < 1e-9
