"""Paper Figure 4: (a) per-worker memory vs number of workers;
(b) convergence-speed scaling with workers.

(a) is measured exactly (bytes of the resident model shard).
(b) on one CPU core, wall-clock speedup cannot manifest; we report the
    iterations-to-target (which the paper shows stays flat for MP — adding
    workers does not degrade inference quality) plus the communication
    volume per iteration, whose O(M) vs O(M²) split is the mechanism behind
    the paper's Fig-4b speedup/degradation curves.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit_csv_row, save_result
from repro.core.data_parallel import DataParallelLDA
from repro.core.model_parallel import ModelParallelLDA
from repro.data.synthetic import synthetic_corpus


def run(vocab=1600, topics=32, seed=0):
    corpus, _, _ = synthetic_corpus(256, vocab, topics, 50, seed=seed)
    rows = []
    target = None
    for m in (1, 2, 4, 8, 16):
        mp = ModelParallelLDA(corpus, topics, m, seed=seed)
        dp = DataParallelLDA(corpus, topics, m, seed=seed)
        mp_bytes = int(np.asarray(mp.state.ckt)[0].nbytes)
        dp_bytes = int(np.asarray(dp.ckt_local)[0].nbytes)
        if target is None:
            probe = ModelParallelLDA(corpus, topics, 8, seed=seed + 1)
            probe.run(20)
            ll0 = mp.log_likelihood()
            target = ll0 + 0.95 * (probe.log_likelihood() - ll0)
        iters = 0
        while mp.log_likelihood() < target and iters < 40:
            mp.step()
            iters += 1
        # communication per iteration (bytes): MP moves M blocks of V/M·K
        # counts + 2 K-vectors per round; DP all-reduces the V·K table.
        k = topics
        mp_comm = m * (mp.partition.block_size * k * 4 * 2 + k * 4 * 2)
        dp_comm = 2 * vocab * k * 4 * (m - 1) if m > 1 else 0
        rows.append({"workers": m,
                     "mp_model_bytes_per_worker": mp_bytes,
                     "dp_model_bytes_per_worker": dp_bytes,
                     "mp_iters_to_target": iters,
                     "mp_comm_bytes_per_iter": mp_comm,
                     "dp_comm_bytes_per_iter": dp_comm})
    out = {"rows": rows}
    # 1/M law check (paper Fig 4a)
    b1 = rows[0]["mp_model_bytes_per_worker"]
    out["memory_follows_1_over_m"] = all(
        abs(r["mp_model_bytes_per_worker"] * r["workers"] / b1 - 1) < 0.2
        for r in rows)
    out["dp_memory_flat"] = len({r["dp_model_bytes_per_worker"]
                                 for r in rows}) == 1
    save_result("fig4_scaling", out)
    emit_csv_row("fig4_scaling", 0.0,
                 f"mem_1_over_M={out['memory_follows_1_over_m']};"
                 f"dp_flat={out['dp_memory_flat']};"
                 f"iters@16w={rows[-1]['mp_iters_to_target']}")
    return out


if __name__ == "__main__":
    run()
