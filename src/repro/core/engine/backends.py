"""The two bit-identical execution backends (DESIGN.md §2–§3).

One iteration = ``B = S·M`` rounds.  Every round each worker samples its
resident block (slot 0 of its queue), hands exactly that block to ring
neighbour ``m - 1`` (``ppermute`` — parked slots never travel), and
enqueues the received block at the tail of its queue, where it surfaces
``S`` rounds later.  At ``S = 1`` the queue degenerates to the paper's
original rotation: the received block is resident immediately.

* ``vmap`` backend — the worker axis is a batch axis on one device;
  ``ppermute`` becomes ``jnp.roll``, ``psum`` a sum.  Runs anywhere, used
  by tests/benchmarks on the single-CPU container.
* ``shard_map`` backend — the worker axis is a mesh axis; collectives are
  real.  This is the production path; on the dry-run mesh the round
  rotation lowers to HLO ``collective-permute``.

Both backends share :func:`repro.core.engine.rounds.worker_round`, so
agreement tests are meaningful, and the non-separable topic totals
``{C_k}`` are synchronized once per round via ``psum`` of per-worker
deltas and drift in between (§3.3).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core import schedule as sched
from repro.core.engine.rounds import resolve_sampler, worker_round
from repro.core.engine.state import MPState


@partial(jax.jit, static_argnames=("sampler_mode", "sync_ck"))
def iteration_vmap(state: MPState, u, doc, woff, mask, alpha, beta, vbeta,
                   sampler_mode: str = "scan", sync_ck: bool = True):
    """One full iteration = S·M rounds with rotation, stacked on one device.

    ``u`` is ``[B, M, T]`` — one uniform per (round, worker, token slot).
    """
    sampler = resolve_sampler(sampler_mode)
    round_fn = partial(worker_round, sampler=sampler)

    def round_step(carry, u_r):
        cdk, ckt, blk, ck_syn, ck_loc, z = carry
        res_ckt = ckt[:, 0]
        res_blk = blk[:, 0]
        cdk, res_ckt, ck_loc, z = jax.vmap(
            round_fn, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0,
                               None, None, None))(
            cdk, res_ckt, res_blk, ck_loc, z, u_r, doc, woff, mask,
            alpha, beta, vbeta)
        # rotation m -> m-1: worker m-1 receives worker m's resident block
        # and parks it at the tail of its queue (immediately resident when
        # S == 1).  Parked slots shift one toward the head.
        res_ckt = jnp.roll(res_ckt, -1, axis=0)
        res_blk = jnp.roll(res_blk, -1, axis=0)
        ckt = jnp.concatenate([ckt[:, 1:], res_ckt[:, None]], axis=1)
        blk = jnp.concatenate([blk[:, 1:], res_blk[:, None]], axis=1)
        # paper Fig-3 error: pre-sync ℓ1 drift of local {C_k} vs true totals
        ck_true = ck_syn + (ck_loc - ck_syn[None, :]).sum(axis=0)
        n_tok = jnp.maximum(ck_true.sum(), 1).astype(jnp.float32)
        err = (jnp.abs(ck_loc - ck_true[None, :]).sum().astype(jnp.float32)
               / (ck_loc.shape[0] * n_tok))
        if sync_ck:
            ck_loc = jnp.broadcast_to(ck_true, ck_loc.shape)
            ck_syn = ck_true
        return (cdk, ckt, blk, ck_syn, ck_loc, z), err

    carry = (state.cdk, state.ckt, state.block_id, state.ck_synced,
             state.ck_local, state.z)
    carry, errs = jax.lax.scan(round_step, carry, u)
    return MPState(*carry), errs


def make_shard_map_iteration(mesh: Mesh, axis: str, sampler_mode: str,
                             sync_ck: bool):
    """Build the jitted per-device iteration function for ``mesh``."""
    perm = sched.rotation_permutation(mesh.shape[axis])
    sampler = resolve_sampler(sampler_mode)

    def per_device(cdk, ckt, blk, ck_syn, ck_loc, z, u, doc, woff, mask,
                   alpha, beta, vbeta):
        # local shards arrive with a leading worker axis of size 1
        cdk, ckt, blk, ck_loc, z = (x[0] for x in (cdk, ckt, blk, ck_loc, z))
        doc, woff, mask, u = (x[0] for x in (doc, woff, mask, u))

        def round_step(carry, u_r):
            cdk, ckt, blk, ck_syn, ck_loc, z = carry
            res_ckt = ckt[0]
            res_blk = blk[0]
            cdk, res_ckt, ck_loc, z = worker_round(
                cdk, res_ckt, res_blk, ck_loc, z, u_r, doc, woff, mask,
                alpha, beta, vbeta, sampler=sampler)
            # Algorithm 2 commit+request: ONLY the resident block travels —
            # per-round traffic stays one [Vb, K] block per worker no
            # matter how large S makes the total model.
            res_ckt = jax.lax.ppermute(res_ckt, axis, perm)
            res_blk = jax.lax.ppermute(res_blk, axis, perm)
            ckt = jnp.concatenate([ckt[1:], res_ckt[None]], axis=0)
            blk = jnp.concatenate([blk[1:], res_blk[None]], axis=0)
            ck_true = ck_syn + jax.lax.psum(ck_loc - ck_syn, axis)
            n_tok = jnp.maximum(ck_true.sum(), 1).astype(jnp.float32)
            err = jax.lax.pmean(
                jnp.abs(ck_loc - ck_true).sum().astype(jnp.float32),
                axis) / n_tok
            if sync_ck:
                ck_loc = ck_true
                ck_syn = ck_true
            return (cdk, ckt, blk, ck_syn, ck_loc, z), err

        carry, errs = jax.lax.scan(
            round_step, (cdk, ckt, blk, ck_syn, ck_loc, z), u)
        cdk, ckt, blk, ck_syn, ck_loc, z = carry
        return (cdk[None], ckt[None], blk[None], ck_syn, ck_loc[None],
                z[None], errs)

    w = P(axis)
    return jax.jit(compat.shard_map(
        per_device, mesh=mesh,
        in_specs=(w, w, w, P(), w, w, w, w, w, w, P(), P(), P()),
        out_specs=(w, w, w, P(), w, w, P()),
        check_vma=False))
