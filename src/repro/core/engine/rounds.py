"""Per-round worker step and the sampler registry.

``worker_round`` is Algorithm 2 lines 2–5 for ONE worker and ONE round:
sample the token group of the resident block.  Both execution backends
(`backends.py`) call this exact function — vmapped over the ``R = D·M``
worker-grid axis or per-device under shard_map — which is what makes
backend-agreement tests bit-exact rather than statistical.  The step is
oblivious to the hybrid layout: a worker at grid row ``g = d·M + m``
samples its own doc shard against its replica's copy of the resident
block; all cross-worker coordination (rotation along model, delta-psum
reconciliation along data, ``C_k`` sync) lives in the backends.

Samplers are pluggable through a registry so new kernels (e.g. an
alternative Pallas variant) can be added without touching the engine:
register a factory with :func:`register_sampler` and select it via
``ModelParallelLDA(..., sampler_mode=<name>)``.  Built-ins: the exact
``scan``/``scan_eq1`` serial sweeps, the word-frozen ``batched`` sweep
and its ``pallas`` kernel form, and the O(1) alias-table MH pair
``mh``/``mh_pallas`` (DESIGN.md §9).

A second registry holds the *table-aware* forms of the samplers whose
proposal tables can outlive a round (DESIGN.md §10): same signature plus
two trailing packed-table args ``(word_packed [3, Vb, K], doc_packed
[3, D_loc, K])``.  The engine selects them when running with
``table_lifetime="iteration"`` — the traveling-table schedule where word
tables rotate with their block and doc tables are built once per
iteration.  Only the MH family is table-capable: the exact samplers have
no proposal tables to amortize.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict

from repro.core.sampler import sweep_block_batched, sweep_block_scan

# A sampler factory returns fn(cdk, ckt_block, ck, doc, woff, z, mask, u,
# alpha, beta, vbeta) -> (cdk, ckt_block, ck, z_new).
_SAMPLERS: Dict[str, Callable[[], Callable]] = {}


def register_sampler(name: str):
    """Decorator registering a sampler factory under ``name``."""
    def deco(factory: Callable[[], Callable]):
        _SAMPLERS[name] = factory
        return factory
    return deco


def resolve_sampler(mode: str, sampler_args: tuple = ()) -> Callable:
    """Instantiate the sampler registered under ``mode``.

    ``sampler_args`` is a hashable tuple of ``(name, value)`` pairs
    forwarded to the factory as keyword arguments — static sampler
    config (e.g. the sparse family's ``dcap``/``wcap`` lane capacities)
    that must ride the jit cache key, hence a tuple rather than a dict.
    Factories that take no config reject a non-empty tuple loudly."""
    try:
        factory = _SAMPLERS[mode]
    except KeyError:
        raise ValueError(
            f"unknown sampler mode {mode!r}; "
            f"registered: {sorted(_SAMPLERS)}") from None
    return factory(**dict(sampler_args)) if sampler_args else factory()


def available_samplers() -> list:
    return sorted(_SAMPLERS)


@register_sampler("scan")
def _scan_sampler():
    return partial(sweep_block_scan, use_eq3=True)


@register_sampler("scan_eq1")
def _scan_eq1_sampler():
    return partial(sweep_block_scan, use_eq3=False)


@register_sampler("batched")
def _batched_sampler():
    def f(cdk, ckt, ck, d, t, z, mk, u, alpha, beta, vbeta):
        return sweep_block_batched(cdk, ckt, ck, d, t, z, mk, u,
                                   alpha, beta, vbeta, None)
    return f


@register_sampler("pallas")
def _pallas_sampler():
    from repro.kernels.ops import sweep_block_pallas
    return sweep_block_pallas


@register_sampler("mh")
def _mh_sampler():
    # O(1) alias-table Metropolis–Hastings backend (DESIGN.md §9):
    # distribution-equal to "scan"/"batched" but not trajectory-equal —
    # validated statistically by tests/test_mh_stats.py.
    from repro.core.mh import sweep_block_mh
    return sweep_block_mh


@register_sampler("mh_pallas")
def _mh_pallas_sampler():
    from repro.kernels.ops import sweep_block_mh_pallas
    return sweep_block_mh_pallas


@register_sampler("sparse")
def _sparse_sampler(dcap: int = 64, wcap: int = None):
    # Hybrid dense-head/sparse-tail bucket sampler (DESIGN.md §12):
    # frozen-count relaxation like "batched", per-token cost tracking the
    # cdk/ckt nonzeros instead of K.  dcap MUST bound the per-doc nnz
    # (the facade derives it via default_sparse_args); wcap is the
    # head/tail threshold, a pure perf knob.
    from repro.core.sparse_device import DEFAULT_WCAP, sweep_block_sparse
    wcap = DEFAULT_WCAP if wcap is None else wcap

    def f(cdk, ckt, ck, d, t, z, mk, u, alpha, beta, vbeta):
        return sweep_block_sparse(cdk, ckt, ck, d, t, z, mk, u, alpha,
                                  beta, vbeta, dcap=dcap, wcap=wcap)
    return f


@register_sampler("sparse_pallas")
def _sparse_pallas_sampler(dcap: int = 64, wcap: int = None):
    from repro.core.sparse_device import DEFAULT_WCAP
    from repro.kernels.ops import sweep_block_sparse_pallas
    wcap = DEFAULT_WCAP if wcap is None else wcap

    def f(cdk, ckt, ck, d, t, z, mk, u, alpha, beta, vbeta):
        return sweep_block_sparse_pallas(cdk, ckt, ck, d, t, z, mk, u,
                                         alpha, beta, vbeta, dcap=dcap,
                                         wcap=wcap)
    return f


# ---------------------------------------------------------------------------
# Store-native samplers (pluggable CountStore layouts, DESIGN.md §16)
# ---------------------------------------------------------------------------

# fn(cdk, *store_device_operands, ck, doc, woff, z, mask, u, alpha, beta,
#    vbeta) -> (cdk, ck, z_new) — the word-block fold happens in the
# store (exact integer token deltas), not on device.
_STORE_SAMPLERS: Dict[tuple, Callable[[], Callable]] = {}


def register_store_sampler(mode: str, store_kind: str):
    """Decorator registering a STORE-NATIVE sampler factory for the
    ``(sampler mode, store kind)`` pair: a form that consumes the store's
    device operands directly instead of a densified ``[Vb, K]`` block."""
    def deco(factory: Callable[[], Callable]):
        _STORE_SAMPLERS[(mode, store_kind)] = factory
        return factory
    return deco


def resolve_store_sampler(mode: str, store_kind: str,
                          sampler_args: tuple = ()):
    """The store-native sampler for ``(mode, store_kind)``, or ``None``
    when the pair has no native form — the caller then goes through the
    store's explicit ``to_dense`` escape hatch (and should SAY so in its
    config echo: densification is never silent, DESIGN.md §16)."""
    factory = _STORE_SAMPLERS.get((mode, store_kind))
    if factory is None:
        return None
    return factory(**dict(sampler_args)) if sampler_args else factory()


def store_native(mode: str, store_kind: str) -> bool:
    """Whether ``mode`` consumes ``store_kind``'s layout with zero
    conversion (dense stores are native to every sampler by definition)."""
    return store_kind == "dense" or (mode, store_kind) in _STORE_SAMPLERS


@register_store_sampler("sparse", "tail")
@register_store_sampler("sparse_pallas", "tail")
def _sparse_tail_sampler(dcap: int = 64, wcap: int = None):
    # The §12 sparse family reads the TailStore's lane layout natively:
    # the store IS the sampler's working format, so no [Vb, K] buffer
    # exists anywhere on the path.  wcap is accepted for signature parity
    # with the dense factory but is implied by the lane shape — the
    # engine guarantees the store was built with the same wcap.
    from repro.core.sparse_device import sweep_block_sparse_tail

    def f(cdk, tail_topics, tail_counts, over_pad, row_map,
          ck, d, t, z, mk, u, alpha, beta, vbeta):
        return sweep_block_sparse_tail(
            cdk, tail_topics, tail_counts, over_pad, row_map, ck,
            d, t, z, mk, u, alpha, beta, vbeta, dcap=dcap)
    return f


# ---------------------------------------------------------------------------
# Table-aware samplers (iteration table lifetime, DESIGN.md §10)
# ---------------------------------------------------------------------------

# fn(cdk, ckt_block, ck, doc, woff, z, mask, u, alpha, beta, vbeta,
#    word_packed, doc_packed) -> (cdk, ckt_block, ck, z_new)
_TABLE_SAMPLERS: Dict[str, Callable[[], Callable]] = {}


def register_table_sampler(name: str):
    """Decorator registering a table-aware sampler factory under ``name``
    (the same name as its round-lifetime form in the main registry)."""
    def deco(factory: Callable[[], Callable]):
        _TABLE_SAMPLERS[name] = factory
        return factory
    return deco


def resolve_table_sampler(mode: str) -> Callable:
    """Instantiate the table-aware sampler registered under ``mode``."""
    try:
        factory = _TABLE_SAMPLERS[mode]
    except KeyError:
        raise ValueError(
            f"sampler mode {mode!r} has no table-aware form — "
            f"table_lifetime='iteration' supports: "
            f"{sorted(_TABLE_SAMPLERS)}") from None
    return factory()


def table_capable(mode: str) -> bool:
    """Whether ``mode`` supports the iteration table lifetime."""
    return mode in _TABLE_SAMPLERS


@register_table_sampler("mh")
def _mh_table_sampler():
    from repro.core.mh import sweep_block_mh_tables
    return sweep_block_mh_tables


@register_table_sampler("mh_pallas")
def _mh_pallas_table_sampler():
    from repro.kernels.ops import sweep_block_mh_pallas_tables
    return sweep_block_mh_pallas_tables


def worker_round(cdk, ckt_blk, block_id, ck_loc, z_all, u_r,
                 doc, woff, mask, alpha, beta, vbeta, *, sampler):
    """One worker, one round: sample the token group of the resident block.

    ``block_id`` (the resident block's id, in ``[0, S·M)``) addresses the
    per-block token group directly; the "request model block" / "commit
    model block" steps of Algorithm 2 are the surrounding rotation
    collective in `backends.py`.
    """
    d = doc[block_id]
    t = woff[block_id]
    zz = z_all[block_id]
    mk = mask[block_id]
    cdk, ckt_blk, ck_loc, z_new = sampler(
        cdk, ckt_blk, ck_loc, d, t, zz, mk, u_r, alpha, beta, vbeta)
    z_all = z_all.at[block_id].set(z_new)
    return cdk, ckt_blk, ck_loc, z_all


def worker_round_tables(cdk, ckt_blk, block_id, ck_loc, z_all, u_r,
                        doc, woff, mask, alpha, beta, vbeta,
                        word_packed, doc_packed, *, sampler):
    """:func:`worker_round` for a table-aware sampler: the resident
    block's traveling word table (packed, possibly rounds old) and the
    worker's per-iteration doc table ride along to the sampler.  The
    backends own the tables' lifecycle — building at first residency,
    rotating with the block — exactly as they own the block rotation."""
    d = doc[block_id]
    t = woff[block_id]
    zz = z_all[block_id]
    mk = mask[block_id]
    cdk, ckt_blk, ck_loc, z_new = sampler(
        cdk, ckt_blk, ck_loc, d, t, zz, mk, u_r, alpha, beta, vbeta,
        word_packed, doc_packed)
    z_all = z_all.at[block_id].set(z_new)
    return cdk, ckt_blk, ck_loc, z_all
