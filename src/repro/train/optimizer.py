"""Hand-rolled AdamW (no optax in this environment).

State is a pytree mirroring params (m, v) plus a step counter; everything
is fp32 and inherits the parameter sharding, so under FSDP the optimizer
state is fully sharded too (the ZeRO property).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    m: Any
    v: Any

    def tree_flatten(self):
        return (self.step, self.m, self.v), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p)
        return AdamWState(jnp.zeros((), jnp.int32), zeros(params),
                          zeros(params))

    def schedule(self, step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = step / max(self.warmup_steps, 1)
        prog = jnp.clip((step - self.warmup_steps)
                        / max(self.total_steps - self.warmup_steps, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return self.learning_rate * jnp.minimum(warm, 1.0) * jnp.maximum(
            cos, 0.1)

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        lr = self.schedule(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mh = m / b1c
            vh = v / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m, v

        out = jax.tree_util.tree_map(upd, grads, state.m, state.v, params)
        new_params = jax.tree_util.tree_map(lambda o: o[0], out,
                                            is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(step, new_m, new_v)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda x: x * scale, tree), norm
