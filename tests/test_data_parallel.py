"""Data-parallel baseline behaviour + the paper's Fig-2 ordering."""
import numpy as np
import pytest

from repro.core.counts import check_invariants
from repro.core.data_parallel import DataParallelLDA, adlda_engine
from repro.core.model_parallel import ModelParallelLDA


def test_dp_invariants_and_ascent(tiny_corpus):
    corpus, _, _ = tiny_corpus
    dp = DataParallelLDA(corpus, num_topics=8, num_workers=4, seed=1)
    ll0 = dp.log_likelihood()
    hist = dp.run(5)
    assert hist[-1]["log_likelihood"] > ll0
    check_invariants(dp.gather_counts(), corpus.num_tokens)


def test_dp_staleness_error_positive(tiny_corpus):
    """DP samples from stale copies — its reconciliation error is strictly
    positive, while MP's word-topic error is zero by construction."""
    corpus, _, _ = tiny_corpus
    dp = DataParallelLDA(corpus, num_topics=8, num_workers=4, seed=1)
    dp.step()
    assert dp.model_error() > 0


def test_more_syncs_reduce_staleness(small_corpus):
    corpus, _, _ = small_corpus
    errs = []
    for s in (1, 4):
        dp = DataParallelLDA(corpus, num_topics=10, num_workers=4, seed=3,
                             syncs_per_iter=s)
        dp.step()
        errs.append(dp.model_error())
    assert errs[1] < errs[0]


def test_mp_converges_at_least_as_fast_per_iteration(small_corpus):
    """Fig 2a: per-iteration likelihood of MP dominates DP early on."""
    corpus, _, _ = small_corpus
    mp = ModelParallelLDA(corpus, num_topics=10, num_workers=8, seed=5)
    dp = DataParallelLDA(corpus, num_topics=10, num_workers=8, seed=5)
    h_mp = mp.run(6)
    h_dp = dp.run(6)
    mp_ll = [h["log_likelihood"] for h in h_mp]
    dp_ll = [h["log_likelihood"] for h in h_dp]
    # compare the early trajectory where staleness hurts most
    wins = sum(a >= b for a, b in zip(mp_ll[:4], dp_ll[:4]))
    assert wins >= 3, (mp_ll, dp_ll)


def test_dp_memory_is_flat_mp_shrinks(small_corpus):
    """Fig 4a: per-worker model bytes — DP O(VK) flat, MP O(VK/M)."""
    corpus, _, _ = small_corpus
    for m in (2, 4):
        mp = ModelParallelLDA(corpus, num_topics=10, num_workers=m)
        dp = DataParallelLDA(corpus, num_topics=10, num_workers=m)
        mp_bytes = np.asarray(mp.state.ckt)[0].nbytes
        dp_bytes = np.asarray(dp.ckt_local)[0].nbytes
        assert dp_bytes == corpus.vocab_size * 10 * 4
        assert mp_bytes == mp.partition.block_size * 10 * 4
        assert mp_bytes <= dp_bytes // m + 10 * 4 * mp.partition.block_size // 100 + 40


def test_hybrid_round_sync_staleness_below_adlda_baseline(small_corpus):
    """Fig 2/3 ordering, pinned in CI: the per-round-synced hybrid engine
    reconciles S·M times per iteration and confines parallelization error
    to {C_k} within a round, so its normalized staleness must stay at or
    below the AD-LDA baseline's (one reconciliation per iteration) for the
    same total worker count."""
    corpus, _, _ = small_corpus
    dp = DataParallelLDA(corpus, num_topics=10, num_workers=4, seed=3,
                         syncs_per_iter=1)
    hybrid = ModelParallelLDA(corpus, num_topics=10, num_workers=2,
                              data_parallel=2, seed=3)
    for _ in range(2):
        dp.step()
        hybrid.step()
    assert hybrid.delta_error() <= dp.model_error(), (
        hybrid.delta_error(), dp.model_error())


def test_adlda_engine_is_degenerate_hybrid(small_corpus):
    """The engine-built AD-LDA (M=1) exposes the same staleness model as
    the standalone baseline: positive pre-sync error at one sync per
    iteration, shrinking as blocks_per_worker adds sync points (the
    syncs_per_iter analogue)."""
    corpus, _, _ = small_corpus
    errs = []
    for s in (1, 4):
        eng = adlda_engine(corpus, num_topics=10, num_replicas=4, seed=3,
                           blocks_per_worker=s)
        eng.step()
        errs.append(eng.delta_error())
    assert errs[0] > 0
    assert errs[1] < errs[0]
