"""Sparse-LDA bucket sampler (Yao et al. 2009; paper eq. 2).

This is the sampler inside Yahoo!LDA, the paper's baseline.  It splits the
conditional into three buckets

  A_k = α_k β / (C_k + Vβ)                    (dense, precomputed once)
  B_k = β C_d^k / (C_k + Vβ)                  (document-sparse, cached per doc)
  C_k = (α_k + C_d^k) C_k^t / (C_k + Vβ)      (word-sparse)

and samples bucket-first, exploiting that mass concentrates in B and C.  We
implement it host-side, document-major (its natural order), for three
purposes: (i) a second independent oracle for correctness tests (it must
define the same distribution as eq. 1/eq. 3); (ii) the per-token sampler of
the data-parallel baseline's host path; (iii) to document why it is the
WRONG decomposition for inverted-index order (the per-document B cache
thrashes), motivating the paper's eq. 3 — see ``cache_recompute_count``.

The A and B vectors are maintained INCREMENTALLY (the Sparse-LDA cache):
a token's draw moves counts at exactly two topic lanes (``z_old`` down,
``z_new`` up), so only those two lanes of ``A`` and of the current
document's ``B`` are recomputed per accepted move — O(1) float work where
the naive form rebuilds both full-K vectors every token.  ``B`` rebuilds
in full only when the visit order crosses a document boundary (once per
document in the natural doc-major order).  Bucket SUMS remain full-length
``np.sum`` over the dense cached vectors: a lane value recomputed by the
same expression is bitwise identical to a fresh rebuild, and summing the
identical dense array keeps numpy's pairwise summation tree — so the
incremental sweep is bit-for-bit the reference sweep
(:func:`sparse_gibbs_sweep_np_reference`, pinned by regression test),
not merely statistically equivalent.

The device port of this decomposition (hybrid dense-head/sparse-tail
layout, engine sampler ``sparse``/``sparse_pallas``) lives in
``core/sparse_device.py`` — see DESIGN.md §12.
"""
from __future__ import annotations

import numpy as np


def bucket_masses(ckt_row, cdk_row, ck, alpha, beta, vbeta):
    """Return (A_k, B_k, C_k) bucket vectors; their sum is eq. (1)."""
    denom = ck + vbeta
    a = alpha * beta / denom
    b = beta * cdk_row / denom
    c = (alpha + cdk_row) * ckt_row / denom
    return a, b, c


def _bucket_draw(a, b, c, sa, sb, sc, ckt_row, cdk_row, u_i):
    """One bucket-major inverse-CDF draw given the cached vectors/sums."""
    x = u_i * (sa + sb + sc)
    # The sparse-bucket draws clamp like the dense one in sampler.py: the
    # bucket test compares x against a PAIRWISE sum (sc = c.sum()) while
    # the inverse-CDF walks the SEQUENTIAL cumsum over nz, so roundoff
    # (u -> 1.0, or the x - sc cancellation in B) can leave x at or past
    # cs[-1] and searchsorted one past the end of nz.
    if x < sc:                      # word-sparse bucket first (most mass)
        nz = np.nonzero(ckt_row)[0]
        cs = np.cumsum(c[nz])
        return int(nz[min(np.searchsorted(cs, x, side="right"),
                          len(nz) - 1)])
    if x < sc + sb:                 # document-sparse bucket
        nz = np.nonzero(cdk_row)[0]
        cs = np.cumsum(b[nz])
        return int(nz[min(np.searchsorted(cs, x - sc, side="right"),
                          len(nz) - 1)])
    cs = np.cumsum(a)               # dense smoothing bucket
    return int(min(np.searchsorted(cs, x - sc - sb, side="right"),
                   len(a) - 1))


def sparse_gibbs_sweep_np(cdk, ckt, ck, doc, word, z, u, alpha, beta,
                          order=None):
    """Exact serial sweep using the A/B/C bucket draw, incremental caches.

    Consumes one uniform per token, like ``gibbs_sweep_np``; the bucket walk
    uses the same uniform rescaled, so the draw is still exact inverse-CDF
    over A+B+C mass (bucket-major ordering of the CDF).

    Cache invariants (module docstring): after every count move, ``a`` and
    the current doc's ``b`` hold exactly the values a full
    ``bucket_masses`` rebuild would produce — only the two changed lanes
    are written, with the same scalar expression the vector rebuild uses.
    The word-sparse ``c`` is inherently per-token (the word changes every
    token) and is built only on its nonzero lanes; the zero lanes of a
    full rebuild are exact ``+0.0`` (finite·0/denom), so the dense
    scatter reproduces the reference vector bitwise.
    """
    doc = np.asarray(doc); word = np.asarray(word)
    z = np.array(z, np.int32, copy=True)
    alpha = np.asarray(alpha, np.float64)
    k = ckt.shape[1]
    vbeta = np.float64(beta * ckt.shape[0])
    beta = np.float64(beta)
    if order is None:
        order = range(doc.shape[0])

    denom = ck.astype(np.float64) + vbeta
    a = alpha * beta / denom                    # dense smoothing cache
    b = np.zeros(k, np.float64)                 # per-doc cache (lazy)
    c = np.zeros(k, np.float64)                 # per-token scatter buffer
    cur_doc = -1

    def refresh(lane, d):
        """Recompute the changed lane of every cached vector (O(1))."""
        dn = np.float64(ck[lane]) + vbeta
        denom[lane] = dn
        a[lane] = alpha[lane] * beta / dn
        b[lane] = beta * np.float64(cdk[d, lane]) / dn

    for i in order:
        d, t, k_old = doc[i], word[i], z[i]
        if d != cur_doc:                        # doc boundary: rebuild B
            b = beta * cdk[d].astype(np.float64) / denom
            cur_doc = d
        cdk[d, k_old] -= 1; ckt[t, k_old] -= 1; ck[k_old] -= 1
        refresh(k_old, d)
        nzc = np.nonzero(ckt[t])[0]
        c.fill(0.0)
        c[nzc] = (alpha[nzc] + cdk[d, nzc]) * ckt[t, nzc] / denom[nzc]
        # full-length sums over the dense caches — identical arrays to a
        # per-token rebuild, hence identical pairwise-summation results
        k_new = _bucket_draw(a, b, c, a.sum(), b.sum(), c.sum(),
                             ckt[t], cdk[d], u[i])
        z[i] = k_new
        cdk[d, k_new] += 1; ckt[t, k_new] += 1; ck[k_new] += 1
        refresh(k_new, d)
    return z


def sparse_gibbs_sweep_np_reference(cdk, ckt, ck, doc, word, z, u, alpha,
                                    beta, order=None):
    """The pre-incremental form: rebuild all three bucket vectors per
    token.  Kept as the regression anchor — the incremental sweep must
    reproduce it bit for bit (``tests/test_sampler.py``)."""
    doc = np.asarray(doc); word = np.asarray(word)
    z = np.array(z, np.int32, copy=True)
    alpha = np.asarray(alpha, np.float64)
    vbeta = np.float64(beta * ckt.shape[0])
    beta = np.float64(beta)
    if order is None:
        order = range(doc.shape[0])
    for i in order:
        d, t, k_old = doc[i], word[i], z[i]
        cdk[d, k_old] -= 1; ckt[t, k_old] -= 1; ck[k_old] -= 1
        a, b, c = bucket_masses(ckt[t].astype(np.float64),
                                cdk[d].astype(np.float64),
                                ck.astype(np.float64), alpha, beta, vbeta)
        k_new = _bucket_draw(a, b, c, a.sum(), b.sum(), c.sum(),
                             ckt[t], cdk[d], u[i])
        z[i] = k_new
        cdk[d, k_new] += 1; ckt[t, k_new] += 1; ck[k_new] += 1
    return z


def cache_recompute_count(doc, word, order_doc_major: bool) -> int:
    """How many times the Sparse-LDA per-document ``Σ_k B_k`` cache must be
    rebuilt under a visit order (paper §4.2's motivating observation).

    Document-major order rebuilds once per document; word-major (inverted
    index) order rebuilds on nearly every token, which is why the paper
    replaces eq. (2) with the word-major eq. (3).
    """
    doc = np.asarray(doc); word = np.asarray(word)
    if order_doc_major:
        idx = np.lexsort((word, doc))
    else:
        idx = np.lexsort((doc, word))
    d_seq = doc[idx]
    return int(1 + (d_seq[1:] != d_seq[:-1]).sum())
