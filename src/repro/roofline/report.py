"""Render the dry-run JSON records into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.roofline.report benchmarks/results/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List


def load(outdir: str) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _fmt(x: float) -> str:
    return f"{x:.2e}" if x else "0"


def dryrun_table(recs: List[Dict], mesh: str) -> str:
    rows = ["| arch | shape | status | mem/dev GiB | compile s | "
            "collectives (per-device bytes) |",
            "|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "ok":
            det = r["costs"]["collective_detail"]["bytes"]
            coll = ", ".join(f"{k.split('-')[-1] if False else k}:"
                             f"{_fmt(v)}" for k, v in det.items() if v)
            rows.append(
                f"| {r['arch']} | {r['shape']} | ok | "
                f"{r['memory']['total_gib_per_device']} | "
                f"{r.get('compile_s', '')} | {coll or '-'} |")
        elif r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP | - | - | "
                        f"{r['reason'][:70]} |")
        else:
            rows.append(f"| {r['arch']} | {r['shape']} | **FAIL** | - | - | "
                        f"{r.get('error', '')[:70]} |")
    return "\n".join(rows)


def roofline_table(recs: List[Dict], mesh: str) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | MODEL_FLOPs | useful ratio |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        t = r["roofline"]
        dom = t["dominant"].replace("_s", "")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt(t['compute_s'])} | "
            f"{_fmt(t['memory_s'])} | {_fmt(t['collective_s'])} | "
            f"**{dom}** | {_fmt(r['model_flops_global'])} | "
            f"{r['useful_compute_ratio']} |")
    return "\n".join(rows)


def summarize(recs: List[Dict]) -> Dict:
    out = {"ok": 0, "skipped": 0, "failed": 0}
    for r in recs:
        out[r["status"] if r["status"] in out else "failed"] += 1
    return out


def main() -> None:
    outdir = sys.argv[1] if len(sys.argv) > 1 else "benchmarks/results/dryrun"
    recs = load(outdir)
    for mesh in sorted({r["mesh"] for r in recs}):
        sub = [r for r in recs if r["mesh"] == mesh]
        print(f"\n## Mesh: {mesh}  ({summarize(sub)})\n")
        print("### Dry-run\n")
        print(dryrun_table(recs, mesh))
        print("\n### Roofline\n")
        print(roofline_table(recs, mesh))


if __name__ == "__main__":
    main()
