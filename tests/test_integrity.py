"""Integrity layer (DESIGN.md §15): sidecar checksums, atomic writes,
torn-write detection, and validate-on-load across every artifact family
— corpus shards, engine checkpoints, serving snapshots.  The acceptance
criterion pinned here: a bit-flipped checkpoint, corpus shard, and
snapshot are each REJECTED with a structured error, never loaded
silently."""
import json
import os

import numpy as np
import pytest

from repro.data import integrity
from repro.data.integrity import (CorruptArtifactError, IntegrityError,
                                  MissingArtifactError, TornWriteError)


# ---------------------------------------------------------------------------
# Sidecars and validation primitives
# ---------------------------------------------------------------------------

class TestSidecars:
    def test_save_npy_roundtrip_with_sidecar(self, tmp_path):
        p = str(tmp_path / "a.npy")
        arr = np.arange(12, dtype=np.int32).reshape(3, 4)
        integrity.save_npy(p, arr)
        assert os.path.exists(integrity.sidecar_path(p))
        assert integrity.validate_file(p) is True
        out = integrity.load_npy(p)
        assert out.dtype == arr.dtype and np.array_equal(out, arr)

    def test_save_npz_roundtrip(self, tmp_path):
        p = str(tmp_path / "a.npz")
        integrity.save_npz(p, x=np.arange(5), y=np.ones((2, 2)))
        d = integrity.load_npz(p)
        assert set(d) == {"x", "y"}
        assert np.array_equal(d["x"], np.arange(5))

    def test_unstamped_file_passes_without_requirement(self, tmp_path):
        p = str(tmp_path / "plain.npy")
        np.save(p, np.zeros(3))
        assert integrity.validate_file(p) is False      # no sidecar, ok
        with pytest.raises(MissingArtifactError):
            integrity.validate_file(p, require_sidecar=True)

    def test_missing_artifact(self, tmp_path):
        with pytest.raises(MissingArtifactError):
            integrity.validate_file(str(tmp_path / "nope.npy"))
        with pytest.raises(MissingArtifactError):
            integrity.load_npy(str(tmp_path / "nope.npy"))

    def test_bit_flip_detected(self, tmp_path):
        p = str(tmp_path / "a.npy")
        integrity.save_npy(p, np.arange(100, dtype=np.float64))
        integrity.flip_byte(p, seed=3)
        with pytest.raises(CorruptArtifactError):
            integrity.load_npy(p)

    def test_flip_byte_is_deterministic(self, tmp_path):
        p1, p2 = str(tmp_path / "a.bin"), str(tmp_path / "b.bin")
        for p in (p1, p2):
            with open(p, "wb") as f:
                f.write(bytes(range(200)))
        assert integrity.flip_byte(p1, seed=7) == \
            integrity.flip_byte(p2, seed=7)
        with open(p1, "rb") as f1, open(p2, "rb") as f2:
            assert f1.read() == f2.read()

    def test_torn_write_detected_as_torn(self, tmp_path):
        p = str(tmp_path / "a.npy")
        integrity.save_npy(p, np.arange(1000, dtype=np.int64))
        integrity.truncate_file(p, os.path.getsize(p) // 2)
        with pytest.raises(TornWriteError):
            integrity.validate_file(p)
        # TornWriteError IS a CorruptArtifactError (one catch for "bad")
        with pytest.raises(CorruptArtifactError):
            integrity.validate_file(p)

    def test_sha256_option(self, tmp_path):
        p = str(tmp_path / "a.npy")
        np.save(p, np.arange(4))
        integrity.write_sidecar(p, algo="sha256")
        assert integrity.validate_file(p) is True
        integrity.flip_byte(p, seed=0)
        with pytest.raises(CorruptArtifactError):
            integrity.validate_file(p)

    def test_validate_tree(self, tmp_path):
        root = tmp_path / "tree"
        (root / "sub").mkdir(parents=True)
        integrity.save_npy(str(root / "a.npy"), np.zeros(3))
        integrity.save_npy(str(root / "sub" / "b.npy"), np.ones(3))
        assert integrity.validate_tree(str(root)) == 2
        integrity.flip_byte(str(root / "sub" / "b.npy"), seed=1)
        with pytest.raises(CorruptArtifactError):
            integrity.validate_tree(str(root))

    def test_unreadable_sidecar_is_corrupt(self, tmp_path):
        p = str(tmp_path / "a.npy")
        integrity.save_npy(p, np.zeros(2))
        with open(integrity.sidecar_path(p), "w") as f:
            f.write("{not json")
        with pytest.raises(CorruptArtifactError):
            integrity.validate_file(p)


class TestAtomicJson:
    def test_roundtrip_and_checksum(self, tmp_path):
        p = str(tmp_path / "cfg.json")
        integrity.atomic_write_json(p, {"a": 1}, checksum=True)
        assert integrity.validate_file(p) is True
        with open(p) as f:
            assert json.load(f) == {"a": 1}

    def test_overwrite_leaves_no_temp(self, tmp_path):
        p = str(tmp_path / "cfg.json")
        integrity.atomic_write_json(p, {"v": 1})
        integrity.atomic_write_json(p, {"v": 2})
        assert json.load(open(p)) == {"v": 2}
        assert not os.path.exists(p + ".tmp")


# ---------------------------------------------------------------------------
# The three artifact families of the acceptance criterion
# ---------------------------------------------------------------------------

class TestArtifactFamilies:
    def test_bit_flipped_corpus_shard_rejected(self, tmp_path):
        from repro.data.stream import ShardedCorpus, write_zipf_stream
        out = write_zipf_stream(str(tmp_path / "c"), 12, 64, 8, seed=0,
                                docs_per_shard=4)
        sc = ShardedCorpus(out)
        shard_file = os.path.join(out, sc.meta["shards"][1]["file"])
        integrity.flip_byte(shard_file, seed=2)
        sc.load_shard(0)                        # untouched shard still fine
        with pytest.raises(CorruptArtifactError):
            sc.load_shard(1)

    def test_bit_flipped_mp_checkpoint_rejected(self, tmp_path):
        from repro.core.model_parallel import ModelParallelLDA
        from repro.data.synthetic import synthetic_corpus
        corpus, _, _ = synthetic_corpus(12, 32, 4, 8, seed=0)
        lda = ModelParallelLDA(corpus, 4, 2, seed=0)
        lda.step()
        ckpt = str(tmp_path / "ck.npz")
        lda.save_checkpoint(ckpt)
        assert os.path.exists(integrity.sidecar_path(ckpt))
        integrity.flip_byte(ckpt, seed=5)
        with pytest.raises(CorruptArtifactError):
            ModelParallelLDA.resume(corpus, ckpt)

    def test_bit_flipped_snapshot_npz_rejected(self, tmp_path):
        from repro.core.infer import ModelSnapshot, load_snapshot
        snap = ModelSnapshot.from_counts(
            np.arange(32 * 4, dtype=np.int32).reshape(32, 4),
            np.arange(4, dtype=np.int32) * 32, 0.1, 0.01)
        p = str(tmp_path / "snap.npz")
        snap.save(p)
        assert load_snapshot(p).fingerprint() == snap.fingerprint()
        integrity.flip_byte(p, seed=9)
        with pytest.raises(CorruptArtifactError):
            load_snapshot(p)

    def test_bit_flipped_sharded_snapshot_block_rejected(self, tmp_path):
        from repro.core.engine.streaming import StreamingLDA
        from repro.core.infer import load_snapshot_rows
        from repro.data.stream import write_zipf_stream
        cdir = write_zipf_stream(str(tmp_path / "c"), 12, 48, 8, seed=1,
                                 docs_per_shard=6)
        lda = StreamingLDA(cdir, str(tmp_path / "wd"), 4, 2, seed=0)
        lda.step()
        sd = lda.save_snapshot_sharded(str(tmp_path / "snap"))
        words = np.arange(8, dtype=np.int32)
        load_snapshot_rows(sd, words)           # validates clean
        integrity.flip_byte(os.path.join(sd, "block_00000.npy"), seed=4)
        with pytest.raises(CorruptArtifactError):
            load_snapshot_rows(sd, words)

    def test_streaming_resume_rejects_flipped_checkpoint(self, tmp_path):
        from repro.core.engine.streaming import StreamingLDA
        from repro.data.stream import write_zipf_stream
        cdir = write_zipf_stream(str(tmp_path / "c"), 12, 48, 8, seed=1,
                                 docs_per_shard=6)
        wd = str(tmp_path / "wd")
        lda = StreamingLDA(cdir, wd, 4, 2, seed=0)
        lda.step()
        lda.save_checkpoint()
        integrity.flip_byte(os.path.join(wd, "ckpt", "ck.npy"), seed=6)
        with pytest.raises(CorruptArtifactError):
            StreamingLDA.resume(wd)

    def test_error_taxonomy_hierarchy(self):
        assert issubclass(TornWriteError, CorruptArtifactError)
        assert issubclass(CorruptArtifactError, IntegrityError)
        assert issubclass(MissingArtifactError, IntegrityError)
