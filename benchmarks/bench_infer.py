"""Query-serving benchmark: fold-in latency and throughput (DESIGN.md §11).

    PYTHONPATH=src python -m benchmarks.bench_infer [--smoke]

Trains a small model, snapshots it, then measures the
:class:`TopicInferenceServer` across samplers × batch sizes on a fixed
bucket: per-batch latency p50/p99 and derived queries/s + query-tokens/s.
This is the serving-side twin of `bench_e2e.py` — where that benchmark
answers "how fast does an iteration train", this one answers "how fast
does a frozen snapshot answer queries", which is the quantity the
north-star's "heavy traffic" goal actually bounds.

What to expect: the MH sampler's per-token cost is O(1) against tables
built ONCE per snapshot, so its advantage over the exact O(K) ``scan``
GROWS with K — the frozen-model ideal case LightLDA describes.  Batch
size amortizes dispatch overhead into throughput at the cost of p99.

Results land in ``benchmarks/results/bench_infer.json`` and — full mode
only — are folded into the repo-root ``BENCH_e2e.json`` trajectory via
``bench_e2e.aggregate_root`` (smoke mode writes a separate *_smoke file
that the root digest excludes, so CI never clobbers recorded numbers).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.bench_e2e import aggregate_root
from benchmarks.common import emit_csv_row, save_result
from repro.core.engine.api import ModelParallelLDA
from repro.data.synthetic import synthetic_corpus
from repro.serve.topic_infer import TopicInferenceServer

FULL = dict(docs=128, vocab=256, topics=16, doc_len=48, k=256,
            train_iters=3, sweeps=5, query_len=32,
            samplers=("scan", "mh", "mh_pallas"),
            batch_sizes=(1, 8, 32),
            repeats={"scan": 30, "mh": 30, "mh_pallas": 8})
SMOKE = dict(docs=24, vocab=64, topics=8, doc_len=16, k=16,
             train_iters=1, sweeps=2, query_len=12,
             samplers=("mh",), batch_sizes=(4,),
             repeats={"mh": 3})


def _measure(server, docs, repeats: int) -> dict:
    """Latency distribution of repeated `infer` calls on one bucket."""
    server.infer(docs)                       # compile + warm the bucket
    lat = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        theta = server.infer(docs)
        lat.append(time.perf_counter() - t0)
    assert np.isfinite(theta).all()
    lat = np.asarray(lat)
    p50 = float(np.percentile(lat, 50))
    tokens = sum(len(d) for d in docs)
    return {"batch": len(docs),
            "p50_ms": p50 * 1e3,
            "p99_ms": float(np.percentile(lat, 99)) * 1e3,
            "queries_per_s": len(docs) / p50,
            "query_tokens_per_s": tokens / p50,
            "repeats": repeats}


def run(smoke: bool = False, seed: int = 0) -> dict:
    cfg = SMOKE if smoke else FULL
    corpus, _, _ = synthetic_corpus(cfg["docs"], cfg["vocab"],
                                    cfg["topics"], cfg["doc_len"],
                                    seed=seed)
    # train at serving K (the snapshot's K is what the fold-in pays for);
    # the fast word-frozen sampler keeps the benchmark's setup cheap
    lda = ModelParallelLDA(corpus, cfg["k"], num_workers=2, seed=seed,
                           sampler_mode="batched", track_error=False)
    lda.run(cfg["train_iters"])
    snap = lda.snapshot()
    rng = np.random.default_rng(seed + 1)
    out = {
        "mode": "smoke" if smoke else "full",
        "workload": {"vocab": cfg["vocab"], "k": cfg["k"],
                     "train_tokens": corpus.num_tokens,
                     "query_len": cfg["query_len"],
                     "fold_in_sweeps": cfg["sweeps"]},
        "samplers": {},
    }
    for sampler in cfg["samplers"]:
        server = TopicInferenceServer(snap, sampler=sampler,
                                      num_sweeps=cfg["sweeps"], seed=seed)
        rec = {}
        for b in cfg["batch_sizes"]:
            docs = [rng.integers(0, cfg["vocab"],
                                 size=cfg["query_len"]).astype(np.int32)
                    for _ in range(b)]
            r = _measure(server, docs, cfg["repeats"][sampler])
            rec[f"batch{b}"] = r
            emit_csv_row(f"infer_{sampler}_b{b}_k{cfg['k']}",
                         r["p50_ms"] * 1e3,
                         f"qps={r['queries_per_s']:.1f},"
                         f"p99_ms={r['p99_ms']:.2f}")
        # sanity: the server really served from one bucket per batch size
        rec["buckets"] = {f"{k[0]}x{k[1]}": v
                          for k, v in server.bucket_calls.items()}
        out["samplers"][sampler] = rec
    # end-to-end sanity on an explicit server (not whichever sampler the
    # loop happened to end on): perplexity of a random query set is finite
    ppl = TopicInferenceServer(snap, sampler=cfg["samplers"][0],
                               num_sweeps=cfg["sweeps"], seed=seed) \
        .perplexity([rng.integers(0, cfg["vocab"], size=cfg["query_len"])
                     for _ in range(4)])
    out["holdout_perplexity_sanity"] = {"sampler": cfg["samplers"][0],
                                        "perplexity": ppl["perplexity"]}
    assert np.isfinite(ppl["perplexity"])
    save_result("bench_infer_smoke" if smoke else "bench_infer", out)
    if not smoke:
        aggregate_root()      # fold into the repo-root BENCH trajectory
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI workload; not recorded in the root "
                         "BENCH trajectory")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    res = run(smoke=args.smoke)
    for sampler, rec in res["samplers"].items():
        for key, r in rec.items():
            if not key.startswith("batch"):
                continue
            print(f"# {sampler} {key}: p50 {r['p50_ms']:.2f} ms  "
                  f"p99 {r['p99_ms']:.2f} ms  "
                  f"{r['queries_per_s']:,.1f} queries/s")


if __name__ == "__main__":
    main()
