"""Artifact integrity layer: checksummed sidecars, atomic writes, and
validate-on-load with a structured error taxonomy (DESIGN.md §15).

The paper's regime — multi-day runs on "a low-end cluster with very
limited computational resources" — is exactly where disks tear writes
and bit-rot corrupts artifacts.  Every on-disk artifact this repo
produces (corpus shards, streaming workdir state files, engine
checkpoints, serving snapshots) flows through this module, which
enforces two invariants:

* **writes are atomic** — data lands in a temp file, is fsynced, and is
  published with a single ``os.replace``; a kill at ANY instant leaves
  either the old artifact or the new one, never a torn file under the
  final name.
* **reads are validated** — each artifact carries a sidecar
  (``<name>.sum``, JSON: algorithm, digest, byte size) stamped at write
  time; loads verify it and raise a STRUCTURED error instead of the
  silent ``np.load`` failures (truncated-zip tracebacks, or worse,
  garbage arrays) a torn or bit-flipped file produces today.

Error taxonomy (all subclass :class:`IntegrityError`):

* :class:`MissingArtifactError` — the artifact (or a required sidecar)
  does not exist.
* :class:`CorruptArtifactError` — content does not match its stamp
  (bit flip, overwrite, unreadable container).
* :class:`TornWriteError` — the artifact is SHORTER than its stamp: the
  signature of a write killed mid-flight.  Subclasses
  ``CorruptArtifactError`` so callers that only care about "bad" catch
  one type.

The default digest is ``crc32`` (zlib, ~GB/s — cheap enough to stamp on
every per-round state write of the streaming engine); ``sha256`` is
available for long-lived artifacts (checkpoints, snapshots) where
adversarial-grade integrity is worth the extra pass.

Fault-injection hooks: every read/write funnels through
`core/faults.py` fire points (``"read"``, ``"write"``, ``"wrote"``), so
a deterministic :class:`~repro.core.faults.FaultPlan` can kill, error,
or bit-flip any specific artifact operation — the machinery the
crash-recovery tests and CI pass 9 drive.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import zlib
from typing import Iterable, List, Optional

import numpy as np

SIDECAR_SUFFIX = ".sum"
SIDECAR_FORMAT = "integrity-sidecar-v1"
DEFAULT_ALGO = "crc32"


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------

class IntegrityError(Exception):
    """Base of the artifact-integrity taxonomy; carries the path."""

    def __init__(self, path: str, message: str):
        self.path = path
        super().__init__(f"{message} [{path}]")


class MissingArtifactError(IntegrityError):
    """Artifact (or a required sidecar) absent from disk."""


class CorruptArtifactError(IntegrityError):
    """Artifact bytes disagree with their integrity stamp, or the
    container is unreadable (bad magic, truncated zip, ...)."""


class TornWriteError(CorruptArtifactError):
    """Artifact shorter than its stamp — a write killed mid-flight.
    Distinguished from generic corruption because the RESPONSE differs:
    a torn file under a temp name is expected debris a supervisor
    quarantines; a torn file under a FINAL name means some writer
    bypassed the atomic-publish protocol."""


# ---------------------------------------------------------------------------
# Digests and sidecars
# ---------------------------------------------------------------------------

def _digest_bytes(data: bytes, algo: str) -> str:
    if algo == "crc32":
        return f"{zlib.crc32(data) & 0xFFFFFFFF:08x}"
    if algo == "sha256":
        return hashlib.sha256(data).hexdigest()
    raise ValueError(f"unknown digest algorithm {algo!r}")


def file_digest(path: str, algo: str = DEFAULT_ALGO) -> str:
    """Streaming digest of a file (one 1-MiB-chunk pass)."""
    if algo == "crc32":
        crc = 0
        with open(path, "rb") as f:
            while chunk := f.read(1 << 20):
                crc = zlib.crc32(chunk, crc)
        return f"{crc & 0xFFFFFFFF:08x}"
    if algo == "sha256":
        h = hashlib.sha256()
        with open(path, "rb") as f:
            while chunk := f.read(1 << 20):
                h.update(chunk)
        return h.hexdigest()
    raise ValueError(f"unknown digest algorithm {algo!r}")


def sidecar_path(path: str) -> str:
    return path + SIDECAR_SUFFIX


def write_sidecar(path: str, algo: str = DEFAULT_ALGO,
                  digest: Optional[str] = None,
                  size: Optional[int] = None) -> str:
    """Stamp ``<path>.sum`` for an existing artifact.  The sidecar write
    is itself atomic, and ordered AFTER the artifact's publish — so a
    kill between the two leaves (new artifact, old/absent sidecar),
    which validation reports as corruption and a supervisor quarantines:
    fail-loud, never fail-wrong."""
    if digest is None:
        digest = file_digest(path, algo)
    if size is None:
        size = os.path.getsize(path)
    meta = {"format": SIDECAR_FORMAT, "algo": algo, "digest": digest,
            "size": int(size)}
    sc = sidecar_path(path)
    _atomic_write_bytes(sc, json.dumps(meta).encode())
    return sc


def validate_file(path: str, require_sidecar: bool = False) -> bool:
    """Check one artifact against its sidecar.

    Returns True when validated, False when no sidecar exists (and
    ``require_sidecar`` is off — unstamped artifacts are legal, they
    just get no protection).  Raises the taxonomy otherwise:
    ``MissingArtifactError`` (file or required sidecar absent),
    ``TornWriteError`` (shorter than stamped), ``CorruptArtifactError``
    (size or digest mismatch, unreadable sidecar).
    """
    from repro.core import faults
    faults.fire("read", path)
    if not os.path.exists(path):
        raise MissingArtifactError(path, "artifact missing")
    sc = sidecar_path(path)
    if not os.path.exists(sc):
        if require_sidecar:
            raise MissingArtifactError(sc, "required integrity sidecar "
                                           "missing")
        return False
    try:
        with open(sc) as f:
            meta = json.load(f)
        algo, want, size = meta["algo"], meta["digest"], int(meta["size"])
    except (OSError, ValueError, KeyError) as e:
        raise CorruptArtifactError(sc, f"unreadable sidecar ({e})") from e
    actual = os.path.getsize(path)
    if actual < size:
        raise TornWriteError(
            path, f"torn write: {actual} bytes on disk, {size} stamped")
    if actual != size:
        raise CorruptArtifactError(
            path, f"size mismatch: {actual} bytes on disk, {size} stamped")
    got = file_digest(path, algo)
    if got != want:
        raise CorruptArtifactError(
            path, f"{algo} mismatch: {got} on disk, {want} stamped")
    return True


def validate_tree(root: str, require_sidecar: bool = False) -> int:
    """Validate every sidecar-stamped artifact under ``root`` (and,
    with ``require_sidecar``, demand that every non-sidecar file IS
    stamped).  Returns the number of artifacts validated; raises the
    taxonomy on the first bad one.  This is what checkpoint restore and
    snapshot hot-swap run before trusting a directory."""
    if not os.path.isdir(root):
        raise MissingArtifactError(root, "artifact directory missing")
    n = 0
    for dirpath, _, files in sorted(os.walk(root)):
        for fname in sorted(files):
            if fname.endswith(SIDECAR_SUFFIX):
                continue
            path = os.path.join(dirpath, fname)
            if validate_file(path, require_sidecar=require_sidecar):
                n += 1
    return n


# ---------------------------------------------------------------------------
# Atomic writes
# ---------------------------------------------------------------------------

def _fsync_dir(path: str) -> None:
    """Best-effort directory fsync so the rename itself is durable."""
    try:
        fd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _atomic_write_bytes(path: str, data: bytes) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(path)


def atomic_write_json(path: str, obj, indent: Optional[int] = None,
                      checksum: bool = False) -> str:
    """Publish a JSON artifact atomically (write temp, fsync, rename).

    A kill mid-write can never leave a torn file under ``path`` — the
    failure mode today's bare ``open(...).write`` has for
    ``progress.json`` / ``run.json`` / corpus manifests.  Fault points:
    ``json.tmp_written`` fires between the temp write and the rename,
    which is exactly where the regression test injects its kill."""
    from repro.core import faults
    faults.fire("write", path)
    data = json.dumps(obj, indent=indent).encode()
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    faults.fire("json.tmp_written", path)
    os.replace(tmp, path)
    _fsync_dir(path)
    if checksum:
        write_sidecar(path, digest=_digest_bytes(data, DEFAULT_ALGO),
                      size=len(data))
    faults.fire("wrote", path)
    return path


def save_npy(path: str, arr: np.ndarray, checksum: bool = True) -> str:
    """Atomic, checksummed replacement for ``np.save``: serialize to a
    temp file, fsync, publish with ``os.replace``, then stamp the
    sidecar.  The artifact under ``path`` is therefore always either
    the previous complete array or the new complete array."""
    from repro.core import faults
    faults.fire("write", path)
    buf = io.BytesIO()
    np.save(buf, np.ascontiguousarray(arr))
    data = buf.getvalue()
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    faults.fire("npy.tmp_written", path)
    os.replace(tmp, path)
    _fsync_dir(path)
    if checksum:
        write_sidecar(path, digest=_digest_bytes(data, DEFAULT_ALGO),
                      size=len(data))
    faults.fire("wrote", path)
    return path


def save_npz(path: str, compressed: bool = False, checksum: bool = True,
             **arrays) -> str:
    """Atomic, checksummed replacement for ``np.savez(path, **arrays)``."""
    from repro.core import faults
    faults.fire("write", path)
    buf = io.BytesIO()
    (np.savez_compressed if compressed else np.savez)(buf, **arrays)
    data = buf.getvalue()
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    faults.fire("npz.tmp_written", path)
    os.replace(tmp, path)
    _fsync_dir(path)
    if checksum:
        write_sidecar(path, digest=_digest_bytes(data, DEFAULT_ALGO),
                      size=len(data))
    faults.fire("wrote", path)
    return path


# ---------------------------------------------------------------------------
# Validated loads
# ---------------------------------------------------------------------------

def load_npy(path: str, require_sidecar: bool = False) -> np.ndarray:
    """``np.load`` with validate-on-load: sidecar check first (the
    taxonomy replaces silent failures), then a parse whose own errors —
    truncated header, bad magic — are wrapped as corruption, because by
    then the bytes matched their stamp or were never stamped."""
    validate_file(path, require_sidecar=require_sidecar)
    try:
        return np.load(path)
    except Exception as e:  # np.load raises a zoo of types on bad bytes
        raise CorruptArtifactError(
            path, f"unreadable npy ({type(e).__name__}: {e})") from e


def load_npz(path: str, require_sidecar: bool = False) -> dict:
    """Validated eager ``np.load`` of an ``.npz``: returns a plain dict
    of arrays (the lazy zip handle is closed before returning, so a
    later corruption of the file cannot surface mid-iteration)."""
    validate_file(path, require_sidecar=require_sidecar)
    try:
        with np.load(path) as data:
            return {k: np.asarray(data[k]) for k in data.files}
    except IntegrityError:
        raise
    except Exception as e:
        raise CorruptArtifactError(
            path, f"unreadable npz ({type(e).__name__}: {e})") from e


# ---------------------------------------------------------------------------
# Test / injection utilities
# ---------------------------------------------------------------------------

def flip_byte(path: str, offset: Optional[int] = None, seed: int = 0) -> int:
    """Deterministically corrupt one byte of an artifact (XOR 0xFF at
    ``offset``, or a seeded position).  The fault-injection harness and
    the acceptance tests use this to prove bit flips are REJECTED with
    a structured error, never loaded silently.  Returns the offset."""
    size = os.path.getsize(path)
    if size == 0:
        raise ValueError(f"cannot flip a byte of empty file {path!r}")
    if offset is None:
        offset = int(np.random.default_rng(seed).integers(0, size))
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ 0xFF]))
    return offset


def truncate_file(path: str, keep_bytes: int) -> None:
    """Simulate a torn write: keep only the first ``keep_bytes``."""
    with open(path, "r+b") as f:
        f.truncate(keep_bytes)


def list_unstamped(root: str) -> List[str]:
    """Files under ``root`` without a sidecar (debugging aid)."""
    out = []
    for dirpath, _, files in sorted(os.walk(root)):
        names = set(files)
        for fname in sorted(files):
            if fname.endswith(SIDECAR_SUFFIX):
                continue
            if fname + SIDECAR_SUFFIX not in names:
                out.append(os.path.join(dirpath, fname))
    return out


__all__ = [
    "IntegrityError", "MissingArtifactError", "CorruptArtifactError",
    "TornWriteError", "DEFAULT_ALGO", "SIDECAR_SUFFIX", "file_digest",
    "sidecar_path", "write_sidecar", "validate_file", "validate_tree",
    "atomic_write_json", "save_npy", "save_npz", "load_npy", "load_npz",
    "flip_byte", "truncate_file", "list_unstamped",
]
