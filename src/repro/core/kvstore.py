"""Host-process simulation of the paper's Figure-1 architecture.

On TPU the key-value store dissolves into the sharded array + ppermute ring
(DESIGN.md §2); this module keeps the original component structure —
Scheduler / Workers / distributed KV store — as explicit objects, for two
reasons: (i) it documents Algorithms 1–2 in their native form and is used
by an example; (ii) it is the checkpointable host representation of a
sharded model (each block is one KV entry, exactly how ``train/checkpoint``
persists LDA runs).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core import schedule as sched
from repro.core.invindex import build_inverted_index
from repro.core.sampler import gibbs_sweep_np
from repro.data.corpus import Corpus
from repro.data.sharding import worker_shard


class KVStore:
    """Distributed in-memory block store (a DHT in the paper; a dict here).

    Keys are block ids for ``C_k^t`` blocks plus the special key ``"ck"``
    for the non-separable topic totals (§3.3 special channel).
    """

    def __init__(self):
        self._blocks: Dict[int, np.ndarray] = {}
        self._ck: np.ndarray | None = None
        self.bytes_moved = 0

    # -- word-topic blocks (on-demand, §3.2) --
    def put_block(self, block_id: int, rows: np.ndarray) -> None:
        self.bytes_moved += rows.nbytes
        self._blocks[block_id] = rows.copy()

    def get_block(self, block_id: int) -> np.ndarray:
        rows = self._blocks[block_id]
        self.bytes_moved += rows.nbytes
        return rows.copy()

    # -- topic totals (per-round lazy sync, §3.3) --
    def put_ck_delta(self, delta: np.ndarray) -> None:
        self.bytes_moved += delta.nbytes
        self._ck = self._ck + delta

    def get_ck(self) -> np.ndarray:
        self.bytes_moved += self._ck.nbytes
        return self._ck.copy()

    def init_ck(self, ck: np.ndarray) -> None:
        self._ck = ck.astype(np.int64).copy()


@dataclasses.dataclass
class HostWorker:
    """Algorithm 2: request block -> Gibbs sweep -> commit block."""

    worker_id: int
    cdk: np.ndarray            # [D_local, K]
    index: object              # InvertedIndex
    z: np.ndarray              # [M, T] block-layout assignments

    def run_round(self, block_id: int, store: KVStore, partition,
                  alpha, beta, rng) -> None:
        ckt_block = store.get_block(block_id).astype(np.int32)
        ck_synced = store.get_ck().astype(np.int32)
        ck = ck_synced.copy()
        d = self.index.doc[block_id]
        off = self.index.word_off[block_id]
        msk = self.index.mask[block_id]
        n = int(msk.sum())
        if n:
            u = rng.random(n)
            z_new = gibbs_sweep_np(
                self.cdk, ckt_block, ck,
                d[:n], off[:n], self.z[block_id, :n], u, alpha, beta,
                use_eq3=True)
            self.z[block_id, :n] = z_new
        store.put_block(block_id, ckt_block)
        store.put_ck_delta((ck - ck_synced).astype(np.int64))


class HostModelParallelLDA:
    """Scheduler loop (Algorithm 1) driving host workers round-robin.

    Executes the model-parallel schedule *serially* with the exact same
    frozen-``C_k``-per-round semantics as the SPMD engine; used by tests as
    the structural reference and by ``examples/architecture_walkthrough``.
    """

    def __init__(self, corpus: Corpus, num_topics: int, num_workers: int,
                 alpha: float = 0.1, beta: float = 0.01, seed: int = 0):
        corpus.validate()
        self.corpus = corpus
        self.num_topics = num_topics
        self.num_workers = num_workers
        self.alpha = np.full(num_topics, alpha, np.float32)
        self.beta = float(beta)
        self.partition = sched.partition_vocab(corpus.vocab_size, num_workers)
        self.rng = np.random.default_rng(seed)
        self.store = KVStore()
        k = num_topics
        vb = self.partition.block_size
        z0 = self.rng.integers(0, k, size=corpus.num_tokens).astype(np.int32)
        ckt = np.zeros((num_workers, vb, k), np.int32)
        self.workers: List[HostWorker] = []
        for w in range(num_workers):
            s = worker_shard(corpus, w, num_workers)
            idx = build_inverted_index(s.doc_local, s.word, self.partition)
            cdk = np.zeros((s.num_local_docs, k), np.int32)
            zz = z0[s.token_id]
            np.add.at(cdk, (s.doc_local, zz), 1)
            blk = self.partition.block_of_word(s.word)
            off = self.partition.word_offset_in_block(s.word)
            np.add.at(ckt, (blk, off, zz), 1)
            zlay = np.zeros_like(idx.token_id)
            zlay[idx.mask] = zz[idx.token_id[idx.mask]]
            self.workers.append(HostWorker(w, cdk, idx, zlay))
        for b in range(num_workers):
            self.store.put_block(b, ckt[b])
        self.store.init_ck(ckt.sum(axis=(0, 1)))
        self.iteration_count = 0

    def step(self) -> None:
        m = self.num_workers
        for r in range(m):
            # scheduler: dispatch tasks, then rotate (Algorithm 1)
            for w in range(m):
                b = sched.block_for(w, r, m)
                self.workers[w].run_round(b, self.store, self.partition,
                                          self.alpha, self.beta, self.rng)
        self.iteration_count += 1

    def gather_ckt(self) -> np.ndarray:
        vb = self.partition.block_size
        out = np.zeros((self.partition.padded_vocab, self.num_topics),
                       np.int32)
        for b in range(self.num_workers):
            out[b * vb:(b + 1) * vb] = self.store.get_block(b)
        return out[:self.corpus.vocab_size]
