from repro.data.corpus import Corpus, load_corpus, save_corpus
from repro.data.synthetic import synthetic_corpus
from repro.data.sharding import shard_documents, worker_shard

__all__ = ["Corpus", "load_corpus", "save_corpus", "synthetic_corpus",
           "shard_documents", "worker_shard"]
