"""Unit tests for the model substrate: attention, SSD mixers, MoE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.attention import (_causal_window_mask, chunked_mha, mha)
from repro.models.common import KeyGen
from repro.models.moe import moe_layer, moe_layer_dense_ref, moe_params
from repro.models.ssd import (mamba_decode, mamba_init_state, mamba_mixer,
                              mamba_params, mlstm_decode, mlstm_init_state,
                              mlstm_mixer, mlstm_params, slstm_decode,
                              slstm_init_state, slstm_params, slstm_scan,
                              ssd_chunked, ssd_decode_step, ssd_ref)


# -- attention ---------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 5, 64, 1000])
@pytest.mark.parametrize("t", [64, 96, 256])
def test_chunked_equals_naive_attention(window, t):
    rng = np.random.default_rng(t + window)
    b, h, hd = 2, 3, 16
    q = jnp.asarray(rng.normal(size=(b, t, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, t, h, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, t, h, hd)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(t), (b, t)).astype(jnp.int32)
    mask = _causal_window_mask(pos, pos, jnp.int32(window))[:, None]
    out_naive = mha(q, k, v, mask)
    out_chunk = chunked_mha(q, k, v, pos, pos, jnp.int32(window),
                            q_chunk=32, k_chunk=32)
    np.testing.assert_allclose(np.asarray(out_naive), np.asarray(out_chunk),
                               rtol=2e-5, atol=2e-5)


def test_window_mask_properties():
    pos = jnp.arange(10)[None]
    m = np.asarray(_causal_window_mask(pos, pos, jnp.int32(3))[0])
    assert not m[2, 5]          # no future
    assert m[5, 5] and m[5, 3]  # self + within window
    assert not m[5, 2]          # outside window
    m_global = np.asarray(_causal_window_mask(pos, pos, jnp.int32(0))[0])
    assert m_global[9, 0]       # global causal sees everything behind


# -- SSD ----------------------------------------------------------------------

@given(st.integers(0, 1000), st.sampled_from([32, 64, 128]),
       st.sampled_from([16, 32]))
@settings(max_examples=8, deadline=None)
def test_ssd_chunked_matches_quadratic_ref(seed, t, chunk):
    rng = np.random.default_rng(seed)
    b, h, dk, dv = 2, 2, 8, 12
    q = jnp.asarray(rng.normal(size=(b, t, h, dk)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, t, h, dk)).astype(np.float32)) * 0.2
    v = jnp.asarray(rng.normal(size=(b, t, h, dv)).astype(np.float32))
    g = jnp.asarray(-rng.random((b, t, h)).astype(np.float32))
    out = ssd_chunked(q, k, v, g, chunk=chunk)
    ref = ssd_ref(q, k, v, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_decode_matches_ref():
    rng = np.random.default_rng(0)
    b, t, h, dk, dv = 1, 32, 2, 4, 6
    q = jnp.asarray(rng.normal(size=(b, t, h, dk)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, t, h, dk)).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.normal(size=(b, t, h, dv)).astype(np.float32))
    g = jnp.asarray(-rng.random((b, t, h)).astype(np.float32))
    ref = np.asarray(ssd_ref(q, k, v, g))
    state = jnp.zeros((b, h, dk, dv))
    for i in range(t):
        y, state = ssd_decode_step(state, q[:, i], k[:, i], v[:, i], g[:, i])
        np.testing.assert_allclose(np.asarray(y), ref[:, i], rtol=1e-4,
                                   atol=1e-4)


def _decode_vs_scan(mixer_full, mixer_step, state0, t):
    """Full-sequence mixer output must equal step-by-step decode."""
    full = np.asarray(mixer_full())
    state = state0
    for i in range(t):
        y, state = mixer_step(i, state)
        np.testing.assert_allclose(np.asarray(y)[:, 0], full[:, i],
                                   rtol=5e-3, atol=5e-3)


def test_mamba_decode_consistency():
    rng = np.random.default_rng(1)
    keys = KeyGen(0)
    b, t, d, h, hd, ds = 2, 16, 32, 4, 8, 8
    p = mamba_params(keys, d, h, hd, ds)
    x = jnp.asarray(rng.normal(size=(b, t, d)).astype(np.float32)) * 0.3
    _decode_vs_scan(
        lambda: mamba_mixer(p, x, h, hd, ds, chunk=8),
        lambda i, s: mamba_decode(p, s, x[:, i:i + 1], h, hd, ds),
        mamba_init_state(b, h, hd, ds), t)


def test_mlstm_decode_consistency():
    rng = np.random.default_rng(2)
    keys = KeyGen(0)
    b, t, d, h, hd = 2, 16, 32, 2, 16
    p = mlstm_params(keys, d, h, hd)
    x = jnp.asarray(rng.normal(size=(b, t, d)).astype(np.float32)) * 0.3
    _decode_vs_scan(
        lambda: mlstm_mixer(p, x, h, hd, chunk=8),
        lambda i, s: mlstm_decode(p, s, x[:, i:i + 1], h, hd),
        mlstm_init_state(b, h, hd), t)


def test_slstm_decode_consistency():
    rng = np.random.default_rng(3)
    keys = KeyGen(0)
    b, t, d = 2, 12, 24
    p = slstm_params(keys, d)
    x = jnp.asarray(rng.normal(size=(b, t, d)).astype(np.float32)) * 0.3
    _decode_vs_scan(
        lambda: slstm_scan(p, x),
        lambda i, s: slstm_decode(p, s, x[:, i:i + 1]),
        slstm_init_state(b, d), t)


# -- MoE -----------------------------------------------------------------------

@given(st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_moe_matches_dense_ref_with_ample_capacity(seed):
    keys = KeyGen(seed)
    p = moe_params(keys, 32, 64, 4, num_shared=1, shared_d_ff=64)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 8, 32)).astype(np.float32))
    y, aux = moe_layer(p, x, 4, 2, capacity_factor=4.0)
    y_ref = moe_layer_dense_ref(p, x, 4, 2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    assert float(aux) >= 0.99  # balance loss >= 1 at optimum (=E·1/E·1/E·E)


def test_moe_capacity_drops_tokens_gracefully():
    keys = KeyGen(0)
    p = moe_params(keys, 32, 64, 4)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, 32)).astype(np.float32))
    y_tight, _ = moe_layer(p, x, 4, 2, capacity_factor=0.25)
    assert np.isfinite(np.asarray(y_tight)).all()
    # dropped tokens produce zero expert output (residual passthrough lives
    # in the transformer block, not here)
    y_ample, _ = moe_layer(p, x, 4, 2, capacity_factor=8.0)
    assert np.abs(np.asarray(y_tight)).sum() < np.abs(np.asarray(y_ample)).sum()


def test_moe_grad_finite():
    keys = KeyGen(1)
    p = moe_params(keys, 32, 64, 4)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 8, 32)).astype(np.float32))
    g = jax.grad(lambda pp: moe_layer(pp, x, 4, 2)[0].sum())(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
