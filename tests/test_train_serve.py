"""Training substrate and serving-path tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve.serve_step import BatchedServer, generate
from repro.train.checkpoint import (checkpoint_step, load_checkpoint,
                                    save_checkpoint)
from repro.train.data_iter import synthetic_lm_stream
from repro.train.optimizer import AdamW, clip_by_global_norm, global_norm
from repro.train.train_step import make_train_step


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("olmo-1b").reduced()
    model = build_model(cfg)
    params = model.init(0)
    return cfg, model, params


def _batch(cfg, rng, b=4, t=32):
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)))}


def test_adamw_descends_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0, warmup_steps=0,
                total_steps=1000)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_grad_clipping():
    tree = {"a": jnp.ones((10,)) * 100.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4
    assert float(norm) > 100


def test_accumulation_matches_full_batch(tiny_model):
    """accum_steps=2 must produce (numerically) the same update as a full
    batch — the microbatch mean of grads equals the full-batch grad when
    every microbatch has equal token counts."""
    cfg, model, params = tiny_model
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng, b=4)
    opt = AdamW(learning_rate=1e-3)
    s1 = make_train_step(model, opt, accum_steps=1)
    s2 = make_train_step(model, opt, accum_steps=2)
    p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, opt.init(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=2e-4)


def test_loss_decreases_in_20_steps(tiny_model):
    cfg, model, params = tiny_model
    opt = AdamW(learning_rate=3e-3, warmup_steps=2, total_steps=40)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    stream = synthetic_lm_stream(cfg.vocab_size, 8, 32, seed=1)
    losses = []
    for _, batch in zip(range(20), stream):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::5]


def test_checkpoint_roundtrip(tiny_model):
    cfg, model, params = tiny_model
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        save_checkpoint(path, params, step=7)
        assert checkpoint_step(path) == 7
        restored = load_checkpoint(path, params)
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_lda_state():
    from repro.core.model_parallel import ModelParallelLDA
    from repro.data.synthetic import synthetic_corpus
    corpus, _, _ = synthetic_corpus(30, 80, 4, 20, seed=0)
    lda = ModelParallelLDA(corpus, 4, 2, seed=0)
    lda.step()
    state = lda.gather_counts()
    tree = {"ckt": state.ckt, "cdk": state.cdk, "ck": state.ck}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "lda")
        save_checkpoint(path, tree)
        back = load_checkpoint(path, tree)
        np.testing.assert_array_equal(np.asarray(back["ckt"]),
                                      np.asarray(state.ckt))


def test_generate_shapes_and_determinism(tiny_model):
    cfg, model, params = tiny_model
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 5)))
    out1 = generate(model, params, prompts, num_tokens=6)
    out2 = generate(model, params, prompts, num_tokens=6)
    assert out1.shape == (2, 11)
    np.testing.assert_array_equal(out1, out2)  # greedy = deterministic
    np.testing.assert_array_equal(out1[:, :5], np.asarray(prompts))


def test_batched_server_runs(tiny_model):
    cfg, model, params = tiny_model
    server = BatchedServer(model, params, batch_size=3, max_len=16)
    rng = np.random.default_rng(1)
    s = server.submit(list(rng.integers(0, cfg.vocab_size, 4)))
    assert s is not None
    done = {}
    for _ in range(20):
        done.update(server.tick())
    assert done, "request never finished"


def test_synthetic_stream_is_learnable_structure():
    stream = synthetic_lm_stream(64, 4, 16, seed=0, structure=1.0)
    batch = next(stream)
    toks, labels = batch["tokens"], batch["labels"]
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])
    # deterministic successor: same token -> same label everywhere
    flat_t, flat_l = toks.reshape(-1), labels.reshape(-1)
    mapping = {}
    for t, l in zip(flat_t, flat_l):
        assert mapping.setdefault(int(t), int(l)) == int(l)
