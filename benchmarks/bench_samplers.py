"""Sampler-backend tokens/sec trajectory: scan vs batched vs mh over K.

The repo's first throughput baseline (ISSUE 3): one synthetic block
workload per K ∈ {256, 1024, 4096}, each sampler timed on the identical
(counts, tokens, uniforms) inputs, tokens/sec recorded into
``benchmarks/results/bench_samplers.json``.

Expected shape of the curve (DESIGN.md §9 cost model):

* ``scan``    — O(K) per token AND serial over tokens: collapses as K
  grows (the exact baseline, not a contender);
* ``batched`` — O(K) per token, vectorized: the [T, K] mass + cumsum is
  roofline-bound, throughput ∝ 1/K;
* ``mh``      — O((Vb + D_loc)·K) alias build per block + O(1) per token:
  amortized per-token cost is flat in K, so it overtakes ``batched`` as
  K grows — fastest at K = 4096 is this benchmark's acceptance bar.

    PYTHONPATH=src python -m benchmarks.bench_samplers
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_csv_row, save_result
from repro.core.engine.rounds import resolve_sampler

K_SWEEP = (256, 1024, 4096)
SAMPLERS = ("scan", "batched", "mh")

# one block's workload: Vb word rows, D_loc local docs, T tokens.
# T/Vb = 256 mean postings per word — conservative for the big-corpus
# regime the alias amortization is built for (the paper's wiki-scale
# runs sit higher: tokens/(R·V) ≈ 470 postings per word-row at 3e9
# tokens, V = 1e5, a 64-worker ring), and honest across samplers since
# each is timed on the identical inputs
VB, DLOC, T = 64, 48, 16384


def _block_workload(k: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    doc = rng.integers(0, DLOC, T).astype(np.int32)
    woff = np.sort(rng.integers(0, VB, T)).astype(np.int32)
    z = rng.integers(0, k, T).astype(np.int32)
    cdk = np.zeros((DLOC, k), np.int32)
    ckt = np.zeros((VB, k), np.int32)
    np.add.at(cdk, (doc, z), 1)
    np.add.at(ckt, (woff, z), 1)
    u = rng.random(T, np.float32)
    return (jnp.asarray(cdk), jnp.asarray(ckt),
            jnp.asarray(ckt.sum(0).astype(np.int32)),
            jnp.asarray(doc), jnp.asarray(woff), jnp.asarray(z),
            jnp.ones(T, bool), jnp.asarray(u),
            jnp.full(k, 0.1, jnp.float32), jnp.float32(0.01),
            jnp.float32(0.01 * VB))


def _time_sampler(fn, args, repeats: int) -> float:
    """Median seconds per call, outputs blocked on."""
    jax.block_until_ready(fn(*args))          # compile + warm
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def run(seed: int = 0) -> dict:
    out = {"workload": {"vb": VB, "dloc": DLOC, "tokens": T},
           "k_sweep": list(K_SWEEP), "results": {}}
    for k in K_SWEEP:
        args = _block_workload(k, seed)
        rec = {}
        for mode in SAMPLERS:
            fn = resolve_sampler(mode)
            repeats = 2 if mode == "scan" else 5
            sec = _time_sampler(fn, args, repeats)
            rec[mode] = {"sec_per_block": sec, "tokens_per_s": T / sec}
            emit_csv_row(f"sampler_{mode}_k{k}", sec * 1e6,
                         f"tokens_per_s={T / sec:.0f}")
        fastest = max(SAMPLERS, key=lambda m: rec[m]["tokens_per_s"])
        rec["fastest"] = fastest
        out["results"][str(k)] = rec
    out["mh_fastest_at_k4096"] = \
        out["results"]["4096"]["fastest"] == "mh"
    save_result("bench_samplers", out)
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    res = run()
    for k in K_SWEEP:
        r = res["results"][str(k)]
        print(f"K={k}: fastest={r['fastest']} "
              + " ".join(f"{m}={r[m]['tokens_per_s']:.0f}tok/s"
                         for m in SAMPLERS))
