"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L, d_model 2048, 16 heads (MHA: kv=16), expert d_ff 1408, vocab 151936;
60 routed experts top-4 plus 4 shared experts (shared FFN 4×1408 = 5632)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    head_dim=128,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    num_experts=60,
    num_experts_per_tok=4,
    num_shared_experts=4,
    norm="rms",
    tie_embeddings=False,
    subquadratic_decode=False,
)
