"""Sampler correctness: eq(1) ≡ eq(3) ≡ Sparse-LDA eq(2); JAX scan vs
numpy oracle; invariant preservation; masked-token no-ops.

Only the ``@given`` property tests need hypothesis; the deterministic
tests run everywhere (previously the module-level importorskip silently
skipped ALL of them on hypothesis-less hosts)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_property_tests_need_hypothesis():
        """Visible sentinel: the @given tests in this module were not
        collected because hypothesis is absent."""

from repro.core.counts import build_counts, check_invariants
from repro.core.sampler import (conditional_eq1, conditional_eq3,
                                gibbs_sweep_np, sample_from_mass,
                                sweep_block_batched, sweep_block_scan)
from repro.core.sparse import (bucket_masses, cache_recompute_count,
                               sparse_gibbs_sweep_np,
                               sparse_gibbs_sweep_np_reference)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**31 - 1), st.integers(2, 40))
    @settings(max_examples=30, deadline=None)
    def test_eq1_eq3_identical(seed, k):
        """Paper eq. (3) is an algebraic refactoring of eq. (1)."""
        rng = np.random.default_rng(seed)
        ckt = rng.integers(0, 100, k).astype(np.float32)
        cdk = rng.integers(0, 20, k).astype(np.float32)
        ck = ckt + rng.integers(0, 1000, k).astype(np.float32)
        alpha = rng.random(k).astype(np.float32) + 0.01
        beta, vbeta = np.float32(0.01), np.float32(0.01 * 50)
        p1 = np.asarray(conditional_eq1(ckt, cdk, ck, alpha, beta, vbeta))
        p3 = np.asarray(conditional_eq3(ckt, cdk, ck, alpha, beta, vbeta))
        np.testing.assert_allclose(p1, p3, rtol=1e-5)

    @given(st.integers(0, 2**31 - 1), st.integers(2, 40))
    @settings(max_examples=30, deadline=None)
    def test_eq2_buckets_sum_to_eq1(seed, k):
        """Sparse-LDA's A+B+C buckets (eq. 2) carry the same total mass."""
        rng = np.random.default_rng(seed)
        ckt = rng.integers(0, 100, k).astype(np.float64)
        cdk = rng.integers(0, 20, k).astype(np.float64)
        ck = ckt + rng.integers(0, 1000, k).astype(np.float64)
        alpha = rng.random(k) + 0.01
        beta, vbeta = 0.01, 0.5
        a, b, c = bucket_masses(ckt, cdk, ck, alpha, beta, vbeta)
        p1 = np.asarray(conditional_eq1(ckt, cdk, ck, alpha, beta, vbeta))
        np.testing.assert_allclose(a + b + c, p1, rtol=1e-10)


def _random_state(rng, n=300, d=15, v=25, k=6):
    doc = rng.integers(0, d, n).astype(np.int32)
    word = rng.integers(0, v, n).astype(np.int32)
    z = rng.integers(0, k, n).astype(np.int32)
    state = build_counts(doc, word, z, d, v, k)
    return (doc, word, z, np.array(state.cdk), np.array(state.ckt),
            np.array(state.ck))


def test_numpy_sweep_eq1_vs_eq3_identical_draws():
    """Same uniforms -> identical trajectories for the two factorizations."""
    rng = np.random.default_rng(3)
    doc, word, z, cdk, ckt, ck = _random_state(rng)
    u = rng.random(doc.shape[0])
    alpha = np.full(6, 0.1, np.float32)
    z1 = gibbs_sweep_np(cdk.copy(), ckt.copy(), ck.copy(), doc, word, z,
                        u, alpha, 0.01, use_eq3=False)
    z3 = gibbs_sweep_np(cdk.copy(), ckt.copy(), ck.copy(), doc, word, z,
                        u, alpha, 0.01, use_eq3=True)
    np.testing.assert_array_equal(z1, z3)


def test_numpy_vs_sparse_sweep_identical_draws():
    """The bucket-walk sampler draws the same topics as direct inverse-CDF
    when buckets are visited in C, B, A order of the same CDF mass."""
    rng = np.random.default_rng(4)
    doc, word, z, cdk, ckt, ck = _random_state(rng)
    u = rng.random(doc.shape[0])
    alpha = np.full(6, 0.1, np.float64)
    z_sparse = sparse_gibbs_sweep_np(cdk.copy(), ckt.copy(), ck.copy(),
                                     doc, word, z, u, alpha, 0.01)
    # the draws define the same distribution; counts must stay conserved
    state = build_counts(doc, word, z_sparse, 15, 25, 6)
    check_invariants(state, doc.shape[0])


@pytest.mark.parametrize("seed,ordering", [(4, "natural"), (11, "natural"),
                                           (7, "word_major"),
                                           (9, "shuffled")])
def test_sparse_incremental_matches_reference_bitwise(seed, ordering):
    """The incremental A/B cache sweep is bit-for-bit the per-token
    full-rebuild reference: same draws, same mutated counts — including
    under visit orders that thrash the per-doc cache (word-major,
    shuffled) and adversarial u -> 1.0 clamp uniforms."""
    rng = np.random.default_rng(seed)
    doc, word, z, cdk, ckt, ck = _random_state(rng)
    n = doc.shape[0]
    u = rng.random(n)
    u[:: n // 7] = 1.0                       # exercise the clamp paths
    u[1:: n // 5] = np.nextafter(1.0, 0.0)
    alpha = rng.random(6) + 0.01
    if ordering == "natural":
        order = None
    elif ordering == "word_major":
        order = np.lexsort((doc, word))
    else:
        order = rng.permutation(n)
    cdk_i, ckt_i, ck_i = cdk.copy(), ckt.copy(), ck.copy()
    cdk_r, ckt_r, ck_r = cdk.copy(), ckt.copy(), ck.copy()
    z_inc = sparse_gibbs_sweep_np(cdk_i, ckt_i, ck_i, doc, word, z, u,
                                  alpha, 0.01, order=order)
    z_ref = sparse_gibbs_sweep_np_reference(cdk_r, ckt_r, ck_r, doc, word,
                                            z, u, alpha, 0.01, order=order)
    np.testing.assert_array_equal(z_inc, z_ref)
    np.testing.assert_array_equal(cdk_i, cdk_r)
    np.testing.assert_array_equal(ckt_i, ckt_r)
    np.testing.assert_array_equal(ck_i, ck_r)


def test_scan_sweep_matches_numpy_oracle():
    """JAX lax.scan sweep == numpy oracle, same uniforms, same order."""
    rng = np.random.default_rng(5)
    doc, word, z, cdk, ckt, ck = _random_state(rng)
    n = doc.shape[0]
    u = rng.random(n).astype(np.float32)
    alpha = np.full(6, 0.1, np.float32)
    vbeta = np.float32(0.01 * 25)
    z_np = gibbs_sweep_np(cdk.copy(), ckt.copy(), ck.copy(), doc, word, z,
                          u, alpha, 0.01, use_eq3=True)
    cdk_j, ckt_j, ck_j, z_j = sweep_block_scan(
        jnp.asarray(cdk), jnp.asarray(ckt), jnp.asarray(ck),
        jnp.asarray(doc), jnp.asarray(word), jnp.asarray(z),
        jnp.ones(n, bool), jnp.asarray(u),
        jnp.asarray(alpha), jnp.float32(0.01), vbeta)
    assert (np.asarray(z_j) == z_np).mean() > 0.995  # float-order tolerance
    state = build_counts(doc, word, np.asarray(z_j), 15, 25, 6)
    check_invariants(state, n)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_scan_sweep_preserves_invariants(seed):
        rng = np.random.default_rng(seed)
        doc, word, z, cdk, ckt, ck = _random_state(rng, n=200)
        n = doc.shape[0]
        u = rng.random(n).astype(np.float32)
        alpha = jnp.full(6, 0.1, jnp.float32)
        out = sweep_block_scan(
            jnp.asarray(cdk), jnp.asarray(ckt), jnp.asarray(ck),
            jnp.asarray(doc), jnp.asarray(word), jnp.asarray(z),
            jnp.ones(n, bool), jnp.asarray(u), alpha,
            jnp.float32(0.01), jnp.float32(0.25))
        state = build_counts(doc, word, np.asarray(out[3]), 15, 25, 6)
        check_invariants(state, n)
        np.testing.assert_array_equal(np.asarray(out[0]),
                                      np.asarray(state.cdk))
        np.testing.assert_array_equal(np.asarray(out[1]),
                                      np.asarray(state.ckt))


def test_masked_tokens_are_noops():
    rng = np.random.default_rng(6)
    doc, word, z, cdk, ckt, ck = _random_state(rng, n=100)
    n = doc.shape[0]
    u = rng.random(n).astype(np.float32)
    alpha = jnp.full(6, 0.1, jnp.float32)
    mask = np.zeros(n, bool)  # everything masked
    out = sweep_block_scan(
        jnp.asarray(cdk), jnp.asarray(ckt), jnp.asarray(ck),
        jnp.asarray(doc), jnp.asarray(word), jnp.asarray(z),
        jnp.asarray(mask), jnp.asarray(u), alpha,
        jnp.float32(0.01), jnp.float32(0.25))
    np.testing.assert_array_equal(np.asarray(out[0]), cdk)
    np.testing.assert_array_equal(np.asarray(out[1]), ckt)
    np.testing.assert_array_equal(np.asarray(out[3]), z)


def test_batched_sweep_preserves_invariants():
    rng = np.random.default_rng(7)
    doc, word, z, cdk, ckt, ck = _random_state(rng, n=250)
    n = doc.shape[0]
    u = rng.random(n).astype(np.float32)
    alpha = jnp.full(6, 0.1, jnp.float32)
    out = sweep_block_batched(
        jnp.asarray(cdk), jnp.asarray(ckt), jnp.asarray(ck),
        jnp.asarray(doc), jnp.asarray(word), jnp.asarray(z),
        jnp.ones(n, bool), jnp.asarray(u), alpha,
        jnp.float32(0.01), jnp.float32(0.25), None)
    state = build_counts(doc, word, np.asarray(out[3]), 15, 25, 6)
    check_invariants(state, n)


def test_sample_from_mass_edge_cases():
    """Regression: ``u == 1.0`` made ``csum > u*csum[-1]`` all-False and
    argmax silently returned topic 0; same for an all-zero mass row."""
    p = jnp.asarray(np.array([0.2, 0.5, 0.3, 0.0], np.float32))
    # interior draws unchanged
    assert int(sample_from_mass(p, jnp.float32(0.0))) == 0
    assert int(sample_from_mass(p, jnp.float32(0.3))) == 1
    assert int(sample_from_mass(p, jnp.float32(0.9))) == 2
    # u == 1.0: clamp to the LAST positive-mass topic, not topic 0
    assert int(sample_from_mass(p, jnp.float32(1.0))) == 2
    # all-zero mass row: in-range, deterministic
    z = jnp.zeros(4, jnp.float32)
    for u in (0.0, 0.5, 1.0):
        assert 0 <= int(sample_from_mass(z, jnp.float32(u))) < 4


def test_batched_draw_edge_cases():
    """The batched argmax draw has the same edges: u == 1.0 rows and
    zero-mass rows (β = 0 with an unseen word) stay in-range and hit the
    last positive-mass topic, not topic 0."""
    k = 4
    # one word with mass only on topics {1, 2}; beta=0 so an unseen word
    # (row 1) has an all-zero conditional
    ckt = np.array([[0, 3, 2, 0], [0, 0, 0, 0]], np.int32)
    cdk = np.array([[1, 2, 2, 1]], np.int32)
    doc = np.zeros(3, np.int32)
    woff = np.array([0, 0, 1], np.int32)
    z = np.array([1, 2, 1], np.int32)
    ck = ckt.sum(0).astype(np.int32) + 10
    u = np.array([1.0, 1.0, 1.0], np.float32)
    out = sweep_block_batched(
        jnp.asarray(cdk), jnp.asarray(ckt), jnp.asarray(ck),
        jnp.asarray(doc), jnp.asarray(woff), jnp.asarray(z),
        jnp.ones(3, bool), jnp.asarray(u),
        jnp.full(k, 0.1, jnp.float32), jnp.float32(0.0), jnp.float32(0.0),
        None)
    z_new = np.asarray(out[3])
    assert ((z_new >= 0) & (z_new < k)).all()
    # u == 1.0 on a positive-mass row: the last topic with mass, never 0
    assert z_new[0] != 0 and z_new[1] != 0


def test_numpy_sweep_u_equals_one_in_range():
    rng = np.random.default_rng(11)
    doc, word, z, cdk, ckt, ck = _random_state(rng, n=50)
    u = np.ones(50)                      # every draw at the edge
    alpha = np.full(6, 0.1, np.float32)
    z_new = gibbs_sweep_np(cdk.copy(), ckt.copy(), ck.copy(), doc, word, z,
                           u, alpha, 0.01, use_eq3=True)
    assert ((z_new >= 0) & (z_new < 6)).all()
    state = build_counts(doc, word, z_new, 15, 25, 6)
    check_invariants(state, 50)


def test_cache_recompute_motivation():
    """§4.2: doc-major order reuses the Sparse-LDA cache; word-major
    (inverted index) order thrashes it — the reason eq (3) exists."""
    rng = np.random.default_rng(8)
    doc = rng.integers(0, 20, 2000)
    word = rng.integers(0, 500, 2000)
    doc_major = cache_recompute_count(doc, word, order_doc_major=True)
    word_major = cache_recompute_count(doc, word, order_doc_major=False)
    assert doc_major <= 20
    assert word_major > 10 * doc_major


def test_sparse_bucket_overflow_clamped():
    """Regression: the C/B bucket draws used an unclamped searchsorted.

    The bucket test compares ``x`` against ``sc = c.sum()`` (numpy's
    PAIRWISE summation) while the inverse-CDF walks the SEQUENTIAL
    ``cumsum(c[nz])``; with u -> 1.0 the two roundings leave ``x`` in
    ``[cs[-1], sc)`` and the pre-fix ``nz[searchsorted(...)]`` indexed
    one past the end of ``nz`` (IndexError).  This state + uniform are a
    found instance of exactly that gap; the fixed sweep must clamp to
    the last positive-count topic like the dense bucket does.
    """
    seed, u_adv = 4, 0.9999977241694266
    rng = np.random.default_rng(seed)
    k, v = 24, 50
    ckt_row = rng.integers(0, 2000, k)
    ckt_row[rng.random(k) < 0.3] = 0
    cdk_row = rng.integers(0, 6, k)
    cdk_row[rng.random(k) < 0.5] = 0
    ck = ckt_row + rng.integers(0, 3000, k)
    alpha = np.full(k, 1e-4)
    beta = 1e-3
    vbeta = beta * v

    # embed the rows in a 1-token state whose POST-decrement counts are
    # exactly the searched rows (the sweep removes the token first)
    j = int(np.nonzero((cdk_row > 0) & (ckt_row > 0))[0][0])
    cdk = cdk_row[None, :].astype(np.int64).copy()
    cdk[0, j] += 1
    ckt = np.zeros((v, k), np.int64)
    ckt[0] = ckt_row
    ckt[0, j] += 1
    ck_full = ck.astype(np.int64).copy()
    ck_full[j] += 1

    # prove this instance hits the pre-fix out-of-bounds condition
    a, b, c = bucket_masses(ckt_row.astype(np.float64),
                            cdk_row.astype(np.float64),
                            ck.astype(np.float64), alpha, beta, vbeta)
    x = u_adv * (a.sum() + b.sum() + c.sum())
    nz = np.nonzero(ckt_row)[0]
    cs = np.cumsum(c[nz])
    assert x < c.sum(), "instance must land in the C bucket"
    assert np.searchsorted(cs, x, side="right") == len(nz), \
        "instance must overflow the unclamped draw"

    z_new = sparse_gibbs_sweep_np(cdk, ckt, ck_full, np.array([0]),
                                  np.array([0]), np.array([j], np.int32),
                                  np.array([u_adv]), alpha, beta)
    assert z_new[0] == nz[-1]      # clamped like the dense bucket


@pytest.mark.parametrize("u_val", [1.0, float(np.nextafter(1.0, 0.0))])
def test_sparse_sweep_adversarial_uniforms(u_val):
    """Whole sweeps with every uniform pinned to the u -> 1.0 edge (both
    exactly 1.0 and its predecessor) stay in range and conserve counts,
    for a well-mixed state and for the sparse extremes (single-token
    docs + near-zero alpha, where the A/B buckets carry ~no mass)."""
    rng = np.random.default_rng(12)
    doc, word, z, cdk, ckt, ck = _random_state(rng, n=200)
    u = np.full(200, u_val)
    z_new = sparse_gibbs_sweep_np(cdk, ckt, ck, doc, word, z, u,
                                  np.full(6, 0.1, np.float64), 0.01)
    assert ((z_new >= 0) & (z_new < 6)).all()
    state = build_counts(doc, word, z_new, 15, 25, 6)
    check_invariants(state, 200)

    # sparse extreme: every doc holds ONE token (B bucket empties after
    # the decrement) and alpha ~ 0 starves the dense bucket
    n, k = 40, 8
    doc = np.arange(n, dtype=np.int32)
    word = rng.integers(0, 10, n).astype(np.int32)
    z = rng.integers(0, k, n).astype(np.int32)
    state = build_counts(doc, word, z, n, 10, k)
    cdk2, ckt2, ck2 = (np.array(state.cdk, np.int64),
                       np.array(state.ckt, np.int64),
                       np.array(state.ck, np.int64))
    z_new = sparse_gibbs_sweep_np(cdk2, ckt2, ck2, doc, word, z,
                                  np.full(n, u_val),
                                  np.full(k, 1e-9, np.float64), 1e-6)
    assert ((z_new >= 0) & (z_new < k)).all()
    state = build_counts(doc, word, z_new, n, 10, k)
    check_invariants(state, n)
