"""Engine state: per-worker slot queues + construction and gathering.

State layout (DESIGN.md §3, §8).  With ``M`` model workers and ``S``
blocks per worker the vocabulary is split into ``B = S·M`` blocks; each
worker keeps a length-``S`` FIFO of ``[Vb, K]`` word-topic blocks.  Slot 0
is the *resident* block — the only one touched by compute and the only one
that travels in the per-round rotation; slots ``1..S-1`` are *parked*
(they model the paper's distributed key-value store / host offload, where
non-resident blocks live outside worker RAM).

Nothing in this layout is sampler-specific: the alias tables of the
``mh`` backend (DESIGN.md §9) are derived state — built inside the
sampler at round start under ``table_lifetime="round"``, or built and
rotated by the backends as iteration-local payloads under the
traveling-table schedule (DESIGN.md §10, where every table a round
reads was built earlier in the SAME iteration) — so the pytree carries
no table arrays and checkpoints are sampler-agnostic either way.

Hybrid data×model parallelism (DESIGN.md §8) adds ``D`` data replicas:
every per-worker array keeps ONE leading axis of length ``R = D·M``
(row ``g = d·M + m``, data-major), so at ``D = 1`` shapes are bit-for-bit
those of the original 1D engine.  Documents are sharded ``R`` ways; the
block queues are REPLICATED along data (replica ``d``'s row ``d·M + m``
holds the same blocks as row ``m``) and reconciled by a per-round delta
psum on the data axis.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schedule as sched
from repro.core.counts import CountState
from repro.core.invindex import (InvertedIndex, build_inverted_index,
                                 common_block_capacity, scatter_assignments)
from repro.data.corpus import Corpus
from repro.data.sharding import WorkerShard, grid_shard


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MPState:
    """Stacked per-worker state (leading axis = the ``R = D·M`` grid rows,
    data-major; ``R == M`` when ``data_parallel == 1``)."""

    cdk: jax.Array        # [R, Dloc, K]
    ckt: jax.Array        # [R, S, Vb, K] slot queue; slot 0 = resident
    block_id: jax.Array   # [R, S] which block sits in each slot
    ck_synced: jax.Array  # [K] totals agreed at last round boundary
    ck_local: jax.Array   # [R, K] per-worker drifting view (§3.3)
    z: jax.Array          # [R, B, T] assignments in inverted-index layout

    def tree_flatten(self):
        return ((self.cdk, self.ckt, self.block_id, self.ck_synced,
                 self.ck_local, self.z), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- shape views -------------------------------------------------------
    @property
    def num_shards(self) -> int:
        """Grid rows ``R = D·M`` (== ``M`` for the 1D engine)."""
        return self.ckt.shape[0]

    @property
    def num_workers(self) -> int:
        return self.ckt.shape[0]

    @property
    def blocks_per_worker(self) -> int:
        return self.ckt.shape[1]

    @property
    def resident_ckt(self) -> jax.Array:
        """[R, Vb, K] — the block each worker is actively sampling."""
        return self.ckt[:, 0]

    @property
    def resident_block(self) -> jax.Array:
        """[R] — id of each worker's resident block."""
        return self.block_id[:, 0]

    def local_ck_views(self) -> np.ndarray:
        return np.asarray(self.ck_local)

    def true_ck(self) -> np.ndarray:
        return np.asarray(self.ck_synced) + (
            np.asarray(self.ck_local)
            - np.asarray(self.ck_synced)[None, :]).sum(axis=0)


@dataclasses.dataclass
class EngineLayout:
    """Static (non-pytree) engine geometry: shards, indexes, partition.

    Built once per ``(corpus, M, S)``; everything here is host-side numpy
    plus the device-resident token-layout arrays shared by every round.
    """

    corpus: Corpus
    num_workers: int
    blocks_per_worker: int
    data_parallel: int
    partition: sched.VocabPartition
    shards: List[WorkerShard]
    indexes: List[InvertedIndex]
    capacity: int
    doc: jax.Array    # [R, B, T] int32
    woff: jax.Array   # [R, B, T] int32
    mask: jax.Array   # [R, B, T] bool

    @property
    def num_blocks(self) -> int:
        return self.partition.num_blocks

    @property
    def num_shards(self) -> int:
        """Worker-grid rows ``R = D·M`` — leading axis of every array."""
        return self.data_parallel * self.num_workers

    @property
    def num_rounds(self) -> int:
        """Rounds per iteration — every (worker, block) pair meets once."""
        return self.num_blocks

    @property
    def resident_block_rows(self) -> int:
        """Rows of the resident ``ckt`` block: ``ceil(V / (S·M))``."""
        return self.partition.block_size


def build_layout(corpus: Corpus, num_workers: int,
                 blocks_per_worker: int = 1,
                 data_parallel: int = 1) -> EngineLayout:
    """Shard documents ``R = D·M`` ways, partition the vocabulary into
    ``B = S·M`` blocks (shared across data replicas), and build each grid
    cell's per-block inverted index with a common capacity."""
    num_blocks = num_workers * blocks_per_worker
    partition = sched.partition_vocab(corpus.vocab_size, num_blocks)
    sched.validate_schedule_2d(data_parallel, num_workers, blocks_per_worker)
    shards = [grid_shard(corpus, d, m, data_parallel, num_workers)
              for d in range(data_parallel) for m in range(num_workers)]
    cap = common_block_capacity((s.word for s in shards), partition)
    indexes = [build_inverted_index(s.doc_local, s.word, partition, cap)
               for s in shards]
    doc = np.stack([i.doc for i in indexes])
    woff = np.stack([i.word_off for i in indexes])
    mask = np.stack([i.mask for i in indexes])
    return EngineLayout(
        corpus=corpus, num_workers=num_workers,
        blocks_per_worker=blocks_per_worker, data_parallel=data_parallel,
        partition=partition,
        shards=shards, indexes=indexes, capacity=cap,
        doc=jnp.asarray(doc), woff=jnp.asarray(woff),
        mask=jnp.asarray(mask))


def init_state(layout: EngineLayout, num_topics: int,
               z0: np.ndarray) -> MPState:
    """Build the initial :class:`MPState` from token-order assignments.

    Slot-major placement: block ``b = s·M + m`` starts in slot ``s`` of
    worker ``m`` (``schedule.home_slot``), so at ``S = 1`` worker ``m``
    opens holding block ``m`` exactly as the original engine did.  With
    ``D > 1`` data replicas the block queues of the ``M`` model positions
    are tiled along data: grid row ``d·M + m`` opens with the same queue
    as row ``m`` (replicated model, DESIGN.md §8).
    """
    m, s_ = layout.num_workers, layout.blocks_per_worker
    d_, r_ = layout.data_parallel, layout.num_shards
    b, k = layout.num_blocks, num_topics
    part, cap = layout.partition, layout.capacity
    vb = part.block_size
    dloc = layout.shards[0].num_local_docs

    cdk = np.zeros((r_, dloc, k), np.int32)
    ckt_blocks = np.zeros((b, vb, k), np.int32)
    zarr = np.zeros((r_, b, cap), np.int32)
    for g, (shard, idx) in enumerate(zip(layout.shards, layout.indexes)):
        zz = z0[shard.token_id]
        np.add.at(cdk[g], (shard.doc_local, zz), 1)
        blk = part.block_of_word(shard.word)
        off = part.word_offset_in_block(shard.word)
        np.add.at(ckt_blocks, (blk, off, zz), 1)
        real = idx.mask
        zarr[g][real] = zz[idx.token_id[real]]
    ck = ckt_blocks.sum(axis=(0, 1)).astype(np.int32)

    # [B, Vb, K] -> [M, S, Vb, K]: block s·M + m into (worker m, slot s);
    # then tile the queues along the data axis -> [R = D·M, S, Vb, K]
    slots = ckt_blocks.reshape(s_, m, vb, k).swapaxes(0, 1)
    slots = np.broadcast_to(slots[None], (d_, m, s_, vb, k)) \
        .reshape(r_, s_, vb, k)
    block_id = (np.arange(s_)[None, :] * m
                + np.arange(m)[:, None]).astype(np.int32)
    block_id = np.broadcast_to(block_id[None], (d_, m, s_)) \
        .reshape(r_, s_)
    return MPState(
        cdk=jnp.asarray(cdk),
        ckt=jnp.asarray(np.ascontiguousarray(slots)),
        block_id=jnp.asarray(np.ascontiguousarray(block_id)),
        ck_synced=jnp.asarray(ck),
        ck_local=jnp.broadcast_to(jnp.asarray(ck), (r_, k)),
        z=jnp.asarray(zarr),
    )


def gather_counts(layout: EngineLayout, state: MPState,
                  num_topics: int) -> CountState:
    """Reassemble the global model (the KV-store "dump").

    Only replica 0's queues are read for ``C_k^t``: at iteration (and
    round) boundaries every replica's copy of a block is identical — the
    per-round delta psum reconciles them — so any replica is the model.
    """
    s_ = layout.blocks_per_worker
    vb = layout.partition.block_size
    v, k = layout.corpus.vocab_size, num_topics
    ckt_full = np.zeros((layout.num_blocks * vb, k), np.int32)
    blocks = np.asarray(state.block_id)
    ckt = np.asarray(state.ckt)
    for w in range(layout.num_workers):       # replica 0 rows: g = m
        for s in range(s_):
            blk = int(blocks[w, s])
            ckt_full[blk * vb:(blk + 1) * vb] = ckt[w, s]
    ckt_full = ckt_full[:v]
    cdk_full = np.zeros((layout.corpus.num_docs, k), np.int32)
    cdk = np.asarray(state.cdk)
    for w, shard in enumerate(layout.shards):
        real = shard.doc_global >= 0
        cdk_full[shard.doc_global[real]] = cdk[w][:real.sum()]
    ck = ckt_full.sum(axis=0).astype(np.int32)
    return CountState(jnp.asarray(cdk_full), jnp.asarray(ckt_full),
                      jnp.asarray(ck))


def gather_assignments(layout: EngineLayout, state: MPState) -> np.ndarray:
    """Current z in original token order."""
    z = np.zeros(layout.corpus.num_tokens, np.int32)
    zs = np.asarray(state.z)
    for w, (shard, idx) in enumerate(zip(layout.shards, layout.indexes)):
        z_local = scatter_assignments(idx, zs[w], shard.token_id.shape[0])
        z[shard.token_id] = z_local
    return z


# ---------------------------------------------------------------------------
# CountStore bridging (DESIGN.md §16)
# ---------------------------------------------------------------------------
# The device chain keeps MPState.ckt dense — jit/donation/ppermute need
# static shapes — so the CountStore boundary for the in-memory engine is
# AT REST: these helpers encode/decode the [R, S, Vb, K] slot queue as a
# flat list of per-slot store records for checkpoints (and any future
# host-side parking of non-resident slots).

def ckt_to_stores(ckt: np.ndarray, kind: str, wcap: int) -> list:
    """Encode every ``(r, s)`` slot of the queue as a CountStore of
    ``kind`` (exact integer round-trip)."""
    from repro.core.engine import countstore
    r, s, vb, k = ckt.shape
    cls = countstore.resolve_store(kind)
    return [cls.from_dense(ckt[i, j], wcap=wcap)
            for i in range(r) for j in range(s)]


def ckt_from_stores(stores: list, r: int, s: int) -> np.ndarray:
    """Inverse of :func:`ckt_to_stores`: rebuild the dense slot queue."""
    if len(stores) != r * s:
        raise ValueError(
            f"expected {r * s} store records, got {len(stores)}")
    vb, k = stores[0].shape
    out = np.zeros((r, s, vb, k), np.int32)
    for i in range(r):
        for j in range(s):
            out[i, j] = stores[i * s + j].to_dense()
    return out
