"""LDA training driver — the paper's own workload, end to end.

    PYTHONPATH=src python -m repro.launch.lda_train --docs 500 --vocab 2000 \
        --topics 50 --workers 8 --iters 30

Selects the model-parallel engine by default; ``--data-parallel D`` turns
it into the hybrid 2D (data × model) grid of DESIGN.md §8; ``--engine dp``
runs the Yahoo!LDA-style data-parallel baseline for comparison.

Out-of-core training (DESIGN.md §13): ``--corpus-dir`` points at a
sharded on-disk corpus (`python -m repro.data.stream`) and switches to
the streaming engine — memory bounded by one resident ``[Vb, K]`` block,
never the corpus or the full model.  ``--workdir`` holds the run's
persistent state; ``--checkpoint-every N`` snapshots it every N
iterations and ``--resume`` continues a killed run bit-exactly (the same
two flags also checkpoint/resume the in-memory mp engine, via
``ModelParallelLDA.save_checkpoint``/``resume``).  ``--snapshot-dir``
exports the final model as a sharded serving snapshot (one block file at
a time) that ``lda_infer --snapshot-dir`` serves row-restricted.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core.data_parallel import DataParallelLDA
from repro.core.infer import ModelSnapshot
from repro.core.likelihood import doc_completion_perplexity
from repro.core.metrics import topic_recovery_score
from repro.core.model_parallel import ModelParallelLDA
from repro.data.corpus import split_corpus
from repro.data.synthetic import synthetic_corpus
from repro.launch.samplers import (infer_sampler_choices,
                                   resolve_sampler_choice,
                                   resolve_store_choice, store_choices,
                                   train_sampler_choices)
from repro.train.checkpoint import save_checkpoint


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=["mp", "dp"], default="mp")
    ap.add_argument("--sampler", choices=train_sampler_choices(),
                    default="scan",
                    help="per-block sampler from the engine registry: "
                         "exact scan, word-frozen batched/pallas, O(1) "
                         "alias-table MH, or the hybrid sparse family "
                         "(DESIGN.md §9, §12); 'auto' picks the family "
                         "from the measured (K, doc-len) regime map and "
                         "the Pallas form of it on TPU")
    ap.add_argument("--force", action="store_true",
                    help="run an explicitly requested *_pallas sampler "
                         "in interpret mode off-TPU instead of refusing")
    ap.add_argument("--store", choices=store_choices(), default=None,
                    help="model CountStore layout (DESIGN.md §16): "
                         "'dense' keeps raw [Vb, K] blocks (default), "
                         "'tail' the hybrid dense-head/sparse-tail "
                         "record whose resident bytes track occupancy "
                         "instead of V*K; 'auto' picks tail exactly "
                         "where the regime map picks the sparse sampler "
                         "family. Draw-identical either way; on "
                         "--resume, keeps the run's store unless given")
    ap.add_argument("--table-lifetime",
                    choices=["auto", "round", "iteration"], default="auto",
                    help="MH proposal-table build schedule (DESIGN.md "
                         "§10): 'iteration' = traveling tables built once "
                         "per iteration (MH default), 'round' = rebuild "
                         "every round (the A/B baseline); 'auto' defers "
                         "to the engine default (mp engine, MH samplers)")
    ap.add_argument("--corpus-dir", default="",
                    help="sharded on-disk corpus directory (data/stream) "
                         "— switches to the out-of-core streaming engine "
                         "(requires --workdir)")
    ap.add_argument("--workdir", default="",
                    help="persistent run directory: the streaming "
                         "engine's state store, and the mp engine's "
                         "checkpoint home (engine_ckpt.npz)")
    ap.add_argument("--checkpoint-every", type=int, default=0, metavar="N",
                    help="checkpoint every N iterations into --workdir "
                         "(bit-exact resume via --resume)")
    ap.add_argument("--resume", action="store_true",
                    help="continue a killed run from the --workdir "
                         "checkpoint; draw-for-draw identical to a run "
                         "that never stopped")
    ap.add_argument("--docs", type=int, default=500)
    ap.add_argument("--vocab", type=int, default=2000)
    ap.add_argument("--topics", type=int, default=50)
    ap.add_argument("--doc-len", type=int, default=80)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--data-parallel", type=int, default=1,
                    help="D: hybrid grid — shard docs D*workers ways, "
                         "replicate the block ring D times (mp engine)")
    ap.add_argument("--blocks-per-worker", type=int, default=1,
                    help="S: pipeline S*workers vocabulary blocks "
                         "(mp engine)")
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--beta", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--out", default="")
    ap.add_argument("--eval-every", type=int, default=1, metavar="N",
                    help="evaluate log likelihood every N iterations "
                         "(0 = never; evaluation gathers the full model, "
                         "so big streaming runs want 0)")
    ap.add_argument("--eval-holdout", type=int, default=0, metavar="N",
                    help="hold N docs out of training and report their "
                         "doc-completion perplexity each iteration "
                         "(fold-in on the first half of each held-out "
                         "doc, score the second half — DESIGN.md §11)")
    ap.add_argument("--holdout-sweeps", type=int, default=5,
                    help="fold-in Gibbs sweeps per holdout evaluation")
    ap.add_argument("--holdout-sampler", default="scan",
                    choices=infer_sampler_choices(),
                    help="fold-in sampler for the holdout eval ('scan' "
                         "avoids rebuilding alias tables every snapshot)")
    ap.add_argument("--snapshot-out", default="",
                    help="write the final frozen serving snapshot "
                         "(counts .npz consumed by lda_infer)")
    ap.add_argument("--snapshot-dir", default="",
                    help="export the final model as a SHARDED serving "
                         "snapshot directory, one block file at a time "
                         "(streaming engine; lda_infer --snapshot-dir)")
    ap.add_argument("--supervise", action="store_true",
                    help="run training under the crash-recovery "
                         "supervisor (DESIGN.md §15): on a crash, "
                         "quarantine corrupt/partial checkpoints into "
                         "workdir/quarantine/ and restart from the last "
                         "good one with bounded seeded backoff — the "
                         "recovered chain is bitwise the uninterrupted "
                         "one")
    ap.add_argument("--max-restarts", type=int, default=3, metavar="N",
                    help="restart budget under --supervise")
    args = ap.parse_args()

    if args.supervise:
        import sys

        from repro.launch.supervise import supervise_cli
        if not args.workdir:
            ap.error("--supervise needs --workdir (the checkpoint home "
                     "the supervisor quarantines and resumes from)")
        sys.exit(supervise_cli(sys.argv[1:], args.workdir,
                               max_restarts=args.max_restarts,
                               seed=args.seed))

    streaming = bool(args.corpus_dir) or (
        args.resume and args.workdir
        and os.path.exists(os.path.join(args.workdir, "run.json")))
    if streaming and not args.workdir:
        ap.error("--corpus-dir needs --workdir (the run's state store)")
    if streaming and args.engine != "mp":
        ap.error("--corpus-dir streams through the model-parallel "
                 "engine; --engine dp is in-memory only")
    if streaming and args.eval_holdout:
        ap.error("--eval-holdout needs the in-memory corpus; hold the "
                 "docs out when sharding the corpus instead")
    if (args.checkpoint_every or args.resume) and not args.workdir:
        ap.error("--checkpoint-every/--resume need --workdir")
    if args.checkpoint_every and args.engine == "dp":
        ap.error("--checkpoint-every supports the mp engines only")
    args.holdout_sampler = resolve_sampler_choice(args.holdout_sampler,
                                                  force=args.force)

    lifetime = (None if args.table_lifetime == "auto"
                else args.table_lifetime)
    phi = None
    holdout_docs = None
    mp_ckpt = (os.path.join(args.workdir, "engine_ckpt.npz")
               if args.workdir else "")

    if streaming:
        from repro.core.engine.streaming import StreamingLDA
        from repro.data.stream import ShardedCorpus
        if args.resume:
            lda = StreamingLDA.resume(args.workdir)
            if args.store is not None:
                # the run's geometry is known now, so 'auto' can consult
                # the regime map; set_store converts the on-disk block
                # files (the chain itself is store-invariant)
                new_store = resolve_store_choice(
                    args.store, num_topics=lda.num_topics,
                    max_doc_len=lda.max_doc_len)
                if new_store != lda.store_kind:
                    print(f"switching store {lda.store_kind!r} -> "
                          f"{new_store!r} (chain unchanged)")
                    lda.set_store(new_store)
            print(f"resumed streaming run at iteration "
                  f"{lda.iteration_count} (sampler={lda.sampler_mode}, "
                  f"store={lda.store_kind})")
        else:
            corpus = ShardedCorpus(args.corpus_dir)
            # the corpus exists now, so 'auto' can consult the measured
            # regime map (manifest carries max_doc_len — no shard reads)
            sampler = resolve_sampler_choice(
                args.sampler, force=args.force, num_topics=args.topics,
                max_doc_len=corpus.max_doc_len)
            store = resolve_store_choice(
                args.store or "dense", num_topics=args.topics,
                max_doc_len=corpus.max_doc_len)
            print(f"corpus: {corpus.num_tokens:,} tokens (sharded, "
                  f"{corpus.num_shards} shards), V={corpus.vocab_size:,}, "
                  f"K={args.topics}, sampler={sampler}, store={store}")
            lda = StreamingLDA(corpus, args.workdir, args.topics,
                               args.workers, alpha=args.alpha,
                               beta=args.beta, seed=args.seed,
                               sampler_mode=sampler,
                               blocks_per_worker=args.blocks_per_worker,
                               data_parallel=args.data_parallel,
                               table_lifetime=lifetime, store=store)
        note = lda.store_note()
        if note:
            # densification is never silent (DESIGN.md §16)
            print(f"NOTE: {note}")
        rep = lda.memory_report()
        print(f"resident block: {rep['resident_block_shape']} "
              f"({rep['resident_block_bytes'] / 2**20:.1f} MiB of "
              f"{rep['total_model_bytes'] / 2**20:.1f} MiB total model)")
        num_tokens = lda.num_tokens
    else:
        corpus, phi, _ = synthetic_corpus(args.docs, args.vocab,
                                          args.topics, args.doc_len,
                                          seed=args.seed)
        if args.eval_holdout:
            corpus, held = split_corpus(corpus, args.eval_holdout)
            holdout_docs = held.doc_words()
            print(f"holdout: {held.num_docs} docs / "
                  f"{held.num_tokens:,} tokens (doc-completion, "
                  f"{args.holdout_sweeps} fold-in sweeps, "
                  f"sampler={args.holdout_sampler})")
        args.sampler = resolve_sampler_choice(
            args.sampler, force=args.force, num_topics=args.topics,
            max_doc_len=int(corpus.doc_lengths().max(initial=1)))
        print(f"corpus: {corpus.num_tokens:,} tokens, V={args.vocab}, "
              f"K={args.topics}, model vars={args.vocab * args.topics:,}, "
              f"sampler={args.sampler}")
        max_len = int(corpus.doc_lengths().max(initial=1))
        if args.engine == "mp":
            if args.resume:
                store = (resolve_store_choice(args.store,
                                              num_topics=args.topics,
                                              max_doc_len=max_len)
                         if args.store is not None else None)
                lda = ModelParallelLDA.resume(corpus, mp_ckpt, store=store)
                print(f"resumed mp run at iteration {lda.iteration_count}"
                      f" (store={lda.store_kind})")
            else:
                store = resolve_store_choice(args.store or "dense",
                                             num_topics=args.topics,
                                             max_doc_len=max_len)
                lda = ModelParallelLDA(
                    corpus, args.topics, args.workers, alpha=args.alpha,
                    beta=args.beta, seed=args.seed,
                    sampler_mode=args.sampler,
                    blocks_per_worker=args.blocks_per_worker,
                    data_parallel=args.data_parallel,
                    table_lifetime=lifetime, store=store)
            print(f"table lifetime: {lda.table_lifetime}")
            note = lda.store_note()
            if note:
                # densification is never silent (DESIGN.md §16)
                print(f"NOTE: {note}")
        else:
            if args.store not in (None, "dense"):
                ap.error("--store supports the mp engines only; the dp "
                         "baseline replicates the dense model")
            lda = DataParallelLDA(corpus, args.topics, args.workers,
                                  alpha=args.alpha, beta=args.beta,
                                  seed=args.seed)
        num_tokens = corpus.num_tokens

    def take_snapshot():
        if hasattr(lda, "snapshot"):
            return lda.snapshot()
        state = lda.gather_counts()   # dp baseline: build from the dump
        return ModelSnapshot.from_counts(np.asarray(state.ckt),
                                         np.asarray(state.ck),
                                         args.alpha, args.beta)

    def checkpoint():
        if streaming:
            lda.save_checkpoint()
        else:
            lda.save_checkpoint(mp_ckpt)

    history = []
    t0 = time.time()
    for it in range(lda.iteration_count + 1, args.iters + 1):
        t_it = time.perf_counter()
        lda.step()
        iter_s = time.perf_counter() - t_it   # sampling only, no eval
        rec = {"iteration": it, "iter_s": round(iter_s, 4),
               "tokens_per_s": round(num_tokens / iter_s, 1),
               "elapsed_s": round(time.time() - t0, 2)}
        lstr = ""
        if args.eval_every and it % args.eval_every == 0:
            ll = lda.log_likelihood()
            rec["log_likelihood"] = ll
            lstr = f"LL {ll:,.0f}  "
        if not streaming:
            if args.engine == "mp":
                rec["delta_error"] = lda.delta_error()
            else:
                rec["staleness_error"] = lda.model_error()
        hstr = ""
        if holdout_docs is not None:
            ppl = doc_completion_perplexity(
                take_snapshot(), holdout_docs,
                num_sweeps=args.holdout_sweeps,
                sampler=args.holdout_sampler, seed=args.seed + it)
            rec["holdout_perplexity"] = ppl["perplexity"]
            hstr = f"ppl {ppl['perplexity']:,.1f}  "
        history.append(rec)
        if args.checkpoint_every and it % args.checkpoint_every == 0:
            checkpoint()
            rec["checkpointed"] = True
        if it % max(args.iters // 10, 1) == 0 or it == 1:
            err = rec.get("delta_error", rec.get("staleness_error"))
            extra = f"Δ={err:.5f}" if err is not None else ""
            print(f"iter {it:4d}  {lstr}{hstr}{extra}  "
                  f"{rec['iter_s']:.3f}s/iter "
                  f"{rec['tokens_per_s']:,.0f} tok/s  "
                  f"[{rec['elapsed_s']}s]", flush=True)
    # steady-state throughput: median over post-warmup iterations (the
    # first pays jit compilation)
    if len(history) > 1:
        import statistics
        med = statistics.median(r["tokens_per_s"] for r in history[1:])
        print(f"median throughput: {med:,.0f} tokens/s")
    score = None
    if phi is not None:
        score = topic_recovery_score(np.asarray(lda.gather_counts().ckt),
                                     phi)
        print(f"topic recovery score: {score:.3f}")
    if args.ckpt:
        state = lda.gather_counts()
        save_checkpoint(args.ckpt, {"ckt": state.ckt, "cdk": state.cdk,
                                    "ck": state.ck}, step=args.iters)
        print(f"saved model to {args.ckpt}")
    if args.snapshot_out:
        take_snapshot().save(args.snapshot_out)
        print(f"saved serving snapshot to {args.snapshot_out}")
    if args.snapshot_dir:
        if not streaming:
            ap.error("--snapshot-dir is the streaming engine's sharded "
                     "export; use --snapshot-out for in-memory engines")
        lda.save_snapshot_sharded(args.snapshot_dir)
        print(f"saved sharded serving snapshot to {args.snapshot_dir}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"history": history, "recovery": score}, f, indent=1)


if __name__ == "__main__":
    main()
