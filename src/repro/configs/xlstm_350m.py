"""xLSTM-350M [arXiv:2405.04517].

24 blocks alternating mLSTM (matrix memory, SSD-form chunked evaluation)
and sLSTM (scalar memory, sequential scan); d 1024, 4 heads, no separate
FFN (blocks carry an internal ×2 up-projection); attention-free ⇒
eligible for the 500k decode shape with O(1) recurrent state."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    source="arXiv:2405.04517",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=256,
    rope_theta=0.0,
    ssm_state_size=256,
    block_pattern=("mlstm", "slstm"),
    norm="layernorm",
    tie_embeddings=True,
    subquadratic_decode=True,
)
