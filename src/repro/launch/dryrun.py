"""Multi-pod dry-run: lower + compile every (arch × input shape) on the
production meshes and extract memory/cost/collective analyses.

Usage:
    python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all --mesh pod --out benchmarks/results/dryrun
    python -m repro.launch.dryrun --all --mesh 2pod   # 512-chip multi-pod pass

This container has ONE real CPU device; the 512 placeholder devices below
exist only so ``jax.make_mesh`` can build the production meshes.  This is
the ONLY module that sets the flag, and it must run before any jax import.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs import (ARCH_IDS, INPUT_SHAPES, get_config,
                           shape_applicable)
from repro.launch.input_specs import input_specs
from repro.launch.mesh import make_production_mesh, mesh_devices
from repro.launch.sharding_rules import (batch_shardings, cache_shardings,
                                         param_shardings, replicated)
from repro.models import build_model
from repro.roofline import analysis as roofline
from repro.models.common import set_activation_sharding, set_scan_unroll
from repro.train.optimizer import AdamW, AdamWState
from repro.train.train_step import make_train_step, pick_accum_steps


def _reduced_layers(cfg, n: int):
    """Same architecture with n scan steps (for cost extrapolation)."""
    pat = len(cfg.block_pattern) or 1
    return dataclasses.replace(
        cfg, num_layers=n * pat,
        encoder_layers=n if cfg.encoder_layers else 0)


def build_step(cfg, shape, mesh, fsdp=True, roofline_variant=False,
               opts=frozenset(), accum_override=None):
    """Returns (jitted_fn, abstract_args) for the combo.

    ``roofline_variant=True`` lowers the cost-extrapolation variant:
    accumulation forced to 1 (full batch in one microbatch) and CE in a
    single chunk, so every non-layer scan has trip count 1 and
    cost_analysis counts it exactly (EXPERIMENTS.md §Roofline method).

    ``opts`` — beyond-paper optimizations measured in §Perf:
      * "bf16_inference": prefill/decode weights held in bf16 (halves
        weight-streaming and gather bytes; matmuls are bf16 anyway);
      * "tp_decode_weights": drop FSDP on decode when the model fits
        TP-only residency (kills the per-layer weight all-gathers);
      * "pad_experts": round the expert count up to the model-axis width
        (60 -> 64 on the 16-wide axis) so the expert dimension shards —
        the standard deployment remedy for indivisible expert counts
        (pad experts receive zero routing mass in a real run).
    """
    if "pad_experts" in opts and cfg.num_experts:
        axis = mesh.shape["model"]
        if cfg.num_experts % axis:
            padded = -(-cfg.num_experts // axis) * axis
            cfg = dataclasses.replace(cfg, num_experts=padded)
    from repro.launch.mesh import data_axes
    import numpy as _np
    set_activation_sharding(data_axes(mesh))
    model = build_model(cfg)
    bundle = input_specs(cfg, shape, model)
    params = model.abstract_params()
    if "bf16_inference" in opts and bundle.kind != "train":
        params = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16), params)
    if "bf16_train_params" in opts and bundle.kind == "train":
        # bf16 weights + fp32 Adam moments: halves FSDP gather and grad
        # all-reduce bytes (§Perf HC3); documented quality caveat.
        params = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16), params)
    if "tp_decode_weights" in opts and bundle.kind == "decode":
        pbytes = sum(int(_np.prod(x.shape)) *
                     (2 if x.dtype == jnp.bfloat16 else 4)
                     for x in jax.tree_util.tree_leaves(params))
        if pbytes / mesh.shape["model"] < 8 * 2 ** 30:
            fsdp = False
    pshard = param_shardings(cfg, mesh, params, fsdp=fsdp)

    if bundle.kind == "train":
        opt = AdamW()
        opt_state = jax.eval_shape(opt.init, params)
        oshard = AdamWState(replicated(mesh, opt_state.step), pshard, pshard)
        batch = bundle.args[0]
        bshard = batch_shardings(cfg, mesh, batch)
        dp = int(_np.prod([mesh.shape[a] for a in data_axes(mesh)]))
        accum = 1 if roofline_variant else (
            accum_override or pick_accum_steps(cfg, shape, dp))
        ce_chunk = shape.seq_len if roofline_variant else 512
        step = make_train_step(model, opt, accum_steps=accum,
                               ce_chunk=ce_chunk)
        from jax.sharding import NamedSharding, PartitionSpec as P
        mshard = {k: NamedSharding(mesh, P())
                  for k in ("loss", "grad_norm", "lr")}
        fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, mshard),
                     donate_argnums=(0, 1))
        return fn, (params, opt_state, batch)

    if bundle.kind == "prefill":
        batch = bundle.args[0]
        bshard = batch_shardings(cfg, mesh, batch)

        def prefill(params, batch):
            logits, _ = model.forward(params, batch["tokens"],
                                      batch.get("patch_embeds"),
                                      batch.get("frames"))
            return logits

        fn = jax.jit(prefill, in_shardings=(pshard, bshard))
        return fn, (params, batch)

    # decode
    caches, tokens, pos = bundle.args[:3]
    enc = bundle.args[3] if len(bundle.args) > 3 else None
    cshard = cache_shardings(cfg, mesh, caches)
    tshard = batch_shardings(cfg, mesh, {"t": tokens, "p": pos})
    in_sh = [pshard, cshard, tshard["t"], tshard["p"]]
    args = [params, caches, tokens, pos]
    if enc is not None:
        in_sh.append(batch_shardings(cfg, mesh, {"e": enc})["e"])
        args.append(enc)

    def decode(params, caches, tokens, pos, *rest):
        return build_model(cfg).decode_step(params, caches, tokens, pos,
                                            *rest)

    fn = jax.jit(decode, in_shardings=tuple(in_sh), donate_argnums=(1,))
    return fn, tuple(args)


def run_combo(arch: str, shape_name: str, mesh, mesh_name: str,
              fsdp: bool = True, skip_extrapolation: bool = False,
              opts=frozenset(), accum_override=None) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "devices": mesh_devices(mesh), "kind": shape.kind,
    }
    skip = shape_applicable(cfg, shape)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec

    rec["opts"] = sorted(opts)
    t0 = time.time()
    with set_mesh(mesh):
        fn, args = build_step(cfg, shape, mesh, fsdp=fsdp, opts=opts,
                              accum_override=accum_override)
        lowered = fn.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes_per_device": int(ma.argument_size_in_bytes),
            "temp_bytes_per_device": int(ma.temp_size_in_bytes),
            "output_bytes_per_device": int(ma.output_size_in_bytes),
            "total_gib_per_device": round(
                (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                 - ma.alias_size_in_bytes) / 2**30, 3),
        }
        costs_full = roofline.raw_costs(compiled)

        model = build_model(cfg)
        scan_layers = model._num_scan_layers()
        decode_unrolled = (shape.kind == "decode"
                           and not model.uniform_cache())
        if decode_unrolled or skip_extrapolation:
            # heterogeneous-cache decode unrolls layers: fully counted
            costs = costs_full
            rec["extrapolated"] = False
        else:
            c1 = c2 = None
            set_scan_unroll(True)   # unrolled variants: exact per-layer cost
            try:
                for n in (1, 2):
                    cfg_n = _reduced_layers(cfg, n)
                    fn_n, args_n = build_step(cfg_n, shape, mesh, fsdp=fsdp,
                                              roofline_variant=True,
                                              opts=opts)
                    comp_n = fn_n.lower(*args_n).compile()
                    c = roofline.raw_costs(comp_n)
                    c1, c2 = (c, c2) if n == 1 else (c1, c)
            finally:
                set_scan_unroll(False)
            costs = roofline.extrapolate(c1, c2, scan_layers)
            corr = roofline.inner_scan_corrections(cfg, shape,
                                                   mesh_devices(mesh))
            if shape.kind == "decode":
                corr = {"flops": 0.0, "bytes": 0.0}
            costs.flops += corr["flops"]
            costs.bytes_accessed += corr["bytes"]
            rec["extrapolated"] = True
            rec["analytic_correction"] = corr
            rec["per_layer_flops"] = c2.flops - c1.flops

    rec["costs"] = {
        "flops_per_device": costs.flops,
        "bytes_per_device": costs.bytes_accessed,
        "collective_bytes_per_device": costs.coll_bytes,
        "collective_detail": costs.coll_detail,
    }
    terms = roofline.roofline_terms(costs)
    rec["roofline"] = terms
    mf = roofline.model_flops(cfg, shape)
    hlo_global = costs.flops * mesh_devices(mesh)
    rec["model_flops_global"] = mf
    rec["hlo_flops_global"] = hlo_global
    rec["useful_compute_ratio"] = round(mf / hlo_global, 4) if hlo_global else 0
    rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=["pod", "2pod"], default="pod")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) combination")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip combos whose result JSON already exists")
    ap.add_argument("--opt", default="",
                    help="comma-separated optimizations "
                         "(bf16_inference,tp_decode_weights)")
    ap.add_argument("--accum", type=int, default=None,
                    help="override gradient-accumulation steps")
    ap.add_argument("--tag", default="",
                    help="suffix for result filenames (A/B experiments)")
    ap.add_argument("--skip-roofline", action="store_true",
                    help="compile-only pass (no L-extrapolation variants); "
                         "used for the multi-pod mesh, whose deliverable is "
                         "the successful lower+compile")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "2pod"))
    os.makedirs(args.out, exist_ok=True)

    combos = ([(args.arch, args.shape)] if not args.all else
              [(a, s) for a in ARCH_IDS for s in INPUT_SHAPES])
    if not args.all and (args.arch is None or args.shape is None):
        ap.error("--arch and --shape required unless --all")

    opts = frozenset(x for x in args.opt.split(",") if x)
    failures = 0
    for arch, shape in combos:
        tag = f"{arch}__{shape}__{args.mesh}" + (
            f"__{args.tag}" if args.tag else "")
        path0 = os.path.join(args.out, tag + ".json")
        if args.resume and os.path.exists(path0):
            with open(path0) as f:
                prev = json.load(f)
            if prev.get("status") in ("ok", "skipped"):
                print(f"[cached ] {tag}", flush=True)
                continue
        try:
            rec = run_combo(arch, shape, mesh, args.mesh,
                            fsdp=not args.no_fsdp, opts=opts,
                            accum_override=args.accum,
                            skip_extrapolation=args.skip_roofline)
        except Exception as e:  # noqa: BLE001 — record and continue
            rec = {"arch": arch, "shape": shape, "mesh": args.mesh,
                   "status": "failed", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            failures += 1
        path = os.path.join(args.out, tag + ".json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" dom={r['dominant']} "
                     f"c={r['compute_s']:.2e} m={r['memory_s']:.2e} "
                     f"x={r['collective_s']:.2e} "
                     f"mem/dev={rec['memory']['total_gib_per_device']}GiB "
                     f"compile={rec['compile_s']}s")
        elif status == "skipped":
            extra = " " + rec["reason"][:60]
        else:
            extra = " " + rec["error"][:120]
        print(f"[{status:7s}] {tag}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} combos failed")


if __name__ == "__main__":
    main()
