"""Inverted-index corpus layout (paper §4.2), grouped per *block*.

Model-parallel rounds touch only the tokens whose word falls in the current
block.  A bag-of-words (forward) layout would force a scan over all local
tokens with membership tests every round; the paper's fix is an inverted
index (word -> token postings).  The JAX analogue: sort each worker's token
slice by ``(block(word), word, doc)`` so that

  * a round's tokens are one contiguous slice (no comparisons at all), and
  * within the slice tokens of the same word are adjacent, which is what
    makes the per-word ``coeff``/``sum_k X_k`` cache of eq (3) (and the
    Pallas kernel's VMEM row reuse) effective.

Token groups are keyed by *block id*, not by worker: with ``S`` blocks per
worker (DESIGN.md §3) a worker's tokens split into ``B = S·M`` groups, one
per vocabulary block, and a round addresses the group of the resident
block directly by its id.

Because XLA needs static shapes, the ``B`` per-block slices are padded to a
common per-block capacity and carry a validity mask; padded entries are
no-ops in the samplers.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

from repro.core.schedule import VocabPartition


@dataclasses.dataclass
class InvertedIndex:
    """Per-worker inverted-index token layout, grouped by word block.

    All arrays have shape ``[B, T]`` where ``B`` is the number of blocks and
    ``T`` the padded per-block token capacity.
    """

    doc: np.ndarray        # [B, T] int32 — LOCAL document index
    word_off: np.ndarray   # [B, T] int32 — word offset inside its block
    word: np.ndarray       # [B, T] int32 — global word id (diagnostics)
    mask: np.ndarray       # [B, T] bool  — True for real tokens
    token_id: np.ndarray   # [B, T] int32 — position in the original arrays
    num_real: np.ndarray   # [B]    int32 — real token count per block

    @property
    def num_blocks(self) -> int:
        return self.doc.shape[0]

    @property
    def capacity(self) -> int:
        return self.doc.shape[1]


def block_token_counts(word: np.ndarray,
                       partition: VocabPartition) -> np.ndarray:
    """Tokens-per-block histogram ``[B]`` for one worker's token slice."""
    blk = partition.block_of_word(np.asarray(word, np.int32))
    return np.bincount(blk, minlength=partition.num_blocks).astype(np.int32)


def common_block_capacity(words: Iterable[np.ndarray],
                          partition: VocabPartition) -> int:
    """Smallest per-block capacity valid across all workers' token slices.

    The SPMD engine pads every (worker, block) token group to one static
    length; this is that length — the max over all workers of the largest
    per-block token count (at least 1 so empty blocks keep a real shape).
    """
    cap = 1
    for w in words:
        counts = block_token_counts(w, partition)
        cap = max(cap, int(counts.max(initial=0)))
    return cap


def build_inverted_index(doc: np.ndarray, word: np.ndarray,
                         partition: VocabPartition,
                         capacity: int | None = None) -> InvertedIndex:
    """Sort one worker's tokens into the ``[B, T]`` block-major layout.

    ``doc`` must already be local indices (0..D_local-1).  ``capacity`` may
    be supplied to force a common padding across workers (required so the
    shard_map engine sees identical shapes on every device); see
    :func:`common_block_capacity`.
    """
    doc = np.asarray(doc, np.int32)
    word = np.asarray(word, np.int32)
    n = doc.shape[0]
    blk = partition.block_of_word(word)
    # Stable sort by (block, word, doc): inverted index with postings grouped
    # by word, postings ordered by document.
    order = np.lexsort((doc, word, blk))
    doc_s, word_s, blk_s = doc[order], word[order], blk[order]

    m = partition.num_blocks
    counts = np.bincount(blk_s, minlength=m).astype(np.int32)
    cap = int(counts.max()) if counts.size and capacity is None else int(capacity or 1)
    cap = max(cap, 1)
    if counts.max(initial=0) > cap:
        raise ValueError(f"capacity {cap} < max block size {counts.max()}")

    out_doc = np.zeros((m, cap), np.int32)
    out_off = np.zeros((m, cap), np.int32)
    out_word = np.zeros((m, cap), np.int32)
    out_mask = np.zeros((m, cap), bool)
    out_tid = np.zeros((m, cap), np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for b in range(m):
        s, c = starts[b], counts[b]
        out_doc[b, :c] = doc_s[s:s + c]
        out_word[b, :c] = word_s[s:s + c]
        out_off[b, :c] = partition.word_offset_in_block(word_s[s:s + c])
        out_mask[b, :c] = True
        out_tid[b, :c] = order[s:s + c]
    return InvertedIndex(out_doc, out_off, out_word, out_mask, out_tid, counts)


def scatter_assignments(index: InvertedIndex, z_blocks: np.ndarray,
                        num_tokens: int) -> np.ndarray:
    """Invert the layout: write per-block assignment arrays back to token order."""
    z = np.zeros(num_tokens, np.int32)
    msk = index.mask
    z[index.token_id[msk]] = np.asarray(z_blocks)[msk]
    return z


def gather_assignments(index: InvertedIndex, z: np.ndarray) -> np.ndarray:
    """Forward map: token-order assignments -> ``[M, T]`` block layout."""
    out = np.zeros_like(index.token_id)
    msk = index.mask
    out[msk] = np.asarray(z)[index.token_id[msk]]
    return out
