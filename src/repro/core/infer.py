"""Held-out (fold-in) inference against a frozen model snapshot.

Training (PRs 1–4) produces the collapsed counts ``{C_k^t, C_k}``; this
module is the SERVING half the north-star asks for: given a frozen
snapshot of those counts, infer the topic mixture ``θ̂`` of documents the
trainer never saw (Peacock's "online inference" stage; Hou et al. 2014).
Fold-in runs the same collapsed Gibbs/MH machinery as training but
updates ONLY the query document's ``C_d^k`` — the model counts stay
frozen, which changes the systems story completely (DESIGN.md §11):

* **no reconciliation** — queries never write shared state, so a query
  batch shards embarrassingly along the ``data`` axis: no block ring, no
  delta psum, no ``C_k`` sync.  The per-doc sweep is a ``vmap`` here and
  would be a pure data-parallel ``shard_map`` at scale.
* **alias tables build once per snapshot** — LightLDA notes frozen-model
  inference is the ideal case for alias proposals: ``q_w ∝ C_k^t + β``
  is static, so the per-word tables (`core/alias.py`, packed layout) are
  built once per :class:`ModelSnapshot` and amortize over EVERY query
  token served from it, not just one round's.
* **replayable** — uniforms and initial assignments are drawn externally
  (same convention as the trainer), so a batched device fold-in is
  replayed draw-for-draw by the serial host oracle
  (`kvstore.fold_in_oracle`): the jitted per-doc kernel for the exact
  ``scan`` sampler, a pure-numpy mirror for the MH family.

Two samplers:

* ``scan`` — exact serial CGS per query doc over the frozen word term
  ``φ̂ᵀ = (C_k^t + β)/(C_k + Vβ)`` (one `lax.scan`, vmapped over docs);
* ``mh`` / ``mh_pallas`` — the O(1) alias-table MH cycle against the
  snapshot's static word tables plus per-sweep doc tables, through the
  SAME table-aware samplers the trainer registers (`engine/rounds.py`),
  so the serving path inherits the trainer's bit-exactness guarantees.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mh import DEFAULT_MH_CYCLES, build_doc_tables
from repro.core.sampler import sample_from_mass

# Gibbs/MH sweeps over the estimation half of a query doc.  Fold-in
# burn-in is short because only D_loc = 1 rows of state mix.
DEFAULT_FOLD_IN_SWEEPS = 5


# ---------------------------------------------------------------------------
# Frozen model snapshot (the serving export)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ModelSnapshot:
    """Frozen counts + once-per-snapshot alias tables (DESIGN.md §11).

    ``word_tables`` is the packed ``[3, V, K]`` int32 layout of
    `core/alias.py` (cut-bits / alias / W planes) built by
    ``mh.build_word_tables`` — the SAME builder, hence the same bits, as
    the trainer's traveling tables, so MH fold-in replays against the
    numpy mirrors exactly.  It is built lazily (:meth:`ensure_tables`)
    and exactly once: the ``scan`` sampler and perplexity scoring never
    need it, and rebuilding from counts is bit-deterministic, which is
    why :meth:`save` persists only the counts.
    """

    ckt: np.ndarray                       # [V, K] int32 word-topic counts
    ck: np.ndarray                        # [K] int32 topic totals
    alpha: np.ndarray                     # [K] f32 document prior
    beta: float                           # word smoothing
    word_tables: Optional[np.ndarray] = None   # packed [3, V, K] int32
    # set on row-restricted views (load_snapshot_rows): the smoothing
    # denominator must use the FULL vocabulary size, not the number of
    # resident rows, for sub-snapshot fold-in to stay bitwise
    true_vocab_size: Optional[int] = None
    _word_term: Optional[np.ndarray] = \
        dataclasses.field(default=None, repr=False, compare=False)
    _sparse_state: Optional[tuple] = \
        dataclasses.field(default=None, repr=False, compare=False)
    _fingerprint: Optional[str] = \
        dataclasses.field(default=None, repr=False, compare=False)

    @classmethod
    def from_counts(cls, ckt, ck=None, alpha=0.1, beta=0.01,
                    build_tables: bool = False) -> "ModelSnapshot":
        ckt = np.asarray(ckt, np.int32)
        if ckt.ndim != 2:
            raise ValueError(f"ckt must be [V, K], got shape {ckt.shape}")
        if ck is None:
            ck = ckt.sum(axis=0, dtype=np.int64)
        ck = np.asarray(ck, np.int32)
        k = ckt.shape[1]
        alpha = (np.full(k, alpha, np.float32) if np.isscalar(alpha)
                 else np.asarray(alpha, np.float32))
        snap = cls(ckt=ckt, ck=ck, alpha=alpha, beta=float(beta))
        if build_tables:
            snap.ensure_tables()
        return snap

    # -- shape views -------------------------------------------------------
    @property
    def vocab_size(self) -> int:
        return int(self.ckt.shape[0])

    @property
    def num_topics(self) -> int:
        return int(self.ckt.shape[1])

    @property
    def vbeta(self) -> float:
        v = (self.true_vocab_size if self.true_vocab_size is not None
             else self.vocab_size)
        return float(self.beta * v)

    # -- derived serving state --------------------------------------------
    def word_term(self) -> np.ndarray:
        """``φ̂ᵀ`` [V, K] f32: ``(C_k^t + β) / (C_k + Vβ)`` — row ``t`` is
        the per-topic probability of word ``t`` (rows of the transposed
        topic-word matrix; each COLUMN sums to 1 over the vocabulary).
        One f32 buffer shared by the device sampler and the host oracle,
        so the exact fold-in's conditionals agree bit-for-bit."""
        if self._word_term is None:
            denom = self.ck.astype(np.float32) + np.float32(self.vbeta)
            self._word_term = (self.ckt.astype(np.float32)
                               + np.float32(self.beta)) / denom[None, :]
        return self._word_term

    def sparse_state(self) -> tuple:
        """Frozen dense-segment layout for the ``sparse`` fold-in
        (DESIGN.md §12), built lazily and once per snapshot like the
        alias tables: ``(Xcs [V, K] f32, sX [V] f32)`` where ``X_v,k =
        φ̂ᵀ_v,k · α_k`` is the query-independent part of the fold-in
        conditional and ``Xcs`` its per-word cumsum.  Both the batched
        device fold-in and the serial host oracle consume this ONE
        buffer, so their dense-segment bisections agree bit-for-bit."""
        if self._sparse_state is None:
            xcs = np.cumsum(
                self.word_term() * self.alpha[None, :],
                axis=1, dtype=np.float32)
            self._sparse_state = (xcs, np.ascontiguousarray(xcs[:, -1]))
        return self._sparse_state

    def fingerprint(self) -> str:
        """Content identity of the frozen model (hex digest over counts +
        priors), computed lazily and once per snapshot.

        Two snapshots with the same fingerprint serve bitwise-identical
        responses — every derived quantity (``φ̂ᵀ``, alias tables, sparse
        state) is a deterministic function of exactly these bytes.  The
        serving scheduler (DESIGN.md §14) stamps it on every response
        alongside the swap epoch: the epoch says WHEN a model was
        installed, the fingerprint says WHAT was installed, so a swap to
        a bit-identical snapshot is observable as a new epoch with an
        unchanged fingerprint."""
        if self._fingerprint is None:
            import hashlib
            h = hashlib.sha256()
            h.update(np.asarray(
                [self.ckt.shape[0], self.ckt.shape[1],
                 self.true_vocab_size or 0], np.int64).tobytes())
            h.update(np.ascontiguousarray(self.ckt).tobytes())
            h.update(np.ascontiguousarray(self.ck).tobytes())
            h.update(np.ascontiguousarray(self.alpha).tobytes())
            h.update(np.float64(self.beta).tobytes())
            self._fingerprint = h.hexdigest()[:16]
        return self._fingerprint

    def ensure_tables(self) -> np.ndarray:
        """Build (once) and return the packed per-word alias tables."""
        if self.word_tables is None:
            from repro.core.mh import build_word_tables
            self.word_tables = np.asarray(build_word_tables(
                jnp.asarray(self.ckt), jnp.float32(self.beta)))
        return self.word_tables

    # -- persistence -------------------------------------------------------
    def save(self, path: str) -> None:
        """Persist the counts (npz).  Tables are NOT stored: the builder
        is bit-deterministic, so a load + ``ensure_tables`` reproduces
        them exactly — the checkpoint stays sampler-agnostic, like the
        trainer's (DESIGN.md §10)."""
        import os

        from repro.data import integrity
        from repro.data.corpus import npz_stem
        stem = npz_stem(path)
        os.makedirs(os.path.dirname(stem) or ".", exist_ok=True)
        # atomic publish + crc32 sidecar (DESIGN.md §15): the serving
        # watcher and hot-swap validation key on this stamp
        integrity.save_npz(stem + ".npz", compressed=True,
                           ckt=self.ckt, ck=self.ck,
                           alpha=self.alpha, beta=np.float64(self.beta))


def load_snapshot(path: str) -> ModelSnapshot:
    from repro.data import integrity
    from repro.data.corpus import npz_stem
    data = integrity.load_npz(npz_stem(path) + ".npz")
    return ModelSnapshot.from_counts(data["ckt"], data["ck"],
                                     data["alpha"],
                                     float(data["beta"]))


# ---------------------------------------------------------------------------
# Sharded snapshot (out-of-core serving, DESIGN.md §13)
# ---------------------------------------------------------------------------

SHARDED_SNAPSHOT_FORMAT = "sharded-snapshot-v1"
SHARDED_SNAPSHOT_FORMAT_V2 = "sharded-snapshot-v2"
_SNAPSHOT_FORMATS = (SHARDED_SNAPSHOT_FORMAT, SHARDED_SNAPSHOT_FORMAT_V2)


def load_sharded_snapshot_meta(snap_dir: str) -> dict:
    """Manifest of a sharded snapshot directory
    (``StreamingLDA.save_snapshot_sharded`` output) — O(1) in model
    size.  Accepts v1 (plain dense ``.npy`` blocks, the pre-store
    layout) and v2 (blocks are CountStore records of the ``store`` kind
    stamped here); the returned dict always carries ``store``."""
    import json
    import os
    try:
        with open(os.path.join(snap_dir, "meta.json")) as f:
            meta = json.load(f)
    except OSError as e:
        raise ValueError(
            f"{snap_dir!r} is not a sharded snapshot directory "
            "(missing meta.json)") from e
    if meta.get("format") not in _SNAPSHOT_FORMATS:
        raise ValueError(
            f"unknown snapshot format {meta.get('format')!r} in "
            f"{snap_dir!r}; expected one of {_SNAPSHOT_FORMATS}")
    meta.setdefault("store", "dense")
    return meta


def load_snapshot_rows(snap_dir: str, word: np.ndarray):
    """Row-restricted snapshot view for one query batch: load ONLY the
    ``C_k^t`` rows of the batch's unique words (touching one block file
    per needed block), returning ``(snapshot, remapped_word_ids)`` for
    :func:`fold_in`.

    Every serving quantity is row-independent given the global ``C_k`` —
    ``φ̂ᵀ`` rows, sparse-state rows, and per-word alias tables are all
    computed per vocabulary row with the full-vocabulary smoothing
    denominator (``true_vocab_size`` keeps ``Vβ`` honest) — so fold-in
    against this view is BITWISE the full-snapshot fold-in, while peak
    serving memory is O(unique query words × K) + one block STORE at
    its occupancy — a TailStore block answers ``rows(idx)`` from its
    lanes + overflow dict (only the touched rows' heads and tails are
    ever densified), never ``[Vb, K]``, let alone ``[V, K]``.
    """
    import os

    from repro.core.engine import countstore
    from repro.data import integrity
    meta = load_sharded_snapshot_meta(snap_dir)
    word = np.asarray(word, np.int32)
    v, k = int(meta["vocab_size"]), int(meta["num_topics"])
    if word.size and (word.min() < 0 or word.max() >= v):
        raise ValueError(
            f"query word id outside [0, {v}) for snapshot {snap_dir!r}")
    uniq, inv = np.unique(word, return_inverse=True)
    uniq = uniq.astype(np.int64)
    vb = int(meta["block_size"])
    rows = np.zeros((max(uniq.shape[0], 1), k), np.int32)
    for b in np.unique(uniq // vb):
        sel = (uniq // vb) == b
        blk = countstore.load(
            os.path.join(snap_dir, f"block_{int(b):05d}"))
        rows[:uniq.shape[0]][sel] = blk.rows(uniq[sel] - b * vb)
    ck = integrity.load_npy(
        os.path.join(snap_dir, "ck.npy")).astype(np.int32)
    alpha = meta["alpha"]
    alpha = (np.full(k, alpha, np.float32) if np.isscalar(alpha)
             else np.asarray(alpha, np.float32))
    snap = ModelSnapshot(ckt=rows, ck=ck, alpha=alpha,
                         beta=float(meta["beta"]), true_vocab_size=v)
    return snap, inv.reshape(word.shape).astype(np.int32)


# ---------------------------------------------------------------------------
# Query batch layout
# ---------------------------------------------------------------------------

def pack_queries(docs: Sequence[Sequence[int]], t_pad: int | None = None,
                 q_pad: int | None = None):
    """Pack query docs (word-id sequences) into ``(word [Q, T] int32,
    mask [Q, T] bool)``.  ``t_pad``/``q_pad`` force bucket shapes (the
    serving path pads to power-of-two buckets so jit compiles once per
    bucket); padded slots are masked no-ops."""
    q = len(docs)
    lens = [len(d) for d in docs]
    t = int(t_pad) if t_pad is not None else max(lens + [1])
    t = max(t, 1)
    qq = int(q_pad) if q_pad is not None else max(q, 1)
    if qq < q:
        raise ValueError(f"q_pad {qq} < batch size {q}")
    if lens and max(lens) > t:
        raise ValueError(f"t_pad {t} < longest query ({max(lens)} tokens)")
    word = np.zeros((qq, t), np.int32)
    mask = np.zeros((qq, t), bool)
    for i, d in enumerate(docs):
        word[i, :lens[i]] = np.asarray(d, np.int32)
        mask[i, :lens[i]] = True
    return word, mask


def init_query_cdk(z0: np.ndarray, mask: np.ndarray, k: int) -> np.ndarray:
    """Initial per-query doc-topic counts from the initial assignments
    (shared by the engine and the host oracle)."""
    q, t = z0.shape
    cdk = np.zeros((q, k), np.int32)
    np.add.at(cdk, (np.repeat(np.arange(q), t), z0.reshape(-1)),
              mask.reshape(-1).astype(np.int32))
    return cdk


def theta_from_cdk(cdk: np.ndarray, alpha: np.ndarray) -> np.ndarray:
    """Posterior-mean mixture ``θ̂ = (C_d^k + α) / (N_d + Σα)`` [f64]."""
    cdk = np.asarray(cdk, np.float64)
    alpha = np.asarray(alpha, np.float64)
    return (cdk + alpha) / (cdk.sum(axis=1, keepdims=True) + alpha.sum())


# ---------------------------------------------------------------------------
# Device sweeps
# ---------------------------------------------------------------------------

@jax.jit
def fold_in_doc_scan(cdk_d, wterm, word_t, z_t, mask_t, u_t, alpha):
    """ONE query doc, ONE exact serial CGS sweep against the frozen word
    term.  This is the unit the engine vmaps over the batch — and the
    unit the host oracle replays serially (`kvstore.fold_in_oracle`), so
    batched and serial execution are the same jitted program applied
    per-row (the repo's standard bit-exactness argument)."""
    def body(carry, xs):
        cdk_d = carry
        t_i, k_old, valid, u_i = xs
        delta = valid.astype(jnp.int32)
        cdk_d = cdk_d.at[k_old].add(-delta)        # ¬dn self-exclusion
        p = wterm[t_i] * (alpha + cdk_d.astype(jnp.float32))
        k_new = sample_from_mass(p, u_i).astype(jnp.int32)
        k_new = jnp.where(valid, k_new, k_old)
        cdk_d = cdk_d.at[k_new].add(delta)
        return cdk_d, k_new

    return jax.lax.scan(body, cdk_d, (word_t, z_t, mask_t, u_t))


@jax.jit
def _fold_in_scan_sweeps(cdk, wterm, word, z, mask, u, alpha):
    """All sweeps × all query docs of the exact fold-in: `lax.scan` over
    the sweep axis of ``u`` [S, Q, T], vmap of :func:`fold_in_doc_scan`
    over the doc axis (docs are independent — the model is frozen)."""
    def sweep(carry, u_s):
        cdk, z = carry
        cdk, z = jax.vmap(fold_in_doc_scan,
                          in_axes=(0, None, 0, 0, 0, 0, None))(
            cdk, wterm, word, z, mask, u_s, alpha)
        return (cdk, z), None

    (cdk, z), _ = jax.lax.scan(sweep, (cdk, z), u)
    return cdk, z


@partial(jax.jit, static_argnames=("sampler_mode", "num_cycles"))
def _fold_in_mh_sweeps(cdk, ckt, ck, wtab, word, z, mask, u, alpha, beta,
                       vbeta, sampler_mode: str = "mh",
                       num_cycles: int = DEFAULT_MH_CYCLES):
    """MH fold-in: per sweep, build doc tables from sweep-start ``cdk``
    (the only mutable state) and run the registry's table-aware sampler
    per doc against the snapshot's STATIC word tables.  The model-count
    outputs of the sampler are discarded — that single difference from
    training is what "frozen model" means operationally."""
    from repro.core.engine.rounds import resolve_table_sampler
    sampler = resolve_table_sampler(sampler_mode)
    t = word.shape[1]
    zero_doc = jnp.zeros((t,), jnp.int32)

    def per_doc(cdk_d, dtab_d, w_t, z_t, m_t, u_t):
        out = sampler(cdk_d[None], ckt, ck, zero_doc, w_t, z_t, m_t, u_t,
                      alpha, beta, vbeta, wtab, dtab_d[:, None, :],
                      num_cycles=num_cycles)
        return out[0][0], out[3]          # cdk row + draws; ckt/ck frozen

    def sweep(carry, u_s):
        cdk, z = carry
        dtab = build_doc_tables(cdk, alpha)          # [3, Q, K] per sweep
        cdk, z = jax.vmap(per_doc, in_axes=(0, 1, 0, 0, 0, 0))(
            cdk, dtab, word, z, mask, u_s)
        return (cdk, z), None

    (cdk, z), _ = jax.lax.scan(sweep, (cdk, z), u)
    return cdk, z


@partial(jax.jit, static_argnames=("dcap",))
def fold_in_doc_sparse(cdk_d, wterm, xcs, sx, word_t, z_t, mask_t, u_t,
                       dcap: int):
    """ONE query doc, ONE hybrid sparse fold-in sweep (DESIGN.md §12).

    Frozen-count semantics per sweep, like the training sampler: the
    conditional ``p_k = φ̂ᵀ_t,k (α_k + C_d'^k)`` splits into the
    query-independent dense segment ``X = φ̂ᵀ·α`` (cumsummed once per
    snapshot) and the document-sparse lanes ``φ̂ᵀ·C_d'^k`` on the ≤ dcap
    nonzeros of the sweep-start doc row.  The model is frozen, so the
    rank-1 z0 exclusion lives entirely on the doc lanes (z0 is always a
    sweep-start nonzero) and the dense bisection needs no perturbation —
    simpler than training's head/tail machinery.  This per-doc unit is
    what the engine vmaps over the batch and the host oracle replays
    serially, the repo's standard bit-exactness argument."""
    from repro.core.sparse_device import (_extract_lanes, _lane_cumsum,
                                          _row_count, _segment_draw)
    k = cdk_d.shape[0]
    lanes = _extract_lanes(cdk_d[None], dcap)[0]           # [dcap]
    valid = lanes < k
    kk = jnp.minimum(lanes, k - 1)
    cdk_v = cdk_d.astype(jnp.float32)[kk]
    e = ((kk[None, :] == z_t[:, None])
         & mask_t[:, None]).astype(jnp.float32)
    wt_v = wterm[word_t[:, None], kk[None, :]]             # [T, dcap]
    dval = jnp.maximum(
        jnp.where(valid[None, :], wt_v * (cdk_v[None, :] - e), 0.0), 0.0)
    dcs = _lane_cumsum(dval)
    sd = dcs[:, -1]
    sxt = sx[word_t]
    total = sd + sxt                       # CDF order [doc lanes | dense]
    x = u_t * total
    in_d = x < sd
    kd = _segment_draw(dcs, sd, x,
                       jnp.broadcast_to(kk[None, :], dval.shape))
    y = x - sd
    idx = _row_count(xcs, word_t, y)
    last = _row_count(xcs, word_t, sxt, strict=True)
    k_dense = jnp.minimum(jnp.minimum(idx, last), k - 1).astype(jnp.int32)
    z_new = jnp.where(mask_t, jnp.where(in_d, kd, k_dense), z_t)
    d = mask_t.astype(jnp.int32)
    return cdk_d.at[z_t].add(-d).at[z_new].add(d), z_new


@partial(jax.jit, static_argnames=("dcap",))
def _fold_in_sparse_sweeps(cdk, wterm, xcs, sx, word, z, mask, u,
                           dcap: int):
    """All sweeps × all query docs of the sparse fold-in — the structure
    of ``_fold_in_scan_sweeps`` around :func:`fold_in_doc_sparse`."""
    unit = partial(fold_in_doc_sparse, dcap=dcap)

    def sweep(carry, u_s):
        cdk, z = carry
        cdk, z = jax.vmap(unit, in_axes=(0, None, None, None, 0, 0, 0, 0))(
            cdk, wterm, xcs, sx, word, z, mask, u_s)
        return (cdk, z), None

    (cdk, z), _ = jax.lax.scan(sweep, (cdk, z), u)
    return cdk, z


# ---------------------------------------------------------------------------
# Public fold-in entry point
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FoldInResult:
    cdk: np.ndarray      # [Q, K] int32 inferred doc-topic counts
    z: np.ndarray        # [Q, T] int32 final assignments (block layout)
    theta: np.ndarray    # [Q, K] f64 posterior-mean mixtures


def fold_in(snapshot: ModelSnapshot, word: np.ndarray, mask: np.ndarray,
            num_sweeps: int = DEFAULT_FOLD_IN_SWEEPS, sampler: str = "scan",
            seed: int = 0, rng: Optional[np.random.Generator] = None,
            z0: Optional[np.ndarray] = None, u: Optional[np.ndarray] = None,
            num_cycles: int = DEFAULT_MH_CYCLES) -> FoldInResult:
    """Infer topic mixtures for a packed query batch (see
    :func:`pack_queries`) against a frozen snapshot.

    Randomness follows the trainer's convention: initial assignments
    ``z0`` [Q, T] and uniforms ``u`` [num_sweeps, Q, T] are drawn
    externally (from ``rng``/``seed`` unless supplied), so any run can be
    replayed draw-for-draw by `kvstore.fold_in_oracle` fed the same
    arrays.  ``sampler`` is ``"scan"`` (exact CGS) or any table-capable
    registry sampler (``"mh"``/``"mh_pallas"`` — the MH pair draws
    identically, as in training).
    """
    word = np.asarray(word, np.int32)
    mask = np.asarray(mask, bool)
    if word.shape != mask.shape or word.ndim != 2:
        raise ValueError(f"word/mask must share a [Q, T] shape, got "
                         f"{word.shape} vs {mask.shape}")
    k = snapshot.num_topics
    if rng is None:
        rng = np.random.default_rng(seed)
    if z0 is None:
        z0 = rng.integers(0, k, size=word.shape).astype(np.int32)
    if u is None:
        u = rng.random((num_sweeps, *word.shape), np.float32)
    u = np.asarray(u, np.float32)
    cdk0 = init_query_cdk(z0, mask, k)
    alpha = jnp.asarray(snapshot.alpha)

    if sampler == "scan":
        cdk, z = _fold_in_scan_sweeps(
            jnp.asarray(cdk0), jnp.asarray(snapshot.word_term()),
            jnp.asarray(word), jnp.asarray(z0), jnp.asarray(mask),
            jnp.asarray(u), alpha)
    elif sampler in ("sparse", "sparse_pallas"):
        # one jnp implementation serves both names: with the model frozen
        # there is no per-round lane extraction to fuse, so the serving
        # path has no separate kernel form (the alias keeps `--sampler`
        # choices symmetric between training and inference).
        xcs, sx = snapshot.sparse_state()
        cdk, z = _fold_in_sparse_sweeps(
            jnp.asarray(cdk0), jnp.asarray(snapshot.word_term()),
            jnp.asarray(xcs), jnp.asarray(sx), jnp.asarray(word),
            jnp.asarray(z0), jnp.asarray(mask), jnp.asarray(u),
            dcap=min(k, word.shape[1]))
    else:
        from repro.core.engine.rounds import table_capable
        if not table_capable(sampler):
            raise ValueError(
                f"unknown fold-in sampler {sampler!r}; expected 'scan', "
                "'sparse'/'sparse_pallas', or a table-capable registry "
                "sampler (the MH family)")
        cdk, z = _fold_in_mh_sweeps(
            jnp.asarray(cdk0), jnp.asarray(snapshot.ckt),
            jnp.asarray(snapshot.ck), jnp.asarray(snapshot.ensure_tables()),
            jnp.asarray(word), jnp.asarray(z0), jnp.asarray(mask),
            jnp.asarray(u), alpha, jnp.float32(snapshot.beta),
            jnp.float32(snapshot.vbeta), sampler_mode=sampler,
            num_cycles=num_cycles)

    cdk = np.asarray(cdk)
    return FoldInResult(cdk=cdk, z=np.asarray(z),
                        theta=theta_from_cdk(cdk, snapshot.alpha))
