"""§4.2 sampler benchmarks: inverted-index X+Y kernel vs the scan sampler.

Measures (a) sampler throughput (tokens/s) of the three engine sampler
modes on CPU, (b) convergence parity of the word-frozen batched/Pallas
relaxation vs exact scan CGS (DESIGN.md §2 assumption change #2), and
(c) the word-grouped kernel layout vs the degenerate one-token-per-group
layout (the VMEM-reuse structure).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit_csv_row, save_result
from repro.core.model_parallel import ModelParallelLDA
from repro.data.synthetic import synthetic_corpus


def run(seed=0):
    corpus, _, _ = synthetic_corpus(300, 1200, 32, 60, seed=seed)
    out = {"tokens": corpus.num_tokens}
    ll = {}
    for mode in ("scan", "batched", "pallas"):
        lda = ModelParallelLDA(corpus, 32, 8, seed=seed, sampler_mode=mode)
        lda.step()                      # compile
        t0 = time.time()
        iters = 3
        for _ in range(iters):
            lda.step()
        dt = time.time() - t0
        hist = lda.run(8)
        ll[mode] = hist[-1]["log_likelihood"]
        out[mode] = {
            "tokens_per_s": corpus.num_tokens * iters / dt,
            "final_ll": ll[mode],
        }
    # convergence parity: relaxed samplers within 1% of exact scan CGS
    parity = abs(ll["batched"] - ll["scan"]) / abs(ll["scan"])
    out["batched_vs_scan_ll_gap"] = parity
    out["parity_ok"] = bool(parity < 0.01)
    save_result("kernel_sampler", out)
    emit_csv_row("kernel_sampler_scan",
                 1e6 / max(out["scan"]["tokens_per_s"], 1e-9),
                 f"batched_speedup="
                 f"{out['batched']['tokens_per_s']/out['scan']['tokens_per_s']:.1f}x;"
                 f"parity_gap={parity:.4f}")
    return out


if __name__ == "__main__":
    run()
