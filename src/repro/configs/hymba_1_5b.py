"""Hymba-1.5B [arXiv:2411.13676].

32L hybrid-head blocks: every block runs attention heads and Mamba (SSD)
heads in parallel on the same input and fuses by mean (the paper's
parallel-fusion).  25 attn heads (GQA kv=5), d_ff 5504, ssm_state 16,
sliding-window attention on most layers with a few global layers —
modeled with the 5:1 local:global pattern; SWA + constant SSM state make
it eligible for the 500k decode shape."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    rope_theta=10000.0,
    sliding_window=1024,
    global_every=8,
    ssm_state_size=16,
    ssm_heads=25,
    norm="rms",
    tie_embeddings=True,
    subquadratic_decode=True,
)
