import numpy as np
import pytest

from repro.data.synthetic import synthetic_corpus


@pytest.fixture(scope="session")
def tiny_corpus():
    """~1.2k tokens, 40 docs, V=120, planted 8-topic structure."""
    corpus, phi, theta = synthetic_corpus(
        num_docs=40, vocab_size=120, num_topics=8, doc_len=30, seed=0)
    return corpus, phi, theta


@pytest.fixture(scope="session")
def small_corpus():
    """~6k tokens, 120 docs, V=400 — big enough for convergence ordering."""
    corpus, phi, theta = synthetic_corpus(
        num_docs=120, vocab_size=400, num_topics=10, doc_len=50, seed=7)
    return corpus, phi, theta


def make_random_counts(rng, num_docs, vocab, topics, tokens):
    doc = rng.integers(0, num_docs, tokens).astype(np.int32)
    word = rng.integers(0, vocab, tokens).astype(np.int32)
    z = rng.integers(0, topics, tokens).astype(np.int32)
    return doc, word, z
