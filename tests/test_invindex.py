"""Inverted-index layout properties (paper §4.2)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.invindex import (build_inverted_index, gather_assignments,
                                 scatter_assignments)
from repro.core.schedule import partition_vocab


@given(st.integers(0, 2**31 - 1), st.integers(1, 300), st.integers(1, 50),
       st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_inverted_index_roundtrip(seed, n, v, m):
    rng = np.random.default_rng(seed)
    doc = rng.integers(0, 10, n).astype(np.int32)
    word = rng.integers(0, v, n).astype(np.int32)
    part = partition_vocab(v, m)
    idx = build_inverted_index(doc, word, part)
    # every real token appears exactly once
    assert int(idx.mask.sum()) == n
    tids = np.sort(idx.token_id[idx.mask])
    np.testing.assert_array_equal(tids, np.arange(n))
    # block purity: tokens in row b belong to block b
    for b in range(m):
        msk = idx.mask[b]
        if msk.any():
            np.testing.assert_array_equal(
                part.block_of_word(idx.word[b][msk]), b)
    # word-major within block (the cache-friendly order)
    for b in range(m):
        w = idx.word[b][idx.mask[b]]
        assert (np.diff(w) >= 0).all()
    # z scatter/gather roundtrip
    z = rng.integers(0, 7, n).astype(np.int32)
    z_blocks = gather_assignments(idx, z)
    z_back = scatter_assignments(idx, z_blocks, n)
    np.testing.assert_array_equal(z_back, z)


def test_common_capacity_padding():
    rng = np.random.default_rng(0)
    doc = rng.integers(0, 5, 100).astype(np.int32)
    word = rng.integers(0, 20, 100).astype(np.int32)
    part = partition_vocab(20, 4)
    idx = build_inverted_index(doc, word, part, capacity=64)
    assert idx.capacity == 64
    assert int(idx.mask.sum()) == 100
