"""Serving loop: prefill + batched greedy/temperature decode."""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model


def sample_token(logits: jax.Array, key, temperature: float = 0.0
                 ) -> jax.Array:
    """logits: [B, V] -> [B] next tokens."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)


def prefill_via_decode(model: Model, params, prompts: jax.Array,
                       max_len: int, enc_out=None):
    """Feed the prompt token-by-token through ``decode_step`` (cache-filling
    prefill; exact w.r.t. the decode path by construction)."""
    b, t = prompts.shape
    caches = model.init_cache(b, max_len)
    logits = None
    for i in range(t):
        kwargs = {"enc_out": enc_out} if enc_out is not None else {}
        logits, caches = model.decode_step(
            params, caches, prompts[:, i:i + 1],
            jnp.full((b,), i, jnp.int32), **kwargs)
    return logits, caches


def generate(model: Model, params, prompts: jax.Array, num_tokens: int,
             max_len: int = 0, temperature: float = 0.0, seed: int = 0,
             enc_out=None) -> np.ndarray:
    """Batched generation.  prompts: [B, T0] -> [B, T0 + num_tokens]."""
    b, t0 = prompts.shape
    max_len = max_len or (t0 + num_tokens)
    key = jax.random.PRNGKey(seed)
    logits, caches = prefill_via_decode(model, params, prompts, max_len,
                                        enc_out)
    out = [np.asarray(prompts)]
    tok = sample_token(logits[:, 0], key, temperature)[:, None]
    decode = jax.jit(model.decode_step) if enc_out is None else \
        model.decode_step
    for i in range(num_tokens):
        out.append(np.asarray(tok))
        if i == num_tokens - 1:
            break
        key, sub = jax.random.split(key)
        kwargs = {"enc_out": enc_out} if enc_out is not None else {}
        logits, caches = decode(params, caches, tok,
                                jnp.full((b,), t0 + i, jnp.int32), **kwargs)
        tok = sample_token(logits[:, 0], sub, temperature)[:, None]
    return np.concatenate(out, axis=1)


class BatchedServer:
    """Minimal continuous-batching server facade: accepts requests, packs
    them into a fixed batch, decodes one token per tick for every live
    request — the serving-side example the assignment asks for."""

    def __init__(self, model: Model, params, batch_size: int,
                 max_len: int = 256):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.caches = model.init_cache(batch_size, max_len)
        self.pos = np.zeros(batch_size, np.int32)
        self.live = np.zeros(batch_size, bool)
        self.tokens = np.zeros((batch_size, 1), np.int32)
        self.outputs: List[List[int]] = [[] for _ in range(batch_size)]
        self._decode = jax.jit(model.decode_step)

    def submit(self, prompt: List[int]) -> Optional[int]:
        """Returns a slot id, or None if the batch is full."""
        free = np.nonzero(~self.live)[0]
        if free.size == 0:
            return None
        slot = int(free[0])
        # sequential cache fill for this slot (single-row prefill)
        for i, tok in enumerate(prompt):
            toks = np.zeros((self.batch_size, 1), np.int32)
            toks[slot, 0] = tok
            logits, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(toks),
                jnp.asarray(np.where(self.live | (np.arange(
                    self.batch_size) == slot), np.maximum(self.pos, 0),
                    0).astype(np.int32)))
            self.pos[slot] = i + 1
        self.live[slot] = True
        self.tokens[slot, 0] = int(np.asarray(logits)[slot, 0].argmax())
        self.outputs[slot] = [int(self.tokens[slot, 0])]
        return slot

    def tick(self) -> Dict[int, List[int]]:
        """Advance every live request by one token; returns finished slots."""
        if not self.live.any():
            return {}
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.tokens),
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        done: Dict[int, List[int]] = {}
        for s in range(self.batch_size):
            if not self.live[s]:
                continue
            self.outputs[s].append(int(nxt[s]))
            self.tokens[s, 0] = nxt[s]
            self.pos[s] += 1
            if self.pos[s] >= self.max_len - 1:
                done[s] = self.outputs[s]
                self.live[s] = False
        return done
