"""Backfill coverage for `serve/serve_step.py` (previously untested):
token sampling, batched generation, and the `BatchedServer` slot
lifecycle, on a reduced plain-transformer config."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve.serve_step import BatchedServer, generate, sample_token

B = 2


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("olmo-1b").reduced()
    model = build_model(cfg)
    return cfg, model, model.init(0)


# ---------------------------------------------------------------------------
# sample_token
# ---------------------------------------------------------------------------

def test_sample_token_greedy_is_argmax():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(B, 17)).astype(np.float32))
    tok = sample_token(logits, None, temperature=0.0)
    assert tok.shape == (B,) and tok.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(tok),
                                  np.asarray(logits).argmax(-1))


def test_sample_token_temperature_valid_and_seeded():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(B, 17)).astype(np.float32))
    key = jax.random.PRNGKey(3)
    a = sample_token(logits, key, temperature=0.8)
    b = sample_token(logits, key, temperature=0.8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))  # same key
    assert ((np.asarray(a) >= 0) & (np.asarray(a) < 17)).all()
    # temperature -> 0 recovers the argmax almost surely
    cold = sample_token(logits * 1e4, key, temperature=1.0)
    np.testing.assert_array_equal(np.asarray(cold),
                                  np.asarray(logits).argmax(-1))


# ---------------------------------------------------------------------------
# generate
# ---------------------------------------------------------------------------

def test_generate_shapes_and_prompt_preserved(tiny):
    cfg, model, params = tiny
    rng = np.random.default_rng(2)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 5)))
    out = generate(model, params, prompts, num_tokens=4)
    assert out.shape == (B, 9)
    np.testing.assert_array_equal(out[:, :5], np.asarray(prompts))
    assert ((out >= 0) & (out < cfg.vocab_size)).all()


def test_generate_greedy_deterministic_and_matches_forward(tiny):
    cfg, model, params = tiny
    rng = np.random.default_rng(3)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 6)))
    a = generate(model, params, prompts, num_tokens=3)
    b = generate(model, params, prompts, num_tokens=3)
    np.testing.assert_array_equal(a, b)
    # first generated token == argmax of the teacher-forced forward at
    # the last prompt position (prefill-via-decode is cache-exact)
    full, _ = model.forward(params, prompts)
    np.testing.assert_array_equal(
        a[:, 6], np.asarray(full[:, 5].argmax(-1)))


def test_generate_temperature_seeded(tiny):
    cfg, model, params = tiny
    rng = np.random.default_rng(4)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 4)))
    a = generate(model, params, prompts, num_tokens=4, temperature=0.7,
                 seed=11)
    b = generate(model, params, prompts, num_tokens=4, temperature=0.7,
                 seed=11)
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# BatchedServer slot lifecycle
# ---------------------------------------------------------------------------

def test_batched_server_fills_slots_then_rejects(tiny):
    cfg, model, params = tiny
    srv = BatchedServer(model, params, batch_size=2, max_len=16)
    rng = np.random.default_rng(5)
    p = [rng.integers(0, cfg.vocab_size, size=3).tolist()
         for _ in range(3)]
    assert srv.submit(p[0]) == 0
    assert srv.submit(p[1]) == 1
    assert srv.submit(p[2]) is None           # batch full
    assert srv.live.all()
    assert list(srv.pos) == [3, 3]
    assert all(len(srv.outputs[s]) == 1 for s in range(2))


def test_batched_server_tick_advances_and_finishes(tiny):
    cfg, model, params = tiny
    max_len = 8
    srv = BatchedServer(model, params, batch_size=2, max_len=max_len)
    rng = np.random.default_rng(6)
    srv.submit(rng.integers(0, cfg.vocab_size, size=3).tolist())
    assert srv.tick() == {}                   # advances, nobody done yet
    assert srv.pos[0] == 4 and len(srv.outputs[0]) == 2
    done = {}
    for _ in range(max_len):                  # runs to the length cap
        done = srv.tick()
        if done:
            break
    assert 0 in done
    assert not srv.live[0]                    # slot freed at max_len - 1
    assert len(done[0]) == max_len - 1 - 3 + 1
    assert srv.submit([1, 2]) == 0            # slot reusable after finish


def test_batched_server_idle_tick_is_noop(tiny):
    cfg, model, params = tiny
    srv = BatchedServer(model, params, batch_size=1, max_len=8)
    assert srv.tick() == {}


def test_batched_server_matches_generate_greedy(tiny):
    """A single-slot server is exactly greedy decode: its output stream
    must equal `generate`'s continuation token for token."""
    cfg, model, params = tiny
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, size=4).tolist()
    n = 5
    ref = generate(model, params, jnp.asarray([prompt]), num_tokens=n,
                   max_len=16)[0, 4:]
    srv = BatchedServer(model, params, batch_size=1, max_len=16)
    slot = srv.submit(prompt)
    for _ in range(n - 1):
        srv.tick()
    np.testing.assert_array_equal(np.asarray(srv.outputs[slot][:n]),
                                  np.asarray(ref))
