"""Event-loop serving scheduler: continuous batching, hot-query cache,
multi-replica dispatch, zero-downtime snapshot hot-swap (DESIGN.md §14).

`TopicInferenceServer` answers one batch at a time; a system serving
heavy traffic needs the layer ABOVE it — the thing that decides, every
tick, which queued requests become the next fold-in batch.  That layer
is :class:`ServingScheduler`:

* **admission control** — a bounded FIFO queue; a submission that can't
  be served is rejected immediately with a reason (``queue_full``,
  ``empty``, ``too_long``, ``bad_word_id``) instead of silently queueing
  into unbounded latency.
* **continuous batching** — each :meth:`~ServingScheduler.tick` forms
  fold-in batches from whatever is queued right now (FIFO prefix, capped
  at ``max_batch``), reusing the server's jit-per-bucket pads.  An
  optional ``max_batch_delay`` holds a partial batch to fill, but never
  past the deadline — the no-starvation knob.
* **hot-query cache** — responses are cached keyed on the token
  MULTISET; a hit is bitwise-equal to a fresh fold-in because responses
  are pure functions of (snapshot, multiset, seed) — see the seed
  contract below.
* **multi-replica dispatch** — batches round-robin across ``N`` server
  replicas sharing one snapshot (frozen-model serving is embarrassingly
  data-parallel, §11), so replicas are a pure throughput knob.
* **replica resilience** (DESIGN.md §15) — per-replica consecutive-
  failure circuit breakers (closed → open → half-open probe), bounded
  retry-on-alternate-replica that stays bitwise-invisible (draws are
  keyed on content, not on which replica ran), per-request deadline
  expiry and all-breakers-open load shedding as structured rejections,
  and fingerprint-gated hot-swap that refuses a corrupt candidate while
  the old epoch keeps serving.
* **zero-downtime hot-swap** — :meth:`~ServingScheduler.swap_snapshot`
  installs the next training snapshot as a pointer flip: requests
  admitted before the swap complete on the snapshot they were admitted
  under, new admissions bind the new one, and every response is stamped
  with its swap epoch + snapshot fingerprint.  No queue flush, no
  barrier, no dropped or epoch-mixed response — proven bitwise in
  ``tests/test_scheduler.py``.

**The seed contract.**  Every request's randomness is derived from
``(scheduler seed, snapshot fingerprint, token-multiset digest)`` and
the request's tokens are canonicalized (sorted) before fold-in — topic
mixtures are exchangeable in token order, so the sort is statistically
inert.  With `TopicInferenceServer.infer_with_draws` feeding those
per-request draws into the padded batch (pad invariance makes every
other slot inert), a response is a PURE FUNCTION of (snapshot contents,
token multiset, seed): independent of batch composition, bucket, queue
state, replica, and wall time.  :func:`reference_theta` computes that
function standalone; every scheduler response — batched, cached,
mid-swap, any replica — must equal it bitwise, which is what makes every
scheduler property a bitwise-testable one.

**Time is injected.**  The scheduler never calls ``time`` directly; it
reads a :class:`Clock`.  Tests drive a :class:`VirtualClock` (no
wall-clock sleeps anywhere, fully deterministic replay); the traffic
benchmark and the ``lda_serve`` CLI drive a :class:`WallClock`.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.core import faults
from repro.core.infer import DEFAULT_FOLD_IN_SWEEPS, ModelSnapshot
from repro.data.integrity import CorruptArtifactError
from repro.serve.topic_infer import TopicInferenceServer, bucket_size


# ---------------------------------------------------------------------------
# Injected time
# ---------------------------------------------------------------------------

class Clock:
    """Time-source protocol: ``now() -> float`` seconds and
    ``sleep(dt)``.  Injected so the scheduler is deterministic under a
    virtual clock in tests and runs under wall time in production."""

    def now(self) -> float:
        raise NotImplementedError

    def sleep(self, dt: float) -> None:
        raise NotImplementedError


class WallClock(Clock):
    """Monotonic wall time — the benchmark/CLI clock."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


class VirtualClock(Clock):
    """Deterministic test clock: time moves ONLY when the test (or an
    open-loop replay's idle step) advances it."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def sleep(self, dt: float) -> None:
        self.advance(dt)

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance time by {dt}")
        self._t += float(dt)
        return self._t


# ---------------------------------------------------------------------------
# The seed contract: canonical tokens, multiset digest, per-request draws
# ---------------------------------------------------------------------------

def canonical_tokens(tokens: Sequence[int]) -> np.ndarray:
    """Sorted int32 token ids — the canonical form of a query.  Fold-in
    runs on this form, so two permutations of the same multiset are the
    SAME request (same draws, same response, same cache slot)."""
    return np.sort(np.asarray(tokens, np.int32).ravel())


def multiset_digest(canon: np.ndarray) -> bytes:
    """16-byte identity of a token multiset (sha256 of the canonical
    form).  The cache uses it as the slot key but verifies the stored
    canonical array on every hit, so a collision degrades to a miss —
    never to the wrong answer."""
    return hashlib.sha256(canon.tobytes()).digest()[:16]


def request_draws(seed: int, fingerprint: str, digest: bytes, n: int,
                  num_topics: int, num_sweeps: int):
    """Per-request fold-in randomness: ``(z0 [n], u [num_sweeps, n])``
    derived from (scheduler seed, snapshot fingerprint, multiset digest).
    Including the fingerprint re-keys every request's chain on swap —
    same doc, new model, fresh draws — while keeping the response a pure
    function of content, never of epoch numbering or arrival time."""
    ss = np.random.SeedSequence(
        [int(seed) & 0xFFFFFFFFFFFFFFFF, int(fingerprint, 16),
         int.from_bytes(digest, "big")])
    rng = np.random.default_rng(ss)
    z0 = rng.integers(0, num_topics, size=n).astype(np.int32)
    u = rng.random((num_sweeps, n), dtype=np.float32)
    return z0, u


def reference_theta(snapshot: ModelSnapshot, tokens: Sequence[int], *,
                    sampler: str = "scan",
                    num_sweeps: int = DEFAULT_FOLD_IN_SWEEPS,
                    seed: int = 0) -> np.ndarray:
    """Serve ONE request outside any scheduler: the pure function of
    (snapshot contents, token multiset, seed contract) that every
    scheduler response must equal bitwise — batched or alone, cached or
    fresh, before or after any number of swaps, on any replica.  The
    hot-swap and cache equivalence tests anchor on this."""
    canon = canonical_tokens(tokens)
    z0, u = request_draws(seed, snapshot.fingerprint(),
                          multiset_digest(canon), canon.size,
                          snapshot.num_topics, num_sweeps)
    server = TopicInferenceServer(snapshot, sampler=sampler,
                                  num_sweeps=num_sweeps, seed=seed)
    return server.infer_with_draws([canon], [z0], [u])[0]


# ---------------------------------------------------------------------------
# Hot-query cache
# ---------------------------------------------------------------------------

class QueryCache:
    """LRU response cache keyed on the token multiset.

    Correctness rests on the seed contract, not on trust: an entry is
    only ever written by a fold-in under the CURRENT snapshot, the
    scheduler clears the cache on swap (entries are epoch-bound), and a
    hit verifies the stored canonical token array against the query's
    (collision check) — so a hit is bitwise the fold-in the scheduler
    would otherwise run."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._entries: "OrderedDict[bytes, tuple]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.collisions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, digest: bytes, canon: np.ndarray
            ) -> Optional[np.ndarray]:
        ent = self._entries.get(digest)
        if ent is not None:
            stored, theta = ent
            if stored.shape == canon.shape and \
                    np.array_equal(stored, canon):
                self._entries.move_to_end(digest)
                self.hits += 1
                return theta
            self.collisions += 1         # digest matched, multiset didn't
        self.misses += 1
        return None

    def put(self, digest: bytes, canon: np.ndarray,
            theta: np.ndarray) -> None:
        if self.capacity <= 0:
            return
        self._entries[digest] = (canon, theta)
        self._entries.move_to_end(digest)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._entries.clear()


# ---------------------------------------------------------------------------
# Requests and responses
# ---------------------------------------------------------------------------

REJECT_QUEUE_FULL = "queue_full"
REJECT_EMPTY = "empty"
REJECT_TOO_LONG = "too_long"
REJECT_BAD_WORD = "bad_word_id"
REJECT_SHED = "shed"                       # all replica breakers open
REJECT_DEADLINE = "deadline_expired"       # waited past request_deadline
REJECT_REPLICA = "replica_failure"         # retry budget exhausted

BREAKER_CLOSED = "closed"
BREAKER_OPEN = "open"
BREAKER_HALF_OPEN = "half_open"


@dataclasses.dataclass
class ReplicaHealth:
    """Per-replica circuit breaker (DESIGN.md §15).  CLOSED routes
    traffic normally; ``breaker_threshold`` CONSECUTIVE failures open
    it; an OPEN breaker takes no traffic until ``breaker_cooldown`` has
    passed, then transitions to HALF_OPEN and admits one probe batch —
    success closes it, failure re-opens (and restarts the cooldown).
    Health is keyed on the replica SLOT, not the snapshot epoch: a sick
    process stays sick across hot-swaps."""
    state: str = BREAKER_CLOSED
    consecutive_failures: int = 0
    failures: int = 0
    successes: int = 0
    opens: int = 0
    opened_at: float = 0.0

    def record_failure(self, now: float, threshold: int) -> None:
        self.failures += 1
        self.consecutive_failures += 1
        if self.state == BREAKER_HALF_OPEN or \
                (self.state == BREAKER_CLOSED
                 and self.consecutive_failures >= threshold):
            self.state = BREAKER_OPEN
            self.opened_at = now
            self.opens += 1

    def record_success(self) -> None:
        self.successes += 1
        self.consecutive_failures = 0
        self.state = BREAKER_CLOSED

    def available(self, now: float, cooldown: float) -> bool:
        """Lazy open -> half_open transition: state machines driven by
        the injected clock have no timers, only reads."""
        if self.state == BREAKER_OPEN and now - self.opened_at >= cooldown:
            self.state = BREAKER_HALF_OPEN
        return self.state != BREAKER_OPEN


@dataclasses.dataclass
class _Pending:
    """A queued request: bound to the epoch current at ADMISSION — the
    hot-swap invariant lives here."""
    req_id: int
    canon: np.ndarray
    digest: bytes
    epoch: int
    t_arrival: float
    retries: int = 0


@dataclasses.dataclass
class Response:
    """One answer per submission.  ``epoch``/``fingerprint`` stamp which
    installed snapshot produced ``theta``; timings use the injected
    clock (``t_arrival`` ≤ ``t_dispatch`` ≤ ``t_finish``)."""
    req_id: int
    status: str                        # "ok" | "rejected"
    reason: str = ""                   # rejection reason when rejected
    theta: Optional[np.ndarray] = None
    epoch: int = -1
    fingerprint: str = ""
    replica: int = -1
    cached: bool = False
    t_arrival: float = 0.0
    t_dispatch: float = 0.0
    t_finish: float = 0.0

    @property
    def latency(self) -> float:
        return self.t_finish - self.t_arrival


# ---------------------------------------------------------------------------
# The scheduler
# ---------------------------------------------------------------------------

class ServingScheduler:
    """Event-loop continuous-batching scheduler over
    `TopicInferenceServer` replicas (module docstring; DESIGN.md §14).

    The driving loop is external (`serve/traffic.py` replay, the
    ``lda_serve`` CLI, or a test): call :meth:`submit` as requests
    arrive, :meth:`tick` to let the scheduler act, :meth:`swap_snapshot`
    when training publishes a new model.  Nothing here sleeps or reads
    wall time — all timing flows through the injected clock.
    """

    def __init__(self, snapshot: ModelSnapshot, *, sampler: str = "scan",
                 num_sweeps: int = DEFAULT_FOLD_IN_SWEEPS, seed: int = 0,
                 num_replicas: int = 1, max_queue: int = 64,
                 max_batch: int = 8, max_batch_delay: float = 0.0,
                 max_doc_tokens: Optional[int] = None,
                 cache_capacity: int = 256, clock: Optional[Clock] = None,
                 min_batch_bucket: int = 1, min_token_bucket: int = 8,
                 breaker_threshold: int = 3, breaker_cooldown: float = 1.0,
                 max_retries: int = 2,
                 request_deadline: Optional[float] = None,
                 fault_plan: Optional[faults.FaultPlan] = None):
        if num_replicas < 1:
            raise ValueError(f"num_replicas must be >= 1, got {num_replicas}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.sampler = sampler
        self.num_sweeps = int(num_sweeps)
        self.seed = int(seed)
        self.num_replicas = int(num_replicas)
        self.max_queue = int(max_queue)
        self.max_batch = int(max_batch)
        self.max_batch_delay = float(max_batch_delay)
        self.max_doc_tokens = max_doc_tokens
        self.min_batch_bucket = int(min_batch_bucket)
        self.min_token_bucket = int(min_token_bucket)
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown = float(breaker_cooldown)
        self.max_retries = int(max_retries)
        self.request_deadline = request_deadline
        self.fault_plan = fault_plan
        self.clock = clock if clock is not None else WallClock()
        self.cache = QueryCache(cache_capacity)

        self.epoch = 0
        self._snapshots: Dict[int, ModelSnapshot] = {}
        self._servers: Dict[int, List[TopicInferenceServer]] = {}
        self._fp: Dict[int, str] = {}
        self._install(snapshot)

        self._queue: Deque[_Pending] = deque()
        self._rr = 0                        # round-robin batch counter
        self._next_id = 0
        self.results: Dict[int, Response] = {}
        self.batch_log: List[dict] = []
        self.submitted = 0
        self.admitted = 0
        self.served = 0
        self.cache_hits = 0
        self.swaps = 0
        self.rejections: Dict[str, int] = {}
        # resilience state (DESIGN.md §15): health is per replica SLOT
        self.health = [ReplicaHealth() for _ in range(self.num_replicas)]
        self.retries = 0                   # re-dispatch attempts
        self.replica_failures = 0          # failed dispatch attempts
        self.shed = 0                      # admissions refused: all open
        self.deadline_expired = 0
        self.failed_admitted = 0           # admitted -> structured reject

    # -- model installation / hot-swap ------------------------------------
    def _install(self, snapshot: ModelSnapshot) -> None:
        # replicas share ONE snapshot object, so per-snapshot derived
        # state (alias tables, sparse cumsums) is built once and pointed
        # to N times — a replica is pure compute, not memory
        self._snapshots[self.epoch] = snapshot
        self._fp[self.epoch] = snapshot.fingerprint()
        self._servers[self.epoch] = [
            TopicInferenceServer(snapshot, sampler=self.sampler,
                                 num_sweeps=self.num_sweeps, seed=self.seed,
                                 min_batch_bucket=self.min_batch_bucket,
                                 min_token_bucket=self.min_token_bucket)
            for _ in range(self.num_replicas)]

    def warm(self, max_doc_len: int) -> int:
        """Compile every power-of-two (batch, token) bucket reachable
        for docs up to ``max_doc_len`` — the serving cold-start, done
        once before traffic.  The jit cache is keyed on shapes (the
        snapshot is a runtime argument), so one pass through the current
        epoch's first replica covers every replica AND every snapshot a
        later swap installs.  Returns the bucket count."""
        server = self._servers[self.epoch][0]
        n = 0
        qb = 1
        q_cap = bucket_size(self.max_batch, self.min_batch_bucket)
        t_cap = bucket_size(max(int(max_doc_len), 1),
                            self.min_token_bucket)
        while qb <= q_cap:
            tb = self.min_token_bucket
            while tb <= t_cap:
                server.infer([np.zeros(tb, np.int32)] * qb)
                n += 1
                tb <<= 1
            qb <<= 1
        return n

    def swap_snapshot(self, snapshot: ModelSnapshot,
                      expect_fingerprint: Optional[str] = None) -> int:
        """Install the next training snapshot with zero downtime.

        A pointer flip: the new epoch's replicas are created, new
        admissions bind them immediately, and requests already admitted
        (queued or in flight) complete against the snapshot stamped on
        them at admission — the old epoch's servers are released only
        once its last queued request drains.  The cache is cleared: its
        entries answer for the previous fingerprint.  Returns the new
        epoch.

        ``expect_fingerprint`` is the swap's integrity gate (§15): when
        the caller knows what it exported (trainer-published manifest),
        a candidate whose content fingerprint disagrees — torn copy, bit
        rot, wrong file — is REFUSED with :class:`CorruptArtifactError`
        before any state changes, and the old epoch keeps serving."""
        if expect_fingerprint is not None:
            got = snapshot.fingerprint()
            if got != expect_fingerprint:
                raise CorruptArtifactError(
                    "<candidate snapshot>",
                    f"snapshot fingerprint {got} != expected "
                    f"{expect_fingerprint}; refusing hot-swap")
        self.epoch += 1
        self._install(snapshot)
        self.cache.clear()
        self.swaps += 1
        self._release_drained_epochs()
        return self.epoch

    def _release_drained_epochs(self) -> None:
        live = {p.epoch for p in self._queue} | {self.epoch}
        for e in [e for e in self._servers if e not in live]:
            del self._servers[e]
            del self._snapshots[e]

    @property
    def snapshot(self) -> ModelSnapshot:
        return self._snapshots[self.epoch]

    @property
    def fingerprint(self) -> str:
        return self._fp[self.epoch]

    @property
    def pending(self) -> int:
        return len(self._queue)

    # -- admission ---------------------------------------------------------
    def _reject(self, rid: int, reason: str, now: float) -> int:
        self.rejections[reason] = self.rejections.get(reason, 0) + 1
        self.results[rid] = Response(rid, "rejected", reason=reason,
                                     epoch=self.epoch, t_arrival=now,
                                     t_dispatch=now, t_finish=now)
        return rid

    def submit(self, tokens: Sequence[int],
               now: Optional[float] = None) -> int:
        """Admit (or reject) one query; returns its request id.  The
        outcome lands in ``results[rid]`` — immediately for rejections
        and cache hits, after a future tick otherwise.  ``now`` defaults
        to the clock but is overridable so an open-loop replay can stamp
        the SCHEDULED arrival time (queueing delay then shows up in
        latency even when the submitting loop itself fell behind)."""
        now = float(self.clock.now() if now is None else now)
        rid = self._next_id
        self._next_id += 1
        self.submitted += 1
        tokens = np.asarray(tokens, np.int32).ravel()
        if tokens.size == 0:
            return self._reject(rid, REJECT_EMPTY, now)
        if self.max_doc_tokens is not None and \
                tokens.size > self.max_doc_tokens:
            return self._reject(rid, REJECT_TOO_LONG, now)
        # ids must index the RESIDENT rows (row-restricted snapshots
        # serve remapped ids, so the bound is the local row count)
        if int(tokens.min()) < 0 or \
                int(tokens.max()) >= self.snapshot.vocab_size:
            return self._reject(rid, REJECT_BAD_WORD, now)
        canon = canonical_tokens(tokens)
        digest = multiset_digest(canon)
        theta = self.cache.get(digest, canon)
        if theta is not None:
            # a hit costs no queue slot, so hot queries are served even
            # when admission is otherwise rejecting (overload shedding
            # never sheds the traffic the cache already paid for)
            self.admitted += 1
            self.served += 1
            self.cache_hits += 1
            self.results[rid] = Response(
                rid, "ok", theta=theta.copy(), epoch=self.epoch,
                fingerprint=self._fp[self.epoch], cached=True,
                t_arrival=now, t_dispatch=now, t_finish=now)
            return rid
        if len(self._queue) >= self.max_queue:
            return self._reject(rid, REJECT_QUEUE_FULL, now)
        if not self._available_replicas(now):
            # load shedding: every breaker is open, so an admission now
            # could only rot in the queue — refuse it loudly instead.
            # (After the cache check on purpose: hits cost no replica.)
            self.shed += 1
            return self._reject(rid, REJECT_SHED, now)
        self.admitted += 1
        self._queue.append(_Pending(rid, canon, digest, self.epoch, now))
        return rid

    # -- the event loop body ----------------------------------------------
    def tick(self, flush: bool = False) -> List[Response]:
        """Dispatch every batch that is ready NOW; returns the responses
        completed this tick, in FIFO order.

        A batch is the FIFO prefix of the queue sharing the head's epoch
        (a fold-in binds exactly one snapshot), capped at ``max_batch``.
        It dispatches when it is full, when its oldest member has waited
        ``max_batch_delay`` (the starvation deadline), when its epoch is
        closed (a swap happened, so the group can never grow), or when
        ``flush`` forces it.  With ``max_batch_delay == 0`` every tick
        serves everything queued — pure continuous batching."""
        out: List[Response] = []
        self._expire_deadlines(self.clock.now())
        while self._queue:
            now = self.clock.now()
            if not self._available_replicas(now):
                break                     # every breaker open: hold FIFO
            head = self._queue[0]
            group = 1
            while (group < len(self._queue) and group < self.max_batch
                   and self._queue[group].epoch == head.epoch):
                group += 1
            epoch_closed = head.epoch != self.epoch
            if not (flush or epoch_closed or group >= self.max_batch
                    or now - head.t_arrival >= self.max_batch_delay):
                break
            batch = [self._queue.popleft() for _ in range(group)]
            responses, ok = self._run_batch(batch, now)
            out.extend(responses)
            if not ok:
                # total dispatch failure: survivors are back at the queue
                # head; stop this tick so one tick can't spin forever on
                # a batch no replica will take
                break
        self._release_drained_epochs()
        return out

    # -- resilience --------------------------------------------------------
    def _available_replicas(self, now: float) -> List[int]:
        return [i for i, h in enumerate(self.health)
                if h.available(now, self.breaker_cooldown)]

    def _expire_deadlines(self, now: float) -> None:
        if self.request_deadline is None:
            return
        keep: Deque[_Pending] = deque()
        for p in self._queue:
            if now - p.t_arrival >= self.request_deadline:
                self.deadline_expired += 1
                self._reject_admitted(p, REJECT_DEADLINE, now)
            else:
                keep.append(p)
        self._queue = keep

    def _reject_admitted(self, p: _Pending, reason: str,
                         now: float) -> None:
        """Structured post-admission rejection: the request got a queue
        slot but the system could not serve it (deadline passed, retry
        budget exhausted).  Counted separately from admission-time
        rejects so ``dropped()`` still means 'vanished without ANY
        outcome'."""
        self.rejections[reason] = self.rejections.get(reason, 0) + 1
        self.failed_admitted += 1
        self.results[p.req_id] = Response(
            p.req_id, "rejected", reason=reason, epoch=p.epoch,
            t_arrival=p.t_arrival, t_dispatch=now, t_finish=now)

    def _fire_replica(self, replica: int, epoch: int) -> None:
        """Fault-injection hook around one dispatch attempt: scripted
        replica failures raise here; scripted slowness is charged to the
        injected clock (latency, not error)."""
        detail = f"replica:{replica},epoch:{epoch}"
        plan = self.fault_plan if self.fault_plan is not None \
            else faults.active()
        if plan is None:
            return
        dt = plan.delay("replica", detail)
        if dt > 0:
            self.clock.sleep(dt)
        plan.fire("replica", detail)

    def drain(self) -> List[Response]:
        """Force-dispatch everything queued (end of a replay)."""
        return self.tick(flush=True)

    def _run_batch(self, batch: List[_Pending],
                   t_dispatch: float) -> "tuple[List[Response], bool]":
        """Dispatch one batch, retrying on alternate replicas on failure.

        Returns ``(responses, ok)``.  Retries are bitwise-invisible: the
        draws are keyed on (seed, fingerprint, digest) — never on which
        replica ran — so the answer from attempt 3 on replica 2 is the
        answer attempt 1 would have produced (pinned by
        ``tests/test_scheduler_resilience.py``).  When every available
        replica fails, each request's retry budget is charged: survivors
        requeue at the FRONT (FIFO order preserved), exhausted ones get
        a structured ``replica_failure`` rejection."""
        epoch = batch[0].epoch
        assert all(p.epoch == epoch for p in batch)   # one snapshot/batch
        servers = self._servers[epoch]
        fp = self._fp[epoch]
        docs = [p.canon for p in batch]
        draws = [request_draws(self.seed, fp, p.digest, p.canon.size,
                               servers[0].snapshot.num_topics,
                               self.num_sweeps)
                 for p in batch]
        avail = self._available_replicas(t_dispatch)
        start = self._rr % max(len(avail), 1)
        self._rr += 1
        candidates = avail[start:] + avail[:start]
        theta = None
        replica = -1
        for attempt, rid in enumerate(candidates):
            if attempt > 0:
                self.retries += 1
            now = self.clock.now()
            try:
                self._fire_replica(rid, epoch)
                theta = servers[rid].infer_with_draws(
                    docs, [d[0] for d in draws], [d[1] for d in draws])
            except Exception:
                self.replica_failures += 1
                self.health[rid].record_failure(now,
                                                self.breaker_threshold)
                continue
            self.health[rid].record_success()
            replica = rid
            break
        if theta is None:
            # every available replica refused this batch: charge each
            # request's retry budget and requeue the survivors in order
            now = self.clock.now()
            survivors = []
            for p in batch:
                p.retries += 1
                if p.retries > self.max_retries:
                    self._reject_admitted(p, REJECT_REPLICA, now)
                else:
                    survivors.append(p)
            self._queue.extendleft(reversed(survivors))
            return [], False
        t_finish = self.clock.now()
        self.batch_log.append({
            "epoch": epoch, "size": len(batch), "replica": replica,
            "bucket": servers[replica].bucket_shape(docs),
            "t_dispatch": t_dispatch})
        responses = []
        for i, p in enumerate(batch):
            resp = Response(p.req_id, "ok", theta=theta[i], epoch=epoch,
                            fingerprint=fp, replica=replica, cached=False,
                            t_arrival=p.t_arrival, t_dispatch=t_dispatch,
                            t_finish=t_finish)
            self.results[p.req_id] = resp
            responses.append(resp)
            self.served += 1
            if epoch == self.epoch:      # never cache for a dead epoch
                self.cache.put(p.digest, p.canon, theta[i])
        return responses, True

    # -- observability -----------------------------------------------------
    def ok_responses(self) -> List[Response]:
        return [r for r in self.results.values() if r.status == "ok"]

    def dropped(self) -> int:
        """Admitted requests that vanished with NO outcome — neither an
        ok response nor a structured post-admission rejection.  MUST be
        zero once the queue drains (the hot-swap acceptance criterion):
        even under replica failures and deadline expiry, every admitted
        request gets a definite answer."""
        return (self.admitted - len(self.ok_responses())
                - self.failed_admitted)

    def latency_summary(self) -> dict:
        lat = np.asarray([r.latency for r in self.ok_responses()])
        if lat.size == 0:
            return {"served": 0, "p50_ms": float("nan"),
                    "p99_ms": float("nan")}
        return {"served": int(lat.size),
                "p50_ms": float(np.percentile(lat, 50) * 1e3),
                "p99_ms": float(np.percentile(lat, 99) * 1e3)}

    def stats(self) -> dict:
        return {
            "epoch": self.epoch,
            "fingerprint": self.fingerprint,
            "submitted": self.submitted,
            "admitted": self.admitted,
            "served": self.served,
            "dropped": self.dropped(),
            "queue_depth": len(self._queue),
            "batches": len(self.batch_log),
            "swaps": self.swaps,
            "rejections": dict(self.rejections),
            "cache": {"hits": self.cache.hits, "misses": self.cache.misses,
                      "evictions": self.cache.evictions,
                      "collisions": self.cache.collisions,
                      "size": len(self.cache)},
            "faults": {"retries": self.retries,
                       "replica_failures": self.replica_failures,
                       "breaker_opens": sum(h.opens for h in self.health),
                       "shed": self.shed,
                       "deadline_expired": self.deadline_expired,
                       "failed_admitted": self.failed_admitted},
            "replicas": [{"state": h.state,
                          "failures": h.failures,
                          "successes": h.successes,
                          "opens": h.opens,
                          "consecutive_failures": h.consecutive_failures}
                         for h in self.health],
        }


__all__ = ["Clock", "WallClock", "VirtualClock", "QueryCache", "Response",
           "ReplicaHealth", "ServingScheduler", "bucket_size",
           "canonical_tokens", "multiset_digest", "request_draws",
           "reference_theta",
           "REJECT_QUEUE_FULL", "REJECT_EMPTY", "REJECT_TOO_LONG",
           "REJECT_BAD_WORD", "REJECT_SHED", "REJECT_DEADLINE",
           "REJECT_REPLICA",
           "BREAKER_CLOSED", "BREAKER_OPEN", "BREAKER_HALF_OPEN"]
