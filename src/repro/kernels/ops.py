"""Jitted public wrappers around the Pallas kernels.

Handles padding to tile boundaries, platform selection (interpret mode off
TPU), the word-grouped token layout, and the engine-facing samplers that
plug into ``core.model_parallel``: ``sweep_block_pallas`` (exact
Gibbs-conditional kernel) and the fused alias-MH cycle pair
``sweep_block_mh_pallas`` / ``sweep_block_mh_pallas_tables`` (round vs
iteration table lifetime, DESIGN.md §10).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alias import unpack_tables
from repro.core.mh import (DEFAULT_MH_CYCLES, block_proposal_tables,
                           uniform_streams)
from repro.kernels.gibbs_conditional import (TILE_G, TILE_T,
                                             gibbs_conditional_call)
from repro.kernels.mh_alias import mh_cycle_call
from repro.kernels.ref import gibbs_conditional_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("tile_g", "tile_t", "interpret"))
def gibbs_conditional(ckt_group, cdk_rows, z_old, u, mask, ck, alpha,
                      beta, vbeta, tile_g: int = TILE_G, tile_t: int = TILE_T,
                      interpret: bool | None = None) -> jax.Array:
    """Padded, platform-aware kernel call.  Shapes: see kernel docstring.

    Padding guarantees zero mass on fake topics (α/C_d^k pads are 0) and
    no-ops on fake tokens (mask pads are 0), so results are unaffected.
    """
    if interpret is None:
        interpret = not _on_tpu()
    g0, t0 = z_old.shape
    k0 = ck.shape[0]
    ckt_group = _pad_to(_pad_to(ckt_group.astype(jnp.float32), 1, 128), 0, tile_g)
    cdk_rows = _pad_to(_pad_to(cdk_rows.astype(jnp.float32), 2, 128), 0, tile_g)
    z_old_p = _pad_to(z_old, 0, tile_g)
    u_p = _pad_to(u, 0, tile_g)
    mask_p = _pad_to(mask.astype(jnp.int32), 0, tile_g)
    ck_p = _pad_to(ck.astype(jnp.float32), 0, 128)
    alpha_p = _pad_to(alpha.astype(jnp.float32), 0, 128)
    out = gibbs_conditional_call(ckt_group, cdk_rows, z_old_p, u_p, mask_p,
                                 ck_p, alpha_p, beta, vbeta,
                                 tile_g=tile_g, tile_t=t0,
                                 interpret=interpret)
    return out[:g0, :t0]


def group_tokens_by_word(word_off: np.ndarray, group_width: int
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host helper: chunk word-sorted tokens into ``[G, Tg]`` word groups.

    ``word_off`` must be sorted (inverted-index order).  Each group holds up
    to ``group_width`` tokens of ONE word; long postings split into several
    groups (each still word-pure, so the per-group coeff cache stays exact).

    Returns (group_word [G], position [G, Tg] indices into the token array,
    mask [G, Tg]).
    """
    word_off = np.asarray(word_off)
    n = word_off.shape[0]
    groups_w, groups_pos = [], []
    i = 0
    while i < n:
        w = word_off[i]
        j = i
        while j < n and word_off[j] == w and j - i < group_width:
            j += 1
        groups_w.append(int(w))
        groups_pos.append(np.arange(i, j))
        i = j
    g = max(len(groups_w), 1)
    gw = np.zeros(g, np.int32)
    pos = np.zeros((g, group_width), np.int32)
    msk = np.zeros((g, group_width), bool)
    for gi, (w, p) in enumerate(zip(groups_w, groups_pos)):
        gw[gi] = w
        pos[gi, :len(p)] = p
        msk[gi, :len(p)] = True
    return gw, pos, msk


@jax.jit
def sweep_block_pallas(cdk, ckt_block, ck, doc, word_off, z, mask, u,
                       alpha, beta, vbeta):
    """Engine-facing sampler: same signature/semantics as
    ``core.sampler.sweep_block_batched`` but with the conditional evaluated
    by the Pallas kernel (token-per-group layout; the word-grouped layout is
    exercised by ``gibbs_conditional`` directly in benchmarks/tests).

    Bit-identical to the ``batched`` sampler mode given the same uniforms —
    asserted by tests — so the kernel slots into the model-parallel engine
    without changing its convergence behaviour.
    """
    k = ck.shape[0]
    ckt_rows = ckt_block[word_off].astype(jnp.float32)        # [T, K]
    cdk_rows = cdk[doc].astype(jnp.float32)[:, None, :]       # [T, 1, K]
    z_new = gibbs_conditional(
        ckt_rows, cdk_rows, z[:, None], u[:, None],
        mask[:, None], ck.astype(jnp.float32), alpha,
        beta, vbeta, tile_g=128)[:, 0]
    z_new = jnp.where(mask, z_new, z)
    delta = mask.astype(jnp.int32)
    onehot_old = jax.nn.one_hot(z, k, dtype=jnp.int32) * delta[:, None]
    onehot_new = jax.nn.one_hot(z_new, k, dtype=jnp.int32) * delta[:, None]
    dk = onehot_new - onehot_old
    cdk = cdk.at[doc].add(dk)
    ckt_block = ckt_block.at[word_off].add(dk)
    ck = ck + dk.sum(axis=0)
    return cdk, ckt_block, ck, z_new


def _mh_cycle_pallas_core(cdk, ckt_block, ck, doc, word_off, z, mask, u,
                          alpha, beta, vbeta, word_table, doc_table,
                          num_cycles, interpret):
    """Shared fused-kernel body: pad/gather the per-token operand rows,
    run the FULL MH cycle in one ``mh_cycle_call``, fold count deltas.

    Token-per-group degenerate layout (like ``sweep_block_pallas``): the
    per-token row gathers materialize [T, K] operands, so this path
    trades memory for exercising the kernel end-to-end — it is the
    VALIDATION route for the kernel math; ``mh`` remains the throughput
    mode (never materializes [T, K]).  The word-grouped [G, Tg > 1]
    VMEM-reuse layout the kernel is designed around is exercised on
    ``mh_cycle_call`` directly by
    ``tests/test_alias.py::test_mh_cycle_kernel_word_grouped_layout``
    (multi-tile grid, bit-checked against the jnp cycle).
    """
    t0 = z.shape[0]
    k0 = ck.shape[0]
    ckt_f = ckt_block.astype(jnp.float32)
    cdk_f = cdk.astype(jnp.float32)
    ck_f = ck.astype(jnp.float32)
    wcut, walias, wu, wmass = word_table
    dcut, dalias, du, dmass = doc_table
    streams = uniform_streams(u, 4 * num_cycles)

    # per-token rows, padded to kernel tiles (pads never drawn: the alias
    # cell index is clamped to the REAL K inside the kernel)
    tile_g = 128
    pad2 = lambda x: _pad_to(_pad_to(x, 1, 128), 0, tile_g)
    pad3 = lambda x: _pad_to(_pad_to(x, 1, 128)[:, None, :], 0, tile_g)
    z_new = mh_cycle_call(
        pad2(wcut[word_off]), pad2(walias[word_off]),
        pad2(wmass[word_off].astype(jnp.float32)),
        _pad_to(wu[word_off], 0, tile_g)[:, None],
        pad3(dcut[doc]), pad3(dalias[doc]),
        pad3(dmass[doc].astype(jnp.float32)),
        _pad_to(du[doc], 0, tile_g)[:, None],
        pad2(ckt_f[word_off]), pad3(cdk_f[doc]),
        _pad_to(z, 0, tile_g)[:, None],
        _pad_to(streams, 1, tile_g)[:, :, None],
        _pad_to(mask.astype(jnp.int32), 0, tile_g)[:, None],
        _pad_to(ck_f, 0, 128), _pad_to(alpha.astype(jnp.float32), 0, 128),
        beta, vbeta, k_real=k0, num_cycles=num_cycles,
        tile_g=tile_g, interpret=interpret)[:t0, 0]

    z_new = jnp.where(mask, z_new, z)
    delta = mask.astype(jnp.int32)
    cdk = cdk.at[doc, z].add(-delta).at[doc, z_new].add(delta)
    ckt_block = ckt_block.at[word_off, z].add(-delta) \
                         .at[word_off, z_new].add(delta)
    ck = ck.at[z].add(-delta).at[z_new].add(delta)
    return cdk, ckt_block, ck, z_new


@functools.partial(jax.jit, static_argnames=("num_cycles", "interpret"))
def sweep_block_mh_pallas(cdk, ckt_block, ck, doc, word_off, z, mask, u,
                          alpha, beta, vbeta,
                          num_cycles: int = DEFAULT_MH_CYCLES,
                          interpret: bool | None = None):
    """Engine-facing alias-MH sampler with the WHOLE cycle — word
    proposal, doc proposal, both acceptances, all ``num_cycles`` times —
    fused into one Pallas kernel (``kernels/mh_alias.py``).  Same
    signature/semantics as ``core.mh.sweep_block_mh`` (round table
    lifetime: tables built fresh per call, shared prologue) and
    bit-identical to it given the same uniforms (asserted by tests), so
    the kernel slots into the engine without changing the chain's
    distribution.
    """
    if interpret is None:
        interpret = not _on_tpu()
    word_table, doc_table = block_proposal_tables(cdk, ckt_block, alpha,
                                                  beta)
    return _mh_cycle_pallas_core(cdk, ckt_block, ck, doc, word_off, z,
                                 mask, u, alpha, beta, vbeta, word_table,
                                 doc_table, num_cycles, interpret)


@functools.partial(jax.jit, static_argnames=("num_cycles", "interpret"))
def sweep_block_mh_pallas_tables(cdk, ckt_block, ck, doc, word_off, z,
                                 mask, u, alpha, beta, vbeta,
                                 word_packed, doc_packed,
                                 num_cycles: int = DEFAULT_MH_CYCLES,
                                 interpret: bool | None = None):
    """Table-aware form of :func:`sweep_block_mh_pallas` (iteration table
    lifetime, DESIGN.md §10): consumes the engine's packed traveling word
    table and per-iteration doc table instead of building its own — the
    fused-cycle analogue of ``core.mh.sweep_block_mh_tables`` and
    bit-identical to it given the same uniforms and tables.
    """
    if interpret is None:
        interpret = not _on_tpu()
    return _mh_cycle_pallas_core(cdk, ckt_block, ck, doc, word_off, z,
                                 mask, u, alpha, beta, vbeta,
                                 unpack_tables(word_packed),
                                 unpack_tables(doc_packed), num_cycles,
                                 interpret)


@functools.partial(jax.jit, static_argnames=("dcap", "wcap", "interpret"))
def sweep_block_sparse_pallas(cdk, ckt_block, ck, doc, word_off, z, mask,
                              u, alpha, beta, vbeta, dcap: int,
                              wcap: int, interpret: bool | None = None):
    """Engine-facing hybrid sparse sampler with the lane block — segment
    masses, prefix sums, counted draws, segment select — run in the
    Pallas kernel (``kernels/sparse_gibbs.py``).  Same signature and
    frozen-count semantics as ``core.sparse_device.sweep_block_sparse``
    and bit-identical to it given the same uniforms (asserted by tests):
    the round-frozen prologue and the dense-segment epilogue are the
    SHARED jnp functions, and the kernel mirrors the jnp lane block op
    for op.
    """
    from repro.core.sparse_device import sparse_epilogue, sparse_prologue
    from repro.kernels.sparse_gibbs import sparse_lane_call
    if interpret is None:
        interpret = not _on_tpu()
    ops = sparse_prologue(cdk, ckt_block, ck, doc, word_off, z, mask,
                          alpha, beta, vbeta, dcap, wcap)
    z_lane, is_dense, ydense = sparse_lane_call(
        ops["wops"], ops["dops"], ops["h_t"], z, mask, u, ops["sdense"],
        beta, vbeta, interpret=interpret)
    return sparse_epilogue(ops, z_lane, is_dense, ydense, cdk, ckt_block,
                           ck, doc, word_off, z, mask)
