"""Inject the generated dry-run/roofline tables + hillclimb A/B rows into
EXPERIMENTS.md (replaces the <!-- ... --> placeholders).

    PYTHONPATH=src python scripts/finalize_experiments.py
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.roofline.report import dryrun_table, load, roofline_table  # noqa: E402

OUT = "benchmarks/results/dryrun"
EXP = "EXPERIMENTS.md"


def lda_table() -> str:
    rows = ["| workload | model vars | mem/worker GiB | compute s | "
            "memory s | collective s | rotation GB/iter |",
            "|---|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(os.path.join(OUT, "lda__*.json"))):
        r = json.load(open(path))
        t = r["roofline"]
        rows.append(
            f"| {r['workload']} | {r['model_variables']:.2e} | "
            f"{r['memory']['total_gib_per_device']} | "
            f"{t['compute_s']:.2e} | {t['memory_s']:.2e} | "
            f"{t['collective_s']:.2e} | "
            f"{r['analytic_rotation_bytes_per_iter']/1e9:.2f} |")
    return "\n".join(rows)


def hillclimb_rows() -> str:
    combos = [
        ("hymba-1.5b", "train_4k",
         ["", "accum8", "accum16", "accum8_ssd64"]),
        ("llava-next-mistral-7b", "decode_32k",
         ["", "tpw", "tpw_bf16", "repkv_tpw_bf16", "repkv16_tpw_bf16"]),
        ("qwen2-moe-a2.7b", "train_4k",
         ["", "nofsdp", "bf16params", "pad64", "pad64_bf16"]),
    ]
    out = []
    for arch, shape, tags in combos:
        out.append(f"\n**{arch} × {shape}**\n")
        out.append("| variant | mem/dev GiB | compute s | memory s | "
                   "collective s | dominant |")
        out.append("|---|---|---|---|---|---|")
        for tag in tags:
            suffix = f"__{tag}" if tag else ""
            path = os.path.join(OUT, f"{arch}__{shape}__pod{suffix}.json")
            if not os.path.exists(path):
                out.append(f"| {tag or 'baseline'} | (missing) | | | | |")
                continue
            r = json.load(open(path))
            if r["status"] != "ok":
                out.append(f"| {tag or 'baseline'} | {r['status']} | | | | |")
                continue
            t = r["roofline"]
            out.append(
                f"| {tag or 'baseline'} | "
                f"{r['memory']['total_gib_per_device']} | "
                f"{t['compute_s']:.2e} | {t['memory_s']:.2e} | "
                f"{t['collective_s']:.2e} | "
                f"{t['dominant'].replace('_s','')} |")
    return "\n".join(out)


def main() -> None:
    # baseline records = files named exactly <arch>__<shape>__<mesh>.json
    base = []
    for path in sorted(glob.glob(os.path.join(OUT, "*.json"))):
        stem = os.path.basename(path)[:-5]
        if stem.startswith("lda__") or len(stem.split("__")) != 3:
            continue
        base.append(json.load(open(path)))
    text = open(EXP).read()
    dr = ("### Single-pod (16×16 = 256 chips)\n\n"
          + dryrun_table(base, "pod")
          + "\n\n### Multi-pod (2×16×16 = 512 chips, compile-only pass)\n\n"
          + dryrun_table(base, "2pod"))
    rt = roofline_table(base, "pod")
    text = text.replace("<!-- DRYRUN_TABLES -->", dr)
    text = text.replace("<!-- ROOFLINE_TABLES -->", rt)
    text = text.replace("<!-- PERF_LOG -->",
                        "### Hillclimb A/B measurements\n" + hillclimb_rows())
    text = text.replace("<!-- PERF_LDA -->",
                        "Paper workloads on the 64-worker ring "
                        "(one iteration, batched sampler):\n\n" + lda_table())
    open(EXP, "w").write(text)
    print("EXPERIMENTS.md finalized")


if __name__ == "__main__":
    main()
