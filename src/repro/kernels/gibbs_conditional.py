"""Pallas TPU kernel for the word-major Gibbs conditional (paper eq. 3).

The hot loop of model-parallel LDA is evaluating

    p(z = k) ∝ X_k + Y_k,
    X_k = coeff_k · α_k,   Y_k = coeff_k · C_d^k,
    coeff_k = (C_k^t + β) / (C_k + Vβ)

for every token of the current word block and drawing from it.  The paper's
CPU implementation caches ``coeff``/``Σ X_k`` per *word* because the
inverted index visits tokens word-major.  The TPU translation of that cache
is VMEM reuse: tokens are laid out in word groups ``[G, Tg]``, the kernel
loads each word's ``C^t_k`` row HBM→VMEM **once per group tile** and hits it
``Tg`` times, computing ``coeff`` once per word (rows of the tile) and only
the document-dependent ``Y`` per token — eq. (3)'s exact split of
word-shared vs token-private work.

The ``¬dn`` self-exclusion is a rank-1 correction at ``k = z_old``:
only that topic's numerator counts and the denominator total change, so the
kernel computes the cached base mass and patches the single index, keeping
the per-word cache valid (the kernel analogue of the paper's "O(1)
maintenance" of the cache).

Sampling is inverse-CDF over the K lanes: a cumulative sum along the topic
axis and the first index exceeding ``u · total``.  K is padded to the
128-lane boundary; padded topics receive exactly zero mass (α and C_d^k
pads are zero).

The kernel is TPU-targeted (MXU-free, pure VPU) and validated on CPU via
``interpret=True``; ``ops.py`` selects that automatically off-TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# Tile defaults: one grid step processes TILE_G word groups × TILE_T tokens
# against the full (padded) topic axis.  VMEM @ K=10240, f32:
#   cdk tile 8×8×10240×4B ≈ 2.6 MB, plus p/cumsum temporaries ≈ 8 MB — well
#   inside v5e VMEM while leaving room for double buffering.
TILE_G = 8
TILE_T = 8


def _gibbs_kernel(ckt_ref, cdk_ref, zold_ref, u_ref, mask_ref,
                  ck_ref, alpha_ref, const_ref, out_ref):
    beta = const_ref[0, 0]
    vbeta = const_ref[0, 1]
    ck = ck_ref[0, :]                      # [K]   topic totals (local view)
    alpha = alpha_ref[0, :]                # [K]
    ckt = ckt_ref[...]                     # [G, K] one C^t_k row per word
    cdk = cdk_ref[...]                     # [G, T, K] raw C_d^k rows
    z_old = zold_ref[...]                  # [G, T]
    u = u_ref[...]                         # [G, T]
    mask = mask_ref[...]                   # [G, T] int32 validity

    g, t, k = cdk.shape
    # ---- word-shared work: the eq-(3) cache, once per word row ----------
    denom = ck + vbeta                     # [K]
    coeff = (ckt + beta) / denom[None, :]  # [G, K]
    # ---- token-private work ---------------------------------------------
    base = coeff[:, None, :] * (alpha[None, None, :] + cdk)      # [G, T, K]
    # rank-1 ¬dn correction at k == z_old: numerators and the total drop by 1
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (g, t, k), 2)
    is_old = k_iota == z_old[:, :, None]
    corrected = ((ckt[:, None, :] - 1.0 + beta)
                 * (alpha[None, None, :] + cdk - 1.0)
                 / (ck[None, None, :] - 1.0 + vbeta))
    p = jnp.where(is_old, corrected, base)
    p = jnp.maximum(p, 0.0)                # guards padded/empty rows
    # ---- inverse-CDF draw over the topic lanes ---------------------------
    # counted form (see core.sampler.sample_from_mass): exact at u == 1.0
    # and on all-zero mass rows, where argmax silently returned topic 0
    cum = jnp.cumsum(p, axis=-1)
    total = cum[:, :, -1:]
    idx = jnp.sum((cum <= u[:, :, None] * total).astype(jnp.int32), axis=-1)
    last = jnp.sum((cum < total).astype(jnp.int32), axis=-1)
    z_new = jnp.minimum(idx, last).astype(jnp.int32)
    out_ref[...] = jnp.where(mask != 0, z_new, z_old)


@functools.partial(jax.jit, static_argnames=("tile_g", "tile_t", "interpret"))
def gibbs_conditional_call(ckt_group: jax.Array, cdk_rows: jax.Array,
                           z_old: jax.Array, u: jax.Array, mask: jax.Array,
                           ck: jax.Array, alpha: jax.Array,
                           beta: float, vbeta: float,
                           tile_g: int = TILE_G, tile_t: int = TILE_T,
                           interpret: bool = True) -> jax.Array:
    """Raw pallas_call wrapper (no padding — shapes must be tile-aligned).

    Args:
      ckt_group: [G, K] f32 — word-topic row per word group.
      cdk_rows:  [G, Tg, K] f32 — document-topic row per token (raw counts).
      z_old/u/mask: [G, Tg] current assignments, uniforms, validity.
      ck/alpha:  [K] f32.
    Returns:
      z_new [G, Tg] int32.
    """
    g, tg, k = cdk_rows.shape
    assert g % tile_g == 0 and k % 128 == 0, (g, k)
    grid = (g // tile_g,)
    consts = jnp.array([[beta, vbeta]], jnp.float32)
    row = lambda i: (i, 0)
    row3 = lambda i: (i, 0, 0)
    rep = lambda i: (0, 0)
    return pl.pallas_call(
        _gibbs_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_g, k), row),            # ckt_group
            pl.BlockSpec((tile_g, tg, k), row3),       # cdk_rows
            pl.BlockSpec((tile_g, tg), row),           # z_old
            pl.BlockSpec((tile_g, tg), row),           # u
            pl.BlockSpec((tile_g, tg), row),           # mask
            pl.BlockSpec((1, k), rep),                 # ck (broadcast)
            pl.BlockSpec((1, k), rep),                 # alpha (broadcast)
            pl.BlockSpec((1, 2), rep),                 # (beta, vbeta)
        ],
        out_specs=pl.BlockSpec((tile_g, tg), row),
        out_shape=jax.ShapeDtypeStruct((g, tg), jnp.int32),
        interpret=interpret,
    )(ckt_group, cdk_rows, z_old.astype(jnp.int32),
      u.astype(jnp.float32), mask.astype(jnp.int32),
      ck[None, :].astype(jnp.float32), alpha[None, :].astype(jnp.float32),
      consts)
