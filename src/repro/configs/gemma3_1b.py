"""Gemma-3-1B [hf:google/gemma-3-1b-pt].

26L dense, d 1152, 4 heads (GQA kv=1, head_dim 256), d_ff 6912,
vocab 262144; 5 sliding-window layers (W=1024) per 1 global layer, 128k
(extended 500k here) context.  The SWA pattern + single-query decode on
global layers is sub-quadratic per token ⇒ long_500k runs."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    d_ff=6912,
    vocab_size=262144,
    head_dim=256,
    rope_theta=1_000_000.0,
    sliding_window=1024,
    global_every=6,
    norm="rms",
    tie_embeddings=True,
    subquadratic_decode=True,
)
