"""Dry-run of the model-parallel LDA engine itself at PAPER scale on the
production mesh — the reproduction's §Roofline row for the paper's own
workload.

    PYTHONPATH=src python -m repro.launch.lda_dryrun --config wiki-unigram-k5000
    PYTHONPATH=src python -m repro.launch.lda_dryrun --all
    PYTHONPATH=src python -m repro.launch.lda_dryrun --blocks-per-worker 4
    PYTHONPATH=src python -m repro.launch.lda_dryrun --data-parallel 8

Lowers one full iteration (S·M rounds: sample resident block -> ppermute
resident block -> psum C_k) of the shard_map engine against
ShapeDtypeStruct state at the paper's V/K/token counts, on a 64-worker
ring (the paper's Table-1 cluster) mapped onto v5e chips, and reports
memory per worker, collective bytes (the block-rotation traffic), and
roofline terms.  ``--blocks-per-worker`` (S) pipelines ``S·M`` vocabulary
blocks through the ring, shrinking the resident block ``S``-fold
(DESIGN.md §3).
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs.lda_paper import LDA_CONFIGS
from repro.core.engine.backends import \
    make_shard_map_iteration as _iteration_shard_map
from repro.core.schedule import partition_vocab
from repro.launch.mesh import make_lda_mesh
from repro.roofline import analysis as roofline


def run(cfg_name: str, workers: int = 64, sampler: str = "batched",
        out_dir: str = "benchmarks/results/dryrun",
        blocks_per_worker: int = 1, data_parallel: int = 1) -> dict:
    cfg = LDA_CONFIGS[cfg_name]
    m, k = workers, cfg.num_topics
    sb, dp = blocks_per_worker, data_parallel
    b = sb * m                          # total vocabulary blocks
    r = dp * m                          # worker-grid rows (data × model)
    part = partition_vocab(cfg.vocab_size, b)
    vb = part.block_size
    dloc = -(-cfg.num_docs // r)
    # per-(grid row, block) token capacity with a 1.2 load-imbalance factor
    cap = max(int(cfg.num_tokens / (r * b) * 1.2), 1)
    mesh = make_lda_mesh(m, data_parallel=dp)

    s = lambda shape, dt=jnp.int32: jax.ShapeDtypeStruct(shape, dt)
    state = dict(
        cdk=s((r, dloc, k)), ckt=s((r, sb, vb, k)), blk=s((r, sb)),
        ck_syn=s((k,)), ck_loc=s((r, k)), z=s((r, b, cap)),
        u=s((r, b, cap), jnp.float32), doc=s((r, b, cap)),
        woff=s((r, b, cap)), mask=s((r, b, cap), jnp.bool_),
        alpha=s((k,), jnp.float32), beta=s((), jnp.float32),
        vbeta=s((), jnp.float32),
    )
    sampler_args = ()
    if sampler in ("sparse", "sparse_pallas"):
        # shape-derived caps, like the engine facade: a dryrun has no
        # corpus, so the per-row token capacity bounds the doc nonzeros
        from repro.core.sparse_device import default_sparse_args
        sampler_args = default_sparse_args(k, cap)
    fn = _iteration_shard_map(mesh, "w", sampler, sync_ck=True,
                              data_axis="data" if dp > 1 else None,
                              sampler_args=sampler_args)
    with set_mesh(mesh):
        lowered = fn.lower(*state.values())
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    costs = roofline.raw_costs(compiled)
    # the round scan body (1 of S·M rounds) is counted once: scale by S·M
    costs.flops *= b
    costs.bytes_accessed *= b
    costs.coll_bytes *= b
    for key in costs.coll_detail["bytes"]:
        costs.coll_detail["bytes"][key] *= b
    terms = roofline.roofline_terms(costs)
    block_bytes = vb * k * 4
    rec = {
        "workload": cfg_name, "workers": m, "sampler": sampler,
        "blocks_per_worker": sb, "num_blocks": b,
        "data_parallel": dp, "grid_rows": r,
        "model_variables": cfg.model_variables,
        "block_shape": [vb, k],
        "block_bytes": block_bytes,
        "resident_block_bytes_per_worker": block_bytes,
        "memory": {
            "argument_bytes_per_device": int(ma.argument_size_in_bytes),
            "temp_bytes_per_device": int(ma.temp_size_in_bytes),
            "total_gib_per_device": round(
                (ma.argument_size_in_bytes + ma.temp_size_in_bytes) / 2**30,
                3),
        },
        "costs_per_iteration": {
            "flops_per_device": costs.flops,
            "bytes_per_device": costs.bytes_accessed,
            "collective_bytes_per_device": costs.coll_bytes,
            "collective_detail": costs.coll_detail["bytes"],
        },
        "roofline": terms,
        # paper's communication claim: per-iteration traffic per worker is
        # S·M block moves (one RESIDENT block per round) + 2K-vector syncs
        # — O(V·K/(S·M)) per round regardless of M or S, vs O(M·V·K) for
        # DP gossip; parked blocks never travel.
        "analytic_rotation_bytes_per_iter": b * block_bytes,
        # hybrid grid (DESIGN.md §8): the per-round delta psum along data
        # moves one resident block per worker per round — same order as
        # the rotation, and zero when D = 1
        "analytic_data_psum_bytes_per_iter": (b * block_bytes
                                              if dp > 1 else 0),
        "status": "ok",
    }
    os.makedirs(out_dir, exist_ok=True)
    tag = f"ring{m}x{sb}" if dp == 1 else f"grid{dp}x{m}x{sb}"
    # the sampler is part of the artifact identity: different samplers
    # lower to very different rooflines and must not clobber each other
    if sampler != "batched":
        tag = f"{tag}__{sampler}"
    with open(os.path.join(out_dir, f"lda__{cfg_name}__{tag}.json"),
              "w") as f:
        json.dump(rec, f, indent=1)
    t = terms
    print(f"[ok] lda {cfg_name} {tag} {sampler}: "
          f"mem/dev={rec['memory']['total_gib_per_device']}GiB "
          f"c={t['compute_s']:.2e} m={t['memory_s']:.2e} "
          f"x={t['collective_s']:.2e} dom={t['dominant']}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", choices=list(LDA_CONFIGS),
                    default="wiki-unigram-k5000")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--workers", type=int, default=64)
    ap.add_argument("--blocks-per-worker", type=int, default=1,
                    help="S: pipeline S*workers vocabulary blocks")
    ap.add_argument("--data-parallel", type=int, default=1,
                    help="D: replicate the block ring over D doc shards "
                         "(hybrid 2D grid; needs D*workers devices)")
    from repro.core.engine.rounds import available_samplers
    # registry-derived, no "auto": a dryrun lowers one named sampler, and
    # compile-only means interpret-mode Pallas needs no --force gate
    ap.add_argument("--sampler", default="batched",
                    choices=available_samplers())
    args = ap.parse_args()
    names = list(LDA_CONFIGS) if args.all else [args.config]
    for name in names:
        try:
            run(name, args.workers, args.sampler,
                blocks_per_worker=args.blocks_per_worker,
                data_parallel=args.data_parallel)
        except Exception as e:  # noqa: BLE001
            print(f"[failed] lda {name}: {type(e).__name__}: {e}",
                  flush=True)


if __name__ == "__main__":
    main()
