"""Corpus container and I/O.

A corpus is a flat token stream: parallel int32 arrays ``doc``/``word``.
This is the persistent, conditionally-independent "data" half of the
data/model dichotomy the paper draws; samplers carry the transient ``z``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Sequence

import numpy as np


@dataclasses.dataclass
class Corpus:
    doc: np.ndarray          # [N] int32 document id per token
    word: np.ndarray         # [N] int32 word id per token
    num_docs: int
    vocab_size: int
    vocab: List[str] | None = None   # optional id -> string

    @property
    def num_tokens(self) -> int:
        return int(self.doc.shape[0])

    def doc_lengths(self) -> np.ndarray:
        return np.bincount(self.doc, minlength=self.num_docs)

    def word_freqs(self) -> np.ndarray:
        return np.bincount(self.word, minlength=self.vocab_size)

    def validate(self) -> None:
        """Raise ``ValueError`` on a structurally invalid corpus.

        Raises (not asserts): a corrupt or mismatched on-disk corpus must
        fail at the I/O boundary even under ``python -O``, instead of
        surfacing as an out-of-bounds scatter deep inside the engine.
        """
        if self.doc.shape != self.word.shape:
            raise ValueError(
                f"doc/word length mismatch: {self.doc.shape} vs "
                f"{self.word.shape}")
        if self.doc.min(initial=0) < 0 or self.word.min(initial=0) < 0:
            raise ValueError("negative doc or word id in token stream")
        if self.doc.max(initial=-1) >= self.num_docs:
            raise ValueError(
                f"doc id {int(self.doc.max())} >= num_docs {self.num_docs}")
        if self.word.max(initial=-1) >= self.vocab_size:
            raise ValueError(
                f"word id {int(self.word.max())} >= vocab_size "
                f"{self.vocab_size}")
        if self.vocab is not None and len(self.vocab) != self.vocab_size:
            raise ValueError(
                f"vocab sidecar has {len(self.vocab)} entries, expected "
                f"vocab_size={self.vocab_size}")

    def doc_words(self) -> List[np.ndarray]:
        """Per-document word-id arrays, in stream order within each doc —
        the query format the fold-in/serving path consumes.

        Vectorized: one stable argsort groups the stream by document while
        preserving within-document token order, and ``np.split`` cuts the
        grouped stream at the document-length prefix sums.
        """
        order = np.argsort(self.doc, kind="stable")
        grouped = np.ascontiguousarray(self.word[order], dtype=np.int32)
        lengths = np.bincount(self.doc, minlength=self.num_docs)
        return np.split(grouped, np.cumsum(lengths[:-1]))


def from_documents(docs_as_word_lists: Sequence[Sequence[int]],
                   vocab_size: int, vocab: List[str] | None = None) -> Corpus:
    doc_ids, word_ids = [], []
    for d, ws in enumerate(docs_as_word_lists):
        doc_ids.extend([d] * len(ws))
        word_ids.extend(ws)
    return Corpus(np.asarray(doc_ids, np.int32), np.asarray(word_ids, np.int32),
                  len(docs_as_word_lists), vocab_size, vocab)


def from_texts(texts: Sequence[str], min_count: int = 1) -> Corpus:
    """Whitespace tokenizer + vocabulary build — enough for the examples."""
    counts: Dict[str, int] = {}
    tokenized = []
    for t in texts:
        toks = t.lower().split()
        tokenized.append(toks)
        for w in toks:
            counts[w] = counts.get(w, 0) + 1
    vocab = sorted(w for w, c in counts.items() if c >= min_count)
    index = {w: i for i, w in enumerate(vocab)}
    docs = [[index[w] for w in toks if w in index] for toks in tokenized]
    return from_documents(docs, len(vocab), vocab)


def bigram_corpus(corpus: Corpus, replace: bool = False) -> Corpus:
    """Augment with bigrams the way the paper builds Wiki-bigram (§5):
    every intra-document consecutive token pair becomes a phrase token in
    an ENLARGED vocabulary — the unigram stream is kept and the bigram
    tokens (ids offset by ``vocab_size``) are appended per document, so
    the result has ``N + #pairs`` tokens over ``V + #unique-pairs`` types.

    ``replace=True`` is the escape hatch for the old behaviour: drop the
    unigrams and keep only the bigram stream over a bigram-only
    vocabulary (phrase ids start at 0).
    """
    doc, word = corpus.doc, corpus.word
    same_doc = doc[1:] == doc[:-1]
    pairs = word[:-1][same_doc].astype(np.int64) * corpus.vocab_size \
        + word[1:][same_doc].astype(np.int64)
    uniq, inv = np.unique(pairs, return_inverse=True)
    bigram_doc = doc[:-1][same_doc].astype(np.int32)
    bigram_vocab = None
    if corpus.vocab is not None:
        bigram_vocab = ["{}_{}".format(corpus.vocab[int(p // corpus.vocab_size)],
                                       corpus.vocab[int(p % corpus.vocab_size)])
                        for p in uniq]
    if replace:
        return Corpus(bigram_doc, inv.astype(np.int32), corpus.num_docs,
                      int(uniq.shape[0]), bigram_vocab)
    aug_doc = np.concatenate([doc, bigram_doc])
    aug_word = np.concatenate([word.astype(np.int32),
                               (inv + corpus.vocab_size).astype(np.int32)])
    order = np.argsort(aug_doc, kind="stable")   # doc-major stream
    vocab = (corpus.vocab + bigram_vocab
             if corpus.vocab is not None else None)
    return Corpus(aug_doc[order].astype(np.int32),
                  aug_word[order].astype(np.int32), corpus.num_docs,
                  corpus.vocab_size + int(uniq.shape[0]), vocab)


def split_corpus(corpus: Corpus, num_holdout: int) -> tuple:
    """Split the LAST ``num_holdout`` documents off as a held-out corpus
    (doc ids renumbered from 0); both halves keep the full vocabulary so a
    model trained on the first half can score the second."""
    if not 0 < num_holdout < corpus.num_docs:
        raise ValueError(
            f"num_holdout must be in (0, {corpus.num_docs}), "
            f"got {num_holdout}")
    cut = corpus.num_docs - num_holdout
    train_m = corpus.doc < cut
    train = Corpus(corpus.doc[train_m], corpus.word[train_m], cut,
                   corpus.vocab_size, corpus.vocab)
    held = Corpus((corpus.doc[~train_m] - cut).astype(np.int32),
                  corpus.word[~train_m], num_holdout, corpus.vocab_size,
                  corpus.vocab)
    return train, held


def npz_stem(path: str) -> str:
    """Normalize an ``.npz``-or-stem path to its stem: both
    ``save_corpus("foo")`` and ``load_corpus("foo.npz")`` address the
    same ``foo.npz`` + ``foo.vocab.json`` pair (the sidecar is keyed off
    the STEM on both sides — the old code wrote ``foo.vocab.json`` but
    looked for ``foo.npz.vocab.json``, silently dropping the
    vocabulary).  Shared by the snapshot I/O in `core/infer.py`."""
    return path[:-len(".npz")] if path.endswith(".npz") else path


def save_corpus(corpus: Corpus, path: str) -> None:
    stem = npz_stem(path)
    os.makedirs(os.path.dirname(stem) or ".", exist_ok=True)
    np.savez_compressed(stem + ".npz", doc=corpus.doc, word=corpus.word,
                        num_docs=corpus.num_docs, vocab_size=corpus.vocab_size)
    if corpus.vocab is not None:
        with open(stem + ".vocab.json", "w") as f:
            json.dump(corpus.vocab, f)


def load_corpus(path: str) -> Corpus:
    stem = npz_stem(path)
    # context manager: np.load on an .npz keeps the zip handle open for
    # lazy member reads — without it every load leaks a file descriptor
    # (fatal for the streaming trainer, which opens thousands of shards)
    with np.load(stem + ".npz") as data:
        try:
            corpus = Corpus(np.asarray(data["doc"], np.int32),
                            np.asarray(data["word"], np.int32),
                            int(data["num_docs"]), int(data["vocab_size"]))
        except KeyError as e:
            raise ValueError(
                f"{stem}.npz is not a corpus archive: missing {e}") from e
    vpath = stem + ".vocab.json"
    if os.path.exists(vpath):
        with open(vpath) as f:
            corpus.vocab = json.load(f)
    # fail at the I/O boundary, not deep inside the engine: a truncated or
    # mismatched archive must not be silently accepted
    corpus.validate()
    return corpus
