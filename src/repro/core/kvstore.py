"""Host-process simulation of the paper's Figure-1 architecture.

On TPU the key-value store dissolves into the sharded array + ppermute ring
(DESIGN.md §2); this module keeps the original component structure —
Scheduler / Workers / distributed KV store — as explicit objects, for two
reasons: (i) it documents Algorithms 1–2 in their native form and is used
by an example; (ii) it is the checkpointable host representation of a
sharded model (each block is one KV entry, exactly how ``train/checkpoint``
persists LDA runs).

Like the SPMD engine, the simulation takes ``blocks_per_worker`` (``S``):
the store then holds ``B = S·M`` blocks and the scheduler runs ``B`` rounds
per iteration over the slot-major pipeline schedule (DESIGN.md §3).  Here
the capacity story is literal — a worker's RAM holds exactly one block at a
time; the other ``B - 1`` live in the store.

Two execution flavours:

* ``sampler="numpy"`` (default) — the standalone reference: exact serial
  CGS per block via :func:`gibbs_sweep_np`, uniforms drawn on demand,
  topic totals read eagerly from the store.
* ``sampler="scan", ck_sync="round"`` — the *structural-equivalence
  oracle*: the very same jitted block sampler, padded token layout,
  uniform stream, and frozen-``C_k``-per-round semantics as the SPMD
  engine, so a run is bit-identical to ``ModelParallelLDA`` at any ``S``.
  Tests use this to prove the pipelined engine equals the paper's
  scheduler/worker/KV-store execution exactly.

``sampler="mh"`` extends the oracle mode to the O(1) alias-table MH
backend (DESIGN.md §9): the oracle resolves its per-block sampler from
the same registry as the engine, so a host "mh" run consumes the same
externally supplied uniforms through the same jitted kernel and the
device MH chain replays against it draw-for-draw — the replayability
anchor that lets the MH backend's *statistical* validation
(`tests/test_mh_stats.py`) rest on a bit-exact structural base.

``table_lifetime="iteration"`` mirrors the engine's traveling-table
schedule (DESIGN.md §10) in serial form: at iteration start the
scheduler builds every worker's doc table from its current ``cdk``; a
block's word table is built exactly once per iteration — at the block's
first residency, from the same frozen round-start copy every replica
samples — and is then handed to every later (worker, round) task that
touches the block, the serial transcript of the packed table riding the
engine's rotation collective.  Same jitted builder, same frozen inputs,
so the engine replays draw-for-draw against this schedule too.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core import schedule as sched
from repro.core.invindex import (build_inverted_index,
                                 common_block_capacity)
from repro.core.sampler import gibbs_sweep_np
from repro.data.corpus import Corpus
from repro.data.sharding import worker_shard


class KVStore:
    """Distributed in-memory block store (a DHT in the paper; a dict here).

    Keys are block ids for ``C_k^t`` blocks plus the special key ``"ck"``
    for the non-separable topic totals (§3.3 special channel).

    ``store`` selects the at-rest encoding of each entry (DESIGN.md §16):
    ``"dense"`` keeps the raw ``[Vb, K]`` array; ``"tail"`` holds the
    hybrid head/tail CountStore record.  Encode/decode is an exact
    integer round-trip, so the oracle's chain is bit-identical under
    either — which is precisely the equivalence the engine tests lean
    on.  ``bytes_moved`` keeps counting LOGICAL dense traffic (the §3.2
    cost model the perf tests pin); ``resident_bytes()`` reports what
    the chosen encoding actually holds.
    """

    def __init__(self, store: str = "dense", wcap: int | None = None):
        from repro.core.engine import countstore
        countstore.resolve_store(store)     # fail fast on unknown kinds
        self.store_kind = store
        self.wcap = (countstore.DEFAULT_TAIL_WCAP if wcap is None
                     else int(wcap))
        self._store_cls = countstore.resolve_store(store)
        self._blocks: Dict[int, object] = {}
        self._ck: np.ndarray | None = None
        self.bytes_moved = 0

    # -- word-topic blocks (on-demand, §3.2) --
    def put_block(self, block_id: int, rows: np.ndarray) -> None:
        self.bytes_moved += rows.nbytes
        self._blocks[block_id] = self._store_cls.from_dense(
            np.asarray(rows, np.int32), wcap=self.wcap)

    def get_block(self, block_id: int) -> np.ndarray:
        rows = self._blocks[block_id].to_dense()
        self.bytes_moved += rows.nbytes
        return np.array(rows, copy=True)

    def resident_bytes(self) -> int:
        """Bytes the store's encoding actually holds across all blocks."""
        return sum(st.nbytes_resident() for st in self._blocks.values())

    # -- topic totals (per-round lazy sync, §3.3) --
    def put_ck_delta(self, delta: np.ndarray) -> None:
        self.bytes_moved += delta.nbytes
        self._ck = self._ck + delta

    def get_ck(self) -> np.ndarray:
        self.bytes_moved += self._ck.nbytes
        return self._ck.copy()

    def init_ck(self, ck: np.ndarray) -> None:
        self._ck = ck.astype(np.int64).copy()


@dataclasses.dataclass
class HostWorker:
    """Algorithm 2: request block -> Gibbs sweep -> commit block."""

    worker_id: int
    cdk: np.ndarray            # [D_local, K]
    index: object              # InvertedIndex
    z: np.ndarray              # [B, T] block-layout assignments

    def run_round(self, block_id: int, store: KVStore, partition,
                  alpha, beta, rng) -> None:
        ckt_block = store.get_block(block_id).astype(np.int32)
        ck_synced = store.get_ck().astype(np.int32)
        ck = ck_synced.copy()
        d = self.index.doc[block_id]
        off = self.index.word_off[block_id]
        msk = self.index.mask[block_id]
        n = int(msk.sum())
        if n:
            u = rng.random(n)
            z_new = gibbs_sweep_np(
                self.cdk, ckt_block, ck,
                d[:n], off[:n], self.z[block_id, :n], u, alpha, beta,
                use_eq3=True)
            self.z[block_id, :n] = z_new
        store.put_block(block_id, ckt_block)
        store.put_ck_delta((ck - ck_synced).astype(np.int64))

    def run_round_frozen(self, block_id: int, ckt_block: np.ndarray,
                         ck_frozen, u_round, alpha, beta, vbeta,
                         sampler_fn=None, tables=None):
        """Engine-identical round against CALLER-OWNED frozen state: jitted
        block sampler on the full padded token slice, both the block copy
        and ``C_k`` frozen at the round boundary.  Returns the worker's
        updated block copy and ``C_k`` delta; the scheduler reconciles
        copies across data replicas and commits at round end (§8).

        ``sampler_fn`` is any registry sampler (``rounds.resolve_sampler``)
        — the exact-scan oracle by default; with the ``mh`` sampler this
        worker replays the device MH chain draw-for-draw, since the same
        jitted kernel consumes the same externally supplied uniforms.
        ``tables`` — a ``(word_packed, doc_packed)`` pair for the
        table-aware samplers (``rounds.resolve_table_sampler``): the
        scheduler owns the traveling word table and this worker's
        per-iteration doc table (DESIGN.md §10)."""
        import jax.numpy as jnp

        from repro.core.sampler import sweep_block_scan

        if sampler_fn is None:
            sampler_fn = sweep_block_scan
        args = (
            jnp.asarray(self.cdk), jnp.asarray(ckt_block),
            jnp.asarray(ck_frozen),
            jnp.asarray(self.index.doc[block_id]),
            jnp.asarray(self.index.word_off[block_id]),
            jnp.asarray(self.z[block_id]),
            jnp.asarray(self.index.mask[block_id]),
            jnp.asarray(u_round), alpha,
            jnp.float32(beta), jnp.float32(vbeta))
        if tables is not None:
            args += (jnp.asarray(tables[0]), jnp.asarray(tables[1]))
        out = sampler_fn(*args)
        self.cdk[...] = np.asarray(out[0])
        self.z[block_id] = np.asarray(out[3])
        return np.asarray(out[1]), np.asarray(out[2]) - ck_frozen

    def run_round_oracle(self, block_id: int, store: KVStore, ck_frozen,
                         u_round, alpha, beta, vbeta,
                         sampler_fn=None) -> np.ndarray:
        """Engine-identical round: fetch the block, run
        :meth:`run_round_frozen`, commit.  Returns the worker's ``C_k``
        delta (committed by the scheduler at round end)."""
        ckt_block = store.get_block(block_id).astype(np.int32)
        new_block, ck_delta = self.run_round_frozen(
            block_id, ckt_block, ck_frozen, u_round, alpha, beta, vbeta,
            sampler_fn=sampler_fn)
        store.put_block(block_id, new_block)
        return ck_delta


class HostModelParallelLDA:
    """Scheduler loop (Algorithm 1) driving host workers round-robin.

    Executes the ``S·M``-block model-parallel schedule *serially*; in
    oracle mode (``sampler="scan", ck_sync="round"``) with the exact same
    frozen-``C_k``-per-round semantics, sampler kernel, and uniform stream
    as the SPMD engine — used by tests as the structural reference and by
    ``examples/architecture_walkthrough``.

    ``data_parallel=D`` extends the oracle to the hybrid 2D grid
    (DESIGN.md §8): documents shard over ``R = D·M`` host workers, the
    store still holds ONE copy of each of the ``S·M`` blocks, and within a
    round every replica of model position ``m`` samples the same frozen
    block value; the scheduler sums their deltas and commits once at the
    round boundary — the serial transcript of the engine's delta psum
    along the data axis.  Bit-identical to
    ``ModelParallelLDA(..., data_parallel=D)`` for any ``(D, M, S)``.
    """

    def __init__(self, corpus: Corpus, num_topics: int, num_workers: int,
                 alpha: float = 0.1, beta: float = 0.01, seed: int = 0,
                 blocks_per_worker: int = 1, sampler: str = "numpy",
                 ck_sync: str = "eager", data_parallel: int = 1,
                 table_lifetime: str | None = None,
                 sampler_args: tuple | None = None,
                 store: str = "dense"):
        if ck_sync not in ("eager", "round"):
            raise ValueError(f"unknown ck_sync {ck_sync!r}")
        if ck_sync == "round" and sampler == "numpy":
            raise ValueError(
                "ck_sync='round' (frozen-per-round totals) is only "
                "implemented for the jitted oracle paths (any registry "
                "sampler, e.g. 'scan' or 'mh')")
        if data_parallel < 1:
            raise ValueError(
                f"data_parallel must be >= 1, got {data_parallel}")
        if data_parallel > 1 and ck_sync != "round":
            raise ValueError(
                "data_parallel > 1 needs the frozen-per-round semantics "
                "(sampler='scan'|'mh', ck_sync='round'): replica copies "
                "of a block are only well-defined between round "
                "boundaries")
        corpus.validate()
        self.corpus = corpus
        self.num_topics = num_topics
        self.num_workers = num_workers
        self.blocks_per_worker = int(blocks_per_worker)
        self.data_parallel = int(data_parallel)
        self.num_shards = self.data_parallel * num_workers
        self.num_blocks = num_workers * self.blocks_per_worker
        self.sampler = sampler
        self.ck_sync = ck_sync
        self.alpha = np.full(num_topics, alpha, np.float32)
        self.beta = float(beta)
        self.vbeta = float(beta * corpus.vocab_size)
        self.partition = sched.partition_vocab(corpus.vocab_size,
                                               self.num_blocks)
        sched.validate_schedule(num_workers, self.blocks_per_worker)
        self.rng = np.random.default_rng(seed)
        self.store_kind = store
        k = num_topics
        b = self.num_blocks
        vb = self.partition.block_size
        z0 = self.rng.integers(0, k, size=corpus.num_tokens).astype(np.int32)
        ckt = np.zeros((b, vb, k), np.int32)
        shards = [worker_shard(corpus, g, self.num_shards)
                  for g in range(self.num_shards)]
        # engine-identical padding in oracle (jitted) modes; minimal
        # otherwise.  The oracle sampler is resolved from the SAME registry
        # the SPMD engine uses (resolve_sampler also validates the name),
        # so e.g. an "mh" oracle run consumes the same uniforms through
        # the same jitted kernel — device MH replays against it
        # draw-for-draw.
        from repro.core.engine.rounds import table_capable
        if table_lifetime is None:
            # mirror the engine facade's default (MH family -> iteration)
            # where the oracle can honor it; the eager-sync flavour has no
            # frozen round-start copies to build traveling tables from, so
            # it keeps the per-round schedule rather than raising on a
            # value the caller never chose.
            table_lifetime = ("iteration"
                              if sampler != "numpy" and table_capable(sampler)
                              and ck_sync == "round"
                              else "round")
        if table_lifetime not in ("round", "iteration"):
            raise ValueError(
                f"unknown table_lifetime {table_lifetime!r}")
        if table_lifetime == "iteration":
            if sampler == "numpy" or not table_capable(sampler):
                raise ValueError(
                    "table_lifetime='iteration' needs a table-capable "
                    f"registry sampler (the MH family), got {sampler!r}")
            if ck_sync != "round":
                raise ValueError(
                    "table_lifetime='iteration' needs ck_sync='round': "
                    "traveling tables are built from frozen round-start "
                    "block copies")
        self.table_lifetime = table_lifetime
        if sampler_args is None:
            if sampler in ("sparse", "sparse_pallas"):
                # identical derivation to the engine facade — same corpus,
                # same caps, same jitted sampler instance, so oracle
                # replays of sparse chains are draw-for-draw.
                from repro.core.sparse_device import default_sparse_args
                sampler_args = default_sparse_args(
                    num_topics, int(corpus.doc_lengths().max()))
            else:
                sampler_args = ()
        self.sampler_args = tuple(sampler_args)
        if sampler != "numpy":
            from repro.core.engine.rounds import (resolve_sampler,
                                                  resolve_table_sampler)
            self._sampler_fn = (resolve_table_sampler(sampler)
                                if table_lifetime == "iteration"
                                else resolve_sampler(sampler,
                                                     self.sampler_args))
        else:
            self._sampler_fn = None
        cap = common_block_capacity((s.word for s in shards),
                                    self.partition) \
            if sampler != "numpy" else None
        self.capacity = cap
        # same wcap derivation as the SPMD engine, so a tail-encoded
        # store splits head/tail rows exactly where the sampler does
        from repro.core.engine import countstore
        self.store = KVStore(
            store=store,
            wcap=int(dict(self.sampler_args).get(
                "wcap", countstore.DEFAULT_TAIL_WCAP)))
        self.workers: List[HostWorker] = []
        for w, s in enumerate(shards):
            idx = build_inverted_index(s.doc_local, s.word, self.partition,
                                       cap)
            cdk = np.zeros((s.num_local_docs, k), np.int32)
            zz = z0[s.token_id]
            np.add.at(cdk, (s.doc_local, zz), 1)
            blk = self.partition.block_of_word(s.word)
            off = self.partition.word_offset_in_block(s.word)
            np.add.at(ckt, (blk, off, zz), 1)
            zlay = np.zeros_like(idx.token_id)
            zlay[idx.mask] = zz[idx.token_id[idx.mask]]
            self.workers.append(HostWorker(w, cdk, idx, zlay))
        self.shards = shards
        for blk_id in range(b):
            self.store.put_block(blk_id, ckt[blk_id])
        self.store.init_ck(ckt.sum(axis=(0, 1)))
        self.iteration_count = 0

    def step(self) -> None:
        m, s_ = self.num_workers, self.blocks_per_worker
        rounds = self.num_blocks
        if self.sampler != "numpy":
            # engine-identical uniform stream: [rounds, grid rows, capacity]
            u = self.rng.random((rounds, self.num_shards, self.capacity),
                                np.float32)
        travel = self.table_lifetime == "iteration"
        if travel:
            # per-iteration schedule (DESIGN.md §10): doc tables from
            # iteration-start cdk now; word tables lazily at each block's
            # first residency (from the frozen round-start copy shared by
            # every replica) — the same jitted builder the engine runs, so
            # the serial transcript matches the device tables bit-for-bit.
            import jax.numpy as jnp

            from repro.core.mh import build_doc_tables, build_word_tables
            alpha_j = jnp.asarray(self.alpha)
            doc_tabs = [np.asarray(build_doc_tables(jnp.asarray(w.cdk),
                                                    alpha_j))
                        for w in self.workers]
            word_tabs: Dict[int, np.ndarray] = {}
        for r in range(rounds):
            # scheduler: dispatch tasks, then rotate (Algorithm 1)
            if self.ck_sync == "round":
                ck_frozen = self.store.get_ck().astype(np.int32)
                delta = np.zeros_like(ck_frozen)
                # frozen per-round block copies: the D replicas of model
                # position m all sample the SAME stored value, and their
                # deltas are reconciled at round end (DESIGN.md §8's
                # delta-psum, executed serially)
                blk_frozen: Dict[int, np.ndarray] = {}
                blk_delta: Dict[int, np.ndarray] = {}
            for g in range(self.num_shards):
                w = g % m                        # model position of row g
                blk_id = sched.block_for(w, r, m, s_)
                if self.sampler != "numpy":
                    if self.ck_sync == "round":
                        if blk_id not in blk_frozen:
                            blk_frozen[blk_id] = self.store.get_block(
                                blk_id).astype(np.int32)
                            blk_delta[blk_id] = np.zeros_like(
                                blk_frozen[blk_id])
                        tables = None
                        if travel:
                            if blk_id not in word_tabs:   # first residency
                                word_tabs[blk_id] = np.asarray(
                                    build_word_tables(
                                        jnp.asarray(blk_frozen[blk_id]),
                                        jnp.float32(self.beta)))
                            tables = (word_tabs[blk_id], doc_tabs[g])
                        new_blk, d = self.workers[g].run_round_frozen(
                            blk_id, blk_frozen[blk_id], ck_frozen,
                            u[r, g], self.alpha, self.beta, self.vbeta,
                            sampler_fn=self._sampler_fn, tables=tables)
                        blk_delta[blk_id] += new_blk - blk_frozen[blk_id]
                        delta += d
                    else:
                        ck0 = self.store.get_ck().astype(np.int32)
                        d = self.workers[g].run_round_oracle(
                            blk_id, self.store, ck0, u[r, g], self.alpha,
                            self.beta, self.vbeta,
                            sampler_fn=self._sampler_fn)
                        self.store.put_ck_delta(d.astype(np.int64))
                else:
                    self.workers[g].run_round(blk_id, self.store,
                                              self.partition, self.alpha,
                                              self.beta, self.rng)
            if self.ck_sync == "round":
                for blk_id, dd in blk_delta.items():
                    self.store.put_block(blk_id, blk_frozen[blk_id] + dd)
                self.store.put_ck_delta(delta.astype(np.int64))
        self.iteration_count += 1

    # -- checkpoint / resume -----------------------------------------------
    CKPT_FORMAT = "host-lda-ckpt-v1"

    def save_checkpoint(self, path: str) -> str:
        """Serialize the scheduler/worker/store state to one ``.npz`` so
        an oracle replay can cross a resume boundary: the store's blocks
        and ``C_k``, every worker's ``cdk``/``z``, the rng bit-generator
        state, and a config echo.  Same iteration-boundary invariant as
        the engine checkpoint — tables are iteration-local, the store is
        reconciled — so host and device checkpoints cut the chain at the
        same points and resumed runs stay draw-for-draw comparable."""
        import json

        from repro.data.corpus import npz_stem
        cfg = {
            "format": self.CKPT_FORMAT,
            "num_topics": self.num_topics,
            "num_workers": self.num_workers,
            "blocks_per_worker": self.blocks_per_worker,
            "data_parallel": self.data_parallel,
            "sampler": self.sampler,
            "ck_sync": self.ck_sync,
            "store": self.store_kind,
            "table_lifetime": self.table_lifetime,
            "sampler_args": [list(p) for p in self.sampler_args],
            "alpha": np.asarray(self.alpha, np.float32).tolist(),
            "beta": self.beta,
            "iteration_count": self.iteration_count,
            "num_tokens": self.corpus.num_tokens,
            "vocab_size": self.corpus.vocab_size,
            "num_docs": self.corpus.num_docs,
        }
        arrays = {
            "blocks": np.stack([self.store.get_block(b)
                                for b in range(self.num_blocks)]),
            "ck": self.store.get_ck(),
            "config": np.frombuffer(json.dumps(cfg).encode(), np.uint8),
            "rng_state": np.frombuffer(
                json.dumps(self.rng.bit_generator.state).encode(),
                np.uint8),
        }
        for g, w in enumerate(self.workers):
            arrays[f"cdk_{g}"] = w.cdk
            arrays[f"z_{g}"] = w.z
        import os
        stem = npz_stem(path)
        os.makedirs(os.path.dirname(stem) or ".", exist_ok=True)
        tmp = stem + ".tmp.npz"
        np.savez(tmp, **arrays)
        os.replace(tmp, stem + ".npz")
        return stem + ".npz"

    @classmethod
    def resume(cls, corpus: Corpus, path: str) -> "HostModelParallelLDA":
        """Rebuild a host oracle from :meth:`save_checkpoint` output; the
        static layout is re-derived from the corpus, the mutable chain
        and rng stream restored bitwise."""
        import json

        from repro.data.corpus import npz_stem
        stem = npz_stem(path)
        with np.load(stem + ".npz") as data:
            try:
                cfg = json.loads(bytes(data["config"]).decode())
                rng_state = json.loads(bytes(data["rng_state"]).decode())
                blocks = np.asarray(data["blocks"])
                ck = np.asarray(data["ck"])
                worker_state = [
                    (np.asarray(data[f"cdk_{g}"]), np.asarray(data[f"z_{g}"]))
                    for g in range(cfg["data_parallel"]
                                   * cfg["num_workers"])]
            except KeyError as e:
                raise ValueError(
                    f"{stem}.npz is not a host-oracle checkpoint: "
                    f"missing {e}") from e
        if cfg.get("format") != cls.CKPT_FORMAT:
            raise ValueError(
                f"unknown checkpoint format {cfg.get('format')!r} in "
                f"{stem}.npz; expected {cls.CKPT_FORMAT!r}")
        for key in ("num_tokens", "vocab_size", "num_docs"):
            if int(cfg[key]) != int(getattr(corpus, key)):
                raise ValueError(
                    f"corpus does not match checkpoint: {key} is "
                    f"{getattr(corpus, key)}, checkpoint has {cfg[key]}")
        host = cls(corpus, num_topics=cfg["num_topics"],
                   num_workers=cfg["num_workers"],
                   alpha=np.asarray(cfg["alpha"], np.float32),
                   beta=cfg["beta"],
                   blocks_per_worker=cfg["blocks_per_worker"],
                   sampler=cfg["sampler"], ck_sync=cfg["ck_sync"],
                   data_parallel=cfg["data_parallel"],
                   store=cfg.get("store", "dense"),
                   table_lifetime=cfg["table_lifetime"],
                   sampler_args=tuple(
                       tuple(p) for p in cfg["sampler_args"]))
        for b in range(host.num_blocks):
            host.store.put_block(b, blocks[b])
        host.store.init_ck(ck)
        for g, (cdk_g, z_g) in enumerate(worker_state):
            host.workers[g].cdk[...] = cdk_g
            host.workers[g].z[...] = z_g
        host.rng.bit_generator.state = rng_state
        host.iteration_count = int(cfg["iteration_count"])
        return host

    def gather_ckt(self) -> np.ndarray:
        vb = self.partition.block_size
        out = np.zeros((self.partition.padded_vocab, self.num_topics),
                       np.int32)
        for blk_id in range(self.num_blocks):
            out[blk_id * vb:(blk_id + 1) * vb] = self.store.get_block(blk_id)
        return out[:self.corpus.vocab_size]

    def assignments(self) -> np.ndarray:
        """Current z in original token order (mirrors the engine's view)."""
        from repro.core.invindex import scatter_assignments
        z = np.zeros(self.corpus.num_tokens, np.int32)
        for w, shard in enumerate(self.shards):
            idx = self.workers[w].index
            z_local = scatter_assignments(idx, self.workers[w].z,
                                          shard.token_id.shape[0])
            z[shard.token_id] = z_local
        return z

    def snapshot(self, build_tables: bool = False):
        """Frozen serving export from the store's blocks — the host-side
        twin of ``ModelParallelLDA.snapshot()`` (identical whenever the
        engine replays this scheduler draw-for-draw)."""
        from repro.core.infer import ModelSnapshot
        return ModelSnapshot.from_counts(self.gather_ckt(), None,
                                         self.alpha, self.beta,
                                         build_tables=build_tables)


# ---------------------------------------------------------------------------
# Fold-in host oracle (serving-side replay, DESIGN.md §11)
# ---------------------------------------------------------------------------

def fold_in_oracle(snapshot, word, mask, z0, u, sampler: str = "scan",
                   num_cycles: int | None = None):
    """Serial host replay of the fold-in engine (`core/infer.py`):
    process ONE (sweep, query doc) at a time, like the scheduler loop
    above processes one (round, worker) task at a time.  Returns
    ``(cdk [Q, K], z [Q, T])`` bit-identical to
    ``infer.fold_in(..., z0=z0, u=u)`` fed the same arrays.

    Two replay flavours, matching how each training sampler is anchored:

    * ``"scan"`` — the same jitted per-doc kernel the engine vmaps
      (``infer.fold_in_doc_scan``), applied per row: the training path's
      structural-equivalence argument (vmap == per-row program), which is
      what makes exact-CGS replay bitwise despite f32 cumsums.
    * ``"sparse"``/``"sparse_pallas"`` — the same jitted per-doc hybrid
      sparse unit the engine vmaps (``infer.fold_in_doc_sparse``),
      applied per row against the snapshot's shared ``sparse_state()``
      dense-segment cumsum — the scan flavour's structural argument,
      covering both names at once (the serving pair is one
      implementation).
    * MH family — PURE NUMPY: doc tables via the `core/alias.py` numpy
      builders, cycles via ``mh.mh_cycle_np``.  Every MH decision is a
      single-IEEE-op chain on integer-derived operands (DESIGN.md §9),
      so the mirror is bitwise WITHOUT sharing any compiled code — the
      stronger statement, and it covers ``mh`` and ``mh_pallas`` at once
      (the pair draws identically).
    """
    from repro.core.alias import (build_alias_int_np, int_masses_np,
                                  unpack_tables_np)
    from repro.core.engine.rounds import table_capable
    from repro.core.infer import (DEFAULT_MH_CYCLES, fold_in_doc_scan,
                                  init_query_cdk)
    from repro.core.mh import mh_cycle_np

    if num_cycles is None:
        num_cycles = DEFAULT_MH_CYCLES
    word = np.asarray(word, np.int32)
    mask = np.asarray(mask, bool)
    z0 = np.asarray(z0, np.int32)
    u = np.asarray(u, np.float32)
    num_sweeps, q, t = u.shape
    k = snapshot.num_topics
    cdk = init_query_cdk(z0, mask, k)
    z = z0.copy()

    if sampler == "scan":
        import jax.numpy as jnp
        wterm = jnp.asarray(snapshot.word_term())
        alpha = jnp.asarray(snapshot.alpha)
        for s in range(num_sweeps):
            for qi in range(q):
                cdk_d, z_d = fold_in_doc_scan(
                    jnp.asarray(cdk[qi]), wterm, jnp.asarray(word[qi]),
                    jnp.asarray(z[qi]), jnp.asarray(mask[qi]),
                    jnp.asarray(u[s, qi]), alpha)
                cdk[qi] = np.asarray(cdk_d)
                z[qi] = np.asarray(z_d)
        return cdk, z

    if sampler in ("sparse", "sparse_pallas"):
        import jax.numpy as jnp

        from repro.core.infer import fold_in_doc_sparse
        xcs, sx = snapshot.sparse_state()
        wterm = jnp.asarray(snapshot.word_term())
        xcs, sx = jnp.asarray(xcs), jnp.asarray(sx)
        dcap = min(k, t)                   # shape-derived, like fold_in()
        for s in range(num_sweeps):
            for qi in range(q):
                cdk_d, z_d = fold_in_doc_sparse(
                    jnp.asarray(cdk[qi]), wterm, xcs, sx,
                    jnp.asarray(word[qi]), jnp.asarray(z[qi]),
                    jnp.asarray(mask[qi]), jnp.asarray(u[s, qi]),
                    dcap=dcap)
                cdk[qi] = np.asarray(cdk_d)
                z[qi] = np.asarray(z_d)
        return cdk, z

    if not table_capable(sampler):
        raise ValueError(
            f"unknown fold-in sampler {sampler!r}; expected 'scan', "
            "'sparse'/'sparse_pallas', or a table-capable registry "
            "sampler (the MH family)")
    word_table = unpack_tables_np(snapshot.ensure_tables())
    ckt_f = snapshot.ckt.astype(np.float32)
    ck_f = snapshot.ck.astype(np.float32)
    alpha = np.asarray(snapshot.alpha, np.float32)
    zero_doc = np.zeros(t, np.int32)
    for s in range(num_sweeps):
        # docs are independent (frozen model): each doc's sweep reads only
        # its own cdk row, so per-doc serial == the engine's batched sweep
        for qi in range(q):
            w_int = int_masses_np(cdk[qi], alpha)        # sweep-start row
            dcut, dalias, du_cap = build_alias_int_np(w_int)
            doc_table = (dcut[None], dalias[None],
                         np.asarray([du_cap], np.float32), w_int[None])
            z_old = z[qi].copy()
            z_new = mh_cycle_np(
                z_old, zero_doc, word[qi], mask[qi], u[s, qi],
                cdk[qi].astype(np.float32)[None], ckt_f, ck_f, alpha,
                snapshot.beta, snapshot.vbeta, word_table, doc_table,
                num_cycles=num_cycles)
            m = mask[qi]
            np.add.at(cdk[qi], z_old[m], -1)
            np.add.at(cdk[qi], z_new[m], 1)
            z[qi] = z_new
    return cdk, z
