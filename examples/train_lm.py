"""End-to-end LM training driver example: train a reduced assigned
architecture for a few hundred steps on CPU and watch the loss fall.

    PYTHONPATH=src python examples/train_lm.py [arch] [steps]

(The full-size configs run through the identical code path on the
production mesh via ``repro.launch.train``; this example keeps CPU wall
time reasonable.)
"""
import sys

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.train.data_iter import modality_wrapper, synthetic_lm_stream
from repro.train.optimizer import AdamW
from repro.train.train_step import make_train_step

arch = sys.argv[1] if len(sys.argv) > 1 else "olmo-1b"
steps = int(sys.argv[2]) if len(sys.argv) > 2 else 200

cfg = get_config(arch).reduced()
model = build_model(cfg)
params = model.init(0)
opt = AdamW(learning_rate=3e-3, warmup_steps=20, total_steps=steps)
opt_state = opt.init(params)
step_fn = jax.jit(make_train_step(model, opt))

stream = modality_wrapper(
    synthetic_lm_stream(cfg.vocab_size, batch=8, seq_len=64, seed=0),
    cfg, seed=0)
losses = []
for step, batch in zip(range(1, steps + 1), stream):
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    params, opt_state, metrics = step_fn(params, opt_state, batch)
    losses.append(float(metrics["loss"]))
    if step % 25 == 0 or step == 1:
        print(f"step {step:4d}  loss {losses[-1]:.4f}")

first, last = sum(losses[:10]) / 10, sum(losses[-10:]) / 10
print(f"\nmean loss first 10 steps {first:.4f} -> last 10 steps {last:.4f}")
assert last < first - 0.5, "model failed to learn the synthetic structure"
print("learned the planted Markov structure ✓")
