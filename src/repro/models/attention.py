"""Grouped-query attention with RoPE, sliding windows, cross-attention and
KV-cache decode — the attention substrate for every assigned architecture.

The sliding-window size is a *traced* per-layer scalar so heterogeneous
window patterns (gemma3's 5 local : 1 global) ride through a single
``lax.scan`` over stacked layer parameters.  ``window == 0`` means global
(full causal) attention.
"""
from __future__ import annotations

import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, apply_rope, cast, dense_init

NEG_INF = -1e30


def attention_params(keys, d_model: int, num_heads: int, num_kv_heads: int,
                     head_dim: int, qkv_bias: bool = False) -> Params:
    p = {
        "wq": dense_init(keys(), (d_model, num_heads * head_dim)),
        "wk": dense_init(keys(), (d_model, num_kv_heads * head_dim)),
        "wv": dense_init(keys(), (d_model, num_kv_heads * head_dim)),
        "wo": dense_init(keys(), (num_heads * head_dim, d_model)),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), jnp.float32)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), jnp.float32)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), jnp.float32)
    return p


def _project(x, w, b=None):
    y = x @ cast(w)
    if b is not None:
        y = y + cast(b)
    return y


def _split_heads(x, num_heads, head_dim):
    b, t, _ = x.shape
    return x.reshape(b, t, num_heads, head_dim)


def _repeat_kv(k, num_heads):
    """[B, S, kvH, hd] -> [B, S, H, hd] by group broadcast."""
    b, s, kvh, hd = k.shape
    if kvh == num_heads:
        return k
    rep = num_heads // kvh
    return jnp.broadcast_to(k[:, :, :, None, :],
                            (b, s, kvh, rep, hd)).reshape(b, s, num_heads, hd)


def _causal_window_mask(q_pos, k_pos, window):
    """[.., Tq, Tk] boolean; window==0 -> plain causal."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    causal = diff >= 0
    in_window = jnp.where(window > 0, diff < window, True)
    return causal & in_window


def mha(q, k, v, mask) -> jax.Array:
    """q: [B,Tq,H,hd], k/v: [B,Tk,H,hd], mask: broadcastable [B,1,Tq,Tk].

    Naive attention: materializes the full [B,H,Tq,Tk] score tensor.  Kept
    as the §Perf baseline and for short sequences/decode.
    """
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_mha(q, k, v, q_pos, k_pos, window,
                q_chunk: int = 512, k_chunk: int = 1024) -> jax.Array:
    """Online-softmax (flash-style) attention in pure JAX.

    Double-chunked: an outer scan over query blocks, an inner scan over KV
    blocks carrying (running max, normalizer, accumulator).  Peak buffer is
    one [B, H, q_chunk, k_chunk] score block instead of [B, H, T, T] —
    the XLA-level counterpart of a flash kernel, TPU-idiomatic via fused
    matmul+reduce blocks (§Perf iteration 1 documents the before/after).

    Causal + sliding-window masking via q/k position blocks; ``window`` is
    a traced scalar (0 = global).
    """
    b, tq, h, hd = q.shape
    tk = k.shape[1]
    q_chunk = min(q_chunk, tq)
    k_chunk = min(k_chunk, tk)
    assert tq % q_chunk == 0 and tk % k_chunk == 0, (tq, tk)
    nq, nk = tq // q_chunk, tk // k_chunk
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qb = jnp.moveaxis(q.reshape(b, nq, q_chunk, h, hd), 1, 0)
    qp = jnp.moveaxis(q_pos.reshape(b, nq, q_chunk), 1, 0)
    kb = jnp.moveaxis(k.reshape(b, nk, k_chunk, h, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nk, k_chunk, h, hd), 1, 0)
    kp = jnp.moveaxis(k_pos.reshape(b, nk, k_chunk), 1, 0)

    def q_block(_, q_xs):
        q_i, qp_i = q_xs

        def kv_block(carry, kv_xs):
            m, l, acc = carry
            k_j, v_j, kp_j = kv_xs
            s = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_j).astype(jnp.float32)
            s = s * scale
            msk = _causal_window_mask(qp_i, kp_j, window)[:, None]
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_j.dtype), v_j)
            return (m_new, l, acc), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), (kb, vb, kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, jnp.moveaxis(out, 1, 2)         # [B, q_chunk, H, hd]

    _, blocks = jax.lax.scan(q_block, None, (qb, qp))
    return jnp.moveaxis(blocks, 0, 1).reshape(b, tq, h, hd).astype(q.dtype)


# sequences at or above this length use chunked attention in the
# full-sequence path; overridable for §Perf baseline measurements
CHUNKED_ATTN_MIN_LEN = 2048


def self_attention(p: Params, x: jax.Array, positions: jax.Array,
                   num_heads: int, num_kv_heads: int, head_dim: int,
                   rope_theta: float, window,
                   causal: bool = True) -> jax.Array:
    """Full-sequence self-attention (train / prefill path)."""
    b, t, _ = x.shape
    q = _split_heads(_project(x, p["wq"], p.get("bq")), num_heads, head_dim)
    k = _split_heads(_project(x, p["wk"], p.get("bk")), num_kv_heads, head_dim)
    v = _split_heads(_project(x, p["wv"], p.get("bv")), num_kv_heads, head_dim)
    if rope_theta > 0:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    k = _repeat_kv(k, num_heads)
    v = _repeat_kv(v, num_heads)
    force_naive = os.environ.get("REPRO_ATTN_IMPL") == "naive"
    if causal and t >= CHUNKED_ATTN_MIN_LEN and not force_naive:
        out = chunked_mha(q, k, v, positions, positions, window)
    else:
        if causal:
            mask = _causal_window_mask(positions, positions, window)[:, None]
        else:
            mask = jnp.ones((b, 1, t, t), bool)
        out = mha(q, k, v, mask)
    return out.reshape(b, t, num_heads * head_dim) @ cast(p["wo"])


def cross_attention(p: Params, x: jax.Array, enc_kv: Tuple[jax.Array, jax.Array],
                    num_heads: int, head_dim: int) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V (whisper)."""
    b, t, _ = x.shape
    q = _split_heads(_project(x, p["wq"], p.get("bq")), num_heads, head_dim)
    k, v = enc_kv
    mask = jnp.ones((b, 1, t, k.shape[1]), bool)
    out = mha(q, k, v, mask)
    return out.reshape(b, t, num_heads * head_dim) @ cast(p["wo"])


def encode_cross_kv(p: Params, enc_out: jax.Array, num_kv_heads: int,
                    head_dim: int) -> Tuple[jax.Array, jax.Array]:
    k = _split_heads(_project(enc_out, p["wk"], p.get("bk")),
                     num_kv_heads, head_dim)
    v = _split_heads(_project(enc_out, p["wv"], p.get("bv")),
                     num_kv_heads, head_dim)
    return k, v


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, max_len: int, num_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
    }


def decode_self_attention(p: Params, cache: Dict[str, jax.Array],
                          x: jax.Array, pos: jax.Array,
                          num_heads: int, num_kv_heads: int, head_dim: int,
                          rope_theta: float, window
                          ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    # Repeated-KV cache mode (§Perf HC2): when the cache was allocated with
    # num_heads kv slots, K/V are expanded to full heads BEFORE the cache
    # write, so the cache shards over the `model` axis on the head dim and
    # every device updates only its resident slice — no resharding, no
    # all-gather per step.  Trades kv-cache bytes for collective-free decode.
    """One-token decode: update ring cache at ``pos`` and attend over it.

    x: [B, 1, d]; pos: [B] current absolute position.  For windowed layers
    (``window > 0``) the cache length is the window and indexing is modular
    (ring buffer) — the 500k-context configs rely on this to keep local
    layers O(window) instead of O(S).
    """
    b = x.shape[0]
    s = cache["k"].shape[1]
    q = _split_heads(_project(x, p["wq"], p.get("bq")), num_heads, head_dim)
    k = _split_heads(_project(x, p["wk"], p.get("bk")), num_kv_heads, head_dim)
    v = _split_heads(_project(x, p["wv"], p.get("bv")), num_kv_heads, head_dim)
    if rope_theta > 0:
        q = apply_rope(q, pos[:, None], rope_theta)
        k = apply_rope(k, pos[:, None], rope_theta)
    if cache["k"].shape[2] != num_kv_heads:
        k = _repeat_kv(k, cache["k"].shape[2])
        v = _repeat_kv(v, cache["k"].shape[2])
    slot = jnp.where(window > 0, pos % s, jnp.minimum(pos, s - 1))
    bidx = jnp.arange(b)
    ck = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
    # positions stored at each cache slot (ring for windowed layers)
    slots = jnp.arange(s)
    if_window = pos[:, None] - ((slot[:, None] - slots[None, :]) % s)
    if_global = jnp.broadcast_to(slots[None, :], (b, s))
    k_pos = jnp.where(window > 0, if_window, if_global)
    valid = (k_pos >= 0) & (k_pos <= pos[:, None])
    in_window = jnp.where(window > 0,
                          pos[:, None] - k_pos < window, True)
    mask = (valid & in_window)[:, None, None, :]            # [B,1,1,S]
    kk = _repeat_kv(ck.astype(q.dtype), num_heads)
    vv = _repeat_kv(cv.astype(q.dtype), num_heads)
    out = mha(q, kk, vv, mask)
    out = out.reshape(b, 1, num_heads * head_dim) @ cast(p["wo"])
    return out, {"k": ck, "v": cv}
