"""Token-stream data pipeline for LM training.

Provides (i) a synthetic Zipf-distributed token stream with planted bigram
structure (so loss visibly decreases) and (ii) a text-file pipeline using
the repro tokenizer from ``data.corpus``.  Batches are delivered as the
{tokens, labels} dict every model consumes; VLM/audio wrappers attach the
stub modality inputs.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


def synthetic_lm_stream(vocab_size: int, batch: int, seq_len: int,
                        seed: int = 0,
                        structure: float = 0.8) -> Iterator[Dict[str, np.ndarray]]:
    """Markov-chain token stream: each token deterministically hints its
    successor with prob ``structure`` — a learnable signal for the
    end-to-end driver."""
    rng = np.random.default_rng(seed)
    succ = rng.permutation(vocab_size)
    while True:
        toks = np.empty((batch, seq_len + 1), np.int64)
        toks[:, 0] = rng.integers(0, vocab_size, batch)
        follow = rng.random((batch, seq_len)) < structure
        rand = rng.integers(0, vocab_size, (batch, seq_len))
        for t in range(seq_len):
            toks[:, t + 1] = np.where(follow[:, t], succ[toks[:, t]],
                                      rand[:, t])
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}


def modality_wrapper(stream: Iterator[Dict[str, np.ndarray]], cfg,
                     seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Attach stub patch/frame embeddings for vlm/audio families."""
    rng = np.random.default_rng(seed)
    for batch in stream:
        b = batch["tokens"].shape[0]
        if cfg.family == "vlm":
            batch["patch_embeds"] = rng.normal(
                size=(b, cfg.num_patch_embeds, cfg.d_model)
            ).astype(np.float32)
        if cfg.family == "audio":
            batch["frames"] = rng.normal(
                size=(b, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        yield batch


def text_stream(path: str, batch: int, seq_len: int,
                vocab_size: Optional[int] = None,
                seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Tokenize a text file (whitespace) into a ring of token windows."""
    from repro.data.corpus import from_texts
    with open(path) as f:
        corpus = from_texts(f.read().splitlines())
    ids = corpus.word
    if vocab_size is not None:
        ids = ids % vocab_size
    rng = np.random.default_rng(seed)
    n = ids.shape[0] - seq_len - 1
    while True:
        starts = rng.integers(0, max(n, 1), batch)
        toks = np.stack([ids[s:s + seq_len + 1] for s in starts])
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
