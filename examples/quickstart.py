"""Quickstart: train a model-parallel LDA on a tiny synthetic corpus and
inspect the learned topics.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.metrics import top_words, topic_recovery_score
from repro.core.model_parallel import ModelParallelLDA
from repro.data.synthetic import synthetic_corpus

# 1. Data: a corpus with 8 planted topics (each owning a word band).
corpus, true_phi, _ = synthetic_corpus(
    num_docs=200, vocab_size=400, num_topics=8, doc_len=60, seed=0)
print(f"corpus: {corpus.num_tokens:,} tokens, {corpus.num_docs} docs, "
      f"V={corpus.vocab_size}")

# 2. Model-parallel LDA: 4 workers, each holding 1/4 of the word-topic
#    table; blocks rotate each round (the paper's Algorithm 1+2).
lda = ModelParallelLDA(corpus, num_topics=8, num_workers=4,
                       alpha=0.1, beta=0.01, seed=1)
print(f"word blocks: {lda.partition.num_blocks} × {lda.partition.block_size}"
      f" words; per-worker model = {np.asarray(lda.state.ckt)[0].nbytes:,}"
      " bytes")

# 3. Run 20 iterations, watching likelihood ascend and the Fig-3 error
#    stay tiny.
for it in range(1, 21):
    lda.step()
    if it % 5 == 0 or it == 1:
        print(f"iter {it:3d}  log-likelihood {lda.log_likelihood():,.0f}  "
              f"Δ-error {lda.delta_error():.5f}")

# 4. Inspect: top words per topic + recovery of the planted structure.
ckt = np.asarray(lda.gather_counts().ckt)
for k in range(8):
    print(f"topic {k}: words {top_words(ckt, k, 8).tolist()}")
score = topic_recovery_score(ckt, true_phi)
print(f"topic recovery vs planted topics: {score:.3f} (1.0 = perfect)")
assert score > 0.5

# 5. Model capacity beyond worker RAM: pipeline S blocks per worker —
#    the resident block shrinks S-fold at the same worker count while
#    inference stays exact (DESIGN.md §3).
deep = ModelParallelLDA(corpus, num_topics=8, num_workers=4,
                        alpha=0.1, beta=0.01, seed=1, blocks_per_worker=4)
rep = deep.memory_report()
print(f"\nblocks_per_worker=4: {rep['num_blocks']} blocks, resident block "
      f"{rep['resident_block_shape']} = {rep['resident_block_bytes']:,} B "
      f"of a {rep['total_model_bytes']:,} B model")
deep.run(5)
print(f"pipelined engine log-likelihood after 5 iters: "
      f"{deep.log_likelihood():,.0f}")
