"""Jitted public wrappers around the Gibbs-conditional Pallas kernel.

Handles padding to tile boundaries, platform selection (interpret mode off
TPU), the word-grouped token layout, and the engine-facing
``sweep_block_pallas`` sampler that plugs into ``core.model_parallel``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gibbs_conditional import (TILE_G, TILE_T,
                                             gibbs_conditional_call)
from repro.kernels.ref import gibbs_conditional_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("tile_g", "tile_t", "interpret"))
def gibbs_conditional(ckt_group, cdk_rows, z_old, u, mask, ck, alpha,
                      beta, vbeta, tile_g: int = TILE_G, tile_t: int = TILE_T,
                      interpret: bool | None = None) -> jax.Array:
    """Padded, platform-aware kernel call.  Shapes: see kernel docstring.

    Padding guarantees zero mass on fake topics (α/C_d^k pads are 0) and
    no-ops on fake tokens (mask pads are 0), so results are unaffected.
    """
    if interpret is None:
        interpret = not _on_tpu()
    g0, t0 = z_old.shape
    k0 = ck.shape[0]
    ckt_group = _pad_to(_pad_to(ckt_group.astype(jnp.float32), 1, 128), 0, tile_g)
    cdk_rows = _pad_to(_pad_to(cdk_rows.astype(jnp.float32), 2, 128), 0, tile_g)
    z_old_p = _pad_to(z_old, 0, tile_g)
    u_p = _pad_to(u, 0, tile_g)
    mask_p = _pad_to(mask.astype(jnp.int32), 0, tile_g)
    ck_p = _pad_to(ck.astype(jnp.float32), 0, 128)
    alpha_p = _pad_to(alpha.astype(jnp.float32), 0, 128)
    out = gibbs_conditional_call(ckt_group, cdk_rows, z_old_p, u_p, mask_p,
                                 ck_p, alpha_p, beta, vbeta,
                                 tile_g=tile_g, tile_t=t0,
                                 interpret=interpret)
    return out[:g0, :t0]


def group_tokens_by_word(word_off: np.ndarray, group_width: int
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host helper: chunk word-sorted tokens into ``[G, Tg]`` word groups.

    ``word_off`` must be sorted (inverted-index order).  Each group holds up
    to ``group_width`` tokens of ONE word; long postings split into several
    groups (each still word-pure, so the per-group coeff cache stays exact).

    Returns (group_word [G], position [G, Tg] indices into the token array,
    mask [G, Tg]).
    """
    word_off = np.asarray(word_off)
    n = word_off.shape[0]
    groups_w, groups_pos = [], []
    i = 0
    while i < n:
        w = word_off[i]
        j = i
        while j < n and word_off[j] == w and j - i < group_width:
            j += 1
        groups_w.append(int(w))
        groups_pos.append(np.arange(i, j))
        i = j
    g = max(len(groups_w), 1)
    gw = np.zeros(g, np.int32)
    pos = np.zeros((g, group_width), np.int32)
    msk = np.zeros((g, group_width), bool)
    for gi, (w, p) in enumerate(zip(groups_w, groups_pos)):
        gw[gi] = w
        pos[gi, :len(p)] = p
        msk[gi, :len(p)] = True
    return gw, pos, msk


@jax.jit
def sweep_block_pallas(cdk, ckt_block, ck, doc, word_off, z, mask, u,
                       alpha, beta, vbeta):
    """Engine-facing sampler: same signature/semantics as
    ``core.sampler.sweep_block_batched`` but with the conditional evaluated
    by the Pallas kernel (token-per-group layout; the word-grouped layout is
    exercised by ``gibbs_conditional`` directly in benchmarks/tests).

    Bit-identical to the ``batched`` sampler mode given the same uniforms —
    asserted by tests — so the kernel slots into the model-parallel engine
    without changing its convergence behaviour.
    """
    k = ck.shape[0]
    ckt_rows = ckt_block[word_off].astype(jnp.float32)        # [T, K]
    cdk_rows = cdk[doc].astype(jnp.float32)[:, None, :]       # [T, 1, K]
    z_new = gibbs_conditional(
        ckt_rows, cdk_rows, z[:, None], u[:, None],
        mask[:, None], ck.astype(jnp.float32), alpha,
        beta, vbeta, tile_g=128)[:, 0]
    z_new = jnp.where(mask, z_new, z)
    delta = mask.astype(jnp.int32)
    onehot_old = jax.nn.one_hot(z, k, dtype=jnp.int32) * delta[:, None]
    onehot_new = jax.nn.one_hot(z_new, k, dtype=jnp.int32) * delta[:, None]
    dk = onehot_new - onehot_old
    cdk = cdk.at[doc].add(dk)
    ckt_block = ckt_block.at[word_off].add(dk)
    ck = ck + dk.sum(axis=0)
    return cdk, ckt_block, ck, z_new
