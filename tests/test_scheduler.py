"""Serving scheduler (DESIGN.md §14) under a deterministic virtual clock.

No wall-clock sleeps anywhere: every test drives a `VirtualClock`, so
timing-dependent behaviour (batch-delay deadlines, open-loop replay,
latency stamps) is exact and replayable.  The load-bearing property is
the SEED CONTRACT: a response is a pure function of (snapshot contents,
token multiset, scheduler seed), computable standalone by
``reference_theta`` — which turns batching, caching, multi-replica
dispatch, and mid-replay hot-swaps into bitwise-testable refactorings
of the same function.

Layers:

* **batching invariants** — FIFO admission order, batch ≤ capacity,
  no request starves past the configured deadline, partial batches held
  then force-dispatched.
* **admission control** — every rejection path, with reasons.
* **hot swap** — zero dropped, zero epoch-mixed responses across a
  mid-replay swap; every response bitwise equal to serving its request
  against its stamped snapshot alone.
* **cache** — multiset key permutation-invariant and collision-checked,
  hits bitwise equal to fresh fold-ins, LRU eviction, swap invalidation.
"""
import numpy as np
import pytest

from repro.core.infer import ModelSnapshot
from repro.serve.scheduler import (REJECT_BAD_WORD, REJECT_EMPTY,
                                   REJECT_QUEUE_FULL, REJECT_TOO_LONG,
                                   QueryCache, ServingScheduler,
                                   VirtualClock, canonical_tokens,
                                   multiset_digest, reference_theta,
                                   request_draws)
from repro.serve.traffic import poisson_trace, replay_open_loop

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

V, K = 64, 8
SWEEPS = 3
SEED = 1


def _snapshot(seed: int) -> ModelSnapshot:
    rng = np.random.default_rng(seed)
    return ModelSnapshot.from_counts(
        rng.integers(0, 30, size=(V, K)).astype(np.int32))


@pytest.fixture(scope="module")
def snap_a():
    return _snapshot(10)


@pytest.fixture(scope="module")
def snap_b():
    return _snapshot(20)


def _sched(snap, **kw) -> ServingScheduler:
    kw.setdefault("clock", VirtualClock())
    kw.setdefault("sampler", "scan")
    kw.setdefault("num_sweeps", SWEEPS)
    kw.setdefault("seed", SEED)
    return ServingScheduler(snap, **kw)


def _docs(n, seed=0, lo=3, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, V, size=int(rng.integers(lo, hi)))
            .astype(np.int32) for _ in range(n)]


def _ref(snap, tokens, sampler="scan"):
    return reference_theta(snap, tokens, sampler=sampler,
                           num_sweeps=SWEEPS, seed=SEED)


# ---------------------------------------------------------------------------
# Clock
# ---------------------------------------------------------------------------

def test_virtual_clock():
    c = VirtualClock(5.0)
    assert c.now() == 5.0
    c.advance(1.5)
    c.sleep(0.5)               # sleep == advance: no wall time anywhere
    assert c.now() == 7.0
    with pytest.raises(ValueError):
        c.advance(-1.0)


# ---------------------------------------------------------------------------
# Batching invariants
# ---------------------------------------------------------------------------

def test_fifo_admission_order(snap_a):
    sched = _sched(snap_a, max_batch=4)
    ids = [sched.submit(d) for d in _docs(10, seed=2)]
    out = sched.tick()
    assert [r.req_id for r in out] == ids            # FIFO, across batches
    assert all(r.status == "ok" for r in out)
    disp = [r.t_dispatch for r in out]
    assert disp == sorted(disp)
    assert sched.pending == 0 and sched.dropped() == 0


def test_batch_never_exceeds_capacity(snap_a):
    sched = _sched(snap_a, max_batch=4)
    for d in _docs(10, seed=3):
        sched.submit(d)
    sched.tick()
    sizes = [b["size"] for b in sched.batch_log]
    assert sizes == [4, 4, 2]                         # FIFO prefix groups
    for b in sched.batch_log:
        assert b["size"] <= 4
        assert b["bucket"][0] <= 4                    # pow2 pad of <= max

def test_partial_batch_held_until_deadline(snap_a):
    clock = VirtualClock()
    sched = _sched(snap_a, max_batch=4, max_batch_delay=1.0, clock=clock)
    sched.submit(_docs(1, seed=4)[0])
    assert sched.tick() == []                 # young partial batch: held
    clock.advance(0.5)
    assert sched.tick() == []
    clock.advance(0.6)                        # age 1.1 >= deadline 1.0
    out = sched.tick()
    assert len(out) == 1
    assert out[0].t_dispatch - out[0].t_arrival == pytest.approx(1.1)


def test_full_batch_dispatches_despite_delay(snap_a):
    sched = _sched(snap_a, max_batch=4, max_batch_delay=100.0)
    for d in _docs(4, seed=5):
        sched.submit(d)
    assert len(sched.tick()) == 4             # full => no reason to wait


def test_flush_dispatches_partial_batch(snap_a):
    sched = _sched(snap_a, max_batch=8, max_batch_delay=100.0)
    sched.submit(_docs(1, seed=6)[0])
    assert sched.tick() == []
    assert len(sched.drain()) == 1


def test_no_request_starves_past_deadline(snap_a):
    """The no-starvation invariant: with ticks every ``dt``, every
    request dispatches within ``max_batch_delay + dt`` of arrival —
    batching can delay a request up to the deadline, never past it."""
    delay, dt = 0.5, 0.2
    clock = VirtualClock()
    sched = _sched(snap_a, max_batch=4, max_batch_delay=delay, clock=clock)
    trace = poisson_trace(30, 50.0, V, seed=7, max_len=12)
    i = 0
    while i < len(trace) or sched.pending:
        now = clock.now()
        while i < len(trace) and trace[i].t <= now:
            sched.submit(trace[i].tokens, now=trace[i].t)
            i += 1
        sched.tick()
        clock.advance(dt)
    waits = [r.t_dispatch - r.t_arrival for r in sched.ok_responses()
             if not r.cached]
    assert len(sched.ok_responses()) == 30 and sched.dropped() == 0
    assert max(waits) <= delay + dt + 1e-9


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------

def test_rejection_paths(snap_a):
    sched = _sched(snap_a, max_queue=2, max_doc_tokens=8)
    r_empty = sched.submit([])
    r_long = sched.submit(np.arange(9))
    r_bad = sched.submit([0, V])                    # id out of vocab
    ok1 = sched.submit([1, 2, 3])
    ok2 = sched.submit([4, 5, 6])
    r_full = sched.submit([7, 8])                   # queue depth 2 hit
    assert sched.results[r_empty].reason == REJECT_EMPTY
    assert sched.results[r_long].reason == REJECT_TOO_LONG
    assert sched.results[r_bad].reason == REJECT_BAD_WORD
    assert sched.results[r_full].reason == REJECT_QUEUE_FULL
    for rid in (r_empty, r_long, r_bad, r_full):
        resp = sched.results[rid]
        assert resp.status == "rejected" and resp.theta is None
        assert resp.t_finish == resp.t_arrival      # rejected instantly
    assert ok1 not in (r_empty, r_long, r_bad) and ok2 != ok1
    assert sched.rejections == {REJECT_EMPTY: 1, REJECT_TOO_LONG: 1,
                                REJECT_BAD_WORD: 1, REJECT_QUEUE_FULL: 1}
    assert sched.admitted == 2 and sched.submitted == 6
    sched.drain()
    assert sched.dropped() == 0                     # rejected != dropped


def test_constructor_validation(snap_a):
    with pytest.raises(ValueError, match="num_replicas"):
        _sched(snap_a, num_replicas=0)
    with pytest.raises(ValueError, match="max_batch"):
        _sched(snap_a, max_batch=0)
    with pytest.raises(ValueError, match="max_queue"):
        _sched(snap_a, max_queue=0)


# ---------------------------------------------------------------------------
# The seed contract: responses are pure functions of the request
# ---------------------------------------------------------------------------

def test_response_independent_of_batch_composition(snap_a):
    """The same doc served alone, batched with strangers, and through a
    different max_batch must produce the SAME bits — the property the
    cache, hot-swap, and replica dispatch all rest on."""
    doc = _docs(1, seed=8)[0]
    ref = _ref(snap_a, doc)
    for kw, extra in [(dict(max_batch=1), 0), (dict(max_batch=8), 5),
                      (dict(max_batch=3, num_replicas=2), 7)]:
        sched = _sched(snap_a, **kw)
        rid = sched.submit(doc)
        for d in _docs(extra, seed=9, lo=2, hi=30):
            sched.submit(d)
        sched.drain()
        np.testing.assert_array_equal(sched.results[rid].theta, ref)


def test_replaying_seeded_trace_twice_is_bitwise_identical(snap_a, snap_b):
    """The acceptance property: same trace, same seed, fresh scheduler
    -> every response identical bit for bit, including timings (virtual
    clock) and swap behaviour."""
    trace = poisson_trace(24, 80.0, V, seed=11, max_len=20,
                          hot_fraction=0.3, hot_pool=3)
    outs = []
    for _ in range(2):
        sched = _sched(snap_a, max_batch=4, num_replicas=2)
        summary = replay_open_loop(sched, trace, swap_after=12,
                                   swap_snapshot=snap_b)
        assert summary["dropped"] == 0
        outs.append(sched)
    a, b = outs
    assert set(a.results) == set(b.results)
    for rid in a.results:
        ra, rb = a.results[rid], b.results[rid]
        assert (ra.status, ra.epoch, ra.fingerprint, ra.replica,
                ra.cached) == (rb.status, rb.epoch, rb.fingerprint,
                               rb.replica, rb.cached)
        assert (ra.t_arrival, ra.t_dispatch, ra.t_finish) == \
            (rb.t_arrival, rb.t_dispatch, rb.t_finish)
        if ra.status == "ok":
            np.testing.assert_array_equal(ra.theta, rb.theta)


def test_round_robin_replica_dispatch(snap_a):
    sched = _sched(snap_a, max_batch=1, num_replicas=3)
    docs = _docs(6, seed=12)
    for d in docs:
        sched.submit(d)
    out = sched.tick()
    assert [r.replica for r in out] == [0, 1, 2, 0, 1, 2]
    # replicas share one snapshot object: derived state built once
    servers = sched._servers[sched.epoch]
    assert all(s.snapshot is sched.snapshot for s in servers)
    # and every replica produces contract bits
    for r, d in zip(out, docs):
        np.testing.assert_array_equal(r.theta, _ref(snap_a, d))


# ---------------------------------------------------------------------------
# Hot swap: zero downtime, zero dropped, zero epoch-mixed
# ---------------------------------------------------------------------------

def test_swap_binds_epoch_at_admission(snap_a, snap_b):
    sched = _sched(snap_a, max_batch=8)
    pre = [sched.submit(d) for d in _docs(3, seed=13)]
    new_epoch = sched.swap_snapshot(snap_b)
    assert new_epoch == 1
    post = [sched.submit(d) for d in _docs(3, seed=14)]
    sched.drain()
    fp_a, fp_b = snap_a.fingerprint(), snap_b.fingerprint()
    for rid in pre:       # admitted before the swap: OLD snapshot
        assert sched.results[rid].epoch == 0
        assert sched.results[rid].fingerprint == fp_a
    for rid in post:      # admitted after: NEW snapshot
        assert sched.results[rid].epoch == 1
        assert sched.results[rid].fingerprint == fp_b
    for b in sched.batch_log:                 # no batch mixes epochs
        assert b["size"] <= 8
    assert [b["epoch"] for b in sched.batch_log] == [0, 1]
    assert sched.dropped() == 0


@pytest.mark.parametrize("sampler", ["scan", "mh"])
def test_mid_replay_swap_bitwise_equivalence(snap_a, snap_b, sampler):
    """THE hot-swap acceptance test: replay a seeded trace with a swap
    at the midpoint; every response must be bitwise equal to serving
    that request ALONE against its stamped snapshot; both epochs serve;
    nothing is dropped; no response mixes epochs."""
    trace = poisson_trace(20, 100.0, V, seed=15, max_len=16,
                          hot_fraction=0.2, hot_pool=3)
    sched = _sched(snap_a, sampler=sampler, max_batch=4, num_replicas=2)
    summary = replay_open_loop(sched, trace, swap_after=10,
                               swap_snapshot=snap_b)
    assert summary["dropped"] == 0
    assert summary["swap_epoch"] == 1
    assert set(summary["epochs"]) == {0, 1}          # both models served
    fp = {0: snap_a.fingerprint(), 1: snap_b.fingerprint()}
    by_snap = {snap_a.fingerprint(): snap_a, snap_b.fingerprint(): snap_b}
    for i, req in enumerate(trace):
        r = sched.results[i]
        assert r.status == "ok"
        # the stamp is self-consistent: epoch <-> fingerprint
        assert r.fingerprint == fp[r.epoch]
        # and truthful: the response IS that snapshot's answer, bitwise
        np.testing.assert_array_equal(
            r.theta, reference_theta(by_snap[r.fingerprint], req.tokens,
                                     sampler=sampler, num_sweeps=SWEEPS,
                                     seed=SEED))
    for b in sched.batch_log:                 # a batch binds ONE snapshot
        assert b["epoch"] in (0, 1)


def test_swap_closes_epoch_group_immediately(snap_a, snap_b):
    """A queued pre-swap group can never grow after the swap, so it
    dispatches at the next tick even if the batch-delay deadline hasn't
    passed — swaps never add latency to old-epoch stragglers."""
    sched = _sched(snap_a, max_batch=8, max_batch_delay=100.0)
    rid = sched.submit(_docs(1, seed=16)[0])
    assert sched.tick() == []                 # held: young partial batch
    sched.swap_snapshot(snap_b)
    out = sched.tick()                        # epoch closed: go now
    assert [r.req_id for r in out] == [rid]
    assert out[0].epoch == 0


def test_swap_releases_old_servers_once_drained(snap_a, snap_b):
    sched = _sched(snap_a)
    sched.submit(_docs(1, seed=17)[0])
    sched.swap_snapshot(snap_b)
    assert set(sched._servers) == {0, 1}      # old epoch still queued
    sched.drain()
    sched.tick()
    assert set(sched._servers) == {1}         # drained -> released
    assert sched.snapshot is snap_b


def test_swap_to_identical_snapshot_is_observable(snap_a):
    """Epoch says WHEN, fingerprint says WHAT: swapping in a
    bit-identical model bumps the epoch, keeps the fingerprint, and —
    because draws key on content, not epoch — keeps every response's
    bits."""
    twin = _snapshot(10)                      # same counts as snap_a
    assert twin.fingerprint() == snap_a.fingerprint()
    doc = _docs(1, seed=18)[0]
    sched = _sched(snap_a)
    r0 = sched.submit(doc)
    sched.drain()
    sched.swap_snapshot(twin)
    r1 = sched.submit(doc)
    sched.drain()
    a, b = sched.results[r0], sched.results[r1]
    assert (a.epoch, b.epoch) == (0, 1)
    assert a.fingerprint == b.fingerprint
    assert not b.cached                       # swap cleared the cache...
    np.testing.assert_array_equal(a.theta, b.theta)   # ...same bits anyway


# ---------------------------------------------------------------------------
# Hot-query cache
# ---------------------------------------------------------------------------

def test_cache_hit_bitwise_equals_fresh_fold_in(snap_a):
    doc = _docs(1, seed=19)[0]
    sched = _sched(snap_a)
    r0 = sched.submit(doc)
    sched.drain()
    batches = len(sched.batch_log)
    r1 = sched.submit(doc)                    # same multiset: hot
    a, b = sched.results[r0], sched.results[r1]
    assert not a.cached and b.cached
    assert len(sched.batch_log) == batches    # no fold-in ran
    np.testing.assert_array_equal(b.theta, a.theta)
    np.testing.assert_array_equal(b.theta, _ref(snap_a, doc))
    assert sched.cache_hits == 1


def test_cache_key_is_permutation_invariant(snap_a):
    rng = np.random.default_rng(21)
    doc = rng.integers(0, V, size=12).astype(np.int32)
    sched = _sched(snap_a)
    r0 = sched.submit(doc)
    sched.drain()
    hits = []
    for _ in range(3):
        rid = sched.submit(rng.permutation(doc))
        hits.append(sched.results[rid])
    assert all(h.cached for h in hits)
    for h in hits:
        np.testing.assert_array_equal(h.theta, sched.results[r0].theta)


def test_cache_collision_degrades_to_miss(snap_a, monkeypatch):
    """Force every digest to collide: the stored canonical-array check
    must turn the collision into a MISS (correct answer recomputed),
    never into serving another multiset's response."""
    doc_a, doc_b = _docs(2, seed=22)
    import repro.serve.scheduler as mod
    monkeypatch.setattr(mod, "multiset_digest", lambda canon: b"COLLIDE")
    ref_b = _ref(snap_a, doc_b)     # same patched digest -> same draws
    sched = _sched(snap_a)
    sched.submit(doc_a)
    sched.drain()
    rid = sched.submit(doc_b)                 # same digest, diff multiset
    sched.drain()
    r = sched.results[rid]
    assert not r.cached
    assert sched.cache.collisions >= 1
    np.testing.assert_array_equal(r.theta, ref_b)


def test_cache_lru_eviction_respects_capacity(snap_a):
    docs = _docs(3, seed=23)
    sched = _sched(snap_a, cache_capacity=2)
    for d in docs:                            # A, B, C -> A evicted
        sched.submit(d)
        sched.drain()
    assert len(sched.cache) == 2
    assert sched.cache.evictions == 1
    rid = sched.submit(docs[0])               # A: miss, recomputed
    sched.drain()
    assert not sched.results[rid].cached
    # hit refreshes recency: touch A (now resident), add D -> C evicted
    assert sched.results[sched.submit(docs[0])].cached
    sched.submit(_docs(1, seed=24)[0])
    sched.drain()
    assert sched.results[sched.submit(docs[0])].cached      # A survived
    rid_c = sched.submit(docs[2])                           # C evicted:
    sched.drain()                                           # miss, requeued
    assert not sched.results[rid_c].cached


def test_cache_disabled_at_zero_capacity(snap_a):
    doc = _docs(1, seed=25)[0]
    sched = _sched(snap_a, cache_capacity=0)
    sched.submit(doc)
    sched.drain()
    rid = sched.submit(doc)
    sched.drain()
    assert not sched.results[rid].cached
    assert len(sched.cache) == 0


def test_swap_invalidates_cache(snap_a, snap_b):
    doc = _docs(1, seed=26)[0]
    sched = _sched(snap_a)
    sched.submit(doc)
    sched.drain()
    assert len(sched.cache) == 1
    sched.swap_snapshot(snap_b)
    assert len(sched.cache) == 0
    rid = sched.submit(doc)
    sched.drain()
    r = sched.results[rid]
    assert not r.cached and r.fingerprint == snap_b.fingerprint()
    np.testing.assert_array_equal(r.theta, _ref(snap_b, doc))


def test_cache_hit_bypasses_full_queue(snap_a):
    """Hot queries cost no queue slot, so overload shedding never sheds
    traffic the cache has already paid for."""
    hot = _docs(1, seed=27)[0]
    sched = _sched(snap_a, max_queue=1)
    sched.submit(hot)
    sched.drain()
    sched.submit(_docs(1, seed=28)[0])        # occupies the only slot
    rid_hot = sched.submit(hot)               # still served, instantly
    rid_cold = sched.submit(_docs(1, seed=29)[0])
    assert sched.results[rid_hot].cached
    assert sched.results[rid_cold].reason == REJECT_QUEUE_FULL


def test_query_cache_unit():
    cache = QueryCache(capacity=1)
    canon = canonical_tokens([3, 1, 2])
    np.testing.assert_array_equal(canon, [1, 2, 3])
    d = multiset_digest(canon)
    assert d == multiset_digest(canonical_tokens([2, 3, 1]))
    assert cache.get(d, canon) is None
    cache.put(d, canon, np.arange(3.0))
    np.testing.assert_array_equal(cache.get(d, canon), np.arange(3.0))
    assert (cache.hits, cache.misses) == (1, 1)


if HAVE_HYPOTHESIS:
    @given(st.lists(st.integers(0, V - 1), min_size=1, max_size=24),
           st.randoms(use_true_random=False))
    @settings(max_examples=25, deadline=None)
    def test_multiset_key_permutation_property(tokens, pyrandom):
        """Hypothesis: ANY permutation of ANY doc produces the same
        canonical form, digest, and per-request draws — the cache-key
        contract, independent of the fold-in."""
        shuffled = list(tokens)
        pyrandom.shuffle(shuffled)
        c0, c1 = canonical_tokens(tokens), canonical_tokens(shuffled)
        np.testing.assert_array_equal(c0, c1)
        assert multiset_digest(c0) == multiset_digest(c1)
        z0a, ua = request_draws(SEED, "ab12", multiset_digest(c0),
                                c0.size, K, SWEEPS)
        z0b, ub = request_draws(SEED, "ab12", multiset_digest(c1),
                                c1.size, K, SWEEPS)
        np.testing.assert_array_equal(z0a, z0b)
        np.testing.assert_array_equal(ua, ub)


# ---------------------------------------------------------------------------
# Observability / stats
# ---------------------------------------------------------------------------

def test_stats_and_latency_summary(snap_a, snap_b):
    clock = VirtualClock()
    sched = _sched(snap_a, max_batch=4, clock=clock)
    trace = poisson_trace(16, 60.0, V, seed=30, max_len=12,
                          hot_fraction=0.4, hot_pool=2)
    replay_open_loop(sched, trace, swap_after=8, swap_snapshot=snap_b)
    s = sched.stats()
    assert s["submitted"] == 16 and s["dropped"] == 0
    assert s["served"] == s["admitted"] == 16
    assert s["swaps"] == 1 and s["epoch"] == 1
    assert s["cache"]["hits"] == sched.cache_hits
    lat = sched.latency_summary()
    assert lat["served"] == 16
    assert np.isfinite(lat["p50_ms"]) and np.isfinite(lat["p99_ms"])
    assert lat["p50_ms"] <= lat["p99_ms"]
    # virtual clock: fold-ins are instant, so latency is pure queueing
    for r in sched.ok_responses():
        assert r.t_arrival <= r.t_dispatch <= r.t_finish


# ---------------------------------------------------------------------------
# lda_serve snapshot watcher (unit: no subprocess, no wall clock)
# ---------------------------------------------------------------------------

def test_lda_serve_watcher_swaps_on_new_snapshot(tmp_path, snap_a, snap_b):
    import argparse
    import os

    from repro.launch.lda_serve import _make_watcher
    a_path, b_path = str(tmp_path / "a.npz"), str(tmp_path / "b.npz")
    snap_a.save(a_path)
    os.utime(a_path, (1000.0, 1000.0))
    args = argparse.Namespace(snapshot=a_path, watch=str(tmp_path),
                              watch_interval=0.0)
    sched = _sched(snap_a)
    on_tick = _make_watcher(args, sched)
    on_tick(sched, 0.0)
    assert sched.epoch == 0                   # nothing new yet
    snap_b.save(b_path)
    os.utime(b_path, (2000.0, 2000.0))        # strictly newer
    on_tick(sched, 1.0)
    assert sched.epoch == 1
    assert sched.fingerprint == snap_b.fingerprint()
    on_tick(sched, 2.0)                       # same file: no re-swap
    assert sched.epoch == 1
