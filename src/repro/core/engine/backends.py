"""The two bit-identical execution backends (DESIGN.md §2–§3, §8).

One iteration = ``B = S·M`` rounds.  Every round each worker samples its
resident block (slot 0 of its queue), hands exactly that block to ring
neighbour ``m - 1`` (``ppermute`` — parked slots never travel), and
enqueues the received block at the tail of its queue, where it surfaces
``S`` rounds later.  At ``S = 1`` the queue degenerates to the paper's
original rotation: the received block is resident immediately.

Hybrid data×model parallelism (``data_parallel = D``, DESIGN.md §8): all
per-worker arrays carry one leading axis of length ``R = D·M`` (row
``g = d·M + m``).  The ``D`` replicas run the same model-axis rotation
over replicated copies of the ``S·M`` blocks; at every round boundary the
just-sampled resident copies are reconciled by a delta psum along the
data axis — ``block' = block_pre + Σ_d (block_d − block_pre)`` — before
they rotate, so parked copies never diverge across replicas.  This is the
AD-LDA all-reduce of ``core/data_parallel.py`` folded into the engine,
confined to the one resident ``[Vb, K]`` slice per round; at ``D = 1``
the reconciliation vanishes and both backends execute exactly the frozen
1D reference (``engine/reference.py`` — enforced bitwise by
``tests/test_engine_2d.py``).

* ``vmap`` backend — the worker grid is a batch axis on one device;
  ``ppermute`` becomes a per-replica ``jnp.roll``, ``psum`` a sum.  Runs
  anywhere, used by tests/benchmarks on the single-CPU container.
* ``shard_map`` backend — the grid maps onto a ``(data, model)`` mesh;
  collectives are real.  This is the production path; the round rotation
  lowers to HLO ``collective-permute`` on the model axis and the replica
  reconciliation to an ``all-reduce`` on the data axis.

Both backends share :func:`repro.core.engine.rounds.worker_round`, so
agreement tests are meaningful, and the non-separable topic totals
``{C_k}`` are synchronized once per round via ``psum`` of per-worker
deltas over the WHOLE grid and drift in between (§3.3).

Sampler staleness composes per block (DESIGN.md §9): the ``batched`` /
``pallas`` / ``mh`` samplers freeze block-local counts at round start,
which is exactly the window between two rotation/reconciliation
collectives — so neither the S-block pipeline nor the data axis widens
it, and the vmap/shard_map backends stay bit-identical for every
registered sampler, MH included.

Traveling tables (``table_lifetime="iteration"``, DESIGN.md §10): for
the MH family the per-block word-proposal alias table is built ONCE per
iteration — at the block's first residency, i.e. during the first ``S``
rounds — and then rotates through the ring *with* its block as one
packed int32 array (a second ``ppermute``/``roll`` per round), parked in
a slot queue mirroring the block queue.  Doc-proposal tables are built
once per iteration from iteration-start ``cdk`` and are loop-invariant.
Tables are iteration-local by construction: every table a reuse round
reads was built earlier in the same iteration, so the state pytree
carries none and checkpoints stay sampler-agnostic.  Both iteration
functions donate the state buffers (``donate_argnums``), so the big
count/assignment arrays are updated in place instead of copied.

CountStore boundary (DESIGN.md §16): the device chain both backends run
keeps every slot of ``MPState.ckt`` DENSE — jit caching, buffer
donation, and the ``ppermute`` ring all want static shapes — so the
pluggable store layouts (``engine/countstore.py``) live strictly AT
REST: checkpoints, streaming block files, sharded snapshots, and the
serving row loads.  The streaming engine is where a store's layout also
reaches compute, via the store-native sampler registry
(``rounds.resolve_store_sampler``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core import schedule as sched
from repro.core.engine.rounds import (resolve_sampler,
                                      resolve_table_sampler, worker_round,
                                      worker_round_tables)
from repro.core.engine.state import MPState


@partial(jax.jit, static_argnames=("sampler_mode", "sync_ck",
                                   "data_parallel", "table_lifetime",
                                   "track_error", "sampler_args"),
         donate_argnums=(0,))
def iteration_vmap(state: MPState, u, doc, woff, mask, alpha, beta, vbeta,
                   sampler_mode: str = "scan", sync_ck: bool = True,
                   data_parallel: int = 1, table_lifetime: str = "round",
                   track_error: bool = True, sampler_args: tuple = ()):
    """One full iteration = S·M rounds with rotation, stacked on one device.

    ``u`` is ``[B, R, T]`` — one uniform per (round, grid row, token slot),
    with ``R = data_parallel · M``.  ``state`` is donated: the returned
    :class:`MPState` reuses the input buffers, so callers must not touch
    the argument after the call (the facade always rebinds it).

    ``table_lifetime="iteration"`` selects the traveling-table MH
    schedule (module docstring); ``track_error=False`` skips the per-round
    Fig-3 drift statistic (``errs`` comes back all-zero) — with
    ``sync_ck=True`` the true totals are still computed for the sync.
    """
    d_ = data_parallel

    def rotate(x):
        # rotation m -> m-1 within every replica: worker m-1 receives
        # worker m's payload (resident block / its traveling table) and
        # parks it at the tail of its queue (immediately resident when
        # S == 1).
        if d_ > 1:
            r_ = x.shape[0]
            return jnp.roll(x.reshape(d_, r_ // d_, *x.shape[1:]), -1,
                            axis=1).reshape(x.shape)
        return jnp.roll(x, -1, axis=0)

    def reconcile(res_ckt, res_pre):
        if d_ == 1:
            return res_ckt
        # delta-psum reconciliation along data (DESIGN.md §8): replica
        # copies of block b were identical at round start (res_pre),
        # diverged during sampling; commit pre + Σ_d (post_d − pre).
        r_, vb, k = res_ckt.shape
        m_ = r_ // d_
        delta = (res_ckt - res_pre).reshape(d_, m_, vb, k).sum(axis=0)
        rec = res_pre.reshape(d_, m_, vb, k)[0] + delta
        return jnp.broadcast_to(rec[None], (d_, m_, vb, k)) \
            .reshape(r_, vb, k)

    def sync_and_err(ck_syn, ck_loc):
        # paper Fig-3 error: pre-sync ℓ1 drift of local {C_k} vs true
        # totals.  ck_true feeds the sync too, so it is only skippable
        # when neither consumer is on.
        err = jnp.float32(0.0)
        if sync_ck or track_error:
            ck_true = ck_syn + (ck_loc - ck_syn[None, :]).sum(axis=0)
        if track_error:
            n_tok = jnp.maximum(ck_true.sum(), 1).astype(jnp.float32)
            err = (jnp.abs(ck_loc - ck_true[None, :]).sum()
                   .astype(jnp.float32) / (ck_loc.shape[0] * n_tok))
        if sync_ck:
            ck_loc = jnp.broadcast_to(ck_true, ck_loc.shape)
            ck_syn = ck_true
        return ck_syn, ck_loc, err

    if table_lifetime == "iteration":
        from repro.core.mh import build_doc_tables, build_word_tables
        tsampler = resolve_table_sampler(sampler_mode)
        round_fn = partial(worker_round_tables, sampler=tsampler)
        r_, s_, vb, k = state.ckt.shape
        # per-iteration doc tables from iteration-start cdk (DESIGN.md
        # §10): loop-invariant across all S·M rounds.
        dtab = jax.vmap(build_doc_tables, in_axes=(0, None))(
            state.cdk, alpha)

        def round_step(carry, u_r, *, build):
            cdk, ckt, blk, ck_syn, ck_loc, z, ttab = carry
            res_pre = ckt[:, 0]              # [R, Vb, K] round-start copies
            res_blk = blk[:, 0]
            if build:
                # first residency of this block this iteration: build its
                # word table from the round-start copy (identical across
                # replicas, so the D builds agree bitwise).
                wtab = jax.vmap(build_word_tables, in_axes=(0, None))(
                    res_pre, beta)
            else:
                wtab = ttab[:, 0]            # the table that traveled in
            cdk, res_ckt, ck_loc, z = jax.vmap(
                round_fn, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0,
                                   None, None, None, 0, 0))(
                cdk, res_pre, res_blk, ck_loc, z, u_r, doc, woff, mask,
                alpha, beta, vbeta, wtab, dtab)
            res_ckt = rotate(reconcile(res_ckt, res_pre))
            res_blk = rotate(res_blk)
            wtab = rotate(wtab)      # the table travels WITH its block
            ckt = jnp.concatenate([ckt[:, 1:], res_ckt[:, None]], axis=1)
            blk = jnp.concatenate([blk[:, 1:], res_blk[:, None]], axis=1)
            ttab = jnp.concatenate([ttab[:, 1:], wtab[:, None]], axis=1)
            ck_syn, ck_loc, err = sync_and_err(ck_syn, ck_loc)
            return (cdk, ckt, blk, ck_syn, ck_loc, z, ttab), err

        # table queue mirroring the block queue; never read before its
        # slot is written (every block's table is built in rounds < S),
        # so the zero init is dead weight XLA can elide.
        ttab0 = jnp.zeros((r_, s_, 3, vb, k), jnp.int32)
        carry = (state.cdk, state.ckt, state.block_id, state.ck_synced,
                 state.ck_local, state.z, ttab0)
        carry, errs_b = jax.lax.scan(partial(round_step, build=True),
                                     carry, u[:s_])
        carry, errs_r = jax.lax.scan(partial(round_step, build=False),
                                     carry, u[s_:])
        return MPState(*carry[:6]), jnp.concatenate([errs_b, errs_r])

    sampler = resolve_sampler(sampler_mode, sampler_args)
    round_fn = partial(worker_round, sampler=sampler)

    def round_step(carry, u_r):
        cdk, ckt, blk, ck_syn, ck_loc, z = carry
        res_pre = ckt[:, 0]                  # [R, Vb, K] round-start copies
        res_blk = blk[:, 0]
        cdk, res_ckt, ck_loc, z = jax.vmap(
            round_fn, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0,
                               None, None, None))(
            cdk, res_pre, res_blk, ck_loc, z, u_r, doc, woff, mask,
            alpha, beta, vbeta)
        res_ckt = rotate(reconcile(res_ckt, res_pre))
        res_blk = rotate(res_blk)
        ckt = jnp.concatenate([ckt[:, 1:], res_ckt[:, None]], axis=1)
        blk = jnp.concatenate([blk[:, 1:], res_blk[:, None]], axis=1)
        ck_syn, ck_loc, err = sync_and_err(ck_syn, ck_loc)
        return (cdk, ckt, blk, ck_syn, ck_loc, z), err

    carry = (state.cdk, state.ckt, state.block_id, state.ck_synced,
             state.ck_local, state.z)
    carry, errs = jax.lax.scan(round_step, carry, u)
    return MPState(*carry), errs


def make_shard_map_iteration(mesh: Mesh, axis: str, sampler_mode: str,
                             sync_ck: bool, data_axis: str | None = None,
                             table_lifetime: str = "round",
                             track_error: bool = True,
                             sampler_args: tuple = ()):
    """Build the jitted per-device iteration function for ``mesh``.

    ``axis`` is the model axis carrying the block ring.  When ``data_axis``
    is given the mesh is 2D ``(data, model)``: per-worker arrays shard
    their leading ``R = D·M`` axis over BOTH axes (data-major, matching
    ``state.build_layout``'s row order), resident blocks are reconciled by
    a per-round delta ``psum`` along ``data``, and ``{C_k}`` syncs over
    the whole grid.  ``data_axis=None`` is the original 1D worker ring.

    With ``table_lifetime="iteration"`` the per-round ``ppermute`` of the
    resident block gains a companion: the block's packed word-proposal
    table rides the same ring permutation, so table payloads move as one
    extra ``collective-permute`` per round and never rebuild outside the
    first ``S`` rounds (module docstring; DESIGN.md §10).  The six state
    arrays are donated — counts update in place across iterations.
    """
    perm = sched.rotation_permutation(mesh.shape[axis])
    tables = table_lifetime == "iteration"
    sampler = (resolve_table_sampler(sampler_mode) if tables
               else resolve_sampler(sampler_mode, sampler_args))
    ck_axes = (data_axis, axis) if data_axis is not None else axis

    def per_device(cdk, ckt, blk, ck_syn, ck_loc, z, u, doc, woff, mask,
                   alpha, beta, vbeta):
        # local shards arrive with a leading grid axis of size 1
        cdk, ckt, blk, ck_loc, z = (x[0] for x in (cdk, ckt, blk, ck_loc, z))
        doc, woff, mask, u = (x[0] for x in (doc, woff, mask, u))
        s_ = ckt.shape[0]
        if tables:
            from repro.core.mh import build_doc_tables, build_word_tables
            dtab = build_doc_tables(cdk, alpha)   # per-iteration, invariant

        def round_step(carry, u_r, build=False):
            cdk, ckt, blk, ck_syn, ck_loc, z, ttab = carry
            res_pre = ckt[0]
            res_blk = blk[0]
            if tables:
                wtab = (build_word_tables(res_pre, beta) if build
                        else ttab[0])
                cdk, res_ckt, ck_loc, z = worker_round_tables(
                    cdk, res_pre, res_blk, ck_loc, z, u_r, doc, woff,
                    mask, alpha, beta, vbeta, wtab, dtab, sampler=sampler)
            else:
                cdk, res_ckt, ck_loc, z = worker_round(
                    cdk, res_pre, res_blk, ck_loc, z, u_r, doc, woff,
                    mask, alpha, beta, vbeta, sampler=sampler)
            if data_axis is not None:
                # delta-psum reconciliation of the D replica copies of the
                # resident block (DESIGN.md §8) — the only cross-replica
                # traffic, one [Vb, K] all-reduce per round.
                res_ckt = res_pre + jax.lax.psum(res_ckt - res_pre,
                                                 data_axis)
            # Algorithm 2 commit+request: ONLY the resident block travels —
            # per-round traffic stays one [Vb, K] block per worker (plus
            # its packed table under the iteration lifetime) no matter how
            # large S makes the total model.
            res_ckt = jax.lax.ppermute(res_ckt, axis, perm)
            res_blk = jax.lax.ppermute(res_blk, axis, perm)
            ckt = jnp.concatenate([ckt[1:], res_ckt[None]], axis=0)
            blk = jnp.concatenate([blk[1:], res_blk[None]], axis=0)
            if tables:
                wtab = jax.lax.ppermute(wtab, axis, perm)
                ttab = jnp.concatenate([ttab[1:], wtab[None]], axis=0)
            err = jnp.float32(0.0)
            if sync_ck or track_error:
                ck_true = ck_syn + jax.lax.psum(ck_loc - ck_syn, ck_axes)
            if track_error:
                n_tok = jnp.maximum(ck_true.sum(), 1).astype(jnp.float32)
                err = jax.lax.pmean(
                    jnp.abs(ck_loc - ck_true).sum().astype(jnp.float32),
                    ck_axes) / n_tok
            if sync_ck:
                ck_loc = ck_true
                ck_syn = ck_true
            return (cdk, ckt, blk, ck_syn, ck_loc, z, ttab), err

        ttab0 = (jnp.zeros((s_, 3) + ckt.shape[1:], jnp.int32) if tables
                 else jnp.zeros((), jnp.int32))
        carry = (cdk, ckt, blk, ck_syn, ck_loc, z, ttab0)
        if tables:
            # first S rounds build each block's table at its first
            # residency; the rest reuse the traveling payloads.
            carry, errs_b = jax.lax.scan(
                partial(round_step, build=True), carry, u[:s_])
            carry, errs_r = jax.lax.scan(round_step, carry, u[s_:])
            errs = jnp.concatenate([errs_b, errs_r])
        else:
            carry, errs = jax.lax.scan(round_step, carry, u)
        cdk, ckt, blk, ck_syn, ck_loc, z = carry[:6]
        return (cdk[None], ckt[None], blk[None], ck_syn, ck_loc[None],
                z[None], errs)

    w = P(ck_axes) if data_axis is not None else P(axis)
    return jax.jit(compat.shard_map(
        per_device, mesh=mesh,
        in_specs=(w, w, w, P(), w, w, w, w, w, w, P(), P(), P()),
        out_specs=(w, w, w, P(), w, w, P()),
        check_vma=False), donate_argnums=(0, 1, 2, 3, 4, 5))
