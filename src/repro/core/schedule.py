"""Rotation scheduler (paper Algorithm 1).

The scheduler partitions the vocabulary into ``M`` disjoint word blocks and
rotates block ownership among the ``M`` workers: in round ``r`` worker ``m``
owns block ``(m + r) mod M``.  After ``M`` rounds every (worker, block) pair
has met exactly once — one *iteration* over the data.

Under SPMD the scheduler is not a process: ``owner_of``/``block_of`` define
a compile-time permutation that ``model_parallel.py`` lowers to a single
``jax.lax.ppermute`` (HLO ``collective-permute``) per round.  This module is
also used verbatim by the host-simulation path (``kvstore.py``), where it
plays the paper's original role of a coordinating component.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class VocabPartition:
    """Disjoint word blocks ``{V_1 .. V_M}`` of a padded vocabulary."""

    vocab_size: int          # true V
    num_blocks: int          # M
    block_size: int          # Vb = ceil(V / M)

    @property
    def padded_vocab(self) -> int:
        return self.block_size * self.num_blocks

    def block_of_word(self, word: np.ndarray) -> np.ndarray:
        return np.asarray(word) // self.block_size

    def word_offset_in_block(self, word: np.ndarray) -> np.ndarray:
        return np.asarray(word) % self.block_size

    def block_bounds(self, block: int) -> Tuple[int, int]:
        lo = block * self.block_size
        return lo, min(lo + self.block_size, self.vocab_size)

    def block_rows(self, ckt: np.ndarray, block: int) -> np.ndarray:
        """Slice the rows of a word-major ``[V, K]`` table for one block."""
        lo = block * self.block_size
        return ckt[lo:lo + self.block_size]


def partition_vocab(vocab_size: int, num_blocks: int) -> VocabPartition:
    if num_blocks <= 0:
        raise ValueError(f"num_blocks must be positive, got {num_blocks}")
    block_size = -(-vocab_size // num_blocks)  # ceil division
    return VocabPartition(vocab_size, num_blocks, block_size)


def block_for(worker: int, rnd: int, num_blocks: int) -> int:
    """Block owned by ``worker`` in round ``rnd`` (Algorithm 1, rotation)."""
    return (worker + rnd) % num_blocks


def owner_for(block: int, rnd: int, num_blocks: int) -> int:
    """Worker owning ``block`` in round ``rnd`` (inverse of :func:`block_for`)."""
    return (block - rnd) % num_blocks


def rotation_permutation(num_workers: int) -> List[Tuple[int, int]]:
    """(src, dst) pairs moving each block to its next-round owner.

    Worker ``m`` owns block ``b = m + r``; next round that block belongs to
    worker ``b - (r+1) = m - 1``.  Hence blocks travel ``m -> m-1`` around the
    ring — this list feeds ``jax.lax.ppermute``.
    """
    return [(m, (m - 1) % num_workers) for m in range(num_workers)]


def schedule_table(num_workers: int) -> np.ndarray:
    """Full iteration schedule: ``table[r, m]`` = block at worker m in round r."""
    r = np.arange(num_workers)[:, None]
    m = np.arange(num_workers)[None, :]
    return (m + r) % num_workers


def serial_order(num_workers: int) -> Sequence[Tuple[int, int, int]]:
    """The canonical serial execution order equivalent to the MP schedule.

    Yields ``(round, worker, block)`` in the order a single machine would
    execute the same task pool; used by tests to prove parallel == serial.
    """
    out = []
    for r in range(num_workers):
        for m in range(num_workers):
            out.append((r, m, block_for(m, r, num_workers)))
    return out


def validate_schedule(num_workers: int) -> None:
    """Every round is a permutation; every (worker, block) pair met once."""
    table = schedule_table(num_workers)
    for r in range(num_workers):
        assert sorted(table[r]) == list(range(num_workers)), (
            f"round {r} blocks collide: {table[r]}")
    for m in range(num_workers):
        assert sorted(table[:, m]) == list(range(num_workers)), (
            f"worker {m} misses blocks: {table[:, m]}")
