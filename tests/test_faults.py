"""Deterministic fault injection + crash-recovery supervisor
(DESIGN.md §15).

The headline property pinned here, for BOTH engines: a training chain
killed by injected crashes at several distinct step offsets and
auto-restarted by the :class:`~repro.launch.supervise.Supervisor` ends
**bitwise equal** — every count array, every assignment, and the rng
bit-generator state — to the chain that never crashed.  Plus: a kill at
EVERY fire point inside both engines' ``save_checkpoint`` leaves a
workdir the quarantine pass turns back into a resumable (old or new,
never mixed) checkpoint, and the atomic-JSON writer survives a kill
between temp-write and rename.
"""
import contextlib
import json
import os

import numpy as np
import pytest

from repro.core import faults
from repro.core.faults import FaultPlan, FaultSpec, InjectedCrash
from repro.data import integrity
from repro.launch import supervise
from repro.launch.supervise import (RestartBudgetExceeded, Supervisor,
                                    prepare_restart)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """A crash mid-test must never leave a plan armed for the next."""
    yield
    faults.deactivate()


def _plan_ctx(plan):
    return faults.injected(plan) if plan is not None \
        else contextlib.nullcontext()


# ---------------------------------------------------------------------------
# FaultPlan mechanics
# ---------------------------------------------------------------------------

class TestFaultPlan:
    def test_noop_without_plan(self):
        faults.fire("step", "iter:0,engine:streaming")  # must not raise
        assert faults.delay("replica", "replica:0,epoch:1") == 0.0

    def test_crash_at_step_matches_exact_iteration(self):
        plan = FaultPlan.crash_at_step(1)
        with faults.injected(plan):
            faults.fire("step", "iter:0,engine:streaming")
            faults.fire("step", "iter:10,engine:streaming")  # no prefix hit
            with pytest.raises(InjectedCrash):
                faults.fire("step", "iter:1,engine:streaming")
        assert plan.fired and "iter:1," in plan.fired[0]

    def test_nth_occurrence_and_every(self):
        plan = FaultPlan([FaultSpec("io_error", "read", "", nth=2)])
        plan.fire("read", "a")                       # 1st: no fire
        with pytest.raises(faults.InjectedIOError):
            plan.fire("read", "b")                   # 2nd: fires
        plan.fire("read", "c")                       # 3rd: spent
        every = FaultPlan([FaultSpec("io_error", "read", "", nth=0)])
        for d in ("a", "b", "c"):
            with pytest.raises(faults.InjectedIOError):
                every.fire("read", d)

    def test_json_roundtrip_resets_counters(self):
        plan = FaultPlan([FaultSpec("crash", "step", "iter:2,", 1)], seed=7)
        with pytest.raises(InjectedCrash):
            plan.fire("step", "iter:2,")
        clone = FaultPlan.from_json(plan.to_json())
        assert clone.seed == 7 and clone.specs == plan.specs
        with pytest.raises(InjectedCrash):         # fresh counters
            clone.fire("step", "iter:2,")

    def test_from_json_rejects_other_formats(self):
        with pytest.raises(ValueError):
            FaultPlan.from_json('{"format": "something-else"}')

    def test_env_var_pickup(self, monkeypatch):
        plan = FaultPlan.crash_at_point("write", match="x.npy")
        monkeypatch.setenv(faults.ENV_VAR, plan.to_json())
        monkeypatch.setattr(faults, "_active", None)
        monkeypatch.setattr(faults, "_env_checked", False)
        got = faults.active()
        assert got is not None and got.specs == plan.specs
        faults.deactivate()

    def test_bit_flip_kind_corrupts_artifact(self, tmp_path):
        p = str(tmp_path / "a.npy")
        integrity.save_npy(p, np.arange(64))
        plan = FaultPlan([FaultSpec("bit_flip", "read", "a.npy", nth=1,
                                    arg=-1.0)])
        with faults.injected(plan):
            with pytest.raises(integrity.CorruptArtifactError):
                integrity.load_npy(p)  # fire("read") flips, checksum trips

    def test_replica_slow_delay_accumulates(self):
        plan = FaultPlan.replica_slow(1, 0.25, nth=0)
        assert plan.delay("replica", "replica:0,epoch:1") == 0.0
        assert plan.delay("replica", "replica:1,epoch:1") == 0.25
        assert plan.delay("replica", "replica:1,epoch:2") == 0.25

    def test_injected_ctx_disarms_even_on_crash(self):
        with pytest.raises(InjectedCrash):
            with faults.injected(FaultPlan.crash_at_point("step")):
                faults.fire("step", "anything")
        assert faults.active() is None


# ---------------------------------------------------------------------------
# Atomic JSON writes: kill between temp-write and rename (satellite)
# ---------------------------------------------------------------------------

class TestAtomicJsonCrash:
    def test_old_content_survives_kill_before_rename(self, tmp_path):
        p = str(tmp_path / "manifest.json")
        integrity.atomic_write_json(p, {"v": 1}, checksum=True)
        with faults.injected(FaultPlan.crash_at_point("json.tmp_written")):
            with pytest.raises(InjectedCrash):
                integrity.atomic_write_json(p, {"v": 2}, checksum=True)
        with open(p) as f:
            assert json.load(f) == {"v": 1}      # old content intact
        assert integrity.validate_file(p) is True  # sidecar still matches

    def test_no_file_at_all_if_first_write_killed(self, tmp_path):
        p = str(tmp_path / "fresh.json")
        with faults.injected(FaultPlan.crash_at_point("json.tmp_written")):
            with pytest.raises(InjectedCrash):
                integrity.atomic_write_json(p, {"v": 1})
        assert not os.path.exists(p)             # never half-written


# ---------------------------------------------------------------------------
# Shared fixtures / helpers for the engine-level tests
# ---------------------------------------------------------------------------

TOTAL_ITERS = 4
K, W, SEED = 4, 2, 5


def _stream_corpus(tmp_path):
    from repro.data.stream import write_zipf_stream
    return write_zipf_stream(str(tmp_path / "corpus"), 18, 48, 9,
                             seed=11, docs_per_shard=6)


def _stream_chain_state(lda):
    s = lda.gather_counts()
    return (np.asarray(s.cdk), np.asarray(s.ckt), np.asarray(s.ck),
            lda.assignments(), lda._rng.bit_generator.state,
            lda.iteration_count)


def _assert_state_equal(a, b, ctx):
    for name, x, y in zip(("cdk", "ckt", "ck", "z"), a[:4], b[:4]):
        np.testing.assert_array_equal(x, y,
                                      err_msg=f"{ctx}: {name} diverged")
    assert a[4] == b[4], f"{ctx}: rng state diverged"
    assert a[5] == b[5], f"{ctx}: iteration count diverged"


def _stream_reference(tmp_path, cdir):
    from repro.core.engine.streaming import StreamingLDA
    lda = StreamingLDA(cdir, str(tmp_path / "wd_ref"), K, W, seed=SEED)
    lda.run(TOTAL_ITERS, checkpoint_every=1)
    return _stream_chain_state(lda)


def _mp_corpus():
    from repro.data.synthetic import synthetic_corpus
    corpus, _, _ = synthetic_corpus(16, 32, K, 8, seed=3)
    return corpus


def _mp_chain_state(lda):
    s = lda.gather_counts()
    return (np.asarray(s.cdk), np.asarray(s.ckt), np.asarray(s.ck),
            lda.assignments(), lda._rng.bit_generator.state,
            lda.iteration_count)


# ---------------------------------------------------------------------------
# Kill during save_checkpoint, at EVERY fire point, both engines
# ---------------------------------------------------------------------------

class TestCheckpointKillStreaming:
    @pytest.mark.parametrize("point", ["ckpt.begin", "ckpt.tmp_copied",
                                       "ckpt.old_moved", "ckpt.promoted"])
    def test_kill_mid_checkpoint_resumes_consistent(self, tmp_path, point):
        """Kill inside the checkpoint's atomic swap: resume must land on
        the OLD checkpoint or the NEW one — never a mix — and continuing
        to TOTAL_ITERS matches the uninterrupted chain bitwise."""
        from repro.core.engine.streaming import StreamingLDA
        cdir = _stream_corpus(tmp_path)
        ref = _stream_reference(tmp_path, cdir)

        wd = str(tmp_path / "wd_kill")
        lda = StreamingLDA(cdir, wd, K, W, seed=SEED)
        lda.run(2, checkpoint_every=1)           # good checkpoint @ iter 2
        lda.step()                               # iter 3, not yet saved
        with faults.injected(FaultPlan.crash_at_point(point)):
            with pytest.raises(InjectedCrash):
                lda.save_checkpoint()

        info = prepare_restart(wd)
        assert info["kind"] == "streaming" and info["resumable"]
        res = StreamingLDA.resume(wd)
        assert res.iteration_count in (2, 3), \
            f"kill at {point}: landed on mixed iteration"
        while res.iteration_count < TOTAL_ITERS:
            res.step()
            res.save_checkpoint()
        _assert_state_equal(_stream_chain_state(res), ref,
                            f"kill at {point}")

    def test_second_checkpoint_after_promote_kill(self, tmp_path):
        """A kill right after promote leaves ckpt.old behind; the NEXT
        save_checkpoint must clear the debris, not trip over it."""
        from repro.core.engine.streaming import StreamingLDA
        cdir = _stream_corpus(tmp_path)
        wd = str(tmp_path / "wd")
        lda = StreamingLDA(cdir, wd, K, W, seed=SEED)
        lda.run(2, checkpoint_every=1)
        lda.step()
        with faults.injected(FaultPlan.crash_at_point("ckpt.promoted")):
            with pytest.raises(InjectedCrash):
                lda.save_checkpoint()
        assert os.path.isdir(os.path.join(wd, "ckpt.old"))
        lda.save_checkpoint()                    # in-process retry works
        assert not os.path.exists(os.path.join(wd, "ckpt.old"))
        assert StreamingLDA.resume(wd).iteration_count == 3


class TestCheckpointKillMP:
    @pytest.mark.parametrize("point,match", [
        ("mp_ckpt.begin", ""), ("npz.tmp_written", "engine_ckpt"),
        ("mp_ckpt.promoted", "")])
    def test_kill_mid_checkpoint_resumes_consistent(self, tmp_path, point,
                                                    match):
        from repro.core.model_parallel import ModelParallelLDA
        corpus = _mp_corpus()
        ref = ModelParallelLDA(corpus, K, W, seed=SEED)
        for _ in range(TOTAL_ITERS):
            ref.step()
        ref_state = _mp_chain_state(ref)

        wd = str(tmp_path / "wd")
        os.makedirs(wd)
        ckpt = os.path.join(wd, supervise.MP_CKPT)
        lda = ModelParallelLDA(corpus, K, W, seed=SEED)
        lda.step()
        lda.step()
        lda.save_checkpoint(ckpt)                # good checkpoint @ iter 2
        lda.step()                               # iter 3
        with faults.injected(FaultPlan.crash_at_point(point, match=match)):
            with pytest.raises(InjectedCrash):
                lda.save_checkpoint(ckpt)

        info = prepare_restart(wd)
        assert info["kind"] == "mp" and info["resumable"]
        assert not os.path.exists(ckpt + ".tmp")  # debris quarantined
        res = ModelParallelLDA.resume(corpus, ckpt)
        assert res.iteration_count in (2, 3), \
            f"kill at {point}: landed on mixed iteration"
        while res.iteration_count < TOTAL_ITERS:
            res.step()
        _assert_state_equal(_mp_chain_state(res), ref_state,
                            f"kill at {point}")


# ---------------------------------------------------------------------------
# Supervisor: quarantine + restart decisions
# ---------------------------------------------------------------------------

class TestPrepareRestart:
    def test_empty_and_missing_workdir(self, tmp_path):
        assert prepare_restart(str(tmp_path / "nope"))["kind"] is None
        wd = str(tmp_path / "wd")
        os.makedirs(wd)
        info = prepare_restart(wd)
        assert info == {"kind": None, "resumable": False, "quarantined": []}

    def test_clean_streaming_workdir_untouched(self, tmp_path):
        from repro.core.engine.streaming import StreamingLDA
        cdir = _stream_corpus(tmp_path)
        wd = str(tmp_path / "wd")
        StreamingLDA(cdir, wd, K, W, seed=SEED).run(1, checkpoint_every=1)
        info = prepare_restart(wd)
        assert info["kind"] == "streaming" and info["resumable"]
        assert info["quarantined"] == []
        # idempotent
        assert prepare_restart(wd)["quarantined"] == []

    def test_corrupt_streaming_ckpt_quarantined_not_deleted(self, tmp_path):
        from repro.core.engine.streaming import StreamingLDA
        cdir = _stream_corpus(tmp_path)
        wd = str(tmp_path / "wd")
        StreamingLDA(cdir, wd, K, W, seed=SEED).run(1, checkpoint_every=1)
        integrity.flip_byte(os.path.join(wd, "ckpt", "ck.npy"), seed=1)
        info = prepare_restart(wd)
        assert info["kind"] == "streaming" and not info["resumable"]
        qroot = os.path.join(wd, supervise.QUARANTINE_DIR)
        assert os.path.isdir(qroot) and len(os.listdir(qroot)) > 0
        assert any("ckpt" in os.path.basename(q)
                   for q in info["quarantined"])
        # nothing but the quarantine dir remains: next attempt is fresh
        assert sorted(os.listdir(wd)) == [supervise.QUARANTINE_DIR]

    def test_mp_tmp_debris_quarantined(self, tmp_path):
        from repro.core.model_parallel import ModelParallelLDA
        corpus = _mp_corpus()
        wd = str(tmp_path / "wd")
        os.makedirs(wd)
        ckpt = os.path.join(wd, supervise.MP_CKPT)
        lda = ModelParallelLDA(corpus, K, W, seed=SEED)
        lda.step()
        lda.save_checkpoint(ckpt)
        with open(ckpt + ".tmp", "wb") as f:
            f.write(b"half a checkpoint")
        info = prepare_restart(wd)
        assert info["kind"] == "mp" and info["resumable"]
        assert len(info["quarantined"]) == 1
        assert not os.path.exists(ckpt + ".tmp")
        ModelParallelLDA.resume(corpus, ckpt)    # survivor still loads

    def test_backoff_is_deterministic_and_bounded(self, tmp_path):
        mk = lambda seed: Supervisor(lambda a, r: 0, str(tmp_path),
                                     seed=seed, backoff_base=0.05,
                                     backoff_cap=2.0)
        a, b, c = mk(1), mk(1), mk(2)
        for i in range(6):
            assert a.backoff(i) == b.backoff(i)
            assert 0.0 < a.backoff(i) <= 2.0 * 1.5
        assert any(a.backoff(i) != c.backoff(i) for i in range(6))

    def test_restart_budget_exceeded(self, tmp_path):
        sleeps = []

        def always_crash(attempt, resumable):
            raise RuntimeError(f"boom {attempt}")

        sup = Supervisor(always_crash, str(tmp_path), max_restarts=2,
                         sleep=sleeps.append, log=lambda m: None)
        with pytest.raises(RestartBudgetExceeded):
            sup.run()
        assert len(sleeps) == 2                  # one backoff per restart

    def test_injected_crash_is_caught_by_supervisor(self, tmp_path):
        calls = []

        def child(attempt, resumable):
            calls.append(attempt)
            if attempt == 0:
                raise InjectedCrash("step", "iter:0,", 0)
            return 0

        rep = Supervisor(child, str(tmp_path), sleep=lambda d: None,
                         log=lambda m: None).run()
        assert calls == [0, 1] and rep.exit_code == 0 and rep.restarts == 1
        assert rep.crashes and "InjectedCrash" in rep.crashes[0]

    def test_strip_supervise_args(self):
        argv = ["--engine", "mp", "--supervise", "--max-restarts", "5",
                "--restart-backoff=0.1", "--iters", "3"]
        assert supervise.strip_supervise_args(argv) == \
            ["--engine", "mp", "--iters", "3"]


# ---------------------------------------------------------------------------
# The headline property: crashed+supervised == uninterrupted, bitwise
# ---------------------------------------------------------------------------

CRASH_OFFSETS = [0, 2, 3]      # >= 3 distinct step offsets (acceptance)


def _make_supervised_child(plans, build, resume, total=TOTAL_ITERS):
    """In-process lda_train analogue: attempt i runs under plans[i]
    (None = no faults), building fresh or resuming per the supervisor's
    quarantine verdict, checkpointing every iteration."""

    def run_child(attempt, resumable):
        plan = plans[attempt] if attempt < len(plans) else None
        with _plan_ctx(plan):
            lda = resume() if resumable else build()
            while lda.iteration_count < total:
                lda.step()
                lda.checkpoint()
        return 0

    return run_child


class TestSupervisedBitwiseRecovery:
    def test_streaming_crashes_at_three_offsets(self, tmp_path):
        from repro.core.engine.streaming import StreamingLDA
        cdir = _stream_corpus(tmp_path)
        ref = _stream_reference(tmp_path, cdir)

        wd = str(tmp_path / "wd_crash")
        plans = [FaultPlan.crash_at_step(n) for n in CRASH_OFFSETS]

        def wrap(lda):
            lda.checkpoint = lda.save_checkpoint
            return lda

        child = _make_supervised_child(
            plans,
            build=lambda: wrap(StreamingLDA(cdir, wd, K, W, seed=SEED)),
            resume=lambda: wrap(StreamingLDA.resume(wd)))
        rep = Supervisor(child, wd, max_restarts=len(plans),
                         sleep=lambda d: None, log=lambda m: None).run()
        assert rep.exit_code == 0
        assert rep.restarts == len(CRASH_OFFSETS)
        # crash at iter 0 precedes any checkpoint -> fresh; later crashes
        # resume from the last good checkpoint
        assert rep.resumed == [False, False, True, True]
        assert rep.quarantined                  # iter-0 debris quarantined

        final = StreamingLDA.resume(wd)
        _assert_state_equal(_stream_chain_state(final), ref,
                            "supervised streaming recovery")

    @pytest.mark.parametrize("backend", ["vmap", "shard_map"])
    def test_mp_engine_both_backends(self, tmp_path, backend):
        from repro.core.model_parallel import ModelParallelLDA
        corpus = _mp_corpus()
        ref = ModelParallelLDA(corpus, K, W, seed=SEED, backend=backend)
        for _ in range(TOTAL_ITERS):
            ref.step()
        ref_state = _mp_chain_state(ref)

        wd = str(tmp_path / "wd")
        os.makedirs(wd)
        ckpt = os.path.join(wd, supervise.MP_CKPT)
        plans = [FaultPlan.crash_at_step(n) for n in CRASH_OFFSETS]

        def wrap(lda):
            lda.checkpoint = lambda: lda.save_checkpoint(ckpt)
            return lda

        child = _make_supervised_child(
            plans,
            build=lambda: wrap(ModelParallelLDA(corpus, K, W, seed=SEED,
                                                backend=backend)),
            resume=lambda: wrap(ModelParallelLDA.resume(corpus, ckpt,
                                                        backend=backend)))
        rep = Supervisor(child, wd, max_restarts=len(plans),
                         sleep=lambda d: None, log=lambda m: None).run()
        assert rep.exit_code == 0
        assert rep.resumed == [False, False, True, True]

        final = ModelParallelLDA.resume(corpus, ckpt, backend=backend)
        _assert_state_equal(_mp_chain_state(final), ref_state,
                            f"supervised mp recovery [{backend}]")

    def test_crash_mid_checkpoint_then_supervised_recovery(self, tmp_path):
        """Compound failure: the crash lands INSIDE save_checkpoint (the
        torn-swap window), so the supervisor must quarantine the debris
        AND the resumed chain must still match bitwise."""
        from repro.core.engine.streaming import StreamingLDA
        cdir = _stream_corpus(tmp_path)
        ref = _stream_reference(tmp_path, cdir)

        wd = str(tmp_path / "wd")
        plans = [FaultPlan.crash_at_point("ckpt.tmp_copied", nth=2)]

        def wrap(lda):
            lda.checkpoint = lda.save_checkpoint
            return lda

        child = _make_supervised_child(
            plans,
            build=lambda: wrap(StreamingLDA(cdir, wd, K, W, seed=SEED)),
            resume=lambda: wrap(StreamingLDA.resume(wd)))
        rep = Supervisor(child, wd, max_restarts=2, sleep=lambda d: None,
                         log=lambda m: None).run()
        assert rep.exit_code == 0 and rep.restarts == 1
        assert any("ckpt.tmp" in os.path.basename(q)
                   for q in rep.quarantined)
        final = StreamingLDA.resume(wd)
        _assert_state_equal(_stream_chain_state(final), ref,
                            "mid-checkpoint crash recovery")
