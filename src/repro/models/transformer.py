"""Generic architecture-zoo model: one functional implementation covering
dense / MoE / hybrid (attn ∥ SSD) / xLSTM / VLM / encoder-decoder families,
driven entirely by ``ArchConfig``.

Structure
  * ``init(seed)`` materializes fp32 params (reduced configs only);
    ``abstract_params()`` gives ShapeDtypeStructs for the dry-run.
  * ``forward``/``loss`` — full-sequence path (train & prefill), layers run
    under ``lax.scan`` over stacked parameters with per-layer window sizes
    as scanned scalars, each block wrapped in ``jax.checkpoint`` (remat).
  * ``init_cache``/``decode_step`` — single-token serving path; layers are
    a Python loop so per-layer cache shapes may differ (gemma3's local
    layers keep a 1024-slot ring while global layers keep the full
    context — the sub-quadratic-decode requirement of the 500k shape).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssd
from repro.models.common import (KeyGen, Params, apply_norm, cast,
                                 dense_init, embed_init, gelu, norm_params,
                                 scan_unroll, shard_activations,
                                 shard_logits, swiglu)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_params(keys, d_model: int, d_ff: int, gated: bool) -> Params:
    if gated:
        return {"w_gate": dense_init(keys(), (d_model, d_ff)),
                "w_up": dense_init(keys(), (d_model, d_ff)),
                "w_down": dense_init(keys(), (d_ff, d_model))}
    return {"w_in": dense_init(keys(), (d_model, d_ff)),
            "w_out": dense_init(keys(), (d_ff, d_model))}


def mlp(p: Params, x: jax.Array) -> jax.Array:
    if "w_gate" in p:
        return swiglu(x @ cast(p["w_gate"]), x @ cast(p["w_up"])) \
            @ cast(p["w_down"])
    return gelu(x @ cast(p["w_in"])) @ cast(p["w_out"])


def sinusoid_at(pos: jax.Array, d: int) -> jax.Array:
    """Sinusoidal embedding at traced positions.  pos: [B] -> [B, d]."""
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    angle = pos[:, None].astype(jnp.float32) / jnp.power(10000.0, dim / d)
    out = jnp.zeros((pos.shape[0], d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(angle))
    out = out.at[:, 1::2].set(jnp.cos(angle))
    return out


def sinusoid_positions(t: int, d: int, offset: int = 0) -> jax.Array:
    pos = np.arange(offset, offset + t)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    angle = pos / np.power(10000.0, dim / d)
    out = np.zeros((t, d), np.float32)
    out[:, 0::2] = np.sin(angle)
    out[:, 1::2] = np.cos(angle)
    return jnp.asarray(out)


class Model:
    """Functional model bound to an ``ArchConfig``."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.head_dim = cfg.resolved_head_dim
        self.gated_mlp = cfg.family != "audio"
        self.windows = jnp.asarray(cfg.layer_windows(), jnp.int32)

    # ------------------------------------------------------------------
    # Parameter construction
    # ------------------------------------------------------------------
    def _layer_params(self, keys) -> Params:
        c = self.cfg
        hd = self.head_dim
        kind = c.block_type
        p: Params = {"ln1": norm_params(c.d_model, c.norm)}
        if kind == "xlstm":
            p["mlstm"] = ssd.mlstm_params(keys, c.d_model, c.num_heads, hd)
            p["ln2"] = norm_params(c.d_model, c.norm)
            p["slstm"] = ssd.slstm_params(keys, c.d_model)
            return p
        p["attn"] = attn.attention_params(keys, c.d_model, c.num_heads,
                                          c.num_kv_heads, hd, c.qkv_bias)
        if kind == "hybrid":
            p["mamba"] = ssd.mamba_params(keys, c.d_model,
                                          c.ssm_heads or c.num_heads,
                                          hd, c.ssm_state_size)
        p["ln2"] = norm_params(c.d_model, c.norm)
        if kind == "moe":
            p["moe"] = moe_lib.moe_params(
                keys, c.d_model, c.d_ff, c.num_experts,
                c.num_shared_experts,
                c.num_shared_experts * c.d_ff if c.num_shared_experts else 0)
        else:
            p["mlp"] = mlp_params(keys, c.d_model, c.d_ff, self.gated_mlp)
        return p

    def _encoder_layer_params(self, keys) -> Params:
        c = self.cfg
        return {
            "ln1": norm_params(c.d_model, c.norm),
            "attn": attn.attention_params(keys, c.d_model, c.num_heads,
                                          c.num_kv_heads, self.head_dim),
            "ln2": norm_params(c.d_model, c.norm),
            "mlp": mlp_params(keys, c.d_model, c.d_ff, self.gated_mlp),
        }

    def _decoder_xattn_params(self, keys) -> Params:
        c = self.cfg
        return {
            "ln_x": norm_params(c.d_model, c.norm),
            "xattn": attn.attention_params(keys, c.d_model, c.num_heads,
                                           c.num_kv_heads, self.head_dim),
        }

    def _num_scan_layers(self) -> int:
        if self.cfg.block_pattern:   # xlstm pairs
            return self.cfg.num_layers // len(self.cfg.block_pattern)
        return self.cfg.num_layers

    def init(self, seed: int = 0) -> Params:
        c = self.cfg
        keys = KeyGen(seed)
        layers = [self._layer_params(keys) for _ in range(self._num_scan_layers())]
        if c.family == "audio":
            for lp, _ in zip(layers, range(len(layers))):
                lp.update(self._decoder_xattn_params(keys))
        params: Params = {
            "embed": embed_init(keys(), (c.vocab_size, c.d_model)),
            "layers": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *layers),
            "final_norm": norm_params(c.d_model, c.norm),
        }
        if not c.tie_embeddings:
            params["unembed"] = dense_init(keys(), (c.d_model, c.vocab_size))
        if c.family == "audio":
            enc_layers = [self._encoder_layer_params(keys)
                          for _ in range(c.encoder_layers)]
            params["encoder"] = {
                "layers": jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *enc_layers),
                "final_norm": norm_params(c.d_model, c.norm),
            }
        return params

    def abstract_params(self) -> Params:
        return jax.eval_shape(lambda: self.init(0))

    # ------------------------------------------------------------------
    # Blocks (full-sequence)
    # ------------------------------------------------------------------
    def _block(self, p: Params, x: jax.Array, positions: jax.Array,
               window) -> Tuple[jax.Array, jax.Array]:
        """One decoder block; returns (x, aux_loss)."""
        c = self.cfg
        hd = self.head_dim
        aux = jnp.float32(0.0)
        kind = c.block_type
        if kind == "xlstm":
            h = apply_norm(x, p["ln1"], c.norm)
            x = x + ssd.mlstm_mixer(p["mlstm"], h, c.num_heads, hd)
            h = apply_norm(x, p["ln2"], c.norm)
            x = x + ssd.slstm_scan(p["slstm"], h)
            return x, aux
        h = apply_norm(x, p["ln1"], c.norm)
        a = attn.self_attention(p["attn"], h, positions, c.num_heads,
                                c.num_kv_heads, hd, c.rope_theta, window)
        if kind == "hybrid":
            m = ssd.mamba_mixer(p["mamba"], h, c.ssm_heads or c.num_heads,
                                hd, c.ssm_state_size)
            x = x + 0.5 * (a + m)       # Hymba parallel-head fusion (mean)
        else:
            x = x + a
        h = apply_norm(x, p["ln2"], c.norm)
        if kind == "moe":
            y, aux = moe_lib.moe_layer(p["moe"], h, c.num_experts,
                                       c.num_experts_per_tok,
                                       c.moe_capacity_factor)
            x = x + y
        else:
            x = x + mlp(p["mlp"], h)
        return x, aux

    def _stack(self, params: Params, x: jax.Array, positions: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
        c = self.cfg
        if c.block_pattern:
            windows = jnp.zeros((self._num_scan_layers(),), jnp.int32)
        else:
            windows = self.windows

        def body(carry, xs):
            x, aux = carry
            p, w = xs
            x, a = self._block(p, x, positions, w)
            return (shard_activations(x), aux + a), None

        body = jax.checkpoint(body, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                   (params["layers"], windows),
                                   unroll=scan_unroll())
        return x, aux

    # ------------------------------------------------------------------
    # Encoder (audio)
    # ------------------------------------------------------------------
    def _encode(self, params: Params, frames: jax.Array) -> jax.Array:
        c = self.cfg
        x = cast(frames) + cast(sinusoid_positions(frames.shape[1],
                                                   c.d_model))[None]
        positions = jnp.broadcast_to(jnp.arange(frames.shape[1]),
                                     frames.shape[:2]).astype(jnp.int32)

        def body(carry, p):
            x = carry
            h = apply_norm(x, p["ln1"], c.norm)
            x = x + attn.self_attention(p["attn"], h, positions,
                                        c.num_heads, c.num_kv_heads,
                                        self.head_dim, 0.0, 0,
                                        causal=False)
            h = apply_norm(x, p["ln2"], c.norm)
            x = x + mlp(p["mlp"], h)
            return shard_activations(x), None

        body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["encoder"]["layers"],
                            unroll=scan_unroll())
        return apply_norm(x, params["encoder"]["final_norm"], c.norm)

    # ------------------------------------------------------------------
    # Forward / loss (train & prefill)
    # ------------------------------------------------------------------
    def forward(self, params: Params, tokens: jax.Array,
                patch_embeds: Optional[jax.Array] = None,
                frames: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
        """Returns (logits [B, T_total, V], aux_loss)."""
        c = self.cfg
        x = shard_activations(cast(params["embed"])[tokens])
        if c.family == "vlm":
            assert patch_embeds is not None
            x = shard_activations(
                jnp.concatenate([cast(patch_embeds), x], axis=1))
        if c.family == "audio":
            assert frames is not None
            # encoder runs once; each decoder layer builds its own cross
            # K/V from the shared encoder output inside the layer scan.
            self._enc_out = self._encode(params, frames)
            x = x + cast(sinusoid_positions(x.shape[1], c.d_model))[None]
        b, t, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(t), (b, t)).astype(jnp.int32)
        if c.family == "audio":
            x, aux = self._stack_audio(params, x, positions)
        else:
            x, aux = self._stack(params, x, positions)
        x = apply_norm(x, params["final_norm"], c.norm)
        if c.tie_embeddings:
            logits = x @ cast(params["embed"]).T
        else:
            logits = x @ cast(params["unembed"])
        return logits, aux

    def _stack_audio(self, params, x, positions):
        c = self.cfg
        enc_out = self._enc_out

        def body(carry, p):
            x, aux = carry
            h = apply_norm(x, p["ln1"], c.norm)
            x = x + attn.self_attention(p["attn"], h, positions,
                                        c.num_heads, c.num_kv_heads,
                                        self.head_dim, c.rope_theta, 0)
            hx = apply_norm(x, p["ln_x"], c.norm)
            kv = attn.encode_cross_kv(p["xattn"], enc_out, c.num_kv_heads,
                                      self.head_dim)
            kv = (attn._repeat_kv(kv[0], c.num_heads),
                  attn._repeat_kv(kv[1], c.num_heads))
            x = x + attn.cross_attention(p["xattn"], hx, kv, c.num_heads,
                                         self.head_dim)
            h = apply_norm(x, p["ln2"], c.norm)
            x = x + mlp(p["mlp"], h)
            return (shard_activations(x), aux), None

        body = jax.checkpoint(body, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                   params["layers"], unroll=scan_unroll())
        return x, aux

    def hidden(self, params: Params, tokens, patch_embeds=None, frames=None
               ) -> Tuple[jax.Array, jax.Array]:
        """Final hidden states (pre-unembed) — shared by loss/prefill."""
        c = self.cfg
        x = shard_activations(cast(params["embed"])[tokens])
        if c.family == "vlm":
            assert patch_embeds is not None
            x = shard_activations(
                jnp.concatenate([cast(patch_embeds), x], axis=1))
        if c.family == "audio":
            assert frames is not None
            self._enc_out = self._encode(params, frames)
            x = x + cast(sinusoid_positions(x.shape[1], c.d_model))[None]
        b, t, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(t), (b, t)).astype(jnp.int32)
        if c.family == "audio":
            x, aux = self._stack_audio(params, x, positions)
        else:
            x, aux = self._stack(params, x, positions)
        return apply_norm(x, params["final_norm"], c.norm), aux

    def _unembed_matrix(self, params: Params) -> jax.Array:
        if self.cfg.tie_embeddings:
            return cast(params["embed"]).T
        return cast(params["unembed"])

    def loss(self, params: Params, batch: Dict[str, jax.Array],
             ce_chunk: int = 512) -> jax.Array:
        """Next-token cross-entropy; labels == -1 are masked (e.g. the VLM
        patch positions).  Adds the MoE load-balance auxiliary.

        The CE is computed in sequence chunks under ``jax.checkpoint`` so
        the fp32 [B, T, V] logits tensor is never materialized — peak is
        one [B, ce_chunk, V] block (§Perf iteration: 13.5 GiB -> 1.6 GiB
        on olmo-1b train_4k).
        """
        x, aux = self.hidden(params, batch["tokens"],
                             batch.get("patch_embeds"), batch.get("frames"))
        labels = batch["labels"]
        if self.cfg.family == "vlm":
            pad = -jnp.ones((labels.shape[0], self.cfg.num_patch_embeds),
                            labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        w = self._unembed_matrix(params)
        b, t, d = x.shape
        chunk = min(ce_chunk, t)
        while t % chunk:
            chunk -= 1
        n = t // chunk
        xs = (jnp.moveaxis(x.reshape(b, n, chunk, d), 1, 0),
              jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0))

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def ce_block(carry, xs_i):
            nll_sum, cnt = carry
            xc, lc = xs_i
            logits = shard_logits((xc @ w).astype(jnp.float32))
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
            mask = (lc >= 0).astype(jnp.float32)
            return (nll_sum + jnp.sum((logz - gold) * mask),
                    cnt + jnp.sum(mask)), None

        (nll_sum, cnt), _ = jax.lax.scan(
            ce_block, (jnp.float32(0.0), jnp.float32(0.0)), xs)
        ce = nll_sum / jnp.maximum(cnt, 1.0)
        return ce + 0.01 * aux

    # ------------------------------------------------------------------
    # Serving: cache + single-token decode
    # ------------------------------------------------------------------
    def uniform_cache(self) -> bool:
        """True when every layer's cache has identical shape — then the
        cache is kept STACKED [L, ...] and decode runs as a ``lax.scan``
        over layers (compile time O(1) in depth — the 94-layer MoE decode
        went from a pathological unrolled compile to seconds).  Mixed
        window/global stacks (gemma3, hymba) keep per-layer lists and an
        unrolled loop so local layers can hold ring buffers of a different
        size."""
        c = self.cfg
        return len(set(c.layer_windows())) == 1 or bool(c.block_pattern)

    def _layer_cache(self, batch: int, seq_len: int, window: int, dtype):
        c = self.cfg
        hd = self.head_dim
        if c.block_pattern:
            return {
                "mlstm_state": ssd.mlstm_init_state(batch, c.num_heads, hd),
                "slstm_state": ssd.slstm_init_state(batch, c.d_model),
            }
        size = min(window, seq_len) if window > 0 else seq_len
        import os as _os
        env = _os.environ.get("REPRO_REPEAT_KV_CACHE")
        if env:  # store KV repeated to >= this many heads (model-axis width)
            target = c.num_heads if env == "1" else int(env)
            kvh = c.num_kv_heads
            while kvh < min(target, c.num_heads):
                kvh *= 2
        else:
            kvh = c.num_kv_heads
        entry = attn.init_kv_cache(batch, size, kvh, hd, dtype)
        if c.block_type == "hybrid":
            entry["ssm_state"] = ssd.mamba_init_state(
                batch, c.ssm_heads or c.num_heads, hd, c.ssm_state_size)
        return entry

    def init_cache(self, batch: int, seq_len: int, dtype=jnp.bfloat16):
        """Stacked [L, ...] cache pytree for uniform stacks, else a
        per-layer list (windowed ring buffers differ in size)."""
        c = self.cfg
        n = self._num_scan_layers()
        windows = (c.layer_windows() if not c.block_pattern
                   else (0,) * n)
        if self.uniform_cache():
            one = self._layer_cache(batch, seq_len, windows[0], dtype)
            return jax.tree_util.tree_map(
                lambda x: jnp.zeros((n,) + x.shape, x.dtype), one)
        return [self._layer_cache(batch, seq_len, w, dtype) for w in windows]

    def _decode_layer(self, p: Params, cache: Params, x: jax.Array,
                      pos: jax.Array, window, enc_out
                      ) -> Tuple[jax.Array, Params]:
        """One layer of single-token decode; shared by the unrolled and
        scanned paths."""
        c = self.cfg
        hd = self.head_dim
        if c.block_pattern:
            h = apply_norm(x, p["ln1"], c.norm)
            y, mstate = ssd.mlstm_decode(p["mlstm"], cache["mlstm_state"],
                                         h, c.num_heads, hd)
            x = x + y
            h = apply_norm(x, p["ln2"], c.norm)
            y, sstate = ssd.slstm_decode(p["slstm"], cache["slstm_state"], h)
            x = x + y
            return x, {"mlstm_state": mstate, "slstm_state": sstate}
        h = apply_norm(x, p["ln1"], c.norm)
        a, kv = attn.decode_self_attention(
            p["attn"], {"k": cache["k"], "v": cache["v"]}, h, pos,
            c.num_heads, c.num_kv_heads, hd, c.rope_theta, window)
        entry = dict(kv)
        if c.block_type == "hybrid":
            m, sstate = ssd.mamba_decode(
                p["mamba"], cache["ssm_state"], h,
                c.ssm_heads or c.num_heads, hd, c.ssm_state_size)
            x = x + 0.5 * (a + m)
            entry["ssm_state"] = sstate
        else:
            x = x + a
        if c.family == "audio":
            hx = apply_norm(x, p["ln_x"], c.norm)
            kv_x = attn.encode_cross_kv(p["xattn"], enc_out,
                                        c.num_kv_heads, hd)
            kv_x = (attn._repeat_kv(kv_x[0], c.num_heads),
                    attn._repeat_kv(kv_x[1], c.num_heads))
            x = x + attn.cross_attention(p["xattn"], hx, kv_x,
                                         c.num_heads, hd)
        h = apply_norm(x, p["ln2"], c.norm)
        if c.block_type == "moe":
            y, _ = moe_lib.moe_layer(p["moe"], h, c.num_experts,
                                     c.num_experts_per_tok,
                                     c.moe_capacity_factor)
            x = x + y
        else:
            x = x + mlp(p["mlp"], h)
        return x, entry

    def decode_step(self, params: Params, caches,
                    tokens: jax.Array, pos: jax.Array,
                    enc_out: Optional[jax.Array] = None):
        """tokens: [B, 1]; pos: [B] absolute positions.  Returns
        (logits [B, 1, V], new caches).

        Stacked caches (uniform layers) run under ``lax.scan`` — constant
        compile time in depth; per-layer cache lists (heterogeneous window
        sizes) run an unrolled loop."""
        c = self.cfg
        x = shard_activations(cast(params["embed"])[tokens])
        if c.family == "audio":
            assert enc_out is not None
            x = x + cast(sinusoid_at(pos, c.d_model))[:, None]
        if isinstance(caches, list):
            windows = (list(c.layer_windows()) if not c.block_pattern
                       else [0] * self._num_scan_layers())
            layers = params["layers"]
            new_caches = []
            for i, w in enumerate(windows):
                p = jax.tree_util.tree_map(lambda a, i=i: a[i], layers)
                x, entry = self._decode_layer(p, caches[i], x, pos,
                                              jnp.int32(w), enc_out)
                new_caches.append(entry)
        else:
            window = jnp.int32(c.layer_windows()[0]
                               if not c.block_pattern else 0)

            def body(x, xs):
                p, cache = xs
                x, entry = self._decode_layer(p, cache, x, pos, window,
                                              enc_out)
                return x, entry

            x, new_caches = jax.lax.scan(body, x,
                                         (params["layers"], caches),
                                         unroll=scan_unroll())
        x = apply_norm(x, params["final_norm"], c.norm)
        if c.tie_embeddings:
            logits = x @ cast(params["embed"]).T
        else:
            logits = x @ cast(params["unembed"])
        return logits, new_caches


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
