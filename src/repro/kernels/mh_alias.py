"""Pallas TPU kernel for the FULL alias-table MH cycle.

One ``pallas_call`` runs every MH step of a token's round — word
proposal, doc proposal, and both eq.-(1) acceptances, for all
``num_cycles`` cycles — with the word *and* doc alias rows resident in
VMEM.  Fusing the cycle removes the old kernel-boundary structure (a
kernel per word step with a jnp doc step between kernels): ``z`` now
lives in registers across all ``4·num_cycles`` sub-draws and the only
HBM write is the final assignment tile.

Layout: the word-proposal operands are word-shared — the alias row
``(cut, alias, W)``, the capacity ``U``, and the frozen ``C_k^t`` row
depend only on the word, exactly like the eq.-(3) coefficient cache that
``gibbs_conditional.py`` keeps in VMEM — so the kernel uses the same
word-grouped ``[G, Tg]`` token layout and loads them once per group.
The doc-proposal operands are document-local, so their rows arrive
per-token (``[G, Tg, K]``), as do the frozen ``C_d^k`` rows; fusing
still wins for them because each row is loaded HBM→VMEM once per round
instead of once per cycle.

Scalar gathers are expressed as one-hot reductions over the topic lanes
(`iota == idx` masks) — the TPU-native form of a dynamic lane index; the
values selected are untouched f32 loads, and every draw/accept
comparison is the same division-free single-op form as the jnp steps in
``core/mh.py`` (`_mh_step`), in the same association order, so the fused
kernel is bit-identical to the jnp ``mh`` sweep — asserted by tests at
both table lifetimes.

The sub-draw uniforms arrive pre-expanded (``core.mh.uniform_streams``
stacked to ``[4·num_cycles, G, Tg]``): the splitmix32 expansion is
token-lane-salted with the FLAT token index, which the wrapper knows and
a tile does not, and shipping the streams keeps the kernel math
identical to the jnp path by construction.

K is padded to the 128-lane boundary by the wrapper; the REAL topic
count rides in the consts row so alias cell indices never land on padded
lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gibbs_conditional import TILE_G


def _onehot_f32(values, idx):
    """values [..., K] f32 gathered at idx [...] -> [...] (exact select)."""
    k = values.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, idx.shape + (k,),
                                    idx.ndim)
    return jnp.sum(jnp.where(iota == idx[..., None], values, 0.0), axis=-1)


def _onehot_i32(values, idx):
    k = values.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, idx.shape + (k,),
                                    idx.ndim)
    return jnp.sum(jnp.where(iota == idx[..., None], values, 0), axis=-1)


def _mh_cycle_kernel(num_cycles,
                     wcut_ref, walias_ref, wmass_ref, wucap_ref,
                     dcut_ref, dalias_ref, dmass_ref, ducap_ref,
                     ckt_ref, cdk_ref, z0_ref, streams_ref, mask_ref,
                     ck_ref, alpha_ref, const_ref, out_ref):
    beta = const_ref[0, 0]
    vbeta = const_ref[0, 1]
    kf = const_ref[0, 2]                   # f32(real K), exact for K < 2²⁴
    k_real = kf.astype(jnp.int32)
    ck = ck_ref[0, :]                      # [K]
    alpha = alpha_ref[0, :]                # [K]
    wcut = wcut_ref[...]                   # [G, K] word alias cut masses
    walias = walias_ref[...]               # [G, K] word alias targets
    wmass = wmass_ref[...]                 # [G, K] f32(W) word masses
    wucap = wucap_ref[...]                 # [G, 1] word row capacity
    dcut = dcut_ref[...]                   # [G, T, K] doc alias cut masses
    dalias = dalias_ref[...]               # [G, T, K] doc alias targets
    dmass = dmass_ref[...]                 # [G, T, K] f32(W) doc masses
    ducap = ducap_ref[...]                 # [G, T] doc row capacity
    ckt = ckt_ref[...]                     # [G, K] frozen C_k^t rows
    cdk = cdk_ref[...]                     # [G, T, K] frozen C_d^k rows
    z0 = z0_ref[...]                       # [G, T] round-start assignment
    streams = streams_ref[...]             # [4·cycles, G, T] sub-draws
    mask = mask_ref[...] != 0              # [G, T] validity

    def target_terms(kk):
        # exact eq.-(1) mass at topic kk from frozen counts, ¬dn
        # self-exclusion as a rank-1 correction at z0 (core.mh._target_terms)
        excl = (kk == z0).astype(jnp.float32)
        num = ((_onehot_f32(cdk, kk) - excl
                + _onehot_f32(alpha[None, None, :], kk))
               * (_onehot_f32(ckt[:, None, :], kk) - excl + beta))
        den = _onehot_f32(ck[None, None, :], kk) - excl + vbeta
        return num, den

    def draw(cut, alias, ucap, u_draw):
        # one uniform -> (cell, within-cell threshold) -> resolved topic;
        # cut/alias are [G, K] (word, cell gathered over lanes) or
        # [G, T, K] (doc); ucap broadcasts [G, 1] or [G, T].
        x = u_draw * kf
        j = jnp.minimum(x.astype(jnp.int32), k_real - 1)       # [G, T]
        frac = x - j.astype(jnp.float32)
        if cut.ndim == 2:
            cut_j = _onehot_f32(cut[:, None, :], j)
            alias_j = _onehot_i32(alias[:, None, :], j)
        else:
            cut_j = _onehot_f32(cut, j)
            alias_j = _onehot_i32(alias, j)
        return jnp.where(frac * ucap < cut_j, j, alias_j)

    def gather_mass(massv, kk):
        if massv.ndim == 2:
            return _onehot_f32(massv[:, None, :], kk)
        return _onehot_f32(massv, kk)

    z_cur = z0
    for c in range(num_cycles):
        for table, off in (((wcut, walias, wmass, wucap), 0),
                           ((dcut, dalias, dmass, ducap), 2)):
            cut, alias, massv, ucap = table
            u_draw = streams[4 * c + off]
            u_acc = streams[4 * c + off + 1]
            prop = draw(cut, alias, ucap, u_draw)
            n_new, d_new = target_terms(prop)
            n_old, d_old = target_terms(z_cur)
            q_new = gather_mass(massv, prop)
            q_old = gather_mass(massv, z_cur)
            # division-free cross-multiplied accept test (same association
            # order as core.mh._mh_step — bit-identity depends on it)
            accept = (u_acc * n_old * d_new * q_new
                      < n_new * d_old * q_old) & mask
            z_cur = jnp.where(accept, prop, z_cur)

    out_ref[...] = z_cur


@functools.partial(jax.jit,
                   static_argnames=("k_real", "num_cycles", "tile_g",
                                    "interpret"))
def mh_cycle_call(wcut: jax.Array, walias: jax.Array, wmass: jax.Array,
                  wucap: jax.Array, dcut: jax.Array, dalias: jax.Array,
                  dmass: jax.Array, ducap: jax.Array,
                  ckt_rows: jax.Array, cdk_rows: jax.Array,
                  z0: jax.Array, streams: jax.Array, mask: jax.Array,
                  ck: jax.Array, alpha: jax.Array, beta: float,
                  vbeta: float, k_real: int,
                  num_cycles: int, tile_g: int = TILE_G,
                  interpret: bool = True) -> jax.Array:
    """Raw pallas_call wrapper (tile-aligned shapes; padding in ops.py).

    Args:
      wcut/walias/wmass: [G, K] per-word alias table rows (f32/int32/f32).
      wucap:        [G, 1] f32 per-word cell capacity ``U``.
      dcut/dalias/dmass: [G, Tg, K] per-token DOC alias table rows.
      ducap:        [G, Tg] f32 per-token doc cell capacity.
      ckt_rows:     [G, K] f32 frozen word-topic rows.
      cdk_rows:     [G, Tg, K] f32 frozen doc-topic rows per token; the
                    token tile Tg is taken from this shape.
      z0:           [G, Tg] round-start assignments (the chain starts and
                    self-excludes here).
      streams:      [4·num_cycles, G, Tg] pre-expanded sub-draw uniforms.
      mask:         [G, Tg] int32 validity.
      ck/alpha:     [K] f32.
      k_real:       unpadded K — alias cells only index real topics.
    Returns:
      z after the full fused MH cycle, [G, Tg] int32.
    """
    g, tg, k = cdk_rows.shape
    assert g % tile_g == 0 and k % 128 == 0, (g, k)
    nstream = 4 * num_cycles
    grid = (g // tile_g,)
    consts = jnp.array([[beta, vbeta, float(k_real), 0.0]], jnp.float32)
    row = lambda i: (i, 0)
    row3 = lambda i: (i, 0, 0)
    lead3 = lambda i: (0, i, 0)
    rep = lambda i: (0, 0)
    return pl.pallas_call(
        functools.partial(_mh_cycle_kernel, num_cycles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_g, k), row),            # wcut
            pl.BlockSpec((tile_g, k), row),            # walias
            pl.BlockSpec((tile_g, k), row),            # wmass
            pl.BlockSpec((tile_g, 1), row),            # wucap
            pl.BlockSpec((tile_g, tg, k), row3),       # dcut
            pl.BlockSpec((tile_g, tg, k), row3),       # dalias
            pl.BlockSpec((tile_g, tg, k), row3),       # dmass
            pl.BlockSpec((tile_g, tg), row),           # ducap
            pl.BlockSpec((tile_g, k), row),            # ckt_rows
            pl.BlockSpec((tile_g, tg, k), row3),       # cdk_rows
            pl.BlockSpec((tile_g, tg), row),           # z0
            pl.BlockSpec((nstream, tile_g, tg), lead3),  # streams
            pl.BlockSpec((tile_g, tg), row),           # mask
            pl.BlockSpec((1, k), rep),                 # ck (broadcast)
            pl.BlockSpec((1, k), rep),                 # alpha (broadcast)
            pl.BlockSpec((1, 4), rep),                 # (beta, vbeta, K, _)
        ],
        out_specs=pl.BlockSpec((tile_g, tg), row),
        out_shape=jax.ShapeDtypeStruct((g, tg), jnp.int32),
        interpret=interpret,
    )(wcut.astype(jnp.float32), walias.astype(jnp.int32),
      wmass.astype(jnp.float32), wucap.astype(jnp.float32),
      dcut.astype(jnp.float32), dalias.astype(jnp.int32),
      dmass.astype(jnp.float32), ducap.astype(jnp.float32),
      ckt_rows.astype(jnp.float32), cdk_rows.astype(jnp.float32),
      z0.astype(jnp.int32), streams.astype(jnp.float32),
      mask.astype(jnp.int32), ck[None, :].astype(jnp.float32),
      alpha[None, :].astype(jnp.float32), consts)
