"""Sharded checkpointing to flat .npz archives.

Paths are flattened ``a/b/c`` keys; each save also records a manifest so
restores verify structure.  Works for model params, optimizer state and
LDA engine state (whose KV-store blocks map naturally to one entry each —
the host-side persistence story of the paper's key-value store).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **flat)
    manifest = {"step": step, "keys": sorted(flat),
                "shapes": {k: list(v.shape) for k, v in flat.items()}}
    with open(path + ".manifest.json", "w") as f:
        json.dump(manifest, f)


def load_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (a pytree template)."""
    path_npz = path if path.endswith(".npz") else path + ".npz"
    data = np.load(path_npz)

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(tree[k], f"{prefix}{k}/") for k in tree}
        if isinstance(tree, (list, tuple)):
            vals = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
            return type(tree)(vals)
        key = prefix.rstrip("/")
        arr = data[key]
        assert arr.shape == tuple(np.shape(tree)), (key, arr.shape)
        return jnp.asarray(arr)

    return rebuild(like)


def checkpoint_step(path: str) -> int:
    with open(path + ".manifest.json") as f:
        return json.load(f)["step"]
