"""State-space / linear-recurrent sequence mixers.

Two primitives cover the assigned SSM-family architectures:

* ``ssd_chunked`` — chunked scalar-decay linear attention (the SSD form of
  Mamba-2 / the mLSTM matrix memory).  Exact chunkwise evaluation: within a
  chunk the decay-weighted attention is a dense matmul (MXU-friendly);
  across chunks a ``lax.scan`` carries the [dk, dv] state.  This is the TPU
  adaptation called out in DESIGN.md: per-channel diagonal recurrences are
  restated as scalar-per-head decays so the inner loop is matmuls over
  128-aligned tiles instead of elementwise gather/scatter chains.

* ``slstm_scan`` — the sLSTM scalar recurrence (xLSTM), inherently
  sequential (nonlinear state feedback), evaluated with ``lax.scan`` over
  time; the carry is O(d) so backward-pass storage is T × d, not T × d².

Both have single-step forms for decode with O(1) state — which is what
makes the ssm/hybrid architectures eligible for the 500k-token decode
shape.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

import os

from repro.models.common import Params, cast, dense_init

# SSD chunk width: VMEM/HBM trade-off knob for the §Perf iterations
DEFAULT_CHUNK = int(os.environ.get("REPRO_SSD_CHUNK", "128"))


# ---------------------------------------------------------------------------
# SSD / gated linear attention, chunked
# ---------------------------------------------------------------------------

def ssd_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                log_decay: jax.Array,
                chunk: int | None = None) -> jax.Array:
    """Exact chunked evaluation of  h_t = a_t h_{t-1} + k_t v_t^T,
    y_t = q_t h_t  with per-step scalar decay ``a_t = exp(log_decay_t)``.

    q, k: [B, T, H, dk]; v: [B, T, H, dv]; log_decay: [B, T, H] (≤ 0).
    Returns y: [B, T, H, dv].  T must be a multiple of ``chunk``.
    """
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk or DEFAULT_CHUNK, t)
    assert t % chunk == 0, (t, chunk)
    n = t // chunk
    # ONE scan over chunks does both the intra-chunk attention and the
    # cross-chunk state carry, so at most one [B, c, c, H] block lives at a
    # time (materializing all N chunks at once cost ~800 GiB/device on
    # hymba train_4k — §Perf iteration "ssd-single-scan").
    qc = jnp.moveaxis(q.reshape(b, n, chunk, h, dk), 1, 0)
    kc = jnp.moveaxis(k.reshape(b, n, chunk, h, dk), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n, chunk, h, dv), 1, 0)
    gc = jnp.moveaxis(log_decay.reshape(b, n, chunk, h), 1, 0
                      ).astype(jnp.float32)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def scan_body(state, xs):
        q_i, k_i, v_i, g_i = xs                   # [B, c, H, ·]
        gcum = jnp.cumsum(g_i, axis=1)            # [B, c, H]
        gtot = gcum[:, -1]                        # [B, H]
        # intra-chunk decay attention
        rel = gcum[:, :, None, :] - gcum[:, None, :, :]       # [B,c,c,H]
        decay = jnp.where(causal[None, :, :, None], jnp.exp(rel), 0.0)
        scores = jnp.einsum("bchd,bshd->bcsh", q_i, k_i).astype(jnp.float32)
        y_i = jnp.einsum("bcsh,bshv->bchv", scores * decay,
                         v_i.astype(jnp.float32))
        # inter-chunk: contribution of the carried state
        y_i = y_i + jnp.einsum("bchd,bch,bhdv->bchv",
                               q_i.astype(jnp.float32), jnp.exp(gcum), state)
        # state update: decay old state, absorb this chunk
        carry_w = jnp.exp(gtot[:, None, :] - gcum)            # [B,c,H]
        add = jnp.einsum("bshd,bsh,bshv->bhdv", k_i.astype(jnp.float32),
                         carry_w, v_i.astype(jnp.float32))
        state = jnp.exp(gtot)[:, :, None, None] * state + add
        return state, y_i.astype(v.dtype)

    state0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    _, ys = jax.lax.scan(scan_body, state0, (qc, kc, vc, gc))
    y = jnp.moveaxis(ys, 0, 1)                    # [B, N, c, H, dv]
    return y.reshape(b, t, h, dv)


def ssd_ref(q, k, v, log_decay):
    """O(T²) reference for tests: direct masked decay attention."""
    b, t, h, dk = q.shape
    g = jnp.cumsum(log_decay.astype(jnp.float32), axis=1)      # [B,T,H]
    rel = g[:, :, None, :] - g[:, None, :, :]                  # [B,T,S,H]
    causal = jnp.tril(jnp.ones((t, t), bool))[None, :, :, None]
    decay = jnp.where(causal, jnp.exp(rel), 0.0)
    scores = jnp.einsum("bthd,bshd->btsh", q, k).astype(jnp.float32)
    return jnp.einsum("btsh,bshv->bthv", scores * decay,
                      v.astype(jnp.float32)).astype(v.dtype)


def ssd_decode_step(state: jax.Array, q, k, v, log_decay):
    """One decode step.  state: [B, H, dk, dv]; q/k: [B, H, dk];
    v: [B, H, dv]; log_decay: [B, H].  Returns (y [B, H, dv], new state)."""
    a = jnp.exp(log_decay.astype(jnp.float32))[:, :, None, None]
    state = a * state + jnp.einsum("bhd,bhv->bhdv", k.astype(jnp.float32),
                                   v.astype(jnp.float32))
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), state)
    return y.astype(v.dtype), state


# ---------------------------------------------------------------------------
# Mamba-style head block (used by Hymba's parallel SSM heads)
# ---------------------------------------------------------------------------

def mamba_params(keys, d_model: int, num_heads: int, head_dim: int,
                 d_state: int) -> Params:
    d_inner = num_heads * head_dim
    return {
        "in_proj": dense_init(keys(), (d_model, 2 * d_inner)),
        "bc_proj": dense_init(keys(), (d_model, 2 * num_heads * d_state)),
        "dt_proj": dense_init(keys(), (d_model, num_heads)),
        "dt_bias": jnp.zeros((num_heads,), jnp.float32),
        "a_log": jnp.zeros((num_heads,), jnp.float32),
        "d_skip": jnp.ones((num_heads, head_dim), jnp.float32) * 0.0,
        "out_proj": dense_init(keys(), (d_inner, d_model)),
    }


def _mamba_gates(p, x):
    b, t, _ = x.shape
    dt = jax.nn.softplus(x @ cast(p["dt_proj"])
                         + cast(p["dt_bias"]))             # [B,T,H]
    a = -jax.nn.softplus(p["a_log"]).astype(jnp.float32)   # [H] (negative)
    log_decay = dt.astype(jnp.float32) * a                 # [B,T,H]
    return dt, log_decay


def mamba_mixer(p: Params, x: jax.Array, num_heads: int, head_dim: int,
                d_state: int, chunk: int | None = None) -> jax.Array:
    """Full-sequence Mamba-2/SSD head mixer.  x: [B, T, d]."""
    b, t, _ = x.shape
    xz = x @ cast(p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = xs.reshape(b, t, num_heads, head_dim)
    bc = x @ cast(p["bc_proj"])
    bb, cc = jnp.split(bc, 2, axis=-1)
    bb = bb.reshape(b, t, num_heads, d_state)
    cc = cc.reshape(b, t, num_heads, d_state)
    dt, log_decay = _mamba_gates(p, x)
    # input scaled by dt (ZOH discretization, scalar-per-head form)
    v = xs * dt[..., None].astype(xs.dtype)
    y = ssd_chunked(cc, bb, v, log_decay, chunk=chunk)
    y = y + xs * cast(p["d_skip"])[None, None]
    y = y * jax.nn.silu(z.reshape(b, t, num_heads, head_dim))
    return y.reshape(b, t, num_heads * head_dim) @ cast(p["out_proj"])


def mamba_init_state(batch: int, num_heads: int, head_dim: int,
                     d_state: int) -> jax.Array:
    return jnp.zeros((batch, num_heads, d_state, head_dim), jnp.float32)


def mamba_decode(p: Params, state: jax.Array, x: jax.Array,
                 num_heads: int, head_dim: int, d_state: int
                 ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, 1, d] -> (y [B, 1, d], new state)."""
    b = x.shape[0]
    xz = x[:, 0] @ cast(p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = xs.reshape(b, num_heads, head_dim)
    bc = x[:, 0] @ cast(p["bc_proj"])
    bb, cc = jnp.split(bc, 2, axis=-1)
    bb = bb.reshape(b, num_heads, d_state)
    cc = cc.reshape(b, num_heads, d_state)
    dt, log_decay = _mamba_gates(p, x)       # dt: [B, 1, H]
    v = xs * dt[:, 0][..., None].astype(xs.dtype)
    y, state = ssd_decode_step(state, cc, bb, v, log_decay[:, 0])
    y = y + xs * cast(p["d_skip"])[None]
    y = y * jax.nn.silu(z.reshape(b, num_heads, head_dim))
    return (y.reshape(b, 1, num_heads * head_dim) @ cast(p["out_proj"]),
            state)


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory block)
# ---------------------------------------------------------------------------

def slstm_params(keys, d_model: int) -> Params:
    return {
        "wi": dense_init(keys(), (d_model, 4 * d_model)),
        "wr": dense_init(keys(), (d_model, 4 * d_model)),
        "b": jnp.zeros((4 * d_model,), jnp.float32),
    }


def slstm_scan(p: Params, x: jax.Array) -> jax.Array:
    """Sequential sLSTM over [B, T, d] (sigmoid-stabilized gates)."""
    b, t, d = x.shape
    pre = (x @ cast(p["wi"]) + cast(p["b"])).astype(jnp.float32)

    def step(carry, pre_t):
        h, c = carry
        gates = pre_t + (h.astype(x.dtype) @ cast(p["wr"])).astype(jnp.float32)
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
        c = f * c + i * jnp.tanh(g)
        h = o * jnp.tanh(c)
        return (h, c), h

    h0 = jnp.zeros((b, d), jnp.float32)
    (_, _), hs = jax.lax.scan(step, (h0, h0), jnp.moveaxis(pre, 1, 0))
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype)


def slstm_init_state(batch: int, d_model: int) -> Tuple[jax.Array, jax.Array]:
    z = jnp.zeros((batch, d_model), jnp.float32)
    return (z, z)


def slstm_decode(p: Params, state, x: jax.Array):
    """x: [B, 1, d] -> (y [B, 1, d], new state)."""
    h, c = state
    pre = (x[:, 0] @ cast(p["wi"]) + cast(p["b"])).astype(jnp.float32)
    gates = pre + (h.astype(x.dtype) @ cast(p["wr"])).astype(jnp.float32)
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    c = f * c + i * jnp.tanh(g)
    h = o * jnp.tanh(c)
    return h[:, None].astype(x.dtype), (h, c)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block) — ssd-form
# ---------------------------------------------------------------------------

def mlstm_params(keys, d_model: int, num_heads: int, head_dim: int) -> Params:
    return {
        "wq": dense_init(keys(), (d_model, num_heads * head_dim)),
        "wk": dense_init(keys(), (d_model, num_heads * head_dim)),
        "wv": dense_init(keys(), (d_model, num_heads * head_dim)),
        "wf": dense_init(keys(), (d_model, num_heads)),
        "wi": dense_init(keys(), (d_model, num_heads)),
        "f_bias": jnp.ones((num_heads,), jnp.float32) * 3.0,
        "wo": dense_init(keys(), (num_heads * head_dim, d_model)),
        "out_scale": jnp.ones((num_heads, head_dim), jnp.float32),
    }


def _mlstm_qkv(p, x, num_heads, head_dim):
    b, t, _ = x.shape
    q = (x @ cast(p["wq"])).reshape(b, t, num_heads, head_dim)
    k = (x @ cast(p["wk"])).reshape(b, t, num_heads, head_dim)
    v = (x @ cast(p["wv"])).reshape(b, t, num_heads, head_dim)
    log_f = jax.nn.log_sigmoid(
        (x @ cast(p["wf"])).astype(jnp.float32) + p["f_bias"])      # [B,T,H]
    i_gate = jax.nn.sigmoid((x @ cast(p["wi"])).astype(jnp.float32))
    k = k * (i_gate[..., None] / jnp.sqrt(jnp.float32(head_dim))).astype(k.dtype)
    return q, k, v, log_f


def _mlstm_out(p, y, num_heads, head_dim):
    b, t = y.shape[0], y.shape[1]
    from repro.models.common import rms_norm
    y = rms_norm(y, None) * cast(p["out_scale"])[None, None]
    return y.reshape(b, t, num_heads * head_dim) @ cast(p["wo"])


def mlstm_mixer(p: Params, x: jax.Array, num_heads: int, head_dim: int,
                chunk: int | None = None) -> jax.Array:
    """Full-sequence mLSTM: C_t = f_t C_{t-1} + i_t k_t v_t^T, y_t = q_t C_t."""
    q, k, v, log_f = _mlstm_qkv(p, x, num_heads, head_dim)
    y = ssd_chunked(q, k, v, log_f, chunk=chunk)
    return _mlstm_out(p, y, num_heads, head_dim)


def mlstm_init_state(batch: int, num_heads: int, head_dim: int) -> jax.Array:
    return jnp.zeros((batch, num_heads, head_dim, head_dim), jnp.float32)


def mlstm_decode(p: Params, state: jax.Array, x: jax.Array,
                 num_heads: int, head_dim: int):
    q, k, v, log_f = _mlstm_qkv(p, x, num_heads, head_dim)
    y, state = ssd_decode_step(state, q[:, 0], k[:, 0], v[:, 0], log_f[:, 0])
    return _mlstm_out(p, y[:, None], num_heads, head_dim), state
