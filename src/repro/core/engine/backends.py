"""The two bit-identical execution backends (DESIGN.md §2–§3, §8).

One iteration = ``B = S·M`` rounds.  Every round each worker samples its
resident block (slot 0 of its queue), hands exactly that block to ring
neighbour ``m - 1`` (``ppermute`` — parked slots never travel), and
enqueues the received block at the tail of its queue, where it surfaces
``S`` rounds later.  At ``S = 1`` the queue degenerates to the paper's
original rotation: the received block is resident immediately.

Hybrid data×model parallelism (``data_parallel = D``, DESIGN.md §8): all
per-worker arrays carry one leading axis of length ``R = D·M`` (row
``g = d·M + m``).  The ``D`` replicas run the same model-axis rotation
over replicated copies of the ``S·M`` blocks; at every round boundary the
just-sampled resident copies are reconciled by a delta psum along the
data axis — ``block' = block_pre + Σ_d (block_d − block_pre)`` — before
they rotate, so parked copies never diverge across replicas.  This is the
AD-LDA all-reduce of ``core/data_parallel.py`` folded into the engine,
confined to the one resident ``[Vb, K]`` slice per round; at ``D = 1``
the reconciliation vanishes and both backends execute exactly the frozen
1D reference (``engine/reference.py`` — enforced bitwise by
``tests/test_engine_2d.py``).

* ``vmap`` backend — the worker grid is a batch axis on one device;
  ``ppermute`` becomes a per-replica ``jnp.roll``, ``psum`` a sum.  Runs
  anywhere, used by tests/benchmarks on the single-CPU container.
* ``shard_map`` backend — the grid maps onto a ``(data, model)`` mesh;
  collectives are real.  This is the production path; the round rotation
  lowers to HLO ``collective-permute`` on the model axis and the replica
  reconciliation to an ``all-reduce`` on the data axis.

Both backends share :func:`repro.core.engine.rounds.worker_round`, so
agreement tests are meaningful, and the non-separable topic totals
``{C_k}`` are synchronized once per round via ``psum`` of per-worker
deltas over the WHOLE grid and drift in between (§3.3).

Sampler staleness composes per block (DESIGN.md §9): the ``batched`` /
``pallas`` / ``mh`` samplers freeze block-local counts at round start,
which is exactly the window between two rotation/reconciliation
collectives — so neither the S-block pipeline nor the data axis widens
it, and the vmap/shard_map backends stay bit-identical for every
registered sampler, MH included.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core import schedule as sched
from repro.core.engine.rounds import resolve_sampler, worker_round
from repro.core.engine.state import MPState


@partial(jax.jit, static_argnames=("sampler_mode", "sync_ck",
                                   "data_parallel"))
def iteration_vmap(state: MPState, u, doc, woff, mask, alpha, beta, vbeta,
                   sampler_mode: str = "scan", sync_ck: bool = True,
                   data_parallel: int = 1):
    """One full iteration = S·M rounds with rotation, stacked on one device.

    ``u`` is ``[B, R, T]`` — one uniform per (round, grid row, token slot),
    with ``R = data_parallel · M``.
    """
    sampler = resolve_sampler(sampler_mode)
    round_fn = partial(worker_round, sampler=sampler)
    d_ = data_parallel

    def round_step(carry, u_r):
        cdk, ckt, blk, ck_syn, ck_loc, z = carry
        res_pre = ckt[:, 0]                  # [R, Vb, K] round-start copies
        res_blk = blk[:, 0]
        cdk, res_ckt, ck_loc, z = jax.vmap(
            round_fn, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0,
                               None, None, None))(
            cdk, res_pre, res_blk, ck_loc, z, u_r, doc, woff, mask,
            alpha, beta, vbeta)
        if d_ > 1:
            # delta-psum reconciliation along data (DESIGN.md §8): replica
            # copies of block b were identical at round start (res_pre),
            # diverged during sampling; commit pre + Σ_d (post_d − pre).
            r_, vb, k = res_ckt.shape
            m_ = r_ // d_
            delta = (res_ckt - res_pre).reshape(d_, m_, vb, k).sum(axis=0)
            rec = res_pre.reshape(d_, m_, vb, k)[0] + delta
            res_ckt = jnp.broadcast_to(rec[None], (d_, m_, vb, k)) \
                .reshape(r_, vb, k)
            # rotation m -> m-1 within every replica
            res_ckt = jnp.roll(res_ckt.reshape(d_, m_, vb, k), -1,
                               axis=1).reshape(r_, vb, k)
            res_blk = jnp.roll(res_blk.reshape(d_, m_), -1,
                               axis=1).reshape(r_)
        else:
            # rotation m -> m-1: worker m-1 receives worker m's resident
            # block and parks it at the tail of its queue (immediately
            # resident when S == 1).  Parked slots shift one toward the
            # head.
            res_ckt = jnp.roll(res_ckt, -1, axis=0)
            res_blk = jnp.roll(res_blk, -1, axis=0)
        ckt = jnp.concatenate([ckt[:, 1:], res_ckt[:, None]], axis=1)
        blk = jnp.concatenate([blk[:, 1:], res_blk[:, None]], axis=1)
        # paper Fig-3 error: pre-sync ℓ1 drift of local {C_k} vs true totals
        ck_true = ck_syn + (ck_loc - ck_syn[None, :]).sum(axis=0)
        n_tok = jnp.maximum(ck_true.sum(), 1).astype(jnp.float32)
        err = (jnp.abs(ck_loc - ck_true[None, :]).sum().astype(jnp.float32)
               / (ck_loc.shape[0] * n_tok))
        if sync_ck:
            ck_loc = jnp.broadcast_to(ck_true, ck_loc.shape)
            ck_syn = ck_true
        return (cdk, ckt, blk, ck_syn, ck_loc, z), err

    carry = (state.cdk, state.ckt, state.block_id, state.ck_synced,
             state.ck_local, state.z)
    carry, errs = jax.lax.scan(round_step, carry, u)
    return MPState(*carry), errs


def make_shard_map_iteration(mesh: Mesh, axis: str, sampler_mode: str,
                             sync_ck: bool, data_axis: str | None = None):
    """Build the jitted per-device iteration function for ``mesh``.

    ``axis`` is the model axis carrying the block ring.  When ``data_axis``
    is given the mesh is 2D ``(data, model)``: per-worker arrays shard
    their leading ``R = D·M`` axis over BOTH axes (data-major, matching
    ``state.build_layout``'s row order), resident blocks are reconciled by
    a per-round delta ``psum`` along ``data``, and ``{C_k}`` syncs over
    the whole grid.  ``data_axis=None`` is the original 1D worker ring.
    """
    perm = sched.rotation_permutation(mesh.shape[axis])
    sampler = resolve_sampler(sampler_mode)
    ck_axes = (data_axis, axis) if data_axis is not None else axis

    def per_device(cdk, ckt, blk, ck_syn, ck_loc, z, u, doc, woff, mask,
                   alpha, beta, vbeta):
        # local shards arrive with a leading grid axis of size 1
        cdk, ckt, blk, ck_loc, z = (x[0] for x in (cdk, ckt, blk, ck_loc, z))
        doc, woff, mask, u = (x[0] for x in (doc, woff, mask, u))

        def round_step(carry, u_r):
            cdk, ckt, blk, ck_syn, ck_loc, z = carry
            res_pre = ckt[0]
            res_blk = blk[0]
            cdk, res_ckt, ck_loc, z = worker_round(
                cdk, res_pre, res_blk, ck_loc, z, u_r, doc, woff, mask,
                alpha, beta, vbeta, sampler=sampler)
            if data_axis is not None:
                # delta-psum reconciliation of the D replica copies of the
                # resident block (DESIGN.md §8) — the only cross-replica
                # traffic, one [Vb, K] all-reduce per round.
                res_ckt = res_pre + jax.lax.psum(res_ckt - res_pre,
                                                 data_axis)
            # Algorithm 2 commit+request: ONLY the resident block travels —
            # per-round traffic stays one [Vb, K] block per worker no
            # matter how large S makes the total model.
            res_ckt = jax.lax.ppermute(res_ckt, axis, perm)
            res_blk = jax.lax.ppermute(res_blk, axis, perm)
            ckt = jnp.concatenate([ckt[1:], res_ckt[None]], axis=0)
            blk = jnp.concatenate([blk[1:], res_blk[None]], axis=0)
            ck_true = ck_syn + jax.lax.psum(ck_loc - ck_syn, ck_axes)
            n_tok = jnp.maximum(ck_true.sum(), 1).astype(jnp.float32)
            err = jax.lax.pmean(
                jnp.abs(ck_loc - ck_true).sum().astype(jnp.float32),
                ck_axes) / n_tok
            if sync_ck:
                ck_loc = ck_true
                ck_syn = ck_true
            return (cdk, ckt, blk, ck_syn, ck_loc, z), err

        carry, errs = jax.lax.scan(
            round_step, (cdk, ckt, blk, ck_syn, ck_loc, z), u)
        cdk, ckt, blk, ck_syn, ck_loc, z = carry
        return (cdk[None], ckt[None], blk[None], ck_syn, ck_loc[None],
                z[None], errs)

    w = P(ck_axes) if data_axis is not None else P(axis)
    return jax.jit(compat.shard_map(
        per_device, mesh=mesh,
        in_specs=(w, w, w, P(), w, w, w, w, w, w, P(), P(), P()),
        out_specs=(w, w, w, P(), w, w, P()),
        check_vma=False))
