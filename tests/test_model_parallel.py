"""Model-parallel engine semantics — the paper's central claims as tests.

The key test replays the MP schedule *serially* with the exact same
per-(round, worker) uniforms and frozen-``C_k``-per-round semantics, and
asserts bit-identical results: "parallelizing over the disjoint blocks
produces exactly the same result as the serial execution" (paper §1).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.counts import build_counts, check_invariants
from repro.core.invindex import scatter_assignments
from repro.core.metrics import topic_recovery_score
from repro.core.model_parallel import ModelParallelLDA
from repro.core.sampler import gibbs_sweep_np, sweep_block_scan
from repro.core import schedule as sched


def _serial_replay(lda: ModelParallelLDA, u: np.ndarray):
    """Execute one MP iteration serially, worker-by-worker per round,
    using the same jitted block sampler and the same uniforms, with the
    engine's frozen-``C_k``-within-round semantics.  Follows the
    ``S·M``-round pipeline schedule, so it is the oracle for any
    ``blocks_per_worker``."""
    m, s_ = lda.num_workers, lda.blocks_per_worker
    cdk = np.array(lda.state.cdk)
    ckt = np.array(lda.state.ckt)             # [M, S, Vb, K] slot queues
    bid = np.array(lda.state.block_id)        # [M, S]
    blocks = {int(bid[w, s]): ckt[w, s].copy()
              for w in range(m) for s in range(s_)}
    ck_synced = np.array(lda.state.ck_synced)
    z = np.array(lda.state.z)
    doc, woff, mask = (np.array(lda.doc), np.array(lda.woff),
                       np.array(lda.mask))
    for r in range(lda.num_rounds):
        deltas = np.zeros_like(ck_synced)
        for w in range(m):
            b = sched.block_for(w, r, m, s_)
            ck_local = ck_synced.copy()
            out = sweep_block_scan(
                jnp.asarray(cdk[w]), jnp.asarray(blocks[b]),
                jnp.asarray(ck_local),
                jnp.asarray(doc[w, b]), jnp.asarray(woff[w, b]),
                jnp.asarray(z[w, b]), jnp.asarray(mask[w, b]),
                jnp.asarray(u[r, w]), lda.alpha,
                jnp.float32(lda.beta), jnp.float32(lda.vbeta))
            cdk[w] = np.asarray(out[0])
            blocks[b] = np.asarray(out[1])
            deltas += np.asarray(out[2]) - ck_local
            z[w, b] = np.asarray(out[3])
        ck_synced = ck_synced + deltas
    # after S·M rounds every block is back at its home slot (s·M + w)
    ckt_out = np.stack([np.stack([blocks[s * m + w] for s in range(s_)])
                        for w in range(m)])
    return cdk, ckt_out, ck_synced, z


def test_parallel_equals_serial_bitexact(tiny_corpus):
    corpus, _, _ = tiny_corpus
    lda = ModelParallelLDA(corpus, num_topics=8, num_workers=4, seed=11)
    rng_state = lda._rng.bit_generator.state
    u = np.asarray(lda._uniforms())          # consumes the rng
    lda._rng.bit_generator.state = rng_state  # rewind so step() reuses it
    ref_cdk, ref_ckt, ref_ck, ref_z = _serial_replay(lda, u)
    lda.step()
    # blocks rotated home after S·M rounds: slot (w, s) == block s·M + w
    np.testing.assert_array_equal(np.array(lda.state.cdk), ref_cdk)
    np.testing.assert_array_equal(np.array(lda.state.ckt), ref_ckt)
    np.testing.assert_array_equal(np.array(lda.state.ck_synced), ref_ck)
    np.testing.assert_array_equal(np.array(lda.state.z), ref_z)


def test_single_worker_equals_plain_serial_cgs(tiny_corpus):
    """M=1: no partitioning, no drift — engine must equal textbook CGS."""
    corpus, _, _ = tiny_corpus
    lda = ModelParallelLDA(corpus, num_topics=8, num_workers=1, seed=3)
    rng_state = lda._rng.bit_generator.state
    u = np.asarray(lda._uniforms())[0, 0]
    lda._rng.bit_generator.state = rng_state
    idx = lda.indexes[0]
    n = int(idx.mask.sum())
    st0 = lda.gather_counts()
    cdk, ckt, ck = (np.array(st0.cdk), np.array(st0.ckt), np.array(st0.ck))
    vpad = lda.partition.padded_vocab
    ckt_pad = np.zeros((vpad, 8), np.int32)
    ckt_pad[:ckt.shape[0]] = ckt
    z0 = np.array(lda.state.z)[0, 0]
    z_ref = gibbs_sweep_np(cdk, ckt_pad, ck,
                           idx.doc[0, :n], idx.word_off[0, :n], z0[:n],
                           u[:n], np.asarray(lda.alpha), lda.beta,
                           use_eq3=True)
    lda.step()
    z_eng = np.array(lda.state.z)[0, 0, :n]
    assert (z_eng == z_ref).mean() > 0.995   # float-order tolerance only


def test_invariants_after_many_iterations(tiny_corpus):
    corpus, _, _ = tiny_corpus
    lda = ModelParallelLDA(corpus, num_topics=8, num_workers=4, seed=2)
    lda.run(4)
    state = lda.gather_counts()
    check_invariants(state, corpus.num_tokens)
    # z-consistency: counts rebuilt from assignments match engine counts
    z = lda.assignments()
    rebuilt = build_counts(corpus.doc, corpus.word, z, corpus.num_docs,
                           corpus.vocab_size, 8)
    np.testing.assert_array_equal(np.asarray(rebuilt.ckt),
                                  np.asarray(state.ckt))
    np.testing.assert_array_equal(np.asarray(rebuilt.cdk),
                                  np.asarray(state.cdk))


@pytest.mark.parametrize("mode", ["scan", "scan_eq1", "batched", "pallas"])
def test_likelihood_ascends_all_sampler_modes(tiny_corpus, mode):
    corpus, _, _ = tiny_corpus
    lda = ModelParallelLDA(corpus, num_topics=8, num_workers=4, seed=5,
                           sampler_mode=mode)
    ll0 = lda.log_likelihood()
    hist = lda.run(6)
    assert hist[-1]["log_likelihood"] > ll0 + 1000
    check_invariants(lda.gather_counts(), corpus.num_tokens)


def test_pallas_mode_matches_batched_mode(tiny_corpus):
    corpus, _, _ = tiny_corpus
    a = ModelParallelLDA(corpus, 8, 4, seed=1, sampler_mode="batched")
    b = ModelParallelLDA(corpus, 8, 4, seed=1, sampler_mode="pallas")
    for _ in range(2):
        a.step(); b.step()
    np.testing.assert_array_equal(np.asarray(a.gather_counts().ckt),
                                  np.asarray(b.gather_counts().ckt))


def test_delta_error_small_and_shrinking(small_corpus):
    """Fig 3: Δ_{r,i} is tiny (≪ the [0,2] range) and does not grow."""
    corpus, _, _ = small_corpus
    lda = ModelParallelLDA(corpus, num_topics=10, num_workers=4, seed=9)
    lda.step()
    first = lda.delta_error()
    for _ in range(4):
        lda.step()
    last = lda.delta_error()
    assert first < 0.1
    assert last <= first * 1.5
    assert last < 0.05


def test_worker_count_does_not_change_distribution(small_corpus):
    """Likelihood after T iterations is statistically the same for any M —
    model-parallelism changes the schedule, not the inference."""
    corpus, _, _ = small_corpus
    lls = []
    for m in (1, 2, 4):
        lda = ModelParallelLDA(corpus, num_topics=10, num_workers=m, seed=13)
        lda.run(12)
        lls.append(lda.log_likelihood())
    spread = max(lls) - min(lls)
    assert spread < 0.03 * abs(np.mean(lls)), (lls, spread)


def test_topic_recovery_on_planted_corpus(small_corpus):
    corpus, phi, _ = small_corpus
    lda = ModelParallelLDA(corpus, num_topics=10, num_workers=4, seed=17)
    lda.run(15)
    score = topic_recovery_score(np.asarray(lda.gather_counts().ckt), phi)
    assert score > 0.5, score


def test_assignments_roundtrip(tiny_corpus):
    corpus, _, _ = tiny_corpus
    lda = ModelParallelLDA(corpus, num_topics=8, num_workers=3, seed=21)
    lda.step()
    z = lda.assignments()
    assert z.shape == (corpus.num_tokens,)
    assert (z >= 0).all() and (z < 8).all()
