"""Evaluation metrics from the paper's §5.

``delta_error`` is the paper's Δ_{r,i}: the normalized ℓ1 distance between
each worker's (drifted) local copy of the topic totals ``{C_k}`` and the
true totals, averaged over workers.  Values lie in [0, 2]; 0 means no
parallelization error (their Fig 3 shows ≈0 throughout).
"""
from __future__ import annotations

import numpy as np


def delta_error(true_ck: np.ndarray, local_cks: np.ndarray) -> float:
    """Δ = (1/(M·N)) Σ_m ‖T − T̃_m‖₁ with N = Σ_k C_k (paper §5.1)."""
    true_ck = np.asarray(true_ck, np.int64)
    local_cks = np.asarray(local_cks, np.int64)
    n_tokens = int(true_ck.sum())
    m = local_cks.shape[0]
    err = np.abs(local_cks - true_ck[None, :]).sum()
    return float(err) / (m * n_tokens)


def topic_sparsity(cdk: np.ndarray) -> float:
    """Average fraction of nonzero entries per document row (K_d / K)."""
    cdk = np.asarray(cdk)
    return float((cdk > 0).mean())


def top_words(ckt: np.ndarray, topic: int, n: int = 10) -> np.ndarray:
    """Indices of the ``n`` highest-count words for one topic."""
    return np.argsort(-np.asarray(ckt)[:, topic])[:n]


def topic_recovery_score(ckt: np.ndarray, true_phi: np.ndarray) -> float:
    """Greedy cosine matching of learned topics to ground-truth topics.

    Used with the synthetic corpus generator to check the sampler actually
    recovers planted structure (a stronger check than likelihood alone).
    """
    ckt = np.asarray(ckt, np.float64)
    est = ckt / np.maximum(ckt.sum(axis=0, keepdims=True), 1)      # [V, K]
    tru = np.asarray(true_phi, np.float64).T                        # [V, K*]
    est_n = est / np.maximum(np.linalg.norm(est, axis=0, keepdims=True), 1e-12)
    tru_n = tru / np.maximum(np.linalg.norm(tru, axis=0, keepdims=True), 1e-12)
    sim = est_n.T @ tru_n                                           # [K, K*]
    score, used = 0.0, set()
    for k_true in np.argsort(-sim.max(axis=0)):
        order = np.argsort(-sim[:, k_true])
        for k_est in order:
            if k_est not in used:
                used.add(int(k_est))
                score += float(sim[k_est, k_true])
                break
    return score / sim.shape[1]
