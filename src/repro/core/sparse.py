"""Sparse-LDA bucket sampler (Yao et al. 2009; paper eq. 2).

This is the sampler inside Yahoo!LDA, the paper's baseline.  It splits the
conditional into three buckets

  A_k = α_k β / (C_k + Vβ)                    (dense, precomputed once)
  B_k = β C_d^k / (C_k + Vβ)                  (document-sparse, cached per doc)
  C_k = (α_k + C_d^k) C_k^t / (C_k + Vβ)      (word-sparse)

and samples bucket-first, exploiting that mass concentrates in B and C.  We
implement it host-side, document-major (its natural order), for three
purposes: (i) a second independent oracle for correctness tests (it must
define the same distribution as eq. 1/eq. 3); (ii) the per-token sampler of
the data-parallel baseline's host path; (iii) to document why it is the
WRONG decomposition for inverted-index order (the per-document B cache
thrashes), motivating the paper's eq. 3 — see ``cache_recompute_count``.
"""
from __future__ import annotations

import numpy as np


def bucket_masses(ckt_row, cdk_row, ck, alpha, beta, vbeta):
    """Return (A_k, B_k, C_k) bucket vectors; their sum is eq. (1)."""
    denom = ck + vbeta
    a = alpha * beta / denom
    b = beta * cdk_row / denom
    c = (alpha + cdk_row) * ckt_row / denom
    return a, b, c


def sparse_gibbs_sweep_np(cdk, ckt, ck, doc, word, z, u, alpha, beta,
                          order=None):
    """Exact serial sweep using the A/B/C bucket draw.

    Consumes one uniform per token, like ``gibbs_sweep_np``; the bucket walk
    uses the same uniform rescaled, so the draw is still exact inverse-CDF
    over A+B+C mass (bucket-major ordering of the CDF).
    """
    doc = np.asarray(doc); word = np.asarray(word)
    z = np.array(z, np.int32, copy=True)
    alpha = np.asarray(alpha, np.float64)
    vbeta = np.float64(beta * ckt.shape[0])
    beta = np.float64(beta)
    if order is None:
        order = range(doc.shape[0])
    for i in order:
        d, t, k_old = doc[i], word[i], z[i]
        cdk[d, k_old] -= 1; ckt[t, k_old] -= 1; ck[k_old] -= 1
        a, b, c = bucket_masses(ckt[t].astype(np.float64),
                                cdk[d].astype(np.float64),
                                ck.astype(np.float64), alpha, beta, vbeta)
        sa, sb, sc = a.sum(), b.sum(), c.sum()
        x = u[i] * (sa + sb + sc)
        # The sparse-bucket draws clamp like the dense one below: the
        # bucket test compares x against a PAIRWISE sum (sc = c.sum())
        # while the inverse-CDF walks the SEQUENTIAL cumsum over nz, so
        # roundoff (u -> 1.0, or the x - sc cancellation in B) can leave
        # x at or past cs[-1] and searchsorted one past the end of nz.
        if x < sc:                      # word-sparse bucket first (most mass)
            nz = np.nonzero(ckt[t])[0]
            cs = np.cumsum(c[nz])
            k_new = int(nz[min(np.searchsorted(cs, x, side="right"),
                               len(nz) - 1)])
        elif x < sc + sb:               # document-sparse bucket
            nz = np.nonzero(cdk[d])[0]
            cs = np.cumsum(b[nz])
            k_new = int(nz[min(np.searchsorted(cs, x - sc, side="right"),
                               len(nz) - 1)])
        else:                           # dense smoothing bucket
            cs = np.cumsum(a)
            k_new = int(min(np.searchsorted(cs, x - sc - sb, side="right"),
                            len(a) - 1))
        z[i] = k_new
        cdk[d, k_new] += 1; ckt[t, k_new] += 1; ck[k_new] += 1
    return z


def cache_recompute_count(doc, word, order_doc_major: bool) -> int:
    """How many times the Sparse-LDA per-document ``Σ_k B_k`` cache must be
    rebuilt under a visit order (paper §4.2's motivating observation).

    Document-major order rebuilds once per document; word-major (inverted
    index) order rebuilds on nearly every token, which is why the paper
    replaces eq. (2) with the word-major eq. (3).
    """
    doc = np.asarray(doc); word = np.asarray(word)
    if order_doc_major:
        idx = np.lexsort((word, doc))
    else:
        idx = np.lexsort((doc, word))
    d_seq = doc[idx]
    return int(1 + (d_seq[1:] != d_seq[:-1]).sum())
