"""Alias-table construction and MH acceptance correctness.

Deterministic unit tests run everywhere; the hypothesis property tests
(Vose reconstruction over random sparse/dense/degenerate inputs) skip
when hypothesis is absent, mirroring ``test_properties.py``.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.alias import (SCALE, alias_cell_masses, alias_draw_int_np,
                              alias_draw_np, alias_table_masses,
                              build_alias_int, build_alias_int_np,
                              build_alias_np, build_alias_tables,
                              int_masses_np, pack_tables, pack_tables_np,
                              split_cell_uniform, unpack_tables,
                              unpack_tables_np)
from repro.core.mh import (accept_ratio, build_doc_tables,
                           build_word_tables, sweep_block_mh,
                           sweep_block_mh_tables, uniform_streams,
                           uniform_streams_np)


# ---------------------------------------------------------------------------
# Classic float Vose construction — deterministic degenerate cases
# ---------------------------------------------------------------------------

DEGENERATE = [
    np.array([0.0, 0.0, 3.0, 0.0], np.float32),      # single nonzero
    np.ones(5, np.float32),                           # uniform
    np.zeros(4, np.float32),                          # zero mass
    np.array([1.0], np.float32),                      # K = 1
    np.array([1e-6, 1.0, 1e-6], np.float32),          # extreme skew
]


@pytest.mark.parametrize("p", DEGENERATE, ids=range(len(DEGENERATE)))
def test_vose_np_reconstructs_degenerate_inputs(p):
    prob, alias = build_alias_np(p.copy())
    assert prob.shape == p.shape and alias.shape == p.shape
    assert ((alias >= 0) & (alias < p.shape[0])).all()
    assert ((prob >= 0) & (prob <= 1 + 1e-6)).all()
    if p.sum() > 0:
        mass = alias_cell_masses(prob, alias, float(p.sum()))
        np.testing.assert_allclose(mass, p, rtol=3e-5,
                                   atol=3e-6 * max(p.sum(), 1))


def test_vose_np_draws_follow_distribution():
    p = np.array([1, 5, 0, 2, 8], np.float32)
    prob, alias = build_alias_np(p)
    rng = np.random.default_rng(0)
    u = rng.random(200_000).astype(np.float32)
    freq = np.bincount(alias_draw_np(prob, alias, u), minlength=5) / len(u)
    target = p / p.sum()
    assert np.abs(freq - target).max() < 0.01
    assert freq[2] == 0.0        # zero-mass topic is never drawn


# ---------------------------------------------------------------------------
# Integer-grid device construction (the production MH path)
# ---------------------------------------------------------------------------

INT_CASES = [
    (np.array([0, 0, 37, 0], np.int32), np.full(4, 0.01, np.float32)),
    (np.zeros(6, np.int32), np.full(6, 0.1, np.float32)),      # prior only
    (np.array([5], np.int32), np.array([0.3], np.float32)),    # K = 1
    (np.array([1000, 0, 1, 0, 999], np.int32),
     np.full(5, 0.01, np.float32)),                            # skew
    (np.arange(16, dtype=np.int32),
     np.linspace(0.01, 0.4, 16).astype(np.float32)),           # asym prior
]


@pytest.mark.parametrize("counts,prior", INT_CASES, ids=range(len(INT_CASES)))
def test_int_builder_jax_bit_equals_numpy_mirror(counts, prior):
    """The device builder and its numpy mirror share op order and stack
    discipline — tables must agree BIT FOR BIT (the draw-for-draw replay
    of the MH backend rests on exactly this determinism)."""
    w = int_masses_np(counts, prior)
    cut_np, alias_np, u_np = build_alias_int_np(w)
    cut_j, alias_j, u_j = (np.asarray(x)
                           for x in build_alias_int(jnp.asarray(w)))
    np.testing.assert_array_equal(cut_j, cut_np)
    np.testing.assert_array_equal(alias_j, alias_np)
    assert float(u_j) == float(u_np)


@pytest.mark.parametrize("counts,prior", INT_CASES, ids=range(len(INT_CASES)))
def test_int_builder_reconstructs_masses(counts, prior):
    """Sum of cell masses equals the quantized input masses (·K units)."""
    w = int_masses_np(counts, prior)
    cut, alias, u_cap = build_alias_int_np(w)
    k = w.shape[0]
    assert ((alias >= 0) & (alias < k)).all()
    assert (cut >= 0).all() and (cut <= u_cap).all()
    mass = alias_table_masses(cut, alias, u_cap)
    expect = w.astype(np.float64) * k
    np.testing.assert_allclose(mass, expect, rtol=1e-6,
                               atol=1e-6 * max(expect.sum(), 1))


def test_int_builder_draws_follow_quantized_distribution():
    counts = np.array([3, 0, 11, 1, 25], np.int32)
    prior = np.full(5, 0.01, np.float32)
    w = int_masses_np(counts, prior)
    cut, alias, u_cap = build_alias_int_np(w)
    rng = np.random.default_rng(1)
    u = rng.random(200_000).astype(np.float32)
    d = alias_draw_int_np(cut, alias, float(u_cap), u)
    freq = np.bincount(d, minlength=5) / len(u)
    target = w / w.sum()
    assert np.abs(freq - target).max() < 0.01


def test_build_alias_tables_matches_per_row():
    rng = np.random.default_rng(2)
    counts = rng.integers(0, 40, (6, 17)).astype(np.int32)
    prior = (rng.random(17).astype(np.float32) + 0.01)
    cut, alias, u_cap, w = build_alias_tables(jnp.asarray(counts),
                                              jnp.asarray(prior))
    w_np = int_masses_np(counts, prior)
    np.testing.assert_array_equal(np.asarray(w), w_np)
    for i in range(counts.shape[0]):
        c_i, a_i, u_i = build_alias_int_np(w_np[i])
        np.testing.assert_array_equal(np.asarray(cut[i]), c_i)
        np.testing.assert_array_equal(np.asarray(alias[i]), a_i)
        assert float(u_cap[i]) == float(u_i)


def test_prior_quantization_keeps_full_support():
    """Every topic stays proposable even when the prior rounds to zero on
    the integer grid (the max(·, 1) floor — MH ergodicity needs it)."""
    prior = np.full(8, 1e-5, np.float32)        # << 1/SCALE
    w = int_masses_np(np.zeros(8, np.int32), prior)
    assert (w >= 1).all()
    cut, alias, u_cap = build_alias_int_np(w)
    d = alias_draw_int_np(cut, alias, float(u_cap),
                          np.linspace(0, 0.999, 4096).astype(np.float32))
    assert np.bincount(d, minlength=8).min() > 0
    assert SCALE * 0.01 >= 1    # the default β=0.01 grid is non-degenerate


def test_split_cell_uniform_in_range():
    u = jnp.asarray(np.array([0.0, 0.5, 0.999999, 1.0], np.float32))
    j, frac = split_cell_uniform(u, 7)
    assert ((np.asarray(j) >= 0) & (np.asarray(j) < 7)).all()
    assert (np.asarray(frac) >= 0).all()


# ---------------------------------------------------------------------------
# Shared uniform stream expansion (the replayability anchor)
# ---------------------------------------------------------------------------

def test_uniform_streams_numpy_mirror_is_bit_exact():
    rng = np.random.default_rng(2)
    u = rng.random(500).astype(np.float32)
    np.testing.assert_array_equal(
        uniform_streams_np(u, 8),
        np.asarray(uniform_streams(jnp.asarray(u), 8)))


def test_uniform_streams_are_uniform_and_decorrelated():
    rng = np.random.default_rng(3)
    u = rng.random(20_000).astype(np.float32)
    s = uniform_streams_np(u, 4)
    assert ((s >= 0) & (s < 1)).all()
    assert np.abs(s.mean(axis=1) - 0.5).max() < 0.01
    for i in range(4):
        for j in range(i + 1, 4):
            assert abs(np.corrcoef(s[i], s[j])[0, 1]) < 0.02


# ---------------------------------------------------------------------------
# MH acceptance — closed forms
# ---------------------------------------------------------------------------

def test_acceptance_is_one_when_proposal_equals_target():
    """q ∝ π  =>  A = [π(t) q(s)] / [π(s) q(t)] = 1 identically."""
    rng = np.random.default_rng(4)
    pi = rng.random(16).astype(np.float64) + 0.01
    q = 3.7 * pi                       # proportional proposal
    for s in range(16):
        for t in range(16):
            np.testing.assert_allclose(
                accept_ratio(pi[t], pi[s], q[t], q[s]), 1.0, rtol=1e-12)


def test_acceptance_two_topic_closed_form():
    """Hand-computed 2-topic case: the word-proposal acceptance for
    s=0 -> t=1 must equal

        A = [ (Cd1+a1)(Ct1+b)(C0+Vb) qw0 ] / [ (Cd0+a0)(Ct0+b)(C1+Vb) qw1 ]

    with qwk the (frozen, unexcluded) proposal mass and the ¬dn exclusion
    applied at the current topic s=0 in the target only.
    """
    a0, a1, b, vb = 0.1, 0.2, 0.01, 0.5
    cd = np.array([3.0, 1.0])     # doc-topic counts incl. current token @0
    ct = np.array([5.0, 7.0])     # word-topic counts incl. current token @0
    ck = np.array([40.0, 60.0])   # totals incl. current token @0
    # target with exclusion at topic 0 (the token's current assignment)
    pi0 = (cd[0] - 1 + a0) * (ct[0] - 1 + b) / (ck[0] - 1 + vb)
    pi1 = (cd[1] + a1) * (ct[1] + b) / (ck[1] + vb)
    q0, q1 = ct[0] + b, ct[1] + b
    expected = (pi1 * q0) / (pi0 * q1)
    by_hand = (((cd[1] + a1) * (ct[1] + b) * (ck[0] - 1 + vb) * (ct[0] + b))
               / ((cd[0] - 1 + a0) * (ct[0] - 1 + b) * (ck[1] + vb)
                  * (ct[1] + b)))
    np.testing.assert_allclose(accept_ratio(pi1, pi0, q1, q0), expected,
                               rtol=1e-12)
    np.testing.assert_allclose(expected, by_hand, rtol=1e-12)


def test_cross_multiplied_accept_matches_ratio_form():
    """The samplers decide ``u·π_s·q_t < π_t·q_s``; off fp-tie boundaries
    this is the same decision as ``u < accept_ratio``."""
    rng = np.random.default_rng(5)
    for _ in range(500):
        n_s, n_t, d_s, d_t, q_s, q_t = rng.random(6) + 0.05
        u = rng.random()
        ratio = accept_ratio(n_t / d_t, n_s / d_s, q_t, q_s)
        assert (u * n_s * d_t * q_t < n_t * d_s * q_s) == (u < ratio)


# ---------------------------------------------------------------------------
# MH block sweep — invariants and masking
# ---------------------------------------------------------------------------

def _block_state(rng, n=300, d=12, vb=20, k=8):
    doc = rng.integers(0, d, n).astype(np.int32)
    woff = np.sort(rng.integers(0, vb, n)).astype(np.int32)
    z = rng.integers(0, k, n).astype(np.int32)
    cdk = np.zeros((d, k), np.int32)
    ckt = np.zeros((vb, k), np.int32)
    np.add.at(cdk, (doc, z), 1)
    np.add.at(ckt, (woff, z), 1)
    return doc, woff, z, cdk, ckt, ckt.sum(0).astype(np.int32)


def test_mh_sweep_preserves_invariants():
    rng = np.random.default_rng(5)
    doc, woff, z, cdk, ckt, ck = _block_state(rng)
    n = doc.shape[0]
    u = rng.random(n).astype(np.float32)
    out = sweep_block_mh(
        jnp.asarray(cdk), jnp.asarray(ckt), jnp.asarray(ck),
        jnp.asarray(doc), jnp.asarray(woff), jnp.asarray(z),
        jnp.ones(n, bool), jnp.asarray(u), jnp.full(8, 0.1, jnp.float32),
        jnp.float32(0.01), jnp.float32(0.2))
    z_new = np.asarray(out[3])
    cdk2 = np.zeros_like(cdk); ckt2 = np.zeros_like(ckt)
    np.add.at(cdk2, (doc, z_new), 1)
    np.add.at(ckt2, (woff, z_new), 1)
    np.testing.assert_array_equal(np.asarray(out[0]), cdk2)
    np.testing.assert_array_equal(np.asarray(out[1]), ckt2)
    np.testing.assert_array_equal(np.asarray(out[2]), ckt2.sum(0))
    assert (z_new != z).any()          # the chain actually moves


def test_mh_sweep_masked_tokens_are_noops():
    rng = np.random.default_rng(6)
    doc, woff, z, cdk, ckt, ck = _block_state(rng, n=120)
    n = doc.shape[0]
    u = rng.random(n).astype(np.float32)
    out = sweep_block_mh(
        jnp.asarray(cdk), jnp.asarray(ckt), jnp.asarray(ck),
        jnp.asarray(doc), jnp.asarray(woff), jnp.asarray(z),
        jnp.zeros(n, bool), jnp.asarray(u), jnp.full(8, 0.1, jnp.float32),
        jnp.float32(0.01), jnp.float32(0.2))
    np.testing.assert_array_equal(np.asarray(out[0]), cdk)
    np.testing.assert_array_equal(np.asarray(out[1]), ckt)
    np.testing.assert_array_equal(np.asarray(out[3]), z)


def test_packed_table_roundtrip_bit_exact():
    """pack -> unpack is lossless for every plane, U is recomputed
    bit-identically from the W plane, and the numpy mirror agrees."""
    rng = np.random.default_rng(3)
    counts = rng.integers(0, 50, (6, 16)).astype(np.int32)
    prior = np.full((6, 16), 0.07, np.float32)
    cut, alias, u_cap, w = build_alias_tables(jnp.asarray(counts),
                                              jnp.asarray(prior))
    packed = pack_tables(cut, alias, w)
    assert packed.shape == (3, 6, 16) and packed.dtype == jnp.int32
    cut2, alias2, u2, w2 = unpack_tables(packed)
    np.testing.assert_array_equal(np.asarray(cut).view(np.int32),
                                  np.asarray(cut2).view(np.int32))
    np.testing.assert_array_equal(np.asarray(alias), np.asarray(alias2))
    np.testing.assert_array_equal(np.asarray(u_cap).view(np.int32),
                                  np.asarray(u2).view(np.int32))
    np.testing.assert_array_equal(np.asarray(w), np.asarray(w2))
    packed_np = pack_tables_np(np.asarray(cut), np.asarray(alias),
                               np.asarray(w))
    np.testing.assert_array_equal(np.asarray(packed), packed_np)
    for a, b in zip(unpack_tables_np(packed_np), (cut, alias, u_cap, w)):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_tables_sweep_with_fresh_tables_equals_round_sweep():
    """Row independence of the Vose pairing: word/doc tables built
    separately (the per-iteration builders) are bit-identical to the
    slices of the concatenated per-round build, so feeding FRESH packed
    tables to ``sweep_block_mh_tables`` reproduces ``sweep_block_mh``
    exactly — the staleness of the iteration lifetime is purely a matter
    of WHEN the same builder ran."""
    rng = np.random.default_rng(8)
    doc, woff, z, cdk, ckt, ck = _block_state(rng, n=160, k=16)
    n = doc.shape[0]
    u = rng.random(n).astype(np.float32)
    alpha = jnp.full(16, 0.1, jnp.float32)
    args = (jnp.asarray(cdk), jnp.asarray(ckt), jnp.asarray(ck),
            jnp.asarray(doc), jnp.asarray(woff), jnp.asarray(z),
            jnp.ones(n, bool), jnp.asarray(u), alpha,
            jnp.float32(0.01), jnp.float32(0.2))
    wtab = build_word_tables(jnp.asarray(ckt), jnp.float32(0.01))
    dtab = build_doc_tables(jnp.asarray(cdk), alpha)
    out_round = sweep_block_mh(*args)
    out_tables = sweep_block_mh_tables(*args, wtab, dtab)
    for a, b in zip(out_round, out_tables):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mh_pallas_equals_mh():
    """The Pallas word-proposal kernel composes to the same draws as the
    pure-jnp MH sweep, bit for bit, given the same uniforms."""
    from repro.kernels.ops import sweep_block_mh_pallas
    rng = np.random.default_rng(7)
    doc, woff, z, cdk, ckt, ck = _block_state(rng, n=200, k=24)
    n = doc.shape[0]
    mask = rng.random(n) < 0.9
    u = rng.random(n).astype(np.float32)
    args = (jnp.asarray(cdk), jnp.asarray(ckt), jnp.asarray(ck),
            jnp.asarray(doc), jnp.asarray(woff), jnp.asarray(z),
            jnp.asarray(mask), jnp.asarray(u),
            jnp.full(24, 0.1, jnp.float32),
            jnp.float32(0.01), jnp.float32(0.2))
    out_m = sweep_block_mh(*args)
    out_p = sweep_block_mh_pallas(*args)
    for a, b in zip(out_m, out_p):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mh_cycle_kernel_word_grouped_layout():
    """Direct ``mh_cycle_call`` coverage of the word-grouped [G, Tg>1]
    layout the kernel is designed around (multi-token groups sharing one
    word's alias/count rows, a grid of several tiles), referenced
    against the jnp ``_mh_step`` cycle on the flattened tokens — the
    engine only exercises the degenerate Tg=1 form, so the [G, Tg, K]
    doc-row branches and [G, 1] capacity broadcasts are pinned here."""
    from repro.core.mh import _mh_step, block_proposal_tables
    from repro.kernels.mh_alias import mh_cycle_call

    rng = np.random.default_rng(11)
    k, vb, dloc, g, tg, tile_g = 24, 16, 12, 20, 4, 8
    n = g * tg
    gword = rng.integers(0, vb, g).astype(np.int32)    # one word per group
    woff = np.repeat(gword, tg)                        # flat [N]
    doc = rng.integers(0, dloc, n).astype(np.int32)
    z = rng.integers(0, k, n).astype(np.int32)
    cdk = np.zeros((dloc, k), np.int32)
    ckt = np.zeros((vb, k), np.int32)
    np.add.at(cdk, (doc, z), 1)
    np.add.at(ckt, (woff, z), 1)
    ck = ckt.sum(0).astype(np.int32)
    mask = (rng.random(n) < 0.9).astype(np.int32)
    u = rng.random(n).astype(np.float32)
    alpha = jnp.full(k, 0.1, jnp.float32)
    beta, vbeta = 0.01, 0.2

    word_table, doc_table = block_proposal_tables(
        jnp.asarray(cdk), jnp.asarray(ckt), alpha, beta)
    streams = uniform_streams(jnp.asarray(u), 8)        # 2 cycles

    # jnp reference on the flat token axis
    ckt_f = jnp.asarray(ckt, jnp.float32)
    cdk_f = jnp.asarray(cdk, jnp.float32)
    ck_f = jnp.asarray(ck, jnp.float32)
    z_ref = jnp.asarray(z)
    for c in range(2):
        z_ref = _mh_step(z_ref, jnp.asarray(z), jnp.asarray(doc),
                         jnp.asarray(woff), jnp.asarray(mask, bool),
                         streams[4 * c], streams[4 * c + 1],
                         jnp.asarray(woff), word_table,
                         cdk_f, ckt_f, ck_f, alpha, jnp.float32(beta),
                         jnp.float32(vbeta))
        z_ref = _mh_step(z_ref, jnp.asarray(z), jnp.asarray(doc),
                         jnp.asarray(woff), jnp.asarray(mask, bool),
                         streams[4 * c + 2], streams[4 * c + 3],
                         jnp.asarray(doc), doc_table,
                         cdk_f, ckt_f, ck_f, alpha, jnp.float32(beta),
                         jnp.float32(vbeta))

    # kernel operands in the grouped layout, padded to (tile_g, 128)
    wcut, walias, wu, wmass = (np.asarray(t) for t in word_table)
    dcut, dalias, du, dmass = (np.asarray(t) for t in doc_table)
    gp = -g % tile_g
    kp = -k % 128
    pad_g2 = lambda x: np.pad(x, ((0, gp), (0, kp)))
    pad_g3 = lambda x: np.pad(x.reshape(g, tg, -1),
                              ((0, gp), (0, 0), (0, kp)))
    pad_gt = lambda x: np.pad(x.reshape(g, tg), ((0, gp), (0, 0)))
    out = mh_cycle_call(
        jnp.asarray(pad_g2(wcut[gword])), jnp.asarray(pad_g2(walias[gword])),
        jnp.asarray(pad_g2(wmass[gword].astype(np.float32))),
        jnp.asarray(np.pad(wu[gword], (0, gp))[:, None]),
        jnp.asarray(pad_g3(dcut[doc])), jnp.asarray(pad_g3(dalias[doc])),
        jnp.asarray(pad_g3(dmass[doc].astype(np.float32))),
        jnp.asarray(pad_gt(du[doc])),
        jnp.asarray(pad_g2(np.asarray(ckt_f)[gword])),
        jnp.asarray(pad_g3(np.asarray(cdk_f)[doc])),
        jnp.asarray(pad_gt(z)),
        jnp.asarray(np.pad(np.asarray(streams).reshape(8, g, tg),
                           ((0, 0), (0, gp), (0, 0)))),
        jnp.asarray(pad_gt(mask)),
        jnp.asarray(np.pad(np.asarray(ck_f), (0, kp))),
        jnp.asarray(np.pad(np.asarray(alpha), (0, kp))),
        beta, vbeta, k_real=k, num_cycles=2, tile_g=tile_g,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(out)[:g].reshape(-1),
                                  np.asarray(z_ref))


def test_mh_pallas_tables_equals_mh_tables():
    """The fused Pallas cycle consumes external (possibly stale) packed
    tables bit-identically to the jnp table sweep — here with genuinely
    stale tables (built before perturbing the counts)."""
    from repro.kernels.ops import sweep_block_mh_pallas_tables
    rng = np.random.default_rng(9)
    doc, woff, z, cdk, ckt, ck = _block_state(rng, n=200, k=24)
    n = doc.shape[0]
    alpha = jnp.full(24, 0.1, jnp.float32)
    # stale tables: built from a DIFFERENT (earlier) count state
    z_old = rng.integers(0, 24, n).astype(np.int32)
    cdk_old = np.zeros_like(cdk); ckt_old = np.zeros_like(ckt)
    np.add.at(cdk_old, (doc, z_old), 1)
    np.add.at(ckt_old, (woff, z_old), 1)
    wtab = build_word_tables(jnp.asarray(ckt_old), jnp.float32(0.01))
    dtab = build_doc_tables(jnp.asarray(cdk_old), alpha)
    mask = rng.random(n) < 0.9
    u = rng.random(n).astype(np.float32)
    args = (jnp.asarray(cdk), jnp.asarray(ckt), jnp.asarray(ck),
            jnp.asarray(doc), jnp.asarray(woff), jnp.asarray(z),
            jnp.asarray(mask), jnp.asarray(u), alpha,
            jnp.float32(0.01), jnp.float32(0.2), wtab, dtab)
    out_m = sweep_block_mh_tables(*args)
    out_p = sweep_block_mh_pallas_tables(*args)
    for a, b in zip(out_m, out_p):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Hypothesis property tests (skipped when hypothesis is absent)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_property_tests_need_hypothesis():
        """Visible sentinel: the @given tests in this module were not
        collected because hypothesis is absent."""

if HAVE_HYPOTHESIS:
    @st.composite
    def _float_masses(draw):
        k = draw(st.integers(1, 64))
        kind = draw(st.sampled_from(["dense", "sparse", "single",
                                     "uniform"]))
        rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
        if kind == "dense":
            p = rng.random(k).astype(np.float32) * draw(
                st.floats(0.01, 100.0))
        elif kind == "sparse":
            p = rng.random(k).astype(np.float32)
            p[rng.random(k) < 0.8] = 0.0
        elif kind == "single":
            p = np.zeros(k, np.float32)
            p[rng.integers(0, k)] = draw(st.floats(0.001, 50.0))
        else:
            p = np.full(k, draw(st.floats(0.01, 10.0)), np.float32)
        return p

    @given(_float_masses())
    @settings(max_examples=60, deadline=None)
    def test_vose_np_reconstruction_property(p):
        """Cell masses sum back to p (fp tolerance); draws stay in range
        and never land on zero-mass topics."""
        prob, alias = build_alias_np(p.copy())
        assert ((alias >= 0) & (alias < p.shape[0])).all()
        if p.sum() > 0:
            mass = alias_cell_masses(prob, alias, float(p.sum()))
            np.testing.assert_allclose(
                mass, p, rtol=5e-5, atol=5e-6 * max(float(p.sum()), 1.0))
        rng = np.random.default_rng(0)
        d = alias_draw_np(prob, alias, rng.random(256).astype(np.float32))
        assert ((d >= 0) & (d < p.shape[0])).all()
        if p.sum() > 0:
            assert (p[d] > 0).all()

    @st.composite
    def _int_masses_case(draw):
        k = draw(st.integers(1, 64))
        kind = draw(st.sampled_from(["dense", "sparse", "single",
                                     "uniform"]))
        rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
        if kind == "dense":
            counts = rng.integers(0, 1000, k)
        elif kind == "sparse":
            counts = rng.integers(0, 100, k)
            counts[rng.random(k) < 0.8] = 0
        elif kind == "single":
            counts = np.zeros(k, np.int64)
            counts[rng.integers(0, k)] = draw(st.integers(1, 10_000))
        else:
            counts = np.full(k, draw(st.integers(0, 500)))
        prior = (rng.random(k).astype(np.float32)
                 * draw(st.floats(0.001, 2.0)))
        return counts.astype(np.int32), prior

    @given(_int_masses_case())
    @settings(max_examples=60, deadline=None)
    def test_int_builder_property(case):
        """Device builder == numpy mirror bitwise; reconstruction exact up
        to fp tolerance; every draw index in range."""
        counts, prior = case
        w = int_masses_np(counts, prior)
        cut_np, alias_np, u_np = build_alias_int_np(w)
        cut_j, alias_j, u_j = (np.asarray(x)
                               for x in build_alias_int(jnp.asarray(w)))
        np.testing.assert_array_equal(cut_j, cut_np)
        np.testing.assert_array_equal(alias_j, alias_np)
        assert float(u_j) == float(u_np)
        k = w.shape[0]
        assert ((alias_np >= 0) & (alias_np < k)).all()
        mass = alias_table_masses(cut_np, alias_np, u_np)
        expect = w.astype(np.float64) * k
        np.testing.assert_allclose(mass, expect, rtol=1e-6,
                                   atol=1e-6 * max(expect.sum(), 1))
        rng = np.random.default_rng(0)
        d = alias_draw_int_np(cut_np, alias_np, float(u_np),
                              rng.random(256).astype(np.float32))
        assert ((d >= 0) & (d < k)).all()
