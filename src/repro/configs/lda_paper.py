"""The paper's own workload configs (§5 Experiments).

Not an ``ArchConfig`` — LDA is not a transformer — but registered here so
the launcher, benchmarks and dry-run can select the paper's exact problem
sizes by name.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LDAConfig:
    name: str
    vocab_size: int
    num_topics: int
    num_docs: int
    num_tokens: int
    alpha: float = 0.1
    beta: float = 0.01

    @property
    def model_variables(self) -> int:
        return self.vocab_size * self.num_topics


# Table-1 / §5 dataset scales
PUBMED_1K = LDAConfig("pubmed-k1000", 141_043, 1_000, 8_200_000, 737_900_000)
PUBMED_5K = LDAConfig("pubmed-k5000", 141_043, 5_000, 8_200_000, 737_900_000)
WIKI_UNIGRAM_5K = LDAConfig("wiki-unigram-k5000", 2_500_000, 5_000,
                            3_900_000, 179_000_000)
WIKI_UNIGRAM_10K = LDAConfig("wiki-unigram-k10000", 2_500_000, 10_000,
                             3_900_000, 179_000_000)
WIKI_BIGRAM_5K = LDAConfig("wiki-bigram-k5000", 21_800_000, 5_000,
                           3_900_000, 79_000_000)
# the 218-billion-variable flagship run (Table 1, rightmost column)
WIKI_BIGRAM_10K = LDAConfig("wiki-bigram-k10000", 21_800_000, 10_000,
                            3_900_000, 79_000_000)

LDA_CONFIGS = {c.name: c for c in [
    PUBMED_1K, PUBMED_5K, WIKI_UNIGRAM_5K, WIKI_UNIGRAM_10K,
    WIKI_BIGRAM_5K, WIKI_BIGRAM_10K]}
