"""CLI sampler selection: registry-derived choices + the ``auto`` probe.

The launch drivers (`lda_train`, `lda_infer`) used to hard-code their
``--sampler`` choice lists, so every new registry sampler meant touching
every CLI.  Choices now come from the engine registry itself
(`engine/rounds.py`), plus the pseudo-sampler ``auto``:

* ``auto`` picks the sampler FAMILY from the measured regime map
  (``benchmarks/bench_sparse.py`` full mode, the PR-6 K × doc-len
  sweep): nearest cell in (log₂ K, log₂ max-doc-len) space decides
  between ``scan``, ``mh``, and ``sparse`` — the long-tail observation
  that sparse wins 6/9 cells, MH only the short-K/long-doc corner, and
  exact scan the mid-K dense cells.  Callers pass the workload's
  ``num_topics``/``max_doc_len``; without them, ``auto`` falls back to
  the MH family (the old behaviour).
* ``auto`` then resolves the chosen family per platform: the Pallas
  kernel form on TPU, the jnp twin elsewhere.  The pairs draw
  identically, so the platform leg never changes a chain — only which
  compiled form runs it.
* Off TPU, an EXPLICITLY requested ``*_pallas`` sampler runs the kernel
  in interpret mode — correct (the bit-identity tests rely on it) but
  slow at real workload sizes (the repo-root BENCH digest shows
  ``mh_pallas`` collapsing 208→36 q/s at serving batch 32 on CPU), so
  the drivers refuse it unless ``--force`` is given.
"""
from __future__ import annotations

import math

# Measured winners of the K × max-doc-len sweep
# (benchmarks/results/bench_sparse.json, mode="full": Vb=64, 8k tokens,
# Zipf 1.1).  Keys are the swept (K, doc_len) grid points; lookups snap
# to the nearest cell in log2 space, since both axes are scale
# parameters.
REGIME_MAP = {
    (256, 16): "sparse", (256, 48): "sparse", (256, 256): "mh",
    (4096, 16): "scan", (4096, 48): "sparse", (4096, 256): "scan",
    (16384, 16): "sparse", (16384, 48): "sparse", (16384, 256): "sparse",
}

# jnp form -> Pallas kernel form of the same chain (draw-identical
# pairs).  "scan" is the exact kernel and has no frozen-count Pallas
# twin, so it runs as-is everywhere.
_PALLAS_TWIN = {"mh": "mh_pallas", "sparse": "sparse_pallas"}


def regime_sampler(num_topics: int, max_doc_len: int) -> str:
    """Sampler family for a workload: nearest :data:`REGIME_MAP` cell in
    (log₂ K, log₂ max-doc-len) space; grid-exact at the measured points."""
    lk = math.log2(max(int(num_topics), 1))
    ll = math.log2(max(int(max_doc_len), 1))
    cell = min(REGIME_MAP,
               key=lambda c: ((math.log2(c[0]) - lk) ** 2
                              + (math.log2(c[1]) - ll) ** 2, c))
    return REGIME_MAP[cell]


def train_sampler_choices() -> list:
    """``--sampler`` choices for training: every registered engine
    sampler, plus ``auto``."""
    from repro.core.engine.rounds import available_samplers
    return available_samplers() + ["auto"]


def infer_sampler_choices() -> list:
    """``--sampler`` choices for fold-in/serving: ``scan``, the
    table-capable family, the sparse family, plus ``auto`` — i.e. every
    registry sampler `infer.fold_in` can run against a frozen snapshot."""
    from repro.core.engine.rounds import available_samplers, table_capable
    names = ["scan"] + [m for m in available_samplers()
                        if table_capable(m)
                        or m in ("sparse", "sparse_pallas")]
    return names + ["auto"]


def resolve_sampler_choice(name: str, *, force: bool = False,
                           num_topics: int | None = None,
                           max_doc_len: int | None = None,
                           auto_tpu: str = "mh_pallas",
                           auto_default: str = "mh") -> str:
    """Resolve a CLI ``--sampler`` value to a registry sampler name.

    ``auto`` with the workload's ``num_topics``/``max_doc_len`` picks the
    family from the measured :data:`REGIME_MAP` (so the drivers must
    resolve AFTER the corpus exists), then the Pallas form of that family
    on TPU and the jnp form elsewhere (draw-identical either way).
    Without workload parameters it falls back to ``auto_tpu`` /
    ``auto_default`` — the pre-regime-map behaviour.  An explicit
    ``*_pallas`` off TPU exits with guidance unless ``force`` —
    interpret mode is a validation vehicle, not a serving path.
    """
    import jax
    on_tpu = jax.default_backend() == "tpu"
    if name == "auto":
        if num_topics is not None and max_doc_len is not None:
            family = regime_sampler(num_topics, max_doc_len)
            return (_PALLAS_TWIN.get(family, family) if on_tpu
                    else family)
        return auto_tpu if on_tpu else auto_default
    if name.endswith("_pallas") and not on_tpu and not force:
        raise SystemExit(
            f"--sampler {name}: Pallas kernels run in interpret mode on "
            f"{jax.default_backend()!r} — orders of magnitude slower at "
            f"real sizes (see BENCH_e2e.json). Use --sampler auto, the "
            f"jnp twin {name.removesuffix('_pallas')!r}, or pass --force "
            f"to run interpret mode anyway.")
    return name


# ---------------------------------------------------------------------------
# CountStore selection (DESIGN.md §16)
# ---------------------------------------------------------------------------

def store_choices() -> list:
    """``--store`` choices: every registered CountStore kind, plus
    ``auto`` (regime-derived)."""
    from repro.core.engine.countstore import available_stores
    return available_stores() + ["auto"]


def resolve_store_choice(name: str, *,
                         num_topics: int | None = None,
                         max_doc_len: int | None = None) -> str:
    """Resolve a CLI ``--store`` value to a registered CountStore kind.

    ``auto`` reuses the measured :data:`REGIME_MAP`: the tail store pays
    off exactly where the sparse sampler family does — long-tailed
    word-topic rows whose nnz ≪ K — so ``auto`` picks ``tail`` iff the
    regime probe picks the sparse family for this workload, and the
    bitwise-frozen ``dense`` default otherwise (also the fallback when
    the workload parameters are unknown, e.g. before the corpus exists).
    The choice never affects the chain — stores are draw-equivalent by
    construction — only memory/layout, so resolving it per-workload is
    always safe.
    """
    from repro.core.engine.countstore import available_stores
    if name == "auto":
        if num_topics is not None and max_doc_len is not None:
            family = regime_sampler(num_topics, max_doc_len)
            return "tail" if family == "sparse" else "dense"
        return "dense"
    if name not in available_stores():
        raise SystemExit(
            f"--store {name}: unknown store kind; "
            f"choices: {store_choices()}")
    return name
