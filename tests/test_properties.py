"""Extra property-based tests on system invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.counts import build_counts, check_invariants
from repro.core.model_parallel import ModelParallelLDA
from repro.data.corpus import bigram_corpus, from_documents, from_texts
from repro.data.sharding import shard_documents, worker_shard
from repro.data.synthetic import synthetic_corpus
from repro.models.common import apply_rope


# -- data pipeline -----------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(1, 200), st.integers(2, 16))
@settings(max_examples=20, deadline=None)
def test_document_sharding_partitions(seed, num_docs, workers):
    assignment = shard_documents(num_docs, workers)
    all_docs = np.concatenate(assignment)
    assert sorted(all_docs.tolist()) == list(range(num_docs))
    sizes = [a.shape[0] for a in assignment]
    assert max(sizes) - min(sizes) <= 1           # balanced


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_worker_shards_cover_corpus(seed):
    corpus, _, _ = synthetic_corpus(20, 50, 4, 15, seed=seed)
    workers = 3
    seen = np.zeros(corpus.num_tokens, int)
    for w in range(workers):
        s = worker_shard(corpus, w, workers)
        seen[s.token_id] += 1
    np.testing.assert_array_equal(seen, 1)        # exactly-once cover


def test_bigram_corpus_matches_paper_construction():
    corpus = from_documents([[0, 1, 2], [1, 2]], vocab_size=3)
    # doc0: (0,1), (1,2); doc1: (1,2) -> 2 unique phrases, 3 occurrences.
    # The paper's Wiki-bigram AUGMENTS the vocabulary (§5): unigrams kept,
    # phrase ids appended above V.
    big = bigram_corpus(corpus)
    assert big.num_tokens == 5 + 3
    assert big.vocab_size == 3 + 2
    # replace=True is the bigram-only escape hatch (the old behaviour)
    rep = bigram_corpus(corpus, replace=True)
    assert rep.num_tokens == 3
    assert rep.vocab_size == 2


def test_from_texts_roundtrip():
    corpus = from_texts(["the cat sat", "the dog sat"])
    assert corpus.vocab_size == 4
    assert corpus.num_tokens == 6
    corpus.validate()


# -- RoPE ----------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_rope_preserves_norm_and_relative_phase(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, 4, 2, 16)).astype(np.float32))
    pos = jnp.asarray([[0, 1, 5, 9]], dtype=jnp.int32)
    y = apply_rope(x, pos, 10000.0)
    # rotation: per-token norms preserved
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relative property: <q_i, k_j> depends only on i-j
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))
    def dot_at(pi, pj):
        qq = apply_rope(q, jnp.asarray([[pi]], jnp.int32), 10000.0)
        kk = apply_rope(k, jnp.asarray([[pj]], jnp.int32), 10000.0)
        return float(jnp.sum(qq * kk))
    assert abs(dot_at(7, 3) - dot_at(14, 10)) < 1e-3


# -- engine invariants under adversarial corpora -------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(2, 5))
@settings(max_examples=5, deadline=None)
def test_engine_invariants_random_corpus(seed, workers):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(50, 300))
    from repro.data.corpus import Corpus
    corpus = Corpus(rng.integers(0, 12, n).astype(np.int32),
                    rng.integers(0, 31, n).astype(np.int32), 12, 31)
    lda = ModelParallelLDA(corpus, num_topics=5, num_workers=workers,
                           seed=seed)
    lda.run(2)
    check_invariants(lda.gather_counts(), n)


@given(st.integers(0, 2**31 - 1), st.integers(1, 3), st.integers(1, 3),
       st.integers(1, 2))
@settings(max_examples=5, deadline=None)
def test_hybrid_engine_invariants_random_corpus(seed, d, m, s):
    """The 2D grid preserves the count invariants and the rebuild-from-z
    identity on adversarial corpora for any small (D, M, S)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(50, 300))
    from repro.core.counts import build_counts
    from repro.data.corpus import Corpus
    corpus = Corpus(rng.integers(0, 12, n).astype(np.int32),
                    rng.integers(0, 31, n).astype(np.int32), 12, 31)
    lda = ModelParallelLDA(corpus, num_topics=5, num_workers=m, seed=seed,
                           data_parallel=d, blocks_per_worker=s)
    lda.run(2)
    state = lda.gather_counts()
    check_invariants(state, n)
    rebuilt = build_counts(corpus.doc, corpus.word, lda.assignments(),
                           12, 31, 5)
    np.testing.assert_array_equal(np.asarray(rebuilt.ckt),
                                  np.asarray(state.ckt))


def test_single_doc_single_word_degenerate():
    """Degenerate corpora must not break the schedule or the samplers."""
    from repro.data.corpus import Corpus
    corpus = Corpus(np.zeros(10, np.int32), np.zeros(10, np.int32), 1, 1)
    lda = ModelParallelLDA(corpus, num_topics=3, num_workers=2, seed=0)
    lda.run(2)
    state = lda.gather_counts()
    check_invariants(state, 10)
    assert int(np.asarray(state.ckt)[0].sum()) == 10
