"""Training step assembly: loss → grad (w/ microbatch accumulation) →
clip → AdamW update."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import shard_activations
from repro.models.transformer import Model
from repro.train.optimizer import AdamW, AdamWState, clip_by_global_norm


def make_train_step(model: Model, opt: AdamW, clip_norm: float = 1.0,
                    accum_steps: int = 1, ce_chunk: int = 512):
    """Returns ``train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)`` ready for ``jax.jit`` (the launcher adds
    in/out shardings).

    ``accum_steps > 1`` splits the global batch into microbatches processed
    under a ``lax.scan`` with fp32 gradient accumulation — the standard
    device-memory lever for the big-model train shapes (backward residuals
    scale with the microbatch, the accumulator with the sharded parameter
    count)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p, b: model.loss(p, b, ce_chunk=ce_chunk))(params, batch)

    def train_step(params, opt_state: AdamWState,
                   batch: Dict[str, jax.Array]
                   ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
        if accum_steps == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]), batch)

            def body(carry, mb):
                gsum, lsum = carry
                mb = jax.tree_util.tree_map(shard_activations, mb)
                loss, g = grads_of(params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            gzero = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (gzero, jnp.float32(0.0)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, gsum)
            loss = lsum / accum_steps
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": opt.schedule(opt_state.step)}
        return params, opt_state, metrics

    return train_step


def pick_accum_steps(cfg, shape, data_shards: int,
                     target_elems: int = 2 ** 25) -> int:
    """Largest power-of-2 microbatch split keeping per-device activation
    rows (tokens × d_model) under ``target_elems`` (≈64 MB bf16/layer)."""
    local_batch = max(shape.global_batch // data_shards, 1)
    tokens_per_dev = local_batch * shape.seq_len
    accum = 1
    while (accum < local_batch
           and shape.global_batch % (accum * 2) == 0
           and tokens_per_dev // accum * cfg.d_model > target_elems):
        accum *= 2
    return max(accum, 1)


def make_eval_step(model: Model):
    def eval_step(params, batch):
        return model.loss(params, batch)
    return eval_step
