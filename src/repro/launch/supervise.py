"""Crash-recovery supervisor (DESIGN.md §15).

Runs a training attempt (an in-process callable for tests, a re-exec'd
``lda_train`` child for the CLI), and on a crash:

1. **quarantines** any partial or corrupt checkpoint debris (``ckpt.tmp``
   trees, ``*.tmp`` files, checkpoints whose integrity sidecars no
   longer validate) into ``<workdir>/quarantine/`` — never deleted, so
   a post-mortem can inspect exactly what the crash left behind;
2. decides whether the workdir is **resumable** (a validated checkpoint
   survives) or must **start fresh** (crash before the first
   checkpoint: everything is quarantined so the child re-initializes);
3. **restarts** with bounded exponential backoff whose jitter is drawn
   from a seeded rng — the whole restart schedule is deterministic,
   like everything else in this repo;
4. gives up with :class:`RestartBudgetExceeded` after ``max_restarts``
   restarts.

Why recovery is bitwise-invisible: a checkpoint is the complete chain
state (counts + rng bit-generator state) taken at an iteration
boundary, and both engines' ``resume`` paths restore it bit-for-bit —
so "crash, quarantine, resume from last good checkpoint" lands on the
SAME chain as a run that never crashed.  Even the fresh-start case is
deterministic: the chain is a pure function of (corpus, config, seed).
``tests/test_faults.py`` pins the end-to-end property: a run killed by
injected crashes at several step offsets, auto-restarted by this
supervisor, ends bitwise equal (all count arrays + rng state) to an
uninterrupted run.
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

from repro.core import faults
from repro.data import integrity

QUARANTINE_DIR = "quarantine"
MP_CKPT = "engine_ckpt.npz"


class RestartBudgetExceeded(RuntimeError):
    """The child kept failing past ``max_restarts`` restarts."""


@dataclass
class SupervisorReport:
    attempts: int = 0
    restarts: int = 0
    exit_code: Optional[int] = None
    resumed: List[bool] = field(default_factory=list)
    backoffs: List[float] = field(default_factory=list)
    quarantined: List[str] = field(default_factory=list)
    crashes: List[str] = field(default_factory=list)


def checkpoint_kind(workdir: str) -> Optional[str]:
    """Which engine owns this workdir: 'streaming' (run.json state
    store), 'mp' (single engine_ckpt.npz), or None (nothing yet)."""
    if os.path.exists(os.path.join(workdir, "run.json")):
        return "streaming"
    if os.path.exists(os.path.join(workdir, MP_CKPT)) or \
            os.path.exists(os.path.join(workdir, MP_CKPT + ".tmp")):
        return "mp"
    return None


def _quarantine(workdir: str, path: str, report: List[str]) -> None:
    qroot = os.path.join(workdir, QUARANTINE_DIR)
    os.makedirs(qroot, exist_ok=True)
    dest = os.path.join(qroot,
                        f"{len(os.listdir(qroot)):03d}_"
                        f"{os.path.basename(path)}")
    os.rename(path, dest)
    report.append(dest)


def _valid_streaming_ckpt(ckpt: str) -> bool:
    """A streaming checkpoint dir is good iff every stamped artifact
    validates AND the progress record (iteration + rng state) is there."""
    if not os.path.isdir(ckpt) or \
            not os.path.exists(os.path.join(ckpt, "progress.json")):
        return False
    try:
        integrity.validate_tree(ckpt)
        with open(os.path.join(ckpt, "progress.json")) as f:
            json.load(f)
        return True
    except (integrity.IntegrityError, ValueError, OSError):
        return False


def _valid_mp_ckpt(path: str) -> bool:
    if not os.path.exists(path):
        return False
    try:
        integrity.load_npz(path)
        return True
    except integrity.IntegrityError:
        return False


def prepare_restart(workdir: str) -> dict:
    """Quarantine crash debris and report whether the workdir holds a
    validated checkpoint to resume from.

    Idempotent: on a clean workdir it quarantines nothing.  When NO
    valid checkpoint survives, every remaining artifact is quarantined
    too, so the next attempt re-initializes from scratch instead of
    tripping over a half-built state store.
    """
    quarantined: List[str] = []
    if not os.path.isdir(workdir):
        return {"kind": None, "resumable": False, "quarantined": quarantined}
    kind = checkpoint_kind(workdir)
    resumable = False

    if kind == "streaming":
        tmp = os.path.join(workdir, "ckpt.tmp")
        if os.path.exists(tmp):            # killed mid-copy: always debris
            _quarantine(workdir, tmp, quarantined)
        ckpt = os.path.join(workdir, "ckpt")
        old = os.path.join(workdir, "ckpt.old")
        if os.path.isdir(ckpt) and not _valid_streaming_ckpt(ckpt):
            _quarantine(workdir, ckpt, quarantined)
        if os.path.isdir(ckpt) and os.path.isdir(old):
            # killed after promote but before the old tree was removed
            _quarantine(workdir, old, quarantined)
        if not os.path.isdir(ckpt) and os.path.isdir(old):
            # killed between the two renames of the atomic swap: the
            # previous checkpoint is still complete under ckpt.old
            if _valid_streaming_ckpt(old):
                os.rename(old, ckpt)
            else:
                _quarantine(workdir, old, quarantined)
        try:
            integrity.validate_file(os.path.join(workdir, "run.json"))
            run_ok = True
        except integrity.IntegrityError:
            run_ok = False
        resumable = run_ok and _valid_streaming_ckpt(ckpt)
    elif kind == "mp":
        mp = os.path.join(workdir, MP_CKPT)
        for leftover in (mp + ".tmp",):
            if os.path.exists(leftover):
                _quarantine(workdir, leftover, quarantined)
        if os.path.exists(mp) and not _valid_mp_ckpt(mp):
            _quarantine(workdir, mp, quarantined)
            sc = integrity.sidecar_path(mp)
            if os.path.exists(sc):
                _quarantine(workdir, sc, quarantined)
        resumable = _valid_mp_ckpt(mp)

    if kind is not None and not resumable:
        # no checkpoint survived: clear the way for a fresh, fully
        # deterministic re-initialization (chain = f(corpus, cfg, seed))
        for name in sorted(os.listdir(workdir)):
            if name == QUARANTINE_DIR:
                continue
            _quarantine(workdir, os.path.join(workdir, name), quarantined)
    return {"kind": kind, "resumable": resumable, "quarantined": quarantined}


class Supervisor:
    """Restart loop around a training attempt.

    ``run_child(attempt, resumable) -> int`` runs one attempt and
    returns its exit code; raising (anything up to and including
    :class:`~repro.core.faults.InjectedCrash`) counts as a crash.
    ``max_restarts`` bounds RESTARTS, so at most ``max_restarts + 1``
    attempts run.  Backoff before restart ``i`` is
    ``min(cap, base * 2**i) * jitter`` with jitter drawn uniformly from
    [0.5, 1.5) by ``default_rng([seed, i])`` — deterministic per
    (seed, restart), independent of wall clock.
    """

    def __init__(self, run_child: Callable[[int, bool], int], workdir: str,
                 max_restarts: int = 3, backoff_base: float = 0.05,
                 backoff_cap: float = 2.0, seed: int = 0,
                 sleep: Callable[[float], None] = time.sleep,
                 log: Callable[[str], None] = print):
        self.run_child = run_child
        self.workdir = workdir
        self.max_restarts = int(max_restarts)
        self.backoff_base = float(backoff_base)
        self.backoff_cap = float(backoff_cap)
        self.seed = int(seed)
        self.sleep = sleep
        self.log = log

    def backoff(self, restart: int) -> float:
        base = min(self.backoff_cap, self.backoff_base * 2 ** restart)
        jitter = 0.5 + np.random.default_rng(
            [self.seed, restart]).random()
        return base * jitter

    def run(self) -> SupervisorReport:
        report = SupervisorReport()
        for attempt in range(self.max_restarts + 1):
            info = prepare_restart(self.workdir)
            report.quarantined.extend(info["quarantined"])
            report.resumed.append(info["resumable"])
            report.attempts += 1
            try:
                rc = self.run_child(attempt, info["resumable"])
            except (Exception, faults.InjectedCrash) as e:
                report.crashes.append(f"{type(e).__name__}: {e}")
                rc = -1
            if rc == 0:
                report.exit_code = 0
                return report
            why = (report.crashes[-1] if rc == -1 and report.crashes
                   else f"exit {rc}")
            self.log(f"[supervisor] attempt {attempt} failed ({why})")
            if attempt == self.max_restarts:
                break
            delay = self.backoff(attempt)
            report.backoffs.append(delay)
            report.restarts += 1
            self.log(f"[supervisor] restarting in {delay:.3f}s "
                     f"(restart {attempt + 1}/{self.max_restarts})")
            self.sleep(delay)
        raise RestartBudgetExceeded(
            f"child failed {report.attempts} times "
            f"(max_restarts={self.max_restarts}); last: "
            f"{report.crashes[-1] if report.crashes else 'nonzero exit'}")


# ---------------------------------------------------------------------------
# CLI integration (lda_train --supervise)
# ---------------------------------------------------------------------------

_STRIP_FLAGS = {"--supervise"}
_STRIP_VALUED = {"--max-restarts", "--restart-backoff"}


def strip_supervise_args(argv: List[str]) -> List[str]:
    out, skip = [], False
    for a in argv:
        if skip:
            skip = False
            continue
        if a in _STRIP_FLAGS:
            continue
        if a in _STRIP_VALUED:
            skip = True
            continue
        if any(a.startswith(f + "=") for f in _STRIP_VALUED):
            continue
        out.append(a)
    return out


def supervise_cli(argv: List[str], workdir: str, max_restarts: int,
                  backoff_base: float = 0.05, seed: int = 0) -> int:
    """Supervise ``lda_train`` as a subprocess: re-exec this module's
    CLI with the supervise flags stripped, toggling ``--resume`` per
    attempt based on what the quarantine pass finds.  The
    ``REPRO_FAULT_PLAN`` env var reaches attempt 0 only — restarted
    attempts must not re-trigger the very fault being recovered from
    (a real crash does not follow the process to its replacement)."""
    base = strip_supervise_args(argv)

    def run_child(attempt: int, resumable: bool) -> int:
        child = [a for a in base if a != "--resume"]
        if resumable:
            child.append("--resume")
        env = os.environ.copy()
        if attempt > 0:
            env.pop(faults.ENV_VAR, None)
        cmd = [sys.executable, "-m", "repro.launch.lda_train"] + child
        print(f"[supervisor] attempt {attempt}: "
              f"{'resume' if resumable else 'fresh start'}", flush=True)
        return subprocess.call(cmd, env=env)

    sup = Supervisor(run_child, workdir, max_restarts=max_restarts,
                     backoff_base=backoff_base, seed=seed)
    report = sup.run()
    print(f"[supervisor] done: {report.attempts} attempt(s), "
          f"{report.restarts} restart(s), "
          f"{len(report.quarantined)} artifact(s) quarantined", flush=True)
    return 0


__all__ = [
    "RestartBudgetExceeded", "SupervisorReport", "Supervisor",
    "checkpoint_kind", "prepare_restart", "strip_supervise_args",
    "supervise_cli", "QUARANTINE_DIR", "MP_CKPT",
]
