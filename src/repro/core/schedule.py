"""Rotation scheduler (paper Algorithm 1), generalized to ``S`` blocks per
worker (DESIGN.md §3).

The scheduler partitions the vocabulary into ``B = S·M`` disjoint word
blocks and pipelines them through ``M`` workers.  Blocks are numbered
*slot-major*: block ``b = s·M + w`` starts the iteration in slot ``s`` of
worker ``w``.  In round ``r`` (with ``r = q·S + t``) worker ``m`` samples
the resident block

    ``block_for(m, r) = (r mod S)·M + ((m + r // S) mod M)``

so after ``B`` rounds every (worker, block) pair has met exactly once —
one *iteration* over the data — and within every round the ``M`` resident
blocks are disjoint, which is what makes parallel == serial exact.  At
``S = 1`` this reduces to the paper's original ``(m + r) mod M`` rotation.

Each worker keeps a length-``S`` FIFO of blocks: the head is the resident
block being sampled this round; after sampling it is handed to the ring
neighbour ``m - 1`` (a single ``jax.lax.ppermute`` of the *resident* block
only — parked blocks never move), and the received block joins the tail of
the queue, surfacing again ``S`` rounds later.  Per-worker *resident*
model is therefore ``ceil(V / (S·M)) × K`` rows — model capacity scales
with ``S`` independently of the worker count (the paper's "200B variables
on a low-end cluster" lever; the ``S-1`` parked slots stand in for the
distributed key-value store / host offload of the original system).

Under SPMD the scheduler is not a process: ``block_for``/``owner_for``
define a compile-time permutation that the engine lowers to a single
``jax.lax.ppermute`` (HLO ``collective-permute``) per round.  This module
is also used verbatim by the host-simulation path (``kvstore.py``), where
it plays the paper's original role of a coordinating component.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class VocabPartition:
    """Disjoint word blocks ``{V_1 .. V_B}`` of a padded vocabulary."""

    vocab_size: int          # true V
    num_blocks: int          # B = S·M
    block_size: int          # Vb = ceil(V / B)

    @property
    def padded_vocab(self) -> int:
        return self.block_size * self.num_blocks

    def block_of_word(self, word: np.ndarray) -> np.ndarray:
        return np.asarray(word) // self.block_size

    def word_offset_in_block(self, word: np.ndarray) -> np.ndarray:
        return np.asarray(word) % self.block_size

    def block_bounds(self, block: int) -> Tuple[int, int]:
        lo = block * self.block_size
        return lo, min(lo + self.block_size, self.vocab_size)

    def block_rows(self, ckt: np.ndarray, block: int) -> np.ndarray:
        """Slice the rows of a word-major ``[V, K]`` table for one block."""
        lo = block * self.block_size
        return ckt[lo:lo + self.block_size]


def partition_vocab(vocab_size: int, num_blocks: int) -> VocabPartition:
    if num_blocks <= 0:
        raise ValueError(f"num_blocks must be positive, got {num_blocks}")
    block_size = -(-vocab_size // num_blocks)  # ceil division
    return VocabPartition(vocab_size, num_blocks, block_size)


def block_for(worker: int, rnd: int, num_workers: int,
              blocks_per_worker: int = 1) -> int:
    """Resident block of ``worker`` in round ``rnd`` (Algorithm 1 rotation,
    slot-major pipeline over ``S·M`` blocks).  ``S = 1`` gives the paper's
    ``(worker + rnd) mod M``."""
    s = blocks_per_worker
    b = s * num_workers
    rnd = rnd % b
    return (rnd % s) * num_workers + (worker + rnd // s) % num_workers


def owner_for(block: int, rnd: int, num_workers: int,
              blocks_per_worker: int = 1) -> int:
    """Worker holding ``block`` resident in the round of its slot's turn.

    With ``S = 1`` every block is resident every round and this is the
    exact inverse of :func:`block_for`.  With ``S > 1`` block ``b`` is
    resident only in rounds ``r`` with ``r mod S == b // M``; for other
    rounds the returned worker is where the block sits *parked* awaiting
    its turn, which coincides with its next resident owner.
    """
    s = blocks_per_worker
    del_r = rnd % (s * num_workers)
    home = block % num_workers                    # slot-major home worker
    turns = del_r // s + (1 if del_r % s > block // num_workers else 0)
    return (home - turns) % num_workers


def home_slot(block: int, num_workers: int) -> int:
    """Initial queue slot of ``block`` (slot-major numbering b = s·M + w)."""
    return block // num_workers


def rotation_permutation(num_workers: int) -> List[Tuple[int, int]]:
    """(src, dst) pairs moving each resident block to its next holder.

    Worker ``m`` hands its just-sampled resident block to worker
    ``m - 1`` around the ring — this list feeds ``jax.lax.ppermute`` and is
    independent of ``blocks_per_worker``: parked blocks never travel, so
    per-round traffic is exactly one resident block per worker.
    """
    return [(m, (m - 1) % num_workers) for m in range(num_workers)]


def schedule_table(num_workers: int,
                   blocks_per_worker: int = 1) -> np.ndarray:
    """Full iteration schedule: ``table[r, m]`` = resident block at worker
    ``m`` in round ``r``, for the ``S·M`` rounds of one iteration."""
    s, m_ = blocks_per_worker, num_workers
    r = np.arange(s * m_)[:, None]
    m = np.arange(m_)[None, :]
    return (r % s) * m_ + (m + r // s) % m_


def schedule_table_2d(data_parallel: int, num_workers: int,
                      blocks_per_worker: int = 1) -> np.ndarray:
    """Hybrid (data × model) schedule: ``table[r, d, m]`` = resident block
    of the worker at data replica ``d``, model position ``m``, in round
    ``r`` (DESIGN.md §8).

    The vocabulary is partitioned into ``B = S·M`` blocks *shared* by all
    ``D`` replicas — the model axis is replicated along ``data``, so every
    replica runs the same 1D rotation and the D copies of block
    ``block_for(m, r)`` are reconciled by a delta-psum at the round
    boundary.  Hence the table is the 1D table broadcast along ``d``:
    replicas are ALIGNED (same block at the same model position), which is
    what makes the per-round reconciliation a single axis-local psum.
    """
    if data_parallel < 1:
        raise ValueError(
            f"data_parallel must be >= 1, got {data_parallel}")
    table = schedule_table(num_workers, blocks_per_worker)   # [R, M]
    return np.broadcast_to(table[:, None, :],
                           (table.shape[0], data_parallel,
                            num_workers)).copy()


def validate_schedule_2d(data_parallel: int, num_workers: int,
                         blocks_per_worker: int = 1) -> None:
    """2D schedule invariants: within every (round, replica) the resident
    blocks are disjoint on the model axis; replicas are aligned (the same
    model position holds the same block in every replica, so the data-axis
    psum reconciles copies of ONE block); every (worker-grid position,
    block) pair meets exactly once per ``S·M``-round iteration."""
    table = schedule_table_2d(data_parallel, num_workers, blocks_per_worker)
    rounds, d_, m_ = table.shape
    b = blocks_per_worker * num_workers
    assert rounds == b, (rounds, b)
    for r in range(rounds):
        for d in range(d_):
            row = table[r, d]
            assert len(set(row)) == m_, (
                f"round {r} replica {d} blocks collide: {row}")
            assert (row == table[r, 0]).all(), (
                f"round {r}: replicas misaligned: {row} vs {table[r, 0]}")
    for d in range(d_):
        for m in range(m_):
            assert sorted(table[:, d, m]) == list(range(b)), (
                f"grid position ({d},{m}) misses blocks: {table[:, d, m]}")


def serial_order(num_workers: int,
                 blocks_per_worker: int = 1
                 ) -> Sequence[Tuple[int, int, int]]:
    """The canonical serial execution order equivalent to the MP schedule.

    Yields ``(round, worker, block)`` in the order a single machine would
    execute the same task pool; used by tests to prove parallel == serial.
    """
    out = []
    for r in range(blocks_per_worker * num_workers):
        for m in range(num_workers):
            out.append((r, m, block_for(m, r, num_workers,
                                        blocks_per_worker)))
    return out


def validate_schedule(num_workers: int, blocks_per_worker: int = 1) -> None:
    """Every round's resident blocks are disjoint; every (worker, block)
    pair is met exactly once per iteration."""
    table = schedule_table(num_workers, blocks_per_worker)
    b = blocks_per_worker * num_workers
    for r in range(table.shape[0]):
        row = sorted(table[r])
        assert len(set(row)) == num_workers, (
            f"round {r} blocks collide: {table[r]}")
    for m in range(num_workers):
        assert sorted(table[:, m]) == list(range(b)), (
            f"worker {m} misses blocks: {table[:, m]}")
