"""Sharding rules: parameter/batch/cache PartitionSpecs per architecture.

Strategy (DESIGN.md §6):
  * embeddings & unembeddings: vocab over ``model`` — the direct analogue
    of the paper's word-block partitioning (the V×d table is the "word
    model" and no device holds all of it);
  * attention/MLP: tensor parallel over ``model`` (column- then
    row-parallel pairs);
  * MoE: experts over ``model`` (disjoint expert blocks = disjoint model
    blocks); when the expert count does not divide the axis the expert
    FFN width is sharded instead;
  * FSDP: every weight additionally sharded over the data axes
    (('pod','data')) — optimizer state inherits it, giving the ZeRO
    property.  On inference shapes this becomes 2-D weight sharding with
    per-layer gathers.

Every proposed spec is *sanitized*: an axis that does not evenly divide
its dimension is dropped (jit in_shardings require divisibility).  That
keeps exact public configs (25 heads, 60 experts, odd vocabs) lowering
everywhere; the roofline then shows what the irregular sizes cost.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import tree_map_with_path

from repro.configs.base import ArchConfig, InputShape
from repro.launch.mesh import data_axes


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def sanitize(mesh: Mesh, spec: P, shape) -> P:
    """Drop spec axes that do not evenly divide the dimension."""
    out = []
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            out.append(None)
        elif dim % _axes_size(mesh, axes) == 0 and dim > 0:
            out.append(axes)
        else:
            # try single-axis fallbacks before giving up
            cand = axes if isinstance(axes, tuple) else (axes,)
            kept = None
            for a in cand:
                if dim % mesh.shape[a] == 0:
                    kept = a
                    break
            out.append(kept)
    return P(*out)


def _ns(mesh: Mesh, spec: P, shape) -> NamedSharding:
    return NamedSharding(mesh, sanitize(mesh, spec, shape))


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_shardings(cfg: ArchConfig, mesh: Mesh, abstract_params: Any,
                    fsdp: bool = True) -> Any:
    """NamedSharding pytree matching ``abstract_params``."""
    dp = data_axes(mesh)
    f: Optional[Any] = dp if (fsdp and dp) else None

    def rule(path, x):
        name = _path_str(path)
        nd = x.ndim
        if "embed" == name:
            return _ns(mesh, P("model", f), x.shape)
        if "unembed" in name:
            return _ns(mesh, P(f, "model"), x.shape)
        if nd == 4:                       # MoE experts [L, E, in, out]
            if "w_down" in name:
                return _ns(mesh, P(None, "model", None, f), x.shape)
            return _ns(mesh, P(None, "model", f, None), x.shape)
        if nd == 3:                       # stacked [L, in, out]
            if ("wo" in name or "w_down" in name or "w_out" in name
                    or "out_proj" in name):
                return _ns(mesh, P(None, "model", f), x.shape)
            if "router" in name:
                return _ns(mesh, P(None, f, None), x.shape)
            if "d_skip" in name or "out_scale" in name:   # [L, H, hd]
                return _ns(mesh, P(None, "model", None), x.shape)
            return _ns(mesh, P(None, f, "model"), x.shape)
        if nd == 2:                       # stacked vectors [L, d]
            return _ns(mesh, P(None, f), x.shape)
        return _ns(mesh, P(), x.shape)

    return tree_map_with_path(rule, abstract_params)


def batch_shardings(cfg: ArchConfig, mesh: Mesh, abstract_batch: Any) -> Any:
    dp = data_axes(mesh)

    def rule(path, x):
        spec = [dp] + [None] * (x.ndim - 1)
        return _ns(mesh, P(*spec), x.shape)

    return tree_map_with_path(rule, abstract_batch)


def cache_shardings(cfg: ArchConfig, mesh: Mesh, abstract_caches) -> Any:
    """Cache shardings.  KV tensors ([L,] B, S, kvH, hd): batch over the
    data axes; kv heads over ``model`` when divisible, else the sequence
    dimension takes ``model`` (length-sharded cache — the 500k shape with
    batch 1 relies on this).  Handles both the stacked pytree (uniform
    layers, leading L dim) and the per-layer list form."""
    dp = data_axes(mesh)

    def rule(path, x):
        name = _path_str(path)
        stacked = not isinstance(abstract_caches, list)
        lead = (None,) if stacked else ()
        nd = x.ndim - len(lead)
        if nd == 4 and name.split("/")[-1] in ("k", "v"):
            b, s, kvh, hd = x.shape[len(lead):]
            batch_ok = b % _axes_size(mesh, dp) == 0 and b > 1
            spec_b = dp if batch_ok else None
            if kvh % mesh.shape["model"] == 0:
                return _ns(mesh, P(*lead, spec_b, None, "model", None),
                           x.shape)
            if not batch_ok:
                # batch unshardable (long_500k): spread S over everything
                return _ns(mesh, P(*lead, None, dp + ("model",), None, None),
                           x.shape)
            return _ns(mesh, P(*lead, spec_b, "model", None, None), x.shape)
        # recurrent states: shard batch; next dim over model when divisible
        spec = list(lead) + [dp] + [None] * (nd - 1)
        if nd >= 2:
            spec[len(lead) + 1] = "model"
        return _ns(mesh, P(*spec), x.shape)

    if isinstance(abstract_caches, list):
        return [tree_map_with_path(rule, c) for c in abstract_caches]
    return tree_map_with_path(rule, abstract_caches)


def replicated(mesh: Mesh, tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, P()), tree)
