"""Architecture configuration schema + registry.

One ``ArchConfig`` per assigned architecture (exact sizes from the public
pool) plus the LDA paper's own workload config.  ``reduced()`` produces the
CPU smoke-test variant (≤2 layers, d_model ≤ 512, ≤4 experts) of the same
family, as required by the assignment.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str                      # citation for the exact sizes
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # --- attention ---
    rope_theta: float = 10000.0
    sliding_window: int = 0          # 0 = global attention
    global_every: int = 0            # gemma3: 1 global layer per N (window on rest)
    qkv_bias: bool = False
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state_size: int = 0
    ssm_heads: int = 0               # mamba heads (hymba); 0 = derived
    block_pattern: Tuple[str, ...] = ()   # e.g. ("mlstm", "slstm")
    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0
    encoder_seq: int = 0             # stub frame count (whisper: 1500)
    # --- VLM stub ---
    num_patch_embeds: int = 0        # llava anyres: 5 tiles × 576
    # --- misc ---
    norm: str = "rms"                # rms | layernorm | nonparametric
    tie_embeddings: bool = True
    # derived capability: can this arch serve the 500k decode shape?
    subquadratic_decode: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def block_type(self) -> str:
        if self.family == "moe":
            return "moe"
        if self.family == "hybrid":
            return "hybrid"
        if self.family == "ssm":
            return "xlstm"
        return "dense"

    def layer_windows(self) -> Tuple[int, ...]:
        """Per-layer sliding windows (0 = global) honoring global_every."""
        if self.sliding_window <= 0:
            return tuple(0 for _ in range(self.num_layers))
        out = []
        for i in range(self.num_layers):
            is_global = (self.global_every > 0
                         and (i + 1) % self.global_every == 0)
            out.append(0 if is_global else self.sliding_window)
        return tuple(out)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/topology, tiny sizes."""
        layers = min(self.num_layers, 2)
        if self.block_pattern:
            layers = max(layers, len(set(self.block_pattern)))
        d = min(self.d_model, 128)
        heads = max(min(self.num_heads, 4), 1)
        kv = max(min(self.num_kv_heads, heads), 1)
        if heads % kv:
            kv = 1
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=layers,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=32,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2)
            if self.num_experts_per_tok else 0,
            num_shared_experts=min(self.num_shared_experts, 1)
            if self.num_shared_experts else 0,
            ssm_state_size=min(self.ssm_state_size, 8)
            if self.ssm_state_size else 0,
            ssm_heads=max(min(self.ssm_heads, 4), 1) if self.ssm_heads else 0,
            encoder_layers=min(self.encoder_layers, 2)
            if self.encoder_layers else 0,
            encoder_seq=min(self.encoder_seq, 16) if self.encoder_seq else 0,
            num_patch_embeds=min(self.num_patch_embeds, 8)
            if self.num_patch_embeds else 0,
            sliding_window=min(self.sliding_window, 16)
            if self.sliding_window else 0,
            global_every=self.global_every,
        )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "qwen2-moe-a2.7b", "hymba-1.5b", "phi3-mini-3.8b",
    "llava-next-mistral-7b", "xlstm-350m", "gemma3-1b", "olmo-1b",
    "qwen3-moe-235b-a22b", "whisper-medium", "phi4-mini-3.8b",
]

_MODULE_OF = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
              for a in ARCH_IDS}
_CACHE: Dict[str, ArchConfig] = {}


def get_config(arch: str) -> ArchConfig:
    if arch not in _CACHE:
        if arch not in _MODULE_OF:
            raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
        mod = importlib.import_module(_MODULE_OF[arch])
        _CACHE[arch] = mod.CONFIG
    return _CACHE[arch]


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str    # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: InputShape) -> Optional[str]:
    """None if the (arch, shape) pair runs; else the documented skip reason."""
    if shape.name == "long_500k" and not cfg.subquadratic_decode:
        return ("full-attention architecture: 500k decode requires "
                "sub-quadratic attention (DESIGN.md §5)")
    return None
