"""shard_map backend == vmap backend, bit-exact, on 4 simulated devices.

Runs in a subprocess because the device count must be fixed before JAX
initializes (and the rest of the suite must keep seeing 1 device).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
from repro.data.synthetic import synthetic_corpus
from repro.core.model_parallel import ModelParallelLDA

corpus, _, _ = synthetic_corpus(num_docs=40, vocab_size=120, num_topics=8,
                                doc_len=30, seed=0)
a = ModelParallelLDA(corpus, 8, 4, seed=1, backend="vmap")
b = ModelParallelLDA(corpus, 8, 4, seed=1, backend="shard_map")
for _ in range(2):
    a.step(); b.step()
sa, sb = a.gather_counts(), b.gather_counts()
assert (np.asarray(sa.ckt) == np.asarray(sb.ckt)).all(), "ckt mismatch"
assert (np.asarray(sa.cdk) == np.asarray(sb.cdk)).all(), "cdk mismatch"
assert (a.assignments() == b.assignments()).all(), "z mismatch"
assert np.allclose(a.round_errors, b.round_errors, atol=1e-6), "errs mismatch"
print("SHARD_MAP_OK")
"""


@pytest.mark.slow
def test_shard_map_equals_vmap_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARD_MAP_OK" in out.stdout
