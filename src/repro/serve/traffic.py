"""Seeded serving traffic: Poisson arrivals, heavy-tailed doc lengths,
and the open-loop replay loop (DESIGN.md §14).

The same trace + replay machinery drives three consumers: the
deterministic virtual-clock tests (`tests/test_scheduler.py`), the
wall-clock traffic benchmark (`benchmarks/bench_serve.py`), and the
``lda_serve`` CLI.  A trace is a pure function of its seed, so replaying
it twice — even across processes — submits bit-identical requests at
identical scheduled times.

**Open loop.**  Arrivals follow the SCHEDULE, not the server: a request
whose scheduled time has passed while the server was busy is submitted
late but stamped with its scheduled arrival, so queueing delay lands in
measured latency.  Closed-loop benches (like `bench_infer.py`'s
back-to-back batches) hide exactly this — the latency a user actually
sees when the system saturates.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.serve.scheduler import ServingScheduler


@dataclasses.dataclass
class TraceRequest:
    t: float                 # scheduled arrival, seconds from replay start
    tokens: np.ndarray       # int32 word ids


def poisson_trace(num_requests: int, rate_qps: float, vocab_size: int, *,
                  seed: int = 0, len_tail: float = 1.3, min_len: int = 4,
                  max_len: int = 64, hot_fraction: float = 0.0,
                  hot_pool: int = 8) -> List[TraceRequest]:
    """Synthetic serving trace: exponential inter-arrival gaps (Poisson
    process at ``rate_qps``) and heavy-tailed doc lengths (``min_len - 1
    + Zipf(len_tail)``, clipped to ``max_len`` — most queries are short,
    a few are near the clip, the length mix real query traffic shows).

    ``hot_fraction`` of requests repeat one of ``hot_pool`` fixed hot
    documents (by EXACT token multiset), modelling repeated/trending
    queries — the traffic the scheduler's multiset cache exists for.
    Everything is drawn from one seeded generator: same seed, same
    trace, bit for bit."""
    if rate_qps <= 0:
        raise ValueError(f"rate_qps must be positive, got {rate_qps}")
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate_qps, size=num_requests))
    hot = [rng.integers(0, vocab_size, size=int(np.clip(
               min_len - 1 + rng.zipf(len_tail), min_len, max_len))
           ).astype(np.int32) for _ in range(max(hot_pool, 1))]
    trace = []
    for i in range(num_requests):
        if hot_fraction > 0 and rng.random() < hot_fraction:
            tokens = hot[int(rng.integers(0, len(hot)))]
        else:
            n = int(np.clip(min_len - 1 + rng.zipf(len_tail),
                            min_len, max_len))
            tokens = rng.integers(0, vocab_size, size=n).astype(np.int32)
        trace.append(TraceRequest(float(t[i]), tokens))
    return trace


def replay_open_loop(sched: ServingScheduler,
                     trace: Sequence[TraceRequest], *,
                     swap_after: Optional[int] = None,
                     swap_snapshot=None,
                     on_tick: Optional[Callable] = None,
                     idle_step: float = 1e-3) -> dict:
    """Replay a trace through a scheduler under ITS clock and drain it.

    Each loop iteration submits every request whose scheduled time has
    arrived (stamped with the scheduled time — open loop), ticks the
    scheduler, and otherwise sleeps the clock forward: to the next
    arrival when idle, by ``idle_step`` when a partial batch is being
    held for ``max_batch_delay``.  Under a `VirtualClock` the whole
    replay is deterministic and instant; under a `WallClock` it is the
    real serving loop.

    ``swap_after=N`` hot-swaps to ``swap_snapshot`` immediately before
    the N-th submission — the mid-replay swap the hot-swap tests and the
    CI smoke drive.  ``on_tick(sched, now)`` runs once per loop (the
    ``lda_serve --watch`` hook).  Returns a summary dict; after it, every
    admitted request has a response (asserted via ``sched.dropped()``).
    """
    t0 = sched.clock.now()
    i = 0
    swap_epoch = None
    while i < len(trace) or sched.pending:
        now = sched.clock.now() - t0
        while i < len(trace) and trace[i].t <= now:
            if swap_after is not None and swap_snapshot is not None \
                    and i == swap_after:
                swap_epoch = sched.swap_snapshot(swap_snapshot)
            sched.submit(trace[i].tokens, now=t0 + trace[i].t)
            i += 1
        ticked = sched.tick()
        if on_tick is not None:
            on_tick(sched, now)
        if sched.pending and not ticked:
            # a partial batch is ageing toward its deadline
            sched.clock.sleep(idle_step)
        elif not sched.pending and i < len(trace):
            # idle: jump to the next scheduled arrival
            sched.clock.sleep(max(trace[i].t - (sched.clock.now() - t0),
                                  idle_step))
    sched.drain()
    elapsed = sched.clock.now() - t0
    epochs: dict = {}
    for r in sched.ok_responses():
        epochs[r.epoch] = epochs.get(r.epoch, 0) + 1
    return {
        "requests": len(trace),
        "elapsed_s": float(elapsed),
        "offered_qps": (len(trace) / trace[-1].t if len(trace)
                        and trace[-1].t > 0 else float("nan")),
        "served_qps": (sched.served / elapsed if elapsed > 0
                       else float("nan")),
        "dropped": sched.dropped(),
        "swap_epoch": swap_epoch,
        "epochs": epochs,
        **sched.latency_summary(),
        **{k: v for k, v in sched.stats().items()
           if k in ("admitted", "rejections", "cache", "swaps", "batches",
                    "faults")},
    }
