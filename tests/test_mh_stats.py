"""Statistical equivalence of the O(1) alias-table MH backend.

MH draws are *distribution-equal* but not trajectory-equal to the exact
inverse-CDF chain, so — unlike every other backend pairing in this repo —
scan-vs-mh cannot be validated bitwise.  This suite grows the
verification story accordingly (DESIGN.md §9):

1. **Statistical layer** — exact-``scan`` and ``mh`` chains run from the
   same init on a small synthetic corpus; after burn-in, label-invariant
   posterior summaries (sorted topic occupancy, doc-topic marginal
   moments) must agree within chi-square/tolerance bounds.  Bounds are
   *self-calibrating*: a second exact chain with a different seed
   measures the sampler's own seed-to-seed spread, and MH must land
   within a small multiple of it (plus an absolute floor so a
   degenerate twin distance cannot make the test vacuous).
2. **Structural layer** — everything around the draw IS still bitwise
   testable: device MH replays draw-for-draw against the `kvstore` host
   oracle fed the same uniforms, the vmap and shard_map backends agree
   exactly, and the 2D ``(data, model)`` grid composes with MH exactly
   as with the exact samplers.

All seeds are pinned; with hashes/seeds fixed by ``scripts/ci.sh`` the
chi-square statistics are deterministic, so the tolerance bounds are
exercised reproducibly rather than being flaky-tolerance guesses.
"""
import numpy as np
import pytest

from repro.core.engine.api import ModelParallelLDA
from repro.core.kvstore import HostModelParallelLDA
from repro.data.synthetic import synthetic_corpus

# chain geometry: ~1.2k tokens, K=8, M=2 workers -> blocks small enough
# that the MH round-start freeze window is a few hundred tokens.
#
# The statistical comparison runs on a DIFFUSE corpus (flat topics, wide
# doc-topic prior): there the posterior is weakly multimodal, both chains
# mix within the burn-in, and the twin-calibrated bounds have teeth.  On
# a strongly peaked corpus the posterior modes are far apart and a
# local-proposal MH chain can sit in a more concentrated mode than the
# exact chain for hundreds of iterations — a real property of LightLDA-
# style samplers (DESIGN.md §9), not a bug this suite could flag.
K = 8
BURN, SAMPLES = 60, 40
CHI2_999_DF7 = 24.32          # chi-square 0.999 quantile at K-1 = 7 dof


@pytest.fixture(scope="module")
def mh_corpus():
    corpus, phi, theta = synthetic_corpus(
        num_docs=40, vocab_size=120, num_topics=K, doc_len=30,
        alpha=0.5, seed=0, peaked=False)
    return corpus


def _chain_stats(corpus, sampler_mode, seed, backend="vmap"):
    """Run burn-in + sampling iterations; return label-invariant posterior
    summaries averaged over the sampled iterations."""
    lda = ModelParallelLDA(corpus, K, num_workers=2, seed=seed,
                           sampler_mode=sampler_mode, backend=backend)
    alpha = np.asarray(lda.alpha)
    occ, m2, ent = [], [], []
    for it in range(BURN + SAMPLES):
        lda.step()
        if it < BURN:
            continue
        state = lda.gather_counts()
        ck = np.asarray(state.ck, np.float64)
        occ.append(np.sort(ck)[::-1] / ck.sum())
        cdk = np.asarray(state.cdk, np.float64)
        theta = (cdk + alpha) / (cdk.sum(1, keepdims=True) + alpha.sum())
        m2.append(float((theta ** 2).sum(1).mean()))
        ent.append(float(-(theta * np.log(theta)).sum(1).mean()))
    return {
        "occupancy": np.mean(occ, axis=0),      # sorted, normalized [K]
        "theta_m2": float(np.mean(m2)),         # E_d[Σ_k θ_dk²]
        "theta_entropy": float(np.mean(ent)),   # E_d[H(θ_d)]
        "tokens": float(ck.sum()),
    }


def _chi2(obs, exp, tokens):
    o = obs * tokens
    e = np.maximum(exp * tokens, 1e-9)
    return float(((o - e) ** 2 / e).sum())


@pytest.fixture(scope="module")
def scan_reference(mh_corpus):
    """The exact chain (seed 0) plus its seed-1 twin: the twin-to-reference
    distance calibrates how much two SAME-distribution chains differ."""
    ref = _chain_stats(mh_corpus, "scan", seed=0)
    twin = _chain_stats(mh_corpus, "scan", seed=1)
    return ref, twin


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["vmap", "shard_map"])
def test_mh_matches_exact_chain_statistics(mh_corpus, scan_reference,
                                           backend):
    """MH topic occupancy and doc-topic moments within the declared
    chi-square/tolerance bounds of the exact chain, on both backends."""
    ref, twin = scan_reference
    mh = _chain_stats(mh_corpus, "mh", seed=0, backend=backend)

    # -- per-topic occupancy: L∞ and chi-square vs the exact chain -------
    twin_linf = np.abs(twin["occupancy"] - ref["occupancy"]).max()
    mh_linf = np.abs(mh["occupancy"] - ref["occupancy"]).max()
    assert mh_linf <= max(3.0 * twin_linf, 0.02), \
        (mh_linf, twin_linf, mh["occupancy"], ref["occupancy"])

    twin_chi2 = _chi2(twin["occupancy"], ref["occupancy"], ref["tokens"])
    mh_chi2 = _chi2(mh["occupancy"], ref["occupancy"], ref["tokens"])
    assert mh_chi2 <= max(3.0 * twin_chi2, CHI2_999_DF7), \
        (mh_chi2, twin_chi2)

    # -- doc-topic marginal moments --------------------------------------
    for key in ("theta_m2", "theta_entropy"):
        twin_d = abs(twin[key] - ref[key])
        mh_d = abs(mh[key] - ref[key])
        assert mh_d <= max(3.0 * twin_d, 0.05 * abs(ref[key])), \
            (key, mh_d, twin_d, mh[key], ref[key])


@pytest.mark.slow
def test_mh_improves_likelihood():
    """Mixing sanity on the PEAKED corpus (planted structure): the MH
    chain climbs in joint likelihood toward the structure, like the
    exact samplers do."""
    corpus, _, _ = synthetic_corpus(
        num_docs=40, vocab_size=120, num_topics=K, doc_len=30, seed=0)
    lda = ModelParallelLDA(corpus, K, num_workers=2, seed=0,
                           sampler_mode="mh")
    ll0 = lda.log_likelihood()
    lda.run(15)
    assert lda.log_likelihood() > ll0 + 0.05 * abs(ll0)


# ---------------------------------------------------------------------------
# Structural layer: bitwise anchors under the statistical claim
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,s,d", [(2, 1, 1), (2, 2, 1), (2, 1, 2)])
def test_mh_host_oracle_replay_draw_for_draw(mh_corpus, m, s, d):
    """Device MH == kvstore host-oracle MH, bit for bit: both consume the
    same externally supplied uniforms through the same jitted kernel, so
    the statistical suite rests on a replayable structural base."""
    lda = ModelParallelLDA(mh_corpus, K, num_workers=m, seed=0,
                           sampler_mode="mh", blocks_per_worker=s,
                           data_parallel=d)
    host = HostModelParallelLDA(mh_corpus, K, num_workers=m, seed=0,
                                sampler="mh", ck_sync="round",
                                blocks_per_worker=s, data_parallel=d)
    for _ in range(2):
        lda.step()
        host.step()
    np.testing.assert_array_equal(lda.assignments(), host.assignments())
    np.testing.assert_array_equal(np.asarray(lda.gather_counts().ckt),
                                  host.gather_ckt())


def test_mh_backends_bit_identical(mh_corpus):
    """vmap and shard_map execute the SAME mh worker_round: bitwise equal
    states after two iterations (transfers the statistical validation to
    both backends)."""
    import jax
    if len(jax.devices()) < 2:
        pytest.skip("needs 2 devices")
    a = ModelParallelLDA(mh_corpus, K, num_workers=2, seed=0,
                         sampler_mode="mh", backend="vmap")
    b = ModelParallelLDA(mh_corpus, K, num_workers=2, seed=0,
                         sampler_mode="mh", backend="shard_map")
    for _ in range(2):
        a.step()
        b.step()
    for x, y in [(a.state.cdk, b.state.cdk), (a.state.ckt, b.state.ckt),
                 (a.state.ck_local, b.state.ck_local),
                 (a.state.z, b.state.z)]:
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_mh_pallas_engine_equals_mh_engine(mh_corpus):
    """The mh_pallas sampler mode is a drop-in: same chain, bit for bit."""
    a = ModelParallelLDA(mh_corpus, K, num_workers=2, seed=0,
                         sampler_mode="mh")
    b = ModelParallelLDA(mh_corpus, K, num_workers=2, seed=0,
                         sampler_mode="mh_pallas")
    a.step()
    b.step()
    np.testing.assert_array_equal(np.asarray(a.state.z),
                                  np.asarray(b.state.z))
    np.testing.assert_array_equal(np.asarray(a.state.ckt),
                                  np.asarray(b.state.ckt))
