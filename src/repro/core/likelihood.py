"""Training log-likelihood of the collapsed LDA state.

The paper (§5, Evaluation) tracks the training log-likelihood
``log p(W, Z | α, β)`` of the latest sample as the convergence surrogate.
For symmetric β and (possibly asymmetric) α the collapsed joint is

  log p(W,Z) = Σ_k [ lnΓ(Vβ) − lnΓ(C_k + Vβ) + Σ_t (lnΓ(C_k^t + β) − lnΓ(β)) ]
             + Σ_d [ lnΓ(Σα) − lnΓ(N_d + Σα) + Σ_k (lnΓ(C_d^k + α_k) − lnΓ(α_k)) ]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import gammaln

from repro.core.counts import CountState


@jax.jit
def word_log_likelihood(ckt: jax.Array, ck: jax.Array, beta: float) -> jax.Array:
    """The word-side (topic) term; separable over word-topic rows, so the
    model-parallel engine can evaluate it block-locally and psum."""
    v = ckt.shape[0]
    k = ck.shape[0]
    vbeta = beta * v
    term = jnp.sum(gammaln(ckt.astype(jnp.float32) + beta)) - v * k * gammaln(
        jnp.float32(beta))
    return (term + k * gammaln(jnp.float32(vbeta))
            - jnp.sum(gammaln(ck.astype(jnp.float32) + vbeta)))


@jax.jit
def doc_log_likelihood(cdk: jax.Array, alpha: jax.Array) -> jax.Array:
    """The document-side term; separable over document shards."""
    alpha = jnp.asarray(alpha, jnp.float32)
    d = cdk.shape[0]
    nd = cdk.sum(axis=1).astype(jnp.float32)
    asum = alpha.sum()
    term = jnp.sum(gammaln(cdk.astype(jnp.float32) + alpha[None, :]))
    return (term - d * jnp.sum(gammaln(alpha))
            + d * gammaln(asum) - jnp.sum(gammaln(nd + asum)))


def log_likelihood(state: CountState, alpha, beta) -> float:
    """Full collapsed joint log p(W, Z) (host convenience)."""
    lw = word_log_likelihood(state.ckt, state.ck, beta)
    ld = doc_log_likelihood(state.cdk, jnp.asarray(alpha, jnp.float32))
    return float(lw + ld)


# ---------------------------------------------------------------------------
# Held-out evaluation: doc-completion perplexity (DESIGN.md §11)
# ---------------------------------------------------------------------------

def doc_completion_perplexity(snapshot, docs, num_sweeps: int = 5,
                              sampler: str = "scan", seed: int = 0,
                              rng=None, num_cycles: int | None = None
                              ) -> dict:
    """Doc-completion perplexity of held-out docs under a frozen snapshot.

    The estimator (Wallach et al. 2009's document-completion scheme):
    each held-out doc is split in half; ``θ̂`` is inferred by fold-in
    (`core/infer.py`) on the FIRST half only, then the SECOND half is
    scored under ``p(w) = Σ_k θ̂_k φ̂_k(w)`` with the snapshot's smoothed
    ``φ̂``.  Because no scored token informs its own ``θ̂``, the metric is
    an honest predictive likelihood — unlike the training
    ``log p(W, Z)`` above, it can get WORSE under overfitting, which is
    what makes per-iteration holdout curves comparable across samplers.

    ``docs`` is a sequence of word-id sequences (e.g.
    ``Corpus.doc_words()``).  Returns ``perplexity = exp(-LL/N)`` over
    the scored halves plus the raw pieces.  A zero-count snapshot scores
    every word at exactly ``1/V``, so its perplexity is exactly ``V`` —
    the uninformative ceiling tests pin.
    """
    from repro.core.infer import fold_in, pack_queries

    docs = [np.asarray(d, np.int32) for d in docs]
    if not docs:
        raise ValueError("doc_completion_perplexity needs >= 1 document")
    est = [d[:len(d) // 2] for d in docs]
    sco = [d[len(d) // 2:] for d in docs]
    if not any(len(s) for s in sco):
        raise ValueError("no tokens to score (all held-out docs empty)")
    word, mask = pack_queries(est)
    res = fold_in(snapshot, word, mask, num_sweeps=num_sweeps,
                  sampler=sampler, seed=seed, rng=rng,
                  **({} if num_cycles is None
                     else {"num_cycles": num_cycles}))
    phi_t = snapshot.word_term().astype(np.float64)   # [V, K] = φ̂ᵀ
    ll = 0.0
    n = 0
    for q, s_tok in enumerate(sco):
        if not len(s_tok):
            continue
        p = phi_t[s_tok] @ res.theta[q]               # [n_q] mixture probs
        ll += float(np.log(p).sum())
        n += int(len(s_tok))
    return {"perplexity": float(np.exp(-ll / n)), "log_likelihood": ll,
            "tokens_scored": n, "num_docs": len(docs),
            "sampler": sampler, "num_sweeps": int(num_sweeps)}
