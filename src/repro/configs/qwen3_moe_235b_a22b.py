"""Qwen3-235B-A22B [hf:Qwen/Qwen3-30B-A3B family scaling].

94L MoE: d 4096, 64 heads (GQA kv=4, head_dim 128), 128 routed experts
top-8 with expert d_ff 1536, vocab 151936.  The "big model" architecture
of the assignment (~235B params) — the transformer analogue of the
paper's 200B-variable LDA table; exercises FSDP+EP+TP sharding."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B (235B-A22B scaling)",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    head_dim=128,
    rope_theta=1_000_000.0,
    num_experts=128,
    num_experts_per_tok=8,
    norm="rms",
    tie_embeddings=False,
    subquadratic_decode=False,
)
