"""Model-parallel LDA engine package (DESIGN.md §2–§3).

Layout:

* ``state.py``    — :class:`MPState` (slot-queue per-worker state) plus
  layout construction, initialization, and gather/observation helpers;
* ``rounds.py``   — the shared per-(worker, round) sampling step and the
  sampler registry both backends draw from;
* ``backends.py`` — the two bit-identical execution backends
  (``vmap`` single-device batch, ``shard_map`` one-worker-per-device),
  generalized to the hybrid 2D ``(data, model)`` grid (DESIGN.md §8);
* ``reference.py`` — the FROZEN pre-2D 1D implementation, kept only as
  the bit-exactness anchor for ``tests/test_engine_2d.py``; harness-only,
  deliberately NOT re-exported here;
* ``api.py``      — the :class:`ModelParallelLDA` facade.

``repro.core.model_parallel`` re-exports the public names so pre-package
imports keep working.
"""
from repro.core.engine.api import ModelParallelLDA
from repro.core.engine.backends import (iteration_vmap,
                                        make_shard_map_iteration)
from repro.core.engine.rounds import (available_samplers,
                                      register_sampler,
                                      register_table_sampler,
                                      resolve_sampler,
                                      resolve_table_sampler, table_capable,
                                      worker_round, worker_round_tables)
from repro.core.engine.state import EngineLayout, MPState

__all__ = [
    "EngineLayout", "ModelParallelLDA", "MPState", "available_samplers",
    "iteration_vmap", "make_shard_map_iteration", "register_sampler",
    "register_table_sampler", "resolve_sampler", "resolve_table_sampler",
    "table_capable", "worker_round", "worker_round_tables",
]
