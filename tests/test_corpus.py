"""Corpus I/O and transforms: save/load stem normalization (the vocab
sidecar must survive BOTH call spellings), bigram augmentation semantics
(paper §5 Wiki-bigram: bigrams ADD to the vocabulary), and the holdout
split feeding the serving path."""
import numpy as np
import pytest

from repro.data.corpus import (Corpus, bigram_corpus, from_documents,
                               from_texts, load_corpus, save_corpus,
                               split_corpus)


def _vocab_corpus():
    return from_texts(["the cat sat", "the dog sat down", "cat dog"])


@pytest.mark.parametrize("save_ext,load_ext", [
    ("", ""), ("", ".npz"), (".npz", ""), (".npz", ".npz")])
def test_save_load_roundtrip_both_spellings(tmp_path, save_ext, load_ext):
    """save("foo") / save("foo.npz") x load("foo") / load("foo.npz") all
    address the same file pair — previously load("foo.npz") looked for
    foo.npz.vocab.json and silently dropped the vocabulary."""
    corpus = _vocab_corpus()
    assert corpus.vocab is not None
    stem = str(tmp_path / "corpus")
    save_corpus(corpus, stem + save_ext)
    out = load_corpus(stem + load_ext)
    np.testing.assert_array_equal(out.doc, corpus.doc)
    np.testing.assert_array_equal(out.word, corpus.word)
    assert out.num_docs == corpus.num_docs
    assert out.vocab_size == corpus.vocab_size
    assert out.vocab == corpus.vocab          # the sidecar survived
    out.validate()


def test_save_load_without_vocab(tmp_path):
    corpus = from_documents([[0, 1], [1, 2]], vocab_size=3)
    path = str(tmp_path / "novocab")
    save_corpus(corpus, path)
    out = load_corpus(path)
    assert out.vocab is None
    np.testing.assert_array_equal(out.word, corpus.word)


def test_bigram_augments_vocabulary():
    """Default mode keeps the unigrams and APPENDS offset bigram tokens:
    token count N + #intra-doc pairs, vocab V + #unique pairs."""
    corpus = from_documents([[0, 1, 2], [2, 0]], vocab_size=3)
    aug = bigram_corpus(corpus)
    # pairs: (0,1), (1,2) in doc 0, (2,0) in doc 1 — all unique
    assert aug.num_tokens == 5 + 3
    assert aug.vocab_size == 3 + 3
    assert aug.num_docs == 2
    aug.validate()
    # the unigram stream is intact (ids below V), bigrams sit above V
    uni = aug.word[aug.word < 3]
    big = aug.word[aug.word >= 3]
    assert uni.shape[0] == 5 and big.shape[0] == 3
    np.testing.assert_array_equal(np.sort(aug.doc[aug.word >= 3]), [0, 0, 1])
    # doc-major stream: sharding/invindex layers assume a flat doc stream
    assert (np.diff(aug.doc) >= 0).all()


def test_bigram_repeated_pairs_share_ids():
    corpus = from_documents([[0, 1, 0, 1]], vocab_size=2)
    aug = bigram_corpus(corpus)
    # pairs (0,1), (1,0), (0,1): 2 unique types, 3 bigram tokens
    assert aug.vocab_size == 2 + 2
    assert aug.num_tokens == 4 + 3
    assert (aug.word >= 2).sum() == 3


def test_bigram_vocab_strings_extended():
    corpus = _vocab_corpus()
    aug = bigram_corpus(corpus)
    assert aug.vocab is not None
    assert aug.vocab[:corpus.vocab_size] == corpus.vocab
    assert all("_" in w for w in aug.vocab[corpus.vocab_size:])
    assert len(aug.vocab) == aug.vocab_size


def test_bigram_replace_escape_hatch():
    """replace=True keeps the old semantics: bigram-only stream over a
    bigram-only vocabulary."""
    corpus = from_documents([[0, 1, 2], [2, 0]], vocab_size=3)
    rep = bigram_corpus(corpus, replace=True)
    assert rep.num_tokens == 3          # one token per intra-doc pair
    assert rep.vocab_size == 3          # unique pairs only
    assert rep.word.max() < 3
    rep.validate()


def test_split_corpus():
    corpus = from_documents([[0], [1, 2], [2], [0, 1], [1]], vocab_size=3)
    train, held = split_corpus(corpus, 2)
    assert train.num_docs == 3 and held.num_docs == 2
    assert train.num_tokens + held.num_tokens == corpus.num_tokens
    assert train.vocab_size == held.vocab_size == 3
    assert held.doc.min() == 0          # renumbered from zero
    train.validate()
    held.validate()
    words = held.doc_words()
    assert [list(w) for w in words] == [[0, 1], [1]]
    with pytest.raises(ValueError):
        split_corpus(corpus, 5)


def test_doc_words_roundtrip():
    docs = [[0, 2, 1], [1], [2, 2]]
    corpus = from_documents(docs, vocab_size=3)
    assert [list(w) for w in corpus.doc_words()] == docs


def _doc_words_loop(corpus):
    """The original O(N)-Python-loop implementation, kept as the
    regression reference for the vectorized ``Corpus.doc_words``."""
    out = [[] for _ in range(corpus.num_docs)]
    for d, w in zip(corpus.doc, corpus.word):
        out[d].append(int(w))
    return [np.asarray(ws, np.int32) for ws in out]


def test_doc_words_vectorized_bit_equals_loop():
    """argsort+split must reproduce the loop version exactly — including
    within-document stream order, empty documents, and non-doc-major
    streams (bigram_corpus interleaves before its final sort)."""
    rng = np.random.default_rng(11)
    num_docs, vocab = 37, 19
    # doc ids shuffled (NOT doc-major) with some docs absent entirely
    doc = rng.integers(0, num_docs, size=400).astype(np.int32)
    doc[doc == 5] = 6                     # doc 5 is empty
    word = rng.integers(0, vocab, size=400).astype(np.int32)
    corpus = Corpus(doc, word, num_docs, vocab)
    fast = corpus.doc_words()
    slow = _doc_words_loop(corpus)
    assert len(fast) == len(slow) == num_docs
    for f, s in zip(fast, slow):
        assert f.dtype == np.int32
        np.testing.assert_array_equal(f, s)
    assert fast[5].shape == (0,)


def test_doc_words_empty_corpus():
    corpus = Corpus(np.zeros(0, np.int32), np.zeros(0, np.int32), 3, 4)
    words = corpus.doc_words()
    assert len(words) == 3 and all(w.shape == (0,) for w in words)


def test_load_corpus_validates(tmp_path):
    """A corrupt archive must fail at load time, not deep inside the
    engine: here the stored vocab_size lies about the token stream."""
    corpus = from_documents([[0, 1], [2, 1]], vocab_size=3)
    path = str(tmp_path / "bad")
    np.savez_compressed(path + ".npz", doc=corpus.doc, word=corpus.word,
                        num_docs=corpus.num_docs, vocab_size=2)  # < max id
    with pytest.raises(ValueError, match="vocab_size"):
        load_corpus(path)


def test_load_corpus_rejects_non_corpus_archive(tmp_path):
    path = str(tmp_path / "notacorpus")
    np.savez_compressed(path + ".npz", foo=np.arange(3))
    with pytest.raises(ValueError, match="not a corpus archive"):
        load_corpus(path)


def test_load_corpus_closes_file_handle(tmp_path):
    """load_corpus must not leak the npz zip handle (the streaming
    trainer opens thousands of shard files per run)."""
    import gc

    corpus = from_documents([[0, 1], [1, 2]], vocab_size=3)
    path = str(tmp_path / "handle")
    save_corpus(corpus, path)
    before = _open_fd_count()
    for _ in range(8):
        load_corpus(path)
    gc.collect()
    assert _open_fd_count() <= before


def _open_fd_count() -> int:
    import os
    try:
        return len(os.listdir("/proc/self/fd"))
    except OSError:                        # non-Linux: best effort
        return 0
