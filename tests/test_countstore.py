"""Pluggable CountStore layer (DESIGN.md §16).

The claim under test is BITWISE STORE-INVARIANCE: the choice of model
storage — dense ``[Vb, K]`` blocks vs. the hybrid dense-head/sparse-tail
record — never changes a chain, only its memory layout.  Pillars:

1. **The store is an exact integer codec.**  ``from_dense``/``to_dense``,
   row reads, column sums, and the COO delta fold all round-trip int32
   counts exactly, including head-row promote/demote across the ``wcap``
   threshold; underflow (a corrupt delta stream) raises instead of
   wrapping.
2. **The tail-native sampler equals the dense sampler.**
   ``sweep_block_sparse_tail`` consumes the TailStore's lane layout with
   zero conversion and must equal ``sweep_block_sparse`` draw-for-draw
   (the batch-dim-invariant cumsum + masked-garbage-gather argument of
   §16) at geometries with many, one, and zero overflow rows.
3. **Store-invariance composes through every layer.**  Streaming
   tail == streaming dense (both the sparse store-native path and the
   scan densify path) == in-memory engine; checkpoints cross-resume in
   BOTH directions across formats (v1 dense record ↔ v2 store record);
   the host KV-store oracle under a tail-encoded store replays the
   engine; sharded snapshots round-trip through the row-restricted
   serving load.
4. **Persistence is §15-integrity-covered.**  Store records publish
   atomically with crc sidecars; a flipped bit or torn write surfaces
   through the taxonomy, never as silently-decoded garbage.

Plus the CLI satellites: the ``--store auto`` decision table (regime-map
derived) and the occupancy-aware ``memory_report``/``store_note``.
"""
import json
import os

import numpy as np
import pytest

from repro.core.engine import countstore
from repro.core.engine.countstore import (DEFAULT_TAIL_WCAP, DenseStore,
                                          TailStore, available_stores,
                                          resolve_store)
from repro.core.model_parallel import ModelParallelLDA
from repro.data.integrity import (CorruptArtifactError, TornWriteError,
                                  flip_byte, truncate_file)
from repro.data.stream import ShardedCorpus, shard_corpus

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _assert_chains_equal(a, b, ctx: str):
    sa, sb = a.gather_counts(), b.gather_counts()
    np.testing.assert_array_equal(np.asarray(sa.ckt), np.asarray(sb.ckt),
                                  err_msg=f"{ctx}: ckt diverged")
    np.testing.assert_array_equal(np.asarray(sa.cdk), np.asarray(sb.cdk),
                                  err_msg=f"{ctx}: cdk diverged")
    np.testing.assert_array_equal(np.asarray(sa.ck), np.asarray(sb.ck),
                                  err_msg=f"{ctx}: ck diverged")
    np.testing.assert_array_equal(a.assignments(), b.assignments(),
                                  err_msg=f"{ctx}: z diverged")


def _zipf_dense(vb, k, wcap, seed=0, heads=3):
    """A [vb, k] count block with a few heavy rows (nnz > wcap) and a
    long tail of light rows — the §16 working regime."""
    rng = np.random.default_rng(seed)
    dense = np.zeros((vb, k), np.int32)
    for r in range(vb):
        nnz = min(k, 2 * wcap if r < heads else rng.integers(0, wcap + 1))
        cols = rng.choice(k, size=nnz, replace=False)
        dense[r, cols] = rng.integers(1, 9, size=nnz)
    return dense


# ---------------------------------------------------------------------------
# (1) the store as an exact integer codec
# ---------------------------------------------------------------------------

def test_registry_and_defaults():
    assert available_stores() == ["dense", "tail"]
    assert resolve_store("dense") is DenseStore
    assert resolve_store("tail") is TailStore
    with pytest.raises(ValueError, match="unknown store"):
        resolve_store("bogus")
    # the store's head/tail threshold and the sparse sampler's must be
    # the same number, or the lane layouts disagree silently
    from repro.core.sparse_device import DEFAULT_WCAP
    assert DEFAULT_TAIL_WCAP == DEFAULT_WCAP


@pytest.mark.parametrize("kind", ["dense", "tail"])
def test_roundtrip_rows_colsums(kind):
    dense = _zipf_dense(24, 32, wcap=6, seed=1)
    st = resolve_store(kind).from_dense(dense, wcap=6)
    assert st.shape == (24, 32)
    np.testing.assert_array_equal(st.to_dense(), dense)
    idx = np.array([0, 3, 3, 23, 7])
    np.testing.assert_array_equal(st.rows(idx), dense[idx])
    np.testing.assert_array_equal(st.col_sums(),
                                  dense.sum(axis=0, dtype=np.int64))
    occ = st.occupancy()
    assert occ["kind"] == kind and occ["rows"] == 24
    assert st.nbytes_resident() == occ["nbytes_resident"] > 0
    if kind == "tail":
        assert occ["overflow_rows"] == 3         # the planted heavy rows
        assert occ["head_rows"] + occ["tail_rows"] == 24


def test_tail_apply_coo_promote_demote_underflow():
    wcap = 4
    dense = _zipf_dense(12, 16, wcap=wcap, seed=2)
    st = TailStore.from_dense(dense, wcap=wcap)
    # promote: pile counts onto a light row until nnz > wcap
    light = int(np.argmin((dense > 0).sum(axis=1)))
    rows = np.full(wcap + 2, light)
    topics = np.arange(wcap + 2)
    st.apply_coo(rows, topics, np.ones(wcap + 2, np.int64))
    dense[light, :wcap + 2] += 1
    np.testing.assert_array_equal(st.to_dense(), dense)
    assert light in set(np.asarray(st.over_rows).tolist())
    # demote: drain a heavy row back under the threshold
    heavy = 0
    cols = np.nonzero(dense[heavy])[0]
    drain = cols[wcap - 1:]
    st.apply_coo(np.full(drain.size, heavy), drain,
                 -dense[heavy, drain].astype(np.int64))
    dense[heavy, drain] = 0
    np.testing.assert_array_equal(st.to_dense(), dense)
    assert heavy not in set(np.asarray(st.over_rows).tolist())
    # a delta stream that would go negative is corrupt — raise, don't wrap
    with pytest.raises(ValueError, match="underflow"):
        st.apply_coo(np.array([light]), np.array([0]),
                     np.array([-10 ** 6]))


@pytest.mark.parametrize("kind", ["dense", "tail"])
def test_apply_token_delta_matches_dense_fold(kind):
    rng = np.random.default_rng(3)
    dense = _zipf_dense(10, 12, wcap=3, seed=3) + 5   # every topic legal
    st = resolve_store(kind).from_dense(dense, wcap=3)
    n = 40
    rows = rng.integers(0, 10, n).astype(np.int32)
    z_old = rng.integers(0, 12, n).astype(np.int32)
    z_new = rng.integers(0, 12, n).astype(np.int32)
    st.apply_token_delta(rows, z_old, z_new)
    np.add.at(dense, (rows, z_old), -1)
    np.add.at(dense, (rows, z_new), 1)
    np.testing.assert_array_equal(st.to_dense(), dense)


# ---------------------------------------------------------------------------
# (4) persistence: record format + §15 integrity taxonomy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["dense", "tail"])
def test_save_load_dispatch_and_artifact_swap(kind, tmp_path):
    dense = _zipf_dense(8, 16, wcap=4, seed=4)
    stem = str(tmp_path / "block_00000")
    st = resolve_store(kind).from_dense(dense, wcap=4)
    st.save(stem)
    ext = ".npy" if kind == "dense" else ".npz"
    assert os.path.exists(stem + ext)
    assert countstore.exists(stem)
    back = countstore.load(stem)
    assert type(back) is type(st)
    np.testing.assert_array_equal(back.to_dense(), dense)
    # re-saving under the OTHER kind must retire the old artifact, so a
    # stem never holds two decodable generations at once
    other = "tail" if kind == "dense" else "dense"
    resolve_store(other).from_dense(dense, wcap=4).save(stem)
    assert not os.path.exists(stem + ext)
    assert type(countstore.load(stem)) is resolve_store(other)
    np.testing.assert_array_equal(countstore.load(stem).to_dense(), dense)


def test_dense_store_file_is_plain_npy(tmp_path):
    """Backward compat: a DenseStore block file is byte-identical to the
    pre-§16 raw ``integrity.save_npy`` block file, so old workdirs load
    and dense-store runs write the frozen format."""
    from repro.data import integrity
    dense = _zipf_dense(8, 16, wcap=4, seed=5)
    a = str(tmp_path / "a")
    b = str(tmp_path / "b")
    DenseStore.from_dense(dense, wcap=4).save(a)
    integrity.save_npy(b + ".npy", dense)
    with open(a + ".npy", "rb") as fa, open(b + ".npy", "rb") as fb:
        assert fa.read() == fb.read()
    np.testing.assert_array_equal(countstore.load(b).to_dense(), dense)


def test_missing_and_corrupt_records(tmp_path):
    from repro.data.integrity import MissingArtifactError
    stem = str(tmp_path / "block_00000")
    with pytest.raises(MissingArtifactError):
        countstore.load(stem)
    dense = _zipf_dense(8, 16, wcap=4, seed=6)
    TailStore.from_dense(dense, wcap=4).save(stem)
    # bit-flip -> checksum mismatch
    flip_byte(stem + ".npz", seed=1)
    with pytest.raises(CorruptArtifactError):
        countstore.load(stem)
    # torn write -> truncation class
    TailStore.from_dense(dense, wcap=4).save(stem)
    truncate_file(stem + ".npz", os.path.getsize(stem + ".npz") // 2)
    with pytest.raises(TornWriteError):
        countstore.load(stem)


def test_pack_unpack_record():
    dense = _zipf_dense(8, 16, wcap=4, seed=7)
    st = TailStore.from_dense(dense, wcap=4)
    aux, arrays = st.pack()
    assert aux["kind"] == "tail"
    # aux must be JSON-clean (it rides checkpoint config channels)
    aux2 = json.loads(json.dumps(aux))
    back = countstore.unpack_record(
        aux2, {k: np.asarray(v) for k, v in arrays.items()})
    np.testing.assert_array_equal(back.to_dense(), dense)


# ---------------------------------------------------------------------------
# (2) tail-native sampler == dense sampler, draw-for-draw
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("vb,k,wcap,heads", [
    (32, 64, 8, 16),    # many overflow rows
    (16, 128, 32, 1),   # exactly one
    (8, 16, 8, 0),      # none — pure tail
])
def test_tail_sweep_bitwise_equals_dense_sweep(vb, k, wcap, heads):
    import jax.numpy as jnp

    from repro.core.sparse_device import (sweep_block_sparse,
                                          sweep_block_sparse_tail)
    rng = np.random.default_rng(8)
    ckt = _zipf_dense(vb, k, wcap=wcap, seed=8, heads=heads)
    if heads == 0:      # clamp every row under the threshold
        keep = np.argsort(ckt, axis=1)[:, -wcap:]
        m = np.zeros_like(ckt, bool)
        np.put_along_axis(m, keep, True, axis=1)
        ckt = np.where(m, ckt, 0).astype(np.int32)
    n, dloc, dcap = 96, 6, 32
    doc = rng.integers(0, dloc, n).astype(np.int32)
    woff = rng.integers(0, vb, n).astype(np.int32)
    mask = rng.random(n) < 0.9
    # z consistent with the frozen block: every token's topic has count
    z = np.zeros(n, np.int32)
    for i in range(n):
        cols = np.nonzero(ckt[woff[i]])[0]
        z[i] = cols[rng.integers(0, cols.size)] if cols.size \
            else rng.integers(0, k)
        ckt[woff[i], z[i]] += 1
    cdk = np.zeros((dloc, k), np.int32)
    np.add.at(cdk, (doc[mask], z[mask]), 1)
    ck = ckt.sum(axis=0).astype(np.int32)
    u = rng.random(n).astype(np.float32)
    alpha = np.full(k, 0.1, np.float32)
    beta, vbeta = np.float32(0.01), np.float32(0.01 * vb)

    d_out = sweep_block_sparse(
        jnp.asarray(cdk), jnp.asarray(ckt), jnp.asarray(ck),
        jnp.asarray(doc), jnp.asarray(woff), jnp.asarray(z),
        jnp.asarray(mask), jnp.asarray(u), jnp.asarray(alpha),
        beta, vbeta, dcap=dcap, wcap=wcap)

    st = TailStore.from_dense(ckt, wcap=wcap)
    dev = st.device_operands()
    t_out = sweep_block_sparse_tail(
        jnp.asarray(cdk), jnp.asarray(dev["tail_topics"]),
        jnp.asarray(dev["tail_counts"]), jnp.asarray(dev["over_pad"]),
        jnp.asarray(dev["row_map"]), jnp.asarray(ck),
        jnp.asarray(doc), jnp.asarray(woff), jnp.asarray(z),
        jnp.asarray(mask), jnp.asarray(u), jnp.asarray(alpha),
        beta, vbeta, dcap=dcap)

    np.testing.assert_array_equal(np.asarray(t_out[2]),
                                  np.asarray(d_out[3]),
                                  err_msg="z diverged")
    np.testing.assert_array_equal(np.asarray(t_out[0]),
                                  np.asarray(d_out[0]),
                                  err_msg="cdk diverged")
    np.testing.assert_array_equal(np.asarray(t_out[1]),
                                  np.asarray(d_out[2]),
                                  err_msg="ck diverged")
    # the store-side token fold reproduces the dense sampler's block
    z_new = np.asarray(t_out[2])
    st.apply_token_delta(woff[mask], z[mask], z_new[mask])
    np.testing.assert_array_equal(st.to_dense(), np.asarray(d_out[1]),
                                  err_msg="store fold != dense block")


# ---------------------------------------------------------------------------
# (3) store-invariance through the engines
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def zipf_sharded(tmp_path_factory):
    from repro.data.synthetic import synthetic_corpus
    corpus, _, _ = synthetic_corpus(num_docs=32, vocab_size=96,
                                    num_topics=4, doc_len=24, seed=9)
    out = str(tmp_path_factory.mktemp("cs_sharded") / "corpus")
    shard_corpus(corpus, out, num_shards=2)
    return corpus, ShardedCorpus(out)


@pytest.mark.parametrize("mode", ["sparse", "scan"])
def test_streaming_tail_equals_dense(zipf_sharded, tmp_path, mode):
    """sparse = the store-native lane path; scan = the explicit
    ``to_dense`` escape hatch.  Both must be chain-invariant."""
    from repro.core.engine.streaming import StreamingLDA
    _, sc = zipf_sharded
    kw = dict(num_topics=16, num_workers=2, seed=13, sampler_mode=mode,
              blocks_per_worker=2)
    if mode == "sparse":
        kw["sampler_args"] = (("dcap", 32), ("wcap", 8))
    a = StreamingLDA(sc, str(tmp_path / "dense"), store="dense", **kw)
    b = StreamingLDA(sc, str(tmp_path / "tail"), store="tail", **kw)
    for _ in range(2):
        a.step()
        b.step()
    _assert_chains_equal(a, b, f"streaming tail vs dense ({mode})")
    assert a._rng.bit_generator.state == b._rng.bit_generator.state
    # densification is never silent: native path has no note, the
    # escape hatch names its per-round [Vb, K] cost
    if mode == "sparse":
        assert b.store_note() is None
        assert any(f.endswith(".npz")
                   for f in os.listdir(tmp_path / "tail" / "state"
                                       / "blocks"))
    else:
        assert "densifies" in b.store_note()
    assert a.store_note() is None
    rep = b.memory_report()
    assert rep["store"] == "tail"
    occ = rep["store_occupancy"]
    assert occ["head_rows"] + occ["tail_rows"] \
        == b.num_blocks * b.partition.block_size
    assert rep["resident_store_bytes"] > 0
    # legacy dense-model accounting is untouched
    assert rep["resident_block_bytes"] * b.num_blocks \
        >= rep["total_model_bytes"]


def test_streaming_tail_equals_in_memory(zipf_sharded, tmp_path):
    from repro.core.engine.streaming import StreamingLDA
    corpus, sc = zipf_sharded
    args = (("dcap", 32), ("wcap", 8))
    mem = ModelParallelLDA(corpus, num_topics=16, num_workers=2, seed=17,
                           sampler_mode="sparse", sampler_args=args,
                           store="tail")
    disk = StreamingLDA(sc, str(tmp_path / "run"), num_topics=16,
                        num_workers=2, seed=17, sampler_mode="sparse",
                        sampler_args=args, store="tail")
    for _ in range(2):
        mem.step()
        disk.step()
    _assert_chains_equal(mem, disk, "streaming tail vs in-memory tail")


def test_streaming_cross_store_resume(zipf_sharded, tmp_path):
    """A checkpoint written under one store resumes bitwise under the
    other — count encode/decode is an exact integer round-trip, so the
    chain cannot tell its blocks were re-encoded."""
    from repro.core.engine.streaming import StreamingLDA
    _, sc = zipf_sharded
    kw = dict(num_topics=16, num_workers=2, seed=19,
              sampler_mode="sparse", blocks_per_worker=2,
              sampler_args=(("dcap", 32), ("wcap", 8)))
    ref = StreamingLDA(sc, str(tmp_path / "ref"), store="dense", **kw)
    for _ in range(4):
        ref.step()
    for src, dst in (("tail", "dense"), ("dense", "tail")):
        wd = str(tmp_path / f"{src}2{dst}")
        a = StreamingLDA(sc, wd, store=src, **kw)
        a.step()
        a.step()
        a.save_checkpoint()
        b = StreamingLDA.resume(wd, store=dst)
        assert b.store_kind == dst
        cfg = json.load(open(os.path.join(wd, "run.json")))
        assert cfg["store"] == dst      # the switch is durable
        b.step()
        b.step()
        _assert_chains_equal(ref, b, f"resume {src}->{dst}")
        assert ref._rng.bit_generator.state == b._rng.bit_generator.state


def test_mp_engine_cross_store_checkpoint(zipf_sharded, tmp_path):
    """In-memory engine: dense writes the bitwise-frozen v1 record, tail
    the v2 per-slot store record; each resumes under the other store and
    continues the identical chain."""
    corpus, _ = zipf_sharded
    kw = dict(num_topics=16, num_workers=2, blocks_per_worker=2, seed=23,
              sampler_mode="sparse",
              sampler_args=(("dcap", 32), ("wcap", 8)))
    ref = ModelParallelLDA(corpus, store="dense", **kw)
    ref.run(4)
    for src, dst in (("tail", "dense"), ("dense", "tail")):
        a = ModelParallelLDA(corpus, store=src, **kw)
        a.run(2)
        p = a.save_checkpoint(str(tmp_path / f"ck_{src}"))
        data = np.load(p)
        cfg = json.loads(bytes(data["config"]).decode())
        if src == "dense":
            assert cfg["format"] == ModelParallelLDA.CKPT_FORMAT
            assert "ckt" in data.files       # v1 record frozen
        else:
            assert cfg["format"] == ModelParallelLDA.CKPT_FORMAT_V2
            assert "ckt" not in data.files
            assert "store_aux" in data.files
        b = ModelParallelLDA.resume(corpus, p, store=dst)
        assert b.store_kind == dst
        b.run(2)
        _assert_chains_equal(ref, b, f"mp resume {src}->{dst}")


def test_sharded_snapshot_v2_roundtrip(zipf_sharded, tmp_path):
    """Tail runs export ``sharded-snapshot-v2``; the row-restricted
    serving load decodes exactly the rows a batch touches and matches
    the dense run's v1 export bit-for-bit."""
    from repro.core.engine.streaming import StreamingLDA
    from repro.core.infer import (load_sharded_snapshot_meta,
                                  load_snapshot_rows)
    _, sc = zipf_sharded
    kw = dict(num_topics=16, num_workers=2, seed=29,
              sampler_mode="sparse",
              sampler_args=(("dcap", 32), ("wcap", 8)))
    snaps = {}
    for kind in ("dense", "tail"):
        lda = StreamingLDA(sc, str(tmp_path / f"run_{kind}"),
                           store=kind, **kw)
        lda.step()
        out = str(tmp_path / f"snap_{kind}")
        lda.save_snapshot_sharded(out)
        snaps[kind] = out
    m1 = load_sharded_snapshot_meta(snaps["dense"])
    m2 = load_sharded_snapshot_meta(snaps["tail"])
    assert m1["format"] == "sharded-snapshot-v1"     # frozen
    assert m2["format"] == "sharded-snapshot-v2"
    assert (m1["store"], m2["store"]) == ("dense", "tail")
    words = np.array([0, 5, 5, 91, 44, 17], np.int32)
    s1, r1 = load_snapshot_rows(snaps["dense"], words)
    s2, r2 = load_snapshot_rows(snaps["tail"], words)
    np.testing.assert_array_equal(r1, r2)
    np.testing.assert_array_equal(s1.ckt, s2.ckt)
    np.testing.assert_array_equal(s1.ck, s2.ck)


def test_kvstore_oracle_tail_equals_dense(zipf_sharded):
    """The host oracle is the §16 numpy mirror: a tail-encoded KV store
    replays the dense one draw-for-draw, and both replay the engine."""
    from repro.core.kvstore import HostModelParallelLDA
    corpus, _ = zipf_sharded
    kw = dict(num_topics=16, num_workers=2, blocks_per_worker=2, seed=31,
              sampler_args=(("dcap", 32), ("wcap", 8)))
    hd = HostModelParallelLDA(corpus, sampler="sparse", ck_sync="round",
                              store="dense", **kw)
    ht = HostModelParallelLDA(corpus, sampler="sparse", ck_sync="round",
                              store="tail", **kw)
    eng = ModelParallelLDA(corpus, sampler_mode="sparse", store="tail",
                           **kw)
    for _ in range(2):
        hd.step()
        ht.step()
    eng.run(2)
    np.testing.assert_array_equal(hd.assignments(), ht.assignments())
    np.testing.assert_array_equal(hd.gather_ckt(), ht.gather_ckt())
    np.testing.assert_array_equal(ht.assignments(), eng.assignments())
    # logical dense traffic (the §3.2 cost model) is encoding-invariant
    assert hd.store.bytes_moved == ht.store.bytes_moved
    assert ht.store.resident_bytes() > 0


# ---------------------------------------------------------------------------
# CLI satellites: --store auto decision table, config-echo notes
# ---------------------------------------------------------------------------

def test_resolve_store_choice_decision_table():
    from repro.launch.samplers import (REGIME_MAP, resolve_store_choice,
                                       store_choices)
    assert store_choices() == ["dense", "tail", "auto"]
    assert resolve_store_choice("dense") == "dense"
    assert resolve_store_choice("tail") == "tail"
    # auto == tail exactly where the regime map picks the sparse family
    for (k, dl), fam in REGIME_MAP.items():
        got = resolve_store_choice("auto", num_topics=k, max_doc_len=dl)
        assert got == ("tail" if fam == "sparse" else "dense"), (k, dl)
    # unknown workload (no corpus yet) -> the conservative default
    assert resolve_store_choice("auto") == "dense"
    with pytest.raises(SystemExit, match="unknown store"):
        resolve_store_choice("bogus")


def test_mp_engine_store_note_and_report(zipf_sharded):
    corpus, _ = zipf_sharded
    d = ModelParallelLDA(corpus, num_topics=16, num_workers=2, seed=1)
    t = ModelParallelLDA(corpus, num_topics=16, num_workers=2, seed=1,
                         store="tail")
    assert d.store_note() is None
    assert "dense device chain" in t.store_note()
    rep = t.memory_report()
    assert rep["store"] == "tail"
    occ = rep["store_occupancy"]
    assert occ["head_rows"] + occ["tail_rows"] > 0
    assert rep["total_store_bytes"] > 0
    assert "store_occupancy" not in d.memory_report()
