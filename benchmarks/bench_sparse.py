"""Sparse-sampler regime map: tokens/sec over K × doc_len on a Zipf tail.

    PYTHONPATH=src python -m benchmarks.bench_sparse [--smoke]

`bench_samplers.py` established the scan/batched/mh trajectory on a
uniform workload.  This benchmark maps WHERE the hybrid sparse sampler
(ISSUE 6, DESIGN.md §12) wins: its per-token cost is
O(nnz_word + nnz_doc + log K) instead of scan's O(K) or the MH pair's
O(1)-after-a-O((Vb + D_loc)·K)-table-build, so it should take the
long-tail corner — large K, short docs, Zipf word frequencies — and
lose the corner where docs are long relative to K (doc lanes degenerate
toward dense).

Workload: word slots drawn Zipf(1.1) over the block's Vb rows, so most
``ckt`` rows are tail-sparse while the head rows overflow ``wcap`` and
exercise the dense-head fallback; docs are exactly ``doc_len`` tokens,
making ``dcap = min(K, doc_len)`` the tight per-doc bound.  All three
samplers are timed on the identical (counts, tokens, uniforms) inputs;
``mh`` is the round-lifetime form (registry default — builds its alias
tables inside the timed call, exactly what a per-round schedule pays).

Acceptance bar: at least one grid cell — expected at the largest K and
shortest docs — where ``sparse`` beats BOTH ``scan`` and ``mh`` in
tokens/s (``sparse_wins_regime`` non-empty).  Results land in
``benchmarks/results/bench_sparse.json`` and fold into the repo-root
``BENCH_e2e.json`` digest via `benchmarks.run` / `bench_e2e.aggregate_root`.
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from benchmarks.bench_samplers import _time_sampler
from benchmarks.common import emit_csv_row, save_result
from repro.core.engine.rounds import resolve_sampler
from repro.core.sparse_device import default_sparse_args

SAMPLERS = ("scan", "mh", "sparse")

# grid: model size × doc shape.  T is held fixed so tokens/s is
# comparable across cells; doc_len repartitions the same token budget
# into many short docs (sparse's regime) or few long ones (dense's).
FULL = dict(k_sweep=(256, 4096, 16384), len_sweep=(16, 48, 256),
            vb=64, tokens=8192, zipf=1.1)
SMOKE = dict(k_sweep=(256,), len_sweep=(16,),
             vb=32, tokens=512, zipf=1.1)


def _zipf_workload(k: int, doc_len: int, vb: int, tokens: int,
                   zipf: float, seed: int = 0):
    """One block's workload with a long-tail word-frequency profile."""
    rng = np.random.default_rng(seed)
    dloc = tokens // doc_len
    tokens = dloc * doc_len     # whole docs only; cells stay comparable
    # every doc holds exactly doc_len tokens, so dcap = min(K, doc_len)
    # is a TIGHT correctness bound (per-doc nnz <= token count)
    doc = np.repeat(np.arange(dloc, dtype=np.int32), doc_len)
    w = rng.choice(vb, size=tokens,
                   p=(p := 1.0 / np.arange(1, vb + 1) ** zipf) / p.sum())
    woff = np.sort(w).astype(np.int32)
    z = rng.integers(0, k, tokens).astype(np.int32)
    cdk = np.zeros((dloc, k), np.int32)
    ckt = np.zeros((vb, k), np.int32)
    np.add.at(cdk, (doc, z), 1)
    np.add.at(ckt, (woff, z), 1)
    u = rng.random(tokens, np.float32)
    return (jnp.asarray(cdk), jnp.asarray(ckt),
            jnp.asarray(ckt.sum(0).astype(np.int32)),
            jnp.asarray(doc), jnp.asarray(woff), jnp.asarray(z),
            jnp.ones(tokens, bool), jnp.asarray(u),
            jnp.full(k, 0.1, jnp.float32), jnp.float32(0.01),
            jnp.float32(0.01 * vb))


def run(smoke: bool = False, seed: int = 0) -> dict:
    cfg = SMOKE if smoke else FULL
    t = cfg["tokens"]
    out = {"mode": "smoke" if smoke else "full",
           "workload": {"vb": cfg["vb"], "tokens": t, "zipf": cfg["zipf"]},
           "k_sweep": list(cfg["k_sweep"]),
           "len_sweep": list(cfg["len_sweep"]), "results": {}}
    wins = []
    for k in cfg["k_sweep"]:
        for doc_len in cfg["len_sweep"]:
            args = _zipf_workload(k, doc_len, cfg["vb"], t,
                                  cfg["zipf"], seed)
            tc = (t // doc_len) * doc_len      # whole-doc token count
            cell = f"k{k}_len{doc_len}"
            rec = {"tokens": tc}
            for mode in SAMPLERS:
                sargs = (default_sparse_args(k, doc_len)
                         if mode == "sparse" else ())
                fn = resolve_sampler(mode, sargs)
                repeats = 1 if (smoke or mode == "scan") else 3
                sec = _time_sampler(fn, args, repeats)
                rec[mode] = {"sec_per_block": sec, "tokens_per_s": tc / sec}
                emit_csv_row(f"sparse_{mode}_{cell}", sec * 1e6,
                             f"tokens_per_s={tc / sec:.0f}")
            rec["fastest"] = max(SAMPLERS,
                                 key=lambda m: rec[m]["tokens_per_s"])
            if rec["fastest"] == "sparse":
                wins.append(cell)
            out["results"][cell] = rec
    out["sparse_wins_regime"] = wins
    out["sparse_wins_somewhere"] = bool(wins)
    save_result("bench_sparse_smoke" if smoke else "bench_sparse", out)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single tiny cell for CI; results kept separate "
                         "from the recorded trajectory")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    res = run(smoke=args.smoke)
    for cell, rec in res["results"].items():
        print(f"# {cell}: fastest={rec['fastest']} "
              + " ".join(f"{m}={rec[m]['tokens_per_s']:.0f}tok/s"
                         for m in SAMPLERS))
    print(f"# sparse wins in: {res['sparse_wins_regime'] or 'NONE'}")


if __name__ == "__main__":
    main()
