"""Paper Figure 2: convergence of model-parallel vs data-parallel LDA,
per iteration and per wall-clock second.

The paper's claim: MP reaches a given likelihood in fewer iterations (and
less time) than the stale-sync DP baseline because every round samples from
exact word-topic counts.
"""
from __future__ import annotations

import time

from benchmarks.common import emit_csv_row, save_result
from repro.core.data_parallel import DataParallelLDA
from repro.core.model_parallel import ModelParallelLDA
from repro.data.synthetic import synthetic_corpus


def run(num_docs=300, vocab=1200, topics=32, doc_len=60, workers=8,
        iters=15, seed=0):
    corpus, _, _ = synthetic_corpus(num_docs, vocab, topics, doc_len,
                                    seed=seed)
    out = {"config": {"docs": num_docs, "vocab": vocab, "topics": topics,
                      "tokens": corpus.num_tokens, "workers": workers}}
    for name, engine in [
            ("model_parallel", ModelParallelLDA(corpus, topics, workers,
                                                seed=seed)),
            ("data_parallel", DataParallelLDA(corpus, topics, workers,
                                              seed=seed))]:
        hist = []
        t0 = time.time()
        for it in range(iters):
            engine.step()
            hist.append({"iteration": it + 1,
                         "log_likelihood": engine.log_likelihood(),
                         "elapsed_s": time.time() - t0})
        out[name] = hist
    mp_ll = [h["log_likelihood"] for h in out["model_parallel"]]
    dp_ll = [h["log_likelihood"] for h in out["data_parallel"]]
    wins = sum(a >= b for a, b in zip(mp_ll, dp_ll))
    out["mp_wins_per_iteration"] = wins
    # iterations to reach DP's final likelihood
    target = dp_ll[-1]
    mp_iters_to_target = next((i + 1 for i, v in enumerate(mp_ll)
                               if v >= target), iters)
    out["mp_iters_to_dp_final"] = mp_iters_to_target
    out["dp_iters"] = iters
    save_result("fig2_convergence", out)
    t_per_iter = out["model_parallel"][-1]["elapsed_s"] / iters * 1e6
    emit_csv_row("fig2_convergence_mp", t_per_iter,
                 f"mp_wins={wins}/{iters};mp_iters_to_dp_final="
                 f"{mp_iters_to_target}/{iters}")
    return out


if __name__ == "__main__":
    run()
