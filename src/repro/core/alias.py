"""Vose alias tables — O(1) categorical draws for the MH sampler backend.

LightLDA (Yuan et al. 2014) makes the per-token cost of collapsed Gibbs
O(1) amortized by replacing the exact inverse-CDF draw over K topics with
a Metropolis–Hastings proposal drawn from an *alias table*: per-topic
arrays such that a single uniform yields an exact sample of the table's
distribution in two lookups (Walker 1977; Vose 1991).  Construction is
O(K), done once per *block* per round and amortized over every token that
samples against the block — the same build-once/consume-many shape as the
paper's eq.-(3) word-major cache.

**Determinism is load-bearing.**  The same table must be built bit-for-bit
by every compilation of the sampler — the vmap engine, the shard_map
engine, and the standalone host-oracle kernel — or MH replay stops being
draw-for-draw.  Plain f32 construction (sum → divide → compare against
1.0) is NOT stable across XLA programs: reductions and divisions lower
differently under different fusion, and a 1-ulp disagreement flips a
small/large classification into a different (still valid) table.  The
device builder therefore works on a fixed-point integer grid:

* masses are ``W_i = C_i·SCALE + max(round(prior_i·SCALE), 1)`` — pure
  int32 arithmetic (counts are ints; the prior is quantized once);
* the per-cell capacity is the INTEGER row total ``ΣW`` (masses are kept
  scaled by K, so no division ever happens);
* every fp value that feeds a decision is produced by a single IEEE op
  on integer-derived operands (one convert, one multiply, one add/sub) —
  nothing XLA can reassociate, recompute, or turn into a reciprocal.

Quantizing the prior perturbs only the *proposal*; the MH acceptance
(`core/mh.py`) evaluates the proposal mass from the same ``W`` grid and
the *target* from the unquantized counts, so the chain still targets the
exact eq.-(1) posterior (any proposal with full support is admissible).

Table encoding — row total ``U = f32(ΣW)``, per-cell ``cut``/``alias``:
cell ``j`` yields ``j`` when ``frac·U < cut[j]`` else ``alias[j]``, where
``frac`` is the within-cell uniform.  A full cell has ``cut = U`` and
``alias = j``.  The draw spends ONE uniform: the integer part of ``u·K``
picks the cell, the fractional part is the within-cell threshold (the
standard single-uniform alias trick).

:func:`build_alias_int_np` mirrors the device builder op-for-op in
numpy (same f32 single-op chains, same LIFO stack discipline) and is
asserted bit-equal by tests; :func:`build_alias_np` is the classic
float construction kept as the property-test reference for the pairing
logic itself.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# fixed-point grid for prior quantization: β/α enter proposal masses in
# units of 1/SCALE (target masses stay exact — see module docstring)
SCALE = 256


# ---------------------------------------------------------------------------
# Classic float Vose construction (numpy reference for property tests)
# ---------------------------------------------------------------------------

def build_alias_np(p: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Vose construction: ``p`` [K] nonnegative -> (prob [K] f32, alias [K]).

    Cell ``j`` holds mass ``prob[j]`` of topic ``j`` and ``1 - prob[j]`` of
    topic ``alias[j]`` (in units of ``sum(p)/K``); a zero-sum input yields
    the uniform table.
    """
    p = np.asarray(p, np.float32)
    k = p.shape[0]
    prob = np.ones(k, np.float32)
    alias = np.arange(k, dtype=np.int32)
    total = np.float32(p.sum(dtype=np.float64))
    if not total > 0:
        return prob, alias
    scaled = (p * (np.float32(k) / total)).astype(np.float32)
    small = [i for i in range(k) if scaled[i] < 1.0]
    large = [i for i in range(k) if scaled[i] >= 1.0]
    while small and large:
        s = small.pop()
        lg = large.pop()
        prob[s] = scaled[s]
        alias[s] = lg
        scaled[lg] = (scaled[lg] + scaled[s]) - np.float32(1.0)
        (small if scaled[lg] < 1.0 else large).append(lg)
    for i in small:          # fp residue: treat as full cells
        prob[i] = 1.0
    for i in large:
        prob[i] = 1.0
    return prob, alias


def alias_draw_np(prob: np.ndarray, alias: np.ndarray,
                  u: np.ndarray) -> np.ndarray:
    """Single-uniform draw from a :func:`build_alias_np` table."""
    k = prob.shape[0]
    x = np.asarray(u, np.float32) * np.float32(k)
    j = np.minimum(x.astype(np.int32), k - 1)
    frac = x - j.astype(np.float32)
    return np.where(frac < prob[j], j, alias[j]).astype(np.int32)


def alias_cell_masses(prob: np.ndarray, alias: np.ndarray,
                      total: float) -> np.ndarray:
    """Reconstruct the distribution a (prob, alias) table encodes: topic
    ``t`` receives ``prob[t]`` from its own cell plus ``1 - prob[j]`` from
    every cell aliased to it, in units of ``total / K``."""
    k = prob.shape[0]
    unit = np.float64(total) / k
    mass = prob.astype(np.float64) * unit
    np.add.at(mass, alias, (1.0 - prob.astype(np.float64)) * unit)
    return mass


# ---------------------------------------------------------------------------
# Fixed-point quantization shared by device builder and numpy mirror
# ---------------------------------------------------------------------------

def quantize_prior_np(prior: np.ndarray) -> np.ndarray:
    """Prior -> integer grid units: ``max(round(prior·SCALE), 1)``.

    The floor of 1 keeps every topic proposable (support ⊇ target), which
    MH needs for ergodicity; the acceptance uses these same quantized
    masses so no bias is introduced.
    """
    q = np.round(np.asarray(prior, np.float32) * np.float32(SCALE))
    return np.maximum(q, 1.0).astype(np.int32)


def _quantize_prior(prior: jax.Array) -> jax.Array:
    q = jnp.round(prior.astype(jnp.float32) * jnp.float32(SCALE))
    return jnp.maximum(q, 1.0).astype(jnp.int32)


def int_masses(counts: jax.Array, prior: jax.Array) -> jax.Array:
    """[..., K] int32 proposal masses ``W = C·SCALE + quantized prior``.

    Headroom: the binding constraint is the int32 ROW SUM ``ΣW`` (it
    becomes the table's cell capacity in :func:`build_alias_int_rows`),
    so a table row tolerates ``≈ 2³¹/SCALE ≈ 8.4M`` TOTAL tokens — a
    per-(worker, block) row count, bounded by one worker's share of one
    vocabulary block's postings (or one local doc's length), orders of
    magnitude below the limit at any geometry this engine runs.
    """
    return counts.astype(jnp.int32) * SCALE + _quantize_prior(prior)


def int_masses_np(counts: np.ndarray, prior: np.ndarray) -> np.ndarray:
    return (np.asarray(counts, np.int64) * SCALE
            + quantize_prior_np(prior)).astype(np.int32)


# ---------------------------------------------------------------------------
# Device (JAX) construction — integer-exact decisions, fixed-shape scan
# ---------------------------------------------------------------------------

@jax.jit
def build_alias_int_rows(w: jax.Array
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Vose tables from integer masses ``w`` [N, K] -> (cut, alias, U).

    Works in masses-scaled-by-K units: ``m_i = f32(w_i)·K`` and the
    per-cell capacity is ``U = f32(Σw)`` (an exact int32 reduction, so U
    is bit-identical in every program).  Each scan step pops one small
    and one large cell per row (a no-op once either stack empties) — at
    most K-1 pairings, so K steps suffice.  Every fp decision input is
    one IEEE op away from integers; see the module docstring for why
    that is the point.

    Layout choices are all about making the K-step loop cheap and
    shard_map-safe:

    * rows are HAND-BATCHED on flat ``[N·K]`` buffers with precomputed
      row offsets, so each step issues ONE 1-D gather/scatter of N
      elements instead of XLA's far slower batched-scatter form;
    * both stacks share one packed per-row buffer — smalls grow from the
      left (top at ``ns-1``), larges from the right (top at ``K-nl``,
      deeper = smaller index), so pops take the highest index first,
      matching the numpy mirror's list discipline; ``ns+nl`` shrinks by
      one per pairing, so the regions never collide;
    * stacks are initialized with cumsum positions + scatter, NOT
      argsort: feeding a sort HLO into a rolled loop miscompiles on the
      multi-device XLA CPU runtime the shard_map backend tests run under
      (non-zero devices read corrupted stacks);
    * no-op steps write NOTHING (sentinel index + ``mode="drop"``) and
      guards apply to the written element, never the whole array — a
      ``where(cont, arr.at[i].set(v), arr)`` select is O(K) per step and
      would turn the O(K) build into O(K²) per row;
    * the loop carries only ``(m, stack)`` — cut/alias are emitted as
      scan outputs and scattered once afterwards (each cell is popped as
      a small at most once).
    """
    n, k = w.shape
    nk = n * k
    w = w.astype(jnp.int32)
    base = jnp.arange(n, dtype=jnp.int32) * k
    u_cap = w.sum(axis=1).astype(jnp.float32)    # [N] exact, order-free
    m = (w.astype(jnp.float32) * jnp.float32(k)).reshape(nk)
    small_mask = m.reshape(n, k) < u_cap[:, None]
    idx = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32), (n, k))
    smask = small_mask.astype(jnp.int32)
    spos = jnp.cumsum(smask, axis=1) - 1
    lpos = jnp.cumsum(1 - smask, axis=1) - 1
    sentinel = nk
    stack = jnp.zeros(nk, jnp.int32) \
        .at[jnp.where(small_mask, base[:, None] + spos,
                      sentinel).reshape(nk)].set(idx.reshape(nk),
                                                 mode="drop") \
        .at[jnp.where(small_mask, sentinel,
                      base[:, None] + (k - 1) - lpos).reshape(nk)].set(
            idx.reshape(nk), mode="drop")
    ns = smask.sum(axis=1)
    nl = k - ns

    def step(carry, _):
        m, stack, ns, nl = carry
        cont = (ns > 0) & (nl > 0)
        s = stack[base + jnp.maximum(ns - 1, 0)]
        lg = stack[base + jnp.minimum(k - nl, k - 1)]
        m_s = m[base + s]
        rem = (m[base + lg] + m_s) - u_cap       # single add, single sub
        m = m.at[jnp.where(cont, base + lg, sentinel)].set(rem,
                                                           mode="drop")
        to_small = rem < u_cap
        ns2, nl2 = ns - 1, nl - 1
        # push lg: slot ns2 if it went small, else new large top K-nl2-1
        i_push = jnp.where(to_small, ns2, k - nl2 - 1)
        stack = stack.at[jnp.where(cont, base + i_push, sentinel)].set(
            lg, mode="drop")
        ns3 = jnp.where(cont, jnp.where(to_small, ns2 + 1, ns2), ns)
        nl3 = jnp.where(cont, jnp.where(to_small, nl2, nl2 + 1), nl)
        out = (jnp.where(cont, base + s, sentinel), m_s, lg)
        return (m, stack, ns3, nl3), out

    carry = (m, stack, ns, nl)
    _, (s_seq, ms_seq, lg_seq) = jax.lax.scan(step, carry, None, length=k)
    # full / leftover cells: cut = U, alias = self; popped smalls overwrite
    cut = (jnp.ones((n, k), jnp.float32) * u_cap[:, None]).reshape(nk)
    cut = cut.at[s_seq.reshape(-1)].set(ms_seq.reshape(-1), mode="drop")
    alias = idx.reshape(nk).at[s_seq.reshape(-1)].set(lg_seq.reshape(-1),
                                                      mode="drop")
    return cut.reshape(n, k), alias.reshape(n, k), u_cap


def build_alias_int(w: jax.Array
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-row convenience form of :func:`build_alias_int_rows`."""
    cut, alias, u_cap = build_alias_int_rows(w[None, :])
    return cut[0], alias[0], u_cap[0]


@partial(jax.jit, static_argnames=())
def build_alias_tables(counts: jax.Array, prior: jax.Array
                       ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                  jax.Array]:
    """Counts [N, K] + prior ([K] or [N, K]) -> (cut, alias, U, W).

    ``W`` (the integer proposal masses) is returned alongside the table
    because the MH acceptance must evaluate the proposal density from the
    same quantized grid the table was built on.  Callers building several
    table families per round (word rows + doc rows) should concatenate
    their count rows and call ONCE — the K-step pairing loop then runs a
    single time over all rows instead of once per family.
    """
    prior = jnp.broadcast_to(prior, counts.shape)
    w = int_masses(counts, prior)
    cut, alias, u_cap = build_alias_int_rows(w)
    return cut, alias, u_cap, w


def build_alias_int_np(w: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Numpy mirror of :func:`build_alias_int`, op-for-op (f32 single-op
    chains, LIFO stacks, ascending fill) — tests assert bit-equality."""
    w = np.asarray(w, np.int32)
    k = w.shape[0]
    u_cap = np.float32(w.sum(dtype=np.int64).astype(np.int32))
    m = w.astype(np.float32) * np.float32(k)
    cut = np.full(k, u_cap, np.float32)
    alias = np.arange(k, dtype=np.int32)
    small = [i for i in range(k) if m[i] < u_cap]
    large = [i for i in range(k) if not (m[i] < u_cap)]
    while small and large:
        s = small.pop()
        lg = large.pop()
        cut[s] = m[s]
        alias[s] = lg
        m[lg] = (m[lg] + m[s]) - u_cap
        (small if m[lg] < u_cap else large).append(lg)
    return cut, alias, u_cap


def alias_table_masses(cut: np.ndarray, alias: np.ndarray,
                       u_cap: float) -> np.ndarray:
    """Reconstruct the (·K-scaled) masses an integer-grid table encodes:
    topic ``t`` gets ``cut[t]`` from its own cell plus ``U - cut[j]`` from
    every cell aliased to it.  Equals ``f32(w)·K`` up to fp tolerance."""
    mass = cut.astype(np.float64).copy()
    np.add.at(mass, alias, np.float64(u_cap) - cut.astype(np.float64))
    return mass


# ---------------------------------------------------------------------------
# Packed (rotatable) table layout — the ring payload of traveling tables
# ---------------------------------------------------------------------------
#
# A built table is three [N, K] planes (cut f32, alias i32, W i32) plus the
# per-row capacity U [N] f32.  To let a table travel through the engine's
# rotation collective as ONE array (a single extra ppermute per round, and
# one slot queue to park it in), the planes are packed into a single int32
# array of shape [..., 3, N, K]:
#
#   plane 0 — cut,   IEEE-754 bits reinterpreted as int32 (lossless);
#   plane 1 — alias, already int32;
#   plane 2 — W,     the integer proposal masses.
#
# U is deliberately NOT packed: it is an exact int32 row sum of W
# (`build_alias_int_rows` computes it the same way), so the unpacker
# recomputes it bit-for-bit from plane 2 — one fewer plane to move and one
# fewer value whose staleness could diverge from the masses it summarizes.

def pack_tables(cut: jax.Array, alias: jax.Array,
                w: jax.Array) -> jax.Array:
    """(cut [.., N, K] f32, alias [.., N, K] i32, W [.., N, K] i32) ->
    packed int32 [.., 3, N, K] (bit-lossless; see layout note above)."""
    return jnp.stack([
        jax.lax.bitcast_convert_type(cut.astype(jnp.float32), jnp.int32),
        alias.astype(jnp.int32), w.astype(jnp.int32)], axis=-3)


def unpack_tables(packed: jax.Array
                  ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Packed [.., 3, N, K] int32 -> (cut, alias, U, W) — the tuple shape
    every MH sweep consumes.  ``U`` is recomputed as the exact int32 row
    sum of the W plane, bit-identical to the value the builder produced."""
    cut = jax.lax.bitcast_convert_type(packed[..., 0, :, :], jnp.float32)
    alias = packed[..., 1, :, :]
    w = packed[..., 2, :, :]
    u_cap = w.sum(axis=-1).astype(jnp.float32)
    return cut, alias, u_cap, w


def pack_tables_np(cut: np.ndarray, alias: np.ndarray,
                   w: np.ndarray) -> np.ndarray:
    """Numpy mirror of :func:`pack_tables` (host-side tests/tools)."""
    return np.stack([np.asarray(cut, np.float32).view(np.int32),
                     np.asarray(alias, np.int32),
                     np.asarray(w, np.int32)], axis=-3)


def unpack_tables_np(packed: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                np.ndarray]:
    """Numpy mirror of :func:`unpack_tables`."""
    packed = np.asarray(packed, np.int32)
    cut = packed[..., 0, :, :].view(np.float32)
    alias = packed[..., 1, :, :]
    w = packed[..., 2, :, :]
    u_cap = w.sum(axis=-1, dtype=np.int32).astype(np.float32)
    return cut, alias, u_cap, w


# ---------------------------------------------------------------------------
# Draw helpers (shared by jnp MH steps, Pallas kernel mirrors the math)
# ---------------------------------------------------------------------------

def split_cell_uniform(u: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """One uniform -> (cell index [int32], within-cell uniform [f32])."""
    x = u.astype(jnp.float32) * jnp.float32(k)
    j = jnp.minimum(x.astype(jnp.int32), k - 1)
    return j, x - j.astype(jnp.float32)


def alias_resolve(cut_cell: jax.Array, alias_cell: jax.Array,
                  u_cap: jax.Array, j: jax.Array,
                  frac: jax.Array) -> jax.Array:
    """Resolve a drawn cell: keep ``j`` iff ``frac·U < cut[j]`` (the
    division-free form of ``frac < cut[j]/U``)."""
    return jnp.where(frac * u_cap < cut_cell, j, alias_cell) \
        .astype(jnp.int32)


def alias_draw_int_np(cut: np.ndarray, alias: np.ndarray, u_cap: float,
                      u: np.ndarray) -> np.ndarray:
    """Numpy draw from an integer-grid table, vectorized over ``u``."""
    k = cut.shape[0]
    x = np.asarray(u, np.float32) * np.float32(k)
    j = np.minimum(x.astype(np.int32), k - 1)
    frac = x - j.astype(np.float32)
    return np.where(frac * np.float32(u_cap) < cut[j], j,
                    alias[j]).astype(np.int32)
