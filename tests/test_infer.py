"""Fold-in / serving subsystem (DESIGN.md §11).

Layers, mirroring how the trainer is validated:

* **structural** — the batched device fold-in equals the serial host
  oracle (`kvstore.fold_in_oracle`) draw-for-draw, for snapshots taken
  from engines trained at several (D, M, S) geometries; the MH pair
  (`mh`, `mh_pallas`) draws bit-identically; padding (the serving
  bucket mechanism) provably never perturbs real queries.
* **statistical** — held-out doc-completion perplexity decreases over
  training iterations on the planted-topics corpus, and a zero-count
  snapshot scores exactly the uninformative ceiling V.
"""
import numpy as np
import pytest

from repro.core.engine.api import ModelParallelLDA
from repro.core.infer import (FoldInResult, ModelSnapshot, fold_in,
                              init_query_cdk, load_snapshot, pack_queries,
                              theta_from_cdk)
from repro.core.kvstore import fold_in_oracle
from repro.core.likelihood import doc_completion_perplexity
from repro.data.corpus import split_corpus
from repro.serve.topic_infer import TopicInferenceServer, bucket_size

K = 8


def _train_snapshot(corpus, d=1, s=1, iters=2, seed=0):
    lda = ModelParallelLDA(corpus, K, num_workers=2, seed=seed,
                           blocks_per_worker=s, data_parallel=d)
    lda.run(iters)
    return lda, lda.snapshot()


def _query_arrays(vocab, q=4, t=18, sweeps=3, seed=1):
    rng = np.random.default_rng(seed)
    lens = rng.integers(3, t + 1, size=q)
    docs = [rng.integers(0, vocab, size=n).astype(np.int32) for n in lens]
    word, mask = pack_queries(docs, t_pad=t)
    z0 = rng.integers(0, K, size=word.shape).astype(np.int32)
    u = rng.random((sweeps, *word.shape), np.float32)
    return docs, word, mask, z0, u


@pytest.fixture(scope="module")
def snap(tiny_corpus):
    corpus, _, _ = tiny_corpus
    return _train_snapshot(corpus)[1]


# ---------------------------------------------------------------------------
# Snapshot export
# ---------------------------------------------------------------------------

def test_snapshot_consistency(tiny_corpus):
    corpus, _, _ = tiny_corpus
    lda, snap = _train_snapshot(corpus)
    state = lda.gather_counts()
    np.testing.assert_array_equal(snap.ckt, np.asarray(state.ckt))
    np.testing.assert_array_equal(snap.ck, snap.ckt.sum(axis=0))
    assert snap.vocab_size == corpus.vocab_size
    assert snap.num_topics == K
    assert snap.ck.sum() == corpus.num_tokens
    # φ̂ᵀ columns are normalized over the vocabulary
    np.testing.assert_allclose(snap.word_term().sum(axis=0),
                               np.ones(K), rtol=1e-5)


def test_snapshot_save_load_rebuilds_tables_bitwise(tmp_path, snap):
    """Persistence drops the tables; the bit-deterministic builder must
    reproduce them exactly on load (why the npz stays counts-only)."""
    path = str(tmp_path / "snap")
    snap.save(path)
    out = load_snapshot(path + ".npz")
    np.testing.assert_array_equal(out.ckt, snap.ckt)
    np.testing.assert_array_equal(out.ck, snap.ck)
    np.testing.assert_array_equal(out.alpha, snap.alpha)
    assert out.beta == snap.beta
    np.testing.assert_array_equal(out.ensure_tables(),
                                  snap.ensure_tables())


# ---------------------------------------------------------------------------
# Engine == host oracle, draw for draw
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,s", [(1, 1), (1, 2), (2, 1), (2, 2)])
@pytest.mark.parametrize("sampler", ["scan", "mh"])
def test_fold_in_matches_host_oracle(tiny_corpus, d, s, sampler):
    """Batched device fold-in == serial host replay, bitwise, against
    snapshots exported from engines trained across the (D, M, S) grid —
    the serving-side version of the trainer's oracle anchor."""
    corpus, _, _ = tiny_corpus
    _, snap_g = _train_snapshot(corpus, d=d, s=s)
    _, word, mask, z0, u = _query_arrays(corpus.vocab_size)
    res = fold_in(snap_g, word, mask, sampler=sampler, z0=z0, u=u)
    cdk_o, z_o = fold_in_oracle(snap_g, word, mask, z0, u, sampler=sampler)
    np.testing.assert_array_equal(res.z, z_o)
    np.testing.assert_array_equal(res.cdk, cdk_o)


def test_fold_in_mh_pallas_bitwise(snap, tiny_corpus):
    """The MH pair draws identically at serve time, as in training."""
    corpus, _, _ = tiny_corpus
    _, word, mask, z0, u = _query_arrays(corpus.vocab_size)
    a = fold_in(snap, word, mask, sampler="mh", z0=z0, u=u)
    b = fold_in(snap, word, mask, sampler="mh_pallas", z0=z0, u=u)
    np.testing.assert_array_equal(a.z, b.z)
    np.testing.assert_array_equal(a.cdk, b.cdk)


@pytest.mark.parametrize("sampler", ["scan", "mh"])
def test_fold_in_padding_invariance(snap, tiny_corpus, sampler):
    """Growing the bucket (extra masked rows/columns filled with garbage)
    must not change any real query's draws — the property that makes the
    serving buckets a pure latency knob."""
    corpus, _, _ = tiny_corpus
    _, word, mask, z0, u = _query_arrays(corpus.vocab_size, q=3, t=12)
    base = fold_in(snap, word, mask, sampler=sampler, z0=z0, u=u)

    rng = np.random.default_rng(99)
    q, t = word.shape
    q2, t2 = q + 3, t + 9
    word2 = rng.integers(0, corpus.vocab_size, (q2, t2)).astype(np.int32)
    z02 = rng.integers(0, K, (q2, t2)).astype(np.int32)
    u2 = rng.random((u.shape[0], q2, t2), np.float32)
    mask2 = np.zeros((q2, t2), bool)
    word2[:q, :t] = word
    z02[:q, :t] = z0
    u2[:, :q, :t] = u
    mask2[:q, :t] = mask
    grown = fold_in(snap, word2, mask2, sampler=sampler, z0=z02, u=u2)
    np.testing.assert_array_equal(grown.z[:q, :t], base.z)
    np.testing.assert_array_equal(grown.cdk[:q], base.cdk)


def test_fold_in_validation(snap):
    word = np.zeros((2, 4), np.int32)
    mask = np.ones((2, 4), bool)
    with pytest.raises(ValueError, match="sampler"):
        fold_in(snap, word, mask, sampler="batched")
    with pytest.raises(ValueError, match="shape"):
        fold_in(snap, word, np.ones((2, 5), bool))


def test_fold_in_result_shapes_and_theta(snap, tiny_corpus):
    corpus, _, _ = tiny_corpus
    docs, word, mask, z0, u = _query_arrays(corpus.vocab_size)
    res = fold_in(snap, word, mask, sampler="mh", z0=z0, u=u)
    assert isinstance(res, FoldInResult)
    assert res.cdk.shape == (word.shape[0], K)
    assert res.z.shape == word.shape
    # per-doc token conservation: cdk row sums == real token counts
    np.testing.assert_array_equal(res.cdk.sum(axis=1), mask.sum(axis=1))
    np.testing.assert_allclose(res.theta.sum(axis=1), 1.0, rtol=1e-12)
    assert (res.theta > 0).all()
    # helpers agree with the result
    np.testing.assert_array_equal(
        init_query_cdk(res.z, mask, K).sum(axis=1), mask.sum(axis=1))
    np.testing.assert_allclose(res.theta,
                               theta_from_cdk(res.cdk, snap.alpha))


# ---------------------------------------------------------------------------
# Perplexity estimator
# ---------------------------------------------------------------------------

def test_uniform_snapshot_perplexity_is_vocab_size():
    """Zero counts -> every word scores exactly 1/V -> perplexity == V,
    the uninformative ceiling (closed-form check of the estimator)."""
    v = 120
    snap0 = ModelSnapshot.from_counts(np.zeros((v, K), np.int32),
                                      alpha=0.1, beta=0.01)
    rng = np.random.default_rng(0)
    docs = [rng.integers(0, v, size=12) for _ in range(5)]
    out = doc_completion_perplexity(snap0, docs, num_sweeps=2)
    np.testing.assert_allclose(out["perplexity"], v, rtol=1e-5)
    assert out["tokens_scored"] == 5 * 6


def test_holdout_perplexity_decreases_with_training(small_corpus):
    """Statistical sanity: on the planted-topics corpus, doc-completion
    perplexity of held-out docs falls as the model trains — the
    convergence signal training log-likelihood cannot provide."""
    corpus, _, _ = small_corpus
    train, held = split_corpus(corpus, 20)
    docs = held.doc_words()
    lda = ModelParallelLDA(train, 10, num_workers=2, seed=0,
                           sampler_mode="batched")
    lda.step()
    early = doc_completion_perplexity(lda.snapshot(), docs,
                                      num_sweeps=5, seed=3)
    lda.run(11)
    late = doc_completion_perplexity(lda.snapshot(), docs,
                                     num_sweeps=5, seed=3)
    assert np.isfinite(early["perplexity"])
    assert late["perplexity"] < 0.95 * early["perplexity"], \
        (early["perplexity"], late["perplexity"])
    assert late["perplexity"] < train.vocab_size   # beats the ceiling


def test_perplexity_requires_scorable_tokens(snap):
    with pytest.raises(ValueError, match="score"):
        doc_completion_perplexity(snap, [np.zeros(0, np.int32)])


# ---------------------------------------------------------------------------
# Serving facade
# ---------------------------------------------------------------------------

def test_bucket_size():
    assert [bucket_size(n, 8) for n in (1, 8, 9, 16, 33)] == \
        [8, 8, 16, 16, 64]
    assert bucket_size(3) == 4


def test_server_buckets_batches_and_serves(snap, tiny_corpus):
    corpus, _, _ = tiny_corpus
    rng = np.random.default_rng(5)
    server = TopicInferenceServer(snap, sampler="mh", num_sweeps=3, seed=0)
    docs = [rng.integers(0, corpus.vocab_size, size=n) for n in (5, 9, 17)]
    assert server.bucket_shape(docs) == (4, 32)
    theta = server.infer(docs)
    assert theta.shape == (3, K)
    np.testing.assert_allclose(theta.sum(axis=1), 1.0, rtol=1e-12)
    # a second batch landing in the same bucket reuses the compiled shape
    more = [rng.integers(0, corpus.vocab_size, size=n) for n in (20, 30)]
    server.infer(more)
    assert server.bucket_calls[(4, 32)] == 1
    assert server.bucket_calls[(2, 32)] == 1
    server.infer(docs)
    assert server.bucket_calls[(4, 32)] == 2
    assert server.docs_served == 8
    one = server.infer_one(docs[0])
    assert one.shape == (K,)
    ppl = server.perplexity(docs)
    assert np.isfinite(ppl["perplexity"])


def test_server_empty_batch(snap):
    server = TopicInferenceServer(snap, sampler="scan")
    assert server.infer([]).shape == (0, K)


def test_server_scan_matches_direct_fold_in(snap, tiny_corpus):
    """The server is pure orchestration: same snapshot, same rng stream,
    same bucket -> identical mixtures to calling fold_in directly."""
    corpus, _, _ = tiny_corpus
    rng = np.random.default_rng(7)
    docs = [rng.integers(0, corpus.vocab_size, size=n) for n in (6, 11)]
    server = TopicInferenceServer(snap, sampler="scan", num_sweeps=4,
                                  seed=42)
    theta = server.infer(docs)
    word, mask = pack_queries(docs, t_pad=16, q_pad=2)
    res = fold_in(snap, word, mask, num_sweeps=4, sampler="scan",
                  rng=np.random.default_rng(42))
    np.testing.assert_allclose(theta, res.theta[:2])

# ---------------------------------------------------------------------------
# Server edge cases (PR 8 backfill) + the scheduler's draw-injection API
# ---------------------------------------------------------------------------

def test_server_empty_doc_in_batch_gets_prior_mixture(snap, tiny_corpus):
    """A zero-length doc is all padding: its mixture is the normalized
    prior (no evidence), and it must not perturb its batchmates."""
    corpus, _, _ = tiny_corpus
    rng = np.random.default_rng(9)
    doc = rng.integers(0, corpus.vocab_size, size=7)
    server = TopicInferenceServer(snap, sampler="scan", num_sweeps=3,
                                  seed=0)
    theta = server.infer([doc, np.zeros(0, np.int32)])
    assert theta.shape == (2, K)
    np.testing.assert_allclose(theta[1], snap.alpha / snap.alpha.sum(),
                               rtol=1e-12)
    np.testing.assert_allclose(theta.sum(axis=1), 1.0, rtol=1e-12)


def test_server_batch_of_one(snap, tiny_corpus):
    corpus, _, _ = tiny_corpus
    rng = np.random.default_rng(10)
    doc = rng.integers(0, corpus.vocab_size, size=5)
    server = TopicInferenceServer(snap, sampler="scan", seed=3)
    theta = server.infer([doc])
    assert theta.shape == (1, K)
    assert server.bucket_calls == {(1, 8): 1}


def test_server_doc_longer_than_min_bucket(snap, tiny_corpus):
    """A doc past every warmed bucket pads into the next power of two —
    a fresh compile, never an error or a truncation."""
    corpus, _, _ = tiny_corpus
    rng = np.random.default_rng(11)
    doc = rng.integers(0, corpus.vocab_size, size=100)
    server = TopicInferenceServer(snap, sampler="scan", seed=4)
    assert server.bucket_shape([doc]) == (1, 128)
    theta = server.infer([doc])
    assert theta.shape == (1, K)
    assert np.isfinite(theta).all()
    assert server.bucket_calls == {(1, 128): 1}


@pytest.mark.parametrize("sampler", ["scan", "mh", "sparse"])
def test_infer_with_draws_bucket_invariance(snap, tiny_corpus, sampler):
    """The scheduler's foundation: with per-doc draws supplied, a doc's
    mixture is bitwise the same served alone in a (1, 8) bucket or
    packed with strangers into a (4, 32) bucket — for every sampler
    family the scheduler can bind."""
    corpus, _, _ = tiny_corpus
    rng = np.random.default_rng(12)
    sweeps = 3
    docs = [rng.integers(0, corpus.vocab_size, size=n).astype(np.int32)
            for n in (6, 8, 17)]
    z0s = [rng.integers(0, K, size=len(d)).astype(np.int32) for d in docs]
    us = [rng.random((sweeps, len(d)), dtype=np.float32) for d in docs]
    server = TopicInferenceServer(snap, sampler=sampler, num_sweeps=sweeps,
                                  seed=0)
    batched = server.infer_with_draws(docs, z0s, us)
    for i, d in enumerate(docs):
        alone = server.infer_with_draws([d], [z0s[i]], [us[i]])
        np.testing.assert_array_equal(alone[0], batched[i])


def test_infer_with_draws_validation(snap, tiny_corpus):
    corpus, _, _ = tiny_corpus
    rng = np.random.default_rng(13)
    doc = rng.integers(0, corpus.vocab_size, size=5).astype(np.int32)
    server = TopicInferenceServer(snap, sampler="scan", num_sweeps=2)
    assert server.infer_with_draws([], [], []).shape == (0, K)
    z0 = rng.integers(0, K, size=5).astype(np.int32)
    u = rng.random((2, 5), dtype=np.float32)
    with pytest.raises(ValueError, match="one z0/u row per doc"):
        server.infer_with_draws([doc], [z0, z0], [u, u])
    with pytest.raises(ValueError, match="draws must be"):
        server.infer_with_draws([doc], [z0[:3]], [u])
    with pytest.raises(ValueError, match="draws must be"):
        server.infer_with_draws([doc], [z0], [u[:1]])
