"""Sharding-rule logic against a stub mesh (no devices needed)."""
import dataclasses

import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding_rules as sr


@dataclasses.dataclass
class StubMesh:
    shape: dict


MESH = StubMesh({"data": 16, "model": 16})
MESH3 = StubMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("spec,shape,expect", [
    (P("data", "model"), (32, 64), ("data", "model")),
    (P("data", "model"), (25, 64), (None, "model")),      # 25 % 16 != 0
    (P("data", "model"), (32, 60), ("data", None)),       # 60 % 16 != 0
    (P(("pod", "data"), None), (64, 7), (("pod", "data"), None)),
    (P(("pod", "data"), None), (16, 7), ("pod", None)),   # falls to 1 axis
    (P(None, "model"), (5, 128), (None, "model")),
])
def test_sanitize(spec, shape, expect):
    mesh = MESH3 if any("pod" in str(a) for a in tuple(spec)) else MESH
    out = sr.sanitize(mesh, spec, shape)
    assert tuple(out) == tuple(expect), (spec, shape, out)


def test_sanitize_pads_missing_dims():
    out = sr.sanitize(MESH, P("model"), (32, 64, 128))
    assert tuple(out) == ("model", None, None)


def test_axes_size():
    assert sr._axes_size(MESH3, ("pod", "data")) == 32
    assert sr._axes_size(MESH, "model") == 16
    assert sr._axes_size(MESH, None) == 1


def test_hymba_exact_heads_survive():
    """25 heads / 60 experts: the exact public configs must sanitize to
    legal (if less parallel) shardings rather than erroring."""
    # wq [d, H*hd] = [1600, 1600]: both divisible by 16
    assert tuple(sr.sanitize(MESH, P(None, "data", "model"),
                             (32, 1600, 1600))) == (None, "data", "model")
    # dt_proj out dim 25: model axis dropped
    assert tuple(sr.sanitize(MESH, P(None, "data", "model"),
                             (32, 1600, 25))) == (None, "data", None)
    # qwen2 60 experts: expert dim unsharded, ffn dim over model
    assert tuple(sr.sanitize(MESH, P(None, "model", "data", None),
                             (24, 60, 2048, 1408))) == (None, None, "data",
                                                        None)
