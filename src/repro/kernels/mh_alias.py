"""Pallas TPU kernel for the alias-table MH *word proposal* step.

The word proposal is the half of the LightLDA cycle that is word-shared:
its alias table ``(cut, alias, U)`` and frozen ``C_k^t`` row depend only
on the word, exactly like the eq.-(3) coefficient cache that
``gibbs_conditional.py`` keeps in VMEM.  The kernel therefore uses the
same word-grouped ``[G, Tg]`` token layout: each grid step loads TILE_G
words' alias rows + frozen count rows HBM→VMEM **once** and hits them
``Tg`` times — per-token work is a cell lookup and a handful of scalar
gathers, never a K-wide mass or cumsum.

Scalar gathers are expressed as one-hot reductions over the topic lanes
(`iota == idx` masks) — the TPU-native form of a dynamic lane index; the
values selected are untouched f32 loads, and the draw/accept comparisons
are the same division-free single-op forms as the jnp step in
``core/mh.py`` (`_mh_step`), so the kernel is bit-identical to it —
asserted by tests.

The doc-proposal half of the cycle is document-local, not word-local —
its table rows would have to be re-fetched per token, so it gains nothing
from this tiling and stays in plain jnp (`ops.sweep_block_mh_pallas`
composes the two).

K is padded to the 128-lane boundary by the wrapper; the REAL topic count
rides in the consts row so cell indices never land on padded lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gibbs_conditional import TILE_G


def _onehot_f32(values, idx):
    """values [..., K] f32 gathered at idx [...] -> [...] (exact select)."""
    k = values.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, idx.shape + (k,),
                                    idx.ndim)
    return jnp.sum(jnp.where(iota == idx[..., None], values, 0.0), axis=-1)


def _onehot_i32(values, idx):
    k = values.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, idx.shape + (k,),
                                    idx.ndim)
    return jnp.sum(jnp.where(iota == idx[..., None], values, 0), axis=-1)


def _mh_word_kernel(wcut_ref, walias_ref, wmass_ref, ucap_ref, ckt_ref,
                    cdk_ref, zcur_ref, z0_ref, udraw_ref, uacc_ref,
                    mask_ref, ck_ref, alpha_ref, const_ref, out_ref):
    beta = const_ref[0, 0]
    vbeta = const_ref[0, 1]
    k_real = const_ref[0, 2].astype(jnp.int32)   # unpadded topic count
    ck = ck_ref[0, :]                      # [K]
    alpha = alpha_ref[0, :]                # [K]
    wcut = wcut_ref[...]                   # [G, K] alias cell cut masses
    walias = walias_ref[...]               # [G, K] alias cell targets
    wmass = wmass_ref[...]                 # [G, K] f32(W) proposal masses
    ucap = ucap_ref[...]                   # [G, 1] per-row cell capacity
    ckt = ckt_ref[...]                     # [G, K] frozen C_k^t rows
    cdk = cdk_ref[...]                     # [G, T, K] frozen C_d^k rows
    z_cur = zcur_ref[...]                  # [G, T]
    z0 = z0_ref[...]                       # [G, T] round-start assignment
    u_draw = udraw_ref[...]                # [G, T]
    u_acc = uacc_ref[...]                  # [G, T]
    mask = mask_ref[...]                   # [G, T] int32 validity

    # ---- alias draw: one uniform -> (cell, within-cell threshold) -------
    x = u_draw * k_real.astype(jnp.float32)
    j = jnp.minimum(x.astype(jnp.int32), k_real - 1)          # [G, T]
    frac = x - j.astype(jnp.float32)
    cut_j = _onehot_f32(wcut[:, None, :], j)
    alias_j = _onehot_i32(walias[:, None, :], j)
    prop = jnp.where(frac * ucap < cut_j, j, alias_j)

    # ---- exact eq.-(1) acceptance from frozen counts --------------------
    def target_terms(kk):
        excl = (kk == z0).astype(jnp.float32)
        num = ((_onehot_f32(cdk, kk) - excl + _onehot_f32(
            alpha[None, None, :], kk))
            * (_onehot_f32(ckt[:, None, :], kk) - excl + beta))
        den = _onehot_f32(ck[None, None, :], kk) - excl + vbeta
        return num, den

    n_new, d_new = target_terms(prop)
    n_old, d_old = target_terms(z_cur)
    q_new = _onehot_f32(wmass[:, None, :], prop)
    q_old = _onehot_f32(wmass[:, None, :], z_cur)
    # division-free cross-multiplied accept test (same association order
    # as core.mh._mh_step — bit-identity depends on it)
    accept = (u_acc * n_old * d_new * q_new < n_new * d_old * q_old) \
        & (mask != 0)
    out_ref[...] = jnp.where(accept, prop, z_cur)


@functools.partial(jax.jit,
                   static_argnames=("k_real", "tile_g", "interpret"))
def mh_word_call(wcut: jax.Array, walias: jax.Array, wmass: jax.Array,
                 ucap: jax.Array, ckt_rows: jax.Array, cdk_rows: jax.Array,
                 z_cur: jax.Array, z0: jax.Array,
                 u_draw: jax.Array, u_acc: jax.Array, mask: jax.Array,
                 ck: jax.Array, alpha: jax.Array, beta: float, vbeta: float,
                 k_real: int, tile_g: int = TILE_G,
                 interpret: bool = True) -> jax.Array:
    """Raw pallas_call wrapper (tile-aligned shapes; padding in ops.py).

    Args:
      wcut/walias/wmass: [G, K] per-word alias table rows (f32/int32/f32).
      ucap:         [G, 1] f32 per-word cell capacity ``U``.
      ckt_rows:     [G, K] f32 frozen word-topic rows.
      cdk_rows:     [G, Tg, K] f32 frozen doc-topic rows per token; the
                    token tile Tg is taken from this shape.
      z_cur/z0/u_draw/u_acc/mask: [G, Tg] per-token state.
      ck/alpha:     [K] f32.
      k_real:       unpadded K — alias cells only index real topics.
    Returns:
      z after the word MH step, [G, Tg] int32.
    """
    g, tg, k = cdk_rows.shape
    assert g % tile_g == 0 and k % 128 == 0, (g, k)
    grid = (g // tile_g,)
    consts = jnp.array([[beta, vbeta, float(k_real), 0.0]], jnp.float32)
    row = lambda i: (i, 0)
    row3 = lambda i: (i, 0, 0)
    rep = lambda i: (0, 0)
    return pl.pallas_call(
        _mh_word_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_g, k), row),            # wcut
            pl.BlockSpec((tile_g, k), row),            # walias
            pl.BlockSpec((tile_g, k), row),            # wmass
            pl.BlockSpec((tile_g, 1), row),            # ucap
            pl.BlockSpec((tile_g, k), row),            # ckt_rows
            pl.BlockSpec((tile_g, tg, k), row3),       # cdk_rows
            pl.BlockSpec((tile_g, tg), row),           # z_cur
            pl.BlockSpec((tile_g, tg), row),           # z0
            pl.BlockSpec((tile_g, tg), row),           # u_draw
            pl.BlockSpec((tile_g, tg), row),           # u_acc
            pl.BlockSpec((tile_g, tg), row),           # mask
            pl.BlockSpec((1, k), rep),                 # ck (broadcast)
            pl.BlockSpec((1, k), rep),                 # alpha (broadcast)
            pl.BlockSpec((1, 4), rep),                 # (beta, vbeta, K, _)
        ],
        out_specs=pl.BlockSpec((tile_g, tg), row),
        out_shape=jax.ShapeDtypeStruct((g, tg), jnp.int32),
        interpret=interpret,
    )(wcut.astype(jnp.float32), walias.astype(jnp.int32),
      wmass.astype(jnp.float32), ucap.astype(jnp.float32),
      ckt_rows.astype(jnp.float32), cdk_rows.astype(jnp.float32),
      z_cur.astype(jnp.int32), z0.astype(jnp.int32),
      u_draw.astype(jnp.float32), u_acc.astype(jnp.float32),
      mask.astype(jnp.int32), ck[None, :].astype(jnp.float32),
      alpha[None, :].astype(jnp.float32), consts)
