"""Out-of-core streaming trainer: the big-model regime (DESIGN.md §13).

:class:`StreamingLDA` runs the exact model-parallel chain of
:class:`~repro.core.engine.api.ModelParallelLDA` with BOTH halves of the
state out of core:

* the corpus stays in its sharded on-disk format (`data/stream.py`) and
  is demultiplexed once into per-(grid row, block) token files under a
  working directory — training never holds the full token stream;
* the model blocks live in a disk-backed block store (one
  :class:`~repro.core.engine.countstore.CountStore` record per
  ``[Vb, K]`` block — a plain ``.npy`` for the dense store, a
  ``store-v2`` ``.npz`` for the tail store; DESIGN.md §16 — the paper's
  key-value store made literal), and at most ONE block (plus its
  traveling table, for the MH family) is in memory at any time.

Peak training memory is therefore bounded by the resident ``[Vb, K]``
block and one in-flight row/block token group, independent of corpus
size and of total model size ``V × K`` — the paper's capacity claim,
measured by ``benchmarks/bench_model_size.py --big``.

Bit-exactness.  The scheduler is the serial transcript of the SPMD
engine — the same frozen-per-round semantics as the host oracle
(`core/kvstore.py`): within a round every replica samples frozen
round-start block copies and frozen ``{C_k}``, deltas are reconciled and
committed at the round boundary.  The rng stream is the engine's own:
numpy ``Generator`` fills arrays sequentially from the bit stream, so
drawing ``z0`` chunk-by-chunk in disk-shard order and uniforms
round-by-row in grid order reproduces the engine's one-shot
``integers(0, K, N)`` / ``random((B, R, cap))`` draws bit-for-bit (the
property is pinned by ``tests/test_stream_resume.py``).  Per-row calls
into the SAME jitted registry samplers equal the engine's vmap over rows
— the structural-equivalence argument the oracle already proves — so a
streaming run is draw-for-draw identical to the in-memory engine at any
``(D, M, S)``, any sampler, both table lifetimes.

Checkpoint/resume.  The working directory *is* the persistent state:
``static/`` holds the immutable layout, ``state/`` the mutable chain
(blocks, ``C_k``, per-row ``z``/``cdk``, rng bit-generator state,
iteration count).  :meth:`save_checkpoint` snapshots ``state/`` into
``ckpt/`` with an atomic directory swap at an iteration boundary — where
the table queue is empty and every replica agrees, so nothing
sampler-specific needs saving — and :meth:`StreamingLDA.resume` restores
it; a resumed run re-draws from the restored bit-generator state and is
bit-identical to an uninterrupted one.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Dict, List, Optional

import numpy as np

from repro.core import faults, schedule as sched
from repro.core.engine import countstore
from repro.core.invindex import build_inverted_index
from repro.data import integrity
from repro.data.stream import ShardedCorpus

RUN_JSON = "run.json"
PROGRESS_JSON = "progress.json"


def _save_npy(path: str, arr: np.ndarray) -> None:
    # atomic publish + crc32 sidecar: a kill at any instant leaves the
    # previous complete array or the new one, and a later bit flip is
    # caught at load (DESIGN.md §15)
    integrity.save_npy(path, arr)


def _load_npy(path: str) -> np.ndarray:
    return integrity.load_npy(path)


def _load_npz(path: str) -> dict:
    return integrity.load_npz(path)


def _rng_state_jsonable(state: dict) -> dict:
    """numpy bit-generator state dicts are JSON-safe except for numpy
    integer leaves — normalize to built-in ints recursively."""
    def conv(x):
        if isinstance(x, dict):
            return {k: conv(v) for k, v in x.items()}
        if isinstance(x, (np.integer,)):
            return int(x)
        return x
    return conv(state)


class StreamingLDA:
    """Out-of-core model-parallel LDA over a sharded on-disk corpus.

    Same chain as ``ModelParallelLDA(corpus, ...)`` with the same seed —
    proven draw-for-draw by ``tests/test_stream_resume.py`` — but memory
    bounded by one resident block + one in-flight token group.
    """

    def __init__(self, corpus: "ShardedCorpus | str", workdir: str,
                 num_topics: int, num_workers: int,
                 alpha: float = 0.1, beta: float = 0.01, seed: int = 0,
                 sampler_mode: str = "scan", blocks_per_worker: int = 1,
                 data_parallel: int = 1,
                 table_lifetime: Optional[str] = None,
                 sampler_args: Optional[tuple] = None,
                 store: str = "dense"):
        from repro.core.engine.rounds import table_capable
        if isinstance(corpus, str):
            corpus = ShardedCorpus(corpus)
        self.workdir = workdir
        self.num_topics = int(num_topics)
        self.num_workers = int(num_workers)
        self.blocks_per_worker = int(blocks_per_worker)
        self.data_parallel = int(data_parallel)
        if self.blocks_per_worker < 1 or self.data_parallel < 1:
            raise ValueError("blocks_per_worker and data_parallel must "
                             "be >= 1")
        self.alpha = np.full(self.num_topics, alpha, np.float32) \
            if np.isscalar(alpha) else np.asarray(alpha, np.float32)
        self.alpha_scalar = float(alpha) if np.isscalar(alpha) else None
        self.beta = float(beta)
        self.seed = int(seed)
        self.sampler_mode = sampler_mode
        if table_lifetime is None:
            table_lifetime = ("iteration" if table_capable(sampler_mode)
                              else "round")
        if table_lifetime not in ("round", "iteration"):
            raise ValueError(f"unknown table_lifetime {table_lifetime!r}")
        if table_lifetime == "iteration" and not table_capable(sampler_mode):
            raise ValueError(
                "table_lifetime='iteration' needs a table-capable sampler "
                f"(the MH family), got {sampler_mode!r}")
        self.table_lifetime = table_lifetime
        self.vocab_size = corpus.vocab_size
        self.num_docs = corpus.num_docs
        self.num_tokens = corpus.num_tokens
        self.max_doc_len = corpus.max_doc_len
        self.vbeta = float(beta * self.vocab_size)
        if sampler_args is None:
            if sampler_mode in ("sparse", "sparse_pallas"):
                # same derivation as the engine facade (same corpus-level
                # max doc length, recorded in the corpus manifest), so the
                # identical jitted sampler instance runs both sides
                from repro.core.sparse_device import default_sparse_args
                sampler_args = default_sparse_args(self.num_topics,
                                                   int(self.max_doc_len))
            else:
                sampler_args = ()
        self.sampler_args = tuple(sampler_args)
        countstore.resolve_store(store)     # validate the kind early
        self.store_kind = store
        # head/tail split MUST match the sampler's (it is derived from
        # the same frozen counts); samplers without a wcap get the
        # module default, under which their store still round-trips
        self._store_wcap = int(dict(self.sampler_args).get(
            "wcap", countstore.DEFAULT_TAIL_WCAP))
        self._resolve_sampler()
        self.num_blocks = self.num_workers * self.blocks_per_worker
        self.num_shards = self.data_parallel * self.num_workers
        self.num_rounds = self.num_blocks
        self.partition = sched.partition_vocab(self.vocab_size,
                                               self.num_blocks)
        sched.validate_schedule(self.num_workers, self.blocks_per_worker)
        self._rng = np.random.default_rng(self.seed)
        if os.path.exists(self._p("state", PROGRESS_JSON)):
            raise ValueError(
                f"workdir {workdir!r} already holds a run; use "
                "StreamingLDA.resume() to continue it")
        self._init_from_corpus(corpus)

    # -- paths -------------------------------------------------------------
    def _p(self, *parts: str) -> str:
        return os.path.join(self.workdir, *parts)

    def _block_stem(self, blk: int, root: str = "state") -> str:
        # extensionless: the CountStore layer owns the artifact format
        # (.npy dense / .npz store-v2 record) and `countstore.load`
        # dispatches on whichever exists, so old dense workdirs and
        # cross-store resumes need no migration step
        return self._p(root, "blocks", f"block_{blk:05d}")

    def _load_block_store(self, blk: int,
                          root: str = "state") -> countstore.CountStore:
        return countstore.load(self._block_stem(blk, root))

    def _make_store(self, dense: np.ndarray) -> countstore.CountStore:
        return countstore.resolve_store(self.store_kind).from_dense(
            dense, wcap=self._store_wcap)

    def _empty_store(self) -> countstore.CountStore:
        return countstore.resolve_store(self.store_kind).empty(
            self.partition.block_size, self.num_topics,
            wcap=self._store_wcap)

    def _lay_path(self, g: int, b: int) -> str:
        return self._p("static", "rows", f"row{g:04d}_b{b:04d}.npz")

    def _z_path(self, g: int, b: int) -> str:
        return self._p("state", "rows", f"row{g:04d}_z_b{b:04d}.npy")

    def _cdk_path(self, g: int) -> str:
        return self._p("state", "rows", f"row{g:04d}_cdk.npy")

    # -- construction ------------------------------------------------------
    def _resolve_sampler(self) -> None:
        from repro.core.engine.rounds import (resolve_sampler,
                                              resolve_store_sampler,
                                              resolve_table_sampler)
        self._sampler_fn = (resolve_table_sampler(self.sampler_mode)
                            if self.table_lifetime == "iteration"
                            else resolve_sampler(self.sampler_mode,
                                                 self.sampler_args))
        # store-native form (zero-conversion lane path) when one exists
        # for this (sampler, store) pair; otherwise step() densifies the
        # resident block explicitly — surfaced by store_note()
        self._store_sampler_fn = None
        if self.store_kind != "dense" and self.table_lifetime == "round":
            self._store_sampler_fn = resolve_store_sampler(
                self.sampler_mode, self.store_kind, self.sampler_args)

    def store_note(self) -> Optional[str]:
        """One-line densification warning for the CLI config echo, or
        ``None`` when the store never converts (dense store, or a
        store-native sampler).  Satellite of DESIGN.md §16: densifying
        a compressed store is allowed but NEVER silent."""
        if self.store_kind == "dense" or self._store_sampler_fn is not None:
            return None
        vb, k = self.partition.block_size, self.num_topics
        mib = vb * k * 4 / 2**20
        return (f"store={self.store_kind!r}: sampler "
                f"{self.sampler_mode!r} has no store-native form — each "
                f"resident block densifies to [{vb}, {k}] "
                f"({mib:.1f} MiB) per round (zero-conversion samplers: "
                "sparse, sparse_pallas)")

    def _row_docs(self, g: int) -> np.ndarray:
        """Round-robin doc assignment — identical to `data/sharding.py`:
        grid row ``g`` owns global docs ``{g, g + R, ...}``."""
        return np.arange(g, self.num_docs, self.num_shards, dtype=np.int32)

    @property
    def dloc(self) -> int:
        return -(-self.num_docs // self.num_shards)

    @property
    def resident_block_rows(self) -> int:
        return self.partition.block_size

    def _init_from_corpus(self, corpus: ShardedCorpus) -> None:
        """Two streaming passes build the static layout and the initial
        chain state; peak memory is one disk shard plus one grid row's
        token slice (plus one ``[Vb, K]`` block during count scatter)."""
        r_, b_ = self.num_shards, self.num_blocks
        k, part = self.num_topics, self.partition
        for sub in ("static/rows", "state/rows", "state/blocks", "tables"):
            os.makedirs(self._p(*sub.split("/")), exist_ok=True)

        # pass 1: per-(row, block) token counts -> common capacity, and the
        # z0 chunk draws (engine-identical: integers(0, K, N) consumed in
        # stream order), parked next to their shard for pass 2
        counts = np.zeros((r_, b_), np.int64)
        for shard in corpus.iter_shards():
            z0c = self._rng.integers(0, k, size=shard.num_tokens) \
                .astype(np.int32)
            _save_npy(self._p("static", f"z0_shard{shard.index:05d}.npy"),
                      z0c)
            row = shard.doc % r_
            blk = part.block_of_word(shard.word)
            np.add.at(counts, (row, blk), 1)
        self.capacity = max(1, int(counts.max(initial=0)))

        # pass 2: per grid row, gather its token slice (global stream
        # order), build the inverted-index layout, scatter initial counts
        tok_start = np.zeros(corpus.num_shards + 1, np.int64)
        for i, entry in enumerate(corpus.meta["shards"]):
            tok_start[i + 1] = tok_start[i] + int(entry["num_tokens"])
        for g in range(r_):
            docs_g, words_g, z_g, tid_g = [], [], [], []
            for shard in corpus.iter_shards():
                m = (shard.doc % r_) == g
                docs_g.append(shard.doc[m])
                words_g.append(shard.word[m])
                z0c = _load_npy(
                    self._p("static", f"z0_shard{shard.index:05d}.npy"))
                z_g.append(z0c[m])
                tid_g.append(np.nonzero(m)[0].astype(np.int64)
                             + tok_start[shard.index])
            doc_glob = np.concatenate(docs_g) if docs_g \
                else np.zeros(0, np.int32)
            word_g = np.concatenate(words_g) if words_g \
                else np.zeros(0, np.int32)
            z_row = np.concatenate(z_g) if z_g else np.zeros(0, np.int32)
            tid_row = np.concatenate(tid_g) if tid_g \
                else np.zeros(0, np.int64)
            doc_local = ((doc_glob - g) // r_).astype(np.int32)
            idx = build_inverted_index(doc_local, word_g, part,
                                       self.capacity)
            cdk_g = np.zeros((self.dloc, k), np.int32)
            np.add.at(cdk_g, (doc_local, z_row), 1)
            _save_npy(self._cdk_path(g), cdk_g)
            mine = self._row_docs(g)
            doc_global = np.full(self.dloc, -1, np.int32)
            doc_global[:mine.shape[0]] = mine
            _save_npy(self._p("static", "rows", f"row{g:04d}_docs.npy"),
                      doc_global)
            for b in range(b_):
                msk = idx.mask[b]
                zlay = np.zeros(self.capacity, np.int32)
                zlay[msk] = z_row[idx.token_id[b][msk]]
                glob_tid = np.zeros(self.capacity, np.int64)
                glob_tid[msk] = tid_row[idx.token_id[b][msk]]
                integrity.save_npz(self._lay_path(g, b), doc=idx.doc[b],
                                   woff=idx.word_off[b], mask=msk,
                                   tid=glob_tid)
                _save_npy(self._z_path(g, b), zlay)
                # scatter this (row, block) group's initial counts into
                # the block store — one block (at its store's occupancy,
                # not [Vb, K]) in memory at a time
                stem = self._block_stem(b)
                blk_store = (countstore.load(stem)
                             if countstore.exists(stem)
                             else self._empty_store())
                woff_b = idx.word_off[b][msk]
                blk_store.apply_coo(woff_b, zlay[msk],
                                    np.ones(woff_b.shape[0], np.int64))
                blk_store.save(stem)
        for shard_entry in range(corpus.num_shards):
            z0p = self._p("static", f"z0_shard{shard_entry:05d}.npy")
            os.remove(z0p)
            os.remove(integrity.sidecar_path(z0p))

        ck = np.zeros(k, np.int64)
        for b in range(b_):
            ck += self._load_block_store(b).col_sums()
        _save_npy(self._p("state", "ck.npy"), ck)
        self.iteration_count = 0
        self._write_run_json()
        self._write_progress()

    def _write_run_json(self) -> None:
        cfg = {
            "format": "streaming-lda-v1",
            "num_topics": self.num_topics,
            "num_workers": self.num_workers,
            "blocks_per_worker": self.blocks_per_worker,
            "data_parallel": self.data_parallel,
            "sampler_mode": self.sampler_mode,
            "sampler_args": list(map(list, self.sampler_args)),
            "table_lifetime": self.table_lifetime,
            "alpha": self.alpha_scalar if self.alpha_scalar is not None
            else self.alpha.tolist(),
            "beta": self.beta,
            "seed": self.seed,
            "vocab_size": self.vocab_size,
            "num_docs": self.num_docs,
            "num_tokens": self.num_tokens,
            "max_doc_len": self.max_doc_len,
            "capacity": self.capacity,
            "store": self.store_kind,
            "store_wcap": self._store_wcap,
        }
        integrity.atomic_write_json(self._p(RUN_JSON), cfg, indent=1,
                                    checksum=True)

    def _write_progress(self) -> None:
        prog = {"iteration_count": self.iteration_count,
                "rng_state": _rng_state_jsonable(
                    self._rng.bit_generator.state)}
        integrity.atomic_write_json(self._p("state", PROGRESS_JSON), prog,
                                    checksum=True)

    # -- checkpoint / resume ----------------------------------------------
    def save_checkpoint(self) -> str:
        """Snapshot ``state/`` into ``ckpt/`` with an atomic directory
        swap.  Taken at an iteration boundary (the only place `step`
        returns control), where the traveling-table queue is empty and
        replicas agree — so the snapshot is sampler- and
        backend-agnostic."""
        tmp, final = self._p("ckpt.tmp"), self._p("ckpt")
        faults.fire("ckpt.begin", final)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        shutil.copytree(self._p("state"), tmp)
        faults.fire("ckpt.tmp_copied", tmp)
        old = self._p("ckpt.old")
        if os.path.exists(final):
            if os.path.exists(old):     # debris from a kill after promote
                shutil.rmtree(old)
            os.rename(final, old)
            faults.fire("ckpt.old_moved", old)
        os.rename(tmp, final)
        faults.fire("ckpt.promoted", final)
        if os.path.exists(old):
            shutil.rmtree(old)
        return final

    @classmethod
    def resume(cls, workdir: str,
               store: Optional[str] = None) -> "StreamingLDA":
        """Reopen a run from its last :meth:`save_checkpoint`.  Restores
        ``ckpt/`` over ``state/`` (a kill mid-iteration leaves ``state/``
        partially advanced — the checkpoint is the consistent truth),
        then reloads config, rng bit-generator state, and iteration
        count; subsequent draws are bit-identical to a run that never
        stopped.

        ``store`` optionally MIGRATES the run to a different count-store
        kind: block files are re-encoded (exact integer round-trip, so
        the continued chain stays bitwise identical — pinned by
        tests/test_countstore.py) and run.json is rewritten.  Old
        pre-store workdirs carry no ``store`` field and default to
        ``dense``, which is exactly what their ``.npy`` blocks are."""
        with open(os.path.join(workdir, RUN_JSON)) as f:
            cfg = json.load(f)
        if cfg.get("format") != "streaming-lda-v1":
            raise ValueError(f"not a StreamingLDA workdir: {workdir!r}")
        ckpt = os.path.join(workdir, "ckpt")
        if not os.path.isdir(ckpt):
            old = os.path.join(workdir, "ckpt.old")
            if os.path.isdir(old):      # killed between the two renames
                os.rename(old, ckpt)
            else:
                raise ValueError(
                    f"no checkpoint under {workdir!r}; save_checkpoint() "
                    "must run before a kill to resume from")
        # validate every stamped artifact before trusting the checkpoint:
        # a bit-flipped block/row/progress file raises the integrity
        # taxonomy here instead of poisoning the resumed chain
        integrity.validate_tree(ckpt)
        alpha = cfg["alpha"]
        # constructed manually: the corpus-derived fields come from
        # run.json, not from a corpus scan
        self = cls.__new__(cls)
        self.workdir = workdir
        self.num_topics = int(cfg["num_topics"])
        self.num_workers = int(cfg["num_workers"])
        self.blocks_per_worker = int(cfg["blocks_per_worker"])
        self.data_parallel = int(cfg["data_parallel"])
        self.alpha = (np.full(self.num_topics, alpha, np.float32)
                      if np.isscalar(alpha)
                      else np.asarray(alpha, np.float32))
        self.alpha_scalar = float(alpha) if np.isscalar(alpha) else None
        self.beta = float(cfg["beta"])
        self.seed = int(cfg["seed"])
        self.sampler_mode = cfg["sampler_mode"]
        self.table_lifetime = cfg["table_lifetime"]
        self.vocab_size = int(cfg["vocab_size"])
        self.num_docs = int(cfg["num_docs"])
        self.num_tokens = int(cfg["num_tokens"])
        self.max_doc_len = int(cfg["max_doc_len"])
        self.capacity = int(cfg["capacity"])
        self.vbeta = float(self.beta * self.vocab_size)
        self.sampler_args = tuple(
            tuple(p) for p in cfg.get("sampler_args", []))
        self.store_kind = cfg.get("store", "dense")
        self._store_wcap = int(cfg.get(
            "store_wcap", dict(self.sampler_args).get(
                "wcap", countstore.DEFAULT_TAIL_WCAP)))
        self._resolve_sampler()
        self.num_blocks = self.num_workers * self.blocks_per_worker
        self.num_shards = self.data_parallel * self.num_workers
        self.num_rounds = self.num_blocks
        self.partition = sched.partition_vocab(self.vocab_size,
                                               self.num_blocks)
        state = os.path.join(workdir, "state")
        if os.path.exists(state):
            shutil.rmtree(state)
        shutil.copytree(ckpt, state)
        with open(self._p("state", PROGRESS_JSON)) as f:
            prog = json.load(f)
        self.iteration_count = int(prog["iteration_count"])
        self._rng = np.random.default_rng(self.seed)
        self._rng.bit_generator.state = prog["rng_state"]
        if store is not None and store != self.store_kind:
            self.set_store(store)
        return self

    def set_store(self, store: str) -> None:
        """Migrate the live run's blocks to count-store kind ``store``
        (the ``to_dense`` round-trip — exact, so the chain continues
        bitwise) and make it the kind for all subsequent writes."""
        countstore.resolve_store(store)
        if store == self.store_kind:
            return
        self.store_kind = store
        self._resolve_sampler()
        for b in range(self.num_blocks):
            st = self._load_block_store(b)
            if st.kind != store:
                self._make_store(st.to_dense()).save(self._block_stem(b))
        self._write_run_json()

    # -- stepping ----------------------------------------------------------
    def step(self) -> None:
        """One iteration = ``S·M`` rounds, round-major over the grid rows
        with frozen-per-round semantics — the serial transcript of the
        SPMD engine, with at most one block (plus its packed table) and
        one row/block token group in memory at a time."""
        import jax.numpy as jnp
        faults.fire("step", f"iter:{self.iteration_count},engine:streaming")
        m_, s_, d_ = (self.num_workers, self.blocks_per_worker,
                      self.data_parallel)
        k, cap = self.num_topics, self.capacity
        travel = self.table_lifetime == "iteration"
        alpha_j = jnp.asarray(self.alpha)
        beta_j = jnp.float32(self.beta)
        vbeta_j = jnp.float32(self.vbeta)
        if travel:
            from repro.core.mh import build_doc_tables
            # per-iteration doc tables from iteration-start cdk; word
            # tables are built lazily at each block's first residency
            for g in range(self.num_shards):
                dtab = np.asarray(build_doc_tables(
                    jnp.asarray(_load_npy(self._cdk_path(g))), alpha_j))
                _save_npy(self._p("tables", f"doc_g{g:04d}.npy"), dtab)
            for f in os.listdir(self._p("tables")):
                if f.startswith("word_"):
                    os.remove(self._p("tables", f))

        ck = _load_npy(self._p("state", "ck.npy"))
        for r in range(self.num_rounds):
            faults.fire("round", f"iter:{self.iteration_count},round:{r},")
            ck_frozen = ck.astype(np.int32)
            delta = np.zeros(k, np.int64)
            # engine-identical uniforms: random((B, R, cap)) consumed
            # round-major then row-major — drawn per round here so memory
            # stays one round's worth
            u_r = self._rng.random((self.num_shards, cap), np.float32)
            # process rows grouped by model position so each round's M
            # distinct blocks are loaded, updated by their D replicas, and
            # committed ONE AT A TIME (the memory bound); within a round
            # the tasks are independent given the frozen inputs, so the
            # regrouping cannot change any draw
            for m in range(m_):
                blk_id = sched.block_for(m, r, m_, s_)
                blk_store = self._load_block_store(blk_id)
                if self._store_sampler_fn is not None:
                    # STORE-NATIVE path (DESIGN.md §16): the sampler
                    # consumes the lane layout directly — no [Vb, K]
                    # buffer exists; the block fold is the store's exact
                    # integer token-delta apply at the round boundary
                    dev = blk_store.device_operands()
                    dev_j = tuple(jnp.asarray(dev[n]) for n in
                                  ("tail_topics", "tail_counts",
                                   "over_pad", "row_map"))
                    tok_w, tok_old, tok_new = [], [], []
                    for d in range(d_):
                        g = d * m_ + m
                        lay = _load_npz(self._lay_path(g, blk_id))
                        z = _load_npy(self._z_path(g, blk_id))
                        cdk = _load_npy(self._cdk_path(g))
                        out = self._store_sampler_fn(
                            jnp.asarray(cdk), *dev_j,
                            jnp.asarray(ck_frozen),
                            jnp.asarray(lay["doc"]),
                            jnp.asarray(lay["woff"]), jnp.asarray(z),
                            jnp.asarray(lay["mask"]),
                            jnp.asarray(u_r[g]), alpha_j, beta_j,
                            vbeta_j)
                        z_new = np.asarray(out[2])
                        _save_npy(self._cdk_path(g), np.asarray(out[0]))
                        _save_npy(self._z_path(g, blk_id), z_new)
                        delta += (np.asarray(out[1]).astype(np.int64)
                                  - ck_frozen)
                        msk = lay["mask"]
                        tok_w.append(lay["woff"][msk])
                        tok_old.append(z[msk])
                        tok_new.append(z_new[msk])
                    if tok_w:
                        blk_store.apply_token_delta(
                            np.concatenate(tok_w),
                            np.concatenate(tok_old),
                            np.concatenate(tok_new))
                    blk_store.save(self._block_stem(blk_id))
                    continue
                # dense-view path: DenseStore's to_dense IS the resident
                # array (free); a compressed store densifies here — an
                # EXPLICIT conversion, echoed by store_note()
                blk_frozen = blk_store.to_dense()
                blk_delta = np.zeros_like(blk_frozen)
                if travel:
                    wpath = self._p("tables", f"word_b{blk_id:04d}.npy")
                    if not os.path.exists(wpath):   # first residency
                        from repro.core.mh import build_word_tables
                        wtab = np.asarray(build_word_tables(
                            jnp.asarray(blk_frozen), beta_j))
                        _save_npy(wpath, wtab)
                    else:
                        wtab = _load_npy(wpath)
                for d in range(d_):
                    g = d * m_ + m
                    lay = _load_npz(self._lay_path(g, blk_id))
                    z = _load_npy(self._z_path(g, blk_id))
                    cdk = _load_npy(self._cdk_path(g))
                    args = (jnp.asarray(cdk), jnp.asarray(blk_frozen),
                            jnp.asarray(ck_frozen),
                            jnp.asarray(lay["doc"]),
                            jnp.asarray(lay["woff"]), jnp.asarray(z),
                            jnp.asarray(lay["mask"]),
                            jnp.asarray(u_r[g]), alpha_j, beta_j, vbeta_j)
                    if travel:
                        dtab = _load_npy(
                            self._p("tables", f"doc_g{g:04d}.npy"))
                        args += (jnp.asarray(wtab), jnp.asarray(dtab))
                    out = self._sampler_fn(*args)
                    _save_npy(self._cdk_path(g), np.asarray(out[0]))
                    _save_npy(self._z_path(g, blk_id), np.asarray(out[3]))
                    blk_delta += np.asarray(out[1]) - blk_frozen
                    delta += (np.asarray(out[2]).astype(np.int64)
                              - ck_frozen)
                self._make_store(blk_frozen + blk_delta).save(
                    self._block_stem(blk_id))
            ck = ck + delta
            _save_npy(self._p("state", "ck.npy"), ck)
        self.iteration_count += 1
        self._write_progress()

    def run(self, num_iterations: int,
            checkpoint_every: int = 0) -> List[dict]:
        history = []
        for i in range(num_iterations):
            self.step()
            history.append({"iteration": self.iteration_count})
            if checkpoint_every and (i + 1) % checkpoint_every == 0:
                self.save_checkpoint()
        return history

    # -- observation -------------------------------------------------------
    def memory_report(self, scan_store: bool = True) -> dict:
        """Resident-footprint report.  ``resident_block_bytes`` /
        ``total_model_bytes`` stay the DENSE formulas (the paper's
        capacity denominator, and what a densify would cost); the
        ``store_*`` keys report what the block store ACTUALLY occupies —
        max-over-blocks resident bytes plus aggregated head/tail
        occupancy and overflow-row counters (``scan_store=False`` skips
        the block scan for cheap formula-only calls)."""
        vb, k = self.partition.block_size, self.num_topics
        rep = {
            "num_workers": self.num_workers,
            "blocks_per_worker": self.blocks_per_worker,
            "data_parallel": self.data_parallel,
            "num_blocks": self.num_blocks,
            "resident_block_shape": (vb, k),
            "resident_block_bytes": vb * k * 4,
            "total_model_bytes": self.vocab_size * k * 4,
            "row_group_bytes": self.capacity * 4 * 4,
            "row_cdk_bytes": self.dloc * k * 4,
            "store": self.store_kind,
        }
        if scan_store:
            agg = {"head_rows": 0, "tail_rows": 0, "overflow_rows": 0,
                   "tail_nnz": 0}
            resident = total = 0
            for b in range(self.num_blocks):
                occ = self._load_block_store(b).occupancy()
                for key in agg:
                    agg[key] += occ[key]
                resident = max(resident, occ["nbytes_resident"])
                total += occ["nbytes_resident"]
            rep["store_occupancy"] = agg
            rep["resident_store_bytes"] = resident
            rep["total_store_bytes"] = total
        return rep

    def gather_counts(self):
        """Reassemble the global model — materializes ``[V, K]``; for
        tests and small runs (use :meth:`save_snapshot_sharded` at
        scale)."""
        from repro.core.counts import CountState
        import jax.numpy as jnp
        vb, k = self.partition.block_size, self.num_topics
        ckt = np.zeros((self.partition.padded_vocab, k), np.int32)
        for b in range(self.num_blocks):
            ckt[b * vb:(b + 1) * vb] = self._load_block_store(b).to_dense()
        ckt = ckt[:self.vocab_size]
        cdk = np.zeros((self.num_docs, k), np.int32)
        for g in range(self.num_shards):
            docs = _load_npy(
                self._p("static", "rows", f"row{g:04d}_docs.npy"))
            real = docs >= 0
            cdk[docs[real]] = _load_npy(self._cdk_path(g))[:real.sum()]
        ck = ckt.sum(axis=0).astype(np.int32)
        return CountState(jnp.asarray(cdk), jnp.asarray(ckt),
                          jnp.asarray(ck))

    def assignments(self) -> np.ndarray:
        """Current z in original token order (streamed, O(N) output)."""
        z = np.zeros(self.num_tokens, np.int32)
        for g in range(self.num_shards):
            for b in range(self.num_blocks):
                lay = _load_npz(self._lay_path(g, b))
                msk = lay["mask"]
                z[lay["tid"][msk]] = _load_npy(self._z_path(g, b))[msk]
        return z

    def log_likelihood(self) -> float:
        from repro.core.likelihood import (doc_log_likelihood,
                                           word_log_likelihood)
        state = self.gather_counts()
        return float(word_log_likelihood(state.ckt, state.ck, self.beta)
                     + doc_log_likelihood(state.cdk, self.alpha))

    def snapshot(self, build_tables: bool = False):
        """In-memory frozen serving snapshot (small runs)."""
        from repro.core.infer import ModelSnapshot
        state = self.gather_counts()
        return ModelSnapshot.from_counts(
            np.asarray(state.ckt), np.asarray(state.ck), self.alpha,
            self.beta, build_tables=build_tables)

    def save_snapshot_sharded(self, out_dir: str) -> str:
        """Streaming snapshot export: one block store at a time is copied
        into a sharded snapshot directory (`core/infer.py`
        ``load_snapshot_rows`` serves from it row-restricted) — the full
        ``[V, K]`` model is never materialized.  A dense-store run writes
        the unchanged ``sharded-snapshot-v1`` layout (plain ``.npy``
        blocks); a compressed store exports its own records under format
        v2 with the store kind stamped in meta.json."""
        os.makedirs(out_dir, exist_ok=True)
        ck = np.zeros(self.num_topics, np.int64)
        for b in range(self.num_blocks):
            st = self._load_block_store(b)
            st.save(os.path.join(out_dir, f"block_{b:05d}"))
            ck += st.col_sums()
        integrity.save_npy(os.path.join(out_dir, "ck.npy"),
                           ck.astype(np.int64))
        meta = {
            "format": ("sharded-snapshot-v1"
                       if self.store_kind == "dense"
                       else "sharded-snapshot-v2"),
            "store": self.store_kind,
            "vocab_size": self.vocab_size,
            "num_topics": self.num_topics,
            "num_blocks": self.num_blocks,
            "block_size": self.partition.block_size,
            "alpha": (self.alpha_scalar if self.alpha_scalar is not None
                      else self.alpha.tolist()),
            "beta": self.beta,
            "iteration": self.iteration_count,
        }
        # meta.json is published LAST and atomically: its presence is the
        # completeness signal the serve-side watcher keys on (§15)
        integrity.atomic_write_json(os.path.join(out_dir, "meta.json"),
                                    meta, indent=1, checksum=True)
        return out_dir
