"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

The LM backbone: 32L, d 4096, 32H GQA kv=8, d_ff 14336, vocab 32000.
Vision tower + projector are STUBBED per the assignment: ``input_specs``
provides 2880 pre-projected anyres patch embeddings (5 tiles × 576) that
are concatenated ahead of the text tokens."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    rope_theta=1_000_000.0,
    num_patch_embeds=2880,
    norm="rms",
    tie_embeddings=False,
    subquadratic_decode=False,
)
