"""Structural layer of the hybrid sparse sampler family (DESIGN.md §12).

The sparse family shares the MH family's two-layer verification story
(`tests/test_mh_stats.py` docstring): the draws are frozen-count batched
— distribution-equal but not trajectory-equal to exact ``scan`` — so the
distributional claim lives in `tests/test_sparse_stats.py`, while
everything around the draw is anchored bitwise here:

* the mass DECOMPOSITION is algebra, not sampling: word-lane + doc-lane
  + perturbed-dense segments must reassemble the eq.-(1) conditional of
  the frozen counts exactly (up to f32 association), head and tail words
  alike;
* engine runs replay draw-for-draw against the `kvstore` host oracle —
  which resolves the SAME jitted sampler from the registry — across the
  (D, M, S) grid;
* the vmap and shard_map backends agree bitwise, and ``sparse_pallas``
  is a drop-in for ``sparse`` (the Pallas lane kernel == the jnp lane
  block), including under a tiny ``wcap`` that forces the dense-head
  fallback;
* serving: the sparse fold-in equals its serial host replay, and the
  pallas alias draws identically.
"""
import numpy as np
import pytest

from repro.core.engine.api import ModelParallelLDA
from repro.core.engine.rounds import available_samplers
from repro.core.infer import fold_in, pack_queries
from repro.core.kvstore import HostModelParallelLDA, fold_in_oracle
from repro.core.sampler import conditional_eq1
from repro.core.sparse_device import (default_sparse_args, lane_masses_jnp,
                                      sparse_prologue)
from repro.data.synthetic import synthetic_corpus

K = 8
# wcap = 2 forces most vocabulary rows over the head threshold (dense-
# head fallback path); dcap = K keeps the doc-lane bound exact.
HEAD_HEAVY_ARGS = (("dcap", K), ("wcap", 2))


@pytest.fixture(scope="module")
def sparse_corpus():
    corpus, _, _ = synthetic_corpus(
        num_docs=40, vocab_size=120, num_topics=K, doc_len=30,
        alpha=0.5, seed=0, peaked=False)
    return corpus


# ---------------------------------------------------------------------------
# The decomposition is exact algebra on the frozen counts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wcap", [4, 64])
def test_sparse_mass_decomposition_matches_conditional(wcap):
    """Reassembling the three CDF segments — word lanes, doc lanes, and
    the δ-perturbed dense row — recovers the eq.-(1) conditional of the
    round-frozen counts with the ¬dn exclusion, for every token, at a
    wcap that mixes head/tail words AND one where every word is tail."""
    import jax.numpy as jnp
    rng = np.random.default_rng(3)
    k, vb, dloc = 12, 8, 5
    # long-tail word profile BY CONSTRUCTION: the hot rows exceed
    # wcap = 4 distinct topics, the rare rows cannot
    occ = np.array([30, 25, 15, 8, 3, 2, 2, 1])
    t = int(occ.sum())
    doc = rng.integers(0, dloc, t).astype(np.int32)
    woff = np.repeat(np.arange(vb, dtype=np.int32), occ)
    z = rng.integers(0, k, t).astype(np.int32)
    mask = rng.random(t) < 0.9                  # some padding tokens too
    cdk = np.zeros((dloc, k), np.int32)
    ckt = np.zeros((vb, k), np.int32)
    np.add.at(cdk, (doc[mask], z[mask]), 1)
    np.add.at(ckt, (woff[mask], z[mask]), 1)
    ck = ckt.sum(0) + rng.integers(0, 5, k)     # + other blocks' tokens
    alpha = rng.random(k).astype(np.float32) + 0.05
    beta, vbeta = np.float32(0.01), np.float32(0.01 * vb)
    dcap = k

    ops = sparse_prologue(jnp.asarray(cdk), jnp.asarray(ckt),
                          jnp.asarray(ck.astype(np.int32)),
                          jnp.asarray(doc), jnp.asarray(woff),
                          jnp.asarray(z), jnp.asarray(mask),
                          jnp.asarray(alpha), beta, vbeta,
                          dcap=dcap, wcap=wcap)
    wcs, sw, dlcs, sd = lane_masses_jnp(ops["wops"], ops["dops"],
                                        ops["h_t"], jnp.asarray(z),
                                        jnp.asarray(mask), beta, vbeta)
    h_t = np.asarray(ops["h_t"])
    if wcap == 4:
        assert h_t.any() and (~h_t).any(), "want a head/tail mixture"
    wval = np.diff(np.asarray(wcs), prepend=0.0)        # lane masses back
    dval = np.diff(np.asarray(dlcs), prepend=0.0)
    dmass = np.diff(np.asarray(ops["dcs"]), prepend=0.0)  # [Vb, K] dense
    delta = np.asarray(ops["delta"])
    wkk = np.asarray(ops["wops"]["kk"])
    wvalid = np.asarray(ops["wops"]["valid"])
    dkk = np.asarray(ops["dops"]["kk"])
    dvalid = np.asarray(ops["dops"]["valid"])

    for i in range(t):
        p = dmass[woff[i]].copy()
        p[z[i]] += delta[i]
        np.add.at(p, wkk[i][wvalid[i]], wval[i][wvalid[i]])
        np.add.at(p, dkk[i][dvalid[i]], dval[i][dvalid[i]])
        e = int(mask[i])                        # ¬dn exclusion at z0
        ref = np.asarray(conditional_eq1(
            jnp.asarray(ckt[woff[i]] - e * (np.arange(k) == z[i])),
            jnp.asarray(cdk[doc[i]] - e * (np.arange(k) == z[i])),
            jnp.asarray(ck - e * (np.arange(k) == z[i])),
            jnp.asarray(alpha), beta, vbeta))
        # tolerance: lane masses are reconstructed as diffs of the f32
        # cumsum, which loses low bits against a large running prefix
        np.testing.assert_allclose(p, ref, rtol=1e-3, atol=1e-6)
        # the drawable total equals the segment totals the draw uses
        tot = float(np.asarray(sw)[i] + np.asarray(sd)[i]
                    + np.asarray(ops["sdense"])[i])
        np.testing.assert_allclose(tot, ref.sum(), rtol=5e-5)


# ---------------------------------------------------------------------------
# Engine == host oracle, draw for draw, across the (D, M, S) grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,s,d", [
    (2, 1, 1), (2, 2, 1), (2, 1, 2), (2, 2, 2),
])
def test_sparse_host_oracle_replay_draw_for_draw(sparse_corpus, m, s, d):
    """Device sparse == kvstore host-oracle sparse, bit for bit: the
    oracle resolves the SAME jitted sampler (and the same
    `default_sparse_args` derivation) from the registry, so engine runs
    replay exactly at every pipeline/data-replication geometry."""
    lda = ModelParallelLDA(sparse_corpus, K, num_workers=m, seed=0,
                           sampler_mode="sparse", blocks_per_worker=s,
                           data_parallel=d)
    host = HostModelParallelLDA(sparse_corpus, K, num_workers=m, seed=0,
                                sampler="sparse", ck_sync="round",
                                blocks_per_worker=s, data_parallel=d)
    for _ in range(2):
        lda.step()
        host.step()
    np.testing.assert_array_equal(lda.assignments(), host.assignments())
    np.testing.assert_array_equal(np.asarray(lda.gather_counts().ckt),
                                  host.gather_ckt())


@pytest.mark.parametrize("sampler", ["sparse", "sparse_pallas"])
def test_sparse_backends_bit_identical(sparse_corpus, sampler):
    """vmap and shard_map run the same sparse worker_round: bitwise-equal
    states after two iterations, for both family members."""
    import jax
    if len(jax.devices()) < 2:
        pytest.skip("needs 2 devices")
    a = ModelParallelLDA(sparse_corpus, K, num_workers=2, seed=0,
                         sampler_mode=sampler, backend="vmap")
    b = ModelParallelLDA(sparse_corpus, K, num_workers=2, seed=0,
                         sampler_mode=sampler, backend="shard_map")
    for _ in range(2):
        a.step()
        b.step()
    for x, y in [(a.state.cdk, b.state.cdk), (a.state.ckt, b.state.ckt),
                 (a.state.ck_local, b.state.ck_local),
                 (a.state.z, b.state.z)]:
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("sampler_args", [None, HEAD_HEAVY_ARGS])
def test_sparse_pallas_engine_equals_sparse_engine(sparse_corpus,
                                                   sampler_args):
    """``sparse_pallas`` is a drop-in: same chain bit for bit (the Pallas
    lane kernel == the jnp lane block around the shared prologue and
    epilogue) — at the derived caps AND at a head-heavy wcap = 2 where
    most words overflow into the dense-head fallback."""
    a = ModelParallelLDA(sparse_corpus, K, num_workers=2, seed=0,
                         sampler_mode="sparse", sampler_args=sampler_args)
    b = ModelParallelLDA(sparse_corpus, K, num_workers=2, seed=0,
                         sampler_mode="sparse_pallas",
                         sampler_args=sampler_args)
    for _ in range(2):
        a.step()
        b.step()
    np.testing.assert_array_equal(np.asarray(a.state.z),
                                  np.asarray(b.state.z))
    np.testing.assert_array_equal(np.asarray(a.state.ckt),
                                  np.asarray(b.state.ckt))
    np.testing.assert_array_equal(np.asarray(a.state.cdk),
                                  np.asarray(b.state.cdk))


# ---------------------------------------------------------------------------
# Serving: sparse fold-in == host replay, pallas alias identical
# ---------------------------------------------------------------------------

def _snapshot_and_queries(corpus, q=4, t=18, sweeps=3):
    lda = ModelParallelLDA(corpus, K, num_workers=2, seed=0)
    lda.run(2)
    snap = lda.snapshot()
    rng = np.random.default_rng(1)
    docs = [rng.integers(0, corpus.vocab_size,
                         size=int(n)).astype(np.int32)
            for n in rng.integers(3, t + 1, size=q)]
    word, mask = pack_queries(docs, t_pad=t)
    z0 = rng.integers(0, K, size=word.shape).astype(np.int32)
    u = rng.random((sweeps, *word.shape), np.float32)
    return snap, word, mask, z0, u


def test_sparse_fold_in_matches_host_oracle(sparse_corpus):
    snap, word, mask, z0, u = _snapshot_and_queries(sparse_corpus)
    res = fold_in(snap, word, mask, sampler="sparse", z0=z0, u=u)
    cdk_o, z_o = fold_in_oracle(snap, word, mask, z0, u, sampler="sparse")
    np.testing.assert_array_equal(res.z, z_o)
    np.testing.assert_array_equal(res.cdk, cdk_o)


def test_sparse_fold_in_pallas_alias_bitwise(sparse_corpus):
    """At serve time the model is frozen, so one jnp implementation
    serves both names — the alias must be draw-identical."""
    snap, word, mask, z0, u = _snapshot_and_queries(sparse_corpus)
    a = fold_in(snap, word, mask, sampler="sparse", z0=z0, u=u)
    b = fold_in(snap, word, mask, sampler="sparse_pallas", z0=z0, u=u)
    np.testing.assert_array_equal(a.z, b.z)
    np.testing.assert_array_equal(a.cdk, b.cdk)


# ---------------------------------------------------------------------------
# Registry / CLI plumbing
# ---------------------------------------------------------------------------

def test_sparse_registered_and_cli_choices():
    from repro.launch.samplers import (infer_sampler_choices,
                                       resolve_sampler_choice,
                                       train_sampler_choices)
    regs = available_samplers()
    assert "sparse" in regs and "sparse_pallas" in regs
    for choices in (train_sampler_choices(), infer_sampler_choices()):
        assert {"sparse", "sparse_pallas", "auto"} <= set(choices)
    import jax
    if jax.default_backend() != "tpu":
        with pytest.raises(SystemExit, match="interpret mode"):
            resolve_sampler_choice("sparse_pallas")
        assert resolve_sampler_choice("sparse_pallas",
                                      force=True) == "sparse_pallas"
        assert resolve_sampler_choice("auto") == "mh"
    assert resolve_sampler_choice("sparse") == "sparse"


def test_default_sparse_args_derivation():
    assert default_sparse_args(4096, 16) == (("dcap", 16), ("wcap", 32))
    assert default_sparse_args(8, 300) == (("dcap", 8), ("wcap", 8))
    # hashable — rides jit cache keys and facade attributes
    hash(default_sparse_args(64, 64))
