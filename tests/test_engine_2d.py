"""Hybrid data×model parallelism (DESIGN.md §8) — bit-exactness harness.

The 2D ``(data, model)`` engine makes three equivalence claims, each
enforced here bitwise (not statistically):

(i)   **D = 1 is the old engine.**  With ``data_parallel=1`` both backends
      must reproduce the FROZEN pre-2D implementation
      (``core/engine/reference.py``) array-for-array — the 2D
      generalization is not allowed to perturb the 1D semantics.
(ii)  **D > 1 is the serial KV-store architecture.**  With per-round
      reconciliation (``ck_sync="round"``) the engine equals the host
      Scheduler/Workers/KV-store oracle replayed with the same uniform
      stream, for D ∈ {2, 4} and S ∈ {1, 2}.
(iii) **The backends agree.**  vmap and shard_map produce identical
      states on the 2×2 (data, model) mesh (faked devices, main suite —
      no subprocess needed thanks to the conftest XLA flag).

Plus the structural invariants: gathered counts rebuild from assignments
at any (D, M, S), and the degenerate geometries collapse to the expected
algorithms (D=1 → 1D ring, M=1 → AD-LDA).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schedule as sched
from repro.core.counts import build_counts, check_invariants
from repro.core.data_parallel import adlda_engine
from repro.core.engine import reference
from repro.core.kvstore import HostModelParallelLDA
from repro.core.model_parallel import ModelParallelLDA

STATE_FIELDS = ("cdk", "ckt", "block_id", "ck_synced", "ck_local", "z")


def _assert_states_equal(a, b, ctx=""):
    for f in STATE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{ctx}: state.{f} diverged")


# ---------------------------------------------------------------------------
# (i) D = 1 equals the frozen 1D engine — vmap backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [1, 2])
def test_d1_vmap_equals_frozen_1d_reference(tiny_corpus, s):
    """The generalized iteration at ``data_parallel=1`` reproduces the
    pre-2D vmap implementation bit for bit (including the per-round
    Fig-3 error series)."""
    corpus, _, _ = tiny_corpus
    lda = ModelParallelLDA(corpus, num_topics=8, num_workers=4, seed=13,
                           blocks_per_worker=s)
    ref = ModelParallelLDA(corpus, num_topics=8, num_workers=4, seed=13,
                           blocks_per_worker=s)
    for _ in range(2):
        lda.step()
        u = ref._uniforms()          # same rng stream as lda's step
        ref.state, errs = reference.iteration_vmap_1d(
            ref.state, u, ref.doc, ref.woff, ref.mask, ref.alpha,
            jnp.float32(ref.beta), jnp.float32(ref.vbeta))
    _assert_states_equal(lda.state, ref.state, f"vmap D=1 S={s}")
    np.testing.assert_allclose(lda.round_errors,
                               np.asarray(errs).reshape(-1), rtol=1e-6)


# ---------------------------------------------------------------------------
# (i) D = 1 equals the frozen 1D engine — shard_map backend (4 devices)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [1, 2])
def test_d1_shard_map_2d_path_equals_frozen_1d(tiny_corpus, mesh1x4, s):
    """The 2D shard_map code path on a (1, 4) mesh — psum over a size-1
    data axis — equals the frozen 1D shard_map implementation run on the
    plain 4-worker ring."""
    import jax
    from jax.sharding import Mesh

    corpus, _, _ = tiny_corpus
    two_d = ModelParallelLDA(corpus, num_topics=8, num_workers=4, seed=5,
                             blocks_per_worker=s, backend="shard_map",
                             mesh=mesh1x4, axis="model")
    ref = ModelParallelLDA(corpus, num_topics=8, num_workers=4, seed=5,
                           blocks_per_worker=s)   # state + rng source
    ring = Mesh(np.array(jax.devices()[:4]), ("w",))
    ref_fn = reference.make_shard_map_iteration_1d(ring, "w", "scan", True)
    for _ in range(2):
        two_d.step()
        s_ = ref.state
        u = ref._uniforms()
        out = ref_fn(s_.cdk, s_.ckt, s_.block_id, s_.ck_synced,
                     s_.ck_local, s_.z, jnp.swapaxes(u, 0, 1), ref.doc,
                     ref.woff, ref.mask, ref.alpha,
                     jnp.float32(ref.beta), jnp.float32(ref.vbeta))
        ref.state = type(s_)(*out[:6])
        errs = out[6]
    _assert_states_equal(two_d.state, ref.state, f"shard_map D=1 S={s}")
    np.testing.assert_allclose(two_d.round_errors,
                               np.asarray(errs).reshape(-1), atol=1e-6)


# ---------------------------------------------------------------------------
# (ii) D > 1 with round-sync equals the host KV-store oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,m,s", [(2, 2, 1), (2, 2, 2), (4, 2, 1),
                                   (2, 4, 1)])
def test_hybrid_engine_equals_host_oracle_bitexact(tiny_corpus, d, m, s):
    """The 2D engine equals the paper's Figure-1 architecture extended
    with D doc replicas — same uniforms, same kernel, same frozen-per-round
    staleness model — bit for bit: word-topic table, doc-topic shards,
    and every assignment."""
    corpus, _, _ = tiny_corpus
    eng = ModelParallelLDA(corpus, num_topics=8, num_workers=m, seed=7,
                           blocks_per_worker=s, data_parallel=d)
    host = HostModelParallelLDA(corpus, num_topics=8, num_workers=m,
                                seed=7, blocks_per_worker=s,
                                sampler="scan", ck_sync="round",
                                data_parallel=d)
    for _ in range(2):
        eng.step()
        host.step()
    np.testing.assert_array_equal(np.asarray(eng.gather_counts().ckt),
                                  host.gather_ckt())
    np.testing.assert_array_equal(eng.assignments(), host.assignments())
    np.testing.assert_array_equal(
        np.asarray(eng.state.cdk),
        np.stack([w.cdk for w in host.workers]))


# ---------------------------------------------------------------------------
# (iii) vmap == shard_map on the 2×2 (data, model) mesh
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [1, 2])
def test_hybrid_shard_map_equals_vmap(tiny_corpus, mesh2d, s):
    corpus, _, _ = tiny_corpus
    a = ModelParallelLDA(corpus, num_topics=8, num_workers=2, seed=1,
                         data_parallel=2, blocks_per_worker=s)
    b = ModelParallelLDA(corpus, num_topics=8, num_workers=2, seed=1,
                         data_parallel=2, blocks_per_worker=s,
                         backend="shard_map", mesh=mesh2d, axis="model")
    for _ in range(2):
        a.step()
        b.step()
    _assert_states_equal(a.state, b.state, f"2D vmap vs shard_map S={s}")
    np.testing.assert_allclose(a.round_errors, b.round_errors, atol=1e-6)


# ---------------------------------------------------------------------------
# invariants and degenerate geometries
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,m,s", [(2, 2, 1), (2, 3, 2), (4, 1, 1),
                                   (3, 2, 2)])
def test_hybrid_invariants_and_z_consistency(tiny_corpus, d, m, s):
    """Gathered counts at any grid geometry rebuild exactly from the
    gathered assignments — replica copies cannot silently diverge, since
    gather reads replica 0's blocks but EVERY replica's assignments."""
    corpus, _, _ = tiny_corpus
    lda = ModelParallelLDA(corpus, num_topics=8, num_workers=m, seed=2,
                           blocks_per_worker=s, data_parallel=d)
    lda.run(2)
    state = lda.gather_counts()
    check_invariants(state, corpus.num_tokens)
    z = lda.assignments()
    rebuilt = build_counts(corpus.doc, corpus.word, z, corpus.num_docs,
                           corpus.vocab_size, 8)
    np.testing.assert_array_equal(np.asarray(rebuilt.ckt),
                                  np.asarray(state.ckt))
    np.testing.assert_array_equal(np.asarray(rebuilt.cdk),
                                  np.asarray(state.cdk))


def test_replica_block_copies_identical_at_boundaries(tiny_corpus):
    """The delta psum keeps all D copies of every block slot bitwise equal
    at iteration boundaries (the §8 invariant that makes replica 0 'the'
    model)."""
    corpus, _, _ = tiny_corpus
    d, m = 2, 2
    lda = ModelParallelLDA(corpus, num_topics=8, num_workers=m, seed=4,
                           blocks_per_worker=2, data_parallel=d)
    lda.run(2)
    ckt = np.asarray(lda.state.ckt).reshape(d, m, *lda.state.ckt.shape[1:])
    bid = np.asarray(lda.state.block_id).reshape(d, m, -1)
    for rep in range(1, d):
        np.testing.assert_array_equal(ckt[rep], ckt[0])
        np.testing.assert_array_equal(bid[rep], bid[0])


def test_m1_degenerates_to_adlda(tiny_corpus):
    """M=1, S=1: one vocabulary block, ONE round per iteration, every
    replica holds the full table — the engine IS AD-LDA with one
    reconciliation per iteration, and its pre-sync delta error is positive
    like the DP baseline's (the staleness the paper plots in Fig 3)."""
    corpus, _, _ = tiny_corpus
    lda = adlda_engine(corpus, num_topics=8, num_replicas=4, seed=9)
    assert lda.num_rounds == 1
    assert lda.num_blocks == 1
    # full table resident on every replica: the DP memory layout
    assert lda.resident_block_rows >= corpus.vocab_size
    lda.run(2)
    assert lda.delta_error() > 0
    check_invariants(lda.gather_counts(), corpus.num_tokens)


def test_hybrid_likelihood_ascends(tiny_corpus):
    corpus, _, _ = tiny_corpus
    lda = ModelParallelLDA(corpus, num_topics=8, num_workers=2, seed=5,
                           data_parallel=2, blocks_per_worker=2)
    ll0 = lda.log_likelihood()
    hist = lda.run(6)
    assert hist[-1]["log_likelihood"] > ll0 + 1000


def test_hybrid_memory_report(tiny_corpus):
    """The two levers are orthogonal: resident block = ceil(V/(S·M))×K
    regardless of D; distributed model bytes scale with D."""
    corpus, _, _ = tiny_corpus
    k = 8
    rep1 = ModelParallelLDA(corpus, k, 2, blocks_per_worker=2).memory_report()
    rep2 = ModelParallelLDA(corpus, k, 2, blocks_per_worker=2,
                            data_parallel=3).memory_report()
    assert rep1["resident_block_bytes"] == rep2["resident_block_bytes"]
    assert rep2["num_shards"] == 6
    assert rep2["distributed_model_bytes"] == 3 * rep2["replica_model_bytes"]
    vb = -(-corpus.vocab_size // 4)
    assert rep2["resident_block_shape"] == (vb, k)


def test_hybrid_constructor_rejects_ill_formed_configs(tiny_corpus, mesh2d):
    """Undefined or silently-corrupting configurations fail at
    construction: sync_ck=False at D>1 (no well-defined replica
    semantics — the host oracle rejects it too) and meshes whose axes
    don't match the (D, M) grid (rows would be silently dropped)."""
    corpus, _, _ = tiny_corpus
    with pytest.raises(ValueError, match="sync_ck"):
        ModelParallelLDA(corpus, num_topics=8, num_workers=2,
                         data_parallel=2, sync_ck=False)
    with pytest.raises(ValueError, match="data_parallel"):
        ModelParallelLDA(corpus, num_topics=8, num_workers=2,
                         data_parallel=0)
    with pytest.raises(ValueError, match="mesh axes"):
        # R = 4·2 = 8 rows cannot live on a 2×2 mesh
        ModelParallelLDA(corpus, num_topics=8, num_workers=2,
                         data_parallel=4, backend="shard_map",
                         mesh=mesh2d, axis="model")
    with pytest.raises(ValueError, match="mesh axes"):
        # D = 2 with a mesh that lacks the data axis entirely
        import jax
        from jax.sharding import Mesh
        ring = Mesh(np.array(jax.devices()[:4]), ("w",))
        ModelParallelLDA(corpus, num_topics=8, num_workers=2,
                         data_parallel=2, backend="shard_map", mesh=ring)


def test_hybrid_uses_2d_schedule_table(tiny_corpus):
    """The engine's per-round resident blocks follow schedule_table_2d:
    aligned across replicas, disjoint along model."""
    corpus, _, _ = tiny_corpus
    d, m, s = 2, 2, 2
    lda = ModelParallelLDA(corpus, num_topics=8, num_workers=m, seed=0,
                           blocks_per_worker=s, data_parallel=d)
    table = sched.schedule_table_2d(d, m, s)
    res = np.asarray(lda.state.block_id)[:, 0].reshape(d, m)
    np.testing.assert_array_equal(res, table[0])
