import os

# Fake 4 host devices BEFORE anything imports jax, so shard_map tests —
# including the hybrid 2D (data, model) engine tests — run inside the main
# suite instead of only via subprocess scripts.  The flag only affects the
# host (CPU) platform and is a no-op for the vmap/single-device tests; an
# explicit pre-set count (e.g. the 512-device dry-run subprocesses, which
# overwrite XLA_FLAGS themselves) is respected.
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")

# ruff: noqa: E402
import numpy as np
import pytest

from repro.data.synthetic import synthetic_corpus


@pytest.fixture(scope="session")
def tiny_corpus():
    """~1.2k tokens, 40 docs, V=120, planted 8-topic structure."""
    corpus, phi, theta = synthetic_corpus(
        num_docs=40, vocab_size=120, num_topics=8, doc_len=30, seed=0)
    return corpus, phi, theta


@pytest.fixture(scope="session")
def small_corpus():
    """~6k tokens, 120 docs, V=400 — big enough for convergence ordering."""
    corpus, phi, theta = synthetic_corpus(
        num_docs=120, vocab_size=400, num_topics=10, doc_len=50, seed=7)
    return corpus, phi, theta


def _require_devices(n: int):
    import jax
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} devices, have {len(jax.devices())} "
                    "(XLA_FLAGS was pre-set without a faked device count)")


@pytest.fixture(scope="session")
def mesh2d():
    """2×2 (data, model) mesh over the faked host devices — the hybrid
    engine's shard_map tests run on this inside the main suite."""
    from repro.launch.mesh import make_local_mesh
    _require_devices(4)
    return make_local_mesh(2, 2)


@pytest.fixture(scope="session")
def mesh1x4():
    """1×4 (data, model) mesh: exercises the 2D code path at D = 1 against
    the frozen 1D reference on the same four devices."""
    from repro.launch.mesh import make_local_mesh
    _require_devices(4)
    return make_local_mesh(1, 4)


def make_random_counts(rng, num_docs, vocab, topics, tokens):
    doc = rng.integers(0, num_docs, tokens).astype(np.int32)
    word = rng.integers(0, vocab, tokens).astype(np.int32)
    z = rng.integers(0, topics, tokens).astype(np.int32)
    return doc, word, z
