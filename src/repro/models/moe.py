"""Mixture-of-Experts layer with capacity-bounded sort-based dispatch.

Experts are the transformer-side incarnation of the paper's disjoint model
blocks: each device owns a slice of the expert dimension, and tokens move
to the experts ("move data to the model block") rather than replicating the
expert weights — the same communication inversion the LDA engine performs
with word blocks (DESIGN.md §5).

Dispatch is static-shaped: tokens are ranked per expert by router
probability via a sort, the top ``capacity`` stay, the rest fall through on
the residual path.  Under pjit the gather from token-sharded activations to
expert-sharded slots lowers to the expert-parallel collective
(all-gather/all-to-all family), which the roofline pass measures.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (Params, cast, dense_init,
                                 shard_experts, swiglu)


def moe_params(keys, d_model: int, d_expert: int, num_experts: int,
               num_shared: int = 0, shared_d_ff: int = 0) -> Params:
    p = {
        "router": dense_init(keys(), (d_model, num_experts)),
        "w_gate": dense_init(keys(), (num_experts, d_model, d_expert)),
        "w_up": dense_init(keys(), (num_experts, d_model, d_expert)),
        "w_down": dense_init(keys(), (num_experts, d_expert, d_model)),
    }
    if num_shared > 0:
        ff = shared_d_ff or num_shared * d_expert
        p["shared"] = {
            "w_gate": dense_init(keys(), (d_model, ff)),
            "w_up": dense_init(keys(), (d_model, ff)),
            "w_down": dense_init(keys(), (ff, d_model)),
            "gate": dense_init(keys(), (d_model, 1)),
        }
    return p


def _router(p: Params, x2d: jax.Array, top_k: int):
    logits = (x2d @ cast(p["router"])).astype(jnp.float32)   # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)      # [N, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)
    return logits, probs, gate_vals, expert_ids


def load_balance_loss(probs: jax.Array, expert_ids: jax.Array,
                      num_experts: int) -> jax.Array:
    """Switch-style auxiliary loss: E · <fraction routed> · <router mass>."""
    counts = jnp.zeros((num_experts,), jnp.float32).at[
        expert_ids.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(counts.sum(), 1.0)
    mass = probs.mean(axis=0)
    return num_experts * jnp.sum(frac * mass)


def _route_group(gate_vals, expert_ids, num_experts: int, top_k: int,
                 capacity: int):
    """Slot assignment for ONE token group.  gate_vals/expert_ids: [T, k].
    Returns (slot_token [E, C], slot_gate [E, C], slot_used [E, C])."""
    t = gate_vals.shape[0]
    flat_expert = expert_ids.reshape(-1)                     # [T*k]
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t), top_k)
    # sort key: expert-major, best-gate-first inside an expert.  The ORDER
    # is a discrete routing decision — stop_gradient the key so autodiff
    # never differentiates through sort_key_val (gates re-enter below via a
    # plain gather, whose VJP is a scatter-add).
    sort_key = jax.lax.stop_gradient(
        flat_expert.astype(jnp.float32) * 2.0 - flat_gate)
    order = jnp.argsort(sort_key)
    se, sg, stok = (flat_expert[order], flat_gate[order], flat_token[order])
    # position within expert = index − first index of that expert
    idx = jnp.arange(se.shape[0])
    first_of_expert = jnp.full((num_experts,), t * top_k, jnp.int32).at[
        se].min(idx.astype(jnp.int32))
    pos_in_expert = idx.astype(jnp.int32) - first_of_expert[se]
    keep = pos_in_expert < capacity
    slot = jnp.where(keep, se * capacity + pos_in_expert,
                     num_experts * capacity)
    # scatter token ids / gates into [E * C (+1 overflow)] slot table
    slot_token = jnp.zeros((num_experts * capacity + 1,), jnp.int32).at[
        slot].set(stok.astype(jnp.int32))
    slot_gate = jnp.zeros((num_experts * capacity + 1,), jnp.float32).at[
        slot].set(jnp.where(keep, sg, 0.0))
    slot_used = jnp.zeros((num_experts * capacity + 1,), jnp.bool_).at[
        slot].set(keep)
    return (slot_token[:-1].reshape(num_experts, capacity),
            slot_gate[:-1].reshape(num_experts, capacity),
            slot_used[:-1].reshape(num_experts, capacity))


def moe_layer(p: Params, x: jax.Array, num_experts: int, top_k: int,
              capacity_factor: float = 1.25
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, T, d] -> (y, aux_loss).

    Routing/dispatch is GROUPED PER BATCH ROW so the expert-slot tensors
    keep a leading batch dimension and shard over the data axes; the expert
    dimension shards over ``model``.  (A flat global dispatch makes the
    capacity dimension unshardable — observed 154 GiB/device on
    qwen3-235b train_4k; grouped: every buffer is [B, E, C, ·] and shards
    on both mesh axes.  §Perf iteration "moe-grouped-dispatch".)
    """
    b, t, d = x.shape
    x2d = x.reshape(b * t, d)
    _, probs, gate_vals, expert_ids = _router(p, x2d, top_k)
    aux = load_balance_loss(probs, expert_ids, num_experts)
    capacity = max(int(top_k * t / num_experts * capacity_factor), 1)

    gv = gate_vals.reshape(b, t, top_k)
    ei = expert_ids.reshape(b, t, top_k)
    slot_token, slot_gate, slot_used = jax.vmap(
        lambda g, e: _route_group(g, e, num_experts, top_k, capacity))(gv, ei)
    # dispatch: gather tokens into [B, E, C, d] expert slots
    xe = jax.vmap(lambda xr, st: xr[st])(x.reshape(b, t, d), slot_token)
    xe = shard_experts(xe * slot_used[..., None].astype(x.dtype))
    # expert FFN, batched over (B, E); E is sharded over `model`
    h = swiglu(jnp.einsum("becd,edf->becf", xe, cast(p["w_gate"])),
               jnp.einsum("becd,edf->becf", xe, cast(p["w_up"])))
    ye = jnp.einsum("becf,efd->becd", h, cast(p["w_down"]))
    ye = ye * slot_gate[..., None].astype(ye.dtype)
    ye = ye * slot_used[..., None].astype(ye.dtype)
    # combine: per-row scatter-add back to token order
    y = jax.vmap(lambda yr, st: jnp.zeros((t, d), yr.dtype).at[
        st.reshape(-1)].add(yr.reshape(-1, d)))(ye, slot_token)

    if "shared" in p:
        sp = p["shared"]
        gate = jax.nn.sigmoid((x2d @ cast(sp["gate"])).astype(jnp.float32))
        ys = swiglu(x2d @ cast(sp["w_gate"]),
                    x2d @ cast(sp["w_up"])) @ cast(sp["w_down"])
        y = y + (ys * gate.astype(ys.dtype)).reshape(b, t, d)
    return y.reshape(b, t, d), aux


def moe_layer_dense_ref(p: Params, x: jax.Array, num_experts: int,
                        top_k: int) -> jax.Array:
    """No-capacity oracle: every token reaches its top-k experts — used by
    tests to bound dispatch error (they agree exactly when capacity is
    not exceeded)."""
    b, t, d = x.shape
    x2d = x.reshape(b * t, d)
    _, _, gate_vals, expert_ids = _router(p, x2d, top_k)
    y = jnp.zeros_like(x2d)
    for j in range(top_k):
        e = expert_ids[:, j]
        h = swiglu(jnp.einsum("nd,ndf->nf", x2d, cast(p["w_gate"])[e]),
                   jnp.einsum("nd,ndf->nf", x2d, cast(p["w_up"])[e]))
        y = y + jnp.einsum("nf,nfd->nd", h, cast(p["w_down"])[e]) \
            * gate_vals[:, j:j + 1].astype(h.dtype)
    if "shared" in p:
        sp = p["shared"]
        gate = jax.nn.sigmoid((x2d @ cast(sp["gate"])).astype(jnp.float32))
        ys = swiglu(x2d @ cast(sp["w_gate"]),
                    x2d @ cast(sp["w_up"])) @ cast(sp["w_down"])
        y = y + ys * gate.astype(ys.dtype)
    return y.reshape(b, t, d)
