"""Ground-truth LDA corpus generator.

Samples a corpus from the LDA generative process with known topics so that
tests/benchmarks can check both likelihood ascent and *recovery* of planted
structure (``metrics.topic_recovery_score``).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.data.corpus import Corpus


def synthetic_corpus(num_docs: int, vocab_size: int, num_topics: int,
                     doc_len: int, alpha: float = 0.1, beta: float = 0.01,
                     seed: int = 0, peaked: bool = True
                     ) -> Tuple[Corpus, np.ndarray, np.ndarray]:
    """Returns (corpus, true_phi [K,V], true_theta [D,K]).

    ``peaked=True`` draws topics with near-disjoint support (each topic owns
    a contiguous word band plus Dirichlet noise), making recovery checkable.
    """
    rng = np.random.default_rng(seed)
    if peaked:
        phi = rng.dirichlet([beta] * vocab_size, size=num_topics)
        band = max(vocab_size // num_topics, 1)
        boost = np.zeros((num_topics, vocab_size))
        for k in range(num_topics):
            lo = (k * band) % vocab_size
            boost[k, lo:lo + band] = 1.0
        phi = 0.3 * phi + 0.7 * boost / np.maximum(
            boost.sum(axis=1, keepdims=True), 1)
    else:
        phi = rng.dirichlet([beta * 10] * vocab_size, size=num_topics)
    theta = rng.dirichlet([alpha] * num_topics, size=num_docs)

    lengths = rng.poisson(doc_len, size=num_docs).clip(min=2)
    n = int(lengths.sum())
    doc = np.repeat(np.arange(num_docs, dtype=np.int32), lengths)
    # vectorized ancestral sampling
    zs = np.concatenate([
        rng.choice(num_topics, size=l, p=theta[d])
        for d, l in enumerate(lengths)])
    u = rng.random(n)
    cdf = np.cumsum(phi, axis=1)
    word = np.empty(n, np.int32)
    for k in range(num_topics):
        m = zs == k
        word[m] = np.searchsorted(cdf[k], u[m], side="right").clip(
            max=vocab_size - 1)
    corpus = Corpus(doc, word, num_docs, vocab_size)
    corpus.validate()
    return corpus, phi, theta
