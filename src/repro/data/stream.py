"""Sharded on-disk corpus format + streaming iterator (DESIGN.md §13).

The in-memory :class:`~repro.data.corpus.Corpus` holds the whole token
stream; the paper's regime (billions of tokens, 200B model variables on
low-end nodes) needs the opposite invariant — *training memory bounded by
the resident model block and one in-flight document shard*.  This module
is the data half of that: a corpus is a directory of document-contiguous
``.npz`` shards plus a ``meta.json`` manifest, written incrementally (the
writer never holds more than one shard) and read lazily (the iterator
yields one shard at a time).

On-disk layout::

    corpus_dir/
      meta.json            manifest: counts, shard table, format tag
      vocab.json           optional id -> string sidecar
      shard_00000.npz      {"doc": [n] int32 global ids, "word": [n] int32}
      shard_00001.npz      ...

Shards partition documents into CONTIGUOUS id ranges in stream order, so
the concatenation of shards is exactly the flat doc-major token stream —
which is what lets the out-of-core trainer
(`core/engine/streaming.py`) replay the in-memory engine's rng draws
chunk-by-chunk and stay bit-identical to it (numpy ``Generator`` fills
arrays sequentially from the bit stream, pinned by
``tests/test_stream_resume.py``).

The manifest records ``max_doc_len`` so ``--sampler auto`` and the sparse
family's static lane capacities can be derived without touching a single
shard.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Iterator, List, Optional

import numpy as np

from repro.data import integrity
from repro.data.corpus import Corpus

FORMAT_TAG = "sharded-corpus-v1"
META_NAME = "meta.json"


@dataclasses.dataclass
class CorpusShard:
    """One in-flight document shard: tokens of docs ``[doc_lo, doc_hi)``."""

    index: int
    doc: np.ndarray        # [n] int32 GLOBAL document id per token
    word: np.ndarray       # [n] int32 word id per token
    doc_lo: int
    doc_hi: int

    @property
    def num_tokens(self) -> int:
        return int(self.doc.shape[0])

    @property
    def num_docs(self) -> int:
        return self.doc_hi - self.doc_lo


class ShardedCorpusWriter:
    """Incremental writer: feed documents one at a time, get a sharded
    corpus directory out — peak memory is ONE shard's token buffer, so a
    corpus of any size can be built from a generator or a parse stream.
    """

    def __init__(self, out_dir: str, vocab_size: int,
                 docs_per_shard: int = 4096,
                 vocab: Optional[List[str]] = None):
        if docs_per_shard < 1:
            raise ValueError(
                f"docs_per_shard must be >= 1, got {docs_per_shard}")
        self.out_dir = out_dir
        self.vocab_size = int(vocab_size)
        self.docs_per_shard = int(docs_per_shard)
        os.makedirs(out_dir, exist_ok=True)
        if vocab is not None:
            if len(vocab) != vocab_size:
                raise ValueError(
                    f"vocab has {len(vocab)} entries, expected {vocab_size}")
            with open(os.path.join(out_dir, "vocab.json"), "w") as f:
                json.dump(vocab, f)
        self._buf_doc: List[np.ndarray] = []
        self._buf_word: List[np.ndarray] = []
        self._buf_docs = 0
        self._shards: List[dict] = []
        self.num_docs = 0
        self.num_tokens = 0
        self.max_doc_len = 0
        self._closed = False

    def add_document(self, word_ids) -> int:
        """Append one document (a sequence of word ids); returns its
        global document id."""
        if self._closed:
            raise RuntimeError("writer already closed")
        w = np.asarray(word_ids, np.int32)
        if w.ndim != 1:
            raise ValueError(f"expected 1-D word ids, got shape {w.shape}")
        if w.size and (w.min() < 0 or w.max() >= self.vocab_size):
            raise ValueError(
                f"word id out of range [0, {self.vocab_size}) in document "
                f"{self.num_docs}")
        d = self.num_docs
        self._buf_doc.append(np.full(w.shape[0], d, np.int32))
        self._buf_word.append(w)
        self.num_docs += 1
        self.num_tokens += int(w.shape[0])
        self.max_doc_len = max(self.max_doc_len, int(w.shape[0]))
        self._buf_docs += 1
        if self._buf_docs >= self.docs_per_shard:
            self._flush()
        return d

    def _flush(self) -> None:
        if not self._buf_docs:
            return
        doc = (np.concatenate(self._buf_doc) if self._buf_doc
               else np.zeros(0, np.int32))
        word = (np.concatenate(self._buf_word) if self._buf_word
                else np.zeros(0, np.int32))
        i = len(self._shards)
        fname = f"shard_{i:05d}.npz"
        integrity.save_npz(os.path.join(self.out_dir, fname),
                           compressed=True, doc=doc, word=word)
        self._shards.append({
            "file": fname,
            "doc_lo": self.num_docs - self._buf_docs,
            "doc_hi": self.num_docs,
            "num_tokens": int(word.shape[0]),
        })
        self._buf_doc, self._buf_word, self._buf_docs = [], [], 0

    def close(self) -> str:
        """Flush the tail shard and write the manifest; returns the
        corpus directory (idempotent)."""
        if not self._closed:
            self._flush()
            meta = {
                "format": FORMAT_TAG,
                "num_docs": self.num_docs,
                "vocab_size": self.vocab_size,
                "num_tokens": self.num_tokens,
                "max_doc_len": self.max_doc_len,
                "shards": self._shards,
            }
            # atomic + checksummed: a kill mid-close can never leave a
            # torn manifest shadowing a complete shard set (§15)
            integrity.atomic_write_json(
                os.path.join(self.out_dir, META_NAME), meta, indent=1,
                checksum=True)
            self._closed = True
        return self.out_dir

    def __enter__(self) -> "ShardedCorpusWriter":
        return self

    def __exit__(self, *exc) -> None:
        if exc[0] is None:
            self.close()


class ShardedCorpus:
    """Lazy reader over a sharded corpus directory.

    Construction reads only ``meta.json`` — O(1) in corpus size.  Token
    data is touched one shard at a time via :meth:`load_shard` /
    :meth:`iter_shards`; each load validates the shard against the
    manifest, so corruption fails at the I/O boundary like
    ``load_corpus``.
    """

    def __init__(self, path: str):
        self.path = path
        mpath = os.path.join(path, META_NAME)
        try:
            with open(mpath) as f:
                meta = json.load(f)
        except OSError as e:
            raise ValueError(
                f"{path!r} is not a sharded corpus directory "
                f"(missing {META_NAME})") from e
        if meta.get("format") != FORMAT_TAG:
            raise ValueError(
                f"unknown sharded-corpus format {meta.get('format')!r} in "
                f"{mpath}; expected {FORMAT_TAG!r}")
        self.meta = meta
        self.num_docs = int(meta["num_docs"])
        self.vocab_size = int(meta["vocab_size"])
        self.num_tokens = int(meta["num_tokens"])
        self.max_doc_len = int(meta["max_doc_len"])
        self.vocab: Optional[List[str]] = None
        vpath = os.path.join(path, "vocab.json")
        if os.path.exists(vpath):
            with open(vpath) as f:
                self.vocab = json.load(f)

    @property
    def num_shards(self) -> int:
        return len(self.meta["shards"])

    def load_shard(self, i: int) -> CorpusShard:
        entry = self.meta["shards"][i]
        # validate-on-load: a bit-flipped or torn shard raises the
        # integrity taxonomy instead of decoding into garbage token ids
        data = integrity.load_npz(os.path.join(self.path, entry["file"]))
        doc = np.asarray(data["doc"], np.int32)
        word = np.asarray(data["word"], np.int32)
        lo, hi = int(entry["doc_lo"]), int(entry["doc_hi"])
        if doc.shape != word.shape or doc.shape[0] != entry["num_tokens"]:
            raise ValueError(
                f"shard {entry['file']}: token arrays disagree with "
                f"manifest ({doc.shape[0]} vs {entry['num_tokens']})")
        if doc.size and (doc.min() < lo or doc.max() >= hi):
            raise ValueError(
                f"shard {entry['file']}: doc ids outside [{lo}, {hi})")
        if word.size and (word.min() < 0 or word.max() >= self.vocab_size):
            raise ValueError(
                f"shard {entry['file']}: word id outside "
                f"[0, {self.vocab_size})")
        return CorpusShard(i, doc, word, lo, hi)

    def iter_shards(self) -> Iterator[CorpusShard]:
        """The streaming iterator: one document shard in memory at a time,
        in stream (document id) order."""
        for i in range(self.num_shards):
            yield self.load_shard(i)

    def doc_lengths(self) -> np.ndarray:
        """Per-document token counts — one streaming pass, O(num_docs)
        memory (the engine layouts need these, never the token stream)."""
        out = np.zeros(self.num_docs, np.int64)
        for shard in self.iter_shards():
            out += np.bincount(shard.doc, minlength=self.num_docs)
        return out

    def to_corpus(self) -> Corpus:
        """Materialize as an in-memory :class:`Corpus` — for tests and
        small corpora only; defeats the point at scale."""
        docs = [np.zeros(0, np.int32)]
        words = [np.zeros(0, np.int32)]
        for shard in self.iter_shards():
            docs.append(shard.doc)
            words.append(shard.word)
        corpus = Corpus(np.concatenate(docs), np.concatenate(words),
                        self.num_docs, self.vocab_size, self.vocab)
        corpus.validate()
        return corpus


def shard_corpus(corpus: Corpus, out_dir: str,
                 num_shards: Optional[int] = None,
                 docs_per_shard: Optional[int] = None) -> str:
    """Write an in-memory corpus to the sharded on-disk format.

    The token stream must be doc-major (``corpus.doc`` non-decreasing) —
    the format stores contiguous document ranges in stream order.
    """
    if (num_shards is None) == (docs_per_shard is None):
        raise ValueError("pass exactly one of num_shards / docs_per_shard")
    corpus.validate()
    if corpus.doc.size and (np.diff(corpus.doc) < 0).any():
        raise ValueError(
            "corpus token stream is not doc-major; sort by doc id first")
    if num_shards is not None:
        if not 1 <= num_shards <= max(corpus.num_docs, 1):
            raise ValueError(
                f"num_shards must be in [1, {corpus.num_docs}], "
                f"got {num_shards}")
        docs_per_shard = -(-corpus.num_docs // num_shards)
    writer = ShardedCorpusWriter(out_dir, corpus.vocab_size,
                                 docs_per_shard=docs_per_shard,
                                 vocab=corpus.vocab)
    # one pass over the stream via the (vectorized) per-doc split
    bounds = np.searchsorted(corpus.doc,
                             np.arange(corpus.num_docs + 1, dtype=np.int64))
    for d in range(corpus.num_docs):
        writer.add_document(corpus.word[bounds[d]:bounds[d + 1]])
    return writer.close()


# ---------------------------------------------------------------------------
# Streaming synthetic generators (corpus never materialized in RAM)
# ---------------------------------------------------------------------------

def write_synthetic_stream(out_dir: str, num_docs: int, vocab_size: int,
                           num_topics: int, doc_len: int, seed: int = 0,
                           docs_per_shard: int = 4096,
                           alpha: float = 0.1, beta: float = 0.01) -> str:
    """LDA-generative corpus written shard-by-shard: one shared topic
    matrix (the MODEL, O(K·V)), documents generated and flushed in
    ``docs_per_shard`` chunks — the corpus itself never exists in RAM."""
    rng = np.random.default_rng(seed)
    phi = rng.dirichlet([beta * 10] * vocab_size, size=num_topics)
    cdf = np.cumsum(phi, axis=1)
    writer = ShardedCorpusWriter(out_dir, vocab_size,
                                 docs_per_shard=docs_per_shard)
    for _ in range(num_docs):
        theta = rng.dirichlet([alpha] * num_topics)
        length = max(int(rng.poisson(doc_len)), 2)
        zs = rng.choice(num_topics, size=length, p=theta)
        u = rng.random(length)
        words = np.empty(length, np.int32)
        for k in np.unique(zs):
            m = zs == k
            words[m] = np.searchsorted(cdf[k], u[m], side="right").clip(
                max=vocab_size - 1)
        writer.add_document(words)
    return writer.close()


def write_zipf_stream(out_dir: str, num_docs: int, vocab_size: int,
                      doc_len: int, zipf_a: float = 1.1, seed: int = 0,
                      docs_per_shard: int = 4096) -> str:
    """Long-tail (bounded-Zipf) unigram corpus written shard-by-shard —
    the big-K benchmark workload (Peacock's power-law regime) with O(V)
    generator state, no topic matrix at all."""
    rng = np.random.default_rng(seed)
    freq = 1.0 / np.arange(1, vocab_size + 1, dtype=np.float64) ** zipf_a
    cdf = np.cumsum(freq / freq.sum())
    writer = ShardedCorpusWriter(out_dir, vocab_size,
                                 docs_per_shard=docs_per_shard)
    for _ in range(num_docs):
        length = max(int(rng.poisson(doc_len)), 2)
        words = np.searchsorted(
            cdf, rng.random(length), side="right").clip(
            max=vocab_size - 1).astype(np.int32)
        writer.add_document(words)
    return writer.close()


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Shard a corpus to the on-disk streaming format")
    ap.add_argument("--out", required=True, help="output corpus directory")
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--from-npz", default="",
                     help="shard an existing corpus .npz (load_corpus)")
    src.add_argument("--zipf", type=float, default=0.0, metavar="A",
                     help="generate a bounded-Zipf(A) long-tail stream "
                          "instead of the LDA-generative corpus")
    ap.add_argument("--docs", type=int, default=512)
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--topics", type=int, default=16)
    ap.add_argument("--doc-len", type=int, default=48)
    ap.add_argument("--shards", type=int, default=4,
                    help="shard count when sharding an existing corpus; "
                         "for generated streams, docs per shard is "
                         "ceil(docs/shards)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.from_npz:
        from repro.data.corpus import load_corpus
        out = shard_corpus(load_corpus(args.from_npz), args.out,
                           num_shards=args.shards)
    elif args.zipf > 0:
        out = write_zipf_stream(args.out, args.docs, args.vocab,
                                args.doc_len, zipf_a=args.zipf,
                                seed=args.seed,
                                docs_per_shard=-(-args.docs // args.shards))
    else:
        out = write_synthetic_stream(
            args.out, args.docs, args.vocab, args.topics, args.doc_len,
            seed=args.seed, docs_per_shard=-(-args.docs // args.shards))
    sc = ShardedCorpus(out)
    print(f"sharded corpus: {out}  docs={sc.num_docs:,} "
          f"tokens={sc.num_tokens:,} V={sc.vocab_size:,} "
          f"shards={sc.num_shards} max_doc_len={sc.max_doc_len}")


if __name__ == "__main__":
    main()
