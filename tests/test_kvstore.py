"""Host-process Figure-1 architecture simulation."""
import numpy as np

from repro.core.kvstore import HostModelParallelLDA
from repro.core.likelihood import log_likelihood
from repro.core.counts import CountState
import jax.numpy as jnp


def test_host_sim_conserves_counts(tiny_corpus):
    corpus, _, _ = tiny_corpus
    host = HostModelParallelLDA(corpus, num_topics=8, num_workers=4, seed=0)
    host.step()
    ckt = host.gather_ckt()
    assert int(ckt.sum()) == corpus.num_tokens
    assert (ckt >= 0).all()


def test_host_sim_likelihood_ascends(tiny_corpus):
    corpus, _, _ = tiny_corpus
    host = HostModelParallelLDA(corpus, num_topics=8, num_workers=3, seed=0)

    def ll():
        ckt = host.gather_ckt()
        cdk = np.vstack([w.cdk for w in host.workers])
        ck = ckt.sum(axis=0).astype(np.int32)
        state = CountState(jnp.asarray(cdk), jnp.asarray(ckt),
                           jnp.asarray(ck))
        return log_likelihood(state, np.full(8, 0.1, np.float32), 0.01)

    before = ll()
    host.step()
    host.step()
    assert ll() > before


def test_kvstore_traffic_is_block_granular(tiny_corpus):
    """On-demand communication: traffic per iteration ≈ 2·M·(block bytes)
    + 2·M·(K vector) — not O(M²) gossip."""
    corpus, _, _ = tiny_corpus
    m, k = 4, 8
    host = HostModelParallelLDA(corpus, num_topics=k, num_workers=m, seed=0)
    base = host.store.bytes_moved
    host.step()
    moved = host.store.bytes_moved - base
    block_bytes = host.partition.block_size * k * 4
    ck_bytes = k * 8
    expected = m * m * (2 * block_bytes + 2 * ck_bytes)  # M rounds × M workers
    assert moved == expected, (moved, expected)
