"""Phi-4-mini-3.8B [arXiv:2412.08905]: 32L dense, GQA kv=8, 200k vocab."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    source="arXiv:2412.08905",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    head_dim=128,
    rope_theta=10000.0,
    norm="rms",
    tie_embeddings=True,
    subquadratic_decode=False,
)
