"""OLMo-1B [arXiv:2402.00838]: 16L dense, non-parametric LayerNorm."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    source="arXiv:2402.00838",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    head_dim=128,
    rope_theta=10000.0,
    norm="nonparametric",
    tie_embeddings=True,
    subquadratic_decode=False,
)
