"""The paper's Figure-1 architecture, component by component, using the
host-process simulation: Scheduler -> Workers -> distributed KV store.

Shows on-demand communication (block fetch/commit), the special C_k
channel, and the traffic ledger that makes the O(M) vs O(M^2) argument
concrete.

    PYTHONPATH=src python examples/architecture_walkthrough.py
"""
import numpy as np

from repro.core.kvstore import HostModelParallelLDA
from repro.core.schedule import schedule_table
from repro.data.synthetic import synthetic_corpus

corpus, _, _ = synthetic_corpus(num_docs=120, vocab_size=240,
                                num_topics=8, doc_len=40, seed=0)
M = 4
host = HostModelParallelLDA(corpus, num_topics=8, num_workers=M, seed=0)

print("rotation schedule (rows = rounds, cols = workers, cell = block):")
print(schedule_table(M))

print("\nrunning 3 iterations through the KV store ...")
for it in range(3):
    before = host.store.bytes_moved
    host.step()
    moved = host.store.bytes_moved - before
    block_bytes = host.partition.block_size * 8 * 4
    print(f"iteration {it+1}: {moved:,} bytes moved "
          f"(= M² rounds × (2 blocks of {block_bytes:,} B + 2 C_k vectors))")

ckt = host.gather_ckt()
print(f"\nglobal model reassembled from KV store: shape {ckt.shape}, "
      f"total counts {ckt.sum():,} == corpus tokens {corpus.num_tokens:,}")
assert int(ckt.sum()) == corpus.num_tokens

# Contrast: a data-parallel scheme needs every worker to hold the FULL
# V×K table and sync all of it — per-iteration traffic O(M²·V·K) on a
# gossip fabric vs the managed O(M·V·K/M) = O(V·K) block moves above.
vk = corpus.vocab_size * 8 * 4
print(f"\nDP-equivalent traffic per iteration ≈ {2*(M-1)*vk*M:,} bytes "
      f"(M² pairwise) vs MP {M*M*(2*host.partition.block_size*8*4):,}")
