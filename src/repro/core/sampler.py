"""Collapsed Gibbs sampling for LDA (paper eq. 1) and the word-major
bucket decomposition used for inverted-index sampling (paper eq. 3).

Two interchangeable implementations of the *exact* serial sampler:

  * :func:`gibbs_sweep_np` — numpy host oracle, the ground truth the JAX
    paths are validated against;
  * :func:`sweep_block_scan` — ``jax.lax.scan`` over a (possibly padded)
    token slice against a word *block* of the model, the unit of work one
    worker performs in one round of the model-parallel schedule.

Both consume externally supplied per-token uniforms so runs are exactly
reproducible and, crucially, so that a model-parallel execution can be
replayed serially with the *same* randomness (the paper's "parallel equals
serial" claim becomes a bit-exact test).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Conditional distributions (unnormalized)
# ---------------------------------------------------------------------------

def conditional_eq1(ckt_row, cdk_row, ck, alpha, beta, vbeta):
    """Paper eq. (1): p(z=k) ∝ (C_d^k + α_k)(C_k^t + β) / (C_k + Vβ).

    Counts must already EXCLUDE the current token (the ¬dn terms).
    """
    return (cdk_row + alpha) * (ckt_row + beta) / (ck + vbeta)


def conditional_eq3(ckt_row, cdk_row, ck, alpha, beta, vbeta):
    """Paper eq. (3): p(z=k) ∝ X_k + Y_k with the shared word-major coeff.

    ``coeff`` and ``sum_k X_k`` depend only on the *word*, so when tokens are
    visited word-major (inverted index) they are computed once per word and
    reused — the caching the paper designs for, and exactly what the Pallas
    kernel exploits as VMEM row reuse.  Algebraically identical to eq. (1).
    """
    coeff = (ckt_row + beta) / (ck + vbeta)
    x = coeff * alpha         # X_k — word-dependent only
    y = coeff * cdk_row       # Y_k — O(K_d) under row sparsity on hosts
    return x + y


def sample_from_mass(p, u):
    """Inverse-CDF draw: smallest k with cumsum(p)[k] > u * sum(p).

    Counted form of the draw: ``#{k : csum[k] <= u·total}`` equals the
    naive ``argmax(csum > u·total)`` whenever some entry exceeds the
    threshold, but stays correct at the edges where the comparison is
    all-False and argmax silently returned topic 0 — ``u == 1.0`` (clamped
    to the last positive-mass topic, as ``sparse.py`` does) and an
    all-zero mass row (returns 0).
    """
    csum = jnp.cumsum(p)
    total = csum[-1]
    idx = jnp.sum(csum <= u * total)
    last = jnp.sum(csum < total)   # index of the last positive-mass entry
    return jnp.minimum(idx, last)


# ---------------------------------------------------------------------------
# Numpy host oracle (exact serial CGS)
# ---------------------------------------------------------------------------

def gibbs_sweep_np(cdk, ckt, ck, doc, word, z, u, alpha, beta,
                   order=None, use_eq3: bool = False):
    """One exact serial sweep, mutating counts in place.  Returns new ``z``.

    ``u`` holds one uniform per token, consumed in visit ``order``.
    """
    doc = np.asarray(doc); word = np.asarray(word)
    z = np.array(z, np.int32, copy=True)
    alpha = np.asarray(alpha, np.float32)
    vbeta = np.float32(beta * ckt.shape[0])
    beta = np.float32(beta)
    cond = conditional_eq3 if use_eq3 else conditional_eq1
    if order is None:
        order = range(doc.shape[0])
    for i in order:
        d, t, k_old = doc[i], word[i], z[i]
        cdk[d, k_old] -= 1
        ckt[t, k_old] -= 1
        ck[k_old] -= 1
        p = np.asarray(cond(ckt[t].astype(np.float32),
                            cdk[d].astype(np.float32),
                            ck.astype(np.float32), alpha, beta, vbeta))
        csum = np.cumsum(p)
        # counted inverse-CDF draw (see sample_from_mass): u == 1.0 clamps
        # to the last positive-mass topic instead of wrapping to topic 0
        k_new = int(min((csum <= u[i] * csum[-1]).sum(),
                        (csum < csum[-1]).sum()))
        z[i] = k_new
        cdk[d, k_new] += 1
        ckt[t, k_new] += 1
        ck[k_new] += 1
    return z


# ---------------------------------------------------------------------------
# JAX scan sampler over one word block (the per-round unit of work)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("use_eq3",))
def sweep_block_scan(cdk: jax.Array, ckt_block: jax.Array, ck: jax.Array,
                     doc: jax.Array, word_off: jax.Array, z: jax.Array,
                     mask: jax.Array, u: jax.Array,
                     alpha: jax.Array, beta: jax.Array, vbeta: jax.Array,
                     use_eq3: bool = True
                     ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Exact serial CGS over a padded token slice of one word block.

    Args:
      cdk:        [D_local, K] document-topic counts for this worker's shard.
      ckt_block:  [Vb, K] rows of the word-topic table for the current block.
      ck:         [K] topic totals (synced value + local drift, §3.3).
      doc, word_off, z, mask, u: [T] token slice in inverted-index order;
        ``word_off`` indexes rows of ``ckt_block``; padded entries have
        ``mask=False`` and are exact no-ops.

    Returns updated ``(cdk, ckt_block, ck, z)``.
    """
    cond = conditional_eq3 if use_eq3 else conditional_eq1

    def body(carry, xs):
        cdk, ckt, ck = carry
        d, t, k_old, valid, u_i = xs
        delta = valid.astype(jnp.int32)
        # -- decrement (the ¬dn exclusion) --
        cdk = cdk.at[d, k_old].add(-delta)
        ckt = ckt.at[t, k_old].add(-delta)
        ck = ck.at[k_old].add(-delta)
        # -- conditional + inverse-CDF draw --
        p = cond(ckt[t].astype(jnp.float32), cdk[d].astype(jnp.float32),
                 ck.astype(jnp.float32), alpha, beta, vbeta)
        k_new = sample_from_mass(p, u_i).astype(jnp.int32)
        k_new = jnp.where(valid, k_new, k_old)
        # -- increment --
        cdk = cdk.at[d, k_new].add(delta)
        ckt = ckt.at[t, k_new].add(delta)
        ck = ck.at[k_new].add(delta)
        return (cdk, ckt, ck), k_new

    (cdk, ckt_block, ck), z_new = jax.lax.scan(
        body, (cdk, ckt_block, ck),
        (doc, word_off, z, mask, u))
    return cdk, ckt_block, ck, z_new


# ---------------------------------------------------------------------------
# Batched (word-frozen) sampler — the relaxation behind the Pallas kernel
# ---------------------------------------------------------------------------

@jax.jit
def sweep_block_batched(cdk, ckt_block, ck, doc, word_off, z, mask, u,
                        alpha, beta, vbeta, segment_start):
    """Word-frozen batched CGS over one block (beyond-paper fast path).

    Tokens sharing a word are sampled against the word's ``C_k^t`` row frozen
    at segment start (``C_d^k`` exclusion stays exact per token because each
    token's own assignment is subtracted).  ``C_k^t``/``C_k``/``C_d^k`` deltas
    are folded in afterwards via scatter-add.  DESIGN.md §2 item 2 discusses
    why this staleness (bounded by one word's postings) is far weaker than
    the data-parallel baseline's.

    ``segment_start`` marks the first token of each word segment (unused by
    the math here — the freeze is per-block — but kept so callers can shrink
    the freeze window; the Pallas kernel freezes per word tile).
    """
    del segment_start
    t = word_off
    k = ck.shape[0]
    delta = mask.astype(jnp.int32)
    # LEAN form (§Perf-LDA iteration "lean-batched"): the ¬dn self-exclusion
    # is a rank-1 correction at k == z_old (the Pallas kernel's trick) and
    # the count deltas are two scatter-adds per token, so no [T, K] one-hot
    # tensor is ever materialized — the original formulation built five of
    # them per round and was memory-bound on the LDA roofline.
    ckt_rows = ckt_block[t].astype(jnp.float32)            # [T, K] raw
    cdk_rows = cdk[doc].astype(jnp.float32)                # [T, K] raw
    ck_f = ck.astype(jnp.float32)
    base = (ckt_rows + beta) / (ck_f + vbeta)[None, :] \
        * (alpha[None, :] + cdk_rows)
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (z.shape[0], k), 1)
    is_old = (k_iota == z[:, None]) & mask[:, None]
    corrected = ((ckt_rows - 1.0 + beta) * (alpha[None, :] + cdk_rows - 1.0)
                 / (ck_f[None, :] - 1.0 + vbeta))
    p = jnp.maximum(jnp.where(is_old, corrected, base), 0.0)
    csum = jnp.cumsum(p, axis=-1)
    # counted inverse-CDF draw (see sample_from_mass): exact at u == 1.0
    # and on all-zero mass rows, where argmax returned topic 0
    total = csum[:, -1]
    idx = jnp.sum(csum <= (u * total)[:, None], axis=-1)
    last = jnp.sum(csum < total[:, None], axis=-1)
    z_new = jnp.minimum(idx, last)
    z_new = jnp.where(mask, z_new.astype(jnp.int32), z)
    # fold deltas exactly: -1 at (row, z_old), +1 at (row, z_new)
    cdk = cdk.at[doc, z].add(-delta).at[doc, z_new].add(delta)
    ckt_block = ckt_block.at[t, z].add(-delta).at[t, z_new].add(delta)
    ck = ck.at[z].add(-delta).at[z_new].add(delta)
    return cdk, ckt_block, ck, z_new
