"""Batched topic-inference serving facade (DESIGN.md §11).

The query-side counterpart of ``serve_step.BatchedServer``: accepts
variable-length query documents, packs them into padded power-of-two
buckets, and runs the fold-in engine (`core/infer.py`) against a frozen
:class:`~repro.core.infer.ModelSnapshot`.

Bucketing is the serving-side answer to XLA's static shapes: a batch of
``Q`` docs with longest length ``L`` is padded to ``(pow2(Q), pow2(L))``,
so the jitted fold-in compiles ONCE per bucket and every later batch
that lands in the same bucket reuses the executable.  Padded slots are
masked no-ops, proven not to perturb real queries bit-for-bit by
``tests/test_infer.py`` (pad invariance) — so bucket choice is purely a
latency/compile-cache knob, never a correctness one.

Queries never write model state, so servers scale horizontally with zero
coordination: run one process per replica and round-robin the traffic —
the embarrassing data-parallelism of frozen-model inference (§11).
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

from repro.core.infer import (DEFAULT_FOLD_IN_SWEEPS, ModelSnapshot,
                              fold_in, pack_queries)
from repro.core.likelihood import doc_completion_perplexity


def bucket_size(n: int, floor: int = 1) -> int:
    """Smallest power of two ≥ max(n, floor)."""
    b = max(int(floor), 1)
    while b < n:
        b <<= 1
    return b


class TopicInferenceServer:
    """Serve topic mixtures for unseen docs from a frozen snapshot.

    ``sampler`` is ``"scan"`` (exact CGS), the O(1) MH pair
    ``"mh"``/``"mh_pallas"``, or the hybrid ``"sparse"`` family
    (DESIGN.md §12) — per-snapshot derived state (packed word alias
    tables for MH, the dense-segment cumsum for sparse) is built once at
    server construction and shared by every query (the LightLDA
    frozen-model ideal).  Randomness flows from one seeded generator, so
    a server's response stream is reproducible end to end.
    """

    def __init__(self, snapshot: ModelSnapshot, sampler: str = "mh",
                 num_sweeps: int = DEFAULT_FOLD_IN_SWEEPS, seed: int = 0,
                 min_batch_bucket: int = 1, min_token_bucket: int = 8):
        self.snapshot = snapshot
        self.sampler = sampler
        self.num_sweeps = int(num_sweeps)
        self.min_batch_bucket = int(min_batch_bucket)
        self.min_token_bucket = int(min_token_bucket)
        self._rng = np.random.default_rng(seed)
        if sampler in ("sparse", "sparse_pallas"):
            snapshot.sparse_state()       # build once, serve many
        elif sampler != "scan":
            snapshot.ensure_tables()      # build once, serve many
        # serving observability: how many calls landed in each bucket
        # (tests assert reuse; ops would watch for bucket explosion)
        self.bucket_calls: Dict[Tuple[int, int], int] = {}
        self.docs_served = 0

    def bucket_shape(self, docs: Sequence[Sequence[int]]
                     ) -> Tuple[int, int]:
        """(batch, token) bucket a set of docs pads into."""
        longest = max((len(d) for d in docs), default=1)
        return (bucket_size(len(docs), self.min_batch_bucket),
                bucket_size(longest, self.min_token_bucket))

    def infer(self, docs: Sequence[Sequence[int]]) -> np.ndarray:
        """Batched query: docs (word-id sequences) -> ``θ̂`` [len(docs), K].

        Pads to the power-of-two bucket, folds in, strips the padding.
        """
        if not len(docs):
            return np.zeros((0, self.snapshot.num_topics), np.float64)
        qb, tb = self.bucket_shape(docs)
        word, mask = pack_queries(docs, t_pad=tb, q_pad=qb)
        res = fold_in(self.snapshot, word, mask,
                      num_sweeps=self.num_sweeps, sampler=self.sampler,
                      rng=self._rng)
        self.bucket_calls[(qb, tb)] = self.bucket_calls.get((qb, tb), 0) + 1
        self.docs_served += len(docs)
        return res.theta[:len(docs)]

    def infer_with_draws(self, docs: Sequence[Sequence[int]],
                         z0_rows: Sequence[np.ndarray],
                         u_rows: Sequence[np.ndarray]) -> np.ndarray:
        """Batched query with EXTERNAL per-doc randomness — the serving
        scheduler's seed contract (DESIGN.md §14).

        Row ``i`` of the packed batch takes its initial assignments from
        ``z0_rows[i]`` ``[len_i]`` and its uniforms from ``u_rows[i]``
        ``[num_sweeps, len_i]``; pad slots are filled with inert zeros.
        Because every slot that can influence doc ``i`` is supplied by
        the caller, a doc's mixture is a pure function of (snapshot,
        tokens, its own draws) — independent of batch composition,
        bucket shape, and every other doc (the pad-invariance property,
        proven bitwise in tests/test_infer.py).  This is what lets the
        scheduler cache responses, compare them across swap epochs, and
        dispatch to any replica without changing a single bit.
        """
        if not len(docs):
            return np.zeros((0, self.snapshot.num_topics), np.float64)
        if len(z0_rows) != len(docs) or len(u_rows) != len(docs):
            raise ValueError(
                f"need one z0/u row per doc: {len(docs)} docs vs "
                f"{len(z0_rows)}/{len(u_rows)} rows")
        qb, tb = self.bucket_shape(docs)
        word, mask = pack_queries(docs, t_pad=tb, q_pad=qb)
        z0 = np.zeros((qb, tb), np.int32)
        u = np.zeros((self.num_sweeps, qb, tb), np.float32)
        for i, d in enumerate(docs):
            n = len(d)
            z_r = np.asarray(z0_rows[i], np.int32)
            u_r = np.asarray(u_rows[i], np.float32)
            if z_r.shape != (n,) or u_r.shape != (self.num_sweeps, n):
                raise ValueError(
                    f"doc {i}: draws must be z0 [{n}] / u "
                    f"[{self.num_sweeps}, {n}], got {z_r.shape} / "
                    f"{u_r.shape}")
            z0[i, :n] = z_r
            u[:, i, :n] = u_r
        res = fold_in(self.snapshot, word, mask, num_sweeps=self.num_sweeps,
                      sampler=self.sampler, z0=z0, u=u)
        self.bucket_calls[(qb, tb)] = self.bucket_calls.get((qb, tb), 0) + 1
        self.docs_served += len(docs)
        return res.theta[:len(docs)]

    def infer_one(self, words: Sequence[int]) -> np.ndarray:
        """Single-doc convenience: word ids -> ``θ̂`` [K]."""
        return self.infer([words])[0]

    def perplexity(self, docs: Sequence[Sequence[int]]) -> dict:
        """Doc-completion perplexity of held-out docs under this server's
        snapshot and sampler (`core/likelihood.py`)."""
        return doc_completion_perplexity(
            self.snapshot, docs, num_sweeps=self.num_sweeps,
            sampler=self.sampler, rng=self._rng)
