"""JAX version compatibility layer (DESIGN.md §7).

The repo targets the newest JAX API surface (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.set_mesh``) but must run on
whatever JAX the container bakes in.  Every version-sensitive construct is
resolved HERE, once, so the engine (`core/engine`) and the launch layer
(`launch/*`) share one set of fallbacks instead of sprinkling try/except
at call sites.

Resolution order, newest first:

* ``shard_map``  — ``jax.shard_map`` -> ``jax.experimental.shard_map``;
  the ``check_vma=`` kwarg (new name) is translated to ``check_rep=``
  (old name) when falling back.
* ``make_mesh``  — ``jax.make_mesh`` with ``axis_types`` dropped when the
  installed signature does not accept it (older JAX treats every axis as
  Auto anyway, which is what the callers want); final fallback builds a
  ``Mesh`` from ``jax.devices()`` directly.
* ``set_mesh``   — ``jax.set_mesh`` -> ``jax.sharding.use_mesh`` -> the
  ``Mesh`` object's own context manager.
* ``AxisType``   — re-exported when present, else a minimal stand-in with
  the ``Auto``/``Explicit``/``Manual`` members callers name.
"""
from __future__ import annotations

import contextlib
import enum
import inspect
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["AxisType", "cost_analysis_dict", "make_mesh", "set_mesh",
           "shard_map"]


# ---------------------------------------------------------------------------
# AxisType
# ---------------------------------------------------------------------------

try:  # JAX >= 0.5-era explicit-sharding API
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:
    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stand-in for ``jax.sharding.AxisType`` on older JAX.

        Old JAX has no axis-type concept — every mesh axis behaves like
        ``Auto`` — so the members only need to exist for callers that pass
        ``axis_types=(AxisType.Auto, ...)`` through :func:`make_mesh`.
        """

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn, True
    from jax.experimental.shard_map import shard_map as exp_shard_map
    return exp_shard_map, False


_SHARD_MAP, _SHARD_MAP_IS_TOPLEVEL = _resolve_shard_map()
_SHARD_MAP_PARAMS = frozenset(inspect.signature(_SHARD_MAP).parameters)


def shard_map(f, mesh=None, in_specs=None, out_specs=None, **kwargs):
    """Version-portable ``shard_map``.

    Accepts the modern keyword set; translates ``check_vma`` to the old
    ``check_rep`` spelling and drops keywords the resolved implementation
    does not know (they are semantic no-ops on those versions).
    """
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    kwargs = {k: v for k, v in kwargs.items() if k in _SHARD_MAP_PARAMS}
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


# ---------------------------------------------------------------------------
# make_mesh
# ---------------------------------------------------------------------------

def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, axis_types: Any = None, devices=None) -> Mesh:
    """``jax.make_mesh`` accepting ``axis_types`` on every JAX version."""
    native = getattr(jax, "make_mesh", None)
    if native is not None:
        params = inspect.signature(native).parameters
        kw = {}
        if devices is not None and "devices" in params:
            kw["devices"] = devices
        if axis_types is not None and "axis_types" in params:
            kw["axis_types"] = axis_types
        return native(tuple(axis_shapes), tuple(axis_names), **kw)
    devs = np.asarray(devices if devices is not None else jax.devices())
    n = int(np.prod(axis_shapes))
    return Mesh(devs[:n].reshape(tuple(axis_shapes)), tuple(axis_names))


# ---------------------------------------------------------------------------
# cost_analysis
# ---------------------------------------------------------------------------

def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every JAX version.

    Older JAX returns a one-element list of per-program dicts; newer JAX
    returns the dict directly (and may return ``None`` for trivial
    programs).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


# ---------------------------------------------------------------------------
# set_mesh
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def _mesh_ctx(mesh: Mesh):
    with mesh:
        yield mesh


def set_mesh(mesh: Mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    native = getattr(jax, "set_mesh", None)
    if native is not None:
        return native(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return _mesh_ctx(mesh)
