"""Deterministic fault injection (DESIGN.md §15).

A :class:`FaultPlan` is a seedable, JSON-serializable list of
:class:`FaultSpec`s; production code is instrumented with named *fire
points* (``faults.fire("step", "iter:3")``, ``faults.fire("write",
path)``, ...) that are free no-ops when no plan is active and raise /
delay / corrupt exactly as scripted when one is.  Because the plan is
data, the same failure sequence replays bit-for-bit across runs,
processes (via the ``REPRO_FAULT_PLAN`` env var), and CI — which is
what lets the recovery tests assert BITWISE equality between a crashed-
and-resumed chain and an uninterrupted one.

Fault kinds:

* ``crash``        — raise :class:`InjectedCrash` at the fire point.
  ``InjectedCrash`` subclasses ``BaseException`` (like
  ``KeyboardInterrupt``) so no broad ``except Exception`` in the stack
  can accidentally swallow the "kill" — the process dies at exactly the
  scripted instruction, the closest in-process model of SIGKILL.
* ``io_error``     — raise :class:`InjectedIOError` (an ``OSError``),
  modelling a transient read/write failure that normal error handling
  IS allowed to see.
* ``bit_flip``     — XOR one byte of the artifact named by the fire
  point's detail (deterministic offset from the plan seed), then let
  the operation proceed: the integrity layer must catch it.
* ``replica_fail`` — raise :class:`InjectedReplicaError` inside a
  replica's dispatch, driving the scheduler's retry + circuit-breaker
  path.
* ``replica_slow`` — report a delay (seconds) for the scheduler to add
  under its injected Clock; latency-only, no error.

Matching: a spec names a ``point`` and an optional ``match`` substring
of the detail; ``nth`` fires on the nth matching occurrence (1-based),
``nth=0`` on every one.  Counters live on the plan instance, so
re-activating a fresh plan resets history.

Scope: this is a TEST/CI harness for deterministic failure replay in
this repo's own recovery machinery — not a general-purpose wrench.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

ENV_VAR = "REPRO_FAULT_PLAN"
PLAN_FORMAT = "fault-plan-v1"


class InjectedCrash(BaseException):
    """Scripted process kill.  Deliberately NOT an ``Exception``: broad
    handlers must not be able to swallow it, because the tests that
    inject it are modelling a crash, and a crash does not run
    ``except`` blocks."""

    def __init__(self, point: str, detail: str, spec_index: int):
        self.point, self.detail, self.spec_index = point, detail, spec_index
        super().__init__(f"injected crash at {point}({detail}) "
                         f"[spec {spec_index}]")


class InjectedIOError(OSError):
    """Scripted transient I/O failure (IS an OSError on purpose)."""


class InjectedReplicaError(RuntimeError):
    """Scripted replica failure raised inside scheduler dispatch."""


KINDS = ("crash", "io_error", "bit_flip", "replica_fail", "replica_slow")


@dataclass
class FaultSpec:
    """One scripted fault.

    kind   : one of KINDS.
    point  : fire-point name to match (e.g. "step", "write", "replica").
    match  : substring the fire detail must contain ("" matches all).
    nth    : 1-based matching occurrence to fire on; 0 = every match.
    arg    : kind-specific payload — replica_slow: delay seconds;
             bit_flip: byte offset (-1 = seeded-random offset).
    """
    kind: str
    point: str
    match: str = ""
    nth: int = 1
    arg: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclass
class FaultPlan:
    specs: List[FaultSpec] = field(default_factory=list)
    seed: int = 0
    # per-spec match counters and fired flags (not serialized: state)
    _counts: Dict[int, int] = field(default_factory=dict, repr=False)
    fired: List[str] = field(default_factory=list, repr=False)

    # -- construction helpers ------------------------------------------------
    @classmethod
    def crash_at_step(cls, n: int, seed: int = 0) -> "FaultPlan":
        """Kill at the start of training step ``n`` (0-based iteration
        count, matching the engines' ``fire("step", f"iter:{n}")``)."""
        return cls([FaultSpec("crash", "step", f"iter:{n},")], seed=seed)

    @classmethod
    def crash_at_point(cls, point: str, match: str = "", nth: int = 1,
                       seed: int = 0) -> "FaultPlan":
        return cls([FaultSpec("crash", point, match, nth)], seed=seed)

    @classmethod
    def io_error_on_read(cls, match: str = "", nth: int = 1,
                         seed: int = 0) -> "FaultPlan":
        return cls([FaultSpec("io_error", "read", match, nth)], seed=seed)

    @classmethod
    def replica_fail(cls, rid: int, nth: int = 0, seed: int = 0) -> "FaultPlan":
        """Replica ``rid`` raises on every dispatch (nth=0) or the nth."""
        return cls([FaultSpec("replica_fail", "replica", f"replica:{rid},",
                              nth)], seed=seed)

    @classmethod
    def replica_slow(cls, rid: int, delay: float, nth: int = 0,
                     seed: int = 0) -> "FaultPlan":
        return cls([FaultSpec("replica_slow", "replica", f"replica:{rid},",
                              nth, delay)], seed=seed)

    # -- serialization -------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "format": PLAN_FORMAT, "seed": self.seed,
            "specs": [{"kind": s.kind, "point": s.point, "match": s.match,
                       "nth": s.nth, "arg": s.arg} for s in self.specs],
        })

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        obj = json.loads(text)
        if obj.get("format") != PLAN_FORMAT:
            raise ValueError(f"not a {PLAN_FORMAT} document")
        return cls([FaultSpec(**s) for s in obj["specs"]],
                   seed=int(obj.get("seed", 0)))

    # -- matching ------------------------------------------------------------
    def _matching(self, point: str, detail: str):
        """Yield (index, spec) for specs due to fire NOW, advancing the
        per-spec occurrence counters."""
        for i, s in enumerate(self.specs):
            if s.point != point or s.match not in detail:
                continue
            self._counts[i] = self._counts.get(i, 0) + 1
            if s.nth == 0 or self._counts[i] == s.nth:
                self.fired.append(f"{s.kind}@{point}({detail})")
                yield i, s

    def fire(self, point: str, detail: str = "") -> None:
        """Raise / corrupt per any spec matching this fire point."""
        for i, s in self._matching(point, detail):
            if s.kind == "crash":
                raise InjectedCrash(point, detail, i)
            if s.kind == "io_error":
                raise InjectedIOError(
                    f"injected I/O error at {point}({detail}) [spec {i}]")
            if s.kind == "replica_fail":
                raise InjectedReplicaError(
                    f"injected replica failure at {point}({detail}) "
                    f"[spec {i}]")
            if s.kind == "bit_flip":
                from repro.data import integrity
                offset = None if s.arg < 0 else int(s.arg)
                if os.path.exists(detail):
                    integrity.flip_byte(detail, offset=offset,
                                        seed=self.seed + i)
            # replica_slow contributes no exception here; see delay()

    def delay(self, point: str, detail: str = "") -> float:
        """Total scripted slowdown (seconds) for this fire point.  Kept
        separate from fire() so call sites that cannot raise (pure
        latency modelling) query it without risking an exception."""
        total = 0.0
        for i, s in enumerate(self.specs):
            if s.kind != "replica_slow" or s.point != point \
                    or s.match not in detail:
                continue
            key = ("delay", i)
            self._counts[key] = self._counts.get(key, 0) + 1  # type: ignore
            if s.nth == 0 or self._counts[key] == s.nth:  # type: ignore
                total += float(s.arg)
        return total


# ---------------------------------------------------------------------------
# Module-global activation (plus env-var pickup for child processes)
# ---------------------------------------------------------------------------

_active: Optional[FaultPlan] = None
_env_checked = False


def activate(plan: Optional[FaultPlan]) -> None:
    global _active, _env_checked
    _active = plan
    _env_checked = True  # explicit activation overrides env pickup


def deactivate() -> None:
    activate(None)


def active() -> Optional[FaultPlan]:
    """The active plan, if any.  On first query, picks up
    ``REPRO_FAULT_PLAN`` from the environment so a supervisor (or CI)
    can inject into a child process it execs."""
    global _active, _env_checked
    if not _env_checked:
        _env_checked = True
        text = os.environ.get(ENV_VAR)
        if text:
            _active = FaultPlan.from_json(text)
    return _active


def fire(point: str, detail: str = "") -> None:
    plan = active()
    if plan is not None:
        plan.fire(point, detail)


def delay(point: str, detail: str = "") -> float:
    plan = active()
    return plan.delay(point, detail) if plan is not None else 0.0


class injected:
    """Context manager scoping a plan to a ``with`` block.  Deactivates
    in ``finally`` — mandatory, since :class:`InjectedCrash` is a
    BaseException and would otherwise leave the plan armed for the next
    test."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan

    def __enter__(self) -> FaultPlan:
        activate(self.plan)
        return self.plan

    def __exit__(self, *exc) -> bool:
        deactivate()
        return False


__all__ = [
    "ENV_VAR", "PLAN_FORMAT", "KINDS", "InjectedCrash", "InjectedIOError",
    "InjectedReplicaError", "FaultSpec", "FaultPlan", "activate",
    "deactivate", "active", "fire", "delay", "injected",
]
