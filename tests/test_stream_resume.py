"""Out-of-core streaming + bit-exact checkpoint/resume (DESIGN.md §13).

Three pillars, each proven bitwise:

1. **The rng stream splits.**  The streaming engine draws its uniforms in
   per-round chunks and its initial assignments in per-shard chunks, and
   the resume path serializes the generator through JSON.  All three
   lean on numpy Generator properties that are pinned here so a numpy
   upgrade that silently changes them fails THESE tests, not a 2-hour
   training run.
2. **Streaming == in-memory.**  `StreamingLDA` — one resident ``[Vb, K]``
   block, per-(row, block) state loaded from disk on demand — produces
   the identical chain to `ModelParallelLDA` holding everything in RAM:
   same counts, same ``C_k``, same assignments, across samplers and
   (S, D) geometries.
3. **Resume == uninterrupted.**  A run killed at an iteration boundary
   and resumed from its checkpoint is draw-for-draw the run that never
   stopped — for the streaming engine, the device engine on BOTH
   backends (including resuming a vmap checkpoint on shard_map and vice
   versa — checkpoints carry no backend state), and the host KV-store
   oracle; and the resumed engine still replays the resumed oracle.

Plus the satellite regime-map decision table (``--sampler auto``) and
the row-restricted sharded-snapshot serving path
(`load_snapshot_rows`), which must fold in bitwise-equal to the full
snapshot.
"""
import json
import os

import numpy as np
import pytest

from repro.core.infer import (fold_in, load_sharded_snapshot_meta,
                              load_snapshot_rows, pack_queries)
from repro.core.kvstore import HostModelParallelLDA
from repro.core.model_parallel import ModelParallelLDA
from repro.data.stream import ShardedCorpus, shard_corpus
from repro.launch.samplers import (REGIME_MAP, regime_sampler,
                                   resolve_sampler_choice)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _on_tpu() -> bool:
    import jax
    return jax.default_backend() == "tpu"


def _assert_chains_equal(a, b, ctx: str):
    """Full observable chain state: counts, C_k, and every assignment."""
    sa, sb = a.gather_counts(), b.gather_counts()
    np.testing.assert_array_equal(np.asarray(sa.ckt), np.asarray(sb.ckt),
                                  err_msg=f"{ctx}: ckt diverged")
    np.testing.assert_array_equal(np.asarray(sa.cdk), np.asarray(sb.cdk),
                                  err_msg=f"{ctx}: cdk diverged")
    np.testing.assert_array_equal(np.asarray(sa.ck), np.asarray(sb.ck),
                                  err_msg=f"{ctx}: ck diverged")
    np.testing.assert_array_equal(a.assignments(), b.assignments(),
                                  err_msg=f"{ctx}: z diverged")


# ---------------------------------------------------------------------------
# (1) numpy Generator contracts the streaming/resume design relies on
# ---------------------------------------------------------------------------

def test_rng_integers_chunked_equals_one_shot():
    """Drawing N ints in sequential chunks equals one N-draw — the
    streaming init draws z0 per corpus shard and must match the
    in-memory engine's single draw over the whole token stream."""
    one = np.random.default_rng(42).integers(0, 50, size=100)
    rng = np.random.default_rng(42)
    parts = [rng.integers(0, 50, size=n) for n in (10, 25, 65)]
    np.testing.assert_array_equal(np.concatenate(parts), one)


def test_rng_random_c_order_chunking():
    """A ``[B, R, cap]`` float32 draw equals B sequential ``[R, cap]``
    draws — the streaming engine draws uniforms per ROUND while the
    in-memory engine draws the whole iteration at once."""
    one = np.random.default_rng(7).random((3, 4, 5), dtype=np.float32)
    rng = np.random.default_rng(7)
    parts = [rng.random((4, 5), dtype=np.float32) for _ in range(3)]
    np.testing.assert_array_equal(np.stack(parts), one)


def test_rng_bitgen_state_json_roundtrip():
    """``bit_generator.state`` survives a JSON round trip (PCG64's
    128-bit integers are Python ints) and restores the exact stream —
    the checkpoint serializes the generator this way."""
    rng = np.random.default_rng(123)
    rng.random(17)                      # advance off the seed point
    rng.integers(0, 9, 5)
    state = json.loads(json.dumps(rng.bit_generator.state))
    fresh = np.random.default_rng()
    fresh.bit_generator.state = state
    np.testing.assert_array_equal(fresh.random(8), rng.random(8))
    np.testing.assert_array_equal(fresh.integers(0, 99, 8),
                                  rng.integers(0, 99, 8))


# ---------------------------------------------------------------------------
# (2) streaming == in-memory, across samplers and geometries
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_sharded(tiny_corpus, tmp_path_factory):
    """The tiny corpus, sharded on disk (3 shards) next to its in-memory
    twin."""
    corpus, _, _ = tiny_corpus
    out = str(tmp_path_factory.mktemp("sharded") / "corpus")
    shard_corpus(corpus, out, num_shards=3)
    return corpus, ShardedCorpus(out)


def test_shard_roundtrip_preserves_stream(tiny_sharded):
    """Sharding is a pure re-layout: concatenating the shards (and
    ``to_corpus``) reproduces the original token stream exactly."""
    corpus, sc = tiny_sharded
    back = sc.to_corpus()
    np.testing.assert_array_equal(back.doc, corpus.doc)
    np.testing.assert_array_equal(back.word, corpus.word)
    cat = np.concatenate([s.word for s in sc.iter_shards()])
    np.testing.assert_array_equal(cat, corpus.word)
    assert sc.max_doc_len == int(corpus.doc_lengths().max())


@pytest.mark.parametrize("mode,m,s,d", [
    ("scan", 2, 1, 1),
    ("mh", 2, 2, 2),       # traveling tables + pipelining + replicas
    ("sparse", 3, 1, 1),
])
def test_streaming_equals_in_memory(tiny_sharded, tmp_path, mode, m, s, d):
    from repro.core.engine.streaming import StreamingLDA
    corpus, sc = tiny_sharded
    mem = ModelParallelLDA(corpus, num_topics=8, num_workers=m, seed=11,
                           sampler_mode=mode, blocks_per_worker=s,
                           data_parallel=d)
    disk = StreamingLDA(sc, str(tmp_path / "run"), num_topics=8,
                        num_workers=m, seed=11, sampler_mode=mode,
                        blocks_per_worker=s, data_parallel=d)
    for _ in range(2):
        mem.step()
        disk.step()
    _assert_chains_equal(mem, disk, f"stream vs mem {mode} S={s} D={d}")
    # the resident set really is one block: [Vb, K] of the full [V, K]
    # (>= because the partition pads V up to a multiple of the blocks)
    rep = disk.memory_report()
    assert rep["resident_block_bytes"] * disk.num_blocks \
        >= rep["total_model_bytes"]
    assert rep["resident_block_bytes"] < rep["total_model_bytes"]


def test_streaming_resume_equals_uninterrupted(tiny_sharded, tmp_path):
    """Kill-at-boundary semantics: checkpoint at iter 2, keep training
    (dirtying the live state), then resume from the checkpoint and run
    to iter 4 — identical to the run that never stopped, including the
    restored rng stream."""
    from repro.core.engine.streaming import StreamingLDA
    _, sc = tiny_sharded
    kw = dict(num_topics=8, num_workers=2, seed=5, sampler_mode="mh",
              blocks_per_worker=2)
    a = StreamingLDA(sc, str(tmp_path / "straight"), **kw)
    for _ in range(4):
        a.step()
    b = StreamingLDA(sc, str(tmp_path / "killed"), **kw)
    b.step()
    b.step()
    b.save_checkpoint()
    b.step()                          # state now PAST the checkpoint
    c = StreamingLDA.resume(str(tmp_path / "killed"))
    assert c.iteration_count == 2     # rolled back to the checkpoint
    c.step()
    c.step()
    _assert_chains_equal(a, c, "streaming resume")
    assert c.iteration_count == 4


def test_streaming_resume_rejects_non_run_dir(tmp_path):
    from repro.core.engine.streaming import StreamingLDA
    with pytest.raises((ValueError, OSError)):
        StreamingLDA.resume(str(tmp_path / "nothing-here"))


# ---------------------------------------------------------------------------
# (3) device-engine checkpoint/resume — vmap, shard_map, and across
# ---------------------------------------------------------------------------

def _interrupted(corpus, path, make, stop=2, total=4):
    """Run ``stop`` iters, checkpoint, dirty the live state, resume, and
    finish to ``total`` iters.  Returns the resumed trainer."""
    b = make()
    for _ in range(stop):
        b.step()
    b.save_checkpoint(path)
    b.step()                          # past the checkpoint; discarded
    c = ModelParallelLDA.resume(corpus, path)
    assert c.iteration_count == stop
    for _ in range(total - stop):
        c.step()
    return c


@pytest.mark.parametrize("mode,s,d", [
    ("mh", 1, 1), ("sparse", 2, 1), ("scan", 1, 2),
])
def test_mp_resume_equals_uninterrupted(tiny_corpus, tmp_path, mode, s, d):
    corpus, _, _ = tiny_corpus
    kw = dict(num_topics=8, num_workers=2, seed=3, sampler_mode=mode,
              blocks_per_worker=s, data_parallel=d)
    a = ModelParallelLDA(corpus, **kw)
    for _ in range(4):
        a.step()
    c = _interrupted(corpus, str(tmp_path / "ck.npz"),
                     lambda: ModelParallelLDA(corpus, **kw))
    _assert_chains_equal(a, c, f"mp resume {mode} S={s} D={d}")
    assert c.iteration_count == 4


@pytest.mark.parametrize("s", [1, 2])
def test_mp_resume_across_backends(tiny_corpus, mesh2d, tmp_path, s):
    """Checkpoints are backend-agnostic: a shard_map checkpoint resumes
    bit-exactly on vmap and a vmap checkpoint on shard_map — both equal
    the uninterrupted vmap run."""
    corpus, _, _ = tiny_corpus
    kw = dict(num_topics=8, num_workers=2, seed=1, sampler_mode="mh",
              blocks_per_worker=s, data_parallel=2)
    a = ModelParallelLDA(corpus, **kw)
    for _ in range(4):
        a.step()

    # shard_map run -> checkpoint -> vmap resume
    b = ModelParallelLDA(corpus, **kw, backend="shard_map", mesh=mesh2d,
                         axis="model")
    b.step()
    b.step()
    p = str(tmp_path / "sm.npz")
    b.save_checkpoint(p)
    c = ModelParallelLDA.resume(corpus, p)            # vmap continuation
    c.step()
    c.step()
    _assert_chains_equal(a, c, f"shard_map ckpt -> vmap resume S={s}")

    # vmap run -> checkpoint -> shard_map resume
    v = ModelParallelLDA(corpus, **kw)
    v.step()
    v.step()
    q = str(tmp_path / "vm.npz")
    v.save_checkpoint(q)
    w = ModelParallelLDA.resume(corpus, q, backend="shard_map",
                                mesh=mesh2d, axis="model")
    w.step()
    w.step()
    _assert_chains_equal(a, w, f"vmap ckpt -> shard_map resume S={s}")


def test_mp_resume_rejects_wrong_corpus(tiny_corpus, small_corpus,
                                        tmp_path):
    """The corpus fingerprint guards against resuming onto different
    data — the layout is derived from the corpus, so a silent mismatch
    would scramble every assignment."""
    corpus, _, _ = tiny_corpus
    other, _, _ = small_corpus
    lda = ModelParallelLDA(corpus, num_topics=8, num_workers=2, seed=0)
    lda.step()
    p = str(tmp_path / "ck.npz")
    lda.save_checkpoint(p)
    with pytest.raises(ValueError, match="corpus does not match"):
        ModelParallelLDA.resume(other, p)


def test_host_oracle_resume_and_replay(tiny_corpus, tmp_path):
    """The KV-store oracle checkpoints/resumes bit-exactly too, and the
    resumed device engine still replays the resumed oracle draw for
    draw — the staleness contract survives a kill on either side."""
    corpus, _, _ = tiny_corpus
    hkw = dict(num_topics=8, num_workers=2, seed=7, blocks_per_worker=2,
               sampler="scan", ck_sync="round")
    ekw = dict(num_topics=8, num_workers=2, seed=7, blocks_per_worker=2,
               sampler_mode="scan")
    host_a = HostModelParallelLDA(corpus, **hkw)
    for _ in range(4):
        host_a.step()

    host_b = HostModelParallelLDA(corpus, **hkw)
    host_b.step()
    host_b.step()
    hp = str(tmp_path / "host.npz")
    host_b.save_checkpoint(hp)
    host_b.step()
    host_c = HostModelParallelLDA.resume(corpus, hp)
    host_c.step()
    host_c.step()
    np.testing.assert_array_equal(host_a.gather_ckt(),
                                  host_c.gather_ckt())
    np.testing.assert_array_equal(host_a.assignments(),
                                  host_c.assignments())

    eng = ModelParallelLDA(corpus, **ekw)
    eng.step()
    eng.step()
    ep = str(tmp_path / "eng.npz")
    eng.save_checkpoint(ep)
    eng_r = ModelParallelLDA.resume(corpus, ep)
    eng_r.step()
    eng_r.step()
    np.testing.assert_array_equal(np.asarray(eng_r.gather_counts().ckt),
                                  host_c.gather_ckt(),
                                  err_msg="resumed engine != resumed "
                                          "oracle")
    np.testing.assert_array_equal(eng_r.assignments(),
                                  host_c.assignments())


# ---------------------------------------------------------------------------
# satellite: the --sampler auto regime map (PR-6 measurements)
# ---------------------------------------------------------------------------

def test_regime_map_exact_at_measured_cells():
    for (k, length), family in REGIME_MAP.items():
        assert regime_sampler(k, length) == family, (k, length)


@pytest.mark.parametrize("k,length,family", [
    (16, 46, "sparse"),        # tiny K snaps to the (256, 48) cell
    (300, 200, "mh"),          # the short-K/long-doc MH corner
    (4096, 64, "sparse"),      # log2(64) is nearer 48 than 256
    (65536, 256, "sparse"),    # the big-model regime extrapolates
    (65536, 16, "sparse"),     # ... from the K=16384 row
    (2048, 16, "scan"),        # nearer the 4096 row than the 256 row
])
def test_regime_map_snaps_in_log_space(k, length, family):
    assert regime_sampler(k, length) == family


@pytest.mark.skipif(_on_tpu(), reason="auto resolves to Pallas on TPU")
def test_auto_uses_workload_and_falls_back():
    assert resolve_sampler_choice(
        "auto", num_topics=4096, max_doc_len=16) == "scan"
    assert resolve_sampler_choice(
        "auto", num_topics=16384, max_doc_len=100) == "sparse"
    # no workload parameters -> the pre-regime-map default
    assert resolve_sampler_choice("auto") == "mh"
    # explicit pallas off-TPU: refused without --force
    with pytest.raises(SystemExit):
        resolve_sampler_choice("mh_pallas")
    assert resolve_sampler_choice("mh_pallas", force=True) == "mh_pallas"


# ---------------------------------------------------------------------------
# sharded snapshot serving: row-restricted fold-in is bitwise the full one
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def trained_sharded_snapshot(tiny_sharded, tmp_path_factory):
    from repro.core.engine.streaming import StreamingLDA
    _, sc = tiny_sharded
    wd = tmp_path_factory.mktemp("snap")
    lda = StreamingLDA(sc, str(wd / "run"), num_topics=8, num_workers=2,
                       seed=2, sampler_mode="scan", blocks_per_worker=2)
    lda.step()
    lda.step()
    out = str(wd / "export")
    lda.save_snapshot_sharded(out)
    return lda.snapshot(), out


def test_sharded_snapshot_meta_and_blocks(trained_sharded_snapshot):
    full, snap_dir = trained_sharded_snapshot
    meta = load_sharded_snapshot_meta(snap_dir)
    assert meta["vocab_size"] == full.vocab_size
    assert meta["num_topics"] == full.num_topics
    blocks = [np.load(os.path.join(snap_dir, f"block_{b:05d}.npy"))
              for b in range(meta["num_blocks"])]
    np.testing.assert_array_equal(
        np.concatenate(blocks)[:full.vocab_size], np.asarray(full.ckt))


def test_sharded_snapshot_rejects_bad_dir(tmp_path):
    with pytest.raises(ValueError, match="not a sharded snapshot"):
        load_sharded_snapshot_meta(str(tmp_path))
    (tmp_path / "meta.json").write_text('{"format": "something-else"}')
    with pytest.raises(ValueError, match="unknown snapshot format"):
        load_sharded_snapshot_meta(str(tmp_path))


def test_row_restricted_fold_in_bitwise(trained_sharded_snapshot):
    """Serving from the row-restricted view — only the rows the batch's
    distinct words touch, with ``true_vocab_size`` keeping the ``Vβ``
    smoothing honest — folds in BITWISE equal to the full snapshot."""
    full, snap_dir = trained_sharded_snapshot
    rng = np.random.default_rng(9)
    docs = [rng.integers(0, full.vocab_size, size=n).astype(np.int32)
            for n in (12, 5, 20)]
    word, mask = pack_queries(docs, t_pad=24, q_pad=4)

    sub, remapped = load_snapshot_rows(snap_dir, word)
    assert sub.true_vocab_size == full.vocab_size
    assert sub.vocab_size == np.unique(word).shape[0]
    assert sub.vbeta == full.vbeta
    # the view holds exactly the referenced rows
    np.testing.assert_array_equal(np.asarray(sub.ckt)[remapped],
                                  np.asarray(full.ckt)[word])

    for sampler in ("scan", "mh", "sparse"):
        a = fold_in(full, word, mask, num_sweeps=3, sampler=sampler,
                    seed=4)
        b = fold_in(sub, remapped, mask, num_sweeps=3, sampler=sampler,
                    seed=4)
        np.testing.assert_array_equal(
            np.asarray(a.theta), np.asarray(b.theta),
            err_msg=f"{sampler}: row-restricted theta diverged")
