"""Frozen pre-2D reference implementation of the 1D worker-ring engine.

This module is a verbatim snapshot of ``backends.py`` as it stood before
the engine was generalized to the 2D ``(data, model)`` mesh (DESIGN.md
§8).  It exists ONLY for the bit-exactness harness: ``backends.py`` must
produce exactly these results whenever ``data_parallel == 1``, and
``tests/test_engine_2d.py`` enforces that by stepping the same state
through both implementations and comparing every array bitwise.

Do not extend this module — new engine features belong in ``backends.py``;
this file only changes if the frozen 1D semantics themselves are ever
deliberately re-baselined (which requires re-proving oracle equality).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core import schedule as sched
from repro.core.engine.rounds import resolve_sampler, worker_round
from repro.core.engine.state import MPState


@partial(jax.jit, static_argnames=("sampler_mode", "sync_ck"))
def iteration_vmap_1d(state: MPState, u, doc, woff, mask, alpha, beta,
                      vbeta, sampler_mode: str = "scan",
                      sync_ck: bool = True):
    """One full iteration = S·M rounds with rotation, stacked on one device.

    ``u`` is ``[B, M, T]`` — one uniform per (round, worker, token slot).
    """
    sampler = resolve_sampler(sampler_mode)
    round_fn = partial(worker_round, sampler=sampler)

    def round_step(carry, u_r):
        cdk, ckt, blk, ck_syn, ck_loc, z = carry
        res_ckt = ckt[:, 0]
        res_blk = blk[:, 0]
        cdk, res_ckt, ck_loc, z = jax.vmap(
            round_fn, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0,
                               None, None, None))(
            cdk, res_ckt, res_blk, ck_loc, z, u_r, doc, woff, mask,
            alpha, beta, vbeta)
        res_ckt = jnp.roll(res_ckt, -1, axis=0)
        res_blk = jnp.roll(res_blk, -1, axis=0)
        ckt = jnp.concatenate([ckt[:, 1:], res_ckt[:, None]], axis=1)
        blk = jnp.concatenate([blk[:, 1:], res_blk[:, None]], axis=1)
        ck_true = ck_syn + (ck_loc - ck_syn[None, :]).sum(axis=0)
        n_tok = jnp.maximum(ck_true.sum(), 1).astype(jnp.float32)
        err = (jnp.abs(ck_loc - ck_true[None, :]).sum().astype(jnp.float32)
               / (ck_loc.shape[0] * n_tok))
        if sync_ck:
            ck_loc = jnp.broadcast_to(ck_true, ck_loc.shape)
            ck_syn = ck_true
        return (cdk, ckt, blk, ck_syn, ck_loc, z), err

    carry = (state.cdk, state.ckt, state.block_id, state.ck_synced,
             state.ck_local, state.z)
    carry, errs = jax.lax.scan(round_step, carry, u)
    return MPState(*carry), errs


def make_shard_map_iteration_1d(mesh: Mesh, axis: str, sampler_mode: str,
                                sync_ck: bool):
    """Build the jitted per-device iteration function for a 1-axis mesh."""
    perm = sched.rotation_permutation(mesh.shape[axis])
    sampler = resolve_sampler(sampler_mode)

    def per_device(cdk, ckt, blk, ck_syn, ck_loc, z, u, doc, woff, mask,
                   alpha, beta, vbeta):
        cdk, ckt, blk, ck_loc, z = (x[0] for x in (cdk, ckt, blk, ck_loc, z))
        doc, woff, mask, u = (x[0] for x in (doc, woff, mask, u))

        def round_step(carry, u_r):
            cdk, ckt, blk, ck_syn, ck_loc, z = carry
            res_ckt = ckt[0]
            res_blk = blk[0]
            cdk, res_ckt, ck_loc, z = worker_round(
                cdk, res_ckt, res_blk, ck_loc, z, u_r, doc, woff, mask,
                alpha, beta, vbeta, sampler=sampler)
            res_ckt = jax.lax.ppermute(res_ckt, axis, perm)
            res_blk = jax.lax.ppermute(res_blk, axis, perm)
            ckt = jnp.concatenate([ckt[1:], res_ckt[None]], axis=0)
            blk = jnp.concatenate([blk[1:], res_blk[None]], axis=0)
            ck_true = ck_syn + jax.lax.psum(ck_loc - ck_syn, axis)
            n_tok = jnp.maximum(ck_true.sum(), 1).astype(jnp.float32)
            err = jax.lax.pmean(
                jnp.abs(ck_loc - ck_true).sum().astype(jnp.float32),
                axis) / n_tok
            if sync_ck:
                ck_loc = ck_true
                ck_syn = ck_true
            return (cdk, ckt, blk, ck_syn, ck_loc, z), err

        carry, errs = jax.lax.scan(
            round_step, (cdk, ckt, blk, ck_syn, ck_loc, z), u)
        cdk, ckt, blk, ck_syn, ck_loc, z = carry
        return (cdk[None], ckt[None], blk[None], ck_syn, ck_loc[None],
                z[None], errs)

    w = P(axis)
    return jax.jit(compat.shard_map(
        per_device, mesh=mesh,
        in_specs=(w, w, w, P(), w, w, w, w, w, w, P(), P(), P()),
        out_specs=(w, w, w, P(), w, w, P()),
        check_vma=False))
