"""Serving driver: answer topic-inference queries from a frozen snapshot.

    # serve a snapshot exported by `lda_train --snapshot-out`
    PYTHONPATH=src python -m repro.launch.lda_infer \
        --snapshot /tmp/snap.npz --queries 16 --query-len 32 --sampler mh

    # serve a SHARDED snapshot (lda_train --snapshot-dir) out-of-core:
    # only the [U, K] rows the batch's distinct words hit are loaded
    PYTHONPATH=src python -m repro.launch.lda_infer \
        --snapshot-dir /tmp/snapdir --queries 16 --query-len 32

    # self-contained demo: train a tiny model, hold docs out, serve them
    PYTHONPATH=src python -m repro.launch.lda_infer \
        --docs 200 --vocab 500 --topics 20 --train-iters 10 --queries 16

Loads (or trains) a model, stands up a :class:`TopicInferenceServer`,
infers ``θ̂`` for a batch of unseen documents, and reports the batch
latency plus the doc-completion perplexity of the queries.  Exits
non-zero if the perplexity is not finite — the CI smoke contract
(`scripts/ci.sh` passes 5 and 7).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import types

import numpy as np

from repro.core.infer import (load_sharded_snapshot_meta, load_snapshot,
                              load_snapshot_rows)
from repro.data.corpus import load_corpus, split_corpus
from repro.launch.samplers import (infer_sampler_choices,
                                   resolve_sampler_choice)
from repro.serve.topic_infer import TopicInferenceServer


def _queries_from_args(args, snap):
    """Query docs: a saved corpus (`--query-corpus`), else random words —
    uniform queries are the worst case for the model, but perplexity is
    still finite because ``φ̂`` is β-smoothed everywhere."""
    if args.query_corpus:
        corpus = load_corpus(args.query_corpus)
        if corpus.vocab_size > snap.vocab_size:
            raise SystemExit(
                f"query corpus vocab ({corpus.vocab_size}) exceeds the "
                f"snapshot's ({snap.vocab_size})")
        return corpus.doc_words()[:args.queries]
    rng = np.random.default_rng(args.seed + 1)
    return [rng.integers(0, snap.vocab_size,
                         size=args.query_len).astype(np.int32)
            for _ in range(args.queries)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--snapshot", default="",
                    help="frozen snapshot (.npz from lda_train "
                         "--snapshot-out); empty = self-train a tiny "
                         "model and query its held-out docs")
    ap.add_argument("--snapshot-dir", default="",
                    help="sharded snapshot directory (lda_train "
                         "--snapshot-dir): loads only the count rows the "
                         "query batch touches — the full [V, K] model "
                         "never enters memory (DESIGN.md §13)")
    ap.add_argument("--query-corpus", default="",
                    help="saved corpus whose docs become the queries "
                         "(with --snapshot)")
    ap.add_argument("--sampler", choices=infer_sampler_choices(),
                    default="mh",
                    help="fold-in sampler (DESIGN.md §11–§12): exact "
                         "scan, the O(1) alias-table MH pair, or the "
                         "hybrid sparse family; 'auto' picks per "
                         "platform")
    ap.add_argument("--force", action="store_true",
                    help="run an explicitly requested *_pallas sampler "
                         "in interpret mode off-TPU instead of refusing")
    ap.add_argument("--sweeps", type=int, default=5)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--query-len", type=int, default=32)
    ap.add_argument("--top", type=int, default=3,
                    help="top topics to print per query")
    # self-train flags (ignored with --snapshot)
    ap.add_argument("--docs", type=int, default=120)
    ap.add_argument("--vocab", type=int, default=300)
    ap.add_argument("--topics", type=int, default=12)
    ap.add_argument("--doc-len", type=int, default=40)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--train-iters", type=int, default=5)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--beta", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    args.sampler = resolve_sampler_choice(args.sampler, force=args.force)

    if args.snapshot and args.snapshot_dir:
        ap.error("--snapshot and --snapshot-dir are mutually exclusive")
    if args.snapshot_dir:
        meta = load_sharded_snapshot_meta(args.snapshot_dir)
        # queries live in the TRUE vocab id space; the row-restricted
        # view remaps them after the batch's word set is known
        queries = _queries_from_args(
            args, types.SimpleNamespace(vocab_size=meta["vocab_size"]))
        lens = [len(d) for d in queries]
        flat = np.concatenate([np.asarray(d, np.int32) for d in queries])
        snap, remapped = load_snapshot_rows(args.snapshot_dir, flat)
        queries = np.split(remapped, np.cumsum(lens)[:-1])
        print(f"sharded snapshot: V={meta['vocab_size']:,} "
              f"K={meta['num_topics']} ({meta['num_blocks']} block "
              f"files, store={meta['store']}); batch touches "
              f"{snap.vocab_size:,} distinct "
              f"words -> resident rows [{snap.vocab_size}, "
              f"{snap.num_topics}] "
              f"({snap.ckt.nbytes / 2**20:.2f} MiB of "
              f"{meta['vocab_size'] * meta['num_topics'] * 4 / 2**20:.1f}"
              f" MiB full model)")
        if meta["store"] != "dense":
            # densification is never silent (DESIGN.md §16): only the
            # touched rows decode to dense — never the full model
            print(f"NOTE: store={meta['store']!r} block records decode "
                  f"their touched rows to dense [U, K] for serving")
    elif args.snapshot:
        snap = load_snapshot(args.snapshot)
        print(f"snapshot: V={snap.vocab_size} K={snap.num_topics} "
              f"({snap.ck.sum():,} training tokens)")
        queries = _queries_from_args(args, snap)
    else:
        from repro.core.model_parallel import ModelParallelLDA
        from repro.data.synthetic import synthetic_corpus
        corpus, _, _ = synthetic_corpus(args.docs, args.vocab, args.topics,
                                        args.doc_len, seed=args.seed)
        corpus, held = split_corpus(corpus, args.queries)
        print(f"self-train: {corpus.num_tokens:,} tokens, "
              f"{args.train_iters} iters; querying the {held.num_docs} "
              f"held-out docs")
        lda = ModelParallelLDA(corpus, args.topics, args.workers,
                               alpha=args.alpha, beta=args.beta,
                               seed=args.seed)
        lda.run(args.train_iters)
        snap = lda.snapshot()
        queries = held.doc_words()

    server = TopicInferenceServer(snap, sampler=args.sampler,
                                  num_sweeps=args.sweeps, seed=args.seed)
    t0 = time.perf_counter()
    theta = server.infer(queries)          # includes jit compile
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    theta = server.infer(queries)
    warm_s = time.perf_counter() - t0
    qb, tb = server.bucket_shape(queries)
    print(f"batch of {len(queries)} queries -> bucket ({qb}, {tb}); "
          f"cold {cold_s * 1e3:.1f} ms, warm {warm_s * 1e3:.1f} ms "
          f"({len(queries) / warm_s:,.1f} queries/s)")
    for i, th in enumerate(theta[:min(len(queries), 4)]):
        top = np.argsort(th)[::-1][:args.top]
        desc = ", ".join(f"k{t}:{th[t]:.2f}" for t in top)
        print(f"  query {i}: {desc}")

    ppl = server.perplexity(queries)
    true_v = snap.true_vocab_size or snap.vocab_size
    print(f"doc-completion perplexity: {ppl['perplexity']:,.2f} over "
          f"{ppl['tokens_scored']} scored tokens "
          f"(V = {true_v} is the uninformative ceiling)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"perplexity": ppl, "warm_batch_s": warm_s,
                       "cold_batch_s": cold_s,
                       "bucket": [qb, tb],
                       "theta": np.asarray(theta).tolist()}, f, indent=1)
    if not np.isfinite(ppl["perplexity"]):
        sys.exit("non-finite held-out perplexity — serving smoke FAILED")


if __name__ == "__main__":
    main()
