"""Pallas kernel sweeps: shapes × dtypes vs the pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sampler import sweep_block_batched
from repro.kernels.ops import (gibbs_conditional, group_tokens_by_word,
                               sweep_block_pallas)
from repro.kernels.ref import conditional_mass_ref, gibbs_conditional_ref


def _mk(rng, g, tg, k, dtype=np.float32):
    ckt = rng.integers(0, 60, (g, k)).astype(dtype)
    cdk = rng.integers(0, 12, (g, tg, k)).astype(dtype)
    z = rng.integers(0, k, (g, tg)).astype(np.int32)
    for gi in range(g):       # make exclusion non-negative
        for ti in range(tg):
            ckt[gi, z[gi, ti]] += 1
            cdk[gi, ti, z[gi, ti]] += 1
    ck = ckt.sum(0).astype(dtype) + 50
    u = rng.random((g, tg)).astype(np.float32)
    mask = rng.random((g, tg)) < 0.85
    alpha = (rng.random(k).astype(np.float32) + 0.05)
    return ckt, cdk, z, u, mask, ck, alpha


SHAPES = [(1, 1, 8), (3, 2, 64), (8, 8, 128), (13, 4, 200), (32, 8, 257),
          (5, 16, 1000), (64, 1, 96)]


@pytest.mark.parametrize("g,tg,k", SHAPES)
def test_kernel_matches_ref_over_shapes(g, tg, k):
    rng = np.random.default_rng(g * 1000 + tg * 10 + k)
    ckt, cdk, z, u, mask, ck, alpha = _mk(rng, g, tg, k)
    args = (jnp.asarray(ckt), jnp.asarray(cdk), jnp.asarray(z),
            jnp.asarray(u), jnp.asarray(mask), jnp.asarray(ck),
            jnp.asarray(alpha), 0.01, 0.01 * k)
    out_k = gibbs_conditional(*args)
    out_r = gibbs_conditional_ref(
        args[0], args[1], args[2], args[3],
        jnp.asarray(mask.astype(np.int32)), args[5], args[6], 0.01, 0.01 * k)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("dtype", [np.float32, np.int32, np.float64])
def test_kernel_count_dtypes(dtype):
    """Counts arrive as int or float; wrapper must cast correctly."""
    rng = np.random.default_rng(42)
    ckt, cdk, z, u, mask, ck, alpha = _mk(rng, 8, 4, 64, dtype=np.float32)
    out_a = gibbs_conditional(
        jnp.asarray(ckt.astype(dtype)), jnp.asarray(cdk.astype(dtype)),
        jnp.asarray(z), jnp.asarray(u), jnp.asarray(mask),
        jnp.asarray(ck.astype(dtype)), jnp.asarray(alpha), 0.01, 0.64)
    out_b = gibbs_conditional(
        jnp.asarray(ckt), jnp.asarray(cdk), jnp.asarray(z), jnp.asarray(u),
        jnp.asarray(mask), jnp.asarray(ck), jnp.asarray(alpha), 0.01, 0.64)
    np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_b))


def test_mass_is_valid_distribution():
    rng = np.random.default_rng(1)
    ckt, cdk, z, u, mask, ck, alpha = _mk(rng, 6, 3, 100)
    mass = conditional_mass_ref(jnp.asarray(ckt), jnp.asarray(cdk),
                                jnp.asarray(z), jnp.asarray(ck),
                                jnp.asarray(alpha), 0.01, 1.0)
    m = np.asarray(mass)
    assert (m >= 0).all()
    assert (m.sum(-1) > 0).all()


def test_draws_follow_conditional_distribution():
    """Chi-square check: kernel draws across many uniforms match the
    normalized conditional mass."""
    rng = np.random.default_rng(2)
    k = 16
    ckt, cdk, z, _, _, ck, alpha = _mk(rng, 1, 1, k)
    mass = np.asarray(conditional_mass_ref(
        jnp.asarray(ckt), jnp.asarray(cdk), jnp.asarray(z),
        jnp.asarray(ck), jnp.asarray(alpha), 0.01, 0.16))[0, 0]
    p = mass / mass.sum()
    n = 4000
    us = rng.random(n).astype(np.float32)
    draws = np.asarray(gibbs_conditional(
        jnp.asarray(np.repeat(ckt, 1, 0)),
        jnp.asarray(np.broadcast_to(cdk, (1, n, k)).copy()),
        jnp.asarray(np.broadcast_to(z, (1, n)).copy()),
        jnp.asarray(us[None, :]),
        jnp.ones((1, n), bool), jnp.asarray(ck), jnp.asarray(alpha),
        0.01, 0.16))[0]
    freq = np.bincount(draws, minlength=k) / n
    # inverse-CDF of iid uniforms: strong-law convergence to p
    assert np.abs(freq - p).max() < 0.04


def test_word_grouped_layout_equivalence():
    """Grouped [G, Tg] layout (VMEM-cache form) gives the same draws as the
    degenerate one-token-per-group layout."""
    rng = np.random.default_rng(3)
    k, vb, t = 32, 10, 40
    woff = np.sort(rng.integers(0, vb, t)).astype(np.int32)
    ckt_block = rng.integers(1, 40, (vb, k)).astype(np.float32)
    cdk_rows = rng.integers(0, 8, (t, k)).astype(np.float32)
    z = rng.integers(0, k, t).astype(np.int32)
    for i in range(t):
        ckt_block[woff[i], z[i]] += 1
        cdk_rows[i, z[i]] += 1
    ck = ckt_block.sum(0) + 10
    u = rng.random(t).astype(np.float32)
    alpha = np.full(k, 0.1, np.float32)
    # degenerate layout
    z_flat = np.asarray(gibbs_conditional(
        jnp.asarray(ckt_block[woff]), jnp.asarray(cdk_rows[:, None, :]),
        jnp.asarray(z[:, None]), jnp.asarray(u[:, None]),
        jnp.ones((t, 1), bool), jnp.asarray(ck), jnp.asarray(alpha),
        0.01, 0.32))[:, 0]
    # word-grouped layout
    gw, pos, gm = group_tokens_by_word(woff, group_width=4)
    z_grp = np.asarray(gibbs_conditional(
        jnp.asarray(ckt_block[gw]), jnp.asarray(cdk_rows[pos]),
        jnp.asarray(z[pos]), jnp.asarray(u[pos]), jnp.asarray(gm),
        jnp.asarray(ck), jnp.asarray(alpha), 0.01, 0.32))
    recon = np.zeros(t, np.int32)
    recon[pos[gm]] = z_grp[gm]
    np.testing.assert_array_equal(recon, z_flat)


def test_sweep_pallas_equals_sweep_batched():
    rng = np.random.default_rng(4)
    k, vb, d, t = 24, 12, 9, 70
    doc = rng.integers(0, d, t).astype(np.int32)
    woff = np.sort(rng.integers(0, vb, t)).astype(np.int32)
    z = rng.integers(0, k, t).astype(np.int32)
    mk = rng.random(t) < 0.9
    cdk = np.zeros((d, k), np.int32)
    ckt = np.zeros((vb, k), np.int32)
    for i in range(t):
        if mk[i]:
            cdk[doc[i], z[i]] += 1
            ckt[woff[i], z[i]] += 1
    ck = ckt.sum(0).astype(np.int32)
    u = rng.random(t).astype(np.float32)
    alpha = jnp.full(k, 0.1, jnp.float32)
    args = (jnp.asarray(cdk), jnp.asarray(ckt), jnp.asarray(ck),
            jnp.asarray(doc), jnp.asarray(woff), jnp.asarray(z),
            jnp.asarray(mk), jnp.asarray(u), alpha,
            jnp.float32(0.01), jnp.float32(0.12))
    out_b = sweep_block_batched(*args, None)
    out_p = sweep_block_pallas(*args)
    for a, b in zip(out_b, out_p):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
