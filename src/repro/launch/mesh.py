"""Production mesh construction.

Meshes are built by FUNCTIONS (never at import time) so importing this
module does not touch JAX device state — required because the dry-run
fakes 512 host devices while tests/benches must keep seeing one.
"""
from __future__ import annotations

import numpy as np
from jax.sharding import Mesh

from repro.compat import AxisType, make_mesh


def _auto(n: int):
    return (AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """v5e target: one pod = 16×16 = 256 chips (data × model); the
    multi-pod mesh stacks 2 pods on a leading ``pod`` axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_lda_mesh(num_workers: int, *, data_parallel: int = 1,
                  multi_pod: bool = False) -> Mesh:
    """The paper's worker ring, optionally crossed with data replicas.

    Single-pod, ``data_parallel=1``: a flat ring over all chips.
    ``data_parallel=D``: the hybrid 2D grid — documents sharded over
    ``data`` × the block ring along ``w`` (DESIGN.md §8); this is the LDA
    instantiation of the production ``(data, model)`` mesh.  Multi-pod:
    documents sharded over pods × a ring within each pod (vocabulary
    partitioned pod-major, DESIGN.md §4)."""
    if multi_pod and data_parallel > 1:
        raise ValueError("choose one of multi_pod / data_parallel")
    if multi_pod:
        return make_mesh((2, num_workers), ("pod", "w"),
                         axis_types=_auto(2))
    if data_parallel > 1:
        return make_mesh((data_parallel, num_workers), ("data", "w"),
                         axis_types=_auto(2))
    return make_mesh((num_workers,), ("w",), axis_types=_auto(1))


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over however many (possibly faked) devices exist —
    used by unit tests."""
    return make_mesh((data, model), ("data", "model"),
                     axis_types=_auto(2))


def data_axes(mesh: Mesh):
    """The batch-sharding axes: ('pod','data') on multi-pod meshes."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_devices(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
